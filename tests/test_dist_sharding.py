"""repro.dist sharding rules: spec resolution, divisibility fallback,
train-vs-decode differences, replica placement, and a round-trip through
``sharding_tree`` on the host mesh."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.dist import (
    LOGICAL_AXES,
    ShardingRules,
    make_decode_rules,
    make_replica_set,
    make_train_rules,
)
from repro.launch.mesh import make_host_mesh
from repro.models import Model
from repro.models.params import materialize, sharding_tree


class FakeMesh:
    """Duck-typed mesh for spec-resolution tests: ``spec`` only reads
    ``mesh.shape`` (meshes bigger than the CPU fleet can't be real here)."""

    def __init__(self, **shape):
        self.shape = shape


PROD = dict(data=16, model=16)
POD = dict(pod=2, data=16, model=16)


# ------------------------------------------------------------- resolution
def test_host_mesh_everything_replicated():
    mesh = make_host_mesh()
    rules = make_train_rules(mesh)
    for axes, shape in [
        (("vocab", "embed"), (512, 256)),
        (("embed", "heads"), (256, 256)),
        (("batch", "seq", "embed_act"), (2, 32, 256)),
    ]:
        spec = rules.spec(mesh, axes, shape)
        assert all(s is None for s in spec), (axes, spec)
    assert rules.fallbacks == []  # 1-sized axes never count as lost sharding


def test_train_spec_on_production_mesh():
    mesh = FakeMesh(**PROD)
    rules = make_train_rules(mesh)
    # FSDP (embed over data) x TP (heads/ffn/vocab over model)
    assert rules.spec(mesh, ("embed", "heads"), (1024, 1024)) == P("data", "model")
    assert rules.spec(mesh, ("heads", "embed"), (1024, 1024)) == P("model", "data")
    assert rules.spec(mesh, ("vocab", "embed"), (151_936, 1024)) == P("model", "data")
    # batch over data; norm weights replicated
    assert rules.spec(mesh, ("batch", "seq", "vocab_act"), (256, 4096, 151_936)) \
        == P("data", None, "model")
    assert rules.spec(mesh, ("embed_act",), (1024,)) == P(None)


def test_multi_pod_batch_takes_both_axes():
    mesh = FakeMesh(**POD)
    rules = make_train_rules(mesh)
    spec = rules.spec(mesh, ("batch", "seq", "embed_act"), (256, 4096, 1024))
    assert spec == P(("pod", "data"), None, None)
    # MoE weights: experts over pod, embed over data, expert_ffn over model
    spec = rules.spec(mesh, ("experts", "embed", "expert_ffn"), (128, 7168, 4864))
    assert spec == P("pod", "data", "model")


def test_spec_without_shape_skips_divisibility():
    mesh = FakeMesh(**PROD)
    rules = make_train_rules(mesh)
    assert rules.spec(mesh, (None, "batch", None)) == P(None, "data", None)
    assert rules.fallbacks == []


def test_mesh_axis_never_used_twice():
    mesh = FakeMesh(**PROD)
    rules = ShardingRules({"a": ("model",), "b": ("model",)})
    spec = rules.spec(mesh, ("a", "b"), (64, 64))
    assert spec == P("model", None)
    assert ("b", "model", 64) in rules.fallbacks


# ------------------------------------------------- divisibility fallback
def test_indivisible_dim_falls_back_to_replication():
    mesh = FakeMesh(**PROD)
    rules = make_train_rules(mesh)
    # arctic's 56 q heads * 128 head_dim = 7168 IS divisible; 56 alone isn't
    spec = rules.spec(mesh, ("heads_act",), (56,))
    assert spec == P(None)
    assert ("heads_act", "model", 56) in rules.fallbacks


def test_batch_of_one_replicates_and_records():
    mesh = FakeMesh(**PROD)
    rules = make_decode_rules(mesh, num_kv_heads=16)
    spec = rules.spec(mesh, ("batch",), (1,))  # long_500k
    assert spec == P(None)
    assert ("batch", "data", 1) in rules.fallbacks


def test_partial_axis_product_kept():
    # batch 16 on pod=2 x data=16: pod*data=32 doesn't divide, pod alone does
    mesh = FakeMesh(**POD)
    rules = make_train_rules(mesh)
    assert rules.spec(mesh, ("batch",), (16,)) == P("pod")


# ------------------------------------------------- train vs decode rules
def test_decode_weights_replicated_over_data():
    mesh = FakeMesh(**PROD)
    train = make_train_rules(mesh)
    decode = make_decode_rules(mesh, num_kv_heads=16)
    w = (("embed", "heads"), (1024, 2048))
    assert train.spec(mesh, *w) == P("data", "model")
    assert decode.spec(mesh, *w) == P(None, "model")   # no FSDP at decode


def test_decode_kv_head_sharding_requires_divisibility():
    mesh = FakeMesh(**PROD)
    kv_axes = ("layers", "batch", "kv_seq", "kv_heads_act", "head_dim")
    shape = (24, 128, 32_768, 16, 64)
    ok = make_decode_rules(mesh, num_kv_heads=16)
    assert ok.spec(mesh, kv_axes, shape) == P(None, "data", None, "model", None)
    # 12 KV heads on a 16-way model axis: cache replicates, recorded up front
    bad = make_decode_rules(mesh, num_kv_heads=12)
    assert ("kv_heads_act", "model", 12) in bad.fallbacks
    spec = bad.spec(mesh, kv_axes, (24, 128, 32_768, 12, 64))
    assert spec == P(None, "data", None, None, None)


def test_sequence_parallel_shards_seq_over_model():
    mesh = FakeMesh(**PROD)
    sp = make_train_rules(mesh, sequence_parallel=True)
    spec = sp.spec(mesh, ("batch", "seq", "embed_act"), (256, 4096, 1024))
    assert spec == P("data", "model", None)
    no_sp = make_train_rules(mesh)
    assert no_sp.spec(mesh, ("batch", "seq", "embed_act"), (256, 4096, 1024)) \
        == P("data", None, None)


# ------------------------------------------------------ params round-trip
def test_sharding_tree_round_trip_on_host_mesh():
    mesh = make_host_mesh()
    cfg = get_config("qwen1.5-0.5b").reduced()
    tree = Model(cfg).describe()
    rules = make_decode_rules(mesh, cfg.num_kv_heads)
    shardings = sharding_tree(tree, mesh, rules)
    for s in jax.tree.leaves(shardings, is_leaf=lambda x: isinstance(x, NamedSharding)):
        assert isinstance(s, NamedSharding)
    params = materialize(tree, seed=0)
    placed = jax.tree.map(jax.device_put, params, shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(placed)):
        assert a.shape == b.shape and a.dtype == b.dtype
    # the glossary covers every logical axis the model tree names
    named = {
        ax
        for leaf in jax.tree.leaves(tree, is_leaf=lambda x: hasattr(x, "axes"))
        for ax in getattr(leaf, "axes", ())
        if ax is not None
    }
    assert named <= set(LOGICAL_AXES), named - set(LOGICAL_AXES)


# --------------------------------------------------------------- replicas
def test_replica_set_shares_one_rules_object():
    cfg = get_config("qwen1.5-0.5b").reduced()
    rs = make_replica_set(3, num_kv_heads=cfg.num_kv_heads)
    assert rs.num_replicas == len(rs) == 3
    placements = list(rs)
    assert all(p.rules is rs.rules for p in placements)
    assert [p.replica_id for p in placements] == [0, 1, 2]
    assert dict(placements[0].mesh.shape) == dict(placements[2].mesh.shape)
    assert placements[1].spec(("batch", "vocab_act")) == P(None, None)


def test_replica_set_rejects_undersized_mesh():
    with pytest.raises(AssertionError):
        make_replica_set(1, mesh_shape=(2, 2), devices=jax.devices())


def test_decode_rules_drive_a_real_decode_step():
    """The quickstart path in miniature: host-mesh ctx through prefill+decode."""
    from repro.models import ShardCtx

    mesh = make_host_mesh()
    cfg = get_config("qwen1.5-0.5b").reduced()
    model = Model(cfg)
    params = materialize(model.describe(), seed=0)
    ctx = ShardCtx(mesh, make_decode_rules(mesh, cfg.num_kv_heads))
    B, S = 2, 16
    tokens = jnp.arange(B * S, dtype=jnp.int32).reshape(B, S) % cfg.vocab_size
    logits, cache = model.prefill(params, {"tokens": tokens}, ctx=ctx)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
