"""MORI on attn-free (SSM) programs in the REAL engine: exact-continuation
state reuse, bundle offload/reload, typed eviction, router integration."""
from __future__ import annotations

import pytest

from repro.configs import get_config
from repro.core.scheduler import SchedulerConfig
from repro.core.types import TypeLabel
from repro.models import Model, materialize
from repro.serving import MoriRouter
from repro.serving.engine import EngineRequest
from repro.serving.ssm_engine import SsmEngine
from repro.traces import TraceGenConfig, generate_corpus


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("mamba2-2.7b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    return cfg, params


def make_engine(cfg, params, **kw):
    kw.setdefault("n_device_states", 3)
    kw.setdefault("n_host_states", 6)
    kw.setdefault("max_seq", 256)
    return SsmEngine(cfg, params, **kw)


def test_state_is_o1_and_bundle_bytes_constant(setup):
    cfg, params = setup
    eng = make_engine(cfg, params)
    assert eng.bundle_bytes > 0
    # bundle size is independent of max_seq — the SSM hallmark
    eng2 = SsmEngine(cfg, params, max_seq=4 * eng.max_seq)
    assert eng2.bundle_bytes == eng.bundle_bytes


def test_exact_continuation_reuses_state(setup):
    cfg, params = setup
    eng = make_engine(cfg, params)
    toks = [5, 6, 7, 8]
    eng.submit(EngineRequest("p0", toks, max_new_tokens=2))
    c1 = eng.step()[0]
    assert c1.cached_tokens == 0 and c1.prefilled_tokens == 4

    # continuation: old context + generated + tool-result tokens
    toks2 = toks + c1.output_tokens + [9, 10]
    eng.submit(EngineRequest("p0", toks2, max_new_tokens=2))
    c2 = eng.step()[0]
    # state summarizes everything except the final generated token
    assert c2.cached_tokens == len(toks) + len(c1.output_tokens) - 1
    assert c2.prefilled_tokens == 3          # final token + tool-result suffix


def test_continuation_matches_recompute(setup):
    """Resuming from saved state must produce the same tokens as
    recomputing the full context from scratch."""
    cfg, params = setup
    toks = [3, 4, 5, 6, 7]
    e1 = make_engine(cfg, params)
    e1.submit(EngineRequest("a", toks, max_new_tokens=2))
    first = e1.step()[0]
    full = toks + first.output_tokens + [11]
    e1.submit(EngineRequest("a", full, max_new_tokens=3))
    cont = e1.step()[0]
    assert cont.cached_tokens > 0

    e2 = make_engine(cfg, params)
    e2.submit(EngineRequest("b", full, max_new_tokens=3))
    scratch = e2.step()[0]
    assert scratch.cached_tokens == 0
    assert cont.output_tokens == scratch.output_tokens


def test_divergent_context_recomputes(setup):
    cfg, params = setup
    eng = make_engine(cfg, params)
    eng.submit(EngineRequest("p0", [1, 2, 3, 4], max_new_tokens=1))
    eng.step()
    eng.submit(EngineRequest("p0", [1, 2, 9, 9, 9], max_new_tokens=1))
    c = eng.step()[0]
    assert c.cached_tokens == 0              # lossy state: no partial reuse
    assert c.prefilled_tokens == 5


def test_offload_reload_roundtrip(setup):
    cfg, params = setup
    eng = make_engine(cfg, params)
    toks = [2, 3, 4]
    eng.submit(EngineRequest("p0", toks, max_new_tokens=1))
    out1 = eng.step()[0].output_tokens
    assert eng.offload_program("p0") == 1
    assert "p0" not in eng.device and "p0" in eng.host
    # continuation straight from host: reloads then reuses
    eng.submit(EngineRequest("p0", toks + out1 + [7], max_new_tokens=1))
    c = eng.step()[0]
    assert c.reloaded_pages == 1
    assert c.cached_tokens == len(toks) + len(out1) - 1


def test_typed_eviction_prefers_inactive_then_idle(setup):
    cfg, params = setup
    eng = make_engine(cfg, params, n_device_states=2)
    for i, label in enumerate([TypeLabel.BUSY, TypeLabel.IDLE,
                               TypeLabel.INACTIVE]):
        pid = f"p{i}"
        eng.submit(EngineRequest(pid, [i + 2, i + 3], max_new_tokens=1))
        eng.step()
        eng.set_label(pid, label)
    eng.submit(EngineRequest("p3", [9, 8], max_new_tokens=1))
    eng.step()
    # capacity 2: the INACTIVE and IDLE programs were pushed out first
    assert "p0" in eng.device or eng.device.get("p0") is None
    assert "p2" not in eng.device            # inactive evicted first
    assert eng.evicted_pages["gpu"] >= 2


def test_router_drives_ssm_engine_end_to_end(setup):
    """The full MORI policy stack over the SSM engine, unchanged."""
    cfg, params = setup
    engines = [make_engine(cfg, params, n_device_states=3, n_host_states=8)]
    router = MoriRouter(
        engines,
        scheduler="mori",
        config=SchedulerConfig(tick_interval_s=2.0),
    )
    tg = TraceGenConfig(min_steps=3, mean_steps=4, max_steps=4,
                        initial_context_mean=120, max_context=240)
    corpus = generate_corpus(3, seed=0, cfg=tg)
    m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=2)
    assert m.steps_completed >= 9
    # continuation reuse gives a high hit rate without any radix tree
    assert m.cache_hit_rate > 0.4
