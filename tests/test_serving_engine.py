"""Real-engine tests: paged KV + radix reuse correctness, typed eviction
under pressure, MORI router integration (deliverable b/c)."""
import jax.numpy as jnp
import pytest

from repro.configs import get_config
from repro.core import SchedulerConfig
from repro.core.types import Tier, TypeLabel
from repro.models import Model, materialize
from repro.serving import Engine, EngineRequest, MoriRouter, snapshot_state
from repro.traces import TraceGenConfig, generate_corpus


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").reduced()
    model = Model(cfg)
    params = materialize(model.describe(), seed=0)
    return cfg, model, params


def make_engine(cfg, params, **kw):
    kw.setdefault("page_tokens", 8)
    kw.setdefault("n_device_pages", 64)
    kw.setdefault("n_host_pages", 64)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 256)
    return Engine(cfg, params, **kw)


class TestEngineCorrectness:
    def test_decode_matches_direct_forward(self, setup):
        cfg, model, params = setup
        eng = make_engine(cfg, params)
        ctx = list(range(2, 60))
        eng.submit(EngineRequest("p", ctx, max_new_tokens=4))
        out = eng.run_to_completion()[0].output_tokens
        # greedy reference: iterative full prefill
        ref = []
        cur = list(ctx)
        for _ in range(4):
            logits, _ = model.prefill(params, {"tokens": jnp.asarray([cur], jnp.int32)})
            t = int(jnp.argmax(logits[0]))
            ref.append(t)
            cur.append(t)
        assert out == ref

    def test_prefix_cache_reduces_prefill(self, setup):
        cfg, _, params = setup
        eng = make_engine(cfg, params)
        ctx = list(range(2, 50))
        eng.submit(EngineRequest("p", ctx, max_new_tokens=4))
        c1 = eng.run_to_completion()[0]
        assert c1.cached_tokens == 0
        ctx2 = ctx + c1.output_tokens[:-1] + [99, 98, 97]
        eng.submit(EngineRequest("p", ctx2, max_new_tokens=4))
        c2 = eng.run_to_completion()[0]
        assert c2.cached_tokens >= 40  # most of the prefix reused
        assert c2.prefilled_tokens < len(ctx2) - 32

    def test_chunked_prefill_equals_fresh_prefill(self, setup):
        """A cached-prefix submit must produce the same first token as an
        engine with a cold cache — prefix-conditioned attention correctness."""
        cfg, _, params = setup
        warm = make_engine(cfg, params)
        cold = make_engine(cfg, params)
        ctx = list(range(2, 42))
        warm.submit(EngineRequest("p", ctx, max_new_tokens=3))
        w1 = warm.run_to_completion()[0]
        ctx2 = ctx + w1.output_tokens[:-1] + [1000, 1001, 1002, 1003]
        warm.submit(EngineRequest("p", ctx2, max_new_tokens=3))
        cold.submit(EngineRequest("q", ctx2, max_new_tokens=3))
        wout = warm.run_to_completion()[0]
        cout = cold.run_to_completion()[0]
        assert wout.cached_tokens > 0 and cout.cached_tokens == 0
        assert wout.output_tokens == cout.output_tokens

    def test_shared_prefix_across_programs(self, setup):
        cfg, _, params = setup
        eng = make_engine(cfg, params)
        base = list(range(2, 34))
        eng.submit(EngineRequest("a", base + [50, 51], max_new_tokens=3))
        eng.run_to_completion()
        eng.submit(EngineRequest("b", base + [60, 61], max_new_tokens=3))
        c = eng.run_to_completion()[0]
        assert c.cached_tokens == 32  # the shared full pages


class TestTypedEvictionUnderPressure:
    def test_device_exhaustion_spills_to_host(self, setup):
        cfg, _, params = setup
        eng = make_engine(cfg, params, n_device_pages=12, n_host_pages=48)
        for i in range(4):
            ctx = list(range(1000 * i, 1000 * i + 56))
            eng.submit(EngineRequest(f"p{i}", ctx, max_new_tokens=3))
            eng.run_to_completion()
        st = eng.pool.stats()
        assert st.offload_bytes > 0  # typed eviction spilled pages to host
        assert eng.evicted_pages["gpu"] > 0

    def test_idle_labelled_evicted_before_busy(self, setup):
        cfg, _, params = setup
        eng = make_engine(cfg, params, n_device_pages=16, n_host_pages=64)
        eng.submit(EngineRequest("busy", list(range(0, 56)), max_new_tokens=3))
        eng.run_to_completion()
        eng.submit(EngineRequest("idle", list(range(500, 556)), max_new_tokens=3))
        eng.run_to_completion()
        eng.set_label("idle", TypeLabel.IDLE)
        eng.set_label("busy", TypeLabel.BUSY)
        # force evictions: a third program needs pages
        eng.submit(EngineRequest("new", list(range(900, 956)), max_new_tokens=3))
        eng.run_to_completion()
        busy_dev = sum(
            n.device_page is not None for n in eng.tree.program_nodes("busy")
        )
        idle_dev = sum(
            n.device_page is not None for n in eng.tree.program_nodes("idle")
        )
        assert busy_dev >= idle_dev  # idle-labelled pages went first

    def test_offload_reload_preserves_cache(self, setup):
        cfg, _, params = setup
        eng = make_engine(cfg, params)
        ctx = list(range(2, 50))
        eng.submit(EngineRequest("p", ctx, max_new_tokens=4))
        c1 = eng.run_to_completion()[0]
        n_off = eng.offload_program("p")
        assert n_off > 0
        assert all(n.device_page is None for n in eng.tree.program_nodes("p"))
        n_rel = eng.reload_program("p")
        assert n_rel == n_off
        ctx2 = ctx + c1.output_tokens[:-1] + [40, 41]
        eng.submit(EngineRequest("p", ctx2, max_new_tokens=3))
        c2 = eng.run_to_completion()[0]
        assert c2.cached_tokens >= 40  # cache survived the roundtrip

    def test_discard_frees_everything(self, setup):
        cfg, _, params = setup
        eng = make_engine(cfg, params)
        eng.submit(EngineRequest("p", list(range(2, 50)), max_new_tokens=3))
        eng.run_to_completion()
        before = eng.pool.device_free_count()
        eng.discard_program("p", Tier.GPU)
        assert eng.pool.device_free_count() > before
        assert eng.tree.program_nodes("p") == []


class TestRouterIntegration:
    def test_replay_with_mori(self, setup):
        cfg, _, params = setup
        engines = [
            make_engine(cfg, params, n_device_pages=96, n_host_pages=96, max_seq=384)
            for _ in range(2)
        ]
        router = MoriRouter(engines, scheduler="mori")
        tg = TraceGenConfig(
            min_steps=3, mean_steps=5, max_steps=5,
            initial_context_mean=600, max_context=2000,
        )
        corpus = generate_corpus(4, seed=0, cfg=tg)
        m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)
        assert m.steps_completed >= 12
        assert m.cache_hit_rate > 0.5  # program-aware pinning pays off
        snap = snapshot_state(router)
        # all programs finished and freed; no decode slot left resident
        assert [r["gpu_used"] for r in snap["replicas"]] == [0, 0]
        assert all(r["slots"] == [] for r in snap["replicas"])

    def test_replay_under_pressure_offloads(self, setup):
        cfg, _, params = setup
        engines = [
            make_engine(
                cfg, params, n_device_pages=40, n_host_pages=120,
                max_slots=2, max_seq=320,
            )
        ]
        router = MoriRouter(
            engines,
            scheduler="mori",
            # scheduler budget below the engine pool: overflow must trigger
            # demotions (and real page offloads) well before the pool fails
            gpu_capacity_bytes=700_000,
            config=SchedulerConfig(tick_interval_s=2.0),
        )
        tg = TraceGenConfig(
            min_steps=4, mean_steps=6, max_steps=6,
            initial_context_mean=900, max_context=2200,
            long_median_s=30.0, busy_calls_mean=2.0, idle_calls_mean=2.0,
        )
        corpus = generate_corpus(5, seed=2, cfg=tg)
        m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)
        assert m.steps_completed >= 15
        # memory pressure forced real page movement through the tiers
        assert m.offloaded_pages + engines[0].evicted_pages["gpu"] > 0
