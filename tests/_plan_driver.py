"""Test harness for the PlacementPlan protocol.

:class:`Driver` wraps a scheduler and transparently collects every action
emitted by its plan-returning event methods, so tests can keep calling the
scheduler's event API directly and then assert on the accumulated action
stream. Attribute access falls through to the wrapped scheduler, which
keeps state-inspection code (``s.programs``, ``s.replicas`` ...) unchanged.
"""
from __future__ import annotations

from repro.core.actions import Action, PlacementPlan

_PLAN_EVENTS = frozenset(
    {
        "request_arrived",
        "request_completed",
        "tick",
        "program_finished",
        "replica_failed",
        "on_transfer_complete",
    }
)


class Driver:
    def __init__(self, sched):
        self.sched = sched
        self.actions: list[Action] = []
        self.plans: list[PlacementPlan] = []

    def __getattr__(self, name):
        attr = getattr(self.sched, name)
        if name not in _PLAN_EVENTS:
            return attr

        def wrapped(*args, **kwargs):
            plan = attr(*args, **kwargs)
            self.plans.append(plan)
            self.actions.extend(plan.actions)
            return plan

        return wrapped

    def of_kind(self, kind: type[Action]) -> list[Action]:
        return [a for a in self.actions if isinstance(a, kind)]

    def ack_all(self, now: float):
        """Acknowledge every open transfer (in emission order), as a
        synchronous runtime would, and return the drained plans."""
        return [
            self.on_transfer_complete(rec.pid, rec.action_id, now)
            for rec in sorted(self.sched.ledger.in_flight(), key=lambda r: r.action_id)
        ]
