"""Cross-runtime plan-protocol tests (acceptance gates of the IR redesign).

* The simulator and the real JAX router replay the *same* 3-program trace
  and must emit **byte-identical** serialized action streams — the proof
  that both runtimes drive one policy through one protocol.
* The real router bills SSD-tier reloads to the NVMe counter (regression:
  the old ``reload_src`` side-channel was silently dropped on the real
  path and every reload was accounted as PCIe).
* A Waiting-tier re-admission (``Forward(recompute=True)``) genuinely
  re-prefills in the real engine (regression: the flag used to be ignored).
"""
from __future__ import annotations

import pytest

from repro.core import SchedulerConfig, Tier
from repro.core.actions import Forward, action_to_json
from repro.core.types import ProgramTrace, RequestRecord
from repro.sim import Simulation, small_test_hw


@pytest.fixture(scope="module")
def setup():
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    return cfg, params


def _golden_traces() -> list[ProgramTrace]:
    """Three 2-step programs with widely separated tool windows so the
    event order is identical under both clocks (sim-modeled inference
    finishes in milliseconds; the virtual router clock uses the recorded
    1 s reasoning wall — both far below the 30 s tool spacing)."""
    def tr(pid, ctx, tool):
        return ProgramTrace(pid, [
            RequestRecord(ctx, 4, tool, reasoning_wall_s=1.0),
            RequestRecord(ctx + 12, 4, 0.0, reasoning_wall_s=1.0),
        ])

    return [tr("p0", 48, 30.0), tr("p1", 56, 60.0), tr("p2", 64, 90.0)]


class TestSimRouterEquivalence:
    def test_byte_identical_action_streams(self, setup):
        cfg, params = setup
        from repro.serving import Engine, MoriRouter

        traces = _golden_traces()

        engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                        n_host_pages=64, max_slots=4, max_seq=512)
        # sync_transfers: the compatibility mode whose execute-and-ack-
        # immediately semantics the simulator's fluid model reproduces
        # action-for-action on this trace (async mode acks on the transfer
        # plane's own clock, so its stream interleaves differently).
        # serial_decode: the pre-pump replay order the simulator's
        # run-to-completion event model matches event-for-event (the
        # batched decode pump interleaves scheduler events differently)
        router = MoriRouter([engine], scheduler="mori",
                            config=SchedulerConfig(), record_plans=True,
                            sync_transfers=True, serial_decode=True)
        router.replay(traces, vocab_size=cfg.vocab_size, max_new_tokens=4)

        # same KV geometry as the real engine, capacity far above the
        # working set: placement decisions depend only on the event stream
        hw = small_test_hw(
            kv_bytes_per_token=router.kv_bytes_per_token,
            hbm_bytes=1_000_000_000,
        )
        sim = Simulation(
            "mori", hw, traces, num_replicas=1, concurrency_per_replica=3,
            duration_s=200.0, warmup_s=0.0, seed=0,
            sched_config=SchedulerConfig(),
            reuse_corpus=False, record_plans=True,
        )
        sim.run()

        sim_stream = [action_to_json(a) for a in sim.action_log]
        router_stream = [action_to_json(a) for a in router.action_log]
        assert sim_stream == router_stream
        # and the stream is non-trivial: every program was admitted
        # (recompute), resumed warm, and torn down
        fwd = [a for a in sim.action_log if isinstance(a, Forward)]
        assert sorted(a.pid for a in fwd if a.recompute) == ["p0", "p1", "p2"]
        assert sorted(
            a.pid for a in fwd if a.source_tier is Tier.GPU
        ) == ["p0", "p1", "p2"]

    def test_sim_finite_replay_runs_each_trace_once(self):
        traces = _golden_traces()
        hw = small_test_hw(hbm_bytes=1_000_000_000)
        sim = Simulation(
            "mori", hw, traces, num_replicas=1, concurrency_per_replica=3,
            duration_s=400.0, warmup_s=0.0, seed=0, reuse_corpus=False,
        )
        r = sim.run()
        assert r.programs_finished == 3
        assert r.steps_completed == 6

    def test_sim_finite_replay_drains_corpus_larger_than_slots(self):
        """Freed slots pick up the next unplayed trace: a 6-trace corpus on
        3 slots still runs every trace exactly once."""
        def tr(pid, ctx):
            return ProgramTrace(pid, [RequestRecord(ctx, 4, 2.0),
                                      RequestRecord(ctx + 12, 4, 0.0)])

        traces = [tr(f"q{i}", 40 + 8 * i) for i in range(6)]
        hw = small_test_hw(hbm_bytes=1_000_000_000)
        sim = Simulation(
            "mori", hw, traces, num_replicas=1, concurrency_per_replica=3,
            duration_s=400.0, warmup_s=0.0, seed=0, reuse_corpus=False,
        )
        r = sim.run()
        assert r.programs_finished == 6
        assert r.steps_completed == 12
        assert sorted(p["pid"] for p in sim.finished_programs) == sorted(
            t.program_id for t in traces
        )


class TestRealRouterAccounting:
    def test_ssd_reload_billed_to_nvme(self, setup):
        """With DRAM disabled and an SSD budget, demotions sink to the SSD
        tier and the returning Forward's source_tier bills the NVMe
        counter — zero PCIe reloads."""
        cfg, params = setup
        from repro.serving import Engine, MoriRouter
        from repro.traces import TraceGenConfig, generate_corpus

        engine = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                        n_host_pages=128, max_slots=2, max_seq=320)
        router = MoriRouter(
            [engine],
            scheduler="mori",
            gpu_capacity_bytes=700_000,
            cpu_capacity_bytes=0,
            ssd_capacity_bytes=8_000_000,
            config=SchedulerConfig(tick_interval_s=2.0),
            record_plans=True,
        )
        tg = TraceGenConfig(
            min_steps=4, mean_steps=6, max_steps=6,
            initial_context_mean=900, max_context=2200,
            long_median_s=30.0, busy_calls_mean=2.0, idle_calls_mean=2.0,
        )
        corpus = generate_corpus(5, seed=2, cfg=tg)
        m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)
        assert m.steps_completed >= 15
        ssd_forwards = [
            a for a in router.action_log
            if isinstance(a, Forward) and a.source_tier is Tier.SSD
        ]
        assert ssd_forwards, "pressure never produced an SSD-tier reload"
        assert m.nvme_reloaded_pages > 0
        assert m.reloaded_pages == 0  # no CPU tier configured -> no PCIe bill
        # synchronous real path: every transfer was acknowledged immediately
        assert len(router.sched.ledger) == 0

    def test_recompute_readmission_reprefills(self, setup):
        """A ``Forward(recompute=True)`` must drop any surviving pages so
        the engine genuinely re-prefills — it may not silently serve the
        'recomputed' request from stale cache (the old protocol ignored the
        flag entirely)."""
        cfg, params = setup
        from repro.core.actions import PlacementPlan
        from repro.serving import Engine, EngineRequest, MoriRouter

        engine = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                        n_host_pages=64, max_slots=2, max_seq=256)
        router = MoriRouter([engine], scheduler="mori", record_plans=True)
        # prime the radix cache with a completed step for "p"
        ctx = list(range(2, 50))
        engine.submit(EngineRequest("p", ctx, max_new_tokens=4))
        out = engine.run_to_completion()[0].output_tokens
        assert engine.tree.program_nodes("p"), "cache priming failed"

        # a warm Forward keeps the pages: the continuation cache-hits
        router.apply_plan(PlacementPlan(0.0, (
            Forward(1, "p", 0, Tier.GPU, False, 0),
        )))
        ctx2 = ctx + out[:-1] + [60, 61]
        engine.submit(EngineRequest("p", ctx2, max_new_tokens=4))
        warm = engine.run_to_completion()[0]
        assert warm.cached_tokens > 0

        # a recompute Forward drops them: the next submit fully re-prefills
        router.apply_plan(PlacementPlan(1.0, (
            Forward(2, "p", 0, Tier.WAITING, True, 0),
        )))
        assert router.metrics.recompute_submits == 1
        assert engine.tree.program_nodes("p") == []
        ctx3 = ctx2 + warm.output_tokens[:-1] + [70, 71]
        engine.submit(EngineRequest("p", ctx3, max_new_tokens=4))
        cold = engine.run_to_completion()[0]
        assert cold.cached_tokens == 0
        assert cold.prefilled_tokens == len(ctx3)
