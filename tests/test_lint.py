"""The repo lint (``python -m repro.analysis.lint``): one positive and
one negative fixture per rule, the suppression-marker escape hatch, and
the gate the CI job enforces — the real tree lints clean."""
from __future__ import annotations

from pathlib import Path

from repro.analysis import lint

REPO = Path(__file__).resolve().parent.parent


def run_lint(tmp_path, source: str, name: str = "mod.py"):
    f = tmp_path / name
    f.write_text(source)
    return lint.run([str(f)])


def rules_hit(violations):
    return {v.rule for v in violations}


class TestDonatedReuse:
    BAD = """
import jax

def fn(x):
    return x

step = jax.jit(fn, donate_argnums=(0,))

def caller(buf):
    out = step(buf)
    return buf.sum() + out
"""
    GOOD = """
import jax

def fn(x):
    return x

step = jax.jit(fn, donate_argnums=(0,))

def caller(buf):
    buf = step(buf)
    return buf.sum()
"""

    def test_positive(self, tmp_path):
        assert "KV001" in rules_hit(run_lint(tmp_path, self.BAD))

    def test_negative(self, tmp_path):
        assert "KV001" not in rules_hit(run_lint(tmp_path, self.GOOD))


class TestDecoratedDonatedReuse:
    BAD = """
import functools
import jax

@functools.partial(jax.jit, donate_argnums=(0,))
def step(state, tok):
    return state + tok

def caller(state, tok):
    out = step(state, tok)
    return state.sum() + out
"""
    GOOD = """
import functools
import jax

@functools.partial(jax.jit, donate_argnums=(0,))
def step(state, tok):
    return state + tok

def caller(state, tok):
    state = step(state, tok)
    return state.sum()
"""
    METHOD = """
import functools
import jax

class Engine:
    @functools.partial(jax.jit, donate_argnums=(1,))
    def step(self, state):
        return state + 1

def caller(eng, state):
    out = eng.step(state)
    return state.sum() + out
"""
    SUPPRESSED = """
import functools
import jax

@functools.partial(jax.jit, donate_argnums=(0,))
def step(state):
    return state + 1

def caller(state):
    out = step(state)
    return state.sum() + out  # lint: decorated-donated-reuse-ok
"""

    def test_positive(self, tmp_path):
        assert "KV007" in rules_hit(run_lint(tmp_path, self.BAD))

    def test_rebind_clears(self, tmp_path):
        assert "KV007" not in rules_hit(run_lint(tmp_path, self.GOOD))

    def test_methods_skipped(self, tmp_path):
        # donate positions on a method count `self`; call sites cannot be
        # mapped reliably, so the rule stays quiet rather than guessing
        assert "KV007" not in rules_hit(run_lint(tmp_path, self.METHOD))

    def test_marker_suppresses(self, tmp_path):
        assert "KV007" not in rules_hit(run_lint(tmp_path, self.SUPPRESSED))

    def test_assignment_form_left_to_kv001(self, tmp_path):
        vs = run_lint(tmp_path, TestDonatedReuse.BAD)
        assert "KV007" not in rules_hit(vs)


class TestLruCacheHashable:
    BAD = """
import functools

@functools.lru_cache(maxsize=None)
def build(cfg: dict, n: int):
    return n
"""
    GOOD = """
import functools

@functools.lru_cache(maxsize=None)
def build(cfg: "FrozenCfg", n: int):
    return n
"""

    def test_positive(self, tmp_path):
        assert "KV002" in rules_hit(run_lint(tmp_path, self.BAD))

    def test_negative(self, tmp_path):
        assert "KV002" not in rules_hit(run_lint(tmp_path, self.GOOD))

    def test_unfrozen_dataclass_param(self, tmp_path):
        src = """
import functools
from dataclasses import dataclass

@dataclass
class Cfg:
    n: int = 0

@functools.lru_cache(maxsize=None)
def build(cfg: Cfg):
    return cfg.n
"""
        assert "KV002" in rules_hit(run_lint(tmp_path, src))

    def test_frozen_dataclass_param(self, tmp_path):
        src = """
import functools
from dataclasses import dataclass

@dataclass(frozen=True)
class Cfg:
    n: int = 0

@functools.lru_cache(maxsize=None)
def build(cfg: Cfg):
    return cfg.n
"""
        assert "KV002" not in rules_hit(run_lint(tmp_path, src))


class TestActionExhaustive:
    BAD = """
def apply(plan):
    for act in plan:
        if isinstance(act, Forward):
            pass
        elif isinstance(act, Offload):
            pass
        elif isinstance(act, Discard):
            pass
"""
    GOOD_ELSE = """
def apply(plan):
    for act in plan:
        if isinstance(act, Forward):
            pass
        elif isinstance(act, Offload):
            pass
        else:
            raise ValueError(act)
"""
    GOOD_ALL = """
def apply(plan):
    for act in plan:
        if isinstance(act, Forward):
            pass
        elif isinstance(act, Offload):
            pass
        elif isinstance(act, Discard):
            pass
        elif isinstance(act, SetLabel):
            pass
        elif isinstance(act, CancelTransfer):
            pass
        elif isinstance(act, Migrate):
            pass
"""

    def test_positive(self, tmp_path):
        vs = run_lint(tmp_path, self.BAD)
        assert "KV003" in rules_hit(vs)
        [v] = [v for v in vs if v.rule == "KV003"]
        assert "SetLabel" in v.msg          # names what is missing

    def test_else_suffices(self, tmp_path):
        assert "KV003" not in rules_hit(run_lint(tmp_path, self.GOOD_ELSE))

    def test_all_members_suffice(self, tmp_path):
        assert "KV003" not in rules_hit(run_lint(tmp_path, self.GOOD_ALL))


class TestPinPaired:
    BAD = """
class Stream:
    def start(self, tree, pid):
        tree.pin(pid)

    def finish(self, tree, pid):
        pass
"""
    GOOD = """
class Stream:
    def start(self, tree, pid):
        tree.pin(pid)

    def finish(self, tree, pid):
        tree.unpin(pid)
"""

    def test_positive(self, tmp_path):
        assert "KV004" in rules_hit(run_lint(tmp_path, self.BAD))

    def test_negative(self, tmp_path):
        assert "KV004" not in rules_hit(run_lint(tmp_path, self.GOOD))


class TestWallClock:
    BAD = """
import time

def tick():
    return time.monotonic()
"""
    GOOD = """
import time as _time

def profile():
    return _time.perf_counter()
"""

    def test_positive_in_core(self, tmp_path):
        d = tmp_path / "repro" / "core"
        d.mkdir(parents=True)
        f = d / "clock_user.py"
        f.write_text(self.BAD)
        assert "KV005" in rules_hit(lint.run([str(f)]))

    def test_perf_counter_allowed(self, tmp_path):
        d = tmp_path / "repro" / "sim"
        d.mkdir(parents=True)
        f = d / "prof.py"
        f.write_text(self.GOOD)
        assert "KV005" not in rules_hit(lint.run([str(f)]))

    def test_outside_virtual_clock_modules_allowed(self, tmp_path):
        # serving-layer wall-clock reads (real TTFT) are fine
        d = tmp_path / "repro" / "serving"
        d.mkdir(parents=True)
        f = d / "clock_user.py"
        f.write_text(self.BAD)
        assert "KV005" not in rules_hit(lint.run([str(f)]))


class TestJitShapeBranch:
    BAD = """
import jax

@jax.jit
def fn(x):
    if x.shape[0] > 4:
        return x * 2
    return x
"""
    GOOD_MARKED = """
import jax

@jax.jit
def fn(x):
    if x.shape[0] > 4:  # lint: jit-shape-branch-ok
        return x * 2
    return x
"""
    GOOD_UNJITTED = """
def fn(x):
    if x.shape[0] > 4:
        return x * 2
    return x
"""

    def test_positive(self, tmp_path):
        assert "KV006" in rules_hit(run_lint(tmp_path, self.BAD))

    def test_marker_suppresses(self, tmp_path):
        assert "KV006" not in rules_hit(run_lint(tmp_path, self.GOOD_MARKED))

    def test_unjitted_function_allowed(self, tmp_path):
        assert "KV006" not in rules_hit(run_lint(tmp_path, self.GOOD_UNJITTED))


class TestFormatAwareSizing:
    BAD_DEVICE_ATTR = """
def cpu_budget(pool, n):
    cpu_capacity_bytes = n * pool.page_bytes
    return cpu_capacity_bytes
"""
    BAD_BF16_HARDCODE = """
def kv_size(cfg):
    kvb = cfg.num_layers * 2 * cfg.num_kv_heads * cfg.head_dim * 2
    return kvb
"""
    GOOD_HELPER = """
def cpu_budget(pool, n):
    cpu_capacity_bytes = n * pool.host_page_bytes
    return cpu_capacity_bytes
"""
    GOOD_DEVICE_SIDE = """
def gpu_budget(pool, n):
    gpu_capacity_bytes = n * pool.page_bytes
    return gpu_capacity_bytes
"""
    SUPPRESSED = """
def cpu_budget(pool, n):
    cpu_capacity_bytes = n * pool.page_bytes  # lint: kv008-ok
    return cpu_capacity_bytes
"""

    def test_device_attr_in_offload_context(self, tmp_path):
        vs = run_lint(tmp_path, self.BAD_DEVICE_ATTR)
        assert "KV008" in rules_hit(vs)
        [v] = [v for v in vs if v.rule == "KV008"]
        assert "page_bytes" in v.msg

    def test_bf16_bytes_per_element_hardcode(self, tmp_path):
        assert "KV008" in rules_hit(run_lint(tmp_path, self.BAD_BF16_HARDCODE))

    def test_format_aware_helper_passes(self, tmp_path):
        assert "KV008" not in rules_hit(run_lint(tmp_path, self.GOOD_HELPER))

    def test_device_side_math_allowed(self, tmp_path):
        # a GPU budget *should* be priced at device format — no hint, no flag
        assert "KV008" not in rules_hit(
            run_lint(tmp_path, self.GOOD_DEVICE_SIDE))

    def test_marker_suppresses(self, tmp_path):
        assert "KV008" not in rules_hit(run_lint(tmp_path, self.SUPPRESSED))

    def test_kv_quant_module_exempt(self, tmp_path):
        # the sizing helper itself is the sanctioned home for raw byte math
        d = tmp_path / "repro" / "kernels"
        d.mkdir(parents=True)
        f = d / "kv_quant.py"
        f.write_text(self.BAD_BF16_HARDCODE)
        assert "KV008" not in rules_hit(lint.run([str(f)]))


class TestDriver:
    def test_syntax_error_reported_not_crash(self, tmp_path):
        vs = run_lint(tmp_path, "def broken(:\n")
        assert rules_hit(vs) == {"KV000"}

    def test_clean_file_reports_nothing(self, tmp_path):
        assert run_lint(tmp_path, "x = 1\n") == []

    def test_cli_exit_codes(self, tmp_path, capsys):
        bad = tmp_path / "bad.py"
        bad.write_text(TestActionExhaustive.BAD)
        assert lint.main([str(bad)]) == 1
        good = tmp_path / "good.py"
        good.write_text("x = 1\n")
        assert lint.main([str(good)]) == 0
        assert "clean" in capsys.readouterr().out

    def test_repo_lints_clean(self):
        """The CI gate: the actual tree has no violations (deliberate
        exceptions carry `lint: <rule>-ok` markers)."""
        paths = [
            str(REPO / d)
            for d in ("src", "tests", "benchmarks", "examples")
            if (REPO / d).is_dir()
        ]
        vs = lint.run(paths)
        assert vs == [], "\n".join(str(v) for v in vs)
