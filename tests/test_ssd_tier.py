"""§7.1 SSD tier extension: growth-driven demotion cascade
GPU->CPU->SSD->Waiting, NVMe-billed promotion, default-off invariance."""
from __future__ import annotations

import pytest

from _plan_driver import Driver
from repro.core import Forward, SCHEDULERS, SchedulerConfig, TierCapacity
from repro.core.types import Tier
from repro.sim import CONFIGS, Simulation
from repro.traces import generate_corpus


def _sched(gpu, cpu, ssd):
    return Driver(SCHEDULERS["mori"](
        1, TierCapacity(gpu, cpu, ssd),
        SchedulerConfig(tick_interval_s=1.0),
    ))


def _step(sched, pid, *, tokens, out, at):
    """One inference step: request -> run -> complete (+out ctx growth)."""
    sched.request_arrived(pid, input_tokens=tokens, now=at)
    sched.notify_inference_started(pid, at)
    sched.request_completed(pid, out, at + 0.1)


def _cascade(ssd_bytes):
    """Four 100-byte programs on a 400-byte GPU; the newest then grows by
    300, forcing three demotions in idleness order (oldest = most idle)."""
    sched = _sched(400, 100, ssd_bytes)
    for i, t in enumerate([0.0, 2.0, 4.0, 6.0]):
        pid = f"p{i}"
        sched.program_arrived(pid, 1, t)
        _step(sched, pid, tokens=100, out=0, at=t)
    _step(sched, "p3", tokens=100, out=300, at=8.0)   # p3 -> 400 bytes
    sched.tick(20.0)
    for rep in sched.replicas:
        rep.check()
    return sched, {pid: p.tier for pid, p in sched.programs.items()}


def test_demotion_cascade_fills_gpu_cpu_ssd_waiting():
    _, tiers = _cascade(ssd_bytes=100)
    assert tiers["p3"] is Tier.GPU            # the busy grower keeps HBM
    assert sorted(t.value for t in tiers.values()) == sorted(
        ["gpu", "cpu", "ssd", "waiting"]
    )
    # demotions are idleness-ordered: oldest (most idle) left the GPU first
    assert tiers["p0"] is not Tier.GPU


def test_ssd_disabled_is_paper_behavior():
    """ssd_kv_bytes=0 (default): same cascade never touches SSD."""
    _, tiers = _cascade(ssd_bytes=0)
    vals = [t.value for t in tiers.values()]
    assert "ssd" not in vals
    assert sorted(vals) == sorted(["gpu", "cpu", "waiting", "waiting"])


def test_ssd_promotion_reloads_and_bills_nvme():
    sched = _sched(100, 0, 200)
    sched.program_arrived("p0", 1, 0.0)
    _step(sched, "p0", tokens=50, out=0, at=0.0)
    sched.program_arrived("p1", 1, 2.0)
    _step(sched, "p1", tokens=50, out=0, at=2.0)
    _step(sched, "p1", tokens=50, out=100, at=4.0)    # p1 -> 150 bytes
    sched.tick(10.0)
    sched.ack_all(10.0)
    p0, p1 = sched.programs["p0"], sched.programs["p1"]
    assert p0.tier is Tier.SSD or p1.tier is Tier.SSD
    # p0 returns from its tool call -> promoted out of SSD; the Forward's
    # source_tier bills the reload to the NVMe channel, not PCIe
    if p0.tier is Tier.SSD:
        sched.request_arrived("p0", input_tokens=50, now=20.0)
        sched.tick(21.0)
        assert p0.tier is Tier.GPU
        fwd = [a for a in sched.of_kind(Forward) if a.pid == "p0"]
        assert fwd[-1].source_tier is Tier.SSD and not fwd[-1].recompute
        assert fwd[-1].nbytes == p0.materialized_bytes > 0


def test_tier_invariants_under_cascade():
    sched, _ = _cascade(ssd_bytes=100)
    rep = sched.replicas[0]
    assert rep.gpu_used <= rep.capacity.gpu_kv_bytes
    assert rep.cpu_used <= rep.capacity.cpu_kv_bytes
    assert rep.ssd_used <= rep.capacity.ssd_kv_bytes


def test_sim_ssd_ratio_improves_under_pressure():
    """End-to-end: with CPU deliberately tight (0.25x), the guarded SSD
    tier improves the 7B pair and never regresses the 30B pair (where the
    cost-aware guard rejects every sink: NVMe reload loses to cheap MoE
    recompute)."""
    corpus = generate_corpus(24, seed=0)
    common = dict(
        num_replicas=1, concurrency_per_replica=60, cpu_ratio=0.25,
        duration_s=420.0, warmup_s=60.0, seed=0,
    )
    base = Simulation("mori", CONFIGS["h200-80g-qwen2.5-7b"], corpus,
                      **common).run()
    ssd = Simulation("mori", CONFIGS["h200-80g-qwen2.5-7b"], corpus,
                     ssd_ratio=4.0, **common).run()
    assert ssd.output_tok_per_s >= base.output_tok_per_s
    assert ssd.ttft_avg_s <= base.ttft_avg_s

    b30 = Simulation("mori", CONFIGS["h200-qwen3-30b-a3b"], corpus,
                     **common).run()
    s30 = Simulation("mori", CONFIGS["h200-qwen3-30b-a3b"], corpus,
                     ssd_ratio=4.0, **common).run()
    assert s30.output_tok_per_s == pytest.approx(b30.output_tok_per_s, rel=0.01)
