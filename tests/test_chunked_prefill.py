"""Chunked prefill pinned to the monolithic path, bit for bit.

``Engine.begin_submit`` + ``prefill_step`` split a submit into page-sized
chunks the decode pump interleaves with decode steps. The contract this
battery enforces: chunking changes *when* prefill compute runs, never
what it produces —

* property battery: random suffix lengths × chunk budgets × warm/cold
  radix prefixes produce the same prefill as a monolithic ``submit`` up
  to the one thing bucketed padding may legally change — XLA reduction
  reassociation, bounded here at 2 bf16 ulp on pool pages and an
  argmax pick inside the monolithic logit tie set (see ``_race``);
* bucket edges: suffix exactly a ``prefill_bucket`` multiple (zero pad),
  suffix shorter than one chunk, and a chunk cursor that crosses into a
  partial tail page all line up with the monolithic path;
* job lifecycle: ``begin_submit`` holds real slot occupancy for the whole
  prefill (schedulers probing the engine see the slot as taken),
  ``cancel_prefill`` rolls every resource back, and a finished job's slot
  decodes like any submitted slot;
* the pump: a chunked replay is token-identical to the monolithic pump,
  records TTFT from the submit event to the first token, and beats
  monolithic mean TTFT on a contention corpus (chunk shapes are bucketed
  and jitted once process-wide; monolithic eager prefill re-dispatches
  per context length).
"""
from __future__ import annotations

import hashlib
import itertools
import json
import random
from pathlib import Path

import numpy as np
import pytest

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:
    from _hypothesis_compat import given, settings, st

from repro.configs import get_config
from repro.core import SchedulerConfig
from repro.core.types import ProgramTrace, RequestRecord
from repro.models import Model, materialize
from repro.serving import Engine, EngineRequest, MoriRouter

_pid = itertools.count()
_shared: dict = {}


def _cfg_params():
    if "setup" not in _shared:
        cfg = get_config("qwen1.5-0.5b").reduced()
        params = materialize(Model(cfg).describe(), seed=0)
        _shared["setup"] = (cfg, params)
    return _shared["setup"]


def _engine_pair():
    """One monolithic + one chunked engine, shared across the property
    examples (identical request sequences keep their radix trees, pools
    and jit caches in lockstep, so warm-prefix examples come for free).
    Module-level rather than a fixture: ``@given``-drawn tests cannot
    take fixture parameters under the hypothesis fallback shim."""
    if "pair" not in _shared:
        cfg, params = _cfg_params()

        def mk():
            return Engine(cfg, params, page_tokens=8, n_device_pages=512,
                          n_host_pages=64, max_slots=2, max_seq=512,
                          prefill_bucket_tokens=16)

        _shared["pair"] = (cfg, mk(), mk())
    return _shared["pair"]


@pytest.fixture(scope="module")
def setup():
    return _cfg_params()


def _mono_logits(eng, tokens):
    """The full final-position logit row exactly as ``Engine.submit``
    computes it (same radix match, same pad math), captured *before* the
    submit consumes the request."""
    import jax.numpy as jnp

    nodes = eng.tree.match_prefix(list(tokens))
    cached = len(nodes) * eng.page_tokens
    suffix = list(tokens)[cached:]
    prefix = None
    if nodes:
        pk, pv = eng.pool.read_device_pages([n.device_page for n in nodes])
        prefix = {"k": pk[:, None], "v": pv[:, None]}
    pad = (-len(suffix)) % eng.prefill_bucket
    batch = {"tokens": jnp.asarray([suffix + [0] * pad], jnp.int32)}
    logits, _ = eng.model.prefill(eng.params, batch, ctx=eng.ctx,
                                  prefix=prefix, logit_index=len(suffix) - 1)
    return np.asarray(logits[0])


def _race(cfg, mono, chunked, tokens, budget, max_new_tokens=3,
          strict=True):
    """Submit ``tokens`` monolithically on ``mono`` and chunked (with the
    given per-chunk token budget) on ``chunked``; assert both paths
    compute the same prefill.

    ``strict=True`` demands full bit-identity: same first token, pool
    pages byte-equal, decoded streams equal — the fixed-input edge tests
    hold this on any one machine, like the golden replays do.

    ``strict=False`` is the property-battery contract, exact about what
    chunking is allowed to change: bucketed padding reassociates XLA's
    f32 reductions (the padded kv total differs from the monolithic
    shape), so bf16 KV may legally move by an ulp — and a 1-ulp wiggle
    on a near-zero element flips its sign, while a wiggle on two
    logits tied at the bf16 top flips the argmax. The relaxed
    assertions are still tight: pages allclose at bf16 resolution, the
    chunked first token's *monolithic* logit within a few ulp of the
    monolithic max (a genuinely wrong token — shifted positions, stale
    prefix — misses by hundreds), and any run whose pages and first
    token agree exactly must decode the identical stream.
    """
    pid = f"prop-{next(_pid)}"
    req = EngineRequest(pid, list(tokens), max_new_tokens=max_new_tokens)

    logits = None if strict else _mono_logits(mono, tokens)
    sid = mono.submit(EngineRequest(pid, list(tokens),
                                    max_new_tokens=max_new_tokens))
    job = chunked.begin_submit(req)
    steps = 0
    while not chunked.prefill_step(job, budget):
        steps += 1
        assert steps < 1000, "prefill never converged"
    assert job.done and job.chunks_run == steps + 1

    m_slot, c_slot = mono.slots[sid], chunked.slots[job.slot_id]
    assert c_slot.cached_tokens == m_slot.cached_tokens
    assert c_slot.prefilled_tokens == m_slot.prefilled_tokens
    assert len(c_slot.table) == len(m_slot.table)

    mk_, mv_ = mono.pool.read_device_pages(m_slot.table)
    ck_, cv_ = chunked.pool.read_device_pages(c_slot.table)
    mk_, ck_ = np.asarray(mk_, np.float32), np.asarray(ck_, np.float32)
    mv_, cv_ = np.asarray(mv_, np.float32), np.asarray(cv_, np.float32)
    bit_equal = np.array_equal(mk_, ck_) and np.array_equal(mv_, cv_)
    tokens_equal = c_slot.produced[0] == m_slot.produced[0]

    if strict:
        assert bit_equal, "pool pages diverged"
        assert tokens_equal
    else:
        # a couple of bf16 ulp of slack (eps = 2^-8 rel); anything past
        # that is a real divergence, not reassociation
        assert np.allclose(mk_, ck_, rtol=0.03, atol=0.03)
        assert np.allclose(mv_, cv_, rtol=0.03, atol=0.03)
        # the chunked first token must sit in the monolithic argmax tie
        # set (up to the same reassociation noise: a few bf16 ulp)
        best = float(logits.max())
        got = float(logits[job.first_token])
        assert got >= best - max(0.1, 0.04 * abs(best)), (
            f"first token {job.first_token} has monolithic logit {got}, "
            f"max is {best}"
        )

    m_out = {c.program_id: c.output_tokens for c in mono.run_to_completion()}
    c_out = {c.program_id: c.output_tokens for c in chunked.run_to_completion()}
    if strict or (bit_equal and tokens_equal):
        assert m_out == c_out
    return job


class TestChunkedEqualsMonolithic:
    @given(
        suffix_len=st.integers(1, 70),
        budget=st.integers(0, 48),
        warm_pages=st.integers(0, 3),
        seed=st.integers(0, 10**6),
    )
    @settings(max_examples=6, deadline=None)
    def test_property_token_and_page_identity(self, suffix_len,
                                              budget, warm_pages, seed):
        """Random (suffix length, chunk budget, warm-prefix depth) draws:
        chunked prefill must be indistinguishable from monolithic in
        tokens and in pool bytes, warm or cold radix."""
        cfg, mono, chunked = _engine_pair()
        rng = random.Random(seed)
        vocab = cfg.vocab_size
        prefix = [rng.randrange(2, vocab) for _ in range(8 * warm_pages)]
        if warm_pages:
            # warm the radix on both engines with a request sharing the
            # page-aligned prefix; its continuation (token 1, never drawn
            # below) keeps the match from extending past the prefix pages
            pid = f"warm-{next(_pid)}"
            for eng in (mono, chunked):
                eng.submit(EngineRequest(pid, prefix + [1, 1, 1],
                                         max_new_tokens=1))
                eng.run_to_completion()
        tokens = prefix + [rng.randrange(2, vocab) for _ in range(suffix_len)]
        job = _race(cfg, mono, chunked, tokens, budget, strict=False)
        if warm_pages:
            assert job.cached_tokens == 8 * warm_pages

    def test_suffix_exactly_a_bucket_multiple(self):
        """prefill_bucket=16: a 32-token suffix pads by zero in the
        monolithic path (engine.py submit pad math) and chunks evenly —
        both edges of the bucket arithmetic at once."""
        cfg, mono, chunked = _engine_pair()
        tokens = [((7 * i) % (cfg.vocab_size - 2)) + 2 for i in range(32)]
        job = _race(cfg, mono, chunked, tokens, budget=16)
        assert job.chunks_run == 2

    def test_suffix_shorter_than_one_chunk(self):
        """A 3-token suffix (< page_tokens < budget) must run as a single
        sub-page chunk with a zero-padded tail page."""
        cfg, mono, chunked = _engine_pair()
        tokens = [5, 9, 13]
        job = _race(cfg, mono, chunked, tokens, budget=64)
        assert job.chunks_run == 1

    def test_chunk_cursor_crosses_partial_tail_page(self):
        """page_tokens=8, suffix=17, budget=8: chunks of 8+8+1, the last
        landing a single token in a fresh tail page. The cursor stays
        page-aligned on every chunk except the final one."""
        cfg, mono, chunked = _engine_pair()
        tokens = [((3 * i) % (cfg.vocab_size - 2)) + 2 for i in range(17)]
        job = _race(cfg, mono, chunked, tokens, budget=8)
        assert job.chunks_run == 3

    def test_tiny_budget_is_page_clamped(self):
        """A budget below page_tokens still makes progress: chunks clamp
        up to one full page, never to zero."""
        cfg, mono, chunked = _engine_pair()
        tokens = [((11 * i) % (cfg.vocab_size - 2)) + 2 for i in range(20)]
        job = _race(cfg, mono, chunked, tokens, budget=1)
        assert job.chunks_run == 3          # 8 + 8 + 4


class TestPrefillJobLifecycle:
    def test_begin_submit_holds_slot_occupancy(self, setup):
        """The reserved slot is real occupancy from begin_submit on: a
        1-slot engine refuses a second admission mid-prefill, and frees
        the slot only when the job's decode retires — the contract the
        scheduler's slot probe (core/scheduler.attach_slot_probe) relies
        on for gating."""
        cfg, params = setup
        eng = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                     n_host_pages=64, max_slots=1, max_seq=256)
        job = eng.begin_submit(
            EngineRequest("occ", list(range(2, 40)), max_new_tokens=2))
        with pytest.raises(AssertionError, match="no free decode slots"):
            eng.begin_submit(
                EngineRequest("occ2", list(range(50, 80)), max_new_tokens=2))
        with pytest.raises(AssertionError, match="no free decode slots"):
            eng.submit(
                EngineRequest("occ3", list(range(90, 120)), max_new_tokens=2))
        while not eng.prefill_step(job, 16):
            pass
        assert job.slot_id in eng.slots     # installed for decode
        eng.run_to_completion()
        # pipeline drained and the program retired: slot is free again
        eng.submit(EngineRequest("occ4", list(range(150, 180)),
                                 max_new_tokens=2))
        eng.run_to_completion()

    def test_cancel_prefill_rolls_everything_back(self, setup):
        """Cancelling mid-flight returns the slot, frees the staged pages
        and unpins the prefix; the poisoned job refuses further chunks."""
        cfg, params = setup
        eng = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                     n_host_pages=64, max_slots=1, max_seq=256)
        free_pages = eng.pool.device_free_count()
        job = eng.begin_submit(
            EngineRequest("cx", list(range(2, 40)), max_new_tokens=2))
        eng.prefill_step(job, 8)            # one chunk in flight
        eng.cancel_prefill(job)
        assert eng.pool.device_free_count() == free_pages
        with pytest.raises(AssertionError, match="cancelled"):
            eng.prefill_step(job, 8)
        # the slot and pages are genuinely reusable
        sid = eng.submit(EngineRequest("cy", list(range(2, 40)),
                                       max_new_tokens=2))
        assert sid == job.slot_id
        eng.run_to_completion()

    def test_chunked_rejects_dense_engine(self, setup):
        cfg, params = setup
        eng = Engine(cfg, params, dense_slots=True, max_slots=1, max_seq=256)
        with pytest.raises(AssertionError, match="paged engine"):
            eng.begin_submit(
                EngineRequest("d", list(range(2, 20)), max_new_tokens=2))


def _contention_corpus():
    """Four programs with aligned windows and growing contexts: every
    submit after the first sees a different suffix length, which is
    exactly where monolithic eager prefill pays per-shape dispatch and
    bucketed chunks do not."""
    busy = [
        ProgramTrace(f"p{i}", [
            RequestRecord(48 + 4 * i, 4, 1.0, reasoning_wall_s=2.0),
            RequestRecord(60 + 4 * i, 4, 1.0, reasoning_wall_s=2.0),
            RequestRecord(72 + 4 * i, 4, 0.0, reasoning_wall_s=2.0),
        ])
        for i in range(3)
    ]
    idle = ProgramTrace("p3", [
        RequestRecord(64, 4, 30.0, reasoning_wall_s=2.0),
        RequestRecord(80, 4, 0.0, reasoning_wall_s=2.0),
    ])
    return busy + [idle]


class TestChunkedPump:
    def test_pump_replay_token_identical_and_ttft_faster(self, setup):
        """The full router path: a chunked pump replay over a contention
        corpus (mid-window joins, one long tool call) generates exactly
        the monolithic pump's tokens, counts its chunks, and lands a
        strictly lower mean TTFT — the point of chunking: the first token
        of a join is never hostage to one monolithic prefill."""
        cfg, params = setup
        logs, ttft = {}, {}
        for chunked in (False, True):
            engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                            n_host_pages=64, max_slots=4, max_seq=512)
            router = MoriRouter(
                [engine], scheduler="mori",
                config=SchedulerConfig(tick_interval_s=1.0),
                sync_transfers=True, chunked_prefill=chunked,
                prefill_token_budget=32 if chunked else None,
            )
            m = router.replay(_contention_corpus(),
                              vocab_size=cfg.vocab_size, max_new_tokens=4)
            assert m.steps_completed == 11
            s = m.ttft_s
            assert s["n"] == 11 and s["p50"] <= s["p95"]
            logs[chunked], ttft[chunked] = router.output_log, s["mean"]
            if chunked:
                assert m.prefill_chunks > 0
        assert logs[False] == logs[True]
        assert ttft[True] < ttft[False]

    def test_chunked_requires_the_pump(self, setup):
        cfg, params = setup
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                        n_host_pages=64, max_slots=2, max_seq=256)
        with pytest.raises(ValueError, match="decode pump"):
            MoriRouter([engine], scheduler="mori", serial_decode=True,
                       chunked_prefill=True)

    def test_chunked_requires_paged_engines(self, setup):
        cfg, params = setup
        engine = Engine(cfg, params, dense_slots=True, max_slots=2,
                        max_seq=256)
        with pytest.raises(ValueError, match="paged"):
            MoriRouter([engine], scheduler="mori", chunked_prefill=True)


GOLDEN = Path(__file__).parent / "data" / "golden_chunked_replay.json"
SERIAL_GOLDEN = Path(__file__).parent / "data" / "golden_serial_replay.json"
#: the serial-replay golden may only move when the *replay harness*
#: changes, never when an execution-path PR lands. Last regeneration:
#: the multi-replica failover PR made context synthesis per-program
#: (order-independent), so synthesized corpus token values shifted; the
#: serialized execution order itself is re-verified against the pump by
#: test_decode_pump's equivalence battery
SERIAL_GOLDEN_SHA256 = (
    "33c4a8903f4900afb710282d56708b357c9a743f28fcf351bcbf10eb7a76b469"
)


class TestChunkedGolden:
    def test_chunked_pump_replay_matches_golden(self, setup):
        """Pinned capture: a 4-program generated pressure corpus (async
        transfers, 2-slot engine, mid-window joins under gating) replayed
        through the chunked pump reproduces the golden token streams,
        step count and chunk count exactly."""
        cfg, params = setup
        golden = json.loads(GOLDEN.read_text())

        from repro.core.types import TransferCost
        from repro.traces import TraceGenConfig, generate_corpus

        tg = TraceGenConfig(
            min_steps=3, mean_steps=4, max_steps=4,
            initial_context_mean=700, max_context=1800,
            long_median_s=20.0, busy_calls_mean=2.0, idle_calls_mean=2.0,
        )
        corpus = generate_corpus(4, seed=5, cfg=tg)
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=96,
                        n_host_pages=96, max_slots=2, max_seq=320)
        router = MoriRouter(
            [engine], scheduler="mori", gpu_capacity_bytes=500_000,
            config=SchedulerConfig(tick_interval_s=2.0),
            chunked_prefill=True, prefill_token_budget=64,
            xfer_cost=TransferCost(pcie_bytes_per_s=2e5),
        )
        m = router.replay(corpus, vocab_size=cfg.vocab_size,
                          max_new_tokens=4)
        assert router.output_log == golden["chunked_pump"]
        assert m.steps_completed == golden["chunked_pump_steps"]
        assert m.prefill_chunks == golden["chunked_pump_chunks"]
        assert m.gated_events >= 1          # joins really were mid-window

    def test_serial_golden_pinned(self):
        """The serial-replay golden capture file is byte-pinned: neither
        chunked prefill nor any later execution-path change may move it
        (test_decode_pump re-runs the replay itself; this pins the
        capture file). Regenerating it is only legitimate alongside a
        deliberate replay-harness change — see the note at
        SERIAL_GOLDEN_SHA256."""
        digest = hashlib.sha256(SERIAL_GOLDEN.read_bytes()).hexdigest()
        assert digest == SERIAL_GOLDEN_SHA256
