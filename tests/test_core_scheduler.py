"""Unit + property tests for MORI's three-tier scheduler (paper §4.3),
driven through the PlacementPlan protocol."""
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from _plan_driver import Driver
from repro.core import (
    Discard,
    Forward,
    MoriScheduler,
    Offload,
    SCHEDULERS,
    SchedulerConfig,
    Status,
    Tier,
    TierCapacity,
    TypeLabel,
)


def make(gpu=1000, cpu=1000, replicas=1, ssd=0, **cfg):
    d = Driver(
        MoriScheduler(replicas, TierCapacity(gpu, cpu, ssd), SchedulerConfig(**cfg))
    )
    return d, d


def drive_step(s, pid, input_tokens, output_tokens, t_start, reason_s, tool_s):
    """One full inference+tool cycle; returns end time."""
    s.request_arrived(pid, input_tokens, t_start)
    s.notify_inference_started(pid, t_start)
    s.request_completed(pid, output_tokens, t_start + reason_s)
    return t_start + reason_s + tool_s


class TestPlacementBasics:
    def test_new_program_admitted_to_gpu(self):
        s, ad = make()
        s.program_arrived("a", 1, 0.0)
        s.request_arrived("a", 100, 0.0)
        assert s.programs["a"].tier is Tier.GPU
        fwd = ad.of_kind(Forward)[0]
        assert (fwd.pid, fwd.replica) == ("a", 0)
        assert fwd.recompute and fwd.source_tier is Tier.WAITING

    def test_resident_program_forwarded_without_recompute(self):
        s, ad = make()
        s.program_arrived("a", 1, 0.0)
        t = drive_step(s, "a", 100, 10, 0.0, 1.0, 1.0)
        s.request_arrived("a", 120, t)
        fwd = ad.of_kind(Forward)[-1]
        assert (fwd.pid, fwd.replica) == ("a", 0)
        assert not fwd.recompute and fwd.source_tier is Tier.GPU

    def test_gpu_capacity_respected_on_admission(self):
        s, _ = make(gpu=100)
        s.program_arrived("a", 1, 0.0)
        s.request_arrived("a", 80, 0.0)
        s.program_arrived("b", 1, 0.0)
        s.request_arrived("b", 50, 0.0)  # doesn't fit alongside a
        assert s.programs["a"].tier is Tier.GPU
        assert s.programs["b"].tier is Tier.WAITING
        assert s.programs["b"].has_pending

    def test_action_ids_strictly_increase(self):
        s, ad = make()
        for i in range(3):
            s.program_arrived(f"p{i}", 1, 0.0)
            s.request_arrived(f"p{i}", 20 + i, 0.0)
        ids = [a.action_id for a in ad.actions]
        assert ids == sorted(ids) and len(set(ids)) == len(ids)


class TestDemotion:
    def test_growth_overflow_demotes_most_idle_acting(self):
        s, ad = make(gpu=200, cpu=1000)
        s.program_arrived("idle", 1, 0.0)
        s.program_arrived("busy", 1, 0.0)
        # interleave so both are observed at comparable wall-clock times:
        # "idle" spends ~50s per tool call, "busy" ~0.2s
        t_idle, t_busy = 0.0, 0.0
        for _ in range(5):
            t_idle = drive_step(
                s, "idle", s.programs["idle"].context_tokens + 10, 5, t_idle, 1.0, 50.0
            )
        while t_busy < t_idle - 2.0:
            t_busy = drive_step(
                s, "busy", s.programs["busy"].context_tokens + 1, 1, t_busy, 1.0, 0.2
            )
        now = max(t_idle, t_busy) - 1.0
        # both acting; shrink GPU so only one fits
        s.replicas[0].capacity = TierCapacity(
            max(s.programs["busy"].kv_bytes, s.programs["idle"].kv_bytes) + 5, 1000
        )
        s.tick(now)
        assert s.programs["idle"].tier is Tier.CPU  # most idle demoted
        assert s.programs["busy"].tier is Tier.GPU
        off = ad.of_kind(Offload)[-1]
        assert (off.pid, off.replica, off.dst_tier) == ("idle", 0, Tier.CPU)
        # the offload is ledger-tracked until the runtime acknowledges it
        assert s.ledger.open_offload("idle") is not None
        ad.ack_all(now)
        assert s.ledger.open_offload("idle") is None

    def test_demotion_to_waiting_when_cpu_full(self):
        s, ad = make(gpu=200, cpu=0)
        s.program_arrived("a", 1, 0.0)
        drive_step(s, "a", 150, 10, 0.0, 1.0, 100.0)
        s.replicas[0].capacity = TierCapacity(50, 0)
        s.tick(10.0)
        assert s.programs["a"].tier is Tier.WAITING
        assert any(
            d.pid == "a" and d.replica == 0 and d.tier is Tier.GPU
            for d in ad.of_kind(Discard)
        )

    def test_reasoning_program_demoted_lazily(self):
        s, _ = make(gpu=100, cpu=1000)
        s.program_arrived("a", 1, 0.0)
        s.request_arrived("a", 90, 0.0)
        s.notify_inference_started("a", 0.0)  # reasoning now
        s.replicas[0].capacity = TierCapacity(10, 1000)
        s.tick(1.0)
        # still on GPU (mid-step), but marked for lazy demotion
        assert s.programs["a"].tier is Tier.GPU
        assert s.programs["a"].lazy_demote
        s.request_completed("a", 5, 2.0)
        assert s.programs["a"].tier is Tier.CPU

    def test_second_tick_counts_pending_lazy_demotions(self):
        """Regression: a demote pass that runs while an earlier pass's
        lazy-demote victim is still mid-step must count that victim's
        pending bytes — the old code re-counted the same overflow and
        demoted extra Acting programs whose eviction was never needed."""
        s, ad = make(gpu=1000, cpu=1000)
        s.program_arrived("p0", 1, 0.0)
        s.request_arrived("p0", 60, 0.0)
        s.notify_inference_started("p0", 0.0)   # long step: reasoning
        s.program_arrived("q", 1, 0.0)
        s.request_arrived("q", 30, 0.0)
        s.notify_inference_started("q", 0.0)
        s.replicas[0].capacity = TierCapacity(80, 1000)
        s.tick(1.0)
        # 90 used > 80: p0 (mid-step) marked for lazy demotion; its 60
        # pending bytes already resolve the overflow, so q is untouched
        assert s.programs["p0"].lazy_demote
        assert not s.programs["q"].lazy_demote
        s.request_completed("q", 0, 2.0)        # q finishes its step: Acting
        plan = s.tick(3.0)                      # second pass, p0 still mid-step
        # the pending lazy demotion covers the overflow: q must NOT be
        # demoted (the bug double-counted and evicted it here)
        assert s.programs["q"].tier is Tier.GPU
        assert not s.programs["q"].lazy_demote
        assert s.programs["q"].metrics.demotions == 0
        assert not [o for o in plan.of_kind(Offload) if o.pid == "q"]
        # p0's step finally ends: the deferred demotion fires, q keeps GPU
        s.request_completed("p0", 0, 4.0)
        assert s.programs["p0"].tier is Tier.CPU
        assert s.programs["q"].tier is Tier.GPU
        assert [o.pid for o in ad.of_kind(Offload)] == ["p0"]
        s.replicas[0].check()
        assert s.replicas[0].gpu_used <= 80

    def test_cpu_admission_control_spills_busiest_to_waiting(self):
        s, _ = make(gpu=1000, cpu=100)
        for pid, tool_s in [("busyish", 1.0), ("idler", 80.0)]:
            s.program_arrived(pid, 1, 0.0)
            t = 0.0
            for _ in range(3):
                t = drive_step(s, pid, s.programs[pid].context_tokens + 20, 10, t, 1.0, tool_s)
        # force both to CPU then shrink CPU
        s.replicas[0].capacity = TierCapacity(0, 100)
        s.tick(100.0)
        s.replicas[0].capacity = TierCapacity(0, s.programs["idler"].kv_bytes)
        s.tick(101.0)
        assert s.programs["idler"].tier is Tier.CPU  # CPU retains the idle one
        assert s.programs["busyish"].tier is Tier.WAITING


class TestPromotion:
    def test_cpu_promotion_preserves_affinity_and_reloads(self):
        s, ad = make(gpu=300, cpu=1000, replicas=2)
        s.program_arrived("a", 1, 0.0)
        t = drive_step(s, "a", 100, 10, 0.0, 1.0, 60.0)
        home = s.programs["a"].replica
        s.replicas[home].capacity = TierCapacity(0, 1000)
        s.tick(30.0)  # demote to CPU
        assert s.programs["a"].tier is Tier.CPU
        ad.ack_all(30.0)  # offload transfer lands
        s.replicas[home].capacity = TierCapacity(300, 1000)
        s.request_arrived("a", 130, t)  # tool done -> pending
        s.tick(t + 1.0)
        assert s.programs["a"].tier is Tier.GPU
        assert s.programs["a"].replica == home  # affinity preserved
        fwd = ad.of_kind(Forward)[-1]
        assert fwd.source_tier is Tier.CPU and not fwd.recompute
        # the reload moves only the KV materialized before the offload, not
        # the new input tokens that arrived while the program sat on CPU
        assert fwd.nbytes == s.programs["a"].materialized_bytes
        assert fwd.nbytes < s.programs["a"].kv_bytes

    def test_swap_idle_gpu_resident_for_busy_returner(self):
        s, _ = make(gpu=100, cpu=1000)
        # "idle" occupies all of GPU and sits in a long tool call
        s.program_arrived("idle", 1, 0.0)
        t = 0.0
        for _ in range(3):
            t = drive_step(s, "idle", s.programs["idle"].context_tokens + 30, 2, t, 0.5, 90.0)
        # "busy" cycles fast but was evicted to CPU earlier
        s.program_arrived("busy", 1, 0.0)
        s.waiting.remove(s.programs["busy"])
        s.programs["busy"].context_tokens = 50
        s.replicas[0].cpu_admit(s.programs["busy"])
        tb = 270.0  # recent busy cycles, ending just before the request
        for _ in range(4):
            s.programs["busy"].tracker.transition(Status.REASONING, tb)
            s.programs["busy"].tracker.transition(Status.ACTING, tb + 2.0)
            tb += 2.2
        s.request_arrived("busy", 50, 280.0)
        s.tick(281.0)
        assert s.programs["busy"].tier is Tier.GPU  # swapped in
        assert s.programs["idle"].tier is Tier.CPU  # swapped out

    def test_new_arrivals_admitted_smallest_first(self):
        s, _ = make(gpu=100, cpu=0, eager_promote=False)
        for pid, ctx in [("big", 70), ("small", 20), ("mid", 40)]:
            s.program_arrived(pid, 1, 0.0)
            s.request_arrived(pid, ctx, 0.0)
        s.tick(1.0)
        tiers = {p: s.programs[p].tier for p in ("small", "mid", "big")}
        assert tiers["small"] is Tier.GPU
        assert tiers["mid"] is Tier.GPU  # 20+40 <= 100
        assert tiers["big"] is Tier.WAITING


class TestLabels:
    def test_labels_follow_tiers(self):
        s, ad = make(gpu=100, cpu=1000)
        s.program_arrived("a", 1, 0.0)
        drive_step(s, "a", 90, 5, 0.0, 1.0, 60.0)
        s.tick(5.0)
        assert s.programs["a"].label is TypeLabel.BUSY
        s.replicas[0].capacity = TierCapacity(10, 1000)
        s.tick(70.0)
        assert s.programs["a"].label is TypeLabel.IDLE
        s.replicas[0].capacity = TierCapacity(10, 0)
        s.tick(71.0)
        assert s.programs["a"].label is TypeLabel.INACTIVE


class TestMultiReplica:
    def test_waiting_promotion_goes_to_most_available(self):
        s, _ = make(gpu=100, cpu=100, replicas=3, eager_promote=False)
        s.program_arrived("filler", 1, 0.0)
        s.request_arrived("filler", 60, 0.0)
        s.tick(0.5)
        filled = s.programs["filler"].replica
        s.program_arrived("x", 1, 1.0)
        s.request_arrived("x", 50, 1.0)
        s.tick(1.5)
        assert s.programs["x"].replica != filled

    def test_finished_program_frees_capacity_everywhere(self):
        s, _ = make(gpu=100, cpu=100, replicas=2)
        s.program_arrived("a", 1, 0.0)
        drive_step(s, "a", 80, 10, 0.0, 1.0, 1.0)
        rep = s.programs["a"].replica
        s.program_finished("a", 5.0)
        assert s.replicas[rep].gpu_used == 0
        assert "a" not in s.programs

    def test_replica_failure_discards_and_requeues(self):
        s, ad = make(gpu=200, cpu=200, replicas=2)
        s.program_arrived("a", 1, 0.0)
        drive_step(s, "a", 80, 10, 0.0, 1.0, 30.0)
        rep = s.programs["a"].replica
        plan = s.replica_failed(rep, 5.0)
        assert any(
            d.pid == "a" and d.tier is Tier.GPU for d in plan.of_kind(Discard)
        )
        assert s.programs["a"].tier is Tier.WAITING
        assert len(s.ledger.in_flight(replica=rep)) == 0


@given(
    seed=st.integers(0, 10_000),
    n_programs=st.integers(2, 8),
    gpu=st.integers(50, 400),
    cpu=st.integers(0, 400),
)
@settings(max_examples=60, deadline=None)
def test_property_capacity_invariants_under_random_workload(seed, n_programs, gpu, cpu):
    """After any event sequence: per-tier byte accounting is exact, no
    program is in two tiers, and GPU/CPU never exceed capacity after a tick
    (modulo lazily-demoted reasoning programs)."""
    import random

    rng = random.Random(seed)
    s, _ = make(gpu=gpu, cpu=cpu)
    t = 0.0
    active = {}
    for i in range(n_programs):
        pid = f"p{i}"
        s.program_arrived(pid, 1, t)
        active[pid] = 10 + rng.randrange(40)
    for _ in range(40):
        pid = rng.choice(list(active))
        prog = s.programs[pid]
        if prog.status in (Status.ACTING,) and not prog.has_pending:
            active[pid] += rng.randrange(20)
            s.request_arrived(pid, active[pid], t)
        elif prog.status is Status.GATED and prog.tier is Tier.GPU:
            s.notify_inference_started(pid, t)
        elif prog.status is Status.REASONING:
            out = rng.randrange(1, 15)
            active[pid] += out
            s.request_completed(pid, out, t)
        t += rng.random() * 5
        if rng.random() < 0.3:
            s.tick(t)
        for rep in s.replicas:
            rep.check()
        gpu_pids = {p for rep in s.replicas for p in rep.gpu}
        cpu_pids = {p for rep in s.replicas for p in rep.cpu}
        assert not (gpu_pids & cpu_pids)
        assert not (gpu_pids & set(s.waiting.programs))
    s.tick(t + 10)
    for rep in s.replicas:
        lazy = sum(p.kv_bytes for p in rep.gpu.values() if p.lazy_demote)
        assert rep.gpu_used - lazy <= rep.capacity.gpu_kv_bytes
        assert rep.cpu_used <= rep.capacity.cpu_kv_bytes


@given(
    seed=st.integers(0, 10_000),
    n_programs=st.integers(2, 8),
    gpu=st.integers(50, 400),
    cpu=st.integers(0, 300),
    ssd=st.integers(0, 300),
)
@settings(max_examples=60, deadline=None)
def test_property_invariants_with_ssd_tier(seed, n_programs, gpu, cpu, ssd):
    """The §7.1 SSD tier preserves every invariant of the two-tier design:
    exact byte accounting, tier exclusivity across all four placements,
    capacity bounds after a tick."""
    import random

    rng = random.Random(seed)
    s, _ = make(gpu=gpu, cpu=cpu, ssd=ssd)
    t = 0.0
    active = {}
    for i in range(n_programs):
        pid = f"p{i}"
        s.program_arrived(pid, 1, t)
        active[pid] = 10 + rng.randrange(40)
    for _ in range(40):
        pid = rng.choice(list(active))
        prog = s.programs[pid]
        if prog.status in (Status.ACTING,) and not prog.has_pending:
            active[pid] += rng.randrange(20)
            s.request_arrived(pid, active[pid], t)
        elif prog.status is Status.GATED and prog.tier is Tier.GPU:
            s.notify_inference_started(pid, t)
        elif prog.status is Status.REASONING:
            out = rng.randrange(1, 15)
            active[pid] += out
            s.request_completed(pid, out, t)
        t += rng.random() * 5
        if rng.random() < 0.3:
            s.tick(t)
        for rep in s.replicas:
            rep.check()
        placements = [
            {p for rep in s.replicas for p in rep.gpu},
            {p for rep in s.replicas for p in rep.cpu},
            {p for rep in s.replicas for p in rep.ssd},
            set(s.waiting.programs),
        ]
        for i, a in enumerate(placements):
            for b in placements[i + 1:]:
                assert not (a & b)
    s.tick(t + 10)
    for rep in s.replicas:
        lazy = sum(p.kv_bytes for p in rep.gpu.values() if p.lazy_demote)
        assert rep.gpu_used - lazy <= rep.capacity.gpu_kv_bytes
        assert rep.cpu_used <= rep.capacity.cpu_kv_bytes
        assert rep.ssd_used <= rep.capacity.ssd_kv_bytes


@pytest.mark.parametrize("name", list(SCHEDULERS))
def test_all_schedulers_run_a_small_workload(name):
    s = Driver(SCHEDULERS[name](2, TierCapacity(500, 500)))
    t = 0.0
    for i in range(3):
        s.program_arrived(f"p{i}", 1, t)
    for step in range(4):
        for i in range(3):
            pid = f"p{i}"
            if pid not in s.programs:
                continue
            prog = s.programs[pid]
            s.request_arrived(pid, prog.context_tokens + 20, t)
            if prog.tier is Tier.GPU:
                s.notify_inference_started(pid, t)
                s.request_completed(pid, 10, t + 1.0)
            t += 0.5
        s.tick(t)
        s.ack_all(t)
    for i in range(3):
        if f"p{i}" in s.programs:
            s.program_finished(f"p{i}", t)
    assert all(rep.gpu_used == 0 for rep in s.replicas)
