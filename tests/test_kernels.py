"""Per-kernel validation (deliverable c): shape/dtype sweeps in interpret
mode against the pure-jnp oracles, plus hypothesis property tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.kernels.flash_attention.kernel import flash_attention
from repro.kernels.flash_attention.ref import flash_attention_ref
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd.kernel import ssd
from repro.kernels.ssd.ref import ssd_naive, ssd_reference

RNG = np.random.default_rng(42)


def randn(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else dict(
        rtol=2e-3, atol=2e-3
    )


# ============================================================ paged attention
PAGED_SHAPES = [
    # B, H, KH, D, page_tokens, pages_per_seq
    (1, 4, 4, 64, 8, 2),      # MHA
    (3, 8, 2, 64, 8, 4),      # GQA 4:1
    (2, 16, 8, 128, 16, 3),   # GQA 2:1, 128-dim
    (4, 4, 1, 64, 16, 5),     # MQA
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", PAGED_SHAPES)
def test_paged_attention_matches_ref(shape, dtype):
    B, H, KH, D, T, P = shape
    n_pages = B * P + 3
    q = randn((B, H, D), dtype)
    k = randn((n_pages, T, KH, D), dtype)
    v = randn((n_pages, T, KH, D), dtype)
    tables = jnp.asarray(
        RNG.permutation(n_pages)[: B * P].reshape(B, P), jnp.int32
    )
    lengths = jnp.asarray(RNG.integers(1, P * T + 1, B), jnp.int32)
    out = paged_attention(q, k, v, tables, lengths, interpret=True)
    ref = paged_attention_ref(q, k, v, tables, lengths)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_paged_attention_softcap():
    B, H, KH, D, T, P = 2, 8, 4, 64, 8, 3
    q = randn((B, H, D), jnp.float32)
    k = randn((B * P, T, KH, D), jnp.float32)
    v = randn((B * P, T, KH, D), jnp.float32)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.asarray([T * P, T + 3], jnp.int32)
    out = paged_attention(q, k, v, tables, lengths, softcap=20.0, interpret=True)
    ref = paged_attention_ref(q, k, v, tables, lengths, softcap=20.0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("window", [6, 16, 100])
def test_paged_attention_sliding_window(window):
    """Kernel vs ref across window sizes smaller than / spanning / larger
    than the context (ragged lengths include a partially-filled tail page)."""
    B, H, KH, D, T, P = 3, 8, 4, 64, 8, 4
    q = randn((B, H, D), jnp.float32)
    k = randn((B * P, T, KH, D), jnp.float32)
    v = randn((B * P, T, KH, D), jnp.float32)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.asarray([T * P, 2 * T + 5, 3], jnp.int32)
    out = paged_attention(q, k, v, tables, lengths, window=window, interpret=True)
    ref = paged_attention_ref(q, k, v, tables, lengths, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)


def test_paged_attention_window_matches_decode_attention():
    """Cross-oracle: the paged ref's window semantics equal the dense-slot
    decode_attention the engine's compatibility path uses."""
    from repro.models.layers import decode_attention

    B, H, KH, D, T, P = 2, 4, 2, 64, 8, 3
    window = 10
    q = randn((B, H, D), jnp.float32)
    k = randn((B * P, T, KH, D), jnp.float32)
    v = randn((B * P, T, KH, D), jnp.float32)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.asarray([T * P - 2, T + 3], jnp.int32)
    ref = paged_attention_ref(q, k, v, tables, lengths, window=window)
    k_dense = k[tables].reshape(B, P * T, KH, D)
    v_dense = v[tables].reshape(B, P * T, KH, D)
    dense = decode_attention(
        q, k_dense, v_dense, lengths=lengths, window=window
    ).reshape(B, H, D)
    np.testing.assert_allclose(np.asarray(ref), np.asarray(dense), rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_paged_attention_gqa_softcap_window_combined(dtype):
    """The gemma2-shaped corner all at once: GQA 4:1 + logit softcap +
    sliding window on ragged lengths with partial tail pages."""
    B, H, KH, D, T, P = 2, 8, 2, 64, 16, 3
    q = randn((B, H, D), dtype)
    k = randn((B * P, T, KH, D), dtype)
    v = randn((B * P, T, KH, D), dtype)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.asarray([2 * T + 7, T - 1], jnp.int32)
    out = paged_attention(
        q, k, v, tables, lengths, softcap=50.0, window=20, interpret=True
    )
    ref = paged_attention_ref(q, k, v, tables, lengths, softcap=50.0, window=20)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_paged_attention_partial_tail_page_isolated():
    """A partially-filled tail page: tokens at or past `lengths` in the
    tail page must not affect the output (the block-table decode appends
    there next step)."""
    B, H, KH, D, T, P = 1, 4, 2, 64, 8, 2
    q = randn((B, H, D), jnp.float32)
    k = randn((B * P, T, KH, D), jnp.float32)
    v = randn((B * P, T, KH, D), jnp.float32)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.asarray([T + 5], jnp.int32)  # tail page 5/8 full
    out1 = paged_attention(q, k, v, tables, lengths, interpret=True)
    k2 = k.at[1, 5:].set(123.0)  # poison the unwritten tail slots
    v2 = v.at[1, 5:].set(-123.0)
    out2 = paged_attention(q, k2, v2, tables, lengths, interpret=True)
    ref2 = paged_attention_ref(q, k2, v2, tables, lengths)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(out2), np.asarray(ref2), rtol=2e-3, atol=2e-3)


def test_paged_attention_ignores_garbage_beyond_length():
    """Pages past `lengths` must not affect the result (MORI evicts them)."""
    B, H, KH, D, T, P = 1, 4, 2, 64, 8, 3
    q = randn((B, H, D), jnp.float32)
    k = randn((B * P, T, KH, D), jnp.float32)
    v = randn((B * P, T, KH, D), jnp.float32)
    tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.asarray([T + 2], jnp.int32)
    out1 = paged_attention(q, k, v, tables, lengths, interpret=True)
    k2 = k.at[2].set(1e4)  # poison the unused page
    v2 = v.at[2].set(-1e4)
    out2 = paged_attention(q, k2, v2, tables, lengths, interpret=True)
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-6)


# ============================================================ flash attention
FLASH_SHAPES = [
    # B, H, KH, S, D, qb, kb
    (2, 4, 4, 64, 32, 16, 16),
    (1, 8, 2, 128, 64, 32, 32),
    (2, 4, 1, 64, 64, 64, 16),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", FLASH_SHAPES)
@pytest.mark.parametrize("variant", ["causal", "window", "bidir", "softcap"])
def test_flash_attention_matches_ref(shape, dtype, variant):
    B, H, KH, S, D, qb, kb = shape
    kwargs = {
        "causal": dict(causal=True),
        "window": dict(causal=True, window=24),
        "bidir": dict(causal=False),
        "softcap": dict(causal=True, softcap=50.0),
    }[variant]
    q = randn((B, H, S, D), dtype)
    k = randn((B, KH, S, D), dtype)
    v = randn((B, KH, S, D), dtype)
    out = flash_attention(q, k, v, q_block=qb, kv_block=kb, interpret=True, **kwargs)
    ref = flash_attention_ref(q, k, v, **kwargs)
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), **tol(dtype)
    )


def test_flash_attention_q_offset_decode_chunk():
    """Chunked prefill: suffix attends over full KV with offset positions."""
    B, H, S, D = 1, 4, 64, 32
    q_full = randn((B, H, S, D), jnp.float32)
    k = randn((B, H, S, D), jnp.float32)
    v = randn((B, H, S, D), jnp.float32)
    full = flash_attention_ref(q_full, k, v, causal=True)
    tail = flash_attention(
        q_full[:, :, 32:], k, v, causal=True, q_offset=32,
        q_block=16, kv_block=16, interpret=True,
    )
    np.testing.assert_allclose(
        np.asarray(tail), np.asarray(full[:, :, 32:]), rtol=2e-3, atol=2e-3
    )


# ======================================================================== ssd
SSD_SHAPES = [
    # b, s, h, p, n, chunk
    (2, 32, 2, 8, 8, 8),
    (1, 64, 4, 16, 16, 16),
    (2, 128, 4, 32, 16, 32),
]


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", SSD_SHAPES)
def test_ssd_kernel_matches_chunked_ref(shape, dtype):
    b, s, h, p, n, chunk = shape
    x = randn((b, s, h, p), dtype)
    dt = jax.nn.softplus(randn((b, s, h), jnp.float32))
    A = -jnp.abs(randn((h,), jnp.float32))
    B = randn((b, s, n), jnp.float32)
    C = randn((b, s, n), jnp.float32)
    yk, sk = ssd(x, dt, A, B, C, chunk=chunk, interpret=True)
    yr, sr = ssd_reference(
        x, dt, A, B[:, :, None, :], C[:, :, None, :], chunk=chunk
    )
    np.testing.assert_allclose(
        np.asarray(yk, np.float32), np.asarray(yr, np.float32), **tol(dtype)
    )
    np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-2, atol=1e-2)


def test_ssd_chunked_ref_matches_naive_scan():
    """The chunked decomposition equals the O(s) sequential recurrence."""
    b, s, h, p, n = 1, 24, 2, 4, 8
    x = randn((b, s, h, p), jnp.float32)
    dt = jax.nn.softplus(randn((b, s, h), jnp.float32))
    A = -jnp.abs(randn((h,), jnp.float32))
    B = randn((b, s, 1, n), jnp.float32)
    C = randn((b, s, 1, n), jnp.float32)
    yr, sr = ssd_reference(x, dt, A, B, C, chunk=8)
    yn, sn = ssd_naive(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(yr), np.asarray(yn), rtol=2e-3, atol=2e-3)
    np.testing.assert_allclose(np.asarray(sr), np.asarray(sn), rtol=2e-3, atol=2e-3)


# ========================================================== property testing
@given(
    seed=st.integers(0, 2**16),
    kh=st.sampled_from([1, 2, 4]),
    g=st.sampled_from([1, 2, 4]),
    pages=st.integers(1, 4),
)
@settings(max_examples=20, deadline=None)
def test_property_paged_attention_equals_ref(seed, kh, g, pages):
    rng = np.random.default_rng(seed)
    B, T, D = 2, 8, 32
    H = kh * g
    n_pages = B * pages + 1
    q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((n_pages, T, kh, D)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((n_pages, T, kh, D)), jnp.float32)
    tables = jnp.asarray(
        rng.permutation(n_pages)[: B * pages].reshape(B, pages), jnp.int32
    )
    lengths = jnp.asarray(rng.integers(1, pages * T + 1, B), jnp.int32)
    out = paged_attention(q, k, v, tables, lengths, interpret=True)
    ref = paged_attention_ref(q, k, v, tables, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=5e-3, atol=5e-3)


@given(seed=st.integers(0, 2**16), chunk=st.sampled_from([4, 8, 16]))
@settings(max_examples=15, deadline=None)
def test_property_ssd_chunk_invariance(seed, chunk):
    """The SSD result must be independent of the chunking factor."""
    rng = np.random.default_rng(seed)
    b, s, h, p, n = 1, 32, 2, 8, 4
    x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
    dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32))
    A = -jnp.abs(jnp.asarray(rng.standard_normal((h,)), jnp.float32))
    B = jnp.asarray(rng.standard_normal((b, s, 1, n)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((b, s, 1, n)), jnp.float32)
    y1, s1 = ssd_reference(x, dt, A, B, C, chunk=chunk)
    y2, s2 = ssd_reference(x, dt, A, B, C, chunk=s)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-3, atol=5e-3)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2), rtol=5e-3, atol=5e-3)
