"""JITAUDIT + compile tracker: warmup completeness, the zero-post-warmup-
compile budget through the real pump, and the seeded-violation fixtures
(a broken donation and a shape-branching fn MUST be caught — an auditor
that cannot detect a planted bug certifies nothing)."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import pytest

from repro.analysis import compile_tracker, jitaudit
from repro.configs import get_config
from repro.models import Model, materialize
from repro.serving import Engine, MoriRouter


@pytest.fixture(scope="module")
def cfg():
    return get_config("qwen1.5-0.5b").reduced()


@pytest.fixture(scope="module")
def params(cfg):
    return materialize(Model(cfg).describe(), seed=0)


def make_engine(cfg, params, **kw):
    kw.setdefault("page_tokens", 16)
    kw.setdefault("n_device_pages", 96)
    kw.setdefault("n_host_pages", 64)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 128)
    return Engine(cfg, params, **kw)


@pytest.fixture
def tracker(monkeypatch):
    """Armed, clean tracker; reset on the way out so the process-global
    singleton never leaks registrations into other tests."""
    monkeypatch.setenv(compile_tracker.ENV_VAR, "1")
    t = compile_tracker.get_tracker()
    t.reset()
    yield t
    t.reset()
    t.disarm()


# --------------------------------------------------------------- warmup specs
class TestWarmupSpecs:
    def test_paged_specs_cover_every_bucket(self, cfg, params):
        eng = make_engine(cfg, params)
        specs = eng.warmup_specs(prefill_chunks=True)
        n_buckets = -(-eng.pages_per_slot // eng._table_bucket)
        decode = [s for s in specs if s.kind == "paged_decode"]
        chunk = [s for s in specs if s.kind == "chunk_prefill"]
        assert len(decode) == n_buckets
        assert [s.bucket["table_pages"] for s in decode] == [
            i * eng._table_bucket for i in range(1, n_buckets + 1)
        ]
        # every (prefix bucket x chunk bucket) pair up to the chunk cap
        cap = max(eng.page_tokens,
                  (eng.prefill_chunk_tokens // eng.page_tokens)
                  * eng.page_tokens)
        cap_pad = -(-cap // eng.prefill_bucket) * eng.prefill_bucket
        n_chunk_buckets = cap_pad // eng.prefill_bucket
        assert len(chunk) == (n_buckets + 1) * n_chunk_buckets
        assert len({s.name for s in specs}) == len(specs)

    def test_prefill_chunks_off_omits_chunk_specs(self, cfg, params):
        eng = make_engine(cfg, params)
        kinds = {s.kind for s in eng.warmup_specs(prefill_chunks=False)}
        assert kinds == {"paged_decode"}

    def test_dense_single_spec(self, cfg, params):
        eng = make_engine(cfg, params, dense_slots=True, n_device_pages=8,
                          n_host_pages=8, max_seq=64)
        specs = eng.warmup_specs(prefill_chunks=True)
        assert [s.kind for s in specs] == ["dense"]
        assert specs[0].donate_argnums == (1, 2)

    def test_warmup_compiles_exactly_the_specs(self, cfg, params):
        eng = make_engine(cfg, params)
        specs = eng.warmup_specs(prefill_chunks=True)
        n_decode = sum(s.kind == "paged_decode" for s in specs)
        chunk_before = eng._chunk_fn._cache_size()
        eng.warmup(prefill_chunks=True)
        # the decode fn is per-engine, so its cache is exactly the buckets;
        # the chunk fn is process-shared, so bound the *delta* instead
        assert eng._paged_decode_fn._cache_size() == n_decode
        n_chunk = sum(s.kind == "chunk_prefill" for s in specs)
        assert eng._chunk_fn._cache_size() - chunk_before <= n_chunk
        # idempotence: a second warmup is all cache hits
        decode_size = eng._paged_decode_fn._cache_size()
        chunk_size = eng._chunk_fn._cache_size()
        eng.warmup(prefill_chunks=True)
        assert eng._paged_decode_fn._cache_size() == decode_size
        assert eng._chunk_fn._cache_size() == chunk_size


# ------------------------------------------------------------ compile budget
class TestCompileBudget:
    def _replay(self, cfg, params, engine):
        from repro.core.types import ProgramTrace, RequestRecord

        router = MoriRouter(
            [engine], scheduler="mori",
            gpu_capacity_bytes=(engine.radix_device_pages
                                * engine.pool.page_bytes),
            chunked_prefill=True,
        )
        corpus = [
            ProgramTrace(f"p{p}", [
                RequestRecord(input_tokens=20 + 11 * p + 5 * s,
                              output_tokens=3,
                              tool_duration_s=0.0 if s == 1 else 4.0,
                              reasoning_wall_s=0.0)
                for s in range(2)
            ])
            for p in range(3)
        ]
        router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=3)
        return router

    def test_pump_replay_compiles_nothing_after_warmup(
        self, cfg, params, tracker
    ):
        eng = make_engine(cfg, params)        # registers (env armed)
        assert set(eng.jit_functions()) <= set(tracker.registered())
        eng.warmup(prefill_chunks=True)       # marks the warm baseline
        assert tracker.marked()
        self._replay(cfg, params, eng)        # raises via the router hook
        assert tracker.post_warmup_compiles() == {}

    def test_post_warmup_compile_detected_and_replay_fails(
        self, cfg, params, tracker
    ):
        eng = make_engine(cfg, params)
        eng.warmup(prefill_chunks=True)
        # seed a bucket escape: a table width warmup never compiled
        import numpy as np

        scratch = np.asarray(eng._scratch_pages, np.int32)
        rogue = 3 * eng._table_bucket + eng.pages_per_slot  # off-bucket
        tables = np.repeat(scratch[:, None], rogue, axis=1)
        k_pages, v_pages = eng.pool.block_table_view()
        _, nk, nv = eng._paged_decode_fn(
            eng.params, k_pages, v_pages,
            jnp.zeros(eng.max_slots, jnp.int32),
            jnp.ones(eng.max_slots, jnp.int32),
            jnp.asarray(tables), jnp.asarray(scratch),
            jnp.zeros(eng.max_slots, jnp.int32),
        )
        eng.pool.adopt(nk, nv)
        grew = tracker.post_warmup_compiles()
        assert any("paged_decode" in name for name in grew)
        router = MoriRouter(
            [eng], scheduler="mori",
            gpu_capacity_bytes=(eng.radix_device_pages
                                * eng.pool.page_bytes),
        )
        with pytest.raises(RuntimeError, match="compile budget violated"):
            router._jitaudit_end_of_replay()

    def test_tracker_unarmed_is_inert(self, cfg, params, monkeypatch):
        monkeypatch.delenv(compile_tracker.ENV_VAR, raising=False)
        t = compile_tracker.get_tracker()
        assert not compile_tracker.enabled()
        eng = make_engine(cfg, params)
        # only the per-engine names are conclusive: the shared chunk fn's
        # stable name may have been registered by an earlier armed test
        mine = [n for n in eng.jit_functions()
                if f"engine{eng._audit_id}" in n]
        assert mine and not any(n in t.registered() for n in mine)


# ------------------------------------------------------- seeded violations
class TestSeededViolations:
    def test_broken_donation_fires_verifier(self):
        k = jnp.zeros((8, 16), jnp.bfloat16)
        target = jitaudit.AuditTarget(
            "broken",
            jax.jit(lambda a, b: (a.astype(jnp.float32), b),
                    donate_argnums=(0, 1)),
            lambda: (k, k + 1), donate_argnums=(0, 1))
        _, lowered, compiled, notes = jitaudit.trace_target(target)
        vs = jitaudit.verify_donation(target, lowered, compiled, notes)
        assert vs and vs[0].pass_name == "donation"
        assert "dropped at lowering" in vs[0].msg

    def test_honored_donation_is_clean(self):
        k = jnp.zeros((8, 16), jnp.bfloat16)
        target = jitaudit.AuditTarget(
            "ok", jax.jit(lambda a, b: (a + 1, b * 2), donate_argnums=(0, 1)),
            lambda: (k, k + 1), donate_argnums=(0, 1))
        _, lowered, compiled, notes = jitaudit.trace_target(target)
        assert jitaudit.verify_donation(target, lowered, compiled, notes) == []

    def test_shape_branch_probe_fires(self):
        def branchy(x):
            if x.shape[0] > 8:  # lint: jit-shape-branch-ok — seeded
                return x * 2
            return x + 1

        target = jitaudit.AuditTarget(
            "branchy", jax.jit(branchy), lambda: (jnp.zeros(8),),
            probe_args=lambda: (jnp.zeros(16),))
        traced = target.fn.trace(*target.make_args())
        vs = jitaudit.retrace_hazards(target, traced)
        assert any("primitive structure differs" in v.msg for v in vs)

    def test_baked_constant_and_weak_type_fire(self):
        pool = jnp.zeros((64, 64), jnp.float32)
        baked = jitaudit.AuditTarget(
            "baked", jax.jit(lambda x: x + pool[0]),
            lambda: (jnp.zeros(64),))
        vs = jitaudit.retrace_hazards(
            baked, baked.fn.trace(*baked.make_args()))
        assert any("constant" in v.msg for v in vs)
        weak = jitaudit.AuditTarget(
            "weak", jax.jit(lambda a, b: a * b),
            lambda: (2.5, jnp.zeros(4)))
        vs = jitaudit.retrace_hazards(weak, weak.fn.trace(*weak.make_args()))
        assert any("weak" in v.msg for v in vs)

    def test_selftest_catches_all_classes(self):
        assert jitaudit.selftest() == []


# --------------------------------------------------------------- real targets
class TestRealTargets:
    def test_engine_decode_target_clean_and_in_band(self, cfg, params):
        eng = make_engine(cfg, params)
        targets = jitaudit.engine_targets(eng, prefill_chunks=False)
        assert targets, "engine produced no audit targets"
        t = targets[0]
        traced, lowered, compiled, notes = jitaudit.trace_target(t)
        assert jitaudit.verify_donation(t, lowered, compiled, notes) == []
        assert jitaudit.retrace_hazards(t, traced) == []
        row = jitaudit.roofline_row(t, traced, compiled)
        assert jitaudit.check_roofline(t, row) == []
        # the pool k/v donations must be honored by the compiled module
        from repro.launch.hlo_cost import parse_input_output_alias

        assert len(parse_input_output_alias(compiled.as_text())) >= 2

    def test_kernel_targets_trace_and_stay_in_band(self):
        for t in jitaudit.kernel_targets():
            traced, _, compiled, _ = jitaudit.trace_target(t)
            assert jitaudit.retrace_hazards(t, traced) == [], t.name
            row = jitaudit.roofline_row(t, traced, compiled)
            assert jitaudit.check_roofline(t, row) == [], (t.name, row)


# ------------------------------------------------------------- tracker unit
class TestTrackerUnit:
    def test_register_mark_and_growth(self, tracker):
        f = jax.jit(functools.partial(jnp.multiply, 2))
        tracker.register("unit.f", f)
        f(jnp.zeros(4))
        tracker.mark_warm(("unit.f",))
        assert tracker.post_warmup_compiles() == {}
        f(jnp.zeros(8))                      # new shape -> new lowering
        assert tracker.post_warmup_compiles() == {"unit.f": (1, 2)}

    def test_same_object_reregistration_keeps_baseline(self, tracker):
        f = jax.jit(lambda x: x + 1)
        tracker.register("unit.shared", f)
        f(jnp.zeros(4))
        tracker.mark_warm(("unit.shared",))
        tracker.register("unit.shared", f)   # same object: no-op
        assert tracker.post_warmup_compiles() == {}

    def test_phase_tagged_backend_compiles(self, tracker):
        f = jax.jit(lambda x: x * 3 + 1)
        with tracker.phase("unit-test-phase"):
            f(jnp.arange(7))
        assert len(tracker.events_in("unit-test-phase")) >= 1
