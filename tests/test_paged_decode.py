"""Block-table decode path: golden token identity vs the dense-slot
compatibility path, pool append/adopt API, reload-under-pressure
regressions, and the ops-level interpret dispatch the CI kernel-parity job
exercises."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Model, materialize
from repro.serving import Engine, EngineRequest, MoriRouter
from repro.serving.engine import greedy_token
from repro.serving.kvpool import PagePool
from repro.traces import TraceGenConfig, generate_corpus


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").reduced()
    model = Model(cfg)
    params = materialize(model.describe(), seed=0)
    return cfg, model, params


def make_engine(cfg, params, **kw):
    kw.setdefault("page_tokens", 8)
    kw.setdefault("n_device_pages", 64)
    kw.setdefault("n_host_pages", 64)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 256)
    return Engine(cfg, params, **kw)


def replay_rounds(eng, *, rounds=3, new_tokens=4, n_programs=2, seed=7):
    """Agentic multi-round replay: each round extends every program's
    context with its previous outputs plus a couple of tool tokens, so
    later rounds hit the radix cache and decode crosses page boundaries
    (partial tail pages included — contexts are not page-multiples)."""
    rng = np.random.default_rng(seed)
    ctxs = {
        f"p{i}": list(rng.integers(2, 500, size=37 + 5 * i)) for i in range(n_programs)
    }
    streams: dict[str, list[int]] = {pid: [] for pid in ctxs}
    for _ in range(rounds):
        for pid in ctxs:
            eng.submit(EngineRequest(pid, list(ctxs[pid]), max_new_tokens=new_tokens))
            comp = eng.run_to_completion()[0]
            streams[pid].extend(comp.output_tokens)
            ctxs[pid].extend(comp.output_tokens[:-1])
            ctxs[pid].extend(int(t) for t in rng.integers(2, 500, size=3))
    return streams


class TestGoldenTokenIdentity:
    def test_engine_replay_matches_dense_slots(self, setup):
        """The tentpole's contract: dense_slots=True and the block-table
        path produce token-identical streams on a replayed trace."""
        cfg, _, params = setup
        paged = make_engine(cfg, params)
        dense = make_engine(cfg, params, dense_slots=True)
        assert not paged.dense_slots and dense.dense_slots
        s_paged = replay_rounds(paged)
        s_dense = replay_rounds(dense)
        assert s_paged == s_dense
        # and the paged engine really served later rounds from the cache
        assert paged.steps == dense.steps

    def test_router_replay_matches_dense_slots(self, setup):
        """Same corpus through MoriRouter on both engine modes: identical
        per-program output streams and identical scheduler-visible cache
        accounting (the decode reserve is excluded from the GPU budget)."""
        cfg, _, params = setup
        logs = {}
        for mode in (False, True):
            engines = [
                make_engine(
                    cfg, params, n_device_pages=96, n_host_pages=96,
                    max_seq=384, dense_slots=mode,
                )
                for _ in range(2)
            ]
            router = MoriRouter(engines, scheduler="mori")
            tg = TraceGenConfig(
                min_steps=3, mean_steps=4, max_steps=4,
                initial_context_mean=500, max_context=1600,
            )
            corpus = generate_corpus(3, seed=1, cfg=tg)
            # Sampling itself is deterministic: every sample site routes
            # through engine.greedy_token (bf16-rounded, lowest-index on
            # exact ties — see TestGreedyTieBreaking), so run-to-run and
            # sub-ulp divergence cannot flip tokens. What remains is real
            # numerics: dense and paged attention reduce over different
            # padded layouts and can legitimately differ by one bf16 ulp
            # of the final logit (replay seed 0 hits a context whose top-2
            # gap is exactly that ulp — 3.140625 vs 3.125). The pinned
            # replay seed keeps the synthesized contexts' top-2 gaps above
            # the one-ulp cross-layout band; it is a workload choice, not
            # a flake dodge.
            m = router.replay(corpus, vocab_size=cfg.vocab_size,
                              max_new_tokens=4, seed=1)
            assert m.steps_completed >= 9
            logs[mode] = (router.output_log, router.sched.replicas[0].capacity.gpu_kv_bytes)
        assert logs[False][0] == logs[True][0]
        assert logs[False][1] == logs[True][1]  # reserve-corrected budget

    def test_paged_decode_matches_direct_forward_partial_tail(self, setup):
        """Block-table decode with a partially-filled tail page equals an
        iterative full-prefill oracle (page boundary crossed mid-decode)."""
        cfg, model, params = setup
        eng = make_engine(cfg, params)
        ctx = list(range(2, 2 + 43))            # 5 full pages + 3-token tail
        eng.submit(EngineRequest("p", ctx, max_new_tokens=8))
        out = eng.run_to_completion()[0].output_tokens
        ref, cur = [], list(ctx)
        for _ in range(8):
            logits, _ = model.prefill(params, {"tokens": jnp.asarray([cur], jnp.int32)})
            t = int(jnp.argmax(logits[0]))
            ref.append(t)
            cur.append(t)
        assert out == ref


class TestGreedyTieBreaking:
    """The deterministic-sampling contract behind the golden tests: every
    engine sample site routes through ``greedy_token``, which rounds f32
    logits to bf16 before the argmax so sub-ulp cross-path divergence
    (paged vs dense gather, bf16 vs int8 pages) becomes an exact tie,
    broken lowest-index on every backend."""

    def test_planted_exact_tie_breaks_lowest_index(self):
        logits = (
            jnp.zeros((2, 8), jnp.float32)
            .at[0, 3].set(1.0).at[0, 5].set(1.0)      # tie at 3 and 5
            .at[1, 6].set(1.0).at[1, 2].set(1.0)      # tie at 2 and 6
        )
        assert [int(t) for t in greedy_token(logits)] == [3, 2]

    def test_sub_ulp_divergence_collapses_to_same_token(self):
        # 1e-4 is far below the bf16 ulp at 2.0 (2^-7 * 2 = 0.015625): an
        # f32 argmax flips between these two vectors, the rounded one not
        a = jnp.asarray([[0.0, 2.0, 2.0 + 1e-4, 0.0]], jnp.float32)
        b = jnp.asarray([[0.0, 2.0 + 2e-4, 2.0, 0.0]], jnp.float32)
        assert int(jnp.argmax(a[0])) != int(jnp.argmax(b[0]))  # the flake
        assert int(greedy_token(a)[0]) == int(greedy_token(b)[0]) == 1


class TestPagePoolBlockTableApi:
    def test_append_token_then_read_back(self):
        pool = PagePool(
            layers=2, kv_heads=2, head_dim=4, page_tokens=4,
            n_device_pages=8, n_host_pages=4,
        )
        page = pool.alloc_device()
        rng = np.random.default_rng(0)
        toks = [jnp.asarray(rng.standard_normal((2, 2, 4)), jnp.bfloat16)
                for _ in range(3)]
        for i, t in enumerate(toks):
            pool.append_token(page, i, t, -t)
        k, v = pool.read_device_pages([page])
        for i, t in enumerate(toks):
            np.testing.assert_array_equal(np.asarray(k[:, i]), np.asarray(t))
            np.testing.assert_array_equal(np.asarray(v[:, i]), np.asarray(-t))

    def test_block_table_view_is_zero_copy_and_adopt_swaps(self):
        pool = PagePool(
            layers=1, kv_heads=1, head_dim=4, page_tokens=2,
            n_device_pages=4, n_host_pages=2,
        )
        k, v = pool.block_table_view()
        assert k is pool.k and v is pool.v        # a handle, not a gather
        k2 = k.at[0, 0, 0].set(1.0)
        pool.adopt(k2, v)
        assert pool.k is k2
        with pytest.raises(AssertionError):
            pool.adopt(k2[:, :1], v)              # shape change rejected

    def test_decode_reserve_excluded_from_router_budget(self, setup):
        cfg, _, params = setup
        eng = make_engine(cfg, params, n_device_pages=32)
        assert eng.decode_reserve_pages > 0
        assert eng.pool.n_device_pages == 32 + eng.decode_reserve_pages
        router = MoriRouter([eng], scheduler="mori")
        assert router.sched.replicas[0].capacity.gpu_kv_bytes == 32 * eng.pool.page_bytes


class TestReloadUnderPressure:
    def _warm_offloaded_program(self, cfg, params, **kw):
        eng = make_engine(cfg, params, **kw)
        ctx = list(range(2, 66))                  # 8 full pages @ T=8
        eng.submit(EngineRequest("p", ctx, max_new_tokens=3))
        comp = eng.run_to_completion()[0]
        n_off = eng.offload_program("p")
        assert n_off >= 6
        return eng, ctx, comp

    def test_reload_stops_at_first_failure(self, setup, monkeypatch):
        """Once a reload fails, _reload_prefix must not keep burning device
        pages (or evictions) on nodes past the break point — they cannot
        extend the device-resident prefix chain."""
        cfg, _, params = setup
        eng, ctx, _ = self._warm_offloaded_program(cfg, params)
        ensure_calls = []
        real_ensure = eng._ensure_device_page

        def flaky_ensure(*a, **kw):
            ensure_calls.append(1)
            if len(ensure_calls) > 2:
                raise RuntimeError("device pool exhausted and nothing evictable")
            return real_ensure(*a, **kw)

        reload_calls = []
        real_reload = eng.pool.reload_page

        def counting_reload(hp):
            reload_calls.append(hp)
            return real_reload(hp)

        monkeypatch.setattr(eng, "_ensure_device_page", flaky_ensure)
        monkeypatch.setattr(eng.pool, "reload_page", counting_reload)
        n = eng._reload_prefix(ctx)
        assert n == 2
        assert len(reload_calls) == 2             # no attempts past the break

    def test_submit_survives_reload_exhaustion(self, setup, monkeypatch):
        """A pool that cannot host a single reload (exhausted for cache,
        nothing evictable) degrades the submit to a cold prefill — the
        RuntimeError from the eviction machinery must not escape submit()."""
        cfg, _, params = setup
        eng, ctx, comp = self._warm_offloaded_program(cfg, params)
        real_ensure = eng._ensure_device_page
        in_reload = [False]

        def exhausted_for_reload(*a, **kw):
            if in_reload[0]:
                raise RuntimeError("device pool exhausted and nothing evictable")
            return real_ensure(*a, **kw)

        real_reload_prefix = eng._reload_prefix

        def guarded_reload_prefix(tokens):
            in_reload[0] = True
            try:
                return real_reload_prefix(tokens)
            finally:
                in_reload[0] = False

        monkeypatch.setattr(eng, "_ensure_device_page", exhausted_for_reload)
        monkeypatch.setattr(eng, "_reload_prefix", guarded_reload_prefix)
        ctx2 = ctx + comp.output_tokens[:-1] + [7, 8, 9]
        eng.submit(EngineRequest("p", ctx2, max_new_tokens=3))
        c2 = eng.run_to_completion()[0]
        assert c2.reloaded_pages == 0             # nothing reloaded under pressure
        assert c2.cached_tokens == 0              # device chain fully cold
        assert c2.prefilled_tokens == len(ctx2)   # recomputed instead of crashing

    def test_reload_program_does_not_self_evict(self, setup):
        """reload_program with the cache at its budget: the budget eviction
        must never pick the just-reloaded nodes of the same program as
        victims (the reload would silently undo itself while billing
        full PCIe traffic)."""
        cfg, _, params = setup
        eng, ctx, _ = self._warm_offloaded_program(cfg, params)
        eng.radix_device_pages = 1                # cache budget saturated
        n = eng.reload_program("p")
        chain = eng.tree.program_nodes("p")
        assert n == len(chain)
        assert all(node.device_page is not None for node in chain)
        assert all(node.refcount == 0 for node in chain)

    def test_reload_does_not_evict_later_chain_nodes(self, setup):
        """The chain is refcount-held while it streams: making room for an
        earlier node must never evict a later node of the same prefix."""
        cfg, _, params = setup
        eng, ctx, _ = self._warm_offloaded_program(cfg, params)
        eng.reload_program("p")                   # everything device-resident
        chain = eng.tree.match_prefix_any_tier(ctx)
        node0 = chain[0]
        hp = eng.pool.offload_page(node0.device_page)
        node0.device_page, node0.host_page = None, hp
        # force pressure: cache far over budget, so the reload of node0
        # would love to evict — the only candidates are chain nodes
        eng.radix_device_pages = 1
        n = eng._reload_prefix(ctx)
        assert n == 1
        assert all(node.device_page is not None for node in chain)
        # every refcount taken during the reload was released again
        assert all(node.refcount == 0 for node in chain)


class TestOpsInterpretDispatch:
    """REPRO_KERNEL_INTERPRET=1 must route the off-TPU dispatch through the
    Pallas kernels in interpret mode — the CI kernel-parity job's contract
    (without it the `tpu` branch of kernels/*/ops.py is dead code on CPU)."""

    def test_paged_attention_ops_interpret(self, monkeypatch):
        from repro.kernels.paged_attention import ops
        from repro.kernels.paged_attention.ref import paged_attention_ref

        rng = np.random.default_rng(3)
        B, H, KH, D, T, P = 2, 4, 2, 64, 8, 3
        q = jnp.asarray(rng.standard_normal((B, H, D)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((B * P, T, KH, D)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((B * P, T, KH, D)), jnp.float32)
        tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
        lengths = jnp.asarray([T * P, T + 3], jnp.int32)
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
        out = ops.paged_attention(q, k, v, tables, lengths, softcap=30.0, window=10)
        ref = paged_attention_ref(q, k, v, tables, lengths, softcap=30.0, window=10)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)

    def test_flash_attention_ops_interpret(self, monkeypatch):
        from repro.kernels.flash_attention import ops
        from repro.kernels.flash_attention.ref import flash_attention_ref

        rng = np.random.default_rng(4)
        q = jnp.asarray(rng.standard_normal((1, 4, 64, 32)), jnp.float32)
        k = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
        v = jnp.asarray(rng.standard_normal((1, 2, 64, 32)), jnp.float32)
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
        out = ops.flash_attention(q, k, v, causal=True, window=24)
        ref = flash_attention_ref(q, k, v, causal=True, window=24)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=2e-3, atol=2e-3)

    def test_ssd_ops_interpret(self, monkeypatch):
        import jax

        from repro.kernels.ssd import ops
        from repro.kernels.ssd.ref import ssd_reference

        rng = np.random.default_rng(5)
        b, s, h, p, n, chunk = 1, 32, 2, 8, 8, 8
        x = jnp.asarray(rng.standard_normal((b, s, h, p)), jnp.float32)
        dt = jax.nn.softplus(jnp.asarray(rng.standard_normal((b, s, h)), jnp.float32))
        A = -jnp.abs(jnp.asarray(rng.standard_normal((h,)), jnp.float32))
        B = jnp.asarray(rng.standard_normal((b, s, 1, n)), jnp.float32)
        C = jnp.asarray(rng.standard_normal((b, s, 1, n)), jnp.float32)
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
        yk, sk = ops.ssd(x, dt, A, B, C, chunk=chunk)
        yr, sr = ssd_reference(x, dt, A, B, C, chunk=chunk)
        np.testing.assert_allclose(np.asarray(yk), np.asarray(yr), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(sk), np.asarray(sr), rtol=1e-2, atol=1e-2)

    def test_engine_decode_through_interpreted_kernel(self, setup, monkeypatch):
        """End-to-end seam: a block-table engine whose decode runs the
        *interpreted Pallas kernel* (not the jnp oracle) produces the same
        tokens — the serving path itself is kernel-clean."""
        cfg, _, params = setup
        oracle = make_engine(cfg, params, max_slots=1)
        ctx = list(range(2, 30))
        oracle.submit(EngineRequest("p", ctx, max_new_tokens=3))
        want = oracle.run_to_completion()[0].output_tokens
        monkeypatch.setenv("REPRO_KERNEL_INTERPRET", "1")
        eng = make_engine(cfg, params, max_slots=1)
        eng.submit(EngineRequest("p", ctx, max_new_tokens=3))
        got = eng.run_to_completion()[0].output_tokens
        assert got == want
