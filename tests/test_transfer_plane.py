"""Async transfer plane: shared channel queues, page-granular streaming,
mid-stream cancellation on the real serving path.

The headline regression here is the early-tool-return scenario on *real*
hardware: with transfers asynchronous, a program whose offload is still
streaming when its tool call returns must be re-admitted warm — the
scheduler's ``CancelTransfer`` aborts the copy, the staged partial page
set rolls back, no host round trip is billed, and the generated tokens
are identical to the synchronous-mode run (which pays the full
offload+reload round trip for the same trace).
"""
from __future__ import annotations

import heapq

import pytest

from repro.core.ledger import Channel
from repro.core.transfers import CopyJob, TransferChannels
from repro.core.types import TransferCost


class _Clock:
    """Deterministic event loop for driving TransferChannels directly."""

    def __init__(self):
        self.heap = []
        self.seq = 0
        self.now = 0.0

    def schedule(self, eta, fn):
        heapq.heappush(self.heap, (eta, self.seq, fn))
        self.seq += 1

    def run_until(self, t):
        while self.heap and self.heap[0][0] <= t:
            eta, _, fn = heapq.heappop(self.heap)
            self.now = max(self.now, eta)
            fn(eta)


class TestTransferChannels:
    def _channels(self, clock, done, chunks=None, bw=100.0):
        return TransferChannels(
            cost=TransferCost(
                pcie_bytes_per_s=bw, ssd_bytes_per_s=bw / 2, fixed_latency_s=0.0
            ),
            schedule=clock.schedule,
            on_done=lambda job, t: done.append((job.action_id, t)),
            on_chunk=(lambda job, t: chunks.append((job.action_id, job.chunks_done)))
            if chunks is not None
            else None,
        )

    def test_fifo_serialization_per_channel(self):
        clock, done = _Clock(), []
        ch = self._channels(clock, done)
        ch.enqueue(CopyJob(100, 1, "a"), 0.0)                    # 1.0 s
        ch.enqueue(CopyJob(200, 2, "b"), 0.0)                    # +2.0 s
        ch.enqueue(CopyJob(50, 3, "c", channel=Channel.NVME), 0.0)  # 1.0 s, own lane
        clock.run_until(1.0)
        assert done == [(1, 1.0), (3, 1.0)]  # NVMe overlaps PCIe
        clock.run_until(3.0)
        assert done == [(1, 1.0), (3, 1.0), (2, 3.0)]
        assert not ch.in_flight()

    def test_chunked_job_streams_pages(self):
        clock, done, chunks = _Clock(), [], []
        ch = self._channels(clock, done, chunks)
        ch.enqueue(CopyJob(400, 7, "a", n_chunks=4), 0.0)  # 1 s per chunk
        clock.run_until(2.5)
        assert chunks == [(7, 1), (7, 2)]
        assert done == []
        clock.run_until(4.0)
        assert chunks == [(7, 1), (7, 2), (7, 3), (7, 4)]
        assert done == [(7, 4.0)]

    def test_abort_mid_stream_stops_future_chunks(self):
        clock, done, chunks = _Clock(), [], []
        ch = self._channels(clock, done, chunks)
        ch.enqueue(CopyJob(400, 7, "a", n_chunks=4), 0.0)
        ch.enqueue(CopyJob(100, 8, "b"), 0.0)
        clock.run_until(1.5)
        job = ch.abort(7, 1.5)
        assert job is not None and job.chunks_done == 1
        clock.run_until(10.0)
        # job 7 never completed, its remaining chunks never copied; the
        # queued job behind it started at the abort and ran to completion
        assert [d[0] for d in done] == [8]
        assert chunks == [(7, 1), (8, 1)]
        assert ch.pending_bytes() == 0

    def test_cancel_queued_and_reset(self):
        clock, done = _Clock(), []
        ch = self._channels(clock, done)
        ch.enqueue(CopyJob(100, 1, "a"), 0.0)
        ch.enqueue(CopyJob(100, 2, "a"), 0.0)
        assert ch.cancel_queued(2).action_id == 2
        assert ch.cancel_queued(2) is None
        assert ch.abort(1, 0.0).action_id == 1
        ch.enqueue(CopyJob(100, 3, "b"), 0.0)
        ch.reset()
        clock.run_until(10.0)
        assert done == []  # stale chunk events dropped after reset


# ----------------------------------------------------- bf16 host round trip
def test_pagepool_offload_reload_is_bit_exact():
    """Regression: host pages stored device bf16 as fp16, whose exponent
    range bf16 overflows to inf — an offload→reload round trip silently
    corrupted large-magnitude KV. Raw-bits staging must be lossless."""
    jnp = pytest.importorskip("jax.numpy")
    import numpy as np

    from repro.serving.kvpool import PagePool

    pool = PagePool(layers=2, kv_heads=2, head_dim=4, page_tokens=4,
                    n_device_pages=4, n_host_pages=4)
    shape = (2, 4, 2, 4)  # [L, t, KH, HD]
    # values far outside fp16 range, plus denormal-ish small ones
    k = jnp.asarray(
        np.linspace(-3e38, 3e38, num=int(np.prod(shape))).reshape(shape),
        jnp.bfloat16,
    )
    v = jnp.asarray(
        np.geomspace(1e-30, 1e30, num=int(np.prod(shape))).reshape(shape),
        jnp.bfloat16,
    )
    page = pool.alloc_device()
    pool.write_device_page(page, k, v)
    k_bits = np.asarray(pool.k[:, page]).view(np.uint16).copy()
    v_bits = np.asarray(pool.v[:, page]).view(np.uint16).copy()
    assert np.isfinite(np.asarray(k, np.float32)).all()

    hp = pool.offload_page(page)
    assert hp is not None
    dp = pool.reload_page(hp)
    assert dp is not None
    assert (np.asarray(pool.k[:, dp]).view(np.uint16) == k_bits).all()
    assert (np.asarray(pool.v[:, dp]).view(np.uint16) == v_bits).all()


def test_pagepool_staged_copy_keeps_source_until_freed():
    """The streamed-offload primitives copy without freeing: the device
    page stays valid (cancel-safety) until the commit explicitly frees."""
    pytest.importorskip("jax")
    import numpy as np

    from repro.serving.kvpool import PagePool

    pool = PagePool(layers=1, kv_heads=1, head_dim=2, page_tokens=2,
                    n_device_pages=2, n_host_pages=2)
    import jax.numpy as jnp

    k = jnp.full((1, 2, 1, 2), 7.0, jnp.bfloat16)
    page = pool.alloc_device()
    pool.write_device_page(page, k, k)
    before_dev = pool.device_free_count()
    hp = pool.copy_page_to_host(page)
    assert hp is not None
    assert pool.device_free_count() == before_dev  # source not freed
    # rollback path: discard the staged host copy, device copy untouched
    pool.free_host(hp)
    assert (np.asarray(pool.k[:, page], np.float32) == 7.0).all()


# ------------------------------------------------------- real-path replay
@pytest.fixture(scope="module")
def setup():
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    return cfg, params


def _run(cfg, params, *, sync: bool):
    from repro.core import SchedulerConfig
    from repro.kernels import kv_quant
    from repro.serving import Engine, MoriRouter
    from repro.traces import burst_cancel_corpus

    kvb = kv_quant.token_wire_bytes(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16")
    engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                    n_host_pages=64, max_slots=4, max_seq=256)
    # p1's 64-token offload takes ~20 virtual seconds: queued at the t=3
    # tick, still mid-stream (2-3 of 8 pages staged) at p1's t=9 return
    cost = TransferCost(pcie_bytes_per_s=64 * kvb / 20.0)
    router = MoriRouter(
        [engine], scheduler="mori",
        gpu_capacity_bytes=130 * kvb,
        config=SchedulerConfig(tick_interval_s=1.0),
        sync_transfers=sync, xfer_cost=cost, record_plans=True,
    )
    m = router.replay(burst_cancel_corpus(), vocab_size=cfg.vocab_size,
                      max_new_tokens=4)
    return router, m


class TestRealPathCancel:
    def test_early_return_cancels_streaming_offload(self, setup):
        cfg, params = setup
        router, m = _run(cfg, params, sync=False)
        sync_router, sm = _run(cfg, params, sync=True)

        # the tool call returned mid-stream: the offload was aborted...
        assert m.cancelled_offloads > 0
        assert m.cancelled_pages > 0          # partial page set rolled back
        # ...so no host round trip was billed on the async path...
        assert m.offloaded_pages == 0
        assert m.reloaded_pages == 0 and m.nvme_reloaded_pages == 0
        # ...while sync mode paid the full offload + reload for this trace
        assert sm.cancelled_offloads == 0
        assert sm.offloaded_pages > 0 and sm.reloaded_pages > 0
        # and the generated tokens are identical in both modes (the warm
        # re-admission served the same KV the round trip would have)
        assert router.output_log == sync_router.output_log
        assert m.steps_completed == sm.steps_completed == 5
        # every transfer resolved: nothing left open in the ledger
        assert len(router.sched.ledger) == 0
        assert len(sync_router.sched.ledger) == 0
        assert router.sched.ledger.cancelled == 1

    def test_decode_overlaps_inflight_transfer(self, setup):
        """pbig's t=6 step decodes while p1's offload is streaming: the
        async path must record transfer/compute overlap; sync mode cannot
        (every transfer completes inside apply_plan)."""
        cfg, params = setup
        router, m = _run(cfg, params, sync=False)
        assert m.overlap_decode_steps > 0
        assert m.peak_inflight_bytes > 0
        _, sm = _run(cfg, params, sync=True)
        assert sm.overlap_decode_steps == 0

    def test_discard_mid_stream_closes_ledger_record(self, setup):
        """Regression: evicting a live program whose offload is still
        streaming (CPU-overflow pass emits a Discard, not a Cancel) must
        both abort the copy job and close the ledger record — a stale
        open offload would later match _cancel_inflight_offload and
        cancel the wrong transfer."""
        cfg, params = setup
        from repro.core import Discard, SchedulerConfig, Tier, TierCapacity
        from repro.core.types import TransferCost
        from repro.serving import Engine, MoriRouter

        from repro.kernels import kv_quant
        kvb = kv_quant.token_wire_bytes(
            cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16")
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                        n_host_pages=64, max_slots=2, max_seq=256)
        router = MoriRouter(
            [engine], scheduler="mori",
            gpu_capacity_bytes=200 * kvb, cpu_capacity_bytes=200 * kvb,
            config=SchedulerConfig(tick_interval_s=1.0),
            xfer_cost=TransferCost(pcie_bytes_per_s=64 * kvb / 60.0),
        )
        router._push = lambda t, fn: None  # stand-in virtual clock
        sched = router.sched
        sched.program_arrived("p", kvb, 0.0)
        router.apply_plan(sched.request_arrived("p", 60, 0.0))
        sched.notify_inference_started("p", 0.0)
        router.apply_plan(sched.request_completed("p", 4, 1.0))
        # demote under pressure: the offload starts streaming on the plane
        sched.replicas[0].capacity = TierCapacity(10 * kvb, 200 * kvb)
        router.apply_plan(sched.tick(2.0))
        assert router.planes[0].in_flight()
        assert sched.ledger.open_offload("p") is not None
        # CPU overflow evicts the still-streaming program to Waiting
        sched.replicas[0].capacity = TierCapacity(10 * kvb, 0)
        plan = sched.tick(3.0)
        assert any(
            d.pid == "p" and d.tier is Tier.CPU for d in plan.of_kind(Discard)
        )
        router.apply_plan(plan)
        assert not router.planes[0].in_flight()
        assert sched.ledger.open_offload("p") is None
        assert len(sched.ledger) == 0
        assert sched.ledger.cancelled == 1
        router._push = None

    def test_async_matches_sync_on_pressure_corpus(self, setup):
        """Token-level parity on a generated multi-program corpus: async
        transfers change *when* pages move, never *what* the engine
        serves."""
        cfg, params = setup
        from repro.core import SchedulerConfig
        from repro.serving import Engine, MoriRouter
        from repro.traces import TraceGenConfig, generate_corpus

        tg = TraceGenConfig(
            min_steps=3, mean_steps=4, max_steps=4,
            initial_context_mean=700, max_context=1800,
            long_median_s=20.0, busy_calls_mean=2.0, idle_calls_mean=2.0,
        )
        corpus = generate_corpus(4, seed=5, cfg=tg)
        logs = []
        for sync in (False, True):
            engine = Engine(cfg, params, page_tokens=8, n_device_pages=96,
                            n_host_pages=96, max_slots=2, max_seq=320)
            router = MoriRouter(
                [engine], scheduler="mori",
                gpu_capacity_bytes=500_000,
                config=SchedulerConfig(tick_interval_s=2.0),
                sync_transfers=sync,
                xfer_cost=TransferCost(pcie_bytes_per_s=2e5),
            )
            m = router.replay(corpus, vocab_size=cfg.vocab_size,
                              max_new_tokens=4)
            assert m.steps_completed >= 12
            assert len(router.sched.ledger) == 0
            logs.append(router.output_log)
        assert logs[0] == logs[1]
