"""Multi-replica scale-out: endpoint-addressed copies, live cross-replica
KV migration, and drain/failover on the real router.

Covers the PR's acceptance criteria end to end:

* the endpoint-addressed transfer API (``Endpoint``/``CopyRequest`` and
  the ``copy_request_for`` adapter from the action IR);
* ``Migrate`` as a page-granular replica→replica copy through host
  staging — byte-identical landed KV, cancellable mid-stream exactly
  like a PR-3 offload;
* ``mark_failed`` mid-decode: in-flight copies aborted and rolled back,
  mid-flight slots requeued, DRAM residents drained to a healthy
  replica, and a faulted replay generating the *identical* token stream
  as an undisturbed one (zero lost tokens).

All tests here are KVSAN-clean: CI re-runs them under ``REPRO_KVSAN=1``.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core.actions import Forward, Migrate, Offload, SetLabel
from repro.core.ledger import Channel
from repro.core.transfers import CopyRequest, Endpoint, copy_request_for
from repro.core.types import Tier

pytestmark = []


# ------------------------------------------------- endpoint-addressed API
class TestCopyRequest:
    """The transfer plane's new admission currency (satellite #1)."""

    def test_offload_lowers_to_same_replica_downcopy(self):
        act = Offload(pid="p", action_id=7, replica=1, nbytes=4096,
                      src_tier=Tier.GPU, dst_tier=Tier.CPU)
        creq = copy_request_for(act)
        assert creq == CopyRequest(
            src=Endpoint(1, Tier.GPU), dst=Endpoint(1, Tier.CPU),
            pid="p", nbytes=4096, action_id=7,
        )
        assert not creq.cross_replica
        assert creq.kind == "offload"
        assert creq.channel is Channel.PCIE
        assert creq.exec_replica == 1

    def test_reload_forward_lowers_to_upcopy(self):
        act = Forward(pid="p", action_id=3, replica=0, source_tier=Tier.SSD,
                      nbytes=100)
        creq = copy_request_for(act)
        assert creq.src == Endpoint(0, Tier.SSD)
        assert creq.dst == Endpoint(0, Tier.GPU)
        assert creq.kind == "reload"
        # billing follows the *read* side: SSD-sourced reloads are NVMe
        assert creq.channel is Channel.NVME

    def test_migrate_lowers_to_cross_replica_copy(self):
        act = Migrate(pid="p", action_id=9, src_replica=2, dst_replica=0,
                      nbytes=512)
        creq = copy_request_for(act)
        assert creq.cross_replica
        assert creq.kind == "migrate"
        assert creq.src == Endpoint(2, Tier.CPU)
        assert creq.dst == Endpoint(0, Tier.CPU)
        # the copy executes where it lands
        assert creq.exec_replica == 0
        job = creq.job()
        assert (job.nbytes, job.pid, job.replica) == (512, "p", 0)

    def test_non_copy_actions_are_rejected(self):
        with pytest.raises(TypeError, match="no bytes to copy"):
            copy_request_for(SetLabel(pid="p", action_id=1, replica=None))


# ----------------------------------------------------------- real engines
@pytest.fixture(scope="module")
def setup():
    pytest.importorskip("jax")
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    return cfg, params


def _engine(cfg, params, **kw):
    from repro.serving import Engine

    kw.setdefault("page_tokens", 8)
    kw.setdefault("n_device_pages", 64)
    kw.setdefault("n_host_pages", 64)
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_seq", 256)
    return Engine(cfg, params, **kw)


def _offloaded_program(engine, pid: str, n_tokens: int = 64, *,
                       offload: bool = True):
    """Run one request to completion (and, by default, push its KV to the
    host tier engine-side); returns the raw host bits per chain node for
    byte-identity checks. Router-level tests pass ``offload=False`` and
    let the transfer plane's own offload job do the device→host copy."""
    from repro.serving import EngineRequest

    rng = np.random.default_rng(hash(pid) % 2**32)
    tokens = [int(t) for t in rng.integers(2, 1000, size=n_tokens)]
    engine.submit(EngineRequest(program_id=pid, tokens=tokens,
                                max_new_tokens=4))
    engine.run_to_completion()
    if offload:
        assert engine.offload_program(pid) > 0
    bits = {}
    for node in engine.tree.program_nodes(pid):
        if node.host_page is not None:
            bits[node.tokens] = (
                np.array(engine.pool.host_k[:, node.host_page]),
                np.array(engine.pool.host_v[:, node.host_page]),
            )
    return tokens, bits


class TestMigrateStream:
    """The cross-replica copy itself, driven unit by unit."""

    def test_commit_lands_byte_identical_host_chain(self, setup):
        cfg, params = setup
        from repro.serving.transfer_plane import _MigrateStream

        src, dst = _engine(cfg, params), _engine(cfg, params)
        _tokens, src_bits = _offloaded_program(src, "p")
        stream = _MigrateStream(src, dst, "p")
        assert stream.n_units > 0
        for _ in range(stream.n_units):
            stream.copy_unit()
        landed = stream.commit()
        assert landed == len(src_bits)

        # destination holds the full chain, raw bits identical
        dst_nodes = dst.tree.program_nodes("p")
        assert len(dst_nodes) == landed
        for node in dst_nodes:
            k, v = src_bits[node.tokens]
            assert np.array_equal(np.array(dst.pool.host_k[:, node.host_page]), k)
            assert np.array_equal(np.array(dst.pool.host_v[:, node.host_page]), v)
        # move semantics: the source copies are retired and the source
        # tree forgot the program
        assert src.tree.program_nodes("p") == []
        assert src.pool.host_free_count() == src.pool.n_host_pages
        # the landed chain reloads through the normal promotion path
        assert dst.reload_program("p") == landed

    def test_abort_mid_stream_rolls_back_imports(self, setup):
        cfg, params = setup
        from repro.serving.transfer_plane import _MigrateStream

        src, dst = _engine(cfg, params), _engine(cfg, params)
        _tokens, src_bits = _offloaded_program(src, "p")
        dst_free = dst.pool.host_free_count()
        stream = _MigrateStream(src, dst, "p")
        stream.copy_unit()
        stream.copy_unit()
        assert stream.abort() == 2
        # destination imports rolled back, source untouched
        assert dst.pool.host_free_count() == dst_free
        assert dst.tree.program_nodes("p") == []
        src_nodes = src.tree.program_nodes("p")
        assert len(src_nodes) == len(src_bits)
        assert all(n.host_page is not None for n in src_nodes)


def _two_replica_router(cfg, params, *, seconds_per_64_tokens=60.0):
    from repro.core import SchedulerConfig
    from repro.core.types import TransferCost
    from repro.kernels import kv_quant
    from repro.serving import MoriRouter

    kvb = kv_quant.token_wire_bytes(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16")
    engines = [_engine(cfg, params) for _ in range(2)]
    router = MoriRouter(
        engines, scheduler="mori",
        gpu_capacity_bytes=200 * kvb, cpu_capacity_bytes=200 * kvb,
        config=SchedulerConfig(tick_interval_s=1.0),
        xfer_cost=TransferCost(
            pcie_bytes_per_s=64 * kvb / seconds_per_64_tokens
        ),
    )
    return router, kvb


class TestRouterMigrate:
    """Migrate end-to-end on the real router (tentpole + satellite #3)."""

    def test_pressure_migration_accepted_on_paged_engines(self, setup):
        """migrate_on_pressure now works on the real router when the
        engines are paged (the unpaged rejection — with the actionable
        message naming the knob — is pinned by tests/test_actions.py's
        ``test_router_rejects_migration_config``)."""
        cfg, params = setup
        from repro.core import SchedulerConfig

        from repro.serving import MoriRouter

        engines = [_engine(cfg, params) for _ in range(2)]
        router = MoriRouter(
            engines, scheduler="mori",
            config=SchedulerConfig(migrate_on_pressure=True),
        )
        assert router.sched.config.migrate_on_pressure is True

    def test_drain_migrates_resident_kv_to_healthy_replica(self, setup):
        """mark_failed drains a DRAM-resident program: the Migrate streams
        on the *destination* plane, the ledger tracks it as an open
        migrate, and the ack re-homes the program."""
        cfg, params = setup
        router, kvb = _two_replica_router(cfg, params)
        router._push = lambda t, fn: None  # stand-in virtual clock
        sched = router.sched
        # place one program; the tie-break picks replica 1
        sched.program_arrived("p", kvb, 0.0)
        router.apply_plan(sched.request_arrived("p", 60, 0.0))
        assert sched.replica_of("p") == 1
        _offloaded_program(router.engines[1], "p", offload=False)
        sched.notify_inference_started("p", 0.0)
        router.apply_plan(sched.request_completed("p", 4, 1.0))
        # demote to the CPU tier and let the offload land synchronously
        # via the plane (advance past its eta)
        from repro.core.types import TierCapacity
        sched.replicas[1].capacity = TierCapacity(10 * kvb, 200 * kvb)
        router.apply_plan(sched.tick(2.0))
        router._advance_planes(1000.0)
        assert len(sched.ledger) == 0
        assert sched.programs["p"].tier is Tier.CPU

        router.mark_failed(1, 1000.0)
        assert router.metrics.drain_events == 1
        # the migrate executes on the destination (replica 0) plane
        assert router.planes[0].in_flight()
        assert sched.ledger.open_migrate("p") is not None
        assert any("migrate" in d for d in router.planes[0].describe_jobs())
        assert sched.replica_of("p") == 0
        router._advance_planes(3000.0)
        assert len(sched.ledger) == 0
        assert router.metrics.migrated_pages > 0
        assert router.metrics.migrations == 1
        # destination engine really holds the chain now
        assert router.engines[0].tree.program_nodes("p") != []
        router._push = None

    def test_migrate_cancels_mid_stream(self, setup):
        """A program that finishes while its drain-migrate is still
        streaming aborts the copy exactly like a cancelled offload: the
        imported partial page set rolls back and the ledger closes
        (satellite #4's cancel-mid-stream mirror)."""
        cfg, params = setup
        router, kvb = _two_replica_router(cfg, params,
                                          seconds_per_64_tokens=600.0)
        router._push = lambda t, fn: None
        sched = router.sched
        sched.program_arrived("p", kvb, 0.0)
        router.apply_plan(sched.request_arrived("p", 60, 0.0))
        _offloaded_program(router.engines[1], "p", offload=False)
        sched.notify_inference_started("p", 0.0)
        router.apply_plan(sched.request_completed("p", 4, 1.0))
        from repro.core.types import TierCapacity
        sched.replicas[1].capacity = TierCapacity(10 * kvb, 200 * kvb)
        router.apply_plan(sched.tick(2.0))
        router._advance_planes(1000.0)

        dst_free = router.engines[0].pool.host_free_count()
        router.mark_failed(1, 1000.0)
        # stream a couple of pages, then finish the program mid-stream
        router._advance_planes(1000.0 + 160.0)
        job = next(iter(router.planes[0].channels.jobs()))
        assert 0 < job.chunks_done < job.n_chunks
        router.apply_plan(sched.program_finished("p", 1200.0))
        assert not router.planes[0].in_flight()
        assert router.metrics.cancelled_pages > 0
        assert len(sched.ledger) == 0
        # every imported page rolled back on the destination
        assert router.engines[0].pool.host_free_count() == dst_free
        assert router.engines[0].tree.program_nodes("p") == []
        router._push = None

    def test_mark_failed_aborts_inflight_offload_and_requeues(self, setup):
        """Failure with an offload mid-stream on the dying replica: the
        copy aborts (staged pages rolled back), its ledger record closes,
        and the half-offloaded program is NOT drain-migrated — it falls
        to the Waiting tier for recompute."""
        cfg, params = setup
        router, kvb = _two_replica_router(cfg, params)
        router._push = lambda t, fn: None
        sched = router.sched
        sched.program_arrived("p", kvb, 0.0)
        router.apply_plan(sched.request_arrived("p", 60, 0.0))
        _offloaded_program(router.engines[1], "p", offload=False)
        sched.notify_inference_started("p", 0.0)
        router.apply_plan(sched.request_completed("p", 4, 1.0))
        from repro.core.types import TierCapacity
        sched.replicas[1].capacity = TierCapacity(10 * kvb, 200 * kvb)
        router.apply_plan(sched.tick(2.0))
        # stream a few chunks but do NOT let the offload land
        router._advance_planes(2.0 + 10.0)
        assert router.planes[1].in_flight()
        assert sched.ledger.open_offload("p") is not None

        router.mark_failed(1, 20.0)
        assert not router.planes[1].in_flight()
        assert len(sched.ledger) == 0
        assert router.metrics.cancelled_pages > 0
        # half-written DRAM copies are not trustworthy: no migrate
        assert router.metrics.migrations == 0
        assert sched.programs["p"].tier is Tier.WAITING
        router._push = None


class TestFailoverReplay:
    """Live mid-decode failover on the virtual clock (tentpole)."""

    def _corpus(self):
        from repro.traces import TraceGenConfig, generate_corpus

        tg = TraceGenConfig(
            min_steps=3, mean_steps=4, max_steps=4,
            initial_context_mean=700, max_context=1800,
            long_median_s=20.0, busy_calls_mean=2.0, idle_calls_mean=2.0,
        )
        return generate_corpus(4, seed=5, cfg=tg)

    def _replay(self, cfg, params, faults=None):
        from repro.core import SchedulerConfig
        from repro.core.types import TransferCost
        from repro.serving import MoriRouter

        engines = [
            _engine(cfg, params, n_device_pages=96, n_host_pages=96,
                    max_slots=2, max_seq=320)
            for _ in range(2)
        ]
        router = MoriRouter(
            engines, scheduler="mori",
            gpu_capacity_bytes=500_000,
            config=SchedulerConfig(tick_interval_s=2.0),
            xfer_cost=TransferCost(pcie_bytes_per_s=2e5),
        )
        m = router.replay(self._corpus(), vocab_size=cfg.vocab_size,
                          max_new_tokens=4, faults=faults)
        return router, m

    def test_mid_decode_failover_loses_zero_tokens(self, setup):
        """Fail replica 1 mid-replay, recover it later: every program
        still completes every step, and the token streams are identical
        to an undisturbed run — the requeued steps re-prefilled the same
        context on the surviving replica."""
        cfg, params = setup
        from repro.sim.engine import FaultPlan

        base_router, base = self._replay(cfg, params)
        # fail at t=5: replica 1 still holds live decode slots, so the
        # drain genuinely tears down and requeues in-flight work (a later
        # fail time can land in a tool-call lull and requeue nothing)
        router, m = self._replay(
            cfg, params,
            faults=[FaultPlan(replica=1, fail_at=5.0, recover_at=65.0)],
        )
        assert m.drain_events == 1
        assert m.requeued_slots > 0
        assert m.steps_completed == base.steps_completed
        assert router.output_log == base_router.output_log
        # nothing left open anywhere
        assert len(router.sched.ledger) == 0
        # the balancer explains its placements in the metrics
        assert sum(m.placement_reasons.values()) > 0
        assert base.placement_reasons  # populated on the clean run too
