"""Trace generator calibration against the paper's §3 statistics, plus IO."""
import math

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.traces import (
    busy_phase_durations,
    generate_corpus,
    generate_program,
    load_corpus,
    percentile,
    phase_stats,
    save_corpus,
    tool_call_cdf,
)


class TestCalibration:
    """The generated corpus must reproduce the paper's trace analysis.

    Bands are deliberately generous — these are reproduction targets for a
    *synthetic* corpus, not exact-match assertions: paper values in comments.
    """

    def setup_method(self):
        self.corpus = generate_corpus(186, seed=0)
        self.stats = phase_stats(self.corpus, threshold_s=2.0)

    def test_short_call_fraction_at_2s(self):
        # paper: 87% of tool calls are short at the 2 s threshold
        assert 0.82 <= self.stats.short_fraction <= 0.93

    def test_long_calls_dominate_tool_time(self):
        # paper: the 13% long calls account for 58% of wall-clock tool time
        assert 0.48 <= self.stats.long_time_share <= 0.70

    def test_busy_phase_median_at_2s(self):
        # paper Fig. 5: median busy phase ~20 s at the 2 s threshold
        assert 12.0 <= self.stats.busy_median_s <= 30.0

    def test_busy_phase_medians_rise_with_threshold(self):
        # paper Fig. 5: medians 4 s / 20 s / 41 s at 1 s / 2 s / 5 s
        m1 = percentile(busy_phase_durations(self.corpus, 1.0), 0.5)
        m2 = self.stats.busy_median_s
        m5 = percentile(busy_phase_durations(self.corpus, 5.0), 0.5)
        assert m1 < m2 < m5
        assert 2.0 <= m1 <= 12.0
        assert 25.0 <= m5 <= 60.0

    def test_duration_spread_three_orders_of_magnitude(self):
        # paper Fig. 3: durations span 3+ orders of magnitude
        assert self.stats.orders_of_magnitude >= 3.0

    def test_heavy_tail_reaches_minutes(self):
        durs = tool_call_cdf(self.corpus)
        assert max(durs) >= 60.0
        assert percentile(durs, 0.5) < 1.0  # median well below a second

    def test_programs_issue_tens_of_steps(self):
        steps = sorted(t.num_steps for t in self.corpus)
        assert 20 <= steps[len(steps) // 2] <= 60

    def test_context_grows_monotonically(self):
        for tr in self.corpus[:20]:
            ctxs = [s.input_tokens for s in tr.steps]
            assert all(a <= b for a, b in zip(ctxs, ctxs[1:]))


class TestDeterminismAndIO:
    def test_same_seed_same_corpus(self):
        a = generate_corpus(5, seed=7)
        b = generate_corpus(5, seed=7)
        assert [
            (s.input_tokens, s.output_tokens, s.tool_duration_s)
            for t in a
            for s in t.steps
        ] == [
            (s.input_tokens, s.output_tokens, s.tool_duration_s)
            for t in b
            for s in t.steps
        ]

    def test_roundtrip(self, tmp_path):
        corpus = generate_corpus(8, seed=3)
        p = tmp_path / "corpus.jsonl"
        save_corpus(corpus, p)
        loaded = load_corpus(p)
        assert len(loaded) == len(corpus)
        for a, b in zip(corpus, loaded):
            assert a.program_id == b.program_id
            for sa, sb in zip(a.steps, b.steps):
                assert sa.input_tokens == sb.input_tokens
                assert sa.output_tokens == sb.output_tokens
                assert math.isclose(sa.tool_duration_s, sb.tool_duration_s, abs_tol=1e-3)


@given(seed=st.integers(0, 1000))
@settings(max_examples=30, deadline=None)
def test_property_every_program_is_well_formed(seed):
    import random

    tr = generate_program("x", random.Random(seed))
    assert tr.num_steps >= 1
    for s in tr.steps:
        assert s.input_tokens > 0
        assert s.output_tokens > 0
        assert s.tool_duration_s >= 0.0
        assert s.reasoning_wall_s > 0.0
    # last step ends the session
    assert tr.steps[-1].tool_duration_s == 0.0
