"""Deterministic fallback for the slice of the hypothesis API this suite
uses, so the tier-1 suite runs on images where ``hypothesis`` is not
installed (dependency policy: no network installs in CI containers).

Each test file imports it as::

    try:
        from hypothesis import given, settings
        from hypothesis import strategies as st
    except ImportError:
        from _hypothesis_compat import given, settings, st

Semantics: ``@given`` draws ``max_examples`` pseudo-random examples from a
seed fixed per test name — no shrinking, no example database, but the same
property assertions run over the same example stream on every machine.
When the real hypothesis is present it is always preferred.
"""
from __future__ import annotations

import functools
import random
from types import SimpleNamespace


class _Strategy:
    def __init__(self, draw):
        self.draw = draw


def _integers(min_value, max_value):
    return _Strategy(lambda rng: rng.randint(min_value, max_value))


def _floats(min_value, max_value, allow_nan=False, allow_infinity=False):
    return _Strategy(lambda rng: rng.uniform(min_value, max_value))


def _sampled_from(elements):
    elements = list(elements)
    return _Strategy(lambda rng: elements[rng.randrange(len(elements))])


def _lists(elements, min_size=0, max_size=None):
    hi = (min_size + 10) if max_size is None else max_size

    def draw(rng):
        return [elements.draw(rng) for _ in range(rng.randint(min_size, hi))]

    return _Strategy(draw)


def _tuples(*strategies):
    return _Strategy(lambda rng: tuple(s.draw(rng) for s in strategies))


st = SimpleNamespace(
    integers=_integers,
    floats=_floats,
    sampled_from=_sampled_from,
    lists=_lists,
    tuples=_tuples,
)
strategies = st


def settings(max_examples: int = 100, deadline=None, **_ignored):
    def deco(fn):
        fn._max_examples = max_examples
        return fn

    return deco


def given(**named_strategies):
    def deco(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_max_examples", 100)
            rng = random.Random(fn.__qualname__)
            for _ in range(n):
                drawn = {k: s.draw(rng) for k, s in named_strategies.items()}
                fn(*args, **kwargs, **drawn)

        # keep pytest from reading the wrapped signature and treating the
        # drawn parameters as fixtures
        del wrapper.__wrapped__
        return wrapper

    return deco
