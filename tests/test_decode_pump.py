"""Clocked decode pump: batched multi-program replay on the real router.

Acceptance gates of the continuous-batching refactor:

* ≥4 concurrent programs on one replica genuinely decode *together* —
  mean batch occupancy > 1.0, at least one step advancing ≥2 slots, and
  transfer/decode overlap recorded against batched decode;
* ``serial_decode=True`` reproduces the pre-refactor router's serialized
  replay token-for-token (golden corpus captured from the pre-pump code);
* the scheduler gates on *real* engine occupancy and ``on_slot_freed``
  forwards gated programs the moment a batch slot opens, mid-window;
* ``Engine.step(active=...)`` masking leaves resident-but-unpaced slots
  untouched, so submit/decode interleaving never perturbs tokens;
* the ``max_ctx`` trace-synthesis underflow raises a clear error instead
  of silently corrupting the synthesized context.
"""
from __future__ import annotations

import json
from pathlib import Path

import pytest

from repro.configs import get_config
from repro.core import SchedulerConfig
from repro.core.types import ProgramTrace, RequestRecord, TransferCost
from repro.models import Model, materialize
from repro.serving import Engine, EngineRequest, MoriRouter

GOLDEN = Path(__file__).parent / "data" / "golden_serial_replay.json"


@pytest.fixture(scope="module")
def setup():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    return cfg, params


def _golden_traces():
    def tr(pid, ctx, tool):
        return ProgramTrace(pid, [
            RequestRecord(ctx, 4, tool, reasoning_wall_s=1.0),
            RequestRecord(ctx + 12, 4, 0.0, reasoning_wall_s=1.0),
        ])

    return [tr("p0", 48, 30.0), tr("p1", 56, 60.0), tr("p2", 90, 90.0)]


def _concurrent_corpus():
    """Four programs whose reasoning windows align (same walls, arrivals)
    so the pump batches them, plus one long tool call that parks p3 idle
    long enough for the control tick to demote it mid-replay."""
    busy = [
        ProgramTrace(f"p{i}", [
            RequestRecord(48 + 4 * i, 4, 1.0, reasoning_wall_s=2.0),
            RequestRecord(60 + 4 * i, 4, 1.0, reasoning_wall_s=2.0),
            RequestRecord(72 + 4 * i, 4, 0.0, reasoning_wall_s=2.0),
        ])
        for i in range(3)
    ]
    idle = ProgramTrace("p3", [
        RequestRecord(64, 4, 30.0, reasoning_wall_s=2.0),
        RequestRecord(80, 4, 0.0, reasoning_wall_s=2.0),
    ])
    return busy + [idle]


class TestBatchedReplay:
    def test_four_programs_decode_together_with_overlap(self, setup):
        """The tentpole's contract: one replica, ≥4 resident programs,
        batched steps advancing several slots, and KV movement overlapping
        genuinely batched decode (default async transfer mode)."""
        cfg, params = setup
        from repro.kernels import kv_quant
        kvb = kv_quant.token_wire_bytes(
            cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16")
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                        n_host_pages=128, max_slots=4, max_seq=256)
        router = MoriRouter(
            [engine], scheduler="mori",
            # tight enough that p3's 30 s tool call gets it demoted once
            # contexts grow, loose enough that the four initial programs
            # all admit at t=0
            gpu_capacity_bytes=250 * kvb,
            config=SchedulerConfig(tick_interval_s=1.0),
            # p3's offload streams for ~12 virtual seconds — across the
            # busy programs' later decode windows
            xfer_cost=TransferCost(pcie_bytes_per_s=64 * kvb / 12.0),
        )
        corpus = _concurrent_corpus()
        m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)

        assert m.steps_completed == sum(len(t.steps) for t in corpus)
        # batch occupancy: programs really decoded together
        assert m.mean_batch_occupancy > 1.0
        assert m.multi_slot_steps >= 1
        assert m.peak_live_slots == 4          # all four in one batched step
        # overlap measured against batched decode, not a serialized loop
        assert m.overlap_decode_steps > 0
        assert m.offloaded_pages > 0           # the demotion really streamed
        assert len(router.sched.ledger) == 0   # every transfer resolved

    def test_pump_matches_serial_without_contention(self, setup):
        """With no slot contention the pump changes *when* programs decode
        relative to each other, never what they generate: token streams
        equal the serialized replay's on the golden traces."""
        cfg, params = setup
        logs = {}
        for serial in (False, True):
            engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                            n_host_pages=64, max_slots=4, max_seq=512)
            router = MoriRouter([engine], scheduler="mori",
                                config=SchedulerConfig(),
                                sync_transfers=True, serial_decode=serial)
            m = router.replay(_golden_traces(), vocab_size=cfg.vocab_size,
                              max_new_tokens=4)
            assert m.steps_completed == 6
            logs[serial] = router.output_log
        assert logs[False] == logs[True]

    def test_scheduler_gates_on_real_engine_occupancy(self, setup):
        """Three programs on a 2-slot engine: the third gates on the slot
        probe (real occupancy, no max_running config needed) and forwards
        via on_slot_freed the moment a batch slot opens — long before the
        first control tick at t=50."""
        cfg, params = setup
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                        n_host_pages=64, max_slots=2, max_seq=256)
        router = MoriRouter([engine], scheduler="mori",
                            config=SchedulerConfig(tick_interval_s=50.0))
        started: list[tuple[str, float]] = []
        real_notify = router.sched.notify_inference_started

        def spy(pid, now):
            started.append((pid, now))
            return real_notify(pid, now)

        router.sched.notify_inference_started = spy
        traces = [
            ProgramTrace(f"p{i}", [
                RequestRecord(40 + 4 * i, 4, 1.0, reasoning_wall_s=4.0),
                RequestRecord(56 + 4 * i, 4, 0.0, reasoning_wall_s=1.0),
            ])
            for i in range(3)
        ]
        m = router.replay(traces, vocab_size=cfg.vocab_size, max_new_tokens=4)
        assert m.steps_completed == 6
        assert m.gated_events >= 1             # someone waited for a slot
        gated_start = next(t for pid, t in started if pid == "p2")
        # p2 joined the batch when a slot freed mid-window (engine finishes
        # its 3 decode steps inside the 4 s wall), not at the t=50 tick
        assert 0.0 < gated_start < 4.0

    def test_pump_quantum_batches_heterogeneous_pacing(self, setup):
        """Programs with different reasoning walls pace their steps on
        different grids and only coincide at t=0; snapping due times to a
        shared pump quantum makes them share batched steps again (tokens
        unchanged — pacing moves step *times*, never step results)."""
        cfg, params = setup
        traces = [
            ProgramTrace("fast", [RequestRecord(48, 4, 0.0,
                                                reasoning_wall_s=2.0)]),
            ProgramTrace("slow", [RequestRecord(56, 4, 0.0,
                                                reasoning_wall_s=3.0)]),
        ]
        results = {}
        for quantum in (None, 1.0):
            engine = Engine(cfg, params, page_tokens=8, n_device_pages=128,
                            n_host_pages=64, max_slots=2, max_seq=256)
            router = MoriRouter([engine], scheduler="mori",
                                pump_quantum_s=quantum)
            m = router.replay(traces, vocab_size=cfg.vocab_size,
                              max_new_tokens=4)
            assert m.steps_completed == 2
            results[quantum] = (m.multi_slot_steps, router.output_log)
        # exact pacing shares only the t=0 join step; the 1 s grid aligns
        # the rest of the two programs' schedules as well
        assert results[1.0][0] > results[None][0]
        assert results[1.0][1] == results[None][1]

    def test_max_ctx_underflow_raises_clear_error(self, setup):
        """Regression: max_seq - (max_new_tokens + 2) * steps - 8 used to
        go non-positive for long traces and silently corrupt the
        synthesized context length."""
        cfg, params = setup
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                        n_host_pages=64, max_slots=2, max_seq=256)
        router = MoriRouter([engine], scheduler="mori")
        long_trace = ProgramTrace(
            "long", [RequestRecord(40, 4, 0.1, reasoning_wall_s=0.1)] * 48
        )
        with pytest.raises(ValueError, match="cannot replay on this engine"):
            router.replay([long_trace], vocab_size=cfg.vocab_size,
                          max_new_tokens=4)
        # the error names the knobs that fix it
        try:
            router2 = MoriRouter([engine], scheduler="mori")
            router2.replay([long_trace], vocab_size=cfg.vocab_size,
                           max_new_tokens=4)
        except ValueError as e:
            msg = str(e)
            assert "max_seq" in msg and "max_new_tokens" in msg
            assert "long" in msg


class TestSerialGolden:
    def test_serial_decode_reproduces_prerefactor_outputs(self, setup):
        """``serial_decode=True`` is token-identical (output_log) to the
        pre-refactor run-to-completion router, pinned by a golden capture
        on two corpora: the contention-free golden traces (sync
        transfers) and a generated 4-program pressure corpus (async)."""
        cfg, params = setup
        golden = json.loads(GOLDEN.read_text())

        engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                        n_host_pages=64, max_slots=4, max_seq=512)
        router = MoriRouter([engine], scheduler="mori",
                            config=SchedulerConfig(),
                            sync_transfers=True, serial_decode=True)
        router.replay(_golden_traces(), vocab_size=cfg.vocab_size,
                      max_new_tokens=4)
        assert router.output_log == golden["golden_sync"]

        from repro.traces import TraceGenConfig, generate_corpus

        tg = TraceGenConfig(
            min_steps=3, mean_steps=4, max_steps=4,
            initial_context_mean=700, max_context=1800,
            long_median_s=20.0, busy_calls_mean=2.0, idle_calls_mean=2.0,
        )
        corpus = generate_corpus(4, seed=5, cfg=tg)
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=96,
                        n_host_pages=96, max_slots=2, max_seq=320)
        router = MoriRouter(
            [engine], scheduler="mori", gpu_capacity_bytes=500_000,
            config=SchedulerConfig(tick_interval_s=2.0),
            serial_decode=True,
            xfer_cost=TransferCost(pcie_bytes_per_s=2e5),
        )
        m = router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)
        assert router.output_log == golden["pressure_async"]
        assert m.steps_completed == golden["pressure_async_steps"]
        # the serialized path never batches: exactly one live slot per step
        assert m.mean_batch_occupancy == 1.0
        assert m.multi_slot_steps == 0


class TestEngineMaskedStep:
    def test_masked_step_preserves_inactive_slots(self, setup):
        """submit-while-decoding + per-slot pacing: a program that joins
        mid-decode and steps on its own schedule produces exactly the
        solo-run tokens, and the masked slot's state is untouched while
        others advance."""
        cfg, params = setup

        def solo(pid, ctx):
            eng = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                         n_host_pages=64, max_slots=2, max_seq=256)
            eng.submit(EngineRequest(pid, ctx, max_new_tokens=6))
            return eng.run_to_completion()[0].output_tokens

        ctx_a = list(range(2, 47))
        ctx_b = list(range(300, 338))
        want_a, want_b = solo("a", ctx_a), solo("b", ctx_b)

        eng = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                     n_host_pages=64, max_slots=2, max_seq=256)
        out: dict[str, list[int]] = {}

        def collect(comps):
            for c in comps:
                out[c.program_id] = c.output_tokens

        sa = eng.submit(EngineRequest("a", ctx_a, max_new_tokens=6))
        collect(eng.step(active=[sa]))           # a advances alone
        collect(eng.step(active=[sa]))
        sb = eng.submit(EngineRequest("b", ctx_b, max_new_tokens=6))
        collect(eng.step(active=[sb]))           # b alone; a masked
        collect(eng.step(active=[sb]))
        collect(eng.step(active=[sa, sb]))       # batched
        prog = eng.slot_progress()
        assert prog[sa] == ("a", 4, 6) and prog[sb] == ("b", 4, 6)
        while eng.slots:
            collect(eng.step())                  # finish together
        assert out["a"] == want_a
        assert out["b"] == want_b

    def test_step_with_no_due_slots_is_a_noop(self, setup):
        cfg, params = setup
        eng = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                     n_host_pages=64, max_slots=2, max_seq=256)
        eng.submit(EngineRequest("a", list(range(2, 40)), max_new_tokens=3))
        before = eng.steps
        assert eng.step(active=[]) == []
        assert eng.steps == before               # nothing was dispatched
