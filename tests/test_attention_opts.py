"""§Perf model-level optimizations are exact-equivalence changes:
sliding-window block skip, f32-accumulating bf16 dots, head padding."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.models.layers import blockwise_attention, decode_attention


def _qkv(rng, B, S, H, KH, D, dtype=jnp.float32):
    q = jnp.asarray(rng.standard_normal((B, S, H, D)), dtype)
    k = jnp.asarray(rng.standard_normal((B, S, KH, D)), dtype)
    v = jnp.asarray(rng.standard_normal((B, S, KH, D)), dtype)
    return q, k, v


def _ref(q, k, v, *, causal, window=None, cap=None, q_offset=0):
    """O(S^2) dense oracle."""
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    k = jnp.repeat(k, H // KH, axis=2)
    v = jnp.repeat(v, H // KH, axis=2)
    s = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * D**-0.5
    if cap:
        s = cap * jnp.tanh(s / cap)
    qp = q_offset + jnp.arange(Sq)[:, None]
    kp = jnp.arange(Skv)[None, :]
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))


@pytest.mark.parametrize("window,qb,kb", [
    (256, 128, 128),      # skip active: (256+128)//128+2 = 5 < 16 blocks
    (100, 64, 128),       # window not block-aligned
    (1024, 128, 256),     # skip barely inactive
])
def test_window_block_skip_matches_dense(window, qb, kb):
    rng = np.random.default_rng(0)
    q, k, v = _qkv(rng, 2, 2048, 4, 2, 32)
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              q_block=qb, kv_block=kb)
    want = _ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


def test_window_block_skip_with_q_offset():
    """Chunked decode-side suffix (q_offset > 0) under a window."""
    rng = np.random.default_rng(1)
    q, k, v = _qkv(rng, 1, 1024, 2, 2, 32)
    q_suffix = q[:, :256]
    got = blockwise_attention(q_suffix, k, v, causal=True, window=192,
                              q_offset=768, q_block=64, kv_block=64)
    want = _ref(q_suffix, k, v, causal=True, window=192, q_offset=768)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=3e-5, rtol=1e-4)


@settings(max_examples=20, deadline=None)
@given(
    seq=st.sampled_from([192, 320, 512]),
    window=st.sampled_from([64, 96, 200]),
    qb=st.sampled_from([32, 64]),
)
def test_window_block_skip_property(seq, window, qb):
    rng = np.random.default_rng(seq * 7 + window)
    q, k, v = _qkv(rng, 1, seq, 2, 1, 16)
    got = blockwise_attention(q, k, v, causal=True, window=window,
                              q_block=qb, kv_block=64)
    want = _ref(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               atol=5e-5, rtol=1e-4)


def test_bf16_inputs_f32_accumulation():
    """bf16 Q/K/V with preferred_element_type stays close to the f32 oracle
    (the B1/§Perf dtype change must not regress numerics)."""
    rng = np.random.default_rng(2)
    q, k, v = _qkv(rng, 2, 256, 4, 2, 64, dtype=jnp.bfloat16)
    got = blockwise_attention(q, k, v, causal=True, q_block=64, kv_block=64)
    want = _ref(q.astype(jnp.float32), k.astype(jnp.float32),
                v.astype(jnp.float32), causal=True)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), atol=0.04, rtol=0.05)


def test_decode_attention_bf16_cache():
    rng = np.random.default_rng(3)
    B, S, KH, H, D = 2, 128, 2, 4, 64
    kc = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.bfloat16)
    vc = jnp.asarray(rng.standard_normal((B, S, KH, D)), jnp.bfloat16)
    qt = jnp.asarray(rng.standard_normal((B, H, D)), jnp.bfloat16)
    lengths = jnp.array([100, 64], jnp.int32)
    got = decode_attention(qt, kc, vc, lengths=lengths)
    # oracle: dense attention over the valid prefix, per batch row
    for b in range(B):
        L = int(lengths[b])
        ref = _ref(
            qt[b][None, None].astype(jnp.float32),
            kc[b, :L][None].astype(jnp.float32),
            vc[b, :L][None].astype(jnp.float32),
            causal=False,
        )[0, 0].reshape(-1)
        np.testing.assert_allclose(
            np.asarray(got[b], np.float32), np.asarray(ref),
            atol=0.04, rtol=0.05,
        )


def test_pad_heads_cell_is_exact_noop_shapewise():
    """--pad-heads pads arctic 56->64 q heads; logits shape is unchanged
    and the padded cell lowers without head fallbacks."""
    from repro.launch.steps import build_cell
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    cell = build_cell("qwen1.5-0.5b", "train_4k", mesh, pad_heads=True)
    # 16 heads on a 1-way model axis: padding is a no-op
    assert cell.meta["tokens_per_step"] == 256 * 4096


def test_padded_zero_heads_contribute_nothing():
    """Head padding (§Perf A2) is exact given the documented weight-layout
    permutation: pad heads are inserted per GQA group (G: 2->3 here), with
    zero wq columns and zero wo rows for the pads. Appending pads at the
    end WITHOUT the permutation would remap original heads to the wrong
    kv group — this test pins the correct layout."""
    rng = np.random.default_rng(4)
    B, S, D = 1, 64, 32
    KH, G, HD = 2, 2, 16                          # 4 q heads, 2 kv heads
    H = KH * G
    q, k, v = _qkv(rng, B, S, H, KH, HD)
    base = _ref(q, k, v, causal=True)             # [B,S,H,HD]
    wo = jnp.asarray(rng.standard_normal((H * HD, D)), jnp.float32)
    out_base = base.reshape(B, S, H * HD) @ wo

    # pad G: 2 -> 3 by inserting one zero head at the END OF EACH GROUP
    qg = q.reshape(B, S, KH, G, HD)
    qp = jnp.concatenate([qg, jnp.zeros((B, S, KH, 1, HD))], axis=3)
    qp = qp.reshape(B, S, KH * (G + 1), HD)
    padded = _ref(qp, k, v, causal=True)          # GQA repeat maps groups
    # wo rows permuted the same way: zero rows in each group's pad slot
    wo_g = wo.reshape(KH, G, HD, D)
    wo_p = jnp.concatenate([wo_g, jnp.zeros((KH, 1, HD, D))], axis=1)
    wo_p = wo_p.reshape(KH * (G + 1) * HD, D)
    out_pad = padded.reshape(B, S, KH * (G + 1) * HD) @ wo_p
    np.testing.assert_allclose(np.asarray(out_pad), np.asarray(out_base),
                               atol=1e-5, rtol=1e-5)
