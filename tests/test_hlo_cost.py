"""hlo_cost: loop-aware, utilization-aware HLO cost extraction.

Synthetic HLO snippets pin down each accounting rule; one end-to-end case
lowers a real scan-of-matmuls and checks the trip-count multiplication
that XLA's own cost_analysis() misses.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import pytest

from repro.launch.hlo_cost import analyze

ENTRY_DOT = """\
ENTRY %main (p0: f32[128,256], p1: f32[256,512]) -> f32[128,512] {
  %p0 = f32[128,256]{1,0} parameter(0)
  %p1 = f32[256,512]{1,0} parameter(1)
  ROOT %dot.1 = f32[128,512]{1,0} dot(%p0, %p1), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_dot_flops_and_bytes():
    c = analyze(ENTRY_DOT, bf16_normalize=False)
    assert c.flops == 2 * 128 * 512 * 256
    # operands + output, f32
    assert c.hbm_bytes == (128 * 256 + 256 * 512 + 128 * 512) * 4


def test_bf16_normalization_halves_f32():
    raw = analyze(ENTRY_DOT, bf16_normalize=False)
    norm = analyze(ENTRY_DOT, bf16_normalize=True)
    assert norm.hbm_bytes == raw.hbm_bytes / 2
    assert norm.hbm_bytes_raw == raw.hbm_bytes


WHILE_HLO = """\
%cond (s: (s32[], f32[64,64])) -> pred[] {
  %s = (s32[], f32[64,64]) parameter(0)
  %i = s32[] get-tuple-element(%s), index=0
  %k = s32[] constant(10)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (s.1: (s32[], f32[64,64])) -> (s32[], f32[64,64]) {
  %s.1 = (s32[], f32[64,64]) parameter(0)
  %i.1 = s32[] get-tuple-element(%s.1), index=0
  %x = f32[64,64]{1,0} get-tuple-element(%s.1), index=1
  %dot.2 = f32[64,64]{1,0} dot(%x, %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %one = s32[] constant(1)
  %i.2 = s32[] add(%i.1, %one)
  ROOT %t = (s32[], f32[64,64]) tuple(%i.2, %dot.2)
}

ENTRY %main (a: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[64,64]) tuple(%z, %a)
  %w = (s32[], f32[64,64]) while(%t0), condition=%cond, body=%body
  ROOT %out = f32[64,64]{1,0} get-tuple-element(%w), index=1
}
"""


def test_while_body_multiplied_by_trip_count():
    c = analyze(WHILE_HLO, bf16_normalize=False)
    assert c.flops == 10 * 2 * 64 * 64 * 64


DUS_FUSION = """\
%fused_dus (param_0: s32[], param_1: f32[8,128], param_2: f32[48,8,128]) -> f32[48,8,128] {
  %param_2 = f32[48,8,128]{2,1,0} parameter(2)
  %param_1 = f32[8,128]{1,0} parameter(1)
  %bc = f32[1,8,128]{2,1,0} bitcast(%param_1)
  %param_0 = s32[] parameter(0)
  %c0 = s32[] constant(0)
  ROOT %dus = f32[48,8,128]{2,1,0} dynamic-update-slice(%param_2, %bc, %param_0, %c0, %c0)
}

ENTRY %main (i: s32[], upd: f32[8,128], buf: f32[48,8,128]) -> f32[48,8,128] {
  %i = s32[] parameter(0)
  %upd = f32[8,128]{1,0} parameter(1)
  %buf = f32[48,8,128]{2,1,0} parameter(2)
  ROOT %fusion.1 = f32[48,8,128]{2,1,0} fusion(%i, %upd, %buf), kind=kLoop, calls=%fused_dus
}
"""


def test_dus_fusion_charges_update_not_buffer():
    c = analyze(DUS_FUSION, bf16_normalize=False)
    upd = 8 * 128 * 4
    # buffer param feeds the aliased DUS operand -> update-sized RMW read;
    # update param read + update-sized write (+ scalar index params).
    # NOT 48x buffer traffic.
    assert c.hbm_bytes <= 3 * upd + 16
    assert c.hbm_bytes >= 2 * upd


SLICE_FUSION = """\
%fused_slice (param_0: f32[48,256,128], param_1: s32[]) -> f32[256,128] {
  %param_0 = f32[48,256,128]{2,1,0} parameter(0)
  %param_1 = s32[] parameter(1)
  %c0 = s32[] constant(0)
  %ds = f32[1,256,128]{2,1,0} dynamic-slice(%param_0, %param_1, %c0, %c0), dynamic_slice_sizes={1,256,128}
  ROOT %bc = f32[256,128]{1,0} bitcast(%ds)
}

ENTRY %main (i: s32[], stack: f32[48,256,128]) -> f32[256,128] {
  %i = s32[] parameter(0)
  %stack = f32[48,256,128]{2,1,0} parameter(1)
  ROOT %fusion.2 = f32[256,128]{1,0} fusion(%stack, %i), kind=kLoop, calls=%fused_slice
}
"""


def test_slice_fusion_charges_slice_read_only():
    c = analyze(SLICE_FUSION, bf16_normalize=False)
    sl = 256 * 128 * 4
    # read the slice (+ scalar index param); the write is a slice-shim
    # (fuses into the consumer on the TPU target)
    assert sl <= c.hbm_bytes <= sl + 16


COLL_HLO = """\
ENTRY %main (x: f32[1024,1024]) -> f32[1024,1024] {
  %x = f32[1024,1024]{1,0} parameter(0)
  ROOT %ar = f32[1024,1024]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
}
"""


def test_all_reduce_ring_wire():
    c = analyze(COLL_HLO, bf16_normalize=False)
    n_bytes = 1024 * 1024 * 4
    assert c.wire_bytes["all-reduce"] == pytest.approx(2 * 3 / 4 * n_bytes)
    assert c.collective_counts["all-reduce"] == 1


def test_vmem_budget_drops_small_temporaries():
    hlo = """\
ENTRY %main (a: f32[64,64], b: f32[64,64]) -> f32[64,64] {
  %a = f32[64,64]{1,0} parameter(0)
  %b = f32[64,64]{1,0} parameter(1)
  %dot.s = f32[64,64]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %dot.t = f32[64,64]{1,0} dot(%dot.s, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""
    base = analyze(hlo, bf16_normalize=False)
    vmem = analyze(hlo, bf16_normalize=False, vmem_budget=1 << 20)
    # the intermediate dot.s output (16 KiB) stays in VMEM: saved once as
    # the first dot's output write and once as the second dot's operand read
    assert base.hbm_bytes - vmem.hbm_bytes == 2 * 64 * 64 * 4


def test_real_scan_lowering_end_to_end():
    """A lax.scan of matmuls must cost num_iters x one matmul."""
    n_iter, d = 7, 64

    def step(x, _):
        return x @ x, None

    def f(x):
        return jax.lax.scan(step, x, None, length=n_iter)[0]

    lowered = jax.jit(f).lower(jax.ShapeDtypeStruct((d, d), jnp.float32))
    c = analyze(lowered.compile().as_text(), bf16_normalize=False)
    assert c.flops == pytest.approx(n_iter * 2 * d**3, rel=0.01)
