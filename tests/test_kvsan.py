"""kvsan: page-lifetime sanitizer + control-plane invariant checker.

Covers the PR's acceptance battery:

* pool-level violations become hard :class:`KvsanError`\\ s under
  ``REPRO_KVSAN=1`` — double-free, read/write-after-free, append past a
  page boundary, free of a page a live block table still references;
* the historical silent bugs are now errors: ``PagePool.free_device``
  accepting the same page twice, ``TypedRadixTree.unpin``'s
  ``max(0, ...)`` clamp hiding refcount underflow;
* structural ``verify`` / ``check_leaks`` sweeps catch corruption the
  per-verb hooks cannot see;
* the ledger auditor + control-plane checker raise on lifecycle and
  conservation violations, tolerate the documented complete-after-cancel
  race;
* a full router replay (async pump + chunked prefill) runs *clean* with
  everything armed — and the fuzz harness's machinery round-trips a
  failure into a JSON artifact.

All tests arm the sanitizer per-test via monkeypatch; nothing leaks into
the rest of the suite (kvsan is read at pool/tree construction time).
"""
from __future__ import annotations

import json

import pytest

from repro.analysis import kvsan
from repro.analysis.invariants import InvariantError, LedgerAuditor
from repro.analysis.kvsan import KvsanError
from repro.core.ledger import Channel, TransferLedger, TransferRecord
from repro.core.radix_tree import TypedRadixTree
from repro.core.types import Tier, TypeLabel
from repro.serving.kvpool import PagePool


@pytest.fixture
def arm(monkeypatch):
    monkeypatch.setenv(kvsan.ENV_VAR, "1")


@pytest.fixture
def pool(arm):
    return PagePool(
        layers=2, kv_heads=2, head_dim=8, page_tokens=4,
        n_device_pages=8, n_host_pages=4,
    )


def _rec(action_id=1, pid="p0", kind="offload"):
    return TransferRecord(
        action_id=action_id, pid=pid, replica=0, kind=kind,
        channel=Channel.PCIE, nbytes=1024, src_tier=Tier.GPU,
        dst_tier=Tier.CPU, opened_at=0.0,
    )


class TestPoolLifecycle:
    def test_off_by_default(self, monkeypatch):
        monkeypatch.delenv(kvsan.ENV_VAR, raising=False)
        pool = PagePool(layers=1, kv_heads=1, head_dim=4, page_tokens=2,
                        n_device_pages=2, n_host_pages=2)
        assert pool._san is None
        # the historical bug: double-free silently accepted when unarmed
        p = pool.alloc_device()
        pool.free_device(p)
        pool.free_device(p)
        assert pool._free_dev.count(p) == 2    # corruption, undetected

    def test_double_free_device(self, pool):
        p = pool.alloc_device()
        pool.free_device(p)
        with pytest.raises(KvsanError, match="double-free of dev page"):
            pool.free_device(p)

    def test_double_free_host(self, pool):
        p = pool.alloc_host()
        pool.free_host(p)
        with pytest.raises(KvsanError, match="double-free of host page"):
            pool.free_host(p)

    def test_free_list_corruption_surfaces_at_alloc(self, pool):
        # simulate the *downstream* symptom: a page pushed onto the free
        # list behind the sanitizer's back gets handed out while allocated
        p = pool.alloc_device()
        pool._free_dev.append(p)
        with pytest.raises(KvsanError, match="free-list corruption"):
            for _ in range(pool.n_device_pages + 1):
                pool.alloc_device()

    def test_write_after_free(self, pool):
        import numpy as np
        p = pool.alloc_device()
        pool.free_device(p)
        tok = np.zeros((pool.layers, pool.kv_heads, pool.head_dim))
        with pytest.raises(KvsanError, match="write-after-free"):
            pool.write_device_page(p, tok[:, None], tok[:, None])

    def test_read_after_free(self, pool):
        p = pool.alloc_device()
        pool.free_device(p)
        with pytest.raises(KvsanError, match="read-after-free"):
            pool.read_device_pages([p])

    def test_append_past_page_boundary(self, pool):
        import numpy as np
        p = pool.alloc_device()
        tok = np.zeros((pool.layers, pool.kv_heads, pool.head_dim))
        pool.append_token(p, pool.page_tokens - 1, tok, tok)   # last slot ok
        with pytest.raises(KvsanError, match="append past the tail page"):
            pool.append_token(p, pool.page_tokens, tok, tok)

    def test_free_under_hold(self, pool):
        p = pool.alloc_device()
        tok = pool._san.add_hold("dev", [p], "in-flight copy")
        with pytest.raises(KvsanError, match="while held by"):
            pool.free_device(p)
        pool._san.drop_hold(tok)
        pool.free_device(p)                                    # now legal

    def test_free_under_block_table(self, pool):
        p = pool.alloc_device()
        pool._san.add_reachable_cb(lambda: [("dev", p, "block table of p0")])
        with pytest.raises(KvsanError, match="eviction out from under"):
            pool.free_device(p)

    def test_check_table_append_past_tail(self, pool):
        p = pool.alloc_device()
        san = pool._san
        san.check_table([p], pool.page_tokens - 1, "p0")        # in range
        with pytest.raises(KvsanError, match="past the tail page"):
            san.check_table([p], pool.page_tokens, "p0")

    def test_verify_conservation(self, pool):
        pool.alloc_device()
        pool._san.verify("healthy")                             # clean
        stolen = pool._free_dev.pop()
        with pytest.raises(KvsanError, match="conservation broken"):
            pool._san.verify("after theft")
        pool._free_dev.append(stolen)
        pool._free_dev.append(stolen)
        with pytest.raises(KvsanError, match="duplicates"):
            pool._san.verify("after dup")

    def test_check_leaks(self, pool):
        p = pool.alloc_device()
        with pytest.raises(KvsanError, match="leaked dev page"):
            pool._san.check_leaks("end of replay")
        tok = pool._san.add_hold("dev", [p], "slot")
        pool._san.check_leaks("end of replay")                  # reachable now
        pool._san.drop_hold(tok)


class TestRadixStrictMode:
    def test_unpin_without_pin(self, arm):
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain([0, 1], [0], "p", TypeLabel.BUSY)
        with pytest.raises(KvsanError, match="without a matching pin"):
            t.unpin("p")

    def test_unpin_clamp_hides_underflow_when_unarmed(self, monkeypatch):
        monkeypatch.delenv(kvsan.ENV_VAR, raising=False)
        t = TypedRadixTree(page_tokens=2)
        nodes = t.insert_chain([0, 1], [0], "p", TypeLabel.BUSY)
        t.unpin("p")                       # historical behaviour: clamped
        assert nodes[0].refcount == 0

    def test_release_nodes_underflow(self, arm):
        t = TypedRadixTree(page_tokens=2)
        nodes = t.insert_chain([0, 1], [0], "p", TypeLabel.BUSY)
        t.acquire_nodes(nodes)
        t.release_nodes(nodes)
        with pytest.raises(KvsanError, match="refcount underflow"):
            t.release_nodes(nodes)

    def test_release_program_with_outstanding_pin(self, arm):
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain([0, 1], [0], "p", TypeLabel.BUSY)
        t.pin("p")
        with pytest.raises(KvsanError, match="outstanding pin"):
            t.release_program("p")
        t.unpin("p")
        t.release_program("p")                                  # now legal

    def test_free_while_node_pinned(self, pool, arm):
        t = TypedRadixTree(page_tokens=pool.page_tokens)
        pool._san.tree = t
        p = pool.alloc_device()
        t.insert_chain(list(range(pool.page_tokens)), [p], "p", TypeLabel.BUSY)
        t.pin("p")
        with pytest.raises(KvsanError, match="still pins it"):
            pool.free_device(p)
        # the pin owner itself may retire the page (offload-commit custody)
        with pool._san.owned_pin_frees("offload commit:p"):
            pool.free_device(p)
        t.unpin("p")


class TestLedgerAuditor:
    def _armed_ledger(self):
        led = TransferLedger()
        led.observer = LedgerAuditor()
        return led

    def test_clean_lifecycle(self):
        led = self._armed_ledger()
        led.open(_rec(1))
        led.complete(1)
        assert led.completed == 1

    def test_complete_never_opened(self):
        led = self._armed_ledger()
        with pytest.raises(InvariantError, match="never opened"):
            led.complete(99)

    def test_complete_twice(self):
        led = self._armed_ledger()
        led.open(_rec(1))
        led.complete(1)
        with pytest.raises(InvariantError, match="completed twice"):
            led.complete(1)

    def test_complete_after_cancel_tolerated(self):
        led = self._armed_ledger()
        led.open(_rec(1))
        led.cancel(1)
        led.complete(1)          # documented benign race: no raise
        assert led.cancelled == 1 and led.completed == 0

    def test_cancel_not_open(self):
        led = self._armed_ledger()
        with pytest.raises(InvariantError, match="not open"):
            led.cancel(7)

    def test_reopen_after_close(self):
        led = self._armed_ledger()
        led.open(_rec(1))
        led.complete(1)
        with pytest.raises(InvariantError, match="reopened"):
            led.open(_rec(1))

    def test_drop_then_complete_tolerated(self):
        led = self._armed_ledger()
        led.open(_rec(1, pid="px"))
        led.drop_pid("px")
        led.complete(1)          # ack raced teardown: tolerated
        assert led.dropped == 1


class TestControlPlaneChecker:
    def _checker(self):
        from repro.analysis.invariants import ControlPlaneChecker
        from repro.core import SCHEDULERS, SchedulerConfig, TierCapacity

        sched = SCHEDULERS["mori"](
            1, TierCapacity(1 << 20, 1 << 20, 0), SchedulerConfig()
        )
        return sched, ControlPlaneChecker(sched)

    def test_clean_scheduler_passes(self):
        sched, chk = self._checker()
        sched.program_arrived("p0", 64, 0.0)
        sched.request_arrived("p0", 10, 0.0)
        chk.check(0.0)
        chk.assert_drained()

    def test_occupancy_conservation(self):
        sched, chk = self._checker()
        sched.program_arrived("p0", 64, 0.0)
        sched.request_arrived("p0", 10, 0.0)
        sched.replicas[0].gpu_used += 1
        with pytest.raises(InvariantError, match="conservation broken"):
            chk.check(1.0)

    def test_placement_table_vs_queue(self):
        sched, chk = self._checker()
        sched.program_arrived("p0", 64, 0.0)
        sched.request_arrived("p0", 10, 0.0)
        prog = sched.programs["p0"]
        rep = sched.replicas[prog.replica]
        rec = rep.gpu.pop("p0")
        rep.gpu_used -= rec.kv_bytes
        with pytest.raises(InvariantError, match="not in that queue"):
            chk.check(1.0)

    def test_open_record_for_unknown_program(self):
        sched, chk = self._checker()
        sched.ledger.open(_rec(5, pid="ghost"))
        with pytest.raises(InvariantError, match="unknown program"):
            chk.check(0.0)

    def test_assert_drained_lists_open_records(self):
        sched, chk = self._checker()
        sched.program_arrived("zzz", 64, 0.0)
        sched.ledger.open(_rec(5, pid="zzz"))
        with pytest.raises(InvariantError, match="still open"):
            chk.assert_drained()


@pytest.fixture(scope="module")
def setup():
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    return cfg, params


class TestEngineUnderKvsan:
    def test_eviction_under_live_block_table(self, arm, setup):
        """Freeing a page a resident slot's table references is the bug
        class the sanitizer exists for: a hard error at the free site."""
        from repro.serving import Engine, EngineRequest

        cfg, params = setup
        eng = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                     n_host_pages=16, max_slots=2, max_seq=128)
        eng.submit(EngineRequest("p0", list(range(2, 40)), max_new_tokens=4))
        slot = next(iter(eng.slots.values()))
        victim = slot.table[0]
        # page is pinned via the prefix node and/or referenced by the live
        # block table — either check must stop the free
        with pytest.raises(KvsanError, match="still pins it|live decode"):
            eng.pool.free_device(victim)
        eng.run_to_completion()

    def test_clean_replay_chunked_async(self, arm, setup):
        """Everything armed — sanitizer, strict radix, ledger auditor,
        tick sweeps, end-of-replay leak check — a demoting replay with
        chunked prefill and async transfers must come out clean."""
        from repro.core import SchedulerConfig
        from repro.core.types import ProgramTrace, RequestRecord, TransferCost
        from repro.kernels import kv_quant
        from repro.serving import Engine, MoriRouter

        cfg, params = setup
        kvb = kv_quant.token_wire_bytes(
            cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16")
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                        n_host_pages=128, max_slots=4, max_seq=256)
        router = MoriRouter(
            [engine], scheduler="mori",
            gpu_capacity_bytes=250 * kvb,
            config=SchedulerConfig(tick_interval_s=1.0),
            chunked_prefill=True,
            xfer_cost=TransferCost(pcie_bytes_per_s=64 * kvb / 12.0),
        )
        traces = [
            ProgramTrace(f"p{i}", [
                RequestRecord(48 + 8 * i, 4, 20.0 if i == 3 else 1.0,
                              reasoning_wall_s=2.0),
                RequestRecord(70 + 8 * i, 4, 0.0, reasoning_wall_s=2.0),
            ])
            for i in range(4)
        ]
        m = router.replay(traces, vocab_size=cfg.vocab_size, max_new_tokens=4)
        assert m.steps_completed == 8
        assert router._checker is not None      # the checker really ran
        assert len(router.sched.ledger) == 0


class TestFuzzHarness:
    def test_artifact_round_trip(self, tmp_path, monkeypatch, arm):
        """A failing round shrinks and lands as a replayable JSON artifact
        carrying the error, the kvsan trace, and the minimal corpus."""
        import random

        from repro.analysis import fuzz as fz

        def fake_run(knobs, corpus, cfg, params, *, audit=False):
            # fails regardless of corpus size → shrinks to one program
            return KvsanError("double-free of dev page 3",
                              ["[scope] free dev:3"])

        monkeypatch.setattr(fz, "_run_once", fake_run)
        rng_corp = fz._make_corpus(random.Random(0), 0)
        knobs = fz._make_knobs(random.Random(0))
        corpus, err, attempts = fz._shrink(
            knobs, rng_corp, fake_run(knobs, rng_corp, None, None), None, None
        )
        assert len(corpus) == 1        # shrank to a single program
        rep = fz._report(0, 0, knobs, corpus, err, attempts)
        out = tmp_path / "artifact.json"
        out.write_text(json.dumps(fz.asdict(rep)))
        loaded = json.loads(out.read_text())
        assert loaded["error_type"] == "KvsanError"
        assert loaded["kvsan_trace"] == ["[scope] free dev:3"]
        assert len(loaded["corpus"]) == 1
        assert loaded["corpus"][0]["steps"][0]["input_tokens"] >= 32
