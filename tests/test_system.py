"""End-to-end behaviour: full paper pipeline at reduced scale.

The heavyweight per-figure runs live in ``benchmarks/``; this test asserts
the *pipeline* — trace generation -> simulation of all four schedulers ->
paper-claim directionality — works end to end in one shot.
"""
from repro.sim import Simulation, small_test_hw
from repro.traces import generate_corpus, phase_stats


def test_end_to_end_paper_pipeline():
    corpus = generate_corpus(24, seed=11)

    # §3 characterization holds on this corpus
    stats = phase_stats(corpus, threshold_s=2.0)
    assert stats.short_fraction > 0.75
    assert stats.orders_of_magnitude > 2.5

    # §6 evaluation at reduced scale, under memory pressure
    hw = small_test_hw(hbm_bytes=250_000_000)
    results = {}
    for sched in ["mori", "ta+o", "ta", "smg"]:
        sim = Simulation(
            sched,
            hw,
            corpus,
            num_replicas=2,
            concurrency_per_replica=10,
            cpu_ratio=2.0,
            duration_s=300.0,
            warmup_s=30.0,
            seed=0,
        )
        results[sched] = sim.run()

    mori = results["mori"]
    # headline claim: MORI >= every baseline on throughput, <= on TTFT
    for name, r in results.items():
        assert mori.output_tok_per_s >= 0.99 * r.output_tok_per_s, name
        assert mori.ttft_avg_s <= 1.05 * r.ttft_avg_s, name
    # affinity claim (§6.2.2): near-zero churn for MORI
    assert mori.switches_per_program <= results["ta+o"].switches_per_program
    # all schedulers made real progress
    assert all(r.steps_completed > 200 for r in results.values())
