"""Unit + property tests for the windowed idleness metric (paper §4.2)."""
import math

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core.idleness import IdlenessTracker
from repro.core.types import Status


def run_cycles(tracker, cycles, t0=0.0):
    """cycles: list of (reasoning_s, acting_s). Returns end time."""
    t = t0
    for reasoning, acting in cycles:
        tracker.transition(Status.REASONING, t)
        t += reasoning
        tracker.transition(Status.ACTING, t)
        t += acting
    return t


def test_idleness_basic_ratio():
    tr = IdlenessTracker(window=5)
    t = run_cycles(tr, [(1.0, 3.0)] * 5)
    assert math.isclose(tr.idleness(t), 0.75, rel_tol=1e-9)


def test_unknown_program_defaults_to_half():
    tr = IdlenessTracker(window=5)
    assert tr.idleness(0.0) == 0.5


def test_window_drops_stale_history():
    tr = IdlenessTracker(window=2)
    # two very idle cycles followed by two fully busy cycles
    t = run_cycles(tr, [(0.1, 100.0), (0.1, 100.0)])
    t = run_cycles(tr, [(5.0, 0.1), (5.0, 0.1)], t0=t)
    # window=2 only sees the busy cycles
    assert tr.idleness(t) < 0.05


def test_ongoing_long_tool_call_raises_idleness():
    """Paper: responsiveness — an in-progress long call grows in the window."""
    tr = IdlenessTracker(window=5)
    t = run_cycles(tr, [(2.0, 0.5)] * 5)  # busy phase: iota = 0.2
    busy_iota = tr.idleness(t)
    assert busy_iota < 0.25
    tr.transition(Status.REASONING, t)
    tr.transition(Status.ACTING, t + 1.0)  # enters a tool call at t+1
    assert tr.idleness(t + 1.0 + 60.0) > 0.8  # 60s in: clearly idle


def test_single_outlier_is_diluted():
    """Paper: robustness — one long call amid a busy phase is smoothed."""
    tr = IdlenessTracker(window=5)
    t = run_cycles(tr, [(2.0, 0.5)] * 4)
    t = run_cycles(tr, [(2.0, 6.0)], t0=t)  # one slow shell command
    # 4 cycles of 2/0.5 + 1 cycle of 2/6 -> iota = 8/18 ~ 0.44, not ~1
    assert tr.idleness(t) < 0.5


def test_gated_time_excluded():
    tr = IdlenessTracker(window=5)
    t = run_cycles(tr, [(1.0, 1.0)] * 3)
    before = tr.idleness(t)
    tr.transition(Status.GATED, t)
    # a long scheduler-imposed wait must not change the metric
    assert math.isclose(tr.idleness(t + 500.0), before, rel_tol=1e-9)


def test_resume_after_idle_phase_drops_quickly():
    tr = IdlenessTracker(window=5)
    t = run_cycles(tr, [(1.0, 120.0)])  # one idle-phase cycle
    assert tr.idleness(t) > 0.9
    t = run_cycles(tr, [(3.0, 0.2)] * 5, t0=t)  # burst of short calls
    assert tr.idleness(t) < 0.1  # window pushed the long call out


@given(
    cycles=st.lists(
        st.tuples(
            st.floats(0.01, 100.0, allow_nan=False),
            st.floats(0.01, 100.0, allow_nan=False),
        ),
        min_size=1,
        max_size=30,
    ),
    window=st.integers(1, 8),
)
@settings(max_examples=200, deadline=None)
def test_idleness_always_in_unit_interval(cycles, window):
    tr = IdlenessTracker(window=window)
    t = run_cycles(tr, cycles)
    iota = tr.idleness(t)
    assert 0.0 <= iota <= 1.0


@given(
    cycles=st.lists(
        st.tuples(st.floats(0.01, 50.0), st.floats(0.01, 50.0)),
        min_size=6,
        max_size=12,
    )
)
@settings(max_examples=100, deadline=None)
def test_idleness_matches_manual_window(cycles):
    """iota must equal Eq. (1) computed over exactly the last k cycles."""
    k = 5
    tr = IdlenessTracker(window=k)
    t = run_cycles(tr, cycles)
    last = cycles[-k:]
    acting = sum(a for _, a in last)
    reasoning = sum(r for r, _ in last)
    assert math.isclose(tr.idleness(t), acting / (reasoning + acting), rel_tol=1e-9)
