"""Int8 per-page-scale KV quantization (PR 10): kernel/oracle parity in
interpret mode, host round trips with scale sidecars, format-aware byte
accounting, and the resident-capacity win an int8 device pool buys.

Error band: symmetric per-page int8 bounds each element's error by
``scale/2 = amax/254``. For N(0,1) K/V pages and softmax-normalized
attention the end-to-end logit error stays ~1e-2; the tests pin 5e-2 as
the documented band (comfortably above observed, far below signal).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import kv_quant
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.serving.kvpool import PagePool

RNG = np.random.default_rng(1234)

#: pinned end-to-end error band: int8-quantized attention vs the bf16
#: oracle on the same (pre-quantization) pages, N(0,1) data
QUANT_BAND = 5e-2
#: kernel-vs-oracle band when BOTH run on the same int8 pages (pure
#: numerics difference, no quantization error)
EXACT_BAND = 2e-3


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


def quantized_case(B, H, KH, D, T, P, lengths):
    """Build bf16-ish pages + their int8 twins for one attention case."""
    n_pages = B * P
    q = randn((B, H, D))
    k = randn((n_pages, T, KH, D))
    v = randn((n_pages, T, KH, D))
    tables = jnp.arange(n_pages, dtype=jnp.int32).reshape(B, P)
    lengths = jnp.asarray(lengths, jnp.int32)
    kq, ks = kv_quant.quantize_pages(k)
    vq, vs = kv_quant.quantize_pages(v)
    return q, k, v, kq, ks, vq, vs, tables, lengths


# ===================================================== transform round trips
class TestQuantTransforms:
    def test_quantize_dequantize_error_bound(self):
        x = randn((6, 8, 2, 16))
        q, s = kv_quant.quantize_pages(x)
        back = kv_quant.dequantize_pages(q, s, jnp.float32)
        # per-element bound: half a quantization step of that page's scale
        bound = np.asarray(s)[:, None, None, None] * 0.5 + 1e-7
        assert (np.abs(np.asarray(back) - np.asarray(x)) <= bound).all()

    def test_jnp_and_np_quantizers_agree_bitwise(self):
        """Device- and host-side quantization of the same page must produce
        identical bytes, or staged copies would differ by path taken."""
        x = RNG.standard_normal((4, 8, 2, 16)).astype(np.float32)
        qj, sj = kv_quant.quantize_pages(jnp.asarray(x))
        qn, sn = kv_quant.quantize_np(x)
        np.testing.assert_array_equal(np.asarray(qj), qn)
        np.testing.assert_array_equal(np.asarray(sj), sn)

    def test_all_zero_page_is_representable(self):
        q, s = kv_quant.quantize_pages(jnp.zeros((2, 8, 2, 16)))
        assert np.asarray(s).min() > 0          # SCALE_EPS floor, finite math
        assert (np.asarray(q) == 0).all()

    def test_requantize_insert_grows_scale(self):
        """Appending a token larger than the page's amax must widen the
        scale — the old scale would clip it."""
        x = randn((1, 8, 2, 16)) * 0.1
        q, s = kv_quant.quantize_pages(x)
        big = jnp.full((1, 2, 16), 7.0, jnp.float32)
        q2, s2 = kv_quant.requantize_insert(
            q, s, jnp.asarray([0], jnp.int32), jnp.asarray([3], jnp.int32), big
        )
        assert float(s2[0]) > float(s[0])
        back = kv_quant.dequantize_pages(q2, s2, jnp.float32)
        np.testing.assert_allclose(np.asarray(back[0, 3]), 7.0, rtol=1e-2)

    def test_wire_bytes_halve_plus_sidecar(self):
        L, T, KH, HD = 4, 8, 2, 16
        bf16 = kv_quant.page_wire_bytes(L, T, KH, HD, "bf16")
        int8 = kv_quant.page_wire_bytes(L, T, KH, HD, "int8")
        assert int8 == bf16 // 2 + L * 2 * 4    # payload/2 + f32 sidecars
        assert int8 / bf16 < 0.55               # the regime-boundary mover
        assert kv_quant.token_wire_bytes(L, KH, HD, "int8") * 2 == (
            kv_quant.token_wire_bytes(L, KH, HD, "bf16")
        )

    def test_unknown_format_rejected(self):
        with pytest.raises(ValueError, match="unknown KV page format"):
            kv_quant.check_format("fp8")


# ================================================= kernel parity (interpret)
class TestInt8KernelParity:
    """The satellite battery: int8 × {GQA, softcap, sliding window, partial
    tail page}, Pallas kernel in interpret mode vs both oracles."""

    CASES = {
        "gqa": dict(B=3, H=8, KH=2, D=64, T=8, P=4,
                    lengths=[32, 19, 8], softcap=None, window=None),
        "softcap": dict(B=2, H=8, KH=4, D=64, T=8, P=3,
                        lengths=[24, 11], softcap=20.0, window=None),
        "window": dict(B=3, H=8, KH=4, D=64, T=8, P=4,
                       lengths=[32, 21, 3], softcap=None, window=6),
        "partial-tail": dict(B=1, H=4, KH=2, D=64, T=8, P=2,
                             lengths=[13], softcap=None, window=None),
        "all-at-once": dict(B=2, H=8, KH=2, D=64, T=16, P=3,
                            lengths=[39, 15], softcap=50.0, window=20),
    }

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_kernel_matches_int8_oracle(self, name):
        c = self.CASES[name]
        q, _, _, kq, ks, vq, vs, tables, lengths = quantized_case(
            c["B"], c["H"], c["KH"], c["D"], c["T"], c["P"], c["lengths"]
        )
        out = paged_attention(
            q, kq, vq, tables, lengths, ks, vs,
            softcap=c["softcap"], window=c["window"], interpret=True,
        )
        ref = paged_attention_ref(
            q, kq, vq, tables, lengths, ks, vs,
            softcap=c["softcap"], window=c["window"],
        )
        err = float(np.max(np.abs(np.asarray(out) - np.asarray(ref))))
        assert err < EXACT_BAND, f"{name}: kernel-vs-oracle {err:.2e}"

    @pytest.mark.parametrize("name", sorted(CASES))
    def test_int8_tracks_bf16_oracle_within_band(self, name):
        c = self.CASES[name]
        q, k, v, kq, ks, vq, vs, tables, lengths = quantized_case(
            c["B"], c["H"], c["KH"], c["D"], c["T"], c["P"], c["lengths"]
        )
        out = paged_attention(
            q, kq, vq, tables, lengths, ks, vs,
            softcap=c["softcap"], window=c["window"], interpret=True,
        )
        oracle = paged_attention_ref(
            q, k, v, tables, lengths,
            softcap=c["softcap"], window=c["window"],
        )
        err = float(np.max(np.abs(np.asarray(out) - np.asarray(oracle))))
        assert err < QUANT_BAND, f"{name}: quantization error {err:.2e}"

    def test_partial_tail_garbage_isolated_under_int8(self):
        """Tokens past ``lengths`` in a quantized tail page must not leak
        into the output — even though they share the page's scale."""
        B, H, KH, D, T, P = 1, 4, 2, 64, 8, 2
        q = randn((B, H, D))
        k = randn((B * P, T, KH, D))
        v = randn((B * P, T, KH, D))
        tables = jnp.arange(B * P, dtype=jnp.int32).reshape(B, P)
        lengths = jnp.asarray([T + 5], jnp.int32)
        poisoned_k = k.at[1, 5:].set(123.0)
        poisoned_v = v.at[1, 5:].set(-123.0)
        kq, ks = kv_quant.quantize_pages(poisoned_k)
        vq, vs = kv_quant.quantize_pages(poisoned_v)
        out = paged_attention(q, kq, vq, tables, lengths, ks, vs,
                              interpret=True)
        oracle = paged_attention_ref(q, k, v, tables, lengths)
        # note the WIDE scale the poison forces on the tail page (amax 123):
        # live tokens quantize coarsely, so only the band is guaranteed
        err = float(np.max(np.abs(np.asarray(out) - np.asarray(oracle))))
        assert err < 6 * QUANT_BAND


# ====================================================== pool round trips
def make_pool(device_format="bf16", offload_format="bf16", **kw):
    kw.setdefault("layers", 4)
    kw.setdefault("kv_heads", 2)
    kw.setdefault("head_dim", 16)
    kw.setdefault("page_tokens", 8)
    kw.setdefault("n_device_pages", 8)
    kw.setdefault("n_host_pages", 8)
    return PagePool(device_format=device_format,
                    offload_format=offload_format, **kw)


class TestPoolRoundTrips:
    def test_offload_reload_within_quant_error(self):
        pool = make_pool(offload_format="int8")
        page = pool.alloc_device()
        kt = randn((4, 8, 2, 16), jnp.bfloat16)
        vt = randn((4, 8, 2, 16), jnp.bfloat16)
        pool.write_device_page(page, kt, vt)
        before_k = np.asarray(pool.k[:, page], np.float32)
        hp = pool.offload_page(page)
        dp = pool.reload_page(hp)
        after_k = np.asarray(pool.k[:, dp], np.float32)
        scales = np.max(np.abs(before_k), axis=(1, 2, 3)) / kv_quant.QMAX
        bound = scales[:, None, None, None] * 0.5 + 0.01  # + bf16 rounding
        assert (np.abs(after_k - before_k) <= bound).all()

    def test_scale_sidecars_survive_import_byte_identically(self):
        """The cross-replica migrate path: payload AND sidecars must land
        bit-for-bit — a migrated program's KV is the same bytes."""
        src = make_pool(offload_format="int8")
        dst = make_pool(offload_format="int8")
        page = src.alloc_device()
        src.write_device_page(
            page, randn((4, 8, 2, 16), jnp.bfloat16),
            randn((4, 8, 2, 16), jnp.bfloat16),
        )
        hp = src.copy_page_to_host(page)
        dst_hp = dst.import_host_page(src, hp)
        np.testing.assert_array_equal(dst.host_k[:, dst_hp], src.host_k[:, hp])
        np.testing.assert_array_equal(dst.host_v[:, dst_hp], src.host_v[:, hp])
        np.testing.assert_array_equal(
            dst.host_k_scale[:, dst_hp], src.host_k_scale[:, hp]
        )
        np.testing.assert_array_equal(
            dst.host_v_scale[:, dst_hp], src.host_v_scale[:, hp]
        )

    def test_int8_resident_round_trip_is_byte_exact(self):
        """From an int8 device pool the host copy is verbatim (no second
        quantization), so offload→reload is lossless by construction."""
        pool = make_pool(device_format="int8", offload_format="int8")
        page = pool.alloc_device()
        pool.write_device_page(
            page, randn((4, 8, 2, 16)), randn((4, 8, 2, 16))
        )
        before = (np.asarray(pool.k[:, page]).copy(),
                  np.asarray(pool.k_scale[:, page]).copy())
        hp = pool.offload_page(page)
        dp = pool.reload_page(hp)
        np.testing.assert_array_equal(np.asarray(pool.k[:, dp]), before[0])
        np.testing.assert_array_equal(
            np.asarray(pool.k_scale[:, dp]), before[1]
        )

    def test_mixed_format_import_rejected(self):
        src = make_pool(offload_format="int8")
        dst = make_pool(offload_format="bf16")
        page = src.alloc_device()
        src.write_device_page(
            page, randn((4, 8, 2, 16), jnp.bfloat16),
            randn((4, 8, 2, 16), jnp.bfloat16),
        )
        hp = src.copy_page_to_host(page)
        with pytest.raises(AssertionError, match="incompatible page geometry"):
            dst.import_host_page(src, hp)

    def test_device_int8_requires_offload_int8(self):
        with pytest.raises(ValueError, match="requires offload_format"):
            make_pool(device_format="int8", offload_format="bf16")


# =================================================== byte accounting (ledger)
class TestWireByteBilling:
    def test_int8_offload_bills_half_of_bf16(self):
        """The satellite's ledger assertion: same page, same round trip —
        int8 puts (just over) half the bytes on the wire."""
        pools = {
            fmt: make_pool(offload_format=fmt) for fmt in ("bf16", "int8")
        }
        billed = {}
        for fmt, pool in pools.items():
            page = pool.alloc_device()
            pool.write_device_page(
                page, randn((4, 8, 2, 16), jnp.bfloat16),
                randn((4, 8, 2, 16), jnp.bfloat16),
            )
            hp = pool.offload_page(page)
            pool.reload_page(hp)
            billed[fmt] = (pool.offload_bytes, pool.reload_bytes)
        sidecar = 4 * 2 * 4
        assert billed["int8"][0] == billed["bf16"][0] // 2 + sidecar
        assert billed["int8"][1] == billed["bf16"][1] // 2 + sidecar
        assert billed["int8"][0] / billed["bf16"][0] < 0.55

    def test_program_state_prices_tiers_by_format(self):
        from repro.core.program import ProgramState

        dev_bpt = kv_quant.token_wire_bytes(4, 2, 16, "bf16")
        wire_bpt = kv_quant.token_wire_bytes(4, 2, 16, "int8")
        prog = ProgramState("p", dev_bpt, wire_bytes_per_token=wire_bpt)
        prog.context_tokens = 100
        prog.materialized_tokens = 80
        assert prog.kv_bytes == 100 * dev_bpt            # GPU budget
        assert prog.host_kv_bytes == 100 * wire_bpt      # CPU/SSD budget
        assert prog.materialized_wire_bytes == 80 * wire_bpt  # transfer size
        # the bf16 default collapses every figure to the device size
        plain = ProgramState("q", dev_bpt)
        plain.context_tokens = 100
        plain.materialized_tokens = 80
        assert plain.host_kv_bytes == plain.kv_bytes
        assert plain.materialized_wire_bytes == 80 * dev_bpt

    def test_scheduler_transfer_nbytes_use_wire_format(self):
        """An Offload emitted for an int8-offload program must carry the
        wire byte count, not the device byte count — that number over the
        link bandwidth IS the idle-window fit decision."""
        import sys
        from pathlib import Path

        sys.path.insert(0, str(Path(__file__).parent))
        from _plan_driver import Driver
        from repro.core import (
            MoriScheduler, Offload, SchedulerConfig, TierCapacity,
        )

        s = Driver(MoriScheduler(
            1, TierCapacity(10_000_000, 10_000_000), SchedulerConfig()
        ))
        s.program_arrived("p", 4096, 0.0, wire_bytes_per_token=2048)
        s.request_arrived("p", 64, 0.0)
        s.notify_inference_started("p", 0.0)
        s.request_completed("p", 0, 1.0)        # acting, 64 tokens live
        # shrink GPU below kv_bytes: the tick must demote to CPU
        s.replicas[0].capacity = TierCapacity(1000, 10_000_000)
        s.tick(100.0)
        off = s.of_kind(Offload)[-1]
        assert off.pid == "p"
        assert off.nbytes == 64 * 2048          # wire format, not 64*4096


# ======================================================== resident capacity
class TestResidentCapacity:
    def test_int8_device_pool_fits_ge_1p9x_pages_at_equal_hbm(self):
        """The tentpole's capacity claim: at a fixed HBM budget an int8
        resident pool holds ≥1.9x the pages (2x payload minus the fp32
        sidecar overhead)."""
        L, T, KH, HD = 4, 8, 2, 16
        budget = 64 * kv_quant.page_wire_bytes(L, T, KH, HD, "bf16")
        fits = {
            fmt: budget // kv_quant.page_wire_bytes(L, T, KH, HD, fmt)
            for fmt in ("bf16", "int8")
        }
        assert fits["int8"] / fits["bf16"] >= 1.9

    def test_pool_page_bytes_reflect_device_format(self):
        bf16 = make_pool()
        int8 = make_pool(device_format="int8", offload_format="int8")
        assert bf16.page_bytes / int8.page_bytes > 1.9
        assert int8.page_bytes == int8.host_page_bytes


# ========================================================== engine end-to-end
class TestEngineInt8EndToEnd:
    @pytest.fixture(scope="class")
    def setup(self):
        from repro.configs import get_config
        from repro.models import Model, materialize

        cfg = get_config("qwen1.5-0.5b").reduced()
        params = materialize(Model(cfg).describe(), seed=0)
        return cfg, params

    def _run(self, cfg, params, **fmt):
        from repro.serving import Engine, EngineRequest

        eng = Engine(cfg, params, page_tokens=8, n_device_pages=64,
                     n_host_pages=64, max_slots=2, max_seq=256, **fmt)
        eng.submit(EngineRequest("p", list(range(2, 40)), max_new_tokens=6))
        return eng.run_to_completion()[0].output_tokens

    def test_int8_offload_format_changes_nothing_resident(self, setup):
        """offload_format only affects staged copies; a run that never
        demotes is token-identical to bf16."""
        cfg, params = setup
        assert self._run(cfg, params) == self._run(
            cfg, params, offload_format="int8"
        )

    def test_int8_device_format_matches_bf16_tokens(self, setup):
        """Greedy decode is robust to the ~1e-2 logit band on this
        fixture: the int8-resident engine emits the same tokens."""
        cfg, params = setup
        assert self._run(cfg, params) == self._run(
            cfg, params, device_format="int8", offload_format="int8"
        )
