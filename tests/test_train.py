"""Train substrate: data determinism, checkpoint atomicity/integrity,
crash-restart trajectory equivalence, grad accumulation, elastic re-mesh."""
from __future__ import annotations

import json
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.launch.mesh import make_host_mesh
from repro.train import checkpoint as ck
from repro.train.data import DataConfig, TokenPipeline
from repro.train.loop import FaultInjector, TrainConfig, Trainer


def _data(vocab=128, seq=32, batch=4, seed=0):
    return DataConfig(vocab_size=vocab, seq_len=seq, global_batch=batch, seed=seed)


# ------------------------------------------------------------------- data
def test_data_deterministic_and_host_striped():
    p = TokenPipeline(_data())
    a = p.batch_at(7)["tokens"]
    b = p.batch_at(7)["tokens"]
    np.testing.assert_array_equal(a, b)
    # two-host sharding concatenates to the single-host batch
    h0 = p.batch_at(7, host_id=0, num_hosts=2)["tokens"]
    h1 = p.batch_at(7, host_id=1, num_hosts=2)["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), a)


def test_data_shape_and_vocab_range():
    cfg = _data(vocab=50, seq=16, batch=3)
    t = TokenPipeline(cfg).batch_at(0)["tokens"]
    assert t.shape == (3, 17)
    assert t.min() >= 0 and t.max() < 50


def test_data_different_steps_differ():
    p = TokenPipeline(_data())
    assert not np.array_equal(p.batch_at(0)["tokens"], p.batch_at(1)["tokens"])


# ------------------------------------------------------------- checkpoint
def _tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "w": {"a": rng.standard_normal((4, 8)).astype(np.float32)},
        "b": np.arange(5, dtype=np.int32),
    }


def test_checkpoint_roundtrip(tmp_path):
    t = _tree()
    ck.save(tmp_path, 10, t, extra={"k": 1})
    restored, step, extra = ck.restore(tmp_path, t)
    assert step == 10 and extra == {"k": 1}
    np.testing.assert_array_equal(restored["w"]["a"], t["w"]["a"])
    np.testing.assert_array_equal(restored["b"], t["b"])


def test_checkpoint_latest_and_retention(tmp_path):
    t = _tree()
    for s in (1, 2, 3, 4, 5):
        ck.save(tmp_path, s, t, keep=2)
    assert ck.latest_step(tmp_path) == 5
    kept = sorted(d.name for d in tmp_path.glob("step_*"))
    assert kept == ["step_00000004", "step_00000005"]


def test_checkpoint_corruption_falls_back(tmp_path):
    ck.save(tmp_path, 1, _tree(seed=1))
    ck.save(tmp_path, 2, _tree(seed=2))
    # corrupt the newest shard
    shard = tmp_path / "step_00000002" / "host00.npz"
    shard.write_bytes(shard.read_bytes()[:-20])
    restored, step, _ = ck.restore(tmp_path, _tree())
    assert step == 1
    np.testing.assert_array_equal(restored["w"]["a"], _tree(seed=1)["w"]["a"])


def test_checkpoint_crash_mid_write_leaves_old_intact(tmp_path):
    ck.save(tmp_path, 1, _tree(seed=1))
    # simulate a crash: a stale .tmp directory with partial contents
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "host00.npz").write_bytes(b"garbage")
    restored, step, _ = ck.restore(tmp_path, _tree())
    assert step == 1


def test_checkpoint_crc_matches_manifest(tmp_path):
    d = ck.save(tmp_path, 3, _tree())
    manifest = json.loads((d / "manifest.json").read_text())
    crc = zlib.crc32((d / "host00.npz").read_bytes())
    assert manifest["shards"]["host00.npz"] == crc


# ---------------------------------------------------------------- trainer
def _trainer(tmp_path=None, steps=4, micro=1, ckpt_every=0, seed=0):
    cfg = get_config("qwen1.5-0.5b").reduced()
    tcfg = TrainConfig(
        steps=steps, microbatches=micro, log_every=1,
        ckpt_every=ckpt_every, ckpt_dir=str(tmp_path or ""), seed=seed,
    )
    return Trainer(cfg, tcfg, make_host_mesh(),
                   _data(vocab=cfg.vocab_size, seq=16, batch=4, seed=seed))


def test_trainer_loss_decreases():
    tr = _trainer(steps=8)
    state = tr.run(tr.init_state())
    assert state.step == 8
    losses = [m["loss"] for m in tr.metrics]
    assert losses[-1] < losses[0]
    assert np.isfinite(losses).all()


def test_grad_accumulation_matches_single_batch():
    """M microbatches of B/M must equal one batch of B (same tokens)."""
    tr1 = _trainer(steps=1, micro=1)
    tr2 = _trainer(steps=1, micro=2)
    s1 = tr1.run(tr1.init_state())
    s2 = tr2.run(tr2.init_state())
    flat1 = jax.tree.leaves(s1.params)
    flat2 = jax.tree.leaves(s2.params)
    for a, b in zip(flat1, flat2):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=2e-2, atol=2e-3,
        )


def test_crash_restart_bit_identical(tmp_path):
    """Crash at step 2, restart, reach step 4 == uninterrupted run."""
    tr_a = _trainer(tmp_path / "a", steps=4, ckpt_every=1)
    fault = FaultInjector(fail_at=(2,))
    state = tr_a.resume_or_init()
    with pytest.raises(RuntimeError):
        tr_a.run(state, fault)
    # restart (fresh Trainer = fresh process)
    tr_a2 = _trainer(tmp_path / "a", steps=4, ckpt_every=1)
    resumed = tr_a2.resume_or_init()
    assert resumed.step == 2
    final_a = tr_a2.run(resumed)

    tr_b = _trainer(tmp_path / "b", steps=4, ckpt_every=0)
    final_b = tr_b.run(tr_b.init_state())

    for a, b in zip(jax.tree.leaves(final_a.params),
                    jax.tree.leaves(final_b.params)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=1e-5, atol=1e-6,
        )


def test_restore_across_meshes(tmp_path):
    """Checkpoint written under one mesh restores onto another (elastic)."""
    tr = _trainer(tmp_path, steps=2, ckpt_every=2)
    tr.run(tr.init_state())
    tr2 = _trainer(tmp_path, steps=2, ckpt_every=2)
    state = tr2.resume_or_init()   # re-shards through device_put
    assert state.step == 2
    assert all(jnp.isfinite(l).all() for l in jax.tree.leaves(state.params)
               if l.dtype.kind == "f")
