"""Tests for the typed radix tree (paper §4.3.2): prefix reuse + the
tier-reversed type-priority eviction order."""
import pytest
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

from repro.core.radix_tree import TypedRadixTree
from repro.core.types import TypeLabel


def toks(n, base=0):
    return list(range(base, base + n))


class TestInsertMatch:
    def test_insert_then_match(self):
        t = TypedRadixTree(page_tokens=4)
        nodes = t.insert_chain(toks(8), [10, 11], "p1", TypeLabel.BUSY)
        assert [n.device_page for n in nodes] == [10, 11]
        assert [n.device_page for n in t.match_prefix(toks(8))] == [10, 11]

    def test_partial_page_not_matched(self):
        t = TypedRadixTree(page_tokens=4)
        t.insert_chain(toks(8), [1, 2], "p1", TypeLabel.BUSY)
        # only 7 tokens -> one full page
        assert len(t.match_prefix(toks(7))) == 1

    def test_prefix_sharing_between_programs(self):
        t = TypedRadixTree(page_tokens=4)
        t.insert_chain(toks(8), [1, 2], "p1", TypeLabel.BUSY)
        # p2 shares the first 8 tokens, extends by 4 -> only 1 new page
        nodes = t.insert_chain(toks(8) + toks(4, 100), [3], "p2", TypeLabel.BUSY)
        assert [n.device_page for n in nodes] == [1, 2, 3]

    def test_divergent_suffixes_fork(self):
        t = TypedRadixTree(page_tokens=4)
        t.insert_chain(toks(4) + toks(4, 50), [1, 2], "p1", TypeLabel.BUSY)
        t.insert_chain(toks(4) + toks(4, 60), [3], "p2", TypeLabel.BUSY)
        assert len(t.match_prefix(toks(4) + toks(4, 50))) == 2
        assert len(t.match_prefix(toks(4) + toks(4, 60))) == 2

    def test_page_count_mismatch_raises(self):
        t = TypedRadixTree(page_tokens=4)
        with pytest.raises(ValueError):
            t.insert_chain(toks(8), [1], "p1", TypeLabel.BUSY)


class TestTypedEviction:
    def _three_programs(self):
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain(toks(2, 0), [0], "busy", TypeLabel.BUSY)
        t.insert_chain(toks(2, 10), [1], "idle", TypeLabel.IDLE)
        t.insert_chain(toks(2, 20), [2], "inactive", TypeLabel.INACTIVE)
        return t

    def test_gpu_order_inactive_idle_busy(self):
        t = self._three_programs()
        labels = [n.label for n in t.evictable("gpu")]
        assert labels == [TypeLabel.INACTIVE, TypeLabel.IDLE, TypeLabel.BUSY]

    def test_cpu_order_inactive_busy_idle(self):
        t = self._three_programs()
        for n in list(t._iter_nodes()):
            n.host_page = n.device_page  # pretend all offloaded
        labels = [n.label for n in t.evictable("cpu")]
        assert labels == [TypeLabel.INACTIVE, TypeLabel.BUSY, TypeLabel.IDLE]

    def test_lru_breaks_ties_within_type(self):
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain(toks(2, 0), [0], "a", TypeLabel.IDLE)
        t.insert_chain(toks(2, 10), [1], "b", TypeLabel.IDLE)
        t.match_prefix(toks(2, 0))  # touch a -> b is now least recent
        first = t.evictable("gpu")[0]
        assert first.device_page == 1

    def test_pinned_nodes_never_evictable(self):
        t = self._three_programs()
        t.pin("inactive")
        labels = [n.label for n in t.evictable("gpu")]
        assert TypeLabel.INACTIVE not in labels
        t.unpin("inactive")
        assert TypeLabel.INACTIVE in [n.label for n in t.evictable("gpu")]

    def test_children_evicted_before_parents(self):
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain(toks(6), [0, 1, 2], "p", TypeLabel.IDLE)
        order = t.evictable("gpu")
        assert [n.device_page for n in order] == [2]  # only the leaf
        t.evict(order[0], "gpu")
        assert [n.device_page for n in t.evictable("gpu")] == [1]

    def test_restamp_propagates_label(self):
        t = self._three_programs()
        t.restamp("busy", TypeLabel.INACTIVE)
        first = t.evictable("gpu")[:2]
        assert all(n.label is TypeLabel.INACTIVE for n in first)

    def test_evict_frees_and_gcs(self):
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain(toks(4), [0, 1], "p", TypeLabel.INACTIVE)
        for n in list(t.evictable("gpu")):
            t.evict(n, "gpu")
        for n in list(t.evictable("gpu")):
            t.evict(n, "gpu")
        assert t.stats() == {"device_pages": 0, "host_pages": 0}
        assert not t.root.children  # fully garbage-collected


class TestPinUnpinEdges:
    """Refcount discipline at the seams the transfer plane exercises:
    pins racing eviction, repeated teardown, and (under kvsan strict
    mode) the underflow the historical ``max(0, ...)`` clamp hid."""

    def test_unpin_while_reload_holds_nodes(self):
        """A decode release (unpin) while an in-flight reload still holds
        its own acquire must leave the reload's refcount intact — the
        nodes stay unevictable until the stream also releases."""
        t = TypedRadixTree(page_tokens=2)
        nodes = t.insert_chain(toks(4), [0, 1], "p", TypeLabel.BUSY)
        t.pin("p")                 # decode slot
        t.acquire_nodes(nodes)     # in-flight reload stream
        t.unpin("p")               # decode retires first
        assert [n.refcount for n in nodes] == [1, 1]
        assert t.evictable("gpu") == []          # still protected
        t.release_nodes(nodes)     # stream commits
        assert [n.refcount for n in nodes] == [0, 0]
        assert len(t.evictable("gpu")) == 1      # leaf evictable again

    def test_double_release_program_is_idempotent(self):
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain(toks(4), [0, 1], "p", TypeLabel.BUSY)
        t.release_program("p")
        t.release_program("p")                   # second is a no-op
        assert t.program_nodes("p") == []
        # pins after release target an empty node list, harmlessly
        t.pin("p")
        t.unpin("p")

    def test_pin_after_partial_eviction(self):
        """Eviction between a program's runs shrinks its chain on-device;
        a later pin must hold the *surviving* nodes only and balance."""
        t = TypedRadixTree(page_tokens=2)
        nodes = t.insert_chain(toks(6), [0, 1, 2], "p", TypeLabel.IDLE)
        leaf = t.evictable("gpu")[0]
        assert leaf is nodes[2]
        t.evict(leaf, "gpu")                     # tail page gone
        t.pin("p")
        # the evicted node is still in the program's node list (its page
        # is just elsewhere/nowhere); all three refcounts move together
        assert [n.refcount for n in nodes] == [1, 1, 1]
        assert t.evictable("gpu") == []
        t.unpin("p")
        assert [n.refcount for n in nodes] == [0, 0, 0]

    def test_strict_mode_rejects_unbalanced_unpin(self, monkeypatch):
        from repro.analysis import kvsan

        monkeypatch.setenv(kvsan.ENV_VAR, "1")
        t = TypedRadixTree(page_tokens=2)
        t.insert_chain(toks(4), [0, 1], "p", TypeLabel.BUSY)
        t.pin("p")
        t.unpin("p")
        with pytest.raises(kvsan.KvsanError):
            t.unpin("p")


@given(
    seqs=st.lists(
        st.lists(st.integers(0, 3), min_size=2, max_size=16),
        min_size=1,
        max_size=8,
    )
)
@settings(max_examples=100, deadline=None)
def test_property_shared_prefixes_share_pages(seqs):
    """Two programs with a common full-page prefix must map it to the same
    pages, and total allocated pages == number of distinct page-paths."""
    t = TypedRadixTree(page_tokens=2)
    next_page = [0]
    paths = set()
    for i, seq in enumerate(seqs):
        full = seq[: len(seq) // 2 * 2]
        existing = t.match_prefix(full)
        need = len(full) // 2 - len(existing)
        pages = [next_page[0] + j for j in range(need)]
        next_page[0] += need
        t.insert_chain(full, pages, f"p{i}", TypeLabel.BUSY)
        for k in range(2, len(full) + 1, 2):
            paths.add(tuple(full[:k]))
    assert t.stats()["device_pages"] == len(paths)
