"""ReplicaBalancer unit coverage: most-available-capacity placement,
tie-breaking, the mark_failed/mark_recovered health paths (including the
all-replicas-failed edge), the beyond-paper straggler-penalty discount,
and the typed :class:`PlacementDecision` result (replica + reason) the
router surfaces in its metrics."""
from __future__ import annotations

from repro.core.balancer import PLACEMENT_REASONS, PlacementDecision, ReplicaBalancer
from repro.core.program import ProgramState
from repro.core.tiers import ReplicaTiers
from repro.core.types import SchedulerConfig, TierCapacity


def make_balancer(frees, *, penalty=0.0, cpu=0):
    replicas = [
        ReplicaTiers(replica_id=i, capacity=TierCapacity(free, cpu))
        for i, free in enumerate(frees)
    ]
    cfg = SchedulerConfig(straggler_penalty=penalty)
    return ReplicaBalancer(replicas, cfg), replicas


def prog(tokens=10, kv_bytes_per_token=100):
    p = ProgramState("p", kv_bytes_per_token)
    p.context_tokens = tokens
    return p


class TestPlacement:
    def test_picks_most_available_capacity(self):
        bal, _ = make_balancer([1_000, 50_000, 30_000])
        assert bal.place(prog(), 0.0).replica == 1

    def test_capacity_accounts_for_admitted_programs(self):
        bal, reps = make_balancer([50_000, 50_000])
        reps[0].gpu_admit(prog(tokens=400))      # 40k used on replica 0
        assert bal.place(prog(), 0.0).replica == 1

    def test_tie_breaks_to_highest_replica_id(self):
        # equal effective capacity sorts (free, replica_id) descending:
        # the documented deterministic tie-break is the highest id
        bal, _ = make_balancer([50_000, 50_000])
        assert bal.place(prog(), 0.0).replica == 1

    def test_none_when_nothing_fits(self):
        bal, _ = make_balancer([500, 900])       # prog needs 1000 bytes
        assert bal.place(prog(), 0.0).replica is None


class TestDecision:
    """The typed PlacementDecision: truthiness, reasons, and the counter."""

    def test_truthiness_follows_placement(self):
        bal, _ = make_balancer([50_000, 10_000])
        assert bal.place(prog(), 0.0)
        assert not bal.place(prog(tokens=10_000), 0.0)

    def test_reason_most_available(self):
        bal, _ = make_balancer([1_000, 50_000])
        d = bal.place(prog(), 0.0)
        assert d == PlacementDecision(1, "most-available")

    def test_reason_tie_break(self):
        bal, _ = make_balancer([50_000, 50_000])
        d = bal.place(prog(), 0.0)
        assert (d.replica, d.reason) == (1, "tie-break")

    def test_reason_no_capacity(self):
        bal, _ = make_balancer([500])
        d = bal.place(prog(), 0.0)
        assert (d.replica, d.reason) == (None, "no-capacity")

    def test_reason_no_healthy_replica(self):
        bal, _ = make_balancer([50_000])
        bal.mark_failed(0)
        d = bal.place(prog(), 0.0)
        assert (d.replica, d.reason) == (None, "no-healthy-replica")

    def test_reason_straggler_discount(self):
        # replica 1 has the most raw free HBM but a 10x EWMA step latency;
        # the discount flips the winner to replica 0 and says why
        bal, reps = make_balancer([50_000, 60_000, 45_000], penalty=0.5)
        reps[0].ewma_step_latency_s = 0.1
        reps[1].ewma_step_latency_s = 1.0
        reps[2].ewma_step_latency_s = 0.1
        d = bal.place(prog(), 0.0)
        assert (d.replica, d.reason) == (0, "straggler-discount")

    def test_reason_drain_target(self):
        bal, _ = make_balancer([10_000, 10_000], cpu=50_000)
        d = bal.place_drain(prog(), 0.0)
        assert (d.replica, d.reason) == (1, "drain-target")

    def test_drain_target_needs_host_headroom(self):
        bal, _ = make_balancer([50_000, 50_000], cpu=500)
        d = bal.place_drain(prog(), 0.0)
        assert (d.replica, d.reason) == (None, "no-capacity")

    def test_drain_skips_failed_replicas(self):
        bal, _ = make_balancer([10_000, 10_000], cpu=50_000)
        bal.mark_failed(1)
        assert bal.place_drain(prog(), 0.0).replica == 0
        bal.mark_failed(0)
        assert bal.place_drain(prog(), 0.0).reason == "no-healthy-replica"

    def test_reason_counts_accumulate(self):
        bal, _ = make_balancer([1_000, 50_000])
        bal.place(prog(), 0.0)
        bal.place(prog(), 0.0)
        bal.place(prog(tokens=10_000), 0.0)
        assert bal.reason_counts["most-available"] == 2
        assert bal.reason_counts["no-capacity"] == 1

    def test_every_emitted_reason_is_documented(self):
        bal, _ = make_balancer([50_000, 50_000], cpu=1_000)
        bal.place(prog(), 0.0)
        bal.place_drain(prog(), 0.0)
        bal.mark_failed(0)
        bal.mark_failed(1)
        bal.place(prog(), 0.0)
        assert set(bal.reason_counts) <= set(PLACEMENT_REASONS)


class TestHealth:
    def test_failed_replica_excluded_until_recovered(self):
        bal, _ = make_balancer([10_000, 50_000])
        assert bal.place(prog(), 0.0).replica == 1
        bal.mark_failed(1)
        assert bal.place(prog(), 0.0).replica == 0
        bal.mark_recovered(1)
        assert bal.place(prog(), 0.0).replica == 1

    def test_all_replicas_failed_places_nowhere(self):
        bal, _ = make_balancer([10_000, 50_000])
        bal.mark_failed(0)
        bal.mark_failed(1)
        assert bal.healthy() == []
        assert bal.place(prog(), 0.0).replica is None

    def test_mark_failed_is_idempotent(self):
        bal, _ = make_balancer([10_000, 50_000])
        bal.mark_failed(1)
        bal.mark_failed(1)                       # double-fail is harmless
        assert bal.place(prog(), 0.0).replica == 0
        bal.mark_recovered(1)
        bal.mark_recovered(1)                    # as is double-recover
        assert bal.place(prog(), 0.0).replica == 1


class TestStragglerPenalty:
    def _slow_fleet(self, penalty):
        # three equal-capacity replicas; replica 2's EWMA step latency is
        # 10x the fleet median
        bal, reps = make_balancer([50_000] * 3, penalty=penalty)
        reps[0].ewma_step_latency_s = 0.1
        reps[1].ewma_step_latency_s = 0.1
        reps[2].ewma_step_latency_s = 1.0
        return bal, reps

    def test_discount_biases_away_from_straggler(self):
        bal, _ = self._slow_fleet(penalty=0.5)
        # without the discount the (free, id) tie-break would pick 2
        assert bal.place(prog(), 0.0).replica == 1

    def test_zero_penalty_ignores_latency(self):
        bal, _ = self._slow_fleet(penalty=0.0)
        assert bal.place(prog(), 0.0).replica == 2  # plain capacity tie-break

    def test_extreme_penalty_clamps_at_zero_capacity(self):
        # slowdown 9x with penalty 10 would go deeply negative without the
        # clamp; the straggler must still never beat a healthy replica,
        # and a fleet of one straggler still places (its own median)
        bal, _ = self._slow_fleet(penalty=10.0)
        assert bal.place(prog(), 0.0).replica == 1
        bal.mark_failed(0)
        bal.mark_failed(1)
        assert bal.place(prog(), 0.0).replica == 2  # median of itself: no discount

    def test_fully_discounted_straggler_defers_placement(self):
        """With the healthy replicas full and the straggler's effective
        capacity discounted to zero, place() declines rather than pile new
        work onto the slow replica — the program waits for the next pass
        (same admission-control semantics as a genuinely full fleet)."""
        bal, reps = self._slow_fleet(penalty=0.5)
        reps[0].gpu_admit(prog(tokens=495))
        reps[1].gpu_admit(prog(tokens=495))      # 500 bytes free each
        assert bal.place(prog(), 0.0).replica is None
