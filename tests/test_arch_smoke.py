"""Per-architecture smoke tests (deliverable f): every assigned arch at a
REDUCED config of the same family runs one forward/train step on CPU with
shape + finiteness asserts, and prefill->decode agrees with full-sequence
forward."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.models import Model, count_params, materialize

ARCHS = list(ARCH_IDS)


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {
        "tokens": jnp.asarray(
            rng.integers(0, cfg.vocab_size, (B, S + 1)), jnp.int32
        )
    }
    if cfg.family == "encdec":
        batch["frames"] = jnp.asarray(
            rng.standard_normal((B, cfg.encoder_seq, cfg.d_model)) * 0.02,
            jnp.bfloat16,
        )
    if cfg.family == "vlm":
        batch["image_embeds"] = jnp.asarray(
            rng.standard_normal((B, cfg.num_image_tokens, cfg.d_model)) * 0.02,
            jnp.bfloat16,
        )
    return batch


@pytest.fixture(scope="module")
def models():
    return {}


def get_model(models, arch):
    if arch not in models:
        cfg = get_config(arch).reduced()
        m = Model(cfg)
        params = materialize(m.describe(), seed=0)
        models[arch] = (cfg, m, params)
    return models[arch]


@pytest.mark.parametrize("arch", ARCHS)
def test_exact_config_dimensions(arch):
    """The full (non-reduced) config carries the published dimensions."""
    cfg = get_config(arch)
    expected = {
        "mamba2-2.7b": (64, 2560, 0, 50_280),
        "internlm2-20b": (48, 6144, 16_384, 92_544),
        "gemma2-27b": (46, 4608, 36_864, 256_000),
        "gemma2-9b": (42, 3584, 14_336, 256_000),
        "qwen1.5-0.5b": (24, 1024, 2816, 151_936),
        "arctic-480b": (35, 7168, 4864, 32_000),
        "dbrx-132b": (40, 6144, 0, 100_352),
        "whisper-medium": (24, 1024, 4096, 51_865),
        "internvl2-26b": (48, 6144, 16_384, 92_553),
        "zamba2-2.7b": (54, 2560, 10_240, 32_000),
    }[arch]
    assert (cfg.num_layers, cfg.d_model, cfg.d_ff, cfg.vocab_size) == expected


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_runs_and_is_finite(models, arch):
    cfg, m, params = get_model(models, arch)
    batch = make_batch(cfg)
    loss, metrics = m.loss(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: loss={loss}"
    # random init -> loss near ln(vocab)
    assert abs(float(metrics["ce"]) - np.log(cfg.vocab_size)) < 2.0
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    gnorm = jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in jax.tree.leaves(grads))
    )
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0.0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_shapes_and_finiteness(models, arch):
    cfg, m, params = get_model(models, arch)
    B, S = 2, 32
    batch = make_batch(cfg, B, S)
    batch["tokens"] = batch["tokens"][:, :S]
    logits, cache = m.prefill(params, batch)
    assert logits.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all())
    assert cache is not None


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_full_forward(models, arch):
    """Teacher-forced decode over a slot cache must reproduce the prefill
    logits of the longer sequence — the core KV-cache correctness property."""
    cfg, m, params = get_model(models, arch)
    B, S = 2, 16
    batch = make_batch(cfg, B, S + 1, seed=1)
    tokens = batch["tokens"][:, : S + 1]
    pre_batch = dict(batch)
    pre_batch["tokens"] = tokens[:, :S]

    # ground truth: prefill over the longer sequence
    full_batch = dict(batch)
    full_batch["tokens"] = tokens
    full_logits, _ = m.prefill(params, full_batch)

    # prefill S tokens into padded slot cache, then decode token S
    logits0, cache = m.prefill(params, pre_batch)
    img = cfg.num_image_tokens if cfg.family == "vlm" else 0
    max_seq = S + 8 + img
    cache = pad_cache(m, cache, B, max_seq)
    lengths = jnp.full((B,), S + 1 + img, jnp.int32)
    step_logits, _ = m.decode(params, cache, tokens[:, S], lengths)
    # bf16 params: chunked-prefill vs stepwise paths differ by rounding only
    np.testing.assert_allclose(
        np.asarray(step_logits, np.float32),
        np.asarray(full_logits, np.float32),
        rtol=5e-2,
        atol=6e-2,
    )


def pad_cache(m, cache, B, max_seq):
    """Pad sequence dims of prefill KV caches up to max_seq slots."""

    def pad(name, x):
        if name in ("ssm", "conv") or x.ndim < 5:
            return x
        L, b, S = x.shape[:3]
        if S >= max_seq:
            return x
        pad_width = [(0, 0)] * x.ndim
        pad_width[2] = (0, max_seq - S)
        return jnp.pad(x, pad_width)

    out = {}
    for k, v in cache.items():
        if isinstance(v, dict):
            out[k] = {kk: pad(kk, vv) for kk, vv in v.items()}
        elif k in ("ck", "cv", "ssm", "conv"):
            out[k] = v
        else:
            out[k] = pad(k, v)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_order_of_magnitude(arch):
    """Reduced configs stay tiny; full configs match the advertised scale."""
    cfg = get_config(arch)
    n = count_params(Model(cfg).describe()) / 1e9
    expected = {
        "mamba2-2.7b": (2.0, 4.0),
        "internlm2-20b": (17.0, 24.0),
        "gemma2-27b": (22.0, 33.0),
        "gemma2-9b": (8.0, 13.0),
        "qwen1.5-0.5b": (0.3, 0.8),
        "arctic-480b": (400.0, 520.0),
        "dbrx-132b": (110.0, 150.0),
        # SwiGLU FFN everywhere (simplification) puts us slightly above
        # whisper-medium's published 0.77B
        "whisper-medium": (0.25, 1.2),
        "internvl2-26b": (17.0, 26.0),
        "zamba2-2.7b": (2.0, 4.5),
    }[arch]
    assert expected[0] <= n <= expected[1], f"{arch}: {n:.2f}B params"
    small = count_params(Model(get_config(arch).reduced()).describe())
    assert small < 50e6, f"reduced {arch} too big: {small/1e6:.1f}M"
