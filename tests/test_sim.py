"""Simulator integration tests: conservation laws, paper-claim directionality,
fault tolerance, determinism."""
import pytest

from repro.core import SchedulerConfig
from repro.sim import FaultPlan, Simulation, small_test_hw
from repro.traces import generate_corpus


def run(sched="mori", conc=10, replicas=1, duration=200.0, hw=None, corpus=None, **kw):
    corpus = corpus or generate_corpus(20, seed=1)
    hw = hw or small_test_hw()
    sim = Simulation(
        sched,
        hw,
        corpus,
        num_replicas=replicas,
        concurrency_per_replica=conc,
        duration_s=duration,
        warmup_s=20.0,
        seed=0,
        **kw,
    )
    return sim, sim.run()


class TestConservation:
    def test_steps_complete_and_tokens_flow(self):
        _, r = run()
        assert r.steps_completed > 50
        assert r.output_tok_per_s > 0

    def test_ttft_nonnegative_and_finite(self):
        sim, r = run()
        assert all(t >= 0 for t in sim.ttfts)
        assert r.ttft_p99_s < sim.duration

    def test_gpu_util_in_unit_interval(self):
        _, r = run()
        assert 0.0 <= r.gpu_util <= 1.0 + 1e-9

    def test_forward_accounting_consistent(self):
        sim, _ = run()
        assert (
            sim.warm_forwards + sim.reload_forwards + sim.recompute_forwards
            == sim.forwards
        )
        # every completed step was forwarded exactly once
        assert sim.forwards >= sim.completed_steps

    def test_determinism(self):
        _, r1 = run()
        _, r2 = run()
        assert r1.output_tok_per_s == r2.output_tok_per_s
        assert r1.ttft_avg_s == r2.ttft_avg_s
        assert r1.steps_completed == r2.steps_completed


class TestPaperClaims:
    """Directional reproduction of §6.2 at small scale (full-scale numbers
    live in benchmarks/)."""

    @pytest.fixture(scope="class")
    def pressured(self):
        """A config under real memory pressure: GPU fits only ~1/4 of the
        aggregate working set, CPU tier fits another ~1/2."""
        corpus = generate_corpus(30, seed=2)
        hw = small_test_hw(hbm_bytes=220_000_000)  # ~220k tokens of KV
        results = {}
        for sched in ["mori", "ta+o", "ta", "smg"]:
            _, results[sched] = run(
                sched, conc=24, duration=400.0, hw=hw, corpus=corpus, cpu_ratio=1.0
            )
        return results

    def test_mori_beats_offloading_baseline_under_pressure(self, pressured):
        assert (
            pressured["mori"].output_tok_per_s
            > 1.10 * pressured["ta+o"].output_tok_per_s
        )

    def test_offloading_beats_non_offloading(self, pressured):
        assert pressured["ta+o"].output_tok_per_s > pressured["ta"].output_tok_per_s

    def test_program_aware_beats_request_level(self, pressured):
        assert pressured["ta"].output_tok_per_s > pressured["smg"].output_tok_per_s

    def test_mori_lowest_ttft(self, pressured):
        for other in ["ta+o", "ta", "smg"]:
            assert pressured["mori"].ttft_avg_s <= pressured[other].ttft_avg_s

    def test_mori_cache_hit_rate_highest(self, pressured):
        for other in ["ta+o", "ta", "smg"]:
            assert pressured["mori"].cache_hit_rate >= pressured[other].cache_hit_rate

    def test_no_pressure_all_equal(self):
        """Paper §6.2.1: at low concurrency offloading-capable systems tie."""
        corpus = generate_corpus(10, seed=3)
        hw = small_test_hw(hbm_bytes=800_000_000)  # fits everything
        outs = {}
        for sched in ["mori", "ta+o"]:
            _, outs[sched] = run(sched, conc=4, duration=200.0, hw=hw, corpus=corpus)
        ratio = outs["mori"].output_tok_per_s / max(1e-9, outs["ta+o"].output_tok_per_s)
        assert 0.95 <= ratio <= 1.05


class TestMultiReplica:
    def test_mori_affinity_low_churn(self):
        corpus = generate_corpus(30, seed=4)
        hw = small_test_hw(hbm_bytes=200_000_000)
        _, mori = run("mori", conc=8, replicas=3, duration=400.0, hw=hw, corpus=corpus)
        _, tao = run("ta+o", conc=8, replicas=3, duration=400.0, hw=hw, corpus=corpus)
        assert mori.switches_per_program <= tao.switches_per_program
        assert mori.churn_frac <= 0.15  # paper: 0.3-2.9% for MORI

    def test_load_spread_across_replicas(self):
        sim, _ = run("mori", conc=6, replicas=3, duration=200.0)
        busys = [rep.busy_accum for rep in sim.replicas]
        assert min(busys) > 0.25 * max(busys)


class TestFaultTolerance:
    def test_replica_failure_recovers_and_completes(self):
        corpus = generate_corpus(20, seed=5)
        # capacity sized so the survivor can absorb the failed replica's load
        hw = small_test_hw(hbm_bytes=500_000_000)
        faults = [FaultPlan(replica=1, fail_at=100.0, recover_at=150.0)]
        sim, r = run(
            "mori",
            conc=6,
            replicas=2,
            duration=400.0,
            hw=hw,
            corpus=corpus,
            faults=faults,
        )
        assert r.steps_completed > 100  # progress despite the failure
        # no program got stuck: every pending request eventually dispatched
        stuck = [
            p
            for p in sim.sched.programs.values()
            if p.has_pending and (sim.now - (p.pending_since or 0)) > 120.0
        ]
        assert not stuck
        # the recovered replica is serving again by the end of the run
        assert sim.replicas[1].busy_accum > 0

    def test_failed_replica_receives_no_new_programs(self):
        corpus = generate_corpus(20, seed=6)
        faults = [FaultPlan(replica=0, fail_at=50.0, recover_at=None)]
        sim, _ = run(
            "mori", conc=4, replicas=2, duration=300.0, corpus=corpus, faults=faults
        )
        rep0 = sim.sched.replicas[0]
        assert len(rep0.gpu) == 0

    def test_straggler_penalty_shifts_load(self):
        """Beyond-paper: with the penalty on, a slow replica gets less work."""
        corpus = generate_corpus(30, seed=7)
        hw = small_test_hw()
        placements = {}
        for penalty in [0.0, 5.0]:
            sim = Simulation(
                "mori",
                hw,
                corpus,
                num_replicas=2,
                concurrency_per_replica=6,
                duration_s=300.0,
                warmup_s=20.0,
                seed=0,
                sched_config=SchedulerConfig(straggler_penalty=penalty),
            )
            sim.sched.replicas[0].ewma_step_latency_s = 1.0  # replica 0 slow
            sim.sched.replicas[1].ewma_step_latency_s = 0.1
            sim.run()
            placements[penalty] = sim.replicas[0].busy_accum / max(
                1e-9, sim.replicas[1].busy_accum
            )
        assert placements[5.0] <= placements[0.0]
