"""Action IR, TransferLedger, and plan-protocol semantics.

Golden-sequence tests pin the *exact* action stream two schedulers emit on
a small scripted trace — the IR makes mock-call-order tests obsolete: a
plan is data, so a policy regression shows up as a diff against a literal.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
except ImportError:  # image without hypothesis: deterministic shim
    from _hypothesis_compat import given, settings, st

import pytest

from _plan_driver import Driver
from repro.core import (
    CancelTransfer,
    Channel,
    Discard,
    Forward,
    MoriScheduler,
    Offload,
    PlacementPlan,
    SCHEDULERS,
    SchedulerConfig,
    SetLabel,
    Status,
    TAOScheduler,
    Tier,
    TierCapacity,
    TransferLedger,
    TransferRecord,
    TypeLabel,
    action_from_json,
    action_to_json,
    plan_from_json,
)


# --------------------------------------------------------------------- IR
class TestActionIR:
    def test_actions_are_frozen(self):
        act = Forward(1, "a", 0, Tier.CPU, False, 128)
        with pytest.raises(Exception):
            act.replica = 3  # type: ignore[misc]

    def test_json_roundtrip_every_kind(self):
        acts = [
            Forward(1, "a", 0, Tier.SSD, False, 64),
            Offload(2, "a", 0, Tier.GPU, Tier.CPU, 64),
            Discard(3, "a", None, Tier.CPU),
            SetLabel(4, "a", 0, TypeLabel.IDLE),
            CancelTransfer(5, "a", 0, 2),
        ]
        for act in acts:
            assert action_from_json(action_to_json(act)) == act

    def test_plan_roundtrip_and_equality(self):
        plan = PlacementPlan(3.5, (Forward(1, "a", 0), Discard(2, "a", 0, Tier.GPU)))
        again = plan_from_json(plan.now, plan.to_json())
        assert again == plan
        assert len(plan) == 2 and bool(plan)
        assert plan.of_kind(Forward) == [plan.actions[0]]

    def test_plan_coalesces_superseded_labels(self):
        s = MoriScheduler(1, TierCapacity(1000, 1000), SchedulerConfig())
        p = s.program_arrived("a", 1, 0.0)
        s._set_label(p, TypeLabel.BUSY)
        s._set_label(p, TypeLabel.IDLE)
        s._set_label(p, TypeLabel.INACTIVE)
        plan = s._drain(0.0)
        labels = plan.of_kind(SetLabel)
        assert len(labels) == 1 and labels[0].label is TypeLabel.INACTIVE


# ----------------------------------------------------------------- ledger
class TestTransferLedger:
    def rec(self, aid, pid="a", replica=0, channel=Channel.PCIE, nbytes=100,
            kind="offload"):
        return TransferRecord(aid, pid, replica, kind, channel, nbytes,
                              Tier.GPU, Tier.CPU, 0.0)

    def test_open_complete_cycle(self):
        led = TransferLedger()
        led.open(self.rec(1))
        led.open(self.rec(2, channel=Channel.NVME, nbytes=50))
        assert led.in_flight_bytes(0, Channel.PCIE) == 100
        assert led.in_flight_bytes(0, Channel.NVME) == 50
        assert led.in_flight_bytes() == 150
        assert led.complete(1).nbytes == 100
        assert led.complete(1) is None  # double-ack tolerated
        assert led.completed == 1 and led.completed_bytes[Channel.PCIE] == 100
        assert len(led) == 1

    def test_cancel_and_drop(self):
        led = TransferLedger()
        led.open(self.rec(1, pid="a"))
        led.open(self.rec(2, pid="b", replica=1))
        led.open(self.rec(3, pid="b", replica=1, kind="reload"))
        assert led.open_offload("a").action_id == 1
        assert led.cancel(1) is not None
        assert led.open_offload("a") is None
        dropped = led.drop_replica(1)
        assert {r.action_id for r in dropped} == {2, 3}
        assert len(led) == 0

    def test_drop_pid(self):
        led = TransferLedger()
        led.open(self.rec(1, pid="a"))
        led.open(self.rec(2, pid="b"))
        assert [r.pid for r in led.drop_pid("a")] == ["a"]
        assert len(led) == 1


# ------------------------------------------------------- golden sequences
def _drive_trace(sched_name: str) -> list[dict]:
    """Replay one fixed 2-program script and return the serialized stream:
    p0 runs a step and overflows the GPU during its tool call, p1 takes its
    place, capacity scales up, p0 returns."""
    d = Driver(SCHEDULERS[sched_name](
        1, TierCapacity(100, 1000), SchedulerConfig(tick_interval_s=5.0)
    ))
    d.program_arrived("p0", 1, 0.0)
    d.request_arrived("p0", 60, 0.0)           # admit + first step
    d.notify_inference_started("p0", 0.0)
    d.request_completed("p0", 50, 1.0)         # p0 -> 110 bytes: overflow
    d.tick(5.0)
    d.ack_all(5.0)                             # demotion transfer lands
    d.program_arrived("p1", 1, 6.0)
    d.request_arrived("p1", 80, 6.0)           # p1 takes the freed HBM
    d.notify_inference_started("p1", 6.0)
    d.request_completed("p1", 5, 7.0)          # p1 acting, 85 bytes
    d.sched.replicas[0].capacity = TierCapacity(250, 1000)  # scale-up
    d.request_arrived("p0", 115, 40.0)         # p0 returns from its tool call
    d.tick(45.0)
    d.ack_all(45.0)
    return [action_to_json(a) for a in d.actions]


def test_golden_sequence_mori():
    """MORI: scheduler-coordinated offload with typed labels, then an
    affinity-preserving reload on return — byte-for-byte pinned stream."""
    assert _drive_trace("mori") == [
        {"action_id": 1, "pid": "p0", "replica": 0, "label": "busy",
         "kind": "SetLabel"},
        {"action_id": 2, "pid": "p0", "replica": 0, "source_tier": "waiting",
         "recompute": True, "nbytes": 0, "kind": "Forward"},
        # growth overflow: the acting p0 demotes GPU -> CPU, restamped idle
        {"action_id": 3, "pid": "p0", "replica": 0, "src_tier": "gpu",
         "dst_tier": "cpu", "nbytes": 110, "kind": "Offload"},
        {"action_id": 4, "pid": "p0", "replica": 0, "label": "idle",
         "kind": "SetLabel"},
        {"action_id": 5, "pid": "p1", "replica": 0, "label": "busy",
         "kind": "SetLabel"},
        {"action_id": 6, "pid": "p1", "replica": 0, "source_tier": "waiting",
         "recompute": True, "nbytes": 0, "kind": "Forward"},
        # p0 returns: affinity-preserving CPU -> GPU promotion; the reload
        # moves exactly the 110 materialized bytes, not the grown context
        {"action_id": 7, "pid": "p0", "replica": 0, "label": "busy",
         "kind": "SetLabel"},
        {"action_id": 8, "pid": "p0", "replica": 0, "source_tier": "cpu",
         "recompute": False, "nbytes": 110, "kind": "Forward"},
    ]


def test_golden_sequence_tao():
    """TA+O on the same script: no typed labels, spill via uncoordinated
    HiCache, reload only because routing happened to pick replica 0."""
    assert _drive_trace("ta+o") == [
        {"action_id": 1, "pid": "p0", "replica": 0, "source_tier": "waiting",
         "recompute": True, "nbytes": 0, "kind": "Forward"},
        {"action_id": 2, "pid": "p0", "replica": 0, "src_tier": "gpu",
         "dst_tier": "cpu", "nbytes": 110, "kind": "Offload"},
        {"action_id": 3, "pid": "p1", "replica": 0, "source_tier": "waiting",
         "recompute": True, "nbytes": 0, "kind": "Forward"},
        {"action_id": 4, "pid": "p0", "replica": 0, "source_tier": "cpu",
         "recompute": False, "nbytes": 110, "kind": "Forward"},
    ]


# ------------------------------------------------------ cancel semantics
class TestCancelOnEarlyReturn:
    def _offloaded(self):
        d = Driver(MoriScheduler(1, TierCapacity(1000, 1000), SchedulerConfig()))
        d.program_arrived("a", 1, 0.0)
        d.request_arrived("a", 100, 0.0)
        d.notify_inference_started("a", 0.0)
        d.request_completed("a", 10, 1.0)
        d.sched.replicas[0].capacity = TierCapacity(10, 1000)
        d.tick(5.0)  # offload emitted, NOT acknowledged yet
        d.sched.replicas[0].capacity = TierCapacity(1000, 1000)
        assert d.programs["a"].tier is Tier.CPU
        return d

    def test_early_return_cancels_inflight_offload(self):
        d = self._offloaded()
        off = d.of_kind(Offload)[-1]
        plan = d.request_arrived("a", 110, 6.0)
        cancels = plan.of_kind(CancelTransfer)
        assert len(cancels) == 1 and cancels[0].target_action_id == off.action_id
        # re-admitted warm: no reload, no recompute
        fwd = plan.of_kind(Forward)[-1]
        assert fwd.source_tier is Tier.GPU and not fwd.recompute
        assert d.programs["a"].tier is Tier.GPU
        assert d.programs["a"].metrics.cancelled_offloads == 1
        assert len(d.sched.ledger) == 0
        d.sched.replicas[0].check()

    def test_late_return_reloads_normally(self):
        d = self._offloaded()
        d.ack_all(5.0)  # transfer completed before the tool returned
        plan = d.request_arrived("a", 110, 6.0)
        assert not plan.of_kind(CancelTransfer)
        fwd = plan.of_kind(Forward)[-1]
        assert fwd.source_tier is Tier.CPU
        assert d.programs["a"].tier is Tier.GPU

    def test_stale_ack_after_cancel_is_ignored(self):
        d = self._offloaded()
        off = d.of_kind(Offload)[-1]
        d.request_arrived("a", 110, 6.0)  # cancels
        plan = d.on_transfer_complete("a", off.action_id, 6.5)  # stale
        assert len(plan) == 0
        assert d.sched.ledger.completed == 0


# ------------------------------------------------- ack-interleaving property
@given(
    seed=st.integers(0, 10_000),
    n_programs=st.integers(2, 6),
    gpu=st.integers(60, 300),
    cpu=st.integers(0, 300),
    ack_delay=st.integers(0, 6),
)
@settings(max_examples=50, deadline=None)
def test_property_ack_interleaving_never_double_admits(
    seed, n_programs, gpu, cpu, ack_delay
):
    """Any interleaving of transfer acknowledgements — delayed, reordered,
    replayed against finished programs — never lands a program's bytes in
    two tiers at once, and the ledger never resurrects closed records."""
    import random

    rng = random.Random(seed)
    d = Driver(MoriScheduler(1, TierCapacity(gpu, cpu), SchedulerConfig()))
    t = 0.0
    active = {}
    pending_acks: list[tuple[str, int]] = []
    for i in range(n_programs):
        pid = f"p{i}"
        d.program_arrived(pid, 1, t)
        active[pid] = 10 + rng.randrange(30)

    def stage_acks():
        for rec in d.sched.ledger.in_flight():
            if (rec.pid, rec.action_id) not in pending_acks:
                pending_acks.append((rec.pid, rec.action_id))

    for _ in range(60):
        pid = rng.choice(list(active))
        prog = d.programs[pid]
        if prog.status is Status.ACTING and not prog.has_pending:
            active[pid] += rng.randrange(15)
            d.request_arrived(pid, active[pid], t)
        elif prog.status is Status.GATED and prog.tier is Tier.GPU:
            d.notify_inference_started(pid, t)
        elif prog.status is Status.REASONING:
            out = rng.randrange(1, 10)
            active[pid] += out
            d.request_completed(pid, out, t)
        t += rng.random() * 5
        if rng.random() < 0.3:
            d.tick(t)
        stage_acks()
        # deliver a random subset of pending acks, in shuffled order
        rng.shuffle(pending_acks)
        while pending_acks and rng.random() > ack_delay / 10.0:
            apid, aid = pending_acks.pop()
            d.on_transfer_complete(apid, aid, t)
        # invariants: exact accounting + tier exclusivity
        for rep in d.sched.replicas:
            rep.check()
        placements = [
            set(d.sched.replicas[0].gpu),
            set(d.sched.replicas[0].cpu),
            set(d.sched.replicas[0].ssd),
            set(d.sched.waiting.programs),
        ]
        for i, a in enumerate(placements):
            for b in placements[i + 1:]:
                assert not (a & b)
        # a ledger record always refers to a live program's single placement
        for rec in d.sched.ledger.in_flight():
            assert rec.pid in d.sched.programs
    # drain every remaining ack (plus stale duplicates) — still consistent
    stage_acks()
    for apid, aid in pending_acks + pending_acks:
        d.on_transfer_complete(apid, aid, t)
    for rep in d.sched.replicas:
        rep.check()


# ----------------------------------------------------------- migration IR
class TestMigrate:
    def _stuck_setup(self):
        d = Driver(MoriScheduler(
            2, TierCapacity(100, 200),
            SchedulerConfig(migrate_on_pressure=True, eager_promote=False),
        ))
        # hog fills one replica's GPU and stays Reasoning (not displaceable)
        d.program_arrived("hog", 1, 0.0)
        d.request_arrived("hog", 95, 0.0)
        d.tick(0.5)  # eager_promote off: admission happens on the tick
        rep0 = d.programs["hog"].replica
        d.notify_inference_started("hog", 0.5)
        # stuck lives on the same replica's CPU tier with a pending request
        d.program_arrived("stuck", 1, 0.0)
        stuck = d.programs["stuck"]
        d.sched.waiting.remove(stuck)
        stuck.context_tokens = 50
        stuck.materialized_tokens = 50
        d.sched.replicas[rep0].cpu_admit(stuck)
        d.request_arrived("stuck", 50, 1.0)
        return d, stuck, rep0

    def test_migrate_promotion_deferred_until_ack(self):
        """The promotion (a reload Forward of the same bytes) must wait for
        the migrate's on_transfer_complete — emitting it while the migrate
        record is open would double-bill the PCIe channel and forward KV
        that has not landed on the destination (regression)."""
        from repro.core import Migrate

        d, stuck, rep0 = self._stuck_setup()
        plan = d.tick(2.0)
        migs = plan.of_kind(Migrate)
        assert len(migs) == 1
        assert migs[0].src_replica == rep0 and migs[0].dst_replica != rep0
        assert stuck.replica == migs[0].dst_replica
        # the DRAM copy is still in flight: no promotion, no reload Forward
        assert stuck.tier is Tier.CPU
        assert not [f for f in plan.of_kind(Forward) if f.pid == "stuck"]
        rec = d.sched.ledger.open_migrate("stuck")
        assert rec is not None and rec.action_id == migs[0].action_id
        assert d.sched.ledger.in_flight_bytes(replica=migs[0].dst_replica) == 50
        # further ticks while the migrate is open must not promote either
        plan2 = d.tick(3.0)
        assert not [f for f in plan2.of_kind(Forward) if f.pid == "stuck"]
        assert len(d.sched.ledger.in_flight(kind="migrate")) == 1
        # ack lands the bytes: the deferred promotion opens its reload now
        plan3 = d.on_transfer_complete("stuck", migs[0].action_id, 4.0)
        assert d.sched.ledger.open_migrate("stuck") is None
        fwd = [f for f in plan3.of_kind(Forward) if f.pid == "stuck"]
        assert len(fwd) == 1 and fwd[0].source_tier is Tier.CPU
        assert stuck.tier is Tier.GPU
        # exactly one transfer open now: the reload billed after the move
        reloads = d.sched.ledger.in_flight(kind="reload")
        assert [r.pid for r in reloads] == ["stuck"]
        for rep in d.sched.replicas:
            rep.check()

    def test_migration_off_by_default(self):
        d = Driver(MoriScheduler(2, TierCapacity(100, 200), SchedulerConfig()))
        assert d.sched.config.migrate_on_pressure is False

    def test_router_rejects_migration_config(self):
        pytest.importorskip("jax")
        from repro.serving.router import MoriRouter

        with pytest.raises(ValueError, match="migrate_on_pressure"):
            MoriRouter([_FakeEngine()], config=SchedulerConfig(migrate_on_pressure=True))


class _FakeEngine:
    """Just enough surface for MoriRouter.__init__'s capacity probe."""

    class cfg:
        num_layers = 2
        num_kv_heads = 2
        head_dim = 8

    class pool:
        n_device_pages = 4
        n_host_pages = 4
        page_bytes = 1024


def test_sim_executes_migration_end_to_end():
    """Simulator smoke: migration enabled completes a run and actually
    migrates under per-replica pressure."""
    from repro.sim import Simulation, small_test_hw
    from repro.traces import generate_corpus

    corpus = generate_corpus(20, seed=3)
    hw = small_test_hw(hbm_bytes=120_000_000)
    sim = Simulation(
        "mori", hw, corpus, num_replicas=2, concurrency_per_replica=8,
        duration_s=200.0, warmup_s=20.0, seed=0,
        sched_config=SchedulerConfig(migrate_on_pressure=True),
    )
    r = sim.run()
    assert r.steps_completed > 50
    for rep in sim.sched.replicas:
        rep.check()


def test_tao_offload_is_ledger_tracked():
    d = Driver(TAOScheduler(1, TierCapacity(100, 1000), SchedulerConfig()))
    d.program_arrived("a", 1, 0.0)
    d.request_arrived("a", 60, 0.0)
    d.notify_inference_started("a", 0.0)
    d.request_completed("a", 50, 1.0)  # grows past capacity: HiCache spill
    offs = d.of_kind(Offload)
    assert offs and offs[-1].pid == "a"
    assert d.sched.ledger.open_offload("a") is not None
    d.ack_all(2.0)
    assert len(d.sched.ledger) == 0
