"""DESIGN.md §Arch-applicability: MORI on SSM/hybrid state.

Mamba2's per-program serving state is O(1) in sequence length (~constant
SSM + conv state), so MORI's admission control degenerates to
trivially-admit at realistic concurrency — while a dense arch of the same
scale saturates the same GPU budget. The scheduler code is identical; only
the per-program byte accounting differs.
"""
from __future__ import annotations

from repro.configs import get_config
from repro.core import SCHEDULERS, SchedulerConfig, TierCapacity
from repro.core.types import Tier
from repro.models import Model
from repro.models.params import is_leaf


def _state_bytes(cfg, seq_len: int) -> int:
    """Per-program serving-state bytes at a given context length."""
    m = Model(cfg)
    tree = m.describe_cache(1, seq_len)
    total = 0
    for leaf in (l for l in _leaves(tree)):
        n = 1
        for d in leaf.shape:
            n *= d
        total += n * 2  # bf16
    return total


def _leaves(tree):
    import jax

    return jax.tree.leaves(tree, is_leaf=is_leaf)


def test_ssm_state_is_o1_in_seq_len():
    cfg = get_config("mamba2-2.7b")
    assert _state_bytes(cfg, 4096) == _state_bytes(cfg, 524_288)


def test_dense_state_is_linear_in_seq_len():
    cfg = get_config("internlm2-20b")
    b4k, b32k = _state_bytes(cfg, 4096), _state_bytes(cfg, 32_768)
    assert abs(b32k / b4k - 8.0) < 0.01


def test_ssm_state_tiny_vs_dense_kv():
    """Paper-motivating ratio: ~MBs of SSM state vs ~GBs of 32k dense KV."""
    ssm = _state_bytes(get_config("mamba2-2.7b"), 32_768)
    dense = _state_bytes(get_config("internlm2-20b"), 32_768)
    assert dense / ssm > 50


def _drive(kv_bytes_per_token, n_programs, gpu_bytes):
    """Admit n programs with 8k contexts; return how many were demoted."""
    sched = SCHEDULERS["mori"](
        1, TierCapacity(gpu_bytes, gpu_bytes),
        SchedulerConfig(tick_interval_s=1.0),
    )
    for i in range(n_programs):
        pid = f"p{i}"
        sched.program_arrived(pid, kv_bytes_per_token, now=0.0)
        sched.request_arrived(pid, input_tokens=8192, now=float(i) * 0.01)
    sched.tick(1.0)
    tiers = [p.tier for p in sched.programs.values()]
    return sum(1 for t in tiers if t is not Tier.GPU)


def test_mori_admission_trivial_for_ssm_heavy_for_dense():
    """Same scheduler, same 8 GiB GPU budget, 64 programs at 8k context:
    dense KV (192 KiB/token -> 1.6 GiB/program) must demote; mamba2's O(1)
    state (~82 MiB/program regardless of context) admits everything."""
    gpu = 8 << 30
    dense_per_token = 196_608                # internlm2: 48L*2*8KH*128hd*2B
    ssm_state = _state_bytes(get_config("mamba2-2.7b"), 8192)
    ssm_per_token = max(1, ssm_state // 8192)
    demoted_dense = _drive(dense_per_token, 64, gpu)
    demoted_ssm = _drive(ssm_per_token, 64, gpu)
    assert demoted_dense > 0
    assert demoted_ssm == 0
