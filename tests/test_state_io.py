"""Serving control-plane snapshot/restore (fault tolerance)."""
from __future__ import annotations

import json

import pytest

from repro.configs import get_config
from repro.core.idleness import IdlenessTracker
from repro.core.scheduler import SchedulerConfig
from repro.core.types import Status, Tier, TypeLabel
from repro.models import Model, materialize
from repro.serving import Engine, MoriRouter
from repro.serving.state_io import restore_snapshot, save_snapshot


@pytest.fixture(scope="module")
def cfg_params():
    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    return cfg, params


def _router(cfg, params, replicas=1):
    engines = [
        Engine(cfg, params, page_tokens=16, n_device_pages=64,
               n_host_pages=96, max_slots=2, max_seq=320)
        for _ in range(replicas)
    ]
    return MoriRouter(engines, scheduler="mori",
                      config=SchedulerConfig(tick_interval_s=2.0))


def _mid_flight(router, n=3, replicas=1):
    """Programs in assorted tiers, as during live serving."""
    sched = router.sched
    tiers = [Tier.GPU, Tier.CPU, Tier.NONE]
    for i in range(n):
        p = sched.program_arrived(f"prog-{i}", 4096, now=float(i))
        p.context_tokens = 100 * (i + 1)
        p.steps_completed = i
        p.tier = tiers[i % 3]
        p.replica = i % replicas if p.tier is not Tier.NONE else None
        p.label = [TypeLabel.BUSY, TypeLabel.IDLE, TypeLabel.INACTIVE][i % 3]
        p.tracker.transition(Status.REASONING, float(i))
        p.tracker.transition(Status.ACTING, float(i) + 0.5)
    return sched


def test_snapshot_roundtrip(cfg_params, tmp_path):
    cfg, params = cfg_params
    router = _router(cfg, params)
    _mid_flight(router, n=3)
    p = save_snapshot(router, tmp_path / "state.json")
    snap = json.loads(p.read_text())
    assert snap["version"] == 3
    assert len(snap["programs"]) == 3
    # v2: per-replica tier usage + decode-slot occupancy (idle here)
    assert len(snap["replicas"]) == 1
    assert snap["replicas"][0]["slots"] == []
    # v3: tier formats ride along (bf16 fleet -> bf16 everywhere, and the
    # per-program wire size collapses to None = device size)
    assert snap["replicas"][0]["device_format"] == "bf16"
    assert snap["replicas"][0]["offload_format"] == "bf16"
    assert all(
        rec["wire_bytes_per_token"] is None
        for rec in snap["programs"].values()
    )

    router2 = _router(cfg, params)
    counters = restore_snapshot(router2, p)
    assert counters["restored"] == 3
    for pid, prog in router2.sched.programs.items():
        ref = snap["programs"][pid]
        assert prog.context_tokens == ref["context_tokens"]
        assert prog.steps_completed == ref["steps_completed"]
        assert prog.label.value == ref["label"]
        assert prog.tier is Tier.NONE          # conservative re-queue
        assert prog.replica is None


def test_finished_programs_not_requeued(cfg_params, tmp_path):
    cfg, params = cfg_params
    router = _router(cfg, params)
    sched = _mid_flight(router, n=2)
    sched.programs["prog-0"].finished = True
    p = save_snapshot(router, tmp_path / "f.json")

    router2 = _router(cfg, params)
    counters = restore_snapshot(router2, p)
    assert counters["restored"] == 1
    assert "prog-0" not in router2.sched.programs


def test_snapshot_atomic(cfg_params, tmp_path):
    cfg, params = cfg_params
    router = _router(cfg, params)
    _mid_flight(router, n=2)
    p = tmp_path / "state.json"
    save_snapshot(router, p)
    first = p.read_text()
    save_snapshot(router, p)               # overwrite is atomic, not append
    assert json.loads(p.read_text()) == json.loads(first)
    assert not (tmp_path / "state.json.tmp").exists()


def test_restore_onto_fewer_replicas(cfg_params, tmp_path):
    """A snapshot from 3 replicas restores onto 1 (elastic failover)."""
    cfg, params = cfg_params
    router3 = _router(cfg, params, replicas=3)
    _mid_flight(router3, n=5, replicas=3)
    p = save_snapshot(router3, tmp_path / "s3.json")

    router1 = _router(cfg, params, replicas=1)
    counters = restore_snapshot(router1, p)
    assert counters["restored"] == 5
    for prog in router1.sched.programs.values():
        assert prog.replica is None


def test_snapshot_and_router_snapshot_state_share_one_schema(cfg_params, tmp_path):
    """Regression for the duplicated control-plane serializers: the
    router-level ``snapshot_state`` and ``state_io.save_snapshot`` used to
    build overlapping dicts independently — now both come from
    ``control_plane_state`` and are byte-identical."""
    from repro.serving import snapshot_state
    from repro.serving.state_io import control_plane_state

    cfg, params = cfg_params
    router = _router(cfg, params)
    _mid_flight(router, n=3)
    p = save_snapshot(router, tmp_path / "one.json")
    assert json.loads(p.read_text()) == snapshot_state(router)
    assert snapshot_state(router) == control_plane_state(router)


def test_restore_accepts_v1_snapshots(cfg_params, tmp_path):
    """Snapshots written before the per-slot occupancy section restore."""
    cfg, params = cfg_params
    router = _router(cfg, params)
    _mid_flight(router, n=2)
    snap = json.loads(save_snapshot(router, tmp_path / "v2.json").read_text())
    snap["version"] = 1
    snap.pop("replicas")
    v1 = tmp_path / "v1.json"
    v1.write_text(json.dumps(snap))

    router2 = _router(cfg, params)
    counters = restore_snapshot(router2, v1)
    assert counters["restored"] == 2
    assert counters["was_resident"] == 0


def test_restore_under_load(cfg_params, tmp_path):
    """Snapshot taken while programs are resident in decode slots: the
    occupancy section names them, and restore conservatively requeues them
    as Waiting with their control-plane state intact (their mid-flight
    step re-issues after recompute, like a replica failure)."""
    from repro.core.types import ProgramTrace, RequestRecord

    cfg, params = cfg_params
    router = _router(cfg, params)
    # long reasoning walls so the t=2 control tick lands mid-decode with
    # both programs batched in slots
    traces = [
        ProgramTrace(f"p{i}", [
            RequestRecord(40 + 8 * i, 4, 1.0, reasoning_wall_s=10.0),
            RequestRecord(70 + 8 * i, 4, 0.0, reasoning_wall_s=1.0),
        ])
        for i in range(2)
    ]
    path = tmp_path / "load.json"
    real_tick = router.sched.tick

    def snapshotting_tick(now):
        plan = real_tick(now)
        if not path.exists() and router._pump_slots[0]:
            save_snapshot(router, path)
        return plan

    router.sched.tick = snapshotting_tick
    router.replay(traces, vocab_size=cfg.vocab_size, max_new_tokens=4)
    assert path.exists(), "no tick landed while decode slots were live"
    snap = json.loads(path.read_text())
    live = [s["pid"] for s in snap["replicas"][0]["slots"]]
    assert sorted(live) == ["p0", "p1"]
    for s in snap["replicas"][0]["slots"]:
        assert s["window_end"] > s["started_at"]

    router2 = _router(cfg, params)
    counters = restore_snapshot(router2, path)
    assert counters["restored"] == 2
    assert counters["was_resident"] == 2
    for pid in live:
        prog = router2.sched.programs[pid]
        assert prog.tier is Tier.NONE and prog.replica is None
        assert prog.context_tokens > 0


def test_tracker_window_roundtrip():
    t = IdlenessTracker(window=3)
    t.transition(Status.REASONING, 0.0)
    t.transition(Status.ACTING, 1.0)
    t.transition(Status.REASONING, 3.0)      # cycle: 1s reasoning / 2s acting
    t.transition(Status.ACTING, 4.0)
    dump = t.window_dump()

    t2 = IdlenessTracker(window=3)
    t2.window_load(dump)
    # same window contents -> same idleness estimate at a fresh instant
    assert abs(t2.idleness(0.0) - t.idleness(4.0)) < 0.35
    assert t2.status is Status.ACTING
