"""Dense-slot vs block-table decode throughput on the real engine.

The tentpole's perf claim, measured: serve an agentic multi-round workload
(every round extends each program's context with its own outputs plus tool
tokens, so the radix cache is hot) through the same reduced model twice —
once over the ``dense_slots=True`` compatibility path (gather prefix →
concatenate → slot write → decode over ``max_seq`` slots → copy full pages
back at finish) and once over the block-table path (reference prefix pages,
append to tail pages in place, paged-attention over just the live pages,
zero-copy finish). Sweeps batch size; writes
``artifacts/BENCH_paged_decode.json`` so CI tracks the speedup and asserts
block-table decode is not slower from batch 8 up.
"""
from __future__ import annotations

import os
import time

from benchmarks.common import emit

BATCHES = tuple(
    int(b) for b in os.environ.get("BENCH_PAGED_BATCHES", "1,2,4,8").split(",")
)
ROUNDS = int(os.environ.get("BENCH_PAGED_ROUNDS", "6"))
WARMUP_ROUNDS = 2
# serving-realistic shape: slots provisioned for a long max_seq while the
# live contexts stay well below it — the dense path must attend over (and
# copy through) the full slot depth, the block-table path only touches the
# pages that exist. Coarse pages keep the count of distinct chunked-prefill
# shapes (and so eager-scan recompiles, identical in both modes) low.
NEW_TOKENS = 32
INIT_CTX = 48
MAX_SEQ = 512
PAGE_TOKENS = 32


def _run_mode(dense: bool, batch: int, cfg, params) -> dict:
    import numpy as np

    from repro.serving import Engine, EngineRequest

    eng = Engine(
        cfg, params,
        page_tokens=PAGE_TOKENS,
        n_device_pages=batch * 18 + 16,
        n_host_pages=8,
        max_slots=batch,
        max_seq=MAX_SEQ,
        dense_slots=dense,
    )
    eng.warmup()  # jit every decode bucket outside the timed region
    rng = np.random.default_rng(0)
    ctxs = [
        list(rng.integers(2, cfg.vocab_size, size=INIT_CTX + i))
        for i in range(batch)
    ]

    def round_once() -> tuple[float, float]:
        t_submit = t_decode = 0.0
        t0 = time.perf_counter()
        for i in range(batch):
            eng.submit(
                EngineRequest(f"p{i}", list(ctxs[i]), max_new_tokens=NEW_TOKENS)
            )
        t1 = time.perf_counter()
        done = eng.run_to_completion()
        t2 = time.perf_counter()
        t_submit += t1 - t0
        t_decode += t2 - t1
        for comp in done:
            i = int(comp.program_id[1:])
            ctxs[i].extend(comp.output_tokens[:-1])
            ctxs[i].extend(int(t) for t in rng.integers(2, cfg.vocab_size, size=2))
        return t_submit, t_decode

    for _ in range(WARMUP_ROUNDS):
        round_once()
    submit_s = decode_s = 0.0
    rounds = 0
    t_start = time.perf_counter()
    for _ in range(ROUNDS):
        if max(len(c) for c in ctxs) + NEW_TOKENS > MAX_SEQ:
            break  # context would overflow max_seq; stop the sweep early
        ts, td = round_once()
        submit_s += ts
        decode_s += td
        rounds += 1
    elapsed = time.perf_counter() - t_start
    toks = batch * rounds * NEW_TOKENS
    return {
        "mode": "dense-slots" if dense else "block-table",
        "batch": batch,
        "rounds": rounds,
        "tok_per_s": round(toks / elapsed, 2),
        "decode_tok_per_s": round(toks / decode_s, 2),
        "req_per_s": round(batch * rounds / elapsed, 2),
        "submit_s": round(submit_s, 3),
        "decode_s": round(decode_s, 3),
        "decode_steps": eng.steps,
    }


def main() -> list[dict]:
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    rows = []
    for batch in BATCHES:
        for dense in (True, False):
            rows.append(_run_mode(dense, batch, cfg, params))
    by_batch = {b: {} for b in BATCHES}
    for r in rows:
        by_batch[r["batch"]][r["mode"]] = r["tok_per_s"]
    for b, modes in by_batch.items():
        speedup = modes["block-table"] / max(modes["dense-slots"], 1e-9)
        print(f"batch {b}: block-table {speedup:.2f}x dense-slot throughput")
    emit(rows, "BENCH_paged_decode.json")
    return rows


if __name__ == "__main__":
    main()
