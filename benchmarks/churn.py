"""Paper §6.2.2 churn table: fraction of programs switching backends and
switches/program under DP=3 (MORI's CPU-tier residency tracking vs the
offloading-agnostic baselines)."""
from __future__ import annotations

from benchmarks.common import SCHEDS, emit, run_sim


def main() -> list[dict]:
    rows = []
    paper = {  # (churn_frac_range, switches_per_program) at 20/prog, §6.2.2
        "mori": "0.3-2.9% / 0.00-0.04",
        "ta+o": "14-15% / 0.35-0.38",
        "ta": "14-15% / 0.35-0.38",
        "smg": "(prefix-fragile)",
    }
    for conc in (20, 80):
        for sched in SCHEDS:
            _, r = run_sim(
                sched, "h200-qwen3-30b-a3b", conc=conc, cpu_ratio=2.0, replicas=3
            )
            rows.append(
                {
                    "table": "churn",
                    "concurrency_per_replica": conc,
                    "scheduler": sched,
                    "churn_frac": round(r.churn_frac, 4),
                    "switches_per_program": round(r.switches_per_program, 4),
                    "paper_at_20": paper[sched],
                }
            )
    emit(rows, "churn.json")
    return rows


if __name__ == "__main__":
    main()
