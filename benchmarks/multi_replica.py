"""Paper Fig. 10 + §6.2.2: DP=3 multi-replica scheduling — throughput, TTFT,
GPU utilization, and backend-affinity churn.

Two halves:

- :func:`main` — the simulator sweep behind the paper figure (DP=3,
  concurrency × CPU-ratio grid, all schedulers).
- :func:`real_main` — real-router scale-out smoke on actual ``Engine``
  replicas: the same agentic corpus replayed at N=1, N=2, and N=2 with a
  mid-replay replica failure (live drain + requeue, recovery later).
  Throughput is virtual-clock (``tokens / makespan_s``) so the N=2 > N=1
  gate is deterministic — both engines share one host, wall-clock would
  measure the machine, not the scale-out. The failure row also reports
  ``lost_tokens`` against the undisturbed N=2 run's token streams;
  CI gates it at exactly zero. Writes ``artifacts/BENCH_multi_replica.json``.
"""
from __future__ import annotations

from benchmarks.common import SCHEDS, emit, run_sim

#: real-path replay shape: programs > one replica's decode slots, so a
#: single replica has to queue what two replicas run concurrently
REAL_PROGRAMS = 4
REAL_MAX_NEW_TOKENS = 4
#: mid-decode failure window (virtual seconds) for the failover row:
#: fail while decode slots are live on the victim so the drain genuinely
#: requeues in-flight work (requeued_slots > 0 in the emitted row)
FAIL_AT, RECOVER_AT = 5.0, 65.0


def main(concs=(20, 50, 80), ratios=(1.0, 2.0)) -> list[dict]:
    rows = []
    for ratio in ratios:
        for conc in concs:
            for sched in SCHEDS:
                _, r = run_sim(
                    sched, "h200-qwen3-30b-a3b", conc=conc, cpu_ratio=ratio,
                    replicas=3,
                )
                rows.append(
                    {
                        "figure": "fig10",
                        "cpu_ratio": ratio,
                        "concurrency_per_replica": conc,
                        "scheduler": sched,
                        "tok_per_s": round(r.output_tok_per_s, 1),
                        "ttft_avg_s": round(r.ttft_avg_s, 2),
                        "gpu_util": round(r.gpu_util, 3),
                        "churn_frac": round(r.churn_frac, 4),
                        "switches_per_program": round(r.switches_per_program, 4),
                    }
                )
    emit(rows, "fig10_multi_replica.json")
    return rows


def _real_corpus():
    from repro.traces import TraceGenConfig, generate_corpus

    tg = TraceGenConfig(
        min_steps=3, mean_steps=4, max_steps=4,
        initial_context_mean=700, max_context=1800,
        long_median_s=20.0, busy_calls_mean=2.0, idle_calls_mean=2.0,
    )
    return generate_corpus(REAL_PROGRAMS, seed=5, cfg=tg)


def _real_replay(cfg, params, n_replicas: int, faults=None):
    from repro.core import SchedulerConfig
    from repro.core.types import TransferCost
    from repro.serving import Engine, MoriRouter

    engines = [
        Engine(cfg, params, page_tokens=8, n_device_pages=96,
               n_host_pages=96, max_slots=2, max_seq=320)
        for _ in range(n_replicas)
    ]
    router = MoriRouter(
        engines, scheduler="mori",
        gpu_capacity_bytes=500_000,
        config=SchedulerConfig(tick_interval_s=2.0),
        xfer_cost=TransferCost(pcie_bytes_per_s=2e5),
    )
    m = router.replay(_real_corpus(), vocab_size=cfg.vocab_size,
                      max_new_tokens=REAL_MAX_NEW_TOKENS, faults=faults)
    return router, m


def _lost_tokens(clean_log: dict, fault_log: dict) -> int:
    """Tokens the clean run produced that the fault run dropped or changed."""
    return sum(
        len(toks)
        - sum(1 for a, b in zip(toks, fault_log.get(pid, [])) if a == b)
        for pid, toks in clean_log.items()
    )


def real_main() -> list[dict]:
    from repro.configs import get_config
    from repro.models import Model, materialize
    from repro.sim.engine import FaultPlan

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)

    def row(label, m, *, lost_tokens=None):
        r = {
            "mode": label,
            "tok_per_s": round(m.tokens_generated / m.makespan_s, 2),
            "makespan_s": round(m.makespan_s, 1),
            "tokens": m.tokens_generated,
            "steps": m.steps_completed,
            "ttft_p50_s": round(m.ttft_s["p50"], 3),
            "drain_events": m.drain_events,
            "requeued_slots": m.requeued_slots,
            "migrations": m.migrations,
            "migrated_pages": m.migrated_pages,
            "placement_reasons": dict(m.placement_reasons),
        }
        if lost_tokens is not None:
            r["lost_tokens"] = lost_tokens
        return r

    _, m1 = _real_replay(cfg, params, 1)
    clean_router, m2 = _real_replay(cfg, params, 2)
    fault_router, mf = _real_replay(
        cfg, params, 2,
        faults=[FaultPlan(replica=1, fail_at=FAIL_AT, recover_at=RECOVER_AT)],
    )
    rows = [
        row("n1", m1),
        row("n2", m2),
        row(
            "n2-one-failure", mf,
            lost_tokens=_lost_tokens(
                clean_router.output_log, fault_router.output_log
            ),
        ),
    ]
    emit(rows, "BENCH_multi_replica.json")
    for r in rows:
        extra = (
            f", drains {r['drain_events']}, requeued {r['requeued_slots']}, "
            f"lost tokens {r['lost_tokens']}"
            if "lost_tokens" in r
            else ""
        )
        print(
            f"{r['mode']}: {r['tok_per_s']} tok/s over {r['makespan_s']}s "
            f"virtual ({r['tokens']} tokens, TTFT p50 {r['ttft_p50_s']}s"
            f"{extra})"
        )
    return rows


if __name__ == "__main__":
    main()
