"""Paper Fig. 10 + §6.2.2: DP=3 multi-replica scheduling — throughput, TTFT,
GPU utilization, and backend-affinity churn."""
from __future__ import annotations

from benchmarks.common import SCHEDS, emit, run_sim


def main(concs=(20, 50, 80), ratios=(1.0, 2.0)) -> list[dict]:
    rows = []
    for ratio in ratios:
        for conc in concs:
            for sched in SCHEDS:
                _, r = run_sim(
                    sched, "h200-qwen3-30b-a3b", conc=conc, cpu_ratio=ratio,
                    replicas=3,
                )
                rows.append(
                    {
                        "figure": "fig10",
                        "cpu_ratio": ratio,
                        "concurrency_per_replica": conc,
                        "scheduler": sched,
                        "tok_per_s": round(r.output_tok_per_s, 1),
                        "ttft_avg_s": round(r.ttft_avg_s, 2),
                        "gpu_util": round(r.gpu_util, 3),
                        "churn_frac": round(r.churn_frac, 4),
                        "switches_per_program": round(r.switches_per_program, 4),
                    }
                )
    emit(rows, "fig10_multi_replica.json")
    return rows


if __name__ == "__main__":
    main()
