"""Benchmark orchestrator — one section per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run              # everything (QUICK)
    PYTHONPATH=src python -m benchmarks.run --only fig10,roofline
    BENCH_FULL=1 ... python -m benchmarks.run            # paper-length runs

Each section prints CSV and persists JSON under artifacts/.
"""
from __future__ import annotations

import argparse
import time

from benchmarks import (
    chunked_prefill,
    churn,
    continuous_batching,
    kv_quant,
    multi_replica,
    paged_decode,
    phase_cdf,
    roofline,
    scheduler_overhead,
    single_replica,
    ssd_tier,
    tool_call_cdf,
    transfer_overlap,
)

# every section that emits a BENCH_*.json must be listed here — the
# orchestrator is the one entry point that regenerates the whole
# artifacts/ set, so a module missing from this list silently drifts
SECTIONS = [
    ("fig3_tool_call_cdf", tool_call_cdf.main),
    ("fig5_phase_cdf", phase_cdf.main),
    ("fig7_9_single_replica", single_replica.main),
    ("fig10_multi_replica", multi_replica.main),
    ("table2_scheduler_overhead", scheduler_overhead.main),
    ("churn", churn.main),
    ("ssd_tier_7.1_extension", ssd_tier.main),
    ("roofline", roofline.main),
    ("paged_decode", paged_decode.main),
    ("transfer_overlap", transfer_overlap.main),
    ("continuous_batching", continuous_batching.main),
    ("chunked_prefill", chunked_prefill.main),
    ("multi_replica_real", multi_replica.real_main),
    ("kv_quant", kv_quant.main),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated section prefixes")
    args = ap.parse_args()
    only = [s for s in args.only.split(",") if s]

    t_all = time.time()
    for name, fn in SECTIONS:
        if only and not any(name.startswith(o) or o in name for o in only):
            continue
        print(f"\n### {name} " + "#" * max(0, 60 - len(name)), flush=True)
        t0 = time.time()
        fn()
        print(f"### {name} done in {time.time() - t0:.1f}s", flush=True)
    print(f"\nall benchmarks done in {time.time() - t_all:.1f}s")


if __name__ == "__main__":
    main()
