"""Shared setup for the paper-figure benchmarks."""
from __future__ import annotations

import json
import os
from pathlib import Path

from repro.sim import CONFIGS, Simulation
from repro.traces import generate_corpus

ART = Path(__file__).resolve().parents[1] / "artifacts"
ART.mkdir(exist_ok=True)

#: paper-fidelity knobs: QUICK keeps `python -m benchmarks.run` minutes-scale;
#: FULL reproduces the paper's one-hour runs (set BENCH_FULL=1). CI's smoke
#: step shrinks further via BENCH_DURATION_S / BENCH_WARMUP_S overrides.
FULL = os.environ.get("BENCH_FULL", "0") == "1"
DURATION_S = float(
    os.environ.get("BENCH_DURATION_S", 3600.0 if FULL else 900.0)
)
WARMUP_S = float(os.environ.get("BENCH_WARMUP_S", 300.0 if FULL else 120.0))
CORPUS_N = 186

SCHEDS = ["mori", "ta+o", "ta", "smg"]

_corpus_cache = {}


def corpus(seed: int = 0):
    if seed not in _corpus_cache:
        _corpus_cache[seed] = generate_corpus(CORPUS_N, seed=seed)
    return _corpus_cache[seed]


def run_sim(sched, hw_name, *, conc, cpu_ratio, replicas=1, seed=0, **kw):
    sim = Simulation(
        sched,
        CONFIGS[hw_name],
        corpus(),
        num_replicas=replicas,
        concurrency_per_replica=conc,
        cpu_ratio=cpu_ratio,
        duration_s=DURATION_S,
        warmup_s=WARMUP_S,
        seed=seed,
        **kw,
    )
    return sim, sim.run()


def save_json(name: str, obj) -> Path:
    p = ART / name
    p.write_text(json.dumps(obj, indent=1))
    return p


def emit(rows: list[dict], name: str) -> None:
    """Print rows as CSV and persist them as JSON."""
    if not rows:
        return
    keys = list(dict.fromkeys(k for r in rows for k in r))
    print(",".join(keys))
    for r in rows:
        print(",".join(str(r.get(k, "")) for k in keys))
    save_json(name, rows)
