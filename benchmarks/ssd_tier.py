"""Beyond-paper (§7.1): the SSD third tier, with the cost-aware guard.

The paper proposes extending MORI's ranking to NVMe with a second idleness
threshold and leaves it to future work. This benchmark evaluates the
implemented extension across the paper's three hardware pairs under CPU-
tier pressure (0.25x DRAM), with the reload-vs-recompute guard
(SchedulerConfig.ssd_guard_factor) deciding which programs may sink:

* 7B  (kv*prefill/nvme = 0.48): reload clearly beats recompute
* 30B (1.90): cheap MoE recompute beats NVMe -> guard rejects, exact no-op
* 70B (1.35): wins under load (recompute contends for the prefill queue)

NVMe runs on its own simulated channel (3.5 GB/s single-drive,
conservative). Finding: throughput and p90 TTFT improve (typical requests
stop paying recompute); MEAN TTFT can regress on long-trace corpora where
multi-GB tail reloads serialize on the drive — report both.
"""
from __future__ import annotations

from benchmarks.common import corpus, emit
from repro.sim import CONFIGS, Simulation

HWS = ["h200-80g-qwen2.5-7b", "h200-qwen3-30b-a3b", "b200-llama3.1-70b-tp2"]


def main(conc: int = 60) -> list[dict]:
    rows = []
    for hw in HWS:
        for ssd_ratio in (0.0, 4.0):
            r = Simulation(
                "mori", CONFIGS[hw], corpus(),
                num_replicas=1,
                concurrency_per_replica=conc,
                cpu_ratio=0.25,            # deliberately tight DRAM tier
                ssd_ratio=ssd_ratio,
                duration_s=420.0,
                warmup_s=60.0,
                seed=0,
            ).run()
            rows.append(
                {
                    "table": "ssd_tier",
                    "hw": hw,
                    "ssd_ratio": ssd_ratio,
                    "tok_per_s": round(r.output_tok_per_s, 1),
                    "ttft_avg_s": round(r.ttft_avg_s, 2),
                    "ttft_p90_s": round(r.ttft_p90_s, 2),
                    "hit_rate": round(r.cache_hit_rate, 3),
                }
            )
    emit(rows, "ssd_tier.json")
    return rows


if __name__ == "__main__":
    main()
