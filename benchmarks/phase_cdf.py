"""Paper Fig. 5 + §3.3 trace analysis: busy-phase durations under 1/2/5 s
thresholds; short-call fraction and long-call time share at 2 s."""
from __future__ import annotations

from benchmarks.common import corpus, emit
from repro.traces import busy_phase_durations, percentile, phase_stats


def main() -> list[dict]:
    c = corpus()
    rows = []
    paper = {1.0: (4, 15), 2.0: (20, 81), 5.0: (41, 185)}
    for th, (p_med, p_p90) in paper.items():
        ph = busy_phase_durations(c, th)
        rows.append(
            {
                "figure": "fig5_busy_phase",
                "threshold_s": th,
                "median_s": round(percentile(ph, 0.5), 1),
                "p90_s": round(percentile(ph, 0.9), 1),
                "paper_median_s": p_med,
                "paper_p90_s": p_p90,
            }
        )
    st = phase_stats(c, 2.0)
    rows.append(
        {
            "figure": "sec3.3_stats",
            "threshold_s": 2.0,
            "median_s": round(st.short_fraction, 3),
            "p90_s": round(st.long_time_share, 3),
            "paper_median_s": 0.87,   # short fraction
            "paper_p90_s": 0.58,      # long time share
        }
    )
    emit(rows, "fig5_phase_cdf.json")
    return rows


if __name__ == "__main__":
    main()
