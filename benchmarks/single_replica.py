"""Paper Figs. 7-9: single-replica throughput / step rate / TTFT across
three (GPU, model) pairs x {20,50,80} programs x {1x,2x} CPU ratios x all
four systems."""
from __future__ import annotations

from benchmarks.common import SCHEDS, emit, run_sim

HW_FIGS = {
    "fig7": "h200-80g-qwen2.5-7b",
    "fig8": "h200-qwen3-30b-a3b",
    "fig9": "b200-llama3.1-70b-tp2",
}


def main(figs=None, concs=(20, 50, 80), ratios=(1.0, 2.0)) -> list[dict]:
    rows = []
    for fig, hw in HW_FIGS.items():
        if figs and fig not in figs:
            continue
        for ratio in ratios:
            for conc in concs:
                for sched in SCHEDS:
                    _, r = run_sim(sched, hw, conc=conc, cpu_ratio=ratio)
                    rows.append(
                        {
                            "figure": fig,
                            "hw": hw,
                            "cpu_ratio": ratio,
                            "concurrency": conc,
                            "scheduler": sched,
                            "tok_per_s": round(r.output_tok_per_s, 1),
                            "steps_per_s": round(r.steps_per_s, 3),
                            "ttft_avg_s": round(r.ttft_avg_s, 2),
                            "ttft_p90_s": round(r.ttft_p90_s, 2),
                            "gpu_util": round(r.gpu_util, 3),
                            "hit_rate": round(r.cache_hit_rate, 3),
                        }
                    )
    emit(rows, "fig7_9_single_replica.json")
    return rows


if __name__ == "__main__":
    main()
