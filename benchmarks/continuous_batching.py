"""Continuous batching on the real serving path: the decode pump vs the
serialized run-to-completion replay.

Races the same multi-program agentic corpus through ``MoriRouter`` twice
per concurrency level — the default clocked decode pump (one batched
``Engine.step`` advances every due slot) against ``serial_decode=True``
(each dispatched request monopolizes the replica until it finishes, the
pre-pump behavior) — and reports real wall-clock throughput plus the
pump's batch-occupancy metrics. The corpus aligns every program's
reasoning windows so the pump genuinely batches: at concurrency ``c`` the
pump advances ``c`` slots per decode dispatch while the serialized replay
issues ``c``× as many dispatches for the same token count.

Writes ``artifacts/BENCH_continuous_batching.json``; CI gates on
mean batch occupancy > 1.0 and batched ≥ serialized end-to-end throughput
at every concurrency ≥ 2.
"""
from __future__ import annotations

import time

from benchmarks.common import FULL, emit

CONCS = (1, 2, 4, 8) if FULL else (1, 2, 4)
STEPS_PER_PROGRAM = 3
#: long generations keep the race decode-dominated (the pump batches
#: decode; prefill work is identical in both modes and would only dilute
#: the measured difference)
MAX_NEW_TOKENS = 32


def build_corpus(n: int):
    """n programs with aligned arrival and equal reasoning walls, so their
    decode windows overlap for the whole replay."""
    from repro.core.types import ProgramTrace, RequestRecord

    return [
        ProgramTrace(
            f"c{i}",
            [
                RequestRecord(
                    44 + 4 * i + 10 * s, MAX_NEW_TOKENS,
                    tool_duration_s=1.0, reasoning_wall_s=2.0,
                )
                for s in range(STEPS_PER_PROGRAM)
            ],
        )
        for i in range(n)
    ]


def make_router(cfg, params, *, serial: bool, slots: int):
    from repro.core import SchedulerConfig
    from repro.serving import Engine, MoriRouter

    # max_seq/table_bucket_pages keep the jit shape space small: each
    # engine instance has its own jit cache, so warmup cost is paid per
    # cell and must stay a few compiles, not sixteen
    engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                    n_host_pages=64, max_slots=slots, max_seq=320,
                    table_bucket_pages=10)
    engine.warmup()  # precompile every decode bucket: the race times
    #                  decode, not jit
    return MoriRouter(
        [engine], scheduler="mori",
        config=SchedulerConfig(tick_interval_s=5.0),
        serial_decode=serial,
    )


def run_one(cfg, params, *, conc: int, serial: bool, timed: bool = True):
    """One replay cell; timed cells take the best of two runs so a noisy
    neighbor on a shared runner cannot flip the CI ≥-gate."""
    best = None
    for _ in range(2 if timed else 1):
        corpus = build_corpus(conc)
        router = make_router(cfg, params, serial=serial, slots=max(CONCS))
        t0 = time.perf_counter()
        m = router.replay(corpus, vocab_size=cfg.vocab_size,
                          max_new_tokens=MAX_NEW_TOKENS)
        wall = time.perf_counter() - t0
        assert m.steps_completed == conc * STEPS_PER_PROGRAM
        if best is None or wall < best[0]:
            best = (wall, m)
    if not timed:
        return None
    wall, m = best
    return {
        "concurrency": conc,
        "mode": "serialized" if serial else "batched",
        "wall_s": round(wall, 3),
        "tok_per_s": round(m.tokens_generated / wall, 1),
        "tokens": m.tokens_generated,
        "decode_dispatches": m.pump_steps,
        "mean_batch_occupancy": round(m.mean_batch_occupancy, 3),
        "peak_live_slots": m.peak_live_slots,
        "multi_slot_steps": m.multi_slot_steps,
        "slot_wait_s": round(m.slot_wait_s, 3),
        "cache_hit_rate": round(m.cache_hit_rate, 3),
    }


def main() -> list[dict]:
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)

    # one untimed pass per mode at top concurrency populates the in-process
    # jit cache (prefill buckets, decode shapes) so neither timed mode pays
    # first-compile costs the other skips
    for serial in (False, True):
        run_one(cfg, params, conc=max(CONCS), serial=serial, timed=False)

    rows = []
    for conc in CONCS:
        for serial in (True, False):
            rows.append(run_one(cfg, params, conc=conc, serial=serial))
    emit(rows, "BENCH_continuous_batching.json")

    by = {(r["concurrency"], r["mode"]): r for r in rows}
    for conc in CONCS:
        bt, sr = by[(conc, "batched")], by[(conc, "serialized")]
        speedup = bt["tok_per_s"] / sr["tok_per_s"]
        print(
            f"conc {conc}: batched {bt['tok_per_s']} tok/s "
            f"({bt['decode_dispatches']} dispatches, occupancy "
            f"{bt['mean_batch_occupancy']}) vs serialized "
            f"{sr['tok_per_s']} tok/s ({sr['decode_dispatches']} "
            f"dispatches) -> {speedup:.2f}x"
        )
    return rows


if __name__ == "__main__":
    main()
