"""Paper Fig. 3: CDF of tool-call durations (heavy tail over 3+ orders)."""
from __future__ import annotations

from benchmarks.common import corpus, emit
from repro.traces import percentile, phase_stats, tool_call_cdf


def main() -> list[dict]:
    c = corpus()
    durs = tool_call_cdf(c)
    st = phase_stats(c, 2.0)
    rows = [
        {
            "figure": "fig3_tool_call_cdf",
            "quantile": q,
            "duration_s": round(percentile(durs, q), 3),
        }
        for q in [0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.95, 0.99, 0.999]
    ]
    rows.append(
        {
            "figure": "fig3_summary",
            "quantile": "orders_of_magnitude",
            "duration_s": round(st.orders_of_magnitude, 2),
        }
    )
    emit(rows, "fig3_tool_call_cdf.json")
    return rows


if __name__ == "__main__":
    main()
