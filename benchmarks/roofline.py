"""Deliverable (g): per-(arch x shape x mesh) roofline terms from the dry-run.

Reads ``artifacts/dryrun.json`` (written by ``repro.launch.dryrun``) and, for
every ok cell, derives the three roofline terms on the TPU v5e target:

    compute    = FLOPs_per_device   / peak_FLOP/s          (197 TF bf16/chip)
    memory     = HBM_bytes_per_dev  / HBM_bw               (819 GB/s/chip)
    collective = wire_bytes_per_dev / ICI link bandwidth   (~50 GB/s/link)

The dry-run's ``cost`` block is already *per device* (GSPMD-partitioned
module) and loop-aware (scan bodies multiplied by trip count; see
``repro.launch.hlo_cost``). The dominant term is the bottleneck §Perf
iterates on.

"Useful" model FLOPs:
    train   : 6 * N * D          (fwd 2ND + bwd 4ND)
    prefill : 2 * N * D
    decode  : 2 * N * D          (D = batch tokens, one step)
with N = active params for MoE (6*N_active*D per the assignment) — attention
score/AV FLOPs are excluded by convention, so ratios > 1 are possible for
long-context cells.
"""
from __future__ import annotations

import json
from pathlib import Path

from benchmarks.common import ART, emit

# TPU v5e target constants (per chip / per link), from the assignment.
PEAK_FLOPS = 197e12        # bf16 FLOP/s
HBM_BW = 819e9             # bytes/s
ICI_BW = 50e9              # bytes/s per link

DRYRUN = ART / "dryrun.json"


def _active_fraction(cfg) -> float:
    """Active-parameter fraction for MoE archs (expert FFN utilization)."""
    if not cfg.num_experts:
        return 1.0
    # 3 matrices (gate/up/down) per expert, all layers
    expert = cfg.num_layers * cfg.num_experts * 3 * cfg.d_model * cfg.moe_d_ff
    from repro.models import Model, count_params

    total = count_params(Model(cfg).describe())
    inactive = expert * (1.0 - cfg.top_k / cfg.num_experts)
    return (total - inactive) / total


def model_flops(arch: str, kind: str, tokens: int) -> tuple[float, float]:
    """(useful FLOPs for the step, N_active) for the full cell (all devices)."""
    from repro.configs import get_config
    from repro.models import Model, count_params

    cfg = get_config(arch)
    n_total = count_params(Model(cfg).describe())
    n_active = n_total * _active_fraction(cfg)
    mult = 6.0 if kind == "train" else 2.0
    return mult * n_active * tokens, n_active


def rows_from_dryrun(path: Path = DRYRUN) -> list[dict]:
    data = json.loads(path.read_text())
    rows = []
    for key in sorted(data):
        rec = data[key]
        parts = key.split("|")
        if len(parts) != 3:          # tagged perf-iteration entries
            continue
        arch, shape, mesh = parts
        if rec["status"] != "ok":
            rows.append(
                {
                    "arch": arch, "shape": shape, "mesh": mesh,
                    "kind": rec.get("reason", rec.get("error", ""))[:60],
                    "devices": "", "compute_s": "", "memory_s": "",
                    "collective_s": "", "bound": rec["status"],
                    "roofline_frac": "", "useful_ratio": "",
                    "peak_gib": "",
                }
            )
            continue
        n_dev = rec["devices"]
        flops_dev = rec["cost"]["flops"]
        hbm_dev = rec["cost"]["hbm_bytes"]
        wire_dev = rec["collectives"]["total_wire_bytes"]

        t_c = flops_dev / PEAK_FLOPS
        t_m = hbm_dev / HBM_BW
        t_x = wire_dev / ICI_BW
        bound = max((t_c, "compute"), (t_m, "memory"), (t_x, "collective"))[1]
        t_step = max(t_c, t_m, t_x)

        useful, _ = model_flops(arch, rec["kind"], rec["tokens_per_step"])
        useful_dev = useful / n_dev
        # roofline fraction: useful FLOPs per device over what the chips could
        # do in the bound-limited step time (classic MFU-at-the-roofline).
        frac = useful_dev / (t_step * PEAK_FLOPS) if t_step else 0.0

        rows.append(
            {
                "arch": arch,
                "shape": shape,
                "mesh": mesh,
                "kind": rec["kind"],
                "devices": n_dev,
                "compute_s": round(t_c, 6),
                "memory_s": round(t_m, 6),
                "collective_s": round(t_x, 6),
                "bound": bound,
                "roofline_frac": round(frac, 4),
                "useful_ratio": round(useful_dev / flops_dev, 4) if flops_dev else "",
                "peak_gib": round(rec["memory"]["peak_bytes"] / 2**30, 2),
            }
        )
    return rows


def main() -> list[dict]:
    if not DRYRUN.exists():
        print("roofline: artifacts/dryrun.json missing — run "
              "`PYTHONPATH=src python -m repro.launch.dryrun` first")
        return []
    rows = rows_from_dryrun()
    emit(rows, "roofline.json")
    return rows


if __name__ == "__main__":
    main()
