"""Chunked prefill inside the decode pump vs monolithic submits.

Races the same multi-program agentic corpus through ``MoriRouter`` twice
per batch size — ``chunked_prefill=True`` (the pump runs page-sized,
bucket-shaped prefill chunks between decode steps) against the default
monolithic path (each submit runs one eager variable-shape
``Model.prefill`` before the program can join the batch) — and reports
real end-to-end wall clock plus the TTFT summary ``RouterMetrics``
records from each submit event to its first token.

The corpus grows every program's context across steps, so the monolithic
path sees a fresh prefix shape per submit and pays eager per-shape
dispatch each time; the chunked path folds every prefill into the same
few (prefix-bucket, chunk-bucket) jit shapes, compiled once per process.

Writes ``artifacts/BENCH_chunked_prefill.json``; CI gates on chunked
end-to-end wall ≤ monolithic and chunked mean TTFT strictly lower at
every batch size ≥ 4.
"""
from __future__ import annotations

import time

from benchmarks.common import FULL, emit

BATCHES = (1, 2, 4, 8) if FULL else (1, 2, 4)
STEPS_PER_PROGRAM = 3
#: short generations keep the race prefill-dominated: decode work is
#: identical in both modes and would only dilute the measured difference
MAX_NEW_TOKENS = 4
PREFILL_BUDGET = 32


def build_corpus(n: int):
    """n programs with aligned arrivals and growing contexts: every
    submit after the first presents a new prefix length, the shape churn
    monolithic prefill pays for and bucketed chunks do not."""
    from repro.core.types import ProgramTrace, RequestRecord

    return [
        ProgramTrace(
            f"c{i}",
            [
                RequestRecord(
                    48 + 4 * i + 12 * s, MAX_NEW_TOKENS,
                    tool_duration_s=1.0, reasoning_wall_s=2.0,
                )
                for s in range(STEPS_PER_PROGRAM)
            ],
        )
        for i in range(n)
    ]


def make_router(cfg, params, *, chunked: bool, slots: int):
    from repro.core import SchedulerConfig
    from repro.serving import Engine, MoriRouter

    engine = Engine(cfg, params, page_tokens=8, n_device_pages=512,
                    n_host_pages=64, max_slots=slots, max_seq=512)
    engine.warmup(prefill_chunks=chunked)  # precompile decode buckets and
    #                  (chunked mode) the chunk shapes: the race times the
    #                  serving path, not jit
    return MoriRouter(
        [engine], scheduler="mori",
        config=SchedulerConfig(tick_interval_s=5.0),
        chunked_prefill=chunked,
        prefill_token_budget=PREFILL_BUDGET if chunked else None,
    )


def run_one(cfg, params, *, batch: int, chunked: bool, timed: bool = True):
    """One replay cell; timed cells take the best of two runs so a noisy
    neighbor on a shared runner cannot flip the CI ≥-gate."""
    best = None
    for _ in range(2 if timed else 1):
        corpus = build_corpus(batch)
        router = make_router(cfg, params, chunked=chunked, slots=max(BATCHES))
        t0 = time.perf_counter()
        m = router.replay(corpus, vocab_size=cfg.vocab_size,
                          max_new_tokens=MAX_NEW_TOKENS)
        wall = time.perf_counter() - t0
        assert m.steps_completed == batch * STEPS_PER_PROGRAM
        if best is None or wall < best[0]:
            best = (wall, m)
    if not timed:
        return None
    wall, m = best
    t = m.ttft_s
    return {
        "batch": batch,
        "mode": "chunked" if chunked else "monolithic",
        "wall_s": round(wall, 3),
        "ttft_mean_s": round(t["mean"], 4),
        "ttft_p50_s": round(t["p50"], 4),
        "ttft_p95_s": round(t["p95"], 4),
        "ttft_n": t["n"],
        "prefill_chunks": m.prefill_chunks,
        "tokens": m.tokens_generated,
        "mean_batch_occupancy": round(m.mean_batch_occupancy, 3),
    }


def main() -> list[dict]:
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)

    # one untimed pass per mode at top batch populates the in-process jit
    # cache (decode buckets, chunk shapes) so neither timed mode pays
    # first-compile costs the other skips
    for chunked in (False, True):
        run_one(cfg, params, batch=max(BATCHES), chunked=chunked,
                timed=False)

    rows = []
    for batch in BATCHES:
        for chunked in (False, True):
            rows.append(run_one(cfg, params, batch=batch, chunked=chunked))
    emit(rows, "BENCH_chunked_prefill.json")

    by = {(r["batch"], r["mode"]): r for r in rows}
    for batch in BATCHES:
        ck, mo = by[(batch, "chunked")], by[(batch, "monolithic")]
        print(
            f"batch {batch}: chunked {ck['wall_s']}s e2e / "
            f"{ck['ttft_mean_s']}s mean TTFT ({ck['prefill_chunks']} chunks) "
            f"vs monolithic {mo['wall_s']}s / {mo['ttft_mean_s']}s"
        )
    return rows


if __name__ == "__main__":
    main()
