"""Transfer/compute overlap on the real serving path (the paper's thesis,
measured): replay an agentic corpus through ``MoriRouter`` with the async
transfer plane and report how much KV movement was hidden inside tool-call
idle windows — decode steps executed while a transfer was streaming,
offloads cancelled by early tool returns, and the ledger's in-flight
high-water mark. The sync compatibility mode runs the same corpus as the
no-overlap baseline, and the simulator's ``xfer_overlap_frac`` gives the
fluid-model counterpart on paper-scale hardware.

Writes ``artifacts/BENCH_transfer_overlap.json`` so CI tracks the overlap
trajectory across PRs.
"""
from __future__ import annotations

from benchmarks.common import emit, run_sim


def real_path_rows() -> list[dict]:
    """Bandwidth sweep over the burst trace: a fast link completes the
    offload inside the idle window (round trip billed), a slow link is
    still streaming when the tool returns (cancel + warm re-admit), and
    sync mode is the no-overlap baseline."""
    from repro.configs import get_config
    from repro.core import SchedulerConfig
    from repro.core.types import TransferCost
    from repro.kernels import kv_quant
    from repro.models import Model, materialize
    from repro.serving import Engine, MoriRouter
    from repro.traces import burst_cancel_corpus

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    kvb = kv_quant.token_wire_bytes(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16")
    offload_bytes = 64 * kvb  # p1's materialized KV at demotion time
    cases = [
        ("async-slow-link", False, offload_bytes / 20.0),   # 20 s: cancelled
        ("async-fast-link", False, offload_bytes / 4.0),    # 4 s: round trip
        ("sync", True, offload_bytes / 20.0),
    ]
    rows = []
    for mode, sync, bw in cases:
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                        n_host_pages=64, max_slots=4, max_seq=256)
        router = MoriRouter(
            [engine], scheduler="mori",
            gpu_capacity_bytes=130 * kvb,
            config=SchedulerConfig(tick_interval_s=1.0),
            sync_transfers=sync,
            xfer_cost=TransferCost(pcie_bytes_per_s=bw),
        )
        m = router.replay(burst_cancel_corpus(), vocab_size=cfg.vocab_size,
                          max_new_tokens=4)
        rows.append(
            {
                "path": "real",
                "mode": mode,
                "steps_completed": m.steps_completed,
                "overlap_decode_steps": m.overlap_decode_steps,
                "cancelled_offloads": m.cancelled_offloads,
                "cancelled_pages": m.cancelled_pages,
                "offloaded_pages": m.offloaded_pages,
                "reloaded_pages": m.reloaded_pages,
                "peak_inflight_bytes": m.peak_inflight_bytes,
                "cache_hit_rate": round(m.cache_hit_rate, 3),
            }
        )
    return rows


def sim_rows() -> list[dict]:
    rows = []
    for sched in ("mori", "ta+o"):
        _, r = run_sim(sched, "h200-80g-qwen2.5-7b", conc=50, cpu_ratio=1.0)
        rows.append(
            {
                "path": "sim",
                "mode": sched,
                "steps_completed": r.steps_completed,
                "xfer_overlap_frac": round(r.xfer_overlap_frac, 4),
                "tok_per_s": round(r.output_tok_per_s, 1),
            }
        )
    return rows


def main() -> list[dict]:
    rows = real_path_rows() + sim_rows()
    emit(rows, "BENCH_transfer_overlap.json")
    return rows


if __name__ == "__main__":
    main()
