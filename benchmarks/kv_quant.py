"""Quantized KV pages (int8 per-page-scale), measured end to end: what the
format is worth on every axis MORI prices.

Three sections, one JSON (``artifacts/BENCH_kv_quant.json``):

* ``wire`` — bytes and virtual seconds to ship one 64-token context over a
  fixed link, per offload format. The int8 payload (plus fp32 scale
  sidecars) must come in at ≤0.55x the bf16 wire time — this ratio is the
  lever that moves every placement boundary at once.
* ``capacity`` — resident pages at a fixed HBM budget, per device format.
  ``device_format="int8"`` must fit ≥1.9x the pages (2x payload minus
  sidecar overhead).
* ``regime`` — the cancel-vs-round-trip boundary moving on the *real*
  serving path: the same burst/cancel corpus, the same link bandwidth,
  chosen so a bf16 offload is still mid-stream when the tool returns
  (cancelled, warm re-admit) while the int8 offload has already committed
  (clean round trip). Compare against ``BENCH_transfer_overlap.json``,
  where the bf16-only sweep needed a 5x bandwidth spread to cross the same
  boundary.
"""
from __future__ import annotations

from benchmarks.common import emit

#: bf16 takes 8 virtual seconds for the 64-token offload at this link —
#: outside the corpus's ~6 s tool window; int8 (~0.51x bytes) takes ~4.1 s
#: and commits inside it
BF16_OFFLOAD_SECONDS = 8.0
OFFLOAD_TOKENS = 64


def wire_rows(cfg) -> list[dict]:
    from repro.kernels import kv_quant

    bw = 1e9  # any fixed link; only the ratio matters
    rows = []
    for fmt in ("bf16", "int8"):
        pages = OFFLOAD_TOKENS // 8
        nbytes = pages * kv_quant.page_wire_bytes(
            cfg.num_layers, 8, cfg.num_kv_heads, cfg.head_dim, fmt
        )
        rows.append({
            "section": "wire",
            "format": fmt,
            "context_tokens": OFFLOAD_TOKENS,
            "wire_bytes": nbytes,
            "wire_s": round(nbytes / bw, 6),
        })
    ratio = rows[1]["wire_bytes"] / rows[0]["wire_bytes"]
    for r in rows:
        r["vs_bf16"] = round(r["wire_bytes"] / rows[0]["wire_bytes"], 4)
    print(f"wire ratio int8/bf16 = {ratio:.3f} (gate: <= 0.55)")
    return rows


def capacity_rows(cfg) -> list[dict]:
    from repro.kernels import kv_quant

    budget = 64 * kv_quant.page_wire_bytes(
        cfg.num_layers, 8, cfg.num_kv_heads, cfg.head_dim, "bf16"
    )
    rows = []
    for fmt in ("bf16", "int8"):
        page = kv_quant.page_wire_bytes(
            cfg.num_layers, 8, cfg.num_kv_heads, cfg.head_dim, fmt
        )
        rows.append({
            "section": "capacity",
            "format": fmt,
            "hbm_budget_bytes": budget,
            "page_bytes": page,
            "resident_pages": budget // page,
        })
    ratio = rows[1]["resident_pages"] / rows[0]["resident_pages"]
    for r in rows:
        r["vs_bf16"] = round(r["resident_pages"] / rows[0]["resident_pages"], 4)
    print(f"resident capacity int8/bf16 = {ratio:.3f}x (gate: >= 1.9)")
    return rows


def regime_rows(cfg, params) -> list[dict]:
    from repro.core import SchedulerConfig
    from repro.core.types import TransferCost
    from repro.kernels import kv_quant
    from repro.serving import Engine, MoriRouter
    from repro.traces import burst_cancel_corpus

    kvb = kv_quant.token_wire_bytes(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16"
    )
    # equal link bandwidth for both formats — only the bytes differ
    bw = OFFLOAD_TOKENS * kvb / BF16_OFFLOAD_SECONDS
    rows = []
    for fmt in ("bf16", "int8"):
        engine = Engine(cfg, params, page_tokens=8, n_device_pages=256,
                        n_host_pages=64, max_slots=4, max_seq=256,
                        offload_format=fmt)
        router = MoriRouter(
            [engine], scheduler="mori",
            gpu_capacity_bytes=130 * kvb,
            config=SchedulerConfig(tick_interval_s=1.0),
            xfer_cost=TransferCost(pcie_bytes_per_s=bw),
        )
        m = router.replay(burst_cancel_corpus(), vocab_size=cfg.vocab_size,
                          max_new_tokens=4)
        page_wire = engine.pool.host_page_bytes
        rows.append({
            "section": "regime",
            "format": fmt,
            "pcie_bytes_per_s": int(bw),
            "offload_wire_s_64tok": round(
                (OFFLOAD_TOKENS // 8) * page_wire / bw, 3
            ),
            "steps_completed": m.steps_completed,
            "cancelled_offloads": m.cancelled_offloads,
            "offloaded_pages": m.offloaded_pages,
            "reloaded_pages": m.reloaded_pages,
            "offload_bytes": m.offload_bytes,
            "reload_bytes": m.reload_bytes,
        })
    bf16, int8 = rows
    print(
        f"regime boundary at {bw / 1e3:.1f} KB/s (virtual): bf16 "
        f"{bf16['offload_wire_s_64tok']}s/offload -> "
        f"{bf16['cancelled_offloads']} cancelled; int8 "
        f"{int8['offload_wire_s_64tok']}s -> "
        f"{int8['cancelled_offloads']} cancelled, "
        f"{int8['reloaded_pages']} pages round-tripped"
    )
    return rows


def main() -> list[dict]:
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    rows = wire_rows(cfg) + capacity_rows(cfg) + regime_rows(cfg, params)
    emit(rows, "BENCH_kv_quant.json")
    return rows


if __name__ == "__main__":
    main()
