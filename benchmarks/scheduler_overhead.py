"""Paper Table 2: per-tick scheduler CPU overhead, MORI vs TA+O.

The paper reports 23.8 ms (MORI) vs 21.5 ms (TA+O) per scheduling step at
80 programs — MORI's richer placement logic costs ~11% more CPU but is
fully overlapped with the GPU step. We measure real wall-clock tick() cost
of the actual policy code under the same concurrency; ``tick()`` now
returns a PlacementPlan, so the same run also reports how many actions a
control-loop pass emits (plan construction is part of the measured cost)."""
from __future__ import annotations

from benchmarks.common import emit, run_sim


def main(conc: int = 50) -> list[dict]:
    rows = []
    for sched in ["mori", "ta+o"]:
        sim, r = run_sim(sched, "h200-qwen3-30b-a3b", conc=conc, cpu_ratio=2.0)
        n_ticks = max(1, len(sim.tick_actions))
        rows.append(
            {
                "table": "table2",
                "scheduler": sched,
                "programs": conc,
                "tick_avg_ms": round(r.tick_avg_ms, 3),
                "tick_p99_ms": round(r.tick_p99_ms, 3),
                "actions_per_tick": round(sum(sim.tick_actions) / n_ticks, 3),
                "actions_per_tick_max": max(sim.tick_actions, default=0),
                "paper_avg_ms": 23.8 if sched == "mori" else 21.5,
            }
        )
    emit(rows, "table2_overhead.json")
    return rows


if __name__ == "__main__":
    main()
