"""gemma2-9b [dense]: 42L d_model=3584 16H (GQA kv=8) d_ff=14336
vocab=256000 — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]
Paper role: mid-scale dense GPU pair (single-accelerator serving, ~9B); exercises the local/global alternating-cache shape the window-limited-cache lever targets.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14_336,
    vocab_size=256_000,
    local_global_alternating=True,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
)
