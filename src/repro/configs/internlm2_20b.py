"""internlm2-20b [dense]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92544. [arXiv:2403.17297; hf]
Paper role: plain-GQA 20B dense scale point — the clean baseline column between the 9B and MoE rows of the dry-run matrix.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internlm2-20b",
    family="dense",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=92_544,
    rope_theta=1_000_000.0,
)
