"""Assigned-architecture registry: ``get_config(arch_id)``.

One module per architecture; each exposes ``CONFIG`` with the exact
published dimensions ([source; verified-tier] in each file).
"""
from __future__ import annotations

import importlib

ARCHS = [
    "mamba2_2p7b",
    "internlm2_20b",
    "gemma2_27b",
    "gemma2_9b",
    "qwen1p5_0p5b",
    "arctic_480b",
    "dbrx_132b",
    "whisper_medium",
    "internvl2_26b",
    "zamba2_2p7b",
]

_ALIASES = {
    "mamba2-2.7b": "mamba2_2p7b",
    "internlm2-20b": "internlm2_20b",
    "gemma2-27b": "gemma2_27b",
    "gemma2-9b": "gemma2_9b",
    "qwen1.5-0.5b": "qwen1p5_0p5b",
    "arctic-480b": "arctic_480b",
    "dbrx-132b": "dbrx_132b",
    "whisper-medium": "whisper_medium",
    "internvl2-26b": "internvl2_26b",
    "zamba2-2.7b": "zamba2_2p7b",
}

ARCH_IDS = list(_ALIASES)


def get_config(arch: str):
    mod_name = _ALIASES.get(arch, arch).replace("-", "_").replace(".", "p")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs():
    return {arch: get_config(arch) for arch in ARCH_IDS}
