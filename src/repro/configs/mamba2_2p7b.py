"""mamba2-2.7b [ssm]: 64L d_model=2560, attn-free, vocab=50280, ssm_state=128.
[arXiv:2405.21060; unverified]
Paper role: attention-free O(1)-state family — the long_500k cell and the SSM-state (not KV) variant of MORI's placement currency.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-2.7b",
    family="ssm",
    num_layers=64,
    d_model=2560,
    num_heads=0,
    num_kv_heads=0,
    head_dim=0,
    d_ff=0,
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,     # 80 SSD heads
)
