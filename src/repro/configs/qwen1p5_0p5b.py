"""qwen1.5-0.5b [dense]: 24L d_model=1024 16H (MHA kv=16) d_ff=2816
vocab=151936 — QKV bias. [hf:Qwen/Qwen1.5-0.5B; hf]
Paper role: smallest scale point — the CPU-runnable stand-in for the paper's 7B-class single-GPU pair (h200-80g-qwen2.5-7b); default arch for quickstart, tests and the real-engine replay.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-0.5b",
    family="dense",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=2816,
    vocab_size=151_936,
    qkv_bias=True,
)
