"""internvl2-26b [vlm]: 48L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=92553 — InternViT frontend is a STUB (input_specs provides projected
patch embeddings); backbone = InternLM2-20B. [arXiv:2404.16821; hf]
Paper role: VLM agent workload — image-token prefixes inflate prefill and prefix-cache pressure relative to its InternLM2 text backbone.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16_384,
    vocab_size=92_553,
    rope_theta=1_000_000.0,
    num_image_tokens=256,
)
