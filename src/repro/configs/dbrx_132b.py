"""dbrx-132b [moe]: 40L d_model=6144 48H (GQA kv=8) d_ff=10752 vocab=100352,
MoE 16e top-4, fine-grained. [hf:databricks/dbrx-base; unverified]
Paper role: mid MoE scale point (132B, 16e top-4) standing in for the paper's MoE serving pair (qwen3-30b-a3b rows of repro.sim.hardware).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="dbrx-132b",
    family="moe",
    num_layers=40,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=0,                  # no dense FFN: pure MoE blocks
    vocab_size=100_352,
    num_experts=16,
    top_k=4,
    moe_d_ff=10_752,
)
