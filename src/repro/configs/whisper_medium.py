"""whisper-medium [audio]: 24L(+24 enc) d_model=1024 16H (MHA) d_ff=4096
vocab=51865 — enc-dec; conv frontend is a STUB (input_specs provides
precomputed 1500-frame embeddings). [arXiv:2212.04356; unverified]
Paper role: encoder-decoder tool-side workload (audio transcription as an agent tool call) — cross-attention KV joins the cache inventory.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-medium",
    family="encdec",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    head_dim=64,
    d_ff=4096,
    vocab_size=51_865,
    encoder_layers=24,
    encoder_seq=1500,
)
