"""arctic-480b [moe]: 35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000,
MoE 128e top-2 + dense residual. [hf:Snowflake/snowflake-arctic-base; hf]

Note: 56 q-heads do not divide the 16-way model axis; the sharding rules
fall back to replicated head-activations while the fused projections stay
sharded (DESIGN.md §6). bf16 Adam moments keep optimizer state within HBM.

Paper role: largest capacity-pressure scale point (480B MoE) — the arch that forces KV offload decisions at paper scale and the pad-heads sharding fallback study.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,               # dense residual FFN
    vocab_size=32_000,
    num_experts=128,
    top_k=2,
    moe_d_ff=4864,
    dense_residual=True,
    bf16_moments=True,
)
