"""gemma2-27b [dense]: 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000 — local+global alternating, logit softcap.
[arXiv:2408.00118; hf]
Paper role: upper dense scale point (tensor-parallel single node); the softcap + alternating-window case of the decode_32k cell.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    num_layers=46,
    d_model=4608,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=36_864,
    vocab_size=256_000,
    local_global_alternating=True,
    sliding_window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
)
