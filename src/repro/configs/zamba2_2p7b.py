"""zamba2-2.7b [hybrid]: 54L d_model=2560 32H (MHA kv=32) d_ff=10240
vocab=32000, ssm_state=64 — Mamba2 backbone + ONE shared attention block
applied every 6 layers on concat(hidden, embedding). [arXiv:2411.15242; hf]
Paper role: hybrid SSM+shared-attention family — mixed KV/SSM serving state, the hardest case for tier accounting.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=160,            # shared block: 2*d_model / 32 heads
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    ssm_expand=2,
    ssm_head_dim=64,
    shared_attn_period=6,
)
