"""Randomized replay fuzzer driving the real router under kvsan.

Each round synthesizes a small agentic corpus (random contexts, tool
gaps, reasoning walls) and replays it through a :class:`MoriRouter`
built with randomized knobs — scheduler policy × {sync, async}
transfers × {serial, pump} decode × {monolithic, chunked} prefill ×
randomized capacities tight enough to force offload / reload / cancel
traffic.  ``REPRO_KVSAN=1`` is exported before any pool is built, so
the page-lifetime sanitizer, the strict radix refcount mode, and the
control-plane invariant checker all arm; a clean fuzz run therefore
certifies far more than "no exception": every page alloc/free paired,
no ledger record leaked, occupancy conserved at every tick.

A failing round is **shrunk** (greedily dropping programs, then
truncating trailing steps, re-running after each candidate reduction)
and dumped as a JSON artifact — seed, knobs, the minimal corpus, the
error, the sanitizer's recent page-event ring, and the action log — so
the bug replays from the artifact alone.

``--compile-audit`` additionally arms the compile tracker
(``REPRO_JITAUDIT=1``), warms every engine through its bucket specs
before replay, and lets the router's end-of-replay hook fail the round
if any hot-path jit compiled after warmup — randomized knob coverage
for the recompile budget that the deterministic jitaudit CLI checks at
one geometry only.

CLI::

    python -m repro.analysis.fuzz --rounds 8 --seed 0 --out artifacts/

Exit status 1 when any round fails.  Importable for tests via
:func:`fuzz` (which returns the failure reports instead of exiting).
"""
from __future__ import annotations

import argparse
import json
import os
import random
import sys
from dataclasses import asdict, dataclass, field

from repro.analysis import compile_tracker, kvsan

#: replay knobs every round draws from
_SCHEDULERS = ("mori", "smg", "ta")


@dataclass
class FuzzFailure:
    """One failing round, fully replayable from this record."""

    round: int
    seed: int
    knobs: dict
    corpus: list            # [{program_id, steps: [...]}], post-shrink
    error_type: str
    error: str
    kvsan_trace: list = field(default_factory=list)
    actions: list = field(default_factory=list)
    shrink_attempts: int = 0


def _make_corpus(rng: random.Random, round_idx: int) -> list:
    """2–5 programs × 1–4 steps with growing contexts; small enough to
    replay in seconds, shaped (tool gaps ≫ decode windows) so schedulers
    actually offload into the idle windows."""
    from repro.core.types import ProgramTrace, RequestRecord

    corpus = []
    for p in range(rng.randint(2, 5)):
        ctx = rng.randint(32, 80)
        steps = []
        n_steps = rng.randint(1, 4)
        for s in range(n_steps):
            last = s == n_steps - 1
            steps.append(RequestRecord(
                input_tokens=ctx,
                output_tokens=4,
                tool_duration_s=0.0 if last else rng.uniform(0.0, 40.0),
                reasoning_wall_s=round(rng.uniform(0.0, 3.0), 3),
            ))
            ctx += rng.randint(8, 24)
        corpus.append(ProgramTrace(f"r{round_idx}p{p}", steps))
    return corpus


def _make_knobs(rng: random.Random) -> dict:
    serial = rng.random() < 0.25
    return {
        "scheduler": rng.choice(_SCHEDULERS),
        "sync_transfers": rng.random() < 0.3,
        "serial_decode": serial,
        # chunked prefill needs the pump
        "chunked_prefill": (not serial) and rng.random() < 0.5,
        "tick_interval_s": rng.choice([1.0, 2.0, 5.0]),
        # fraction of the pool's cache capacity the scheduler may use —
        # < 1.0 forces demotions while contexts grow
        "gpu_frac": rng.choice([0.5, 0.7, 1.0]),
        # pages per virtual second over PCIe: slow enough that copies
        # span decode windows (overlap + mid-stream cancels), fast
        # enough that replay drains promptly
        "pcie_pages_per_s": rng.choice([4, 16, 64]),
        "max_slots": rng.choice([2, 4]),
    }


def _build_router(knobs: dict, cfg, params, *, audit: bool = False):
    from repro.core import SchedulerConfig
    from repro.core.types import TransferCost
    from repro.kernels import kv_quant
    from repro.serving import Engine, MoriRouter

    kvb = kv_quant.token_wire_bytes(
        cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, "bf16")
    engine = Engine(
        cfg, params, page_tokens=8, n_device_pages=256, n_host_pages=128,
        max_slots=knobs["max_slots"], max_seq=256,
    )
    if audit:
        # warm every bucket spec and snapshot the compile caches; the
        # router's end-of-replay hook then fails the round on any
        # post-warmup compile (a shape the warmup buckets missed)
        engine.warmup(prefill_chunks=knobs["chunked_prefill"])
    reserve = getattr(engine, "decode_reserve_pages", 0)
    cache_bytes = (engine.pool.n_device_pages - reserve) * engine.pool.page_bytes
    # never squeeze below what the largest single program needs resident
    # (otherwise the replay legitimately wedges and the "failure" is noise)
    floor = int(2.5 * 224 * kvb)
    gpu_cap = max(int(knobs["gpu_frac"] * cache_bytes), floor)
    router = MoriRouter(
        [engine],
        scheduler=knobs["scheduler"],
        gpu_capacity_bytes=min(gpu_cap, cache_bytes),
        config=SchedulerConfig(tick_interval_s=knobs["tick_interval_s"]),
        sync_transfers=knobs["sync_transfers"],
        serial_decode=knobs["serial_decode"],
        chunked_prefill=knobs["chunked_prefill"],
        xfer_cost=TransferCost(
            pcie_bytes_per_s=knobs["pcie_pages_per_s"] * engine.pool.page_bytes
        ),
        record_plans=True,
    )
    return router


def _run_once(knobs: dict, corpus, cfg, params, *,
              audit: bool = False) -> Exception | None:
    """One replay; returns the exception (with router attached) or None."""
    router = _build_router(knobs, cfg, params, audit=audit)
    try:
        router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)
        return None
    except Exception as exc:            # noqa: BLE001 — every crash is a find
        exc._fuzz_router = router
        return exc


def _shrink(knobs: dict, corpus, err, cfg, params, *, audit: bool = False):
    """Greedy corpus reduction preserving the failure's error type."""
    attempts = 0
    want = type(err).__name__
    # pass 1: drop whole programs
    i = 0
    while i < len(corpus) and len(corpus) > 1 and attempts < 32:
        cand = corpus[:i] + corpus[i + 1:]
        attempts += 1
        e = _run_once(knobs, cand, cfg, params, audit=audit)
        if e is not None and type(e).__name__ == want:
            corpus, err = cand, e
        else:
            i += 1
    # pass 2: truncate trailing steps
    changed = True
    while changed and attempts < 48:
        changed = False
        for i, tr in enumerate(corpus):
            if len(tr.steps) <= 1:
                continue
            cand = list(corpus)
            cand[i] = type(tr)(tr.program_id, tr.steps[:-1])
            attempts += 1
            e = _run_once(knobs, cand, cfg, params, audit=audit)
            if e is not None and type(e).__name__ == want:
                corpus, err, changed = cand, e, True
            if attempts >= 48:
                break
    return corpus, err, attempts


def _report(round_idx, seed, knobs, corpus, err, attempts) -> FuzzFailure:
    router = getattr(err, "_fuzz_router", None)
    return FuzzFailure(
        round=round_idx,
        seed=seed,
        knobs=knobs,
        corpus=[
            {"program_id": tr.program_id,
             "steps": [asdict(s) for s in tr.steps]}
            for tr in corpus
        ],
        error_type=type(err).__name__,
        error=str(err),
        kvsan_trace=list(getattr(err, "trace", [])),
        actions=[repr(a) for a in getattr(router, "action_log", [])][-64:],
        shrink_attempts=attempts,
    )


def fuzz(
    rounds: int = 8,
    seed: int = 0,
    out_dir: str | None = None,
    *,
    compile_audit: bool = False,
    log=print,
) -> list[FuzzFailure]:
    """Run ``rounds`` randomized replays; returns failure reports (empty
    means clean). Arms kvsan for every pool built in this process; with
    ``compile_audit`` also arms the compile tracker and fails any round
    whose replay compiles a hot-path jit after warmup."""
    os.environ[kvsan.ENV_VAR] = "1"
    if compile_audit:
        os.environ[compile_tracker.ENV_VAR] = "1"
    from repro.configs import get_config
    from repro.models import Model, materialize

    cfg = get_config("qwen1.5-0.5b").reduced()
    params = materialize(Model(cfg).describe(), seed=0)
    failures: list[FuzzFailure] = []
    for r in range(rounds):
        rng = random.Random((seed << 16) ^ r)
        knobs = _make_knobs(rng)
        corpus = _make_corpus(rng, r)
        err = _run_once(knobs, corpus, cfg, params, audit=compile_audit)
        if err is None:
            log(f"round {r}: ok ({knobs['scheduler']}, "
                f"{'sync' if knobs['sync_transfers'] else 'async'}, "
                f"{'serial' if knobs['serial_decode'] else 'pump'}"
                f"{', chunked' if knobs['chunked_prefill'] else ''}"
                f"{', compile-audited' if compile_audit else ''}, "
                f"{len(corpus)} programs)")
            continue
        corpus, err, attempts = _shrink(knobs, corpus, err, cfg, params,
                                        audit=compile_audit)
        rep = _report(r, seed, knobs, corpus, err, attempts)
        failures.append(rep)
        log(f"round {r}: FAIL {rep.error_type}: {rep.error.splitlines()[0]}")
        if out_dir:
            os.makedirs(out_dir, exist_ok=True)
            path = os.path.join(out_dir, f"fuzz_failure_round{r}.json")
            with open(path, "w") as f:
                json.dump(asdict(rep), f, indent=2)
            log(f"  artifact: {path}")
    return failures


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.fuzz",
        description="randomized kvsan-armed replay fuzz over the router",
    )
    ap.add_argument("--rounds", type=int, default=8)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--out", default="artifacts")
    ap.add_argument(
        "--compile-audit", action="store_true",
        help="arm REPRO_JITAUDIT: warm each engine's bucket specs and "
             "fail any round that compiles a hot-path jit mid-replay",
    )
    args = ap.parse_args(argv)
    failures = fuzz(args.rounds, args.seed, args.out,
                    compile_audit=args.compile_audit)
    if failures:
        print(f"{len(failures)}/{args.rounds} rounds failed")
        return 1
    print(f"clean: {args.rounds} rounds")
    return 0


if __name__ == "__main__":
    sys.exit(main())
