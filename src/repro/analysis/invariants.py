"""Control-plane invariant checker for the scheduler / router seam.

Two cooperating pieces, both constructed only when ``REPRO_KVSAN=1``:

* :class:`LedgerAuditor` — an observer installed on the scheduler's
  :class:`~repro.core.ledger.TransferLedger`. It sees every ``open`` /
  ``complete`` / ``cancel`` / ``drop`` as it happens and raises
  :class:`InvariantError` the moment a record's lifecycle goes wrong:
  an action id reopened after closing, a completion ack for a record
  that never opened, a record completed twice, or a ``CancelTransfer``
  landing on a record that is not open.  The one tolerated race is a
  *completion after cancel/drop*: the runtime's ack may already be in
  flight when the scheduler cancels, and the ledger documents that
  unknown-id completions are dropped on the floor.

* :class:`ControlPlaneChecker` — the router-tick sweep.  After every
  applied plan and at every scheduler tick it re-derives tier occupancy
  from the resident program sets and cross-checks the placement table
  (``prog.tier`` / ``prog.replica``) against actual queue membership;
  at end of replay :meth:`assert_drained` demands the ledger be empty
  (every emitted transfer was acked, cancelled, or dropped — i.e. every
  ``PlacementPlan`` action reached a terminal state).

Violations carry the auditor's recent ledger-operation trace so the
offending pid / action id is one read away.
"""
from __future__ import annotations

from collections import deque

from repro.core.types import Tier


class InvariantError(AssertionError):
    """A control-plane invariant was violated; carries the recent
    ledger-operation trace for post-mortem."""

    def __init__(self, msg: str, trace=()):
        self.trace = list(trace)
        if self.trace:
            msg += "\n  recent ledger ops (oldest first):\n" + "\n".join(
                f"    {e}" for e in self.trace
            )
        super().__init__(msg)


class LedgerAuditor:
    """Observer on ``TransferLedger``: every record opens once and reaches
    exactly one terminal state (completed / cancelled / dropped)."""

    def __init__(self, trace_len: int = 128):
        self.ops: deque[str] = deque(maxlen=trace_len)
        self._closed: dict[int, str] = {}   # action_id -> terminal state

    # -------------------------------------------------- observer protocol
    def on_open(self, rec) -> None:
        self.ops.append(
            f"open #{rec.action_id} {rec.kind} pid={rec.pid} "
            f"r={rec.replica} {rec.nbytes}B @{rec.opened_at:.3f}"
        )
        prior = self._closed.get(rec.action_id)
        if prior is not None:
            raise InvariantError(
                f"transfer record #{rec.action_id} (pid={rec.pid}) "
                f"reopened after being {prior} — action ids must be "
                f"single-use",
                self.ops,
            )

    def on_complete(self, action_id: int, rec) -> None:
        self.ops.append(
            f"complete #{action_id}"
            + (f" pid={rec.pid}" if rec is not None else " (not open)")
        )
        if rec is not None:
            self._closed[action_id] = "completed"
            return
        prior = self._closed.get(action_id)
        if prior is None:
            raise InvariantError(
                f"completion ack for action #{action_id} that was never "
                f"opened in the ledger",
                self.ops,
            )
        if prior == "completed":
            raise InvariantError(
                f"transfer record #{action_id} completed twice",
                self.ops,
            )
        # completed after cancel/drop: the documented benign race — the
        # runtime's ack was already in flight when the scheduler closed
        # the record.

    def on_cancel(self, action_id: int, rec) -> None:
        self.ops.append(
            f"cancel #{action_id}"
            + (f" pid={rec.pid}" if rec is not None else " (not open)")
        )
        if rec is None:
            prior = self._closed.get(action_id, "never opened")
            raise InvariantError(
                f"CancelTransfer targeted action #{action_id} which is not "
                f"open (prior state: {prior})",
                self.ops,
            )
        self._closed[action_id] = "cancelled"

    def on_drop(self, recs) -> None:
        for rec in recs:
            self.ops.append(f"drop #{rec.action_id} pid={rec.pid}")
            self._closed[rec.action_id] = "dropped"


class ControlPlaneChecker:
    """Scheduler-state sweep run from the router's tick / apply_plan."""

    def __init__(self, sched):
        self.sched = sched
        self.auditor = LedgerAuditor()
        sched.ledger.observer = self.auditor

    def check(self, now: float = 0.0) -> None:
        """Re-derive tier occupancy and placement consistency from scratch
        and compare against the scheduler's accounting."""
        sched = self.sched
        trace = self.auditor.ops
        for rep in sched.replicas:
            named = (
                ("gpu", rep.gpu, rep.gpu_used),
                ("cpu", rep.cpu, rep.cpu_used),
                ("ssd", rep.ssd, rep.ssd_used),
            )
            for name, q, used in named:
                want = sum(p.kv_bytes for p in q.values())
                if used != want:
                    raise InvariantError(
                        f"replica {rep.replica_id} {name} occupancy "
                        f"conservation broken at t={now:.3f}: accounted "
                        f"{used}B != Σ resident {want}B over pids "
                        f"{sorted(q)}",
                        trace,
                    )
            for i in range(len(named)):
                for j in range(i + 1, len(named)):
                    both = set(named[i][1]) & set(named[j][1])
                    if both:
                        raise InvariantError(
                            f"replica {rep.replica_id}: programs resident "
                            f"on both {named[i][0]} and {named[j][0]} at "
                            f"t={now:.3f}: {sorted(both)}",
                            trace,
                        )
        for pid, prog in sched.programs.items():
            if prog.tier is Tier.WAITING:
                if pid not in sched.waiting.programs:
                    raise InvariantError(
                        f"program {pid} claims tier=waiting but is not in "
                        f"the waiting queue at t={now:.3f}",
                        trace,
                    )
            elif prog.tier in (Tier.GPU, Tier.CPU, Tier.SSD):
                if prog.replica is None:
                    raise InvariantError(
                        f"program {pid} claims tier={prog.tier.value} with "
                        f"no replica at t={now:.3f}",
                        trace,
                    )
                q = getattr(sched.replicas[prog.replica], prog.tier.value)
                if pid not in q:
                    raise InvariantError(
                        f"program {pid} claims tier={prog.tier.value} on "
                        f"replica {prog.replica} but is not in that queue "
                        f"at t={now:.3f}",
                        trace,
                    )
        for rec in sched.ledger.in_flight():
            if rec.pid not in sched.programs:
                raise InvariantError(
                    f"open transfer #{rec.action_id} references unknown "
                    f"program {rec.pid} at t={now:.3f} (drop_pid missed "
                    f"it on teardown)",
                    trace,
                )

    def assert_drained(self) -> None:
        """End of replay: every opened record must have closed."""
        recs = self.sched.ledger.in_flight()
        if recs:
            desc = ", ".join(
                f"#{r.action_id} {r.kind} pid={r.pid} r={r.replica}"
                for r in recs
            )
            raise InvariantError(
                f"{len(recs)} transfer record(s) still open at end of "
                f"replay: {desc}",
                self.auditor.ops,
            )
