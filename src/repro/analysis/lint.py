"""Custom AST lint encoding the invariants this repo keeps re-learning.

Run as ``python -m repro.analysis.lint [paths...]`` (defaults to
``src tests benchmarks examples``). Exit code 1 when violations exist.

Rules (suppress a finding with a ``# lint: <rule>-ok`` marker on the
flagged line):

* **KV001 donated-reuse** — an array passed to a jitted call whose
  ``donate_argnums`` covers it is *invalidated* by that call; any later
  read of the same name in the function is a use-after-donate (the bug
  class behind the PR 5 decode-buffer clobber).
* **KV002 lru-cache-hashable** — ``functools.lru_cache`` keys must be
  hashable and immutable: parameters must be annotated, never with a
  known-unhashable container type, and any repo dataclass used as a key
  must be ``frozen=True`` (a mutable dataclass hashes by identity or
  not at all, silently splitting or poisoning the cache).
* **KV003 action-exhaustive** — an ``isinstance`` dispatch chain over
  the :mod:`repro.core.actions` union must either name every action
  type or carry an ``else`` branch; otherwise a newly added action is
  silently dropped by that executor (the ``apply_plan`` family).
* **KV004 pin-paired** — a scope (class or module) that calls
  ``tree.pin()`` / ``tree.acquire_nodes()`` must also contain the
  matching ``unpin()``/``release_program()`` / ``release_nodes()``
  call; a pin with no release in sight leaks refcounts and wedges
  eviction.
* **KV005 wall-clock** — modules under ``repro/core`` or ``repro/sim``
  run on the replay's *virtual clock*; ``time.time()`` /
  ``time.monotonic()`` / ``datetime.now()`` there silently couples
  policy decisions to the host's wall clock. (``perf_counter`` is
  allowed: it measures real compute overhead, which is the point.)
* **KV006 jit-shape-branch** — Python ``if``/``while`` on ``.shape`` /
  ``len()`` / ``.ndim`` inside a function handed directly to
  ``jax.jit`` recompiles per shape; either bucket the shapes
  deliberately (and mark the line) or hoist the branch out of the
  jitted body.
* **KV007 decorated-donated-reuse** — the decorator-form complement of
  KV001: an argument passed at a donated position of a
  ``@partial(jax.jit, donate_argnums=...)`` function is invalidated
  when the call returns; reading it afterwards is a use-after-donate.
  (The compile-plane side — whether XLA actually honored the donation —
  is ``python -m repro.analysis.jitaudit``.)
* **KV008 format-aware-sizing** — KV pages carry per-tier formats
  (device bf16/int8, offload bf16/int8), so byte math must go through
  the format-aware helpers (:mod:`repro.kernels.kv_quant`,
  ``PagePool.host_page_bytes``, ``ProgramState.host_kv_bytes`` /
  ``host_bytes_per_token``). Two shapes are flagged: (a) a
  multiplication that prices a host/offload/wire quantity with a
  *device-format* size attribute (``page_bytes`` / ``kv_bytes`` /
  ``kv_bytes_per_token``) — the exact bug class where an int8 offload
  is billed at bf16 size; (b) a byte-quantity expression that
  multiplies model geometry (``num_layers``/``num_kv_heads``/
  ``head_dim``) by a literal ``2`` — a silent bf16 bytes-per-element
  assumption. Suppress deliberate device-side math with
  ``# lint: kv008-ok``.
"""
from __future__ import annotations

import argparse
import ast
import os
import sys
from dataclasses import dataclass

_DEFAULT_PATHS = ("src", "tests", "benchmarks", "examples")

#: the Action union (see ``repro.core.actions._ACTION_TYPES``) — kept as a
#: literal so the linter never imports runtime code
ACTION_NAMES = frozenset(
    {"Forward", "Offload", "Discard", "Migrate", "SetLabel", "CancelTransfer"}
)

_UNHASHABLE = frozenset(
    {"list", "dict", "set", "bytearray", "List", "Dict", "Set",
     "ndarray", "Array", "array"}
)
_WALLCLOCK_TIME_ATTRS = frozenset({"time", "monotonic", "localtime"})
_WALLCLOCK_DT_ATTRS = frozenset({"now", "utcnow", "today"})
_PIN_CALLS = frozenset({"pin", "acquire_nodes"})
_UNPIN_CALLS = frozenset({"unpin", "release_program", "release_nodes"})


@dataclass
class Violation:
    path: str
    line: int
    rule: str
    msg: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.msg}"


def _dotted(node) -> str | None:
    """``a.b.c`` attribute chains as a string (None for anything else)."""
    parts = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _is_jax_jit(func) -> bool:
    d = _dotted(func)
    return d in ("jax.jit", "jit")


def _is_partial(func) -> bool:
    d = _dotted(func)
    return d in ("functools.partial", "partial")


def _suppressed(lines: list[str], lineno: int, rule_key: str) -> bool:
    if 1 <= lineno <= len(lines):
        return f"lint: {rule_key}-ok" in lines[lineno - 1]
    return False


def _ann_base(ann) -> str | None:
    """The base type name of an annotation expression."""
    if ann is None:
        return None
    if isinstance(ann, ast.Subscript):
        return _ann_base(ann.value)
    if isinstance(ann, ast.Attribute):
        return ann.attr
    if isinstance(ann, ast.Name):
        return ann.id
    if isinstance(ann, ast.Constant) and isinstance(ann.value, str):
        head = ann.value.split("[", 1)[0].strip()
        return head.rsplit(".", 1)[-1] or None
    if isinstance(ann, ast.BinOp):            # X | None unions
        return _ann_base(ann.left)
    return None


# --------------------------------------------------------------------------
# module pre-pass: dataclass registry (name -> frozen?) across all files
# --------------------------------------------------------------------------
def _index_dataclasses(tree: ast.Module, registry: dict[str, bool]) -> None:
    for node in ast.walk(tree):
        if not isinstance(node, ast.ClassDef):
            continue
        for dec in node.decorator_list:
            target = dec.func if isinstance(dec, ast.Call) else dec
            if _dotted(target) not in ("dataclass", "dataclasses.dataclass"):
                continue
            frozen = False
            if isinstance(dec, ast.Call):
                for kw in dec.keywords:
                    if kw.arg == "frozen" and isinstance(kw.value, ast.Constant):
                        frozen = bool(kw.value.value)
            registry[node.name] = frozen


# --------------------------------------------------------------------------
# KV001 donated-reuse
# --------------------------------------------------------------------------
def _donated_kw(call: ast.Call) -> tuple[int, ...]:
    """The literal ``donate_argnums`` positions of a jit call, if any."""
    donated: tuple[int, ...] = ()
    for kw in call.keywords:
        if kw.arg != "donate_argnums":
            continue
        if isinstance(kw.value, ast.Tuple):
            donated = tuple(
                e.value for e in kw.value.elts
                if isinstance(e, ast.Constant) and isinstance(e.value, int)
            )
        elif isinstance(kw.value, ast.Constant) and isinstance(
            kw.value.value, int
        ):
            donated = (kw.value.value,)
    return donated


def _donated_targets(tree: ast.Module) -> dict[tuple[str, str], tuple[int, ...]]:
    """Map a callable's reference key -> donated positional indices, from
    ``X = jax.jit(fn, donate_argnums=(...))`` assignments."""
    out: dict[tuple[str, str], tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Assign) and isinstance(node.value, ast.Call)):
            continue
        call = node.value
        if not _is_jax_jit(call.func):
            continue
        donated = _donated_kw(call)
        if not donated:
            continue
        for tgt in node.targets:
            if isinstance(tgt, ast.Name):
                out[("name", tgt.id)] = donated
            elif (
                isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
            ):
                out[("self", tgt.attr)] = donated
    return out


def _expr_key(node) -> tuple[str, str] | None:
    if isinstance(node, ast.Name):
        return ("name", node.id)
    if (
        isinstance(node, ast.Attribute)
        and isinstance(node.value, ast.Name)
        and node.value.id == "self"
    ):
        return ("self", node.attr)
    return None


def _refs_of(func: ast.FunctionDef, key: tuple[str, str]):
    for node in ast.walk(func):
        if key[0] == "name" and isinstance(node, ast.Name) and node.id == key[1]:
            yield node
        elif (
            key[0] == "self"
            and isinstance(node, ast.Attribute)
            and node.attr == key[1]
            and isinstance(node.value, ast.Name)
            and node.value.id == "self"
        ):
            yield node


def _enclosing_stmt(func: ast.FunctionDef, call: ast.Call) -> ast.stmt | None:
    best = None
    for node in ast.walk(func):
        if not isinstance(node, ast.stmt):
            continue
        if node.lineno <= call.lineno and (node.end_lineno or 0) >= (
            call.end_lineno or call.lineno
        ):
            if best is None or node.lineno >= best.lineno:
                best = node
    return best


def _reuse_after_call(
    path: str, func: ast.FunctionDef, call: ast.Call,
    donated: tuple[int, ...], lines: list[str], *, rule: str,
    rule_key: str, callee_desc: str,
) -> list[Violation]:
    """Flag reads of a donated call argument after the call returns —
    shared engine for KV001 (assignment-form jits) and KV007 (decorator-
    form jits).  A Store to the name inside the call's own statement
    (``x, y = fn(..., x, y)``) or any later rebinding clears the taint."""
    out: list[Violation] = []
    stmt = _enclosing_stmt(func, call)
    for pos in donated:
        if pos >= len(call.args):
            continue
        akey = _expr_key(call.args[pos])
        if akey is None:
            continue
        call_end = (call.end_lineno or call.lineno,
                    call.end_col_offset or 0)
        if stmt is not None and any(
            isinstance(r.ctx, ast.Store)
            for r in _refs_of(func, akey)
            if stmt.lineno <= r.lineno <= (stmt.end_lineno or 0)
            and (r.lineno, r.col_offset) < (call.lineno, call.col_offset)
        ):
            continue
        after = sorted(
            (
                r
                for r in _refs_of(func, akey)
                if (r.lineno, r.col_offset) > call_end
            ),
            key=lambda r: (r.lineno, r.col_offset),
        )
        for ref in after:
            if isinstance(ref.ctx, ast.Store):
                break                   # rebound: donation resolved
            if not _suppressed(lines, ref.lineno, rule_key):
                name = akey[1] if akey[0] == "name" else f"self.{akey[1]}"
                out.append(Violation(
                    path, ref.lineno, rule,
                    f"`{name}` is read after being donated to "
                    f"{callee_desc} on line {call.lineno} "
                    f"(donate_argnums position {pos}); the buffer "
                    f"is invalidated by donation — rebind the "
                    f"call's result first",
                ))
            break
    return out


def check_donated_reuse(
    path: str, tree: ast.Module, lines: list[str], registry
) -> list[Violation]:
    del registry
    targets = _donated_targets(tree)
    if not targets:
        return []
    out: list[Violation] = []
    for func in ast.walk(tree):
        if not isinstance(func, ast.FunctionDef):
            continue
        for call in ast.walk(func):
            if not isinstance(call, ast.Call):
                continue
            ckey = _expr_key(call.func)
            if ckey not in targets:
                continue
            out += _reuse_after_call(
                path, func, call, targets[ckey], lines,
                rule="KV001", rule_key="donated-reuse",
                callee_desc="the jitted call",
            )
    return out


# --------------------------------------------------------------------------
# KV007 decorated-donated-reuse
# --------------------------------------------------------------------------
def _decorator_donated(tree: ast.Module) -> dict[str, tuple[int, ...]]:
    """Function name -> donated positional indices for *decorator-form*
    donating jits — ``@partial(jax.jit, donate_argnums=...)`` and
    ``@jax.jit(donate_argnums=...)`` — the forms KV001's assignment
    scanner cannot see.  Methods (first parameter ``self``/``cls``) are
    skipped: their donate positions count the receiver, which call sites
    do not spell as an argument."""
    out: dict[str, tuple[int, ...]] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.FunctionDef):
            continue
        args = node.args.posonlyargs + node.args.args
        if args and args[0].arg in ("self", "cls"):
            continue
        for dec in node.decorator_list:
            if not isinstance(dec, ast.Call):
                continue
            is_jit_dec = _is_jax_jit(dec.func) or (
                _is_partial(dec.func) and dec.args
                and _is_jax_jit(dec.args[0])
            )
            if not is_jit_dec:
                continue
            donated = _donated_kw(dec)
            if donated:
                out[node.name] = donated
    return out


def check_decorated_donated_reuse(
    path: str, tree: ast.Module, lines: list[str], registry
) -> list[Violation]:
    """KV007: the decorator-form complement of KV001 (and the Python-side
    complement of jitaudit's donation verifier) — an argument passed at a
    donated position of a ``@partial(jax.jit, donate_argnums=...)``
    function is invalidated when the call returns; any later read of the
    same name is a use-after-donate."""
    del registry
    targets = _decorator_donated(tree)
    if not targets:
        return []
    out: list[Violation] = []
    for func in ast.walk(tree):
        if not isinstance(func, ast.FunctionDef):
            continue
        for call in ast.walk(func):
            if not isinstance(call, ast.Call):
                continue
            d = _dotted(call.func)
            fname = d.rsplit(".", 1)[-1] if d else None
            if fname not in targets:
                continue
            out += _reuse_after_call(
                path, func, call, targets[fname], lines,
                rule="KV007", rule_key="decorated-donated-reuse",
                callee_desc=f"decorator-jitted `{fname}`",
            )
    return out


# --------------------------------------------------------------------------
# KV002 lru-cache-hashable
# --------------------------------------------------------------------------
def _is_cache_decorator(dec) -> bool:
    target = dec.func if isinstance(dec, ast.Call) else dec
    return _dotted(target) in (
        "lru_cache", "functools.lru_cache", "cache", "functools.cache",
    )


def check_lru_cache_hashable(
    path: str, tree: ast.Module, lines: list[str], registry: dict[str, bool]
) -> list[Violation]:
    out: list[Violation] = []
    for func in ast.walk(tree):
        if not isinstance(func, ast.FunctionDef):
            continue
        if not any(_is_cache_decorator(d) for d in func.decorator_list):
            continue
        if _suppressed(lines, func.lineno, "lru-cache-hashable"):
            continue
        all_args = func.args.posonlyargs + func.args.args + func.args.kwonlyargs
        for arg in all_args:
            if arg.arg in ("self", "cls"):
                continue
            if arg.annotation is None:
                out.append(Violation(
                    path, func.lineno, "KV002",
                    f"cached function `{func.name}` has unannotated "
                    f"parameter `{arg.arg}` — cache keys must be "
                    f"demonstrably hashable (annotate it)",
                ))
                continue
            base = _ann_base(arg.annotation)
            if base in _UNHASHABLE:
                out.append(Violation(
                    path, func.lineno, "KV002",
                    f"cached function `{func.name}` keys on unhashable "
                    f"`{base}` parameter `{arg.arg}`",
                ))
            elif base in registry and not registry[base]:
                out.append(Violation(
                    path, func.lineno, "KV002",
                    f"cached function `{func.name}` keys on dataclass "
                    f"`{base}` which is not frozen=True — mutable keys "
                    f"poison or split the cache",
                ))
    return out


# --------------------------------------------------------------------------
# KV003 action-exhaustive
# --------------------------------------------------------------------------
def _isinstance_targets(test) -> set[str]:
    names: set[str] = set()
    for node in ast.walk(test):
        if not (isinstance(node, ast.Call) and _dotted(node.func) == "isinstance"):
            continue
        if len(node.args) != 2:
            continue
        spec = node.args[1]
        elts = spec.elts if isinstance(spec, ast.Tuple) else [spec]
        for e in elts:
            d = _dotted(e)
            if d is not None:
                names.add(d.rsplit(".", 1)[-1])
    return names


def check_action_exhaustive(
    path: str, tree: ast.Module, lines: list[str], registry
) -> list[Violation]:
    del registry
    out: list[Violation] = []
    elif_nodes: set[int] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.If) and len(node.orelse) == 1 and isinstance(
            node.orelse[0], ast.If
        ):
            elif_nodes.add(id(node.orelse[0]))
    for node in ast.walk(tree):
        if not isinstance(node, ast.If) or id(node) in elif_nodes:
            continue
        covered: set[str] = set()
        cur = node
        has_else = False
        while True:
            covered |= _isinstance_targets(cur.test)
            if len(cur.orelse) == 1 and isinstance(cur.orelse[0], ast.If):
                cur = cur.orelse[0]
                continue
            has_else = bool(cur.orelse)
            break
        handled_actions = covered & ACTION_NAMES
        if len(handled_actions) < 2:
            continue                     # not an Action-union dispatcher
        missing = ACTION_NAMES - covered
        if missing and not has_else:
            if not _suppressed(lines, node.lineno, "action-exhaustive"):
                out.append(Violation(
                    path, node.lineno, "KV003",
                    f"Action dispatch does not handle "
                    f"{sorted(missing)} and has no `else` — a new or "
                    f"unrouted action would be silently dropped",
                ))
    return out


# --------------------------------------------------------------------------
# KV004 pin-paired
# --------------------------------------------------------------------------
def _call_attr_names(scope) -> dict[str, int]:
    """attr-call name -> first line, over a class body or statement list."""
    found: dict[str, int] = {}
    nodes = scope if isinstance(scope, list) else [scope]
    for root in nodes:
        for node in ast.walk(root):
            if isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute):
                found.setdefault(node.func.attr, node.lineno)
    return found


def check_pin_paired(
    path: str, tree: ast.Module, lines: list[str], registry
) -> list[Violation]:
    del registry
    out: list[Violation] = []
    scopes: list[tuple[str, object]] = []
    module_rest = [
        n for n in tree.body if not isinstance(n, ast.ClassDef)
    ]
    scopes.append(("module scope", module_rest))
    for node in ast.walk(tree):
        if isinstance(node, ast.ClassDef):
            scopes.append((f"class {node.name}", node))
    for label, scope in scopes:
        calls = _call_attr_names(scope)
        pins = {c: ln for c, ln in calls.items() if c in _PIN_CALLS}
        has_release = bool(set(calls) & _UNPIN_CALLS)
        if pins and not has_release:
            name, line = min(pins.items(), key=lambda kv: kv[1])
            if _suppressed(lines, line, "pin-paired"):
                continue
            out.append(Violation(
                path, line, "KV004",
                f"`{name}()` called in {label} with no matching "
                f"unpin()/release_program()/release_nodes() anywhere in "
                f"the scope — leaked refcounts wedge eviction",
            ))
    return out


# --------------------------------------------------------------------------
# KV005 wall-clock
# --------------------------------------------------------------------------
def _virtual_clock_module(path: str) -> bool:
    p = path.replace(os.sep, "/")
    return "repro/core/" in p or "repro/sim/" in p


def check_wall_clock(
    path: str, tree: ast.Module, lines: list[str], registry
) -> list[Violation]:
    del registry
    if not _virtual_clock_module(path):
        return []
    out: list[Violation] = []
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call) and isinstance(node.func, ast.Attribute)):
            continue
        attr = node.func.attr
        base = _dotted(node.func.value) or ""
        base_tail = base.rsplit(".", 1)[-1].lstrip("_")
        bad = (
            (attr in _WALLCLOCK_TIME_ATTRS and base_tail == "time")
            or (attr in _WALLCLOCK_DT_ATTRS and base_tail in ("datetime", "date"))
        )
        if bad and not _suppressed(lines, node.lineno, "wall-clock"):
            out.append(Violation(
                path, node.lineno, "KV005",
                f"`{base}.{attr}()` in a virtual-clock module — scheduler "
                f"and simulator time must come from the replay clock, "
                f"never the host's wall clock",
            ))
    return out


# --------------------------------------------------------------------------
# KV006 jit-shape-branch
# --------------------------------------------------------------------------
def _jitted_function_names(tree: ast.Module) -> set[str]:
    partial_of: dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            if _is_partial(node.value.func) and node.value.args:
                inner = _dotted(node.value.args[0])
                if inner is not None:
                    for tgt in node.targets:
                        if isinstance(tgt, ast.Name):
                            partial_of[tgt.id] = inner.rsplit(".", 1)[-1]
    names: set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call) and _is_jax_jit(node.func) and node.args:
            arg = node.args[0]
            if isinstance(arg, ast.Call) and _is_partial(arg.func) and arg.args:
                d = _dotted(arg.args[0])
                if d is not None:
                    names.add(d.rsplit(".", 1)[-1])
            else:
                d = _dotted(arg)
                if d is not None:
                    short = d.rsplit(".", 1)[-1]
                    names.add(partial_of.get(short, short))
        if isinstance(node, ast.FunctionDef):
            for dec in node.decorator_list:
                if _is_jax_jit(dec):
                    names.add(node.name)
                elif isinstance(dec, ast.Call) and (
                    _is_jax_jit(dec.func)
                    or (_is_partial(dec.func) and dec.args
                        and _is_jax_jit(dec.args[0]))
                ):
                    names.add(node.name)
    return names


def _shape_dependent(test) -> bool:
    for node in ast.walk(test):
        if isinstance(node, ast.Attribute) and node.attr in ("shape", "ndim"):
            return True
        if (
            isinstance(node, ast.Call)
            and isinstance(node.func, ast.Name)
            and node.func.id == "len"
        ):
            return True
    return False


def check_jit_shape_branch(
    path: str, tree: ast.Module, lines: list[str], registry
) -> list[Violation]:
    del registry
    jitted = _jitted_function_names(tree)
    if not jitted:
        return []
    out: list[Violation] = []
    for func in ast.walk(tree):
        if not isinstance(func, ast.FunctionDef) or func.name not in jitted:
            continue
        for node in ast.walk(func):
            if not isinstance(node, (ast.If, ast.While)):
                continue
            if _shape_dependent(node.test) and not _suppressed(
                lines, node.lineno, "jit-shape-branch"
            ):
                out.append(Violation(
                    path, node.lineno, "KV006",
                    f"shape-dependent Python branch inside jitted "
                    f"`{func.name}` — compiles once per shape (silent "
                    f"recompile hazard); bucket the shapes and mark the "
                    f"line `# lint: jit-shape-branch-ok` if deliberate",
                ))
    return out


# --------------------------------------------------------------------------
# KV008 format-aware-sizing
# --------------------------------------------------------------------------
#: device-format size attributes — pricing a host/offload/wire quantity with
#: one of these bills an int8 copy at bf16 size
_KV008_DEVICE_ATTRS = frozenset({"page_bytes", "kv_bytes", "kv_bytes_per_token"})
#: identifier fragments that mark a statement as pricing an *offload-side*
#: quantity (host tier budgets, wire transfers, NVMe reloads)
_KV008_OFFLOAD_HINTS = (
    "host", "cpu", "ssd", "wire", "offload", "reload", "nvme", "dram",
)
_KV008_GEOMETRY = frozenset({"num_layers", "num_kv_heads", "num_heads",
                             "head_dim"})


def _kv008_exempt(path: str) -> bool:
    """The sizing helpers themselves are the one sanctioned place for raw
    bytes-per-element arithmetic."""
    p = path.replace(os.sep, "/")
    return p.endswith("repro/kernels/kv_quant.py")


def _own_exprs(stmt: ast.stmt) -> list[ast.AST]:
    """A statement's direct expression children — excludes nested
    statements, so each expression is examined exactly once, in the
    context of the statement that actually spells it."""
    return [
        c for c in ast.iter_child_nodes(stmt) if not isinstance(c, ast.stmt)
    ]


def _idents(nodes: list[ast.AST]) -> set[str]:
    """Every identifier fragment in the expressions, lowercased."""
    idents: set[str] = set()
    for root in nodes:
        for node in ast.walk(root):
            if isinstance(node, ast.Name):
                idents.add(node.id.lower())
            elif isinstance(node, ast.Attribute):
                idents.add(node.attr.lower())
    return idents


def _topmost_mults(exprs: list[ast.AST]) -> list[ast.BinOp]:
    """Multiplication subtrees, outermost chain only — ``a * b * c``
    reports once, not once per nested BinOp."""
    mults = [
        n for root in exprs for n in ast.walk(root)
        if isinstance(n, ast.BinOp) and isinstance(n.op, ast.Mult)
    ]
    inner = {
        id(side)
        for m in mults
        for side in (m.left, m.right)
        if isinstance(side, ast.BinOp) and isinstance(side.op, ast.Mult)
    }
    return [m for m in mults if id(m) not in inner]


def check_format_aware_sizing(
    path: str, tree: ast.Module, lines: list[str], registry
) -> list[Violation]:
    del registry
    if _kv008_exempt(path):
        return []
    out: list[Violation] = []
    for stmt in ast.walk(tree):
        if not isinstance(stmt, ast.stmt):
            continue
        exprs = _own_exprs(stmt)
        mults = _topmost_mults(exprs)
        if not mults:
            continue
        ctx = _idents(exprs)
        hinted = any(any(h in ident for ident in ctx)
                     for h in _KV008_OFFLOAD_HINTS)
        byteish = any("bytes" in ident for ident in ctx)
        for m in mults:
            sub_attrs = {
                n.attr for n in ast.walk(m) if isinstance(n, ast.Attribute)
            }
            sub_names = sub_attrs | {
                n.id for n in ast.walk(m) if isinstance(n, ast.Name)
            }
            has_two = any(
                isinstance(n, ast.Constant) and n.value == 2
                for n in ast.walk(m)
            )
            if _suppressed(lines, m.lineno, "kv008"):
                continue
            dev = sub_attrs & _KV008_DEVICE_ATTRS
            if dev and hinted:
                out.append(Violation(
                    path, m.lineno, "KV008",
                    f"host/offload/wire quantity priced with device-format "
                    f"`{sorted(dev)[0]}` — with an int8 offload format this "
                    f"bills the wrong byte count; use host_page_bytes / "
                    f"host_kv_bytes / kv_quant wire helpers (or mark "
                    f"`# lint: kv008-ok` if device-side math is intended)",
                ))
            elif has_two and (
                (byteish and sub_names & _KV008_GEOMETRY)
                or len(sub_names & _KV008_GEOMETRY) >= 2
            ):
                out.append(Violation(
                    path, m.lineno, "KV008",
                    f"byte sizing multiplies model geometry by literal 2 — "
                    f"a bf16 bytes-per-element assumption that breaks under "
                    f"int8 tiers; use kv_quant.bytes_per_element / "
                    f"token_wire_bytes (or mark `# lint: kv008-ok`)",
                ))
    return out


# --------------------------------------------------------------------------
# driver
# --------------------------------------------------------------------------
RULES = (
    check_donated_reuse,
    check_decorated_donated_reuse,
    check_lru_cache_hashable,
    check_action_exhaustive,
    check_pin_paired,
    check_wall_clock,
    check_jit_shape_branch,
    check_format_aware_sizing,
)


def _gather(paths) -> list[str]:
    files: list[str] = []
    for path in paths:
        if os.path.isfile(path) and path.endswith(".py"):
            files.append(path)
            continue
        for root, dirs, names in os.walk(path):
            dirs[:] = [
                d for d in dirs if not d.startswith(".") and d != "__pycache__"
            ]
            files.extend(
                os.path.join(root, n) for n in names if n.endswith(".py")
            )
    return sorted(files)


def run(paths) -> list[Violation]:
    """Lint ``paths`` (files or directories); returns all violations."""
    files = _gather(paths)
    registry: dict[str, bool] = {}
    parsed: list[tuple[str, ast.Module, list[str]]] = []
    out: list[Violation] = []
    for f in files:
        try:
            with open(f, encoding="utf-8") as fh:
                src = fh.read()
            tree = ast.parse(src, filename=f)
        except SyntaxError as e:
            out.append(Violation(f, e.lineno or 0, "KV000",
                                 f"syntax error: {e.msg}"))
            continue
        parsed.append((f, tree, src.splitlines()))
        _index_dataclasses(tree, registry)
    for f, tree, lines in parsed:
        for rule in RULES:
            out.extend(rule(f, tree, lines, registry))
    out.sort(key=lambda v: (v.path, v.line, v.rule))
    return out


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="repo-specific AST lint (KV001-KV008)",
    )
    ap.add_argument("paths", nargs="*", help="files or directories to lint")
    args = ap.parse_args(argv)
    paths = args.paths or [p for p in _DEFAULT_PATHS if os.path.isdir(p)]
    if not paths:
        print("lint: no paths to check", file=sys.stderr)
        return 2
    violations = run(paths)
    for v in violations:
        print(v)
    n_files = len(_gather(paths))
    if violations:
        print(f"\n{len(violations)} violation(s) in {n_files} file(s)")
        return 1
    print(f"clean: {n_files} file(s), {len(RULES)} rules")
    return 0


if __name__ == "__main__":
    sys.exit(main())
