"""JITAUDIT — static auditor over the hot-path jits' jaxprs and HLO.

The serving numbers only hold while three compile-plane properties do,
none of which ordinary tests observe:

1. **donation** — ``donate_argnums`` on the decode/chunk fns is what
   makes the KV-pool scatter an in-place update.  A donation XLA cannot
   honor (output dtype/shape drifted from the donated input) degrades
   silently to a full pool copy per step; jax prints a warning once and
   the replay still passes every token-equivalence test.
2. **recompile budget** — the pump dispatches from jit's cache; one
   unbucketed shape mid-replay stalls every live slot for a full XLA
   compile.  After ``Engine.warmup()`` a replay must compile nothing.
3. **static roofline** — scheduling policy (and the paper's idle-window
   model) assumes per-step FLOPs/bytes that nobody re-derives when the
   model or kernels change.

This module audits all three *statically*, against what jit actually
traced and XLA actually compiled:

* **donation verifier** — counts the donated array leaves a target
  requests, the ``tf.aliasing_output`` marks the lowered StableHLO
  kept, and the ``input_output_alias`` pairs the compiled module
  honors; any narrowing step is a violation with the dropped avals.
* **retrace-hazard scan** — weak-typed invars (a Python scalar at the
  call site retraces per value-type), closure-captured arrays baked in
  as jaxpr constants (pool snapshots frozen at trace time), and
  structural probes: two same-rank bucket shapes must trace to the
  same primitive sequence, else some Python branch is shape-dependent
  and every new bucket is a surprise recompile.
* **static roofline** — a loop-aware jaxpr walk (scan bodies multiply
  by trip count) tallying dot FLOPs and touched HBM bytes per bucket,
  cross-checked against ``compiled.cost_analysis()`` (XLA's own count,
  while-bodies once, whole-operand bytes) and
  :func:`repro.launch.hlo_cost.analyze` (loop- and utilization-aware);
  ratios outside the documented bands fail the audit.  Emitted as
  ``artifacts/STATIC_roofline.json``.

CLI (the CI ``compile-audit`` job)::

    PYTHONPATH=src python -m repro.analysis.jitaudit \
        --out artifacts/STATIC_roofline.json

audits the engine warmup set (dense + paged + chunked prefill) and the
three kernel dispatches, runs the seeded-violation selftest (a broken
donation and a shape-branching fn MUST be caught — the auditor audits
itself), then replays a small corpus through the pump under the compile
tracker and fails on any post-warmup compile.  Exit 1 on violations.

Tolerance bands (documented, asserted, and recorded in the JSON):

=================  ============  =========================================
ratio              band          why it is loose/tight
=================  ============  =========================================
flops vs hlo_cost  [0.65, 1.60]  both sides are loop-aware dot counts;
                                 disagreement means a lowering rewrote
                                 contractions (calibrated: 1.00 +- 0.01)
flops vs XLA       [0.25, 4.00]  cost_analysis() loop conventions vary by
                                 program — an unrolled scan counts fully,
                                 a while body once (observed 0.9x-3.4x on
                                 this repo's hot paths)
bytes vs hlo_cost  [0.25, 4.00]  different fusion/utilization judgments
bytes vs XLA       [0.01, 1.05]  XLA charges whole operands per op; the
                                 static walk charges touched bytes, so it
                                 must be a lower bound (paged gathers read
                                 pages, not the pool)
=================  ============  =========================================
"""
from __future__ import annotations

import argparse
import json
import os
import re
import sys
import warnings
from dataclasses import dataclass, field

import numpy as np

#: ratio bands, static/reference (see module docstring table)
TOLERANCES = {
    "flops_vs_hlo_cost": (0.65, 1.60),
    "flops_vs_xla": (0.25, 4.00),
    "bytes_vs_hlo_cost": (0.25, 4.00),
    "bytes_vs_xla": (0.01, 1.05),
}

#: a jaxpr constant bigger than this is a baked-in closure capture, not a
#: scalar config value (the pool is megabytes; epsilons are bytes)
CONST_BYTES_LIMIT = 512


@dataclass
class AuditTarget:
    """One jitted hot-path function with example (bucket) arguments.

    ``make_args`` builds the sample call lazily — donation-adjacent
    buffers (the pool view) must be read at trace time, not target-
    construction time.  ``probe_args``, when given, builds a *second*
    bucket shape in the same branch class; the hazard pass asserts both
    trace to the same primitive structure.
    """

    name: str
    fn: object
    make_args: object
    donate_argnums: tuple = ()
    static_argnums: tuple = ()
    bucket: dict = field(default_factory=dict)
    probe_args: object = None


@dataclass
class AuditViolation:
    target: str
    pass_name: str                # donation | retrace-hazard | roofline
    msg: str
    provenance: str = ""

    def __str__(self) -> str:
        s = f"[{self.pass_name}] {self.target}: {self.msg}"
        if self.provenance:
            s += f"\n    provenance: {self.provenance}"
        return s


# --------------------------------------------------------------------------
# tracing
# --------------------------------------------------------------------------
def trace_target(target: AuditTarget):
    """AOT-trace ``target`` (no execution, no buffer donation) and return
    ``(traced, lowered, compiled, captured_warnings)``."""
    args = target.make_args()
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        traced = target.fn.trace(*args)
        lowered = traced.lower()
        compiled = lowered.compile()
    notes = [str(w.message) for w in caught if "donated" in str(w.message)]
    return traced, lowered, compiled, notes


def donated_leaf_count(target: AuditTarget) -> int:
    """Array leaves under the donated argument positions of the sample
    call — what the lowering must mark with ``tf.aliasing_output``."""
    import jax

    args = target.make_args()
    return sum(
        len(jax.tree.leaves(args[i]))
        for i in target.donate_argnums
        if i < len(args)
    )


# --------------------------------------------------------------------------
# pass 1: donation verifier
# --------------------------------------------------------------------------
_MLIR_ALIAS_RE = re.compile(
    r"%arg(\d+):\s*tensor<[^>]*>\s*(?:loc\([^)]*\)\s*)?\{([^}]*)\}"
)
_ALIAS_OUT_RE = re.compile(r"tf\.aliasing_output\s*=\s*(\d+)")
_DONOR_RE = re.compile(r"jax\.buffer_donor\s*=\s*true")


def donation_marks(mlir_text: str) -> dict[int, int]:
    """``{arg_index: output_index}`` for every ``tf.aliasing_output`` mark
    in the lowered module's ``@main`` signature — donations jit kept."""
    start = mlir_text.find("@main(")
    if start < 0:
        start = 0
    # the signature ends at the return-type arrow; scanning to the first
    # function body brace would also work but the arrow is unambiguous
    end = mlir_text.find("->", start)
    sig = mlir_text[start:end if end > 0 else len(mlir_text)]
    out: dict[int, int] = {}
    for m in _MLIR_ALIAS_RE.finditer(sig):
        alias = _ALIAS_OUT_RE.search(m.group(2))
        if alias:
            out[int(m.group(1))] = int(alias.group(1))
    return out


def unmatched_donors(mlir_text: str) -> list[int]:
    """Arg indices marked ``jax.buffer_donor`` (donated, but jit found no
    shape/dtype-compatible output to alias them into)."""
    start = mlir_text.find("@main(")
    end = mlir_text.find("->", max(start, 0))
    sig = mlir_text[max(start, 0):end if end > 0 else len(mlir_text)]
    return [
        int(m.group(1))
        for m in _MLIR_ALIAS_RE.finditer(sig)
        if _DONOR_RE.search(m.group(2))
    ]


def verify_donation(target: AuditTarget, lowered, compiled,
                    notes: list[str]) -> list[AuditViolation]:
    """Every donated leaf must survive lowering (``tf.aliasing_output``)
    and compilation (``input_output_alias``)."""
    if not target.donate_argnums:
        return []
    from repro.launch.hlo_cost import parse_input_output_alias

    expected = donated_leaf_count(target)
    marks = donation_marks(lowered.as_text())
    honored = parse_input_output_alias(compiled.as_text())
    out: list[AuditViolation] = []
    if len(marks) < expected:
        dropped = unmatched_donors(lowered.as_text())
        out.append(AuditViolation(
            target.name, "donation",
            f"{expected - len(marks)} of {expected} donated buffers were "
            f"dropped at lowering — no output shares their shape/dtype, "
            f"so each costs a full copy per call",
            provenance=(
                f"donate_argnums={target.donate_argnums}, lowered marks "
                f"args {sorted(marks)} -> outputs "
                f"{sorted(marks.values())}; unmatched donor args "
                f"{dropped}; jax: {notes or 'no warning captured'}"
            ),
        ))
    # compiled honoring: every lowered mark must appear as an alias pair
    honored_outs = {o for o, _ in honored}
    lost = sorted(set(marks.values()) - honored_outs)
    if lost:
        out.append(AuditViolation(
            target.name, "donation",
            f"lowered donation marks for output(s) {lost} were not honored "
            f"by XLA (missing from the compiled input_output_alias map)",
            provenance=f"compiled aliases: {sorted(honored)}",
        ))
    return out


# --------------------------------------------------------------------------
# pass 2: retrace hazards
# --------------------------------------------------------------------------
def _walk_prims(jaxpr, out: list[str]) -> None:
    """Flatten a jaxpr's primitive sequence, recursing into sub-jaxprs in
    a deterministic order (the structural fingerprint for probes)."""
    for eqn in jaxpr.eqns:
        out.append(eqn.primitive.name)
        name = eqn.primitive.name
        if name == "scan":
            _walk_prims(eqn.params["jaxpr"].jaxpr, out)
        elif name == "while":
            _walk_prims(eqn.params["body_jaxpr"].jaxpr, out)
        elif name == "cond":
            for br in eqn.params["branches"]:
                _walk_prims(br.jaxpr, out)
        else:
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                _walk_prims(getattr(sub, "jaxpr", sub), out)


def prim_signature(closed) -> list[str]:
    out: list[str] = []
    _walk_prims(closed.jaxpr, out)
    return out


def retrace_hazards(target: AuditTarget, traced) -> list[AuditViolation]:
    out: list[AuditViolation] = []
    closed = traced.jaxpr
    # (a) weak-typed invars: a Python scalar at the call site — the next
    # call with a different Python type (or a strong array) retraces
    weak = [
        (i, str(v.aval))
        for i, v in enumerate(closed.jaxpr.invars)
        if getattr(v.aval, "weak_type", False)
    ]
    if weak:
        out.append(AuditViolation(
            target.name, "retrace-hazard",
            f"{len(weak)} weak-typed invar(s) — a Python scalar reached the "
            f"jit boundary; pass a committed array so dtype promotion "
            f"cannot retrace",
            provenance=f"invars {weak}",
        ))
    # (b) closure-captured arrays baked in as constants: a pool snapshot
    # frozen at trace time is both a staleness bug and a retrace per object
    for var, const in zip(closed.jaxpr.constvars, closed.consts):
        nbytes = getattr(const, "nbytes", None)
        if nbytes is None:
            nbytes = np.asarray(const).nbytes
        if nbytes > CONST_BYTES_LIMIT:
            out.append(AuditViolation(
                target.name, "retrace-hazard",
                f"closure-captured array baked into the jaxpr as a "
                f"constant ({nbytes} bytes > {CONST_BYTES_LIMIT}) — pass "
                f"it as an argument instead",
                provenance=f"constvar {var} : {var.aval}",
            ))
    # (c) structural probe: a second bucket shape in the same branch class
    # must trace to the same primitive sequence
    if target.probe_args is not None:
        sig_a = prim_signature(closed)
        sig_b = prim_signature(target.fn.trace(*target.probe_args()).jaxpr)
        if sig_a != sig_b:
            div = next(
                (i for i, (a, b) in enumerate(zip(sig_a, sig_b)) if a != b),
                min(len(sig_a), len(sig_b)),
            )
            ctx_a = sig_a[max(0, div - 2):div + 3]
            ctx_b = sig_b[max(0, div - 2):div + 3]
            out.append(AuditViolation(
                target.name, "retrace-hazard",
                "primitive structure differs between two bucket shapes — "
                "a Python branch depends on the shape, so every bucket "
                "compiles a different program",
                provenance=(
                    f"diverges at eqn {div}: {ctx_a} vs {ctx_b} "
                    f"(lengths {len(sig_a)} vs {len(sig_b)})"
                ),
            ))
    return out


# --------------------------------------------------------------------------
# pass 3: static roofline
# --------------------------------------------------------------------------
#: primitives charged 2 x output bytes (read the touched region, write or
#: forward the result) — mirrors hlo_cost's slice-utilization convention
_GATHERISH = frozenset({"gather", "dynamic_slice", "slice"})
#: primitives charged 2 x update bytes (in-place touched region)
_SCATTERISH = frozenset({"scatter", "scatter-add", "dynamic_update_slice"})
#: primitives charged operand + output bytes (real data movement)
_READWRITE = frozenset({
    "reduce_sum", "reduce_max", "reduce_min", "reduce_prod", "argmax",
    "argmin", "concatenate", "sort", "cumsum", "cumlogsumexp",
})


def _aval_bytes(v) -> int:
    aval = v.aval
    shape = getattr(aval, "shape", ())
    dtype = getattr(aval, "dtype", None)
    if dtype is None:
        return 0
    return int(np.prod(shape, dtype=np.int64)) * dtype.itemsize if shape \
        else dtype.itemsize


@dataclass
class StaticCost:
    flops: float = 0.0
    hbm_bytes: float = 0.0
    eqns: int = 0


def static_cost(closed, *, loop_aware: bool = True) -> StaticCost:
    """Loop-aware FLOPs/bytes from a ClosedJaxpr.

    FLOPs: dot_general only (2 x out_elems x contraction), matching both
    references' dominant term.  Bytes: touched-region model — gathers and
    slices move their *output*, scatters their *update*, dots their
    operands and result; elementwise/layout ops fuse for free on the TPU
    target.  ``loop_aware=False`` reproduces XLA's count-the-body-once
    convention for cross-checking against ``cost_analysis()``.
    """
    acc = StaticCost()

    def walk(jaxpr, mult: float) -> None:
        for eqn in jaxpr.eqns:
            acc.eqns += 1
            name = eqn.primitive.name
            if name == "scan":
                body_mult = mult * (eqn.params["length"] if loop_aware else 1)
                walk(eqn.params["jaxpr"].jaxpr, body_mult)
                continue
            if name == "while":
                walk(eqn.params["body_jaxpr"].jaxpr, mult)
                continue
            if name == "cond":
                # max over branches (the compiled program pays for the
                # branch it takes; buckets should make them equal anyway)
                best: StaticCost | None = None
                for br in eqn.params["branches"]:
                    saved = StaticCost(acc.flops, acc.hbm_bytes, acc.eqns)
                    walk(br.jaxpr, mult)
                    cand = StaticCost(acc.flops, acc.hbm_bytes, acc.eqns)
                    acc.flops, acc.hbm_bytes, acc.eqns = (
                        saved.flops, saved.hbm_bytes, saved.eqns)
                    if best is None or cand.flops > best.flops:
                        best = cand
                if best is not None:
                    acc.flops, acc.hbm_bytes, acc.eqns = (
                        best.flops, best.hbm_bytes, best.eqns)
                continue
            sub = eqn.params.get("jaxpr") or eqn.params.get("call_jaxpr")
            if sub is not None:
                walk(getattr(sub, "jaxpr", sub), mult)
                continue
            if name == "dot_general":
                (lc, _), _ = eqn.params["dimension_numbers"]
                lhs = eqn.invars[0].aval
                contract = 1
                for d in lc:
                    contract *= lhs.shape[d]
                out_elems = int(np.prod(
                    eqn.outvars[0].aval.shape, dtype=np.int64))
                acc.flops += 2.0 * out_elems * max(1, contract) * mult
                acc.hbm_bytes += mult * (
                    sum(_aval_bytes(v) for v in eqn.invars[:2])
                    + _aval_bytes(eqn.outvars[0])
                )
            elif name in _GATHERISH:
                acc.hbm_bytes += 2 * mult * sum(
                    _aval_bytes(o) for o in eqn.outvars)
            elif name in _SCATTERISH:
                idx = 1 if name == "dynamic_update_slice" else 2
                upd = (eqn.invars[idx] if len(eqn.invars) > idx
                       else eqn.outvars[0])
                acc.hbm_bytes += 2 * mult * _aval_bytes(upd)
            elif name in _READWRITE:
                acc.hbm_bytes += mult * (
                    sum(_aval_bytes(v) for v in eqn.invars)
                    + sum(_aval_bytes(o) for o in eqn.outvars)
                )
            # remaining elementwise/layout/metadata ops: fused, free

    walk(closed.jaxpr, 1.0)
    return acc


def roofline_row(target: AuditTarget, traced, compiled) -> dict:
    """One STATIC_roofline.json row: the static walk next to both
    references, with the gated ratios."""
    from repro.launch.hlo_cost import analyze as hlo_analyze

    st = static_cost(traced.jaxpr)
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):        # jax 0.4.x returns [dict]
        ca = ca[0] if ca else {}
    hc = hlo_analyze(compiled.as_text())

    def ratio(a: float, b: float) -> float:
        return a / b if b else float("inf")

    xla_flops = float(ca.get("flops", 0.0))
    xla_bytes = float(ca.get("bytes accessed", 0.0))
    return {
        "target": target.name,
        "bucket": target.bucket,
        "static": {"flops": st.flops, "hbm_bytes": st.hbm_bytes,
                   "eqns": st.eqns},
        "xla_cost_analysis": {"flops": xla_flops,
                              "bytes_accessed": xla_bytes},
        "hlo_cost": {"flops": hc.flops, "hbm_bytes": hc.hbm_bytes},
        "ratios": {
            "flops_vs_hlo_cost": ratio(st.flops, hc.flops),
            "flops_vs_xla": ratio(st.flops, xla_flops),
            "bytes_vs_hlo_cost": ratio(st.hbm_bytes, hc.hbm_bytes),
            "bytes_vs_xla": ratio(st.hbm_bytes, xla_bytes),
        },
    }


def check_roofline(target: AuditTarget, row: dict) -> list[AuditViolation]:
    out: list[AuditViolation] = []
    for key, (lo, hi) in TOLERANCES.items():
        r = row["ratios"][key]
        # a reference reporting 0 for a non-trivial program (some backends
        # omit cost fields) is a skip, not a violation
        if r == float("inf"):
            continue
        if not (lo <= r <= hi):
            out.append(AuditViolation(
                target.name, "roofline",
                f"static/{key.split('_vs_')[1]} ratio {r:.3f} outside "
                f"documented band [{lo}, {hi}] for metric "
                f"{key.split('_vs_')[0]}",
                provenance=json.dumps(row["ratios"]),
            ))
    return out


# --------------------------------------------------------------------------
# target construction
# --------------------------------------------------------------------------
def engine_targets(engine, *, prefill_chunks: bool = True) -> list[AuditTarget]:
    """Audit targets for every shape ``Engine.warmup`` precompiles,
    with structural probes paired inside each warmup probe group."""
    specs = engine.warmup_specs(prefill_chunks=prefill_chunks)
    by_group: dict[str, list] = {}
    for s in specs:
        by_group.setdefault(s.probe_group, []).append(s)
    out: list[AuditTarget] = []
    for group in by_group.values():
        for i, s in enumerate(group):
            probe = group[i + 1].make_args if i + 1 < len(group) else None
            out.append(AuditTarget(
                name=s.name,
                fn=getattr(engine, s.fn_name),
                make_args=s.make_args,
                donate_argnums=s.donate_argnums,
                static_argnums=s.static_argnums,
                bucket=dict(s.bucket),
                probe_args=probe,
            ))
    return out


def kernel_targets() -> list[AuditTarget]:
    """The three kernel dispatch entry points at example bucket shapes
    (each ops module owns its shapes via ``audit_spec()``)."""
    from repro.kernels.flash_attention import ops as flash_ops
    from repro.kernels.paged_attention import ops as paged_ops
    from repro.kernels.ssd import ops as ssd_ops

    out: list[AuditTarget] = []
    for mod in (paged_ops, flash_ops, ssd_ops):
        spec = mod.audit_spec()
        out.append(AuditTarget(
            name=spec["name"],
            fn=spec["fn"],
            make_args=spec["make_args"],
            bucket=spec.get("bucket", {}),
            probe_args=spec.get("probe_args"),
        ))
    return out


def audit(targets: list[AuditTarget]) -> tuple[list[dict], list[AuditViolation]]:
    """All three static passes over ``targets``; returns (roofline rows,
    violations)."""
    rows: list[dict] = []
    violations: list[AuditViolation] = []
    for t in targets:
        traced, lowered, compiled, notes = trace_target(t)
        violations += verify_donation(t, lowered, compiled, notes)
        violations += retrace_hazards(t, traced)
        row = roofline_row(t, traced, compiled)
        rows.append(row)
        violations += check_roofline(t, row)
    return rows, violations


# --------------------------------------------------------------------------
# seeded-violation selftest: the auditor must catch planted bugs
# --------------------------------------------------------------------------
def selftest() -> list[str]:
    """Plant one instance of each bug class in throwaway fns and assert
    the corresponding pass fires; returns failure descriptions (empty ==
    the auditor still detects what it claims to detect)."""
    import jax
    import jax.numpy as jnp

    failures: list[str] = []

    # (a) broken donation: the donated buffer's dtype drifts from every
    # output, so the alias request cannot be honored
    k = jnp.zeros((8, 16), jnp.bfloat16)

    def args():
        return (jnp.float32(1.0), k, k + 1)

    broken = AuditTarget(
        "selftest-donation-broken",
        jax.jit(lambda s, a, b: (a.astype(jnp.float32) * s, b),
                donate_argnums=(1, 2)),
        args, donate_argnums=(1, 2))
    _, lo, co, notes = trace_target(broken)
    if not verify_donation(broken, lo, co, notes):
        failures.append("donation verifier missed a dtype-broken donation")

    # NB the scale multiplies in the donated dtype — `a * jnp.float32(s)`
    # would promote output 0 to f32 and (correctly) break the donation
    intact = AuditTarget(
        "selftest-donation-ok",
        jax.jit(lambda s, a, b: (a * s.astype(a.dtype), b + 1),
                donate_argnums=(1, 2)),
        args, donate_argnums=(1, 2))
    _, lo, co, notes = trace_target(intact)
    if verify_donation(intact, lo, co, notes):
        failures.append("donation verifier false-positived on an honored "
                        "donation")

    # (b) shape-branching fn: adjacent buckets trace different programs
    def branchy(x):
        if x.shape[0] > 8:  # lint: jit-shape-branch-ok — seeded violation
            return x * 2
        return x + 1

    hazard = AuditTarget(
        "selftest-shape-branch", jax.jit(branchy),
        lambda: (jnp.zeros(8),), probe_args=lambda: (jnp.zeros(16),))
    tr = hazard.fn.trace(*hazard.make_args())
    if not any(v.pass_name == "retrace-hazard"
               for v in retrace_hazards(hazard, tr)):
        failures.append("hazard scan missed a shape-dependent branch")

    # (c) closure-captured pool baked in as a constant
    pool = jnp.zeros((64, 64), jnp.float32)
    baked = AuditTarget(
        "selftest-baked-const", jax.jit(lambda x: x + pool[0]),
        lambda: (jnp.zeros(64),))
    tr = baked.fn.trace(*baked.make_args())
    if not any("constant" in v.msg for v in retrace_hazards(baked, tr)):
        failures.append("hazard scan missed a closure-captured array")

    # (d) weak-typed invar from a Python scalar
    weak = AuditTarget(
        "selftest-weak-type", jax.jit(lambda a, b: a * b),
        lambda: (3.0, jnp.zeros(4)))
    tr = weak.fn.trace(*weak.make_args())
    if not any("weak" in v.msg for v in retrace_hazards(weak, tr)):
        failures.append("hazard scan missed a weak-typed invar")
    return failures


# --------------------------------------------------------------------------
# replay gate: zero post-warmup compiles through the real pump
# --------------------------------------------------------------------------
def replay_gate(cfg, params, *, max_seq: int = 128,
                page_tokens: int = 16, log=print) -> dict:
    """Warm a paged engine, mark the compile tracker, push a small corpus
    through the chunked-prefill decode pump, and return the tracker's
    verdict (raises via the router's end-of-replay hook on violations)."""
    from repro.analysis.compile_tracker import get_tracker
    from repro.core.types import ProgramTrace, RequestRecord
    from repro.serving import Engine, MoriRouter

    os.environ[_tracker_env()] = "1"
    tracker = get_tracker()
    with tracker.phase("engine-build"):
        engine = Engine(
            cfg, params, page_tokens=page_tokens, n_device_pages=96,
            n_host_pages=64, max_slots=2, max_seq=max_seq,
        )
    with tracker.phase("warmup"):
        engine.warmup(prefill_chunks=True)
    router = MoriRouter(
        [engine], scheduler="mori",
        gpu_capacity_bytes=engine.radix_device_pages * engine.pool.page_bytes,
        chunked_prefill=True,
    )
    corpus = [
        ProgramTrace(f"audit-p{p}", [
            RequestRecord(input_tokens=24 + 13 * p + 7 * s, output_tokens=4,
                          tool_duration_s=0.0 if s == 2 else 5.0,
                          reasoning_wall_s=0.0)
            for s in range(3)
        ])
        for p in range(3)
    ]
    with tracker.phase("replay"):
        # the router's end-of-replay hook raises on post-warmup compiles
        router.replay(corpus, vocab_size=cfg.vocab_size, max_new_tokens=4)
    verdict = {
        "post_warmup_compiles": tracker.post_warmup_compiles(),
        "cache_sizes": tracker.cache_sizes(),
        "backend_compiles_by_phase": {
            ph: len(tracker.events_in(ph))
            for ph in ("engine-build", "warmup", "replay")
        },
    }
    log(f"replay gate: cache sizes {verdict['cache_sizes']}, "
        f"backend compiles by phase "
        f"{verdict['backend_compiles_by_phase']}")
    return verdict


def _tracker_env() -> str:
    from repro.analysis.compile_tracker import ENV_VAR

    return ENV_VAR


# --------------------------------------------------------------------------
# CLI
# --------------------------------------------------------------------------
def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.jitaudit",
        description="static compile-plane audit: donation verification, "
                    "retrace hazards, recompile budget, static roofline",
    )
    ap.add_argument("--model", default="qwen1.5-0.5b")
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--page-tokens", type=int, default=16)
    ap.add_argument("--out", default="artifacts/STATIC_roofline.json")
    ap.add_argument("--skip-replay", action="store_true",
                    help="skip the pump-replay recompile-budget gate")
    ap.add_argument("--skip-selftest", action="store_true",
                    help="skip the seeded-violation selftest")
    args = ap.parse_args(argv)

    from repro.configs import get_config
    from repro.models import Model, materialize
    from repro.serving import Engine

    cfg = get_config(args.model).reduced()
    params = materialize(Model(cfg).describe(), seed=0)

    failures: list[str] = []
    if not args.skip_selftest:
        failures = selftest()
        for f in failures:
            print(f"SELFTEST FAIL: {f}")
        if not failures:
            print("selftest: 4 seeded violation classes all detected")

    paged = Engine(cfg, params, page_tokens=args.page_tokens,
                   n_device_pages=96, n_host_pages=64, max_slots=2,
                   max_seq=args.max_seq)
    dense = Engine(cfg, params, page_tokens=args.page_tokens,
                   n_device_pages=8, n_host_pages=8, max_slots=2,
                   max_seq=64, dense_slots=True)
    targets = (engine_targets(paged, prefill_chunks=True)
               + engine_targets(dense, prefill_chunks=False)
               + kernel_targets())
    print(f"auditing {len(targets)} jit targets "
          f"({args.model} reduced, max_seq={args.max_seq})")
    rows, violations = audit(targets)
    for v in violations:
        print(v)

    report = {
        "generated_by": "repro.analysis.jitaudit",
        "model": args.model,
        "geometry": {"max_seq": args.max_seq,
                     "page_tokens": args.page_tokens},
        "tolerances": {k: list(v) for k, v in TOLERANCES.items()},
        "targets": rows,
        "violations": [
            {"target": v.target, "pass": v.pass_name, "msg": v.msg,
             "provenance": v.provenance}
            for v in violations
        ],
        "selftest_failures": failures,
    }
    if not args.skip_replay:
        report["replay"] = replay_gate(
            cfg, params, max_seq=args.max_seq, page_tokens=args.page_tokens)
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=2, default=str)
        print(f"wrote {args.out} ({len(rows)} roofline rows)")
    ok = not violations and not failures
    print("jitaudit: " + ("clean" if ok else
                          f"{len(violations)} violation(s), "
                          f"{len(failures)} selftest failure(s)"))
    return 0 if ok else 1


if __name__ == "__main__":
    sys.exit(main())
