"""Compile-cache interposer: recompile budgets for the hot-path jits.

The serving fast path only holds its latency numbers while every decode
step and prefill chunk dispatches from jit's compile cache.  One
mid-replay retrace stalls every live slot in the pump for the full
XLA compile; worse, it is *silent* — the replay still produces correct
tokens, just slowly.  This module makes "the replay compiled nothing
new" a checkable property:

* every hot-path jitted function registers here by name
  (``Engine.__init__`` does this when the tracker is armed);
* ``Engine.warmup()`` calls :meth:`CompileTracker.mark_warm` once it has
  run every bucket shape, snapshotting each function's per-jit cache
  size (``fn._cache_size()`` — the number of distinct lowerings jit
  holds for that callable);
* at end of replay the router asks :meth:`post_warmup_compiles`; any
  registered function whose cache grew past its warm snapshot compiled
  a shape warmup missed, and the replay fails loudly with the count.

The budget is enforced on the *per-function* jit caches rather than the
process-global backend-compile counter because eager ops (``jnp.argmax``
on a host int, debug prints, test scaffolding) legitimately trigger
backend compiles that are not hot-path retraces.  The global counter is
still useful for attribution, so when armed the tracker also registers
a ``jax.monitoring`` listener and keeps a phase-tagged event log of
every backend compile (see :meth:`phase`); the log says *when* a rogue
compile happened, the cache sizes say *which function* it hit.

Armed via ``REPRO_JITAUDIT=1`` (mirrors kvsan's ``REPRO_KVSAN``) or
programmatically with ``get_tracker().arm()``.  Unarmed, the only cost
an engine pays is one ``enabled()`` check in ``__init__``.
"""
from __future__ import annotations

import contextlib
import os
from dataclasses import dataclass, field

ENV_VAR = "REPRO_JITAUDIT"

#: jax.monitoring event keys that mark one backend (XLA) compilation
_COMPILE_EVENTS = (
    "/jax/core/compile/backend_compile_duration",
)


def enabled() -> bool:
    """True when the compile tracker is armed via the environment."""
    return os.environ.get(ENV_VAR, "0") not in ("", "0")


@dataclass
class _Entry:
    fn: object
    #: cache size snapshotted by mark_warm (None until warmed)
    warm: int | None = None


@dataclass
class CompileEvent:
    """One backend compile observed by the monitoring listener."""

    phase: str
    event: str
    duration_s: float


class CompileTracker:
    """Process-wide registry of hot-path jits and their compile budgets."""

    def __init__(self) -> None:
        self._entries: dict[str, _Entry] = {}
        self._armed = False
        self._listener_installed = False
        self._phase = "startup"
        self.events: list[CompileEvent] = []

    # ------------------------------------------------------------- arming
    def arm(self) -> None:
        """Arm the tracker and install the backend-compile listener (once;
        jax.monitoring listeners cannot be unregistered individually, so
        the listener stays installed and checks ``_armed``)."""
        self._armed = True
        if self._listener_installed:
            return
        try:
            from jax import monitoring
        except Exception:  # pragma: no cover — ancient jax
            return

        def _on_event(event: str, duration: float, **kw) -> None:
            if self._armed and any(event.startswith(e) for e in _COMPILE_EVENTS):
                self.events.append(CompileEvent(self._phase, event, duration))

        monitoring.register_event_duration_secs_listener(_on_event)
        self._listener_installed = True

    def disarm(self) -> None:
        self._armed = False

    @property
    def armed(self) -> bool:
        return self._armed

    # ------------------------------------------------------- registration
    def register(self, name: str, fn) -> None:
        """Track ``fn``'s jit cache under ``name``.

        Re-registering a name replaces the entry (fuzz rounds rebuild
        engines; the previous round's function is dead).  Registering the
        same object twice (the process-global chunk-prefill fn is shared
        across engines) is a no-op so an earlier warm snapshot survives.
        """
        prev = self._entries.get(name)
        if prev is not None and prev.fn is fn:
            return
        self._entries[name] = _Entry(fn)

    def registered(self) -> tuple[str, ...]:
        return tuple(self._entries)

    # ------------------------------------------------------------ budgets
    @staticmethod
    def _size(fn) -> int:
        size = getattr(fn, "_cache_size", None)
        return int(size()) if callable(size) else -1

    def cache_sizes(self) -> dict[str, int]:
        """Current per-function compile-cache entry counts."""
        return {name: self._size(e.fn) for name, e in self._entries.items()}

    def mark_warm(self, names: tuple[str, ...] | None = None) -> dict[str, int]:
        """Snapshot cache sizes as the warm baseline (all entries, or just
        ``names``); returns the snapshot.  Compiles past this baseline are
        budget violations."""
        snap: dict[str, int] = {}
        for name, e in self._entries.items():
            if names is not None and name not in names:
                continue
            e.warm = self._size(e.fn)
            snap[name] = e.warm
        return snap

    def post_warmup_compiles(self) -> dict[str, tuple[int, int]]:
        """``{name: (warm_size, current_size)}`` for every registered
        function whose compile cache grew after its warm snapshot.  Empty
        dict == budget held.  Functions never marked warm are skipped (no
        baseline to compare against)."""
        out: dict[str, tuple[int, int]] = {}
        for name, e in self._entries.items():
            if e.warm is None:
                continue
            cur = self._size(e.fn)
            if cur > e.warm:
                out[name] = (e.warm, cur)
        return out

    def marked(self) -> bool:
        """True once any registered function has a warm baseline."""
        return any(e.warm is not None for e in self._entries.values())

    # ------------------------------------------------------------- phases
    @contextlib.contextmanager
    def phase(self, label: str):
        """Tag backend-compile events with ``label`` for attribution."""
        prev, self._phase = self._phase, label
        try:
            yield
        finally:
            self._phase = prev

    def events_in(self, label: str) -> list[CompileEvent]:
        return [e for e in self.events if e.phase == label]

    # -------------------------------------------------------------- reset
    def reset(self) -> None:
        """Drop registrations, baselines and the event log (tests)."""
        self._entries.clear()
        self.events.clear()
        self._phase = "startup"


_TRACKER: CompileTracker | None = None


def get_tracker() -> CompileTracker:
    """The process-wide tracker (created on first use; armed from the
    environment so ``REPRO_JITAUDIT=1`` needs no other plumbing)."""
    global _TRACKER
    if _TRACKER is None:
        _TRACKER = CompileTracker()
    if enabled() and not _TRACKER.armed:
        _TRACKER.arm()
    return _TRACKER
