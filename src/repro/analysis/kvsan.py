"""kvsan — runtime page-lifetime sanitizer for the two-tier ``PagePool``.

Every page in the pool moves through a small lifecycle::

    FREE -> STAGED -> RESIDENT -> OFFLOADING -> HOST -> RELOADING -> ...

* **FREE** — on the pool's free list, owned by nobody.
* **STAGED** — allocated but not yet attached to a radix node, a slot's
  block table, or an explicit hold (suffix pages mid-``submit``, prefill
  staging, transfer-plane staging).
* **RESIDENT** — a device page reachable from a radix node or a live
  block table.
* **OFFLOADING / RELOADING** — the source side of an in-flight
  ``CopyJob`` (held by a transfer stream; must stay valid until commit).
* **HOST** — a host page attached to a radix node.

The sanitizer shadows the real pool: every ``alloc``/``free``/``read``/
``write`` verb reports here, the radix tree and engine register
*reachability* (nodes, block tables, scratch pages), and in-flight work
registers explicit *holds*.  From that shadow state it detects, as hard
errors (:class:`KvsanError`):

* double-free (free of a FREE page) and alloc of a non-free page
  (free-list corruption — the downstream symptom of a double-free),
* free of a page while a pinned radix node (refcount > 0) still points
  at it, or while any hold — live block table, prefill job, in-flight
  copy — covers it (eviction out from under a live decode),
* read / write / append against a FREE page, and appends past the tail
  page of a block table,
* structural corruption on demand via :meth:`verify` (free-list
  duplicates, allocation-count conservation, two nodes sharing a page),
* end-of-replay leaks via :meth:`check_leaks` (allocated pages
  unreachable from any radix node, block table, or hold).

Enabled by exporting ``REPRO_KVSAN=1`` before pools/trees are
constructed.  When off, :func:`maybe_sanitizer` returns ``None`` and the
instrumented seams reduce to one ``is None`` test — zero overhead on the
hot path.
"""
from __future__ import annotations

import contextlib
import os
from collections import deque

#: environment variable gating the sanitizer (read at construction time)
ENV_VAR = "REPRO_KVSAN"

_FREE = 0
_ALLOC = 1

# derived lifecycle states reported by :meth:`PageSanitizer.state_of`
FREE = "FREE"
STAGED = "STAGED"
RESIDENT = "RESIDENT"
OFFLOADING = "OFFLOADING"
HOST = "HOST"
RELOADING = "RELOADING"


def enabled() -> bool:
    """Is kvsan requested for newly constructed pools/trees?"""
    return os.environ.get(ENV_VAR, "").strip().lower() not in (
        "", "0", "false", "off",
    )


class KvsanError(AssertionError):
    """A page-lifetime invariant was violated.

    Subclasses ``AssertionError`` so test harnesses and the repo's
    existing invariant checks treat it uniformly; carries the
    sanitizer's recent event ring for post-mortem."""

    def __init__(self, msg: str, trace=()):
        self.trace = list(trace)
        if self.trace:
            msg += "\n  recent page events (oldest first):\n" + "\n".join(
                f"    {e}" for e in self.trace
            )
        super().__init__(msg)


def maybe_sanitizer(
    *, n_device_pages: int, n_host_pages: int, page_tokens: int
) -> "PageSanitizer | None":
    """The pool-construction entry point: a sanitizer when ``REPRO_KVSAN``
    is set, ``None`` (→ zero instrumentation cost) otherwise."""
    if not enabled():
        return None
    return PageSanitizer(
        n_device_pages=n_device_pages,
        n_host_pages=n_host_pages,
        page_tokens=page_tokens,
    )


class PageSanitizer:
    """Shadow state machine over one ``PagePool``'s pages."""

    def __init__(
        self,
        *,
        n_device_pages: int,
        n_host_pages: int,
        page_tokens: int,
        trace_len: int = 128,
    ):
        self.page_tokens = page_tokens
        self._state = {
            "dev": [_FREE] * n_device_pages,
            "host": [_FREE] * n_host_pages,
        }
        self._trace: deque[str] = deque(maxlen=trace_len)
        self._last: dict[tuple[str, int], str] = {}
        self._scope = "init"
        # wired up by the owning pool / engine
        self.pool = None              # PagePool (free-list introspection)
        self.tree = None              # TypedRadixTree (pin / reachability)
        self._reachable_cbs: list = []   # () -> iterable[(tier, page, tag)]
        # explicit holds: token -> (tier, (pages...), tag)
        self._holds: dict[int, tuple[str, tuple[int, ...], str]] = {}
        self._next_hold = 0
        # >0 inside an owned_pin_frees() region (see below)
        self._pin_free_depth = 0

    # ------------------------------------------------------------- wiring
    def set_scope(self, tag: str) -> None:
        """Name the operation in flight; stamped onto every event."""
        self._scope = tag

    def add_reachable_cb(self, fn) -> None:
        """Register a callback enumerating live page references as
        ``(tier, page, tag)`` triples (block tables, scratch pages)."""
        self._reachable_cbs.append(fn)

    def add_hold(self, tier: str, pages, tag: str) -> int:
        """Mark ``pages`` as held (in-flight copy source/staging, prefill
        staging): freeing a held page is a hard error until
        :meth:`drop_hold`. Returns an opaque token."""
        tok = self._next_hold
        self._next_hold += 1
        self._holds[tok] = (tier, tuple(pages), tag)
        self._event(f"hold[{tag}] {tier}:{list(pages)}")
        return tok

    def drop_hold(self, token: int) -> None:
        tier, pages, tag = self._holds.pop(token)
        self._event(f"drop-hold[{tag}] {tier}:{list(pages)}")

    @contextlib.contextmanager
    def owned_pin_frees(self, tag: str):
        """Custody-transfer region: the caller holds the pin on the nodes
        whose pages it is about to free (a transfer stream committing its
        own offload retires the device copies *before* it unpins).  The
        free-while-pinned check is suspended inside; every other check
        (double-free, holds, block-table reachability) stays armed."""
        self._event(f"owned-pin-frees[{tag}] begin")
        self._pin_free_depth += 1
        try:
            yield
        finally:
            self._pin_free_depth -= 1
            self._event(f"owned-pin-frees[{tag}] end")

    # ------------------------------------------------------------- events
    def _event(self, msg: str) -> str:
        line = f"[{self._scope}] {msg}"
        self._trace.append(line)
        return line

    def _page_event(self, tier: str, page: int, verb: str) -> None:
        self._last[(tier, page)] = self._event(f"{verb} {tier}:{page}")

    def _last_event(self, tier: str, page: int) -> str:
        return self._last.get((tier, page), "<no event recorded>")

    def _raise(self, msg: str) -> None:
        raise KvsanError(msg, self._trace)

    # ------------------------------------------------- pool verb hooks
    def on_alloc(self, tier: str, page: int) -> None:
        st = self._state[tier]
        if st[page] != _FREE:
            self._raise(
                f"allocator returned {tier} page {page} which is already "
                f"allocated — free-list corruption (typically the echo of "
                f"an earlier double-free); last event: "
                f"{self._last_event(tier, page)}"
            )
        st[page] = _ALLOC
        self._page_event(tier, page, "alloc")

    def on_free(self, tier: str, page: int) -> None:
        st = self._state[tier]
        if not (0 <= page < len(st)):
            self._raise(f"free of out-of-range {tier} page {page}")
        if st[page] == _FREE:
            self._raise(
                f"double-free of {tier} page {page}; "
                f"last event: {self._last_event(tier, page)}"
            )
        # free-while-pinned: a refcount-held radix node still points here.
        # Device side only — host pages of pinned nodes are legitimately
        # freed while streaming a reload (the pin protects the KV, which
        # at that moment lives in the freshly-staged device copy).
        if tier == "dev" and self.tree is not None and not self._pin_free_depth:
            for node in self.tree._iter_nodes():
                if node.device_page == page and node.refcount > 0:
                    self._raise(
                        f"free of dev page {page} while radix node "
                        f"{node.node_id} still pins it "
                        f"(refcount={node.refcount})"
                    )
        for _tok, (htier, pages, tag) in self._holds.items():
            if htier == tier and page in pages:
                self._raise(
                    f"free of {tier} page {page} while held by [{tag}]"
                )
        for fn in self._reachable_cbs:
            for rtier, rpage, tag in fn():
                if rtier == tier and rpage == page:
                    self._raise(
                        f"free of {tier} page {page} while referenced by "
                        f"[{tag}] — eviction out from under a live decode"
                    )
        st[page] = _FREE
        self._page_event(tier, page, "free")

    def on_read(self, tier: str, page: int) -> None:
        if self._state[tier][page] == _FREE:
            self._raise(
                f"read-after-free of {tier} page {page}; "
                f"last event: {self._last_event(tier, page)}"
            )

    def on_write(self, tier: str, page: int) -> None:
        if self._state[tier][page] == _FREE:
            self._raise(
                f"write-after-free of {tier} page {page}; "
                f"last event: {self._last_event(tier, page)}"
            )
        self._page_event(tier, page, "write")

    def on_format(self, tier: str, page: int, fmt: str) -> None:
        """A page was (re)written in a declared tier format — a lifecycle
        event like alloc/write: format transitions (bf16→int8 on offload,
        int8→bf16 on reload to a full-precision pool) land in the event
        ring so a post-mortem shows *what representation* a corrupted page
        last held, and writing a format into a FREE page is the same hard
        error as any other write-after-free."""
        if self._state[tier][page] == _FREE:
            self._raise(
                f"format write ({fmt}) to FREE {tier} page {page}; "
                f"last event: {self._last_event(tier, page)}"
            )
        self._page_event(tier, page, f"format[{fmt}]")

    def on_append(self, tier: str, page: int, offset: int) -> None:
        if not (0 <= offset < self.page_tokens):
            self._raise(
                f"append past the tail page: offset {offset} outside "
                f"[0, {self.page_tokens}) on {tier} page {page}"
            )
        self.on_write(tier, page)

    # --------------------------------------------------- engine-side checks
    def check_table(self, table, pos: int, pid: str) -> None:
        """Validate one slot's block table before a decode step: the write
        position must land inside the table and every referenced page must
        be live."""
        T = self.page_tokens
        if pos // T >= len(table):
            self._raise(
                f"decode for {pid} would append past the tail page: "
                f"position {pos} needs table entry {pos // T} but the "
                f"block table has only {len(table)} pages"
            )
        for p in table:
            if self._state["dev"][p] == _FREE:
                self._raise(
                    f"block table of {pid} references freed dev page {p}; "
                    f"last event: {self._last_event('dev', p)}"
                )

    # ------------------------------------------------------- derived state
    def state_of(self, tier: str, page: int) -> str:
        """The page's lifecycle state, derived from the shadow tables."""
        if self._state[tier][page] == _FREE:
            return FREE
        held_tag = None
        for _tok, (htier, pages, tag) in self._holds.items():
            if htier == tier and page in pages:
                held_tag = tag
                break
        if held_tag is not None and held_tag.startswith("offload"):
            return OFFLOADING if tier == "dev" else STAGED
        if held_tag is not None and held_tag.startswith("reload"):
            return RELOADING if tier == "host" else STAGED
        if self.tree is not None:
            attr = "device_page" if tier == "dev" else "host_page"
            for node in self.tree._iter_nodes():
                if getattr(node, attr) == page:
                    return RESIDENT if tier == "dev" else HOST
        for fn in self._reachable_cbs:
            for rtier, rpage, _tag in fn():
                if rtier == tier and rpage == page:
                    return RESIDENT
        return STAGED

    def _reachable(self, tier: str) -> dict[int, str]:
        """page -> tag for every live reference on ``tier``."""
        out: dict[int, str] = {}
        if self.tree is not None:
            attr = "device_page" if tier == "dev" else "host_page"
            for node in self.tree._iter_nodes():
                p = getattr(node, attr)
                if p is not None:
                    out[p] = f"radix node {node.node_id}"
        for _tok, (htier, pages, tag) in self._holds.items():
            if htier == tier:
                for p in pages:
                    out[p] = f"hold[{tag}]"
        for fn in self._reachable_cbs:
            for rtier, rpage, tag in fn():
                if rtier == tier:
                    out[rpage] = tag
        return out

    # -------------------------------------------------- structural checks
    def verify(self, context: str = "") -> None:
        """Structural invariants over the whole pool — free-list integrity,
        allocation conservation, no two radix nodes sharing a page, no
        node referencing a freed page. O(pages + nodes); call at seam
        points (router ticks, end of replay), not per token."""
        where = f" ({context})" if context else ""
        if self.pool is not None:
            lists = (
                ("dev", self.pool._free_dev), ("host", self.pool._free_host),
            )
            for tier, free_list in lists:
                st = self._state[tier]
                if len(set(free_list)) != len(free_list):
                    dupes = sorted(
                        p for p in set(free_list) if free_list.count(p) > 1
                    )
                    self._raise(
                        f"{tier} free list contains duplicates {dupes}{where}"
                    )
                for p in free_list:
                    if not (0 <= p < len(st)):
                        self._raise(
                            f"{tier} free list holds out-of-range page "
                            f"{p}{where}"
                        )
                    if st[p] != _FREE:
                        self._raise(
                            f"{tier} free list holds page {p} the shadow "
                            f"state says is allocated{where}; last event: "
                            f"{self._last_event(tier, p)}"
                        )
                n_alloc = sum(1 for s in st if s == _ALLOC)
                if len(free_list) + n_alloc != len(st):
                    self._raise(
                        f"{tier} page conservation broken{where}: "
                        f"{len(free_list)} free + {n_alloc} allocated != "
                        f"{len(st)} total"
                    )
        if self.tree is not None:
            for tier, attr in (("dev", "device_page"), ("host", "host_page")):
                owner: dict[int, int] = {}
                for node in self.tree._iter_nodes():
                    if node.refcount < 0:
                        self._raise(
                            f"radix node {node.node_id} refcount underflow "
                            f"({node.refcount}){where}"
                        )
                    p = getattr(node, attr)
                    if p is None:
                        continue
                    if self._state[tier][p] == _FREE:
                        self._raise(
                            f"radix node {node.node_id} references freed "
                            f"{tier} page {p}{where}; last event: "
                            f"{self._last_event(tier, p)}"
                        )
                    if p in owner:
                        self._raise(
                            f"{tier} page {p} referenced by two radix nodes "
                            f"({owner[p]} and {node.node_id}){where}"
                        )
                    owner[p] = node.node_id

    def check_leaks(self, context: str = "") -> None:
        """Every allocated page must be reachable from a radix node, a
        block table / scratch registration, or an explicit hold."""
        where = f" ({context})" if context else ""
        for tier in ("dev", "host"):
            reach = self._reachable(tier)
            leaked = [
                p
                for p, s in enumerate(self._state[tier])
                if s == _ALLOC and p not in reach
            ]
            if leaked:
                detail = "; ".join(
                    f"{tier}:{p} last event: {self._last_event(tier, p)}"
                    for p in leaked[:8]
                )
                self._raise(
                    f"{len(leaked)} leaked {tier} page(s){where}: "
                    f"{leaked[:16]} — allocated but unreachable from any "
                    f"radix node, block table, or hold. {detail}"
                )
