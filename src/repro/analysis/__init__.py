"""Dynamic and static analysis tooling for the repro codebase.

* :mod:`repro.analysis.kvsan` — page-lifetime sanitizer over the serving
  engines' :class:`~repro.serving.kvpool.PagePool` (``REPRO_KVSAN=1``).
* :mod:`repro.analysis.invariants` — control-plane invariant checker run
  on every router tick when the sanitizer is enabled.
* :mod:`repro.analysis.lint` — repo-specific AST lint
  (``python -m repro.analysis.lint``).
* :mod:`repro.analysis.fuzz` — randomized replay fuzzer that drives the
  router under the sanitizer (``python -m repro.analysis.fuzz``);
  ``--compile-audit`` also arms the compile tracker per round.
* :mod:`repro.analysis.compile_tracker` — recompile-budget interposer
  over the hot-path jit caches (``REPRO_JITAUDIT=1``).
* :mod:`repro.analysis.jitaudit` — static compile-plane auditor:
  donation verification, retrace-hazard probes, and static rooflines
  over the traced jaxprs/HLO (``python -m repro.analysis.jitaudit``).

This ``__init__`` stays import-light on purpose: ``kvpool`` and
``radix_tree`` import :mod:`repro.analysis.kvsan` at module load, so
anything heavier here would tax every engine import.
"""
