"""Discrete-event simulation of agent serving (drives repro.core policies)."""
from repro.sim.engine import FaultPlan, Simulation
from repro.sim.hardware import CONFIGS, HwConfig, small_test_hw
from repro.sim.metrics import SimResult

__all__ = ["CONFIGS", "FaultPlan", "HwConfig", "SimResult", "Simulation", "small_test_hw"]
