"""Discrete-event simulator replaying agentic traces against a scheduler.

Reproduces the paper's evaluation methodology (§6.1): each concurrency slot
is a closed-loop client that replays one trace — send a request, wait for the
response, sleep the recorded tool-call duration, repeat; when a trace ends the
slot immediately starts the next one. The serving side models each replica
with a roofline decode-step cost (``repro.sim.hardware``), a FIFO prefill
queue with chunked-prefill interference, and full-duplex PCIe + NVMe transfer
channels that overlap compute.

The scheduler under test is *real* policy code from ``repro.core``: every
lifecycle event returns a :class:`~repro.core.actions.PlacementPlan`, the
simulator executes it through :meth:`Simulation.apply_plan`, and each
finished transfer is acknowledged back via
``scheduler.on_transfer_complete`` — the same plan/ack protocol the real
JAX router speaks, so MORI and every baseline run identical code in both
worlds. Every transfer-bearing action (``Offload``, reloading ``Forward``,
``Migrate``) is lowered through the endpoint-addressed
:class:`~repro.core.transfers.CopyRequest` API, so sizing, channel choice
and the executing replica come from the copy's *endpoints*, not from
per-action-kind simulator code.

The PCIe/NVMe queue model itself lives in ``repro.core.transfers``
(:class:`TransferChannels`) and is shared with the real serving path's
:class:`~repro.serving.transfer_plane.ReplicaTransferPlane`: here each
transfer is one single-chunk (fluid) job whose completion event lands in
the simulator's heap; the real plane runs the same queues chunked at page
granularity.
"""
from __future__ import annotations

import heapq
import itertools
import random
import time as _time
from collections import deque
from dataclasses import dataclass

from repro.core import SCHEDULERS, SchedulerConfig, TierCapacity
from repro.core.actions import (
    Action,
    CancelTransfer,
    Discard,
    Forward,
    Migrate,
    Offload,
    PlacementPlan,
    SetLabel,
)
from repro.core.transfers import CopyJob, TransferChannels, copy_request_for
from repro.core.types import ProgramTrace, Tier, TransferCost
from repro.sim.hardware import HwConfig
from repro.sim.metrics import SimResult, percentile


@dataclass
class _Request:
    pid: str
    slot: int
    step_idx: int
    input_tokens: int
    output_tokens: int
    tool_duration_s: float
    arrival: float
    prefill_tokens: int = 0
    reload_bytes: int = 0
    kv_context_tokens: int = 0   # tokens whose KV must be read during decode
    remaining: float = 0.0
    first_token_at: float | None = None


class _Replica:
    """Fluid-rate model of one engine replica."""

    def __init__(self, rid: int, hw: HwConfig, sim: "Simulation"):
        self.rid = rid
        self.hw = hw
        self.sim = sim
        self.alive = True
        self.decode: dict[str, _Request] = {}
        self.prefill_active: _Request | None = None
        self.prefill_remaining = 0.0
        self.prefill_q: deque[_Request] = deque()
        # PCIe + NVMe copy queues: the shared single-chunk (fluid) model;
        # completions land straight in the simulator's event heap
        self.channels = TransferChannels(
            cost=sim.xfer_cost, schedule=sim.at, on_done=self._transfer_done
        )
        self.version = 0
        self.last_settle = 0.0
        self.busy_accum = 0.0
        self.overlap_accum = 0.0
        self.step_samples = 0

    # --------------------------------------------------------------- decode
    def step_time(self) -> float:
        kv_bytes = sum(
            r.kv_context_tokens * self.hw.kv_bytes_per_token
            for r in self.decode.values()
        )
        t = self.hw.decode_step_time(len(self.decode), kv_bytes)
        if self.prefill_active is not None:
            t *= self.hw.prefill_interference
        return t

    def settle(self, now: float) -> None:
        dt = now - self.last_settle
        if dt < 0:
            return
        if self.decode or self.prefill_active is not None:
            self.busy_accum += dt
            if self.channels.in_flight():
                # paper §6.2 "masked by GPU-CPU overlap": compute time
                # during which a KV transfer was concurrently in flight
                self.overlap_accum += dt
        if self.decode and dt > 0:
            tokens = dt / self.step_time()
            for r in self.decode.values():
                r.remaining -= tokens
                r.kv_context_tokens += tokens  # KV grows as tokens generate
        self.last_settle = now

    def reschedule(self, now: float) -> None:
        """Schedule the next decode completion (versioned against staleness)."""
        self.version += 1
        if not self.decode:
            return
        v = self.version
        min_rem = min(r.remaining for r in self.decode.values())
        eta = now + max(0.0, min_rem) * self.step_time()
        self.sim.at(eta, lambda t: self.on_decode_event(t, v))

    def on_decode_event(self, now: float, version: int) -> None:
        if version != self.version or not self.alive:
            return
        self.settle(now)
        done = [r for r in self.decode.values() if r.remaining <= 1e-9]
        for r in done:
            del self.decode[r.pid]
            self.sim.complete_request(r, now)
        self.reschedule(now)

    def add_decode(self, req: _Request, now: float) -> None:
        self.settle(now)
        req.remaining = float(req.output_tokens)
        if req.first_token_at is None:
            req.first_token_at = now
        self.decode[req.pid] = req
        self.reschedule(now)

    # -------------------------------------------------------------- prefill
    def enqueue_prefill(self, req: _Request, now: float) -> None:
        self.prefill_q.append(req)
        if self.prefill_active is None:
            self.start_next_prefill(now)

    def start_next_prefill(self, now: float) -> None:
        self.settle(now)
        if self.prefill_active is not None or not self.prefill_q:
            self.reschedule(now)
            return
        req = self.prefill_q.popleft()
        self.sim.sched.notify_inference_started(req.pid, now)
        if req.prefill_tokens <= 0:
            self.prefill_active = None
            self.finish_prefill(req, now)
            return
        self.prefill_active = req
        dur = req.prefill_tokens / self.hw.prefill_rate
        self.reschedule(now)  # decode slows down under interference
        self.sim.at(now + dur, lambda t: self.on_prefill_done(req, t))

    def on_prefill_done(self, req: _Request, now: float) -> None:
        if not self.alive or self.prefill_active is not req:
            return
        self.settle(now)
        self.prefill_active = None
        self.finish_prefill(req, now)
        self.start_next_prefill(now)

    def finish_prefill(self, req: _Request, now: float) -> None:
        req.first_token_at = now
        self.sim.record_ttft(req, now)
        self.add_decode(req, now)

    # ------------------------------------------------------------ transfers
    # which channel a given action bills is decided once, by
    # core.ledger.channel_for; the FIFO/serialization model itself lives in
    # core.transfers (shared with the real serving transfer plane)
    def _transfer_done(self, job: CopyJob, now: float) -> None:
        if not self.alive:
            return
        # acknowledge the ledger record; the scheduler may emit follow-ups
        self.sim.apply_plan(
            self.sim.sched.on_transfer_complete(job.pid, job.action_id, now)
        )
        if job.payload is not None:  # reload completed -> proceed to prefill
            self.enqueue_prefill(job.payload, now)

    def cancel_transfer(self, target_action_id: int) -> bool:
        """Drop a still-queued transfer. An already-active transfer is left
        to finish: offloads copy rather than move, so the late completion
        is wasted bandwidth, not a correctness problem (the scheduler has
        already closed the ledger record and ignores the stale ack)."""
        return self.channels.cancel_queued(target_action_id) is not None

    def fail(self, now: float) -> None:
        self.settle(now)
        self.alive = False
        self.decode.clear()
        self.prefill_active = None
        self.prefill_q.clear()
        self.channels.reset()
        self.version += 1

    def recover(self, now: float) -> None:
        self.settle(now)
        self.alive = True


@dataclass
class FaultPlan:
    """Inject a replica failure at ``fail_at`` and recover at ``recover_at``."""

    replica: int
    fail_at: float
    recover_at: float | None = None


class Simulation:
    """Closed-loop trace replay against one scheduler policy."""

    def __init__(
        self,
        scheduler: str,
        hw: HwConfig,
        corpus: list[ProgramTrace],
        *,
        num_replicas: int = 1,
        placement: "object | None" = None,   # repro.dist.ReplicaSet
        concurrency_per_replica: int = 20,
        cpu_ratio: float = 1.0,
        ssd_ratio: float = 0.0,
        duration_s: float = 600.0,
        warmup_s: float = 60.0,
        seed: int = 0,
        sched_config: SchedulerConfig | None = None,
        faults: list[FaultPlan] | None = None,
        reuse_corpus: bool = True,
        record_plans: bool = False,
    ):
        # a ReplicaSet pins the simulated fleet to a concrete device layout:
        # replica count comes from the placement; the set stays on the
        # Simulation so callers can read layout provenance (e.g.
        # sim.placement.rules.fallbacks) alongside the SimResult
        self.placement = placement
        if placement is not None:
            num_replicas = placement.num_replicas
        self.hw = hw
        self.corpus = corpus
        self.duration = duration_s
        self.warmup = warmup_s
        self.rng = random.Random(seed)
        self.xfer_cost = TransferCost(pcie_bytes_per_s=hw.pcie_bw)
        cap = TierCapacity(
            hw.gpu_kv_bytes,
            int(hw.gpu_kv_bytes * cpu_ratio),
            int(hw.gpu_kv_bytes * ssd_ratio),
        )
        self.sched_config = sched_config or SchedulerConfig()
        if ssd_ratio > 0 and not self.sched_config.ssd_bytes_per_s:
            # calibrate the cost-aware SSD guard from the hardware model
            self.sched_config.ssd_bytes_per_s = self.xfer_cost.ssd_bytes_per_s
            self.sched_config.recompute_tok_per_s = hw.prefill_rate
        self.sched = SCHEDULERS[scheduler](num_replicas, cap, self.sched_config)
        self.scheduler_name = scheduler
        self.replicas = [_Replica(i, hw, self) for i in range(num_replicas)]
        self.n_slots = num_replicas * concurrency_per_replica
        self.faults = faults or []
        # reuse_corpus=False runs each trace exactly once under its own
        # program id — finite-replay mode for golden cross-runtime tests;
        # freed slots pick up the next unplayed trace until the corpus drains
        self.reuse_corpus = reuse_corpus
        self._finite_next = 0
        self.record_plans = record_plans
        self.action_log: list[Action] = []

        # event queue
        self._q: list[tuple[float, int, object]] = []
        self._seq = itertools.count()
        self.now = 0.0

        # per-program replay state
        self._pending: dict[str, _Request] = {}
        self._last_ctx: dict[str, int] = {}
        self._slot_trace: dict[int, int] = {}
        self._slot_gen: dict[int, int] = {}

        # metrics
        self.completed_tokens = 0
        self.completed_tokens_measured = 0
        self.completed_steps = 0
        self.completed_steps_measured = 0
        self.ttfts: list[float] = []
        self.forwards = 0
        self.warm_forwards = 0
        self.reload_forwards = 0
        self.recompute_forwards = 0
        self.cancelled_transfers = 0
        self.migrations = 0
        self.tick_overhead_s: list[float] = []
        self.tick_actions: list[int] = []
        self.finished_programs: list[dict] = []

    # ------------------------------------------------------------ EventQ
    def at(self, t: float, fn) -> None:
        heapq.heappush(self._q, (t, next(self._seq), fn))

    # ------------------------------------------------------- plan executor
    def apply_plan(self, plan: PlacementPlan) -> None:
        """Execute a scheduler-emitted plan against the modeled hardware.

        ``SetLabel`` is a no-op here (no block level to restamp), and
        ``Discard`` carries no byte accounting (that lives in the
        scheduler) — but it does cancel the program's still-queued
        transfers, mirroring the real router's Discard path.
        """
        if self.record_plans and plan.actions:
            self.action_log.extend(plan.actions)
        for act in plan:
            if isinstance(act, Forward):
                self._exec_forward(act)
            elif isinstance(act, Offload):
                self._exec_offload(act)
            elif isinstance(act, Discard):
                self._exec_discard(act)
            elif isinstance(act, CancelTransfer):
                self._exec_cancel(act)
            elif isinstance(act, Migrate):
                self._exec_migrate(act)
            elif isinstance(act, SetLabel):
                pass  # no block level to restamp in the simulator
            else:
                raise ValueError(f"unhandled plan action: {act!r}")

    def _exec_forward(self, act: Forward) -> None:
        req = self._pending.get(act.pid)
        if req is None:
            return
        rep = self.replicas[act.replica]
        if not rep.alive:
            return  # scheduler will re-place after replica_failed
        prior = 0 if act.recompute else self._last_ctx.get(act.pid, 0)
        req.prefill_tokens = max(0, req.input_tokens - prior)
        req.kv_context_tokens = req.input_tokens
        self.forwards += 1
        if act.recompute:
            self.recompute_forwards += 1
            rep.enqueue_prefill(req, self.now)
        elif act.source_tier in (Tier.CPU, Tier.SSD):
            self.reload_forwards += 1
            req.reload_bytes = act.nbytes
            # SSD-sourced reloads (§7.1 extension) bill the NVMe channel
            # (CopyRequest.channel reads it off the source endpoint)
            self._exec_copy(act, payload=req)
        else:
            self.warm_forwards += 1
            rep.enqueue_prefill(req, self.now)

    def _exec_copy(self, act, payload: object = None) -> None:
        """One executor for every transfer-bearing action: lower to the
        endpoint-addressed :class:`CopyRequest` and enqueue on the replica
        whose channel serializes the copy — the channel billed and the
        executing side are derived from the endpoints, not the action
        class."""
        creq = copy_request_for(act)
        self.replicas[creq.exec_replica].channels.enqueue(
            creq.job(payload), self.now
        )

    def _exec_offload(self, act: Offload) -> None:
        if not self.replicas[act.replica].alive or act.nbytes <= 0:
            return
        self._exec_copy(act)

    def _exec_discard(self, act: Discard) -> None:
        """An evicted program's still-queued transfers must not outlive
        its KV: drop them and close their ledger records, so a later
        ``open_offload`` cannot match a stale record from a previous
        residency (parity with the real router's Discard path). A
        transfer already *on the wire* is left to finish — its ack closes
        the record as usual."""
        if act.replica is None:
            return
        rep = self.replicas[act.replica]
        for rec in self.sched.ledger.in_flight(replica=act.replica):
            if rec.pid == act.pid and rep.cancel_transfer(rec.action_id):
                self.sched.ledger.cancel(rec.action_id)

    def _exec_cancel(self, act: CancelTransfer) -> None:
        if self.replicas[act.replica].cancel_transfer(act.target_action_id):
            self.cancelled_transfers += 1

    def _exec_migrate(self, act: Migrate) -> None:
        """Cross-replica DRAM move: serialized on the destination replica's
        PCIe/ingest channel (``CopyRequest.exec_replica``)."""
        if not self.replicas[act.dst_replica].alive or act.nbytes <= 0:
            return
        self.migrations += 1
        self._exec_copy(act)

    # ------------------------------------------------------------ clients
    def _start_trace(self, slot: int, now: float) -> None:
        if not self.reuse_corpus:
            if self._finite_next >= len(self.corpus):
                return  # corpus drained: every trace ran exactly once
            trace = self.corpus[self._finite_next]
            self._finite_next += 1
            pid = trace.program_id
        else:
            idx = self._slot_trace.setdefault(slot, slot % len(self.corpus))
            gen = self._slot_gen.get(slot, 0)
            trace = self.corpus[idx % len(self.corpus)]
            pid = f"s{slot}g{gen}-{trace.program_id}"
            self._slot_trace[slot] = idx + self.n_slots  # stride through corpus
            self._slot_gen[slot] = gen + 1
        self.sched.program_arrived(
            pid, self.hw.kv_bytes_per_token, now,
            wire_bytes_per_token=self.hw.kv_wire_bytes_per_token,
        )
        self._issue(pid, trace, 0, slot, now)

    def _issue(
        self, pid: str, trace: ProgramTrace, step_idx: int, slot: int, now: float
    ) -> None:
        rec = trace.steps[step_idx]
        req = _Request(
            pid=pid,
            slot=slot,
            step_idx=step_idx,
            input_tokens=rec.input_tokens,
            output_tokens=rec.output_tokens,
            tool_duration_s=rec.tool_duration_s,
            arrival=now,
        )
        req.trace = trace  # type: ignore[attr-defined]
        self._pending[pid] = req
        self.apply_plan(self.sched.request_arrived(pid, rec.input_tokens, now))

    def complete_request(self, req: _Request, now: float) -> None:
        self._pending.pop(req.pid, None)
        self._last_ctx[req.pid] = req.input_tokens + req.output_tokens
        self.completed_tokens += req.output_tokens
        self.completed_steps += 1
        if now >= self.warmup:
            self.completed_tokens_measured += req.output_tokens
            self.completed_steps_measured += 1
        self.apply_plan(self.sched.request_completed(req.pid, req.output_tokens, now))
        trace: ProgramTrace = req.trace  # type: ignore[attr-defined]
        nxt = req.step_idx + 1
        if nxt < len(trace.steps):
            self.at(
                now + req.tool_duration_s,
                lambda t, p=req.pid, tr=trace, n=nxt, s=req.slot: self._issue(
                    p, tr, n, s, t
                ),
            )
        else:
            prog = self.sched.programs.get(req.pid)
            if prog is not None:
                self.finished_programs.append(
                    {
                        "pid": req.pid,
                        "switches": prog.metrics.replica_switches,
                        "evictions": prog.metrics.evictions,
                        "gated_s": prog.metrics.gated_time_s,
                    }
                )
            self.apply_plan(self.sched.program_finished(req.pid, now))
            self._last_ctx.pop(req.pid, None)
            if now < self.duration:
                self.at(now + 1.0, lambda t, s=req.slot: self._start_trace(s, t))

    def record_ttft(self, req: _Request, now: float) -> None:
        if now >= self.warmup:
            self.ttfts.append(now - req.arrival)

    # ---------------------------------------------------------------- run
    def run(self) -> SimResult:
        stagger = 2.0 / max(1, self.n_slots)
        for slot in range(self.n_slots):
            self.at(slot * stagger, lambda t, s=slot: self._start_trace(s, t))

        def tick(t: float) -> None:
            w0 = _time.perf_counter()
            plan = self.sched.tick(t)
            self.tick_overhead_s.append(_time.perf_counter() - w0)
            self.tick_actions.append(len(plan))
            self.apply_plan(plan)
            if t + self.sched_config.tick_interval_s <= self.duration:
                self.at(t + self.sched_config.tick_interval_s, tick)

        self.at(self.sched_config.tick_interval_s, tick)

        for f in self.faults:
            self.at(f.fail_at, lambda t, f=f: self._fail(f.replica, t))
            if f.recover_at is not None:
                self.at(f.recover_at, lambda t, f=f: self._recover(f.replica, t))

        while self._q:
            t, _, fn = heapq.heappop(self._q)
            if t > self.duration:
                break
            self.now = t
            fn(t)
        for rep in self.replicas:
            rep.settle(min(self.duration, self.now))
        return self._result()

    def _fail(self, rid: int, now: float) -> None:
        self.replicas[rid].fail(now)
        self.apply_plan(self.sched.replica_failed(rid, now))

    def _recover(self, rid: int, now: float) -> None:
        self.replicas[rid].recover(now)
        self.sched.replica_recovered(rid)

    # ------------------------------------------------------------- metrics
    def _result(self) -> SimResult:
        span = max(1e-9, self.duration - self.warmup)
        switched = [p for p in self.finished_programs if p["switches"] > 0]
        nprog = max(1, len(self.finished_programs))
        util = [
            rep.busy_accum / max(1e-9, min(self.duration, self.now))
            for rep in self.replicas
        ]
        resumes = self.warm_forwards + self.reload_forwards + self.recompute_forwards
        return SimResult(
            scheduler=self.scheduler_name,
            hw=self.hw.name,
            duration_s=self.duration,
            output_tok_per_s=self.completed_tokens_measured / span,
            steps_per_s=self.completed_steps_measured / span,
            ttft_avg_s=sum(self.ttfts) / max(1, len(self.ttfts)),
            ttft_p50_s=percentile(self.ttfts, 0.5),
            ttft_p90_s=percentile(self.ttfts, 0.9),
            ttft_p99_s=percentile(self.ttfts, 0.99),
            gpu_util=sum(util) / max(1, len(util)),
            cache_hit_rate=(
                (self.warm_forwards + self.reload_forwards) / resumes if resumes else 0.0
            ),
            churn_frac=len(switched) / nprog,
            switches_per_program=(
                sum(p["switches"] for p in self.finished_programs) / nprog
            ),
            programs_finished=len(self.finished_programs),
            steps_completed=self.completed_steps,
            tick_avg_ms=(
                1e3 * sum(self.tick_overhead_s) / max(1, len(self.tick_overhead_s))
            ),
            tick_p99_ms=1e3 * percentile(self.tick_overhead_s, 0.99),
            xfer_overlap_frac=(
                sum(r.overlap_accum for r in self.replicas)
                / max(1e-9, sum(r.busy_accum for r in self.replicas))
            ),
        )
