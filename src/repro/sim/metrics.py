"""Result schema for simulator runs (the paper's §6.2 metrics)."""
from __future__ import annotations

from dataclasses import asdict, dataclass


def percentile(xs: list[float], q: float) -> float:
    if not xs:
        return 0.0
    s = sorted(xs)
    idx = min(len(s) - 1, max(0, int(round(q * (len(s) - 1)))))
    return s[idx]


@dataclass
class SimResult:
    scheduler: str
    hw: str
    duration_s: float
    # paper's three headline metrics (§6.2)
    output_tok_per_s: float
    steps_per_s: float
    ttft_avg_s: float
    ttft_p50_s: float
    ttft_p90_s: float
    ttft_p99_s: float
    # secondary metrics
    gpu_util: float
    cache_hit_rate: float
    churn_frac: float                # §6.2.2: fraction of programs switching
    switches_per_program: float
    programs_finished: int
    steps_completed: int
    tick_avg_ms: float               # Table 2: scheduler overhead
    tick_p99_ms: float
    # fraction of compute-busy time during which a KV transfer was in
    # flight on the same replica — the paper's "masked by GPU-CPU overlap"
    # claim (§6.2) as a measurable number
    xfer_overlap_frac: float = 0.0

    def to_dict(self) -> dict:
        return asdict(self)

    def row(self) -> str:
        return (
            f"{self.scheduler:6s} | {self.output_tok_per_s:9.1f} tok/s | "
            f"{self.steps_per_s:6.3f} step/s | TTFT {self.ttft_avg_s:7.2f}s "
            f"(p90 {self.ttft_p90_s:7.2f}) | util {self.gpu_util:5.1%} | "
            f"hit {self.cache_hit_rate:5.1%} | churn {self.churn_frac:5.1%} "
            f"({self.switches_per_program:.3f} sw/prog)"
        )
