"""Hardware + model cost-model configs for the simulator (paper Table 1).

Each :class:`HwConfig` describes one replica of one (GPU, model) pair. The
decode-step model is roofline-style:

    t_step = weight_read_bytes / hbm_bw            (weight streaming)
           + sum_r kv_bytes(r) / hbm_bw            (KV reads, batch-summed)
           + batch * flop_per_token / flops        (MXU/TensorCore term)

Prefill runs at a fixed MFU-derived token rate; chunked-prefill interference
multiplies the decode step time while a prefill is active. KV transfers
(offload/reload) share a full-duplex PCIe link per replica and overlap with
compute (paper §2.2, §6.2 'masked by GPU-CPU overlap').

The four paper rows are reproduced; `v5e8-*` rows are the TPU-native targets
used by the beyond-paper experiments (DESIGN.md §2).
"""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class HwConfig:
    name: str
    # model
    kv_bytes_per_token: int
    weight_bytes: int            # total parameter bytes (per replica)
    active_weight_bytes: int     # bytes actually streamed per decode step
    flop_per_token: float        # 2 * active params
    # memory system
    hbm_bytes: int               # per replica (sum over TP group)
    hbm_bw: float                # bytes/s aggregate
    flops: float                 # peak FLOP/s aggregate (bf16)
    pcie_bw: float               # host<->device bytes/s per replica
    # engine behaviour
    prefill_mfu: float = 0.45
    decode_overhead_s: float = 4e-3   # launch/sampling/framework per step
    prefill_interference: float = 1.7  # decode slowdown while prefilling
    kv_reserve_frac: float = 0.88      # fraction of (HBM - weights) for KV
    # per-token KV size in the *offload* format — what PCIe/NVMe transfers
    # and host tiers carry when pages quantize on offload (int8 ≈ half of
    # kv_bytes_per_token). None = offload format equals device format.
    kv_wire_bytes_per_token: int | None = None

    @property
    def wire_bytes_per_token(self) -> int:
        """Offload-format per-token size with the bf16 fallback applied."""
        return (
            self.kv_bytes_per_token
            if self.kv_wire_bytes_per_token is None
            else self.kv_wire_bytes_per_token
        )

    @property
    def gpu_kv_bytes(self) -> int:
        return int((self.hbm_bytes - self.weight_bytes) * self.kv_reserve_frac)

    @property
    def prefill_rate(self) -> float:
        """tokens/s during prefill."""
        return self.prefill_mfu * self.flops / self.flop_per_token

    def decode_step_time(self, batch: int, total_kv_bytes: int) -> float:
        if batch <= 0:
            return self.decode_overhead_s
        return (
            self.decode_overhead_s
            + self.active_weight_bytes / self.hbm_bw
            + total_kv_bytes / self.hbm_bw
            + batch * self.flop_per_token / self.flops
        )

    def with_cpu_ratio(self, ratio: float) -> "TieredHwConfig":
        return TieredHwConfig(self, int(self.gpu_kv_bytes * ratio))


@dataclass(frozen=True)
class TieredHwConfig:
    hw: HwConfig
    cpu_kv_bytes: int


def _gib(x: float) -> int:
    return int(x * (1 << 30))


# --------------------------------------------------------------- paper rows
# H200 (80 GB cap) + Qwen-2.5 7B, TP=1   [paper Fig. 7]
H200_80_QWEN7B = HwConfig(
    name="h200-80g-qwen2.5-7b",
    kv_bytes_per_token=28 * 2 * 4 * 128 * 2,      # 28L, 4 KV heads, d128, bf16
    weight_bytes=_gib(15.4),
    active_weight_bytes=_gib(15.4),
    flop_per_token=2 * 7.6e9,
    hbm_bytes=_gib(80),
    hbm_bw=4.8e12,
    flops=990e12,
    pcie_bw=55e9,
)

# H200 (141 GB) + Qwen-3 30B-A3B (MoE), TP=1   [paper Fig. 8, Fig. 10]
H200_QWEN30B = HwConfig(
    name="h200-qwen3-30b-a3b",
    kv_bytes_per_token=48 * 2 * 4 * 128 * 2,
    weight_bytes=_gib(61),
    active_weight_bytes=_gib(8.2),                # 3B active + shared
    flop_per_token=2 * 3.3e9,
    hbm_bytes=_gib(141),
    hbm_bw=4.8e12,
    flops=990e12,
    pcie_bw=55e9,
)

# B200 + Llama-3.1 70B, TP=2   [paper Fig. 9]
B200_LLAMA70B = HwConfig(
    name="b200-llama3.1-70b-tp2",
    kv_bytes_per_token=80 * 2 * 8 * 128 * 2,
    weight_bytes=_gib(141),
    active_weight_bytes=_gib(141),
    flop_per_token=2 * 70e9,
    hbm_bytes=2 * _gib(186),
    hbm_bw=2 * 8.0e12,
    flops=2 * 2250e12,
    pcie_bw=60e9,
)

# ------------------------------------------------------ TPU-native targets
# One v5e host (8 chips, TP=8) serving a 7B-class dense model. PCIe gen4
# shared per host; ICI-internal TP is inside the replica (not modeled here).
V5E8_QWEN7B = HwConfig(
    name="v5e8-qwen2.5-7b",
    kv_bytes_per_token=28 * 2 * 4 * 128 * 2,
    weight_bytes=_gib(15.4),
    active_weight_bytes=_gib(15.4),
    flop_per_token=2 * 7.6e9,
    hbm_bytes=8 * _gib(16),
    hbm_bw=8 * 819e9,
    flops=8 * 197e12,
    pcie_bw=16e9,          # host DRAM path is much narrower on TPU hosts
)

# One v5e host serving the 30B MoE (fits: 61 GB weights on 128 GB HBM).
V5E8_QWEN30B = HwConfig(
    name="v5e8-qwen3-30b-a3b",
    kv_bytes_per_token=48 * 2 * 4 * 128 * 2,
    weight_bytes=_gib(61),
    active_weight_bytes=_gib(8.2),
    flop_per_token=2 * 3.3e9,
    hbm_bytes=8 * _gib(16),
    hbm_bw=8 * 819e9,
    flops=8 * 197e12,
    pcie_bw=16e9,
)

CONFIGS: dict[str, HwConfig] = {
    c.name: c
    for c in [
        H200_80_QWEN7B,
        H200_QWEN30B,
        B200_LLAMA70B,
        V5E8_QWEN7B,
        V5E8_QWEN30B,
    ]
}


def small_test_hw(**overrides) -> HwConfig:
    """Tiny deterministic config for unit tests.

    Ratios mirror real serving hardware: a full-HBM read takes ~15 ms,
    recomputing a median context (~45k tokens) takes seconds, while
    reloading it over 'PCIe' takes tens of milliseconds — so placement
    policy matters exactly the way it does at real scale.
    """
    base = HwConfig(
        name="test-hw",
        kv_bytes_per_token=1000,
        weight_bytes=0,
        active_weight_bytes=10_000_000,
        flop_per_token=4.5e7,     # prefill ~10k tok/s at 45% MFU
        hbm_bytes=100_000_000,
        hbm_bw=14e9,
        flops=1e12,
        pcie_bw=2e9,
        decode_overhead_s=1e-3,
    )
    return replace(base, **overrides)
