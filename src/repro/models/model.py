"""Family-dispatching model assembly: describe / train-forward / prefill /
decode for every assigned architecture.

All layer stacks use ``jax.lax.scan`` over stacked parameters so compile time
and HLO size stay O(1) in depth (MaxText-style); decode caches are dense slot
buffers ``[L, B, S_max, ...]`` updated in place (JetStream-style — the TPU
adaptation of paged GPU caches, see DESIGN.md §2).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import kv_quant
from repro.models.config import ModelConfig
from repro.models.layers import (
    NULL_CTX,
    apply_dense_block,
    apply_dense_block_paged,
    apply_ffn,
    apply_mamba_block,
    apply_shared_block,
    blockwise_attention,
    decode_attention,
    describe_attention,
    describe_dense_block,
    describe_mamba_block,
    describe_shared_block,
    rmsnorm,
    softcap,
    stack,
    _project_qkv,
    _write_slot,
)
from repro.models.params import Leaf

F32 = jnp.float32
KV_AXES = ("layers", "batch", "kv_seq", "kv_heads_act", "head_dim")


def _maybe_remat(fn, enabled: bool):
    if not enabled:
        return fn
    return jax.checkpoint(fn, policy=jax.checkpoint_policies.nothing_saveable)


class Model:
    """Functional model for one :class:`ModelConfig`."""

    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg

    # ------------------------------------------------------------ describe
    def describe(self) -> dict:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab_size
        tree: dict = {
            "embed": Leaf((v, d), ("vocab", "embed"), scale=0.02),
            "ln_f": Leaf((d,), ("embed_act",), init="zeros"),
            "head": Leaf((d, v), ("embed", "vocab")),
        }
        if cfg.family in ("dense", "moe", "vlm"):
            tree["blocks"] = stack(describe_dense_block(cfg), cfg.num_layers)
        elif cfg.family == "ssm":
            tree["blocks"] = stack(describe_mamba_block(cfg), cfg.num_layers)
        elif cfg.family == "hybrid":
            groups = cfg.num_layers // cfg.shared_attn_period
            tree["blocks"] = stack(
                stack(describe_mamba_block(cfg), cfg.shared_attn_period), groups
            )
            tree["shared"] = describe_shared_block(cfg)
        elif cfg.family == "encdec":
            tree["enc_blocks"] = stack(describe_dense_block(cfg), cfg.encoder_layers)
            dec = describe_dense_block(cfg)
            dec["lnx"] = Leaf((d,), ("embed_act",), init="zeros")
            dec["cross"] = describe_attention(cfg)
            tree["blocks"] = stack(dec, cfg.num_layers)
            tree["enc_ln_f"] = Leaf((d,), ("embed_act",), init="zeros")
        else:
            raise ValueError(cfg.family)
        if cfg.local_global_alternating and cfg.family != "encdec":
            # gemma2: scan over (local, global) pairs
            pair = {
                "local": describe_dense_block(cfg),
                "global": describe_dense_block(cfg),
            }
            tree["blocks"] = stack(pair, cfg.num_layers // 2)
        return tree

    # --------------------------------------------------------------- cache
    def describe_cache(self, batch: int, max_seq: int) -> dict:
        cfg = self.cfg
        L, KH, HD = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim

        def kv(layers, seq, kh, hd):
            return {
                "k": Leaf((layers, batch, seq, kh, hd), KV_AXES, jnp.bfloat16,
                          init="zeros"),
                "v": Leaf((layers, batch, seq, kh, hd), KV_AXES, jnp.bfloat16,
                          init="zeros"),
            }

        if cfg.family in ("dense", "moe", "vlm"):
            if cfg.local_global_alternating:
                half = L // 2
                return {"local": kv(half, max_seq, KH, HD),
                        "global": kv(half, max_seq, KH, HD)}
            return kv(L, max_seq, KH, HD)
        if cfg.family == "ssm":
            return self._ssm_cache((L,), batch)
        if cfg.family == "hybrid":
            groups = L // cfg.shared_attn_period
            c = self._ssm_cache((groups, cfg.shared_attn_period), batch)
            c.update(
                {
                    "shared_"
                    + k: Leaf(
                        (groups, batch, max_seq, cfg.num_kv_heads, cfg.hybrid_head_dim),
                        KV_AXES,
                        jnp.bfloat16,
                        init="zeros",
                    )
                    for k in ("k", "v")
                }
            )
            return c
        if cfg.family == "encdec":
            c = kv(L, max_seq, KH, HD)
            c.update(
                {
                    "ck": Leaf((L, batch, cfg.encoder_seq, KH, HD), KV_AXES,
                               jnp.bfloat16, init="zeros"),
                    "cv": Leaf((L, batch, cfg.encoder_seq, KH, HD), KV_AXES,
                               jnp.bfloat16, init="zeros"),
                }
            )
            return c
        raise ValueError(cfg.family)

    def _ssm_cache(self, lead: tuple[int, ...], batch: int) -> dict:
        cfg = self.cfg
        conv_dim = cfg.ssm_inner + 2 * cfg.ssm_state
        lead_axes = tuple("layers" for _ in lead)
        return {
            "ssm": Leaf(
                (*lead, batch, cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state),
                (*lead_axes, "batch", "ssm_heads_act", None, None),
                F32,
                init="zeros",
            ),
            "conv": Leaf(
                (*lead, batch, cfg.ssm_conv_width - 1, conv_dim),
                (*lead_axes, "batch", None, "ssm_heads_act"),
                jnp.bfloat16,
                init="zeros",
            ),
        }

    # ------------------------------------------------------- sequence mode
    def sequence(self, params, x, positions, ctx=NULL_CTX, collect_cache=False,
                 frames=None, prefix=None, prefix_valid=None):
        """Run the full stack over a token-embedded sequence ``x`` [B,S,d].

        ``prefix``: optional {"k","v"} [L,B,Sp,KH,HD] radix-cached KV for
        chunked prefill (dense families only). ``prefix_valid`` (traced
        scalar) marks how many prefix positions are real when the prefix
        is padded to a bucket for shape-stable jit. Returns
        (hidden, cache_tree_or_None, aux_loss).
        """
        cfg = self.cfg
        enc_out = None
        if cfg.family == "encdec":
            enc_out = self._encode(params, frames, ctx)

        if cfg.family in ("dense", "moe", "vlm") and not cfg.local_global_alternating:

            def body(h, xs):
                p, pre = xs
                h, kv, aux = apply_dense_block(
                    p, h, cfg, positions=positions, window=cfg.sliding_window,
                    prefix=pre, prefix_valid=prefix_valid, ctx=ctx,
                )
                return h, (kv if collect_cache else None, aux)

            body = _maybe_remat(body, cfg.remat)
            pre_xs = (prefix["k"], prefix["v"]) if prefix is not None else None
            x, (kvs, auxs) = jax.lax.scan(body, x, (params["blocks"], pre_xs))
            cache = {"k": kvs[0], "v": kvs[1]} if collect_cache else None
            return x, cache, jnp.sum(auxs)

        if cfg.local_global_alternating and cfg.family != "encdec":

            def body(h, p):
                h, kv_l, aux1 = apply_dense_block(
                    p["local"], h, cfg, positions=positions,
                    window=cfg.sliding_window, ctx=ctx,
                )
                h, kv_g, aux2 = apply_dense_block(
                    p["global"], h, cfg, positions=positions, window=None, ctx=ctx
                )
                out = ((kv_l, kv_g) if collect_cache else None, aux1 + aux2)
                return h, out

            body = _maybe_remat(body, cfg.remat)
            x, (kvs, auxs) = jax.lax.scan(body, x, params["blocks"])
            cache = None
            if collect_cache:
                (lk, lv), (gk, gv) = kvs
                cache = {"local": {"k": lk, "v": lv}, "global": {"k": gk, "v": gv}}
            return x, cache, jnp.sum(auxs)

        if cfg.family == "ssm":

            def body(h, p):
                h, st = apply_mamba_block(p, h, cfg, ctx=ctx)
                return h, (st if collect_cache else None)

            body = _maybe_remat(body, cfg.remat)
            x, sts = jax.lax.scan(body, x, params["blocks"])
            cache = {"ssm": sts[0], "conv": sts[1]} if collect_cache else None
            return x, cache, jnp.zeros((), F32)

        if cfg.family == "hybrid":
            x0 = x

            def group(h, p):
                def inner(hh, pp):
                    hh, st = apply_mamba_block(pp, hh, cfg, ctx=ctx)
                    return hh, (st if collect_cache else None)

                h, sts = jax.lax.scan(inner, h, p)
                h, kv = apply_shared_block(
                    params["shared"], h, x0, cfg, positions=positions, ctx=ctx
                )
                return h, (sts, kv if collect_cache else None)

            group = _maybe_remat(group, cfg.remat)
            x, (sts, kvs) = jax.lax.scan(group, x, params["blocks"])
            cache = None
            if collect_cache:
                cache = {
                    "ssm": sts[0],
                    "conv": sts[1],
                    "shared_k": kvs[0],
                    "shared_v": kvs[1],
                }
            return x, cache, jnp.zeros((), F32)

        if cfg.family == "encdec":

            def body(h, p):
                h, kv, ckv, aux = self._decoder_block(
                    p, h, enc_out, positions, ctx, cache=None
                )
                return h, ((kv, ckv) if collect_cache else None, aux)

            body = _maybe_remat(body, cfg.remat)
            x, (kvs, auxs) = jax.lax.scan(body, x, params["blocks"])
            cache = None
            if collect_cache:
                (k, v), (ck, cv) = kvs
                cache = {"k": k, "v": v, "ck": ck, "cv": cv}
            return x, cache, jnp.sum(auxs)

        raise ValueError(cfg.family)

    def _encode(self, params, frames, ctx):
        cfg = self.cfg
        pos = jnp.arange(frames.shape[1])[None, :]

        def body(h, p):
            h, _, aux = apply_dense_block(
                p, h, cfg, positions=pos, causal=False, ctx=ctx
            )
            return h, aux

        body = _maybe_remat(body, cfg.remat)
        h, _ = jax.lax.scan(body, frames.astype(jnp.bfloat16), params["enc_blocks"])
        return rmsnorm(h, params["enc_ln_f"])

    def _decoder_block(self, p, h, enc_out, positions, ctx, cache, lengths=None):
        """whisper decoder block: self-attn + cross-attn + ffn."""
        cfg = self.cfg
        H, KH, HD = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
        if cache is None:
            h, kv, aux = _self_attn_only(p, h, cfg, positions, ctx)
            # cross attention against encoder output
            xq = rmsnorm(h, p["lnx"])
            q = (xq @ p["cross"]["wq"]).reshape(*xq.shape[:2], H, HD)
            ck = (enc_out @ p["cross"]["wk"]).reshape(
                enc_out.shape[0], enc_out.shape[1], KH, HD
            )
            cv = (enc_out @ p["cross"]["wv"]).reshape(
                enc_out.shape[0], enc_out.shape[1], KH, HD
            )
            a = blockwise_attention(q, ck, cv, causal=False, ctx=ctx)
            a = a.reshape(*xq.shape[:2], H * HD)
            h = h + a @ p["cross"]["wo"]
            h = h + apply_ffn(p["ffn"], rmsnorm(h, p["ln2"]), ctx)
            h = ctx.constrain(h, ("batch", "seq", "embed_act"))
            return h, kv, (ck, cv), aux
        else:
            (k_cache, v_cache, ck, cv) = cache
            h, (k_cache, v_cache), aux = _self_attn_only(
                p, h, cfg, positions, ctx, cache=(k_cache, v_cache), lengths=lengths
            )
            xq = rmsnorm(h, p["lnx"])
            q = (xq @ p["cross"]["wq"]).reshape(xq.shape[0], H, HD)
            enc_len = jnp.full((xq.shape[0],), ck.shape[1], jnp.int32)
            a = decode_attention(q, ck, cv, lengths=enc_len, ctx=ctx)[:, None, :]
            h = h + a @ p["cross"]["wo"]
            h = h + apply_ffn(p["ffn"], rmsnorm(h, p["ln2"]), ctx)
            return h, (k_cache, v_cache), (ck, cv), aux

    # ----------------------------------------------------------- decode
    def decode(self, params, cache, tokens, lengths, ctx=NULL_CTX):
        """One decode step. tokens [B] int32; lengths [B] = context length
        including the new token. Returns (logits [B,V], new_cache)."""
        cfg = self.cfg
        x = jnp.take(params["embed"], tokens, axis=0)[:, None, :]  # [B,1,d]
        positions = (lengths - 1)[:, None]

        if cfg.family in ("dense", "moe", "vlm") and not cfg.local_global_alternating:

            def body(h, xs):
                p, k, v = xs
                h, (k, v), _ = apply_dense_block(
                    p, h, cfg, positions=positions, window=cfg.sliding_window,
                    cache=(k, v), lengths=lengths, ctx=ctx,
                )
                return h, (k, v)

            x, (ks, vs) = jax.lax.scan(body, x, (params["blocks"], cache["k"], cache["v"]))
            new_cache = {"k": ks, "v": vs}

        elif cfg.local_global_alternating:
            # ring-buffer local cache when its slot count < the global cache's
            local_slots = cache["local"]["k"].shape[2]          # [L/2,B,S,KH,HD]
            ring = local_slots if local_slots < cache["global"]["k"].shape[2] else None

            def body(h, xs):
                p, lk, lv, gk, gv = xs
                h, (lk, lv), _ = apply_dense_block(
                    p["local"], h, cfg, positions=positions,
                    window=cfg.sliding_window, cache=(lk, lv), lengths=lengths,
                    ring_window=ring, ctx=ctx,
                )
                h, (gk, gv), _ = apply_dense_block(
                    p["global"], h, cfg, positions=positions, window=None,
                    cache=(gk, gv), lengths=lengths, ctx=ctx,
                )
                return h, (lk, lv, gk, gv)

            x, (lks, lvs, gks, gvs) = jax.lax.scan(
                body,
                x,
                (
                    params["blocks"],
                    cache["local"]["k"],
                    cache["local"]["v"],
                    cache["global"]["k"],
                    cache["global"]["v"],
                ),
            )
            new_cache = {
                "local": {"k": lks, "v": lvs},
                "global": {"k": gks, "v": gvs},
            }

        elif cfg.family == "ssm":

            def body(h, xs):
                p, st, cv = xs
                h, (st, cv) = apply_mamba_block(p, h, cfg, cache=(st, cv), ctx=ctx)
                return h, (st, cv)

            x, (sts, cvs) = jax.lax.scan(
                body, x, (params["blocks"], cache["ssm"], cache["conv"])
            )
            new_cache = {"ssm": sts, "conv": cvs}

        elif cfg.family == "hybrid":
            x0 = x

            def group(h, xs):
                p, st, cv, sk, sv = xs

                def inner(hh, pp_s):
                    pp, st1, cv1 = pp_s
                    hh, (st1, cv1) = apply_mamba_block(
                        pp, hh, cfg, cache=(st1, cv1), ctx=ctx
                    )
                    return hh, (st1, cv1)

                h, (st, cv) = jax.lax.scan(inner, h, (p, st, cv))
                h, (sk, sv) = apply_shared_block(
                    params["shared"], h, x0, cfg, positions=positions,
                    cache=(sk, sv), lengths=lengths, ctx=ctx,
                )
                return h, (st, cv, sk, sv)

            x, (sts, cvs, sks, svs) = jax.lax.scan(
                group,
                x,
                (
                    params["blocks"],
                    cache["ssm"],
                    cache["conv"],
                    cache["shared_k"],
                    cache["shared_v"],
                ),
            )
            new_cache = {"ssm": sts, "conv": cvs, "shared_k": sks, "shared_v": svs}

        elif cfg.family == "encdec":

            def body(h, xs):
                p, k, v, ck, cv = xs
                h, (k, v), (ck, cv), _ = self._decoder_block(
                    p, h, None, positions, ctx, cache=(k, v, ck, cv), lengths=lengths
                )
                return h, (k, v, ck, cv)

            x, (ks, vs, cks, cvs) = jax.lax.scan(
                body, x, (params["blocks"], cache["k"], cache["v"], cache["ck"], cache["cv"])
            )
            new_cache = {"k": ks, "v": vs, "ck": cks, "cv": cvs}
        else:
            raise ValueError(cfg.family)

        h = rmsnorm(x[:, 0, :], params["ln_f"])
        logits = softcap((h @ params["head"]).astype(F32), cfg.final_logit_softcap)
        logits = ctx.constrain(logits, ("batch", "vocab_act"))
        return logits, new_cache

    # ------------------------------------------------------- paged decode
    def decode_paged(
        self, params, k_pages, v_pages, tokens, lengths, block_tables,
        tail_pages, tail_offsets, k_scales=None, v_scales=None, ctx=NULL_CTX,
    ):
        """One block-table decode step (dense-cache families only).

        The paged twin of :meth:`decode`: the KV cache is the serving
        engine's ``PagePool`` arrays ``k_pages``/``v_pages``
        ``[L, N, T, KH, HD]`` — not a per-slot dense buffer — and each
        sequence reads its context through ``block_tables`` ``[B, P]``.
        The new token's KV (global position ``lengths[b] - 1``) is carried
        out of the layer scan and appended at ``(tail_pages[b],
        tail_offsets[b])`` in ONE batched scatter for all layers — with
        input donation that is an in-place pool update, so a decode step
        never copies the pool (the old per-layer write forced L full-pool
        copies through the scan). Layers scan exactly like :meth:`decode`
        so compile stays O(1) in depth.

        On an int8-resident pool, pass the per-(layer, page) scale
        sidecars ``k_scales``/``v_scales`` ``[L, N]``: each layer's slice
        rides the scan for the kernel's dequant, and the commit becomes a
        requantize-insert of the tail pages (their scales may grow to
        admit the new token).
        Returns ``(logits [B, V], k_pages', v_pages')`` — plus
        ``(k_scales', v_scales')`` when sidecars were passed.
        """
        cfg = self.cfg
        assert cfg.family in ("dense", "moe", "vlm") and (
            not cfg.local_global_alternating
        ), "paged decode serves the dense-cache families"
        quantized = k_scales is not None
        x = jnp.take(params["embed"], tokens, axis=0)[:, None, :]  # [B,1,d]

        def body(h, xs):
            if quantized:
                p, kp, vp, ks, vs = xs
            else:
                p, kp, vp = xs
                ks = vs = None
            h, (k_new, v_new), _ = apply_dense_block_paged(
                p, h, cfg, k_pages=kp, v_pages=vp, block_tables=block_tables,
                tail_pages=tail_pages, tail_offsets=tail_offsets,
                lengths=lengths, k_scales=ks, v_scales=vs,
                window=cfg.sliding_window, ctx=ctx,
            )
            return h, (k_new, v_new)

        xs = (
            (params["blocks"], k_pages, v_pages, k_scales, v_scales)
            if quantized
            else (params["blocks"], k_pages, v_pages)
        )
        x, (k_news, v_news) = jax.lax.scan(body, x, xs)
        # commit all layers' appends at once: k_news/v_news [L, B, KH, HD]
        # land at [:, tail_pages[b], tail_offsets[b]] (unique per row)
        if quantized:
            k_pages, k_scales = kv_quant.requantize_insert_run(
                k_pages, k_scales, tail_pages, tail_offsets, k_news
            )
            v_pages, v_scales = kv_quant.requantize_insert_run(
                v_pages, v_scales, tail_pages, tail_offsets, v_news
            )
        else:
            k_pages = k_pages.at[:, tail_pages, tail_offsets].set(
                k_news.astype(k_pages.dtype)
            )
            v_pages = v_pages.at[:, tail_pages, tail_offsets].set(
                v_news.astype(v_pages.dtype)
            )
        h = rmsnorm(x[:, 0, :], params["ln_f"])
        logits = softcap((h @ params["head"]).astype(F32), cfg.final_logit_softcap)
        logits = ctx.constrain(logits, ("batch", "vocab_act"))
        if quantized:
            return logits, k_pages, v_pages, k_scales, v_scales
        return logits, k_pages, v_pages

    # ------------------------------------------------------------ prefill
    def prefill(self, params, batch: dict, ctx=NULL_CTX, prefix=None,
                logit_index=None, positions_offset=None, prefix_valid=None):
        """Full- or suffix-context forward; returns (last_logits, cache).

        With ``prefix`` (stacked radix-cached KV), this is chunked prefill:
        only ``batch["tokens"]`` (the suffix) is computed, attending over
        prefix+suffix. The returned cache covers the suffix only.

        ``logit_index`` names the *token* position whose logits to return
        (default: the last; may be a traced scalar — the engine's jitted
        chunk prefill passes it as an argument so the final-chunk shape
        compiles once). The serving engine pads suffixes to a fixed bucket
        so prefill compiles once per bucket instead of once per length —
        causality guarantees positions at or before ``logit_index`` never
        see the padding.

        ``positions_offset``/``prefix_valid`` support a *bucketed* prefix:
        when the prefix KV is padded past its real length for shape-stable
        jit, ``positions_offset`` is the real absolute position of the
        first suffix token (RoPE must use true positions, not padded
        indices) and ``prefix_valid`` masks the padded prefix tail out of
        attention. Both default to the unpadded behaviour.
        """
        cfg = self.cfg
        tokens = batch["tokens"]
        x = jnp.take(params["embed"], tokens, axis=0)
        n_img = 0
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(x.dtype)
            n_img = img.shape[1]
            x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
        q_off = 0 if prefix is None else prefix["k"].shape[2]
        pos0 = q_off if positions_offset is None else positions_offset
        positions = pos0 + jnp.arange(S)[None, :]
        x = ctx.constrain(x, ("batch", "seq", "embed_act"))
        h, cache, _ = self.sequence(
            params, x, positions, ctx, collect_cache=True,
            frames=batch.get("frames"), prefix=prefix,
            prefix_valid=prefix_valid,
        )
        idx = -1 if logit_index is None else n_img + logit_index
        h = rmsnorm(h[:, idx, :], params["ln_f"])
        logits = softcap((h @ params["head"]).astype(F32), cfg.final_logit_softcap)
        return logits, cache

    # -------------------------------------------------------------- train
    def loss(self, params, batch: dict, ctx=NULL_CTX):
        cfg = self.cfg
        tokens = batch["tokens"]                               # [B, S+1]
        inputs, labels = tokens[:, :-1], tokens[:, 1:]
        x = jnp.take(params["embed"], inputs, axis=0)
        n_img = 0
        if cfg.family == "vlm":
            img = batch["image_embeds"].astype(x.dtype)
            n_img = img.shape[1]
            x = jnp.concatenate([img, x], axis=1)
        S = x.shape[1]
        positions = jnp.arange(S)[None, :]
        x = ctx.constrain(x, ("batch", "seq", "embed_act"))
        h, _, aux = self.sequence(
            params, x, positions, ctx, collect_cache=False,
            frames=batch.get("frames"),
        )
        if n_img:
            h = h[:, n_img:, :]
        h = rmsnorm(h, params["ln_f"])
        logits = softcap((h @ params["head"]).astype(F32), cfg.final_logit_softcap)
        logits = ctx.constrain(logits, ("batch", "seq", "vocab_act"))
        logz = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
        ce = (logz - gold).mean()
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}


def _self_attn_only(p, h, cfg, positions, ctx, cache=None, lengths=None):
    """The attention half of a dense block (used by the whisper decoder)."""
    H, KH, HD = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    a_in = rmsnorm(h, p["ln1"])
    q, k, v = _project_qkv(p["attn"], a_in, H, KH, HD, positions, cfg.rope_theta, ctx=ctx)
    if cache is None:
        a = blockwise_attention(q, k, v, causal=True, ctx=ctx)
        a = a.reshape(*h.shape[:2], H * HD)
        h = h + a @ p["attn"]["wo"]
        return h, (k, v), jnp.zeros((), F32)
    k_cache, v_cache = cache
    idx = lengths - 1
    k_cache = _write_slot(k_cache, k[:, 0], idx)
    v_cache = _write_slot(v_cache, v[:, 0], idx)
    a = decode_attention(q[:, 0], k_cache, v_cache, lengths=lengths, ctx=ctx)[:, None, :]
    h = h + a @ p["attn"]["wo"]
    return h, (k_cache, v_cache), jnp.zeros((), F32)
