"""Parameter descriptors: shapes + logical sharding axes, materialized lazily.

Model code builds a pytree of :class:`Leaf` descriptors (no allocation).
From it we derive, without ever touching device memory:

* ``abstract(tree)``      -> ShapeDtypeStruct pytree (dry-run `.lower()` input)
* ``spec_tree(tree, ...)``-> PartitionSpec pytree (in/out shardings)
* ``materialize(tree)``   -> real initialized params (smoke tests / engine)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.dist.sharding import ShardingRules


@dataclass
class Leaf:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]
    dtype: Any = jnp.bfloat16
    scale: float | None = None   # None -> 1/sqrt(fan_in); 0.0 -> zeros; else stddev
    init: str = "normal"         # normal | zeros | ones

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_leaf(x) -> bool:
    return isinstance(x, Leaf)


def abstract(tree):
    return jax.tree.map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), tree, is_leaf=is_leaf
    )


def spec_tree(tree, mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda l: rules.spec(mesh, l.axes, l.shape), tree, is_leaf=is_leaf
    )


def sharding_tree(tree, mesh, rules: ShardingRules):
    return jax.tree.map(
        lambda l: rules.sharding(mesh, l.axes, l.shape), tree, is_leaf=is_leaf
    )


def materialize(tree, seed: int = 0):
    """Initialize real parameter values (small configs only)."""
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_leaf)
    rng = np.random.default_rng(seed)
    out = []
    for l in leaves:
        if l.init == "zeros" or l.scale == 0.0:
            out.append(jnp.zeros(l.shape, l.dtype))
            continue
        if l.init == "ones":
            out.append(jnp.ones(l.shape, l.dtype))
            continue
        fan_in = l.shape[-2] if len(l.shape) >= 2 else max(1, l.shape[-1])
        std = l.scale if l.scale is not None else 1.0 / np.sqrt(fan_in)
        vals = rng.standard_normal(l.shape, dtype=np.float32) * std
        out.append(jnp.asarray(vals, l.dtype))
    return jax.tree.unflatten(treedef, out)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_leaf)
    return sum(int(np.prod(l.shape)) for l in leaves)
