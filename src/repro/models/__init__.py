"""Model zoo: composable blocks + family-dispatching assembly."""
from repro.models.config import (
    DECODE_32K,
    LONG_500K,
    PREFILL_32K,
    SHAPES,
    TRAIN_4K,
    ModelConfig,
    ShapeConfig,
)
from repro.models.layers import NULL_CTX, ShardCtx
from repro.models.model import Model
from repro.models.params import abstract, count_params, materialize, spec_tree

__all__ = [
    "DECODE_32K",
    "LONG_500K",
    "Model",
    "ModelConfig",
    "NULL_CTX",
    "PREFILL_32K",
    "SHAPES",
    "ShapeConfig",
    "ShardCtx",
    "TRAIN_4K",
    "abstract",
    "count_params",
    "materialize",
    "spec_tree",
]
