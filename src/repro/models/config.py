"""Unified model configuration covering all 10 assigned architectures."""
from __future__ import annotations

from dataclasses import dataclass, replace


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense | moe | ssm | hybrid | encdec | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # attention variants
    qkv_bias: bool = False            # qwen1.5
    sliding_window: int | None = None  # gemma2 local layers
    local_global_alternating: bool = False
    attn_logit_softcap: float | None = None   # gemma2: 50.0
    final_logit_softcap: float | None = None  # gemma2: 30.0
    rope_theta: float = 10_000.0
    # MoE
    num_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0
    dense_residual: bool = False      # arctic: dense FFN in parallel with MoE
    capacity_factor: float = 1.25
    # SSM (mamba2 / zamba2)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    ssm_chunk: int = 128
    # hybrid (zamba2): one *shared* attention block applied every N layers
    shared_attn_period: int = 0
    # encoder-decoder (whisper)
    encoder_layers: int = 0
    encoder_seq: int = 1500           # whisper frame positions (stub frontend)
    # VLM (internvl2): stub patch embeddings prepended to the text sequence
    num_image_tokens: int = 0
    # numerics / memory
    dtype: str = "bfloat16"
    remat: bool = True
    # optimizer memory mode for the giants (arctic): bf16 Adam moments
    bf16_moments: bool = False

    # ------------------------------------------------------------- derived
    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def ssm_inner(self) -> int:
        return self.d_model * self.ssm_expand

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic context handling (long_500k eligibility)."""
        return self.family in ("ssm", "hybrid")

    @property
    def kv_bytes_per_token(self) -> int:
        """Serving-state bytes per context token (MORI's placement currency)."""
        b = 2  # bf16
        if self.family == "ssm":
            return 0  # O(1) state, accounted separately
        if self.family == "hybrid":
            n_shared = self.num_layers // max(1, self.shared_attn_period)
            return n_shared * 2 * self.num_kv_heads * self.hybrid_head_dim * b
        return self.num_layers * 2 * self.num_kv_heads * self.head_dim * b  # lint: kv008-ok (b parameterizes the element size; the 2 is K/V planes)

    @property
    def hybrid_head_dim(self) -> int:
        # zamba2's shared block runs on concat(hidden, embedding) = 2*d_model
        return 2 * self.d_model // self.num_heads

    def params_billions(self) -> float:
        """Rough parameter count (for 6ND model-FLOPs accounting)."""
        d = self.d_model
        attn = d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if self.family == "ssm":
            attn = 0
        dense_ffn = 3 * d * self.d_ff if self.d_ff else 0
        moe = self.num_experts * 3 * d * self.moe_d_ff
        ssm = 0
        if self.family in ("ssm", "hybrid"):
            inner = self.ssm_inner
            ssm = d * 2 * inner + d * 2 * self.ssm_heads * self.ssm_state  # in_proj
            ssm += inner * d  # out_proj
        per_layer = attn + dense_ffn + moe + ssm
        total = self.num_layers * per_layer + 2 * self.vocab_size * d
        if self.family == "hybrid" and self.shared_attn_period:
            d2 = 2 * d
            shared = 4 * d2 * d2 + 3 * d2 * self.d_ff + d2 * d
            total += shared - self.num_layers * (attn + dense_ffn)  # replace
            total += self.num_layers * ssm
        if self.encoder_layers:
            total += self.encoder_layers * (attn + dense_ffn)
        return total / 1e9

    def active_params_billions(self) -> float:
        """Active (per-token) params: replaces E experts with top_k."""
        if not self.num_experts:
            return self.params_billions()
        full = self.params_billions()
        moe_total = self.num_layers * self.num_experts * 3 * self.d_model * self.moe_d_ff
        moe_active = self.num_layers * self.top_k * 3 * self.d_model * self.moe_d_ff
        return full - (moe_total - moe_active) / 1e9

    def reduced(self, **overrides) -> "ModelConfig":
        """A smoke-test-sized config of the same family."""
        small = dict(
            num_layers=max(2, min(4, self.num_layers)),
            d_model=256,
            num_heads=4,
            num_kv_heads=min(4, max(1, self.num_kv_heads // max(1, self.num_heads // 4))),
            head_dim=64,
            d_ff=512 if self.d_ff else 0,
            vocab_size=512,
            sliding_window=64 if self.sliding_window else None,
            num_experts=min(4, self.num_experts),
            top_k=min(2, self.top_k),
            moe_d_ff=256 if self.num_experts else 0,
            # dropless at smoke scale so decode == full-forward exactly
            capacity_factor=8.0 if self.num_experts else self.capacity_factor,
            ssm_state=32 if self.ssm_state else 0,
            ssm_head_dim=32,
            ssm_chunk=16,
            shared_attn_period=2 if self.shared_attn_period else 0,
            encoder_layers=2 if self.encoder_layers else 0,
            encoder_seq=24 if self.encoder_layers else 1500,
            num_image_tokens=8 if self.num_image_tokens else 0,
            remat=False,
        )
        if self.family == "hybrid":
            small["num_layers"] = 4
        small.update(overrides)
        return replace(self, **small)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""

    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


TRAIN_4K = ShapeConfig("train_4k", "train", 4096, 256)
PREFILL_32K = ShapeConfig("prefill_32k", "prefill", 32_768, 32)
DECODE_32K = ShapeConfig("decode_32k", "decode", 32_768, 128)
LONG_500K = ShapeConfig("long_500k", "decode", 524_288, 1)

SHAPES = {s.name: s for s in [TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K]}
