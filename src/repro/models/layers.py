"""Block library for all assigned architecture families.

Every block is a (describe_*, apply_*) pair: ``describe_*`` builds a pytree
of :class:`repro.models.params.Leaf` descriptors with logical sharding axes;
``apply_*`` is the pure function. Blocks are scan-friendly (stacked along a
leading "layers" axis via :func:`stack`).

Attention uses a blockwise online-softmax (flash-style) path for sequence
processing so 32k-token prefill never materializes an SxS score matrix, and
a single-token path for decode. Sliding windows (gemma2 local layers), logit
softcap, GQA, cross-attention (whisper) and QKV bias (qwen1.5) are supported.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.dist.sharding import ShardingRules
from repro.kernels.paged_attention.ops import paged_attention_decode
from repro.kernels.ssd.ref import ssd_decode_step, ssd_reference
from repro.models.config import ModelConfig
from repro.models.params import Leaf

F32 = jnp.float32


@dataclass
class ShardCtx:
    """Mesh + rules for activation sharding constraints (None in tests)."""

    mesh: object | None = None
    rules: ShardingRules | None = None

    def constrain(self, x, logical):
        if self.mesh is None or self.rules is None:
            return x
        return jax.lax.with_sharding_constraint(
            x, self.rules.sharding(self.mesh, logical, x.shape)
        )


NULL_CTX = ShardCtx()


def stack(tree, n: int):
    """Add a leading stacked-layers dim to every Leaf (for lax.scan)."""
    return jax.tree.map(
        lambda l: Leaf((n, *l.shape), ("layers", *l.axes), l.dtype, l.scale, l.init),
        tree,
        is_leaf=lambda x: isinstance(x, Leaf),
    )


# =========================================================== tiny primitives
def rmsnorm(x, w, eps=1e-6):
    xf = x.astype(F32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps)).astype(x.dtype) * (1.0 + w.astype(x.dtype))


def softcap(t, cap):
    if cap is None:
        return t
    return cap * jnp.tanh(t / cap)


def rope(x, positions, theta: float):
    """x: [..., S, H, D]; positions broadcastable to [..., S]."""
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=F32) / half)
    angles = positions.astype(F32)[..., None] * freqs          # [..., S, half]
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half].astype(F32), x[..., half:].astype(F32)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ================================================================= attention
def describe_attention(cfg: ModelConfig, d_in: int | None = None, heads=None,
                       kv_heads=None, head_dim=None, bias: bool | None = None):
    d = d_in or cfg.d_model
    h = heads or cfg.num_heads
    kh = kv_heads or cfg.num_kv_heads
    hd = head_dim or cfg.head_dim
    bias = cfg.qkv_bias if bias is None else bias
    p = {
        "wq": Leaf((d, h * hd), ("embed", "heads")),
        "wk": Leaf((d, kh * hd), ("embed", "heads")),
        "wv": Leaf((d, kh * hd), ("embed", "heads")),
        "wo": Leaf((h * hd, d), ("heads", "embed")),
    }
    if bias:
        p["bq"] = Leaf((h * hd,), ("heads",), init="zeros")
        p["bk"] = Leaf((kh * hd,), ("heads",), init="zeros")
        p["bv"] = Leaf((kh * hd,), ("heads",), init="zeros")
    return p


def _project_qkv(p, x, h, kh, hd, positions, theta, use_rope=True, ctx=NULL_CTX):
    B, S, _ = x.shape
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, h, hd)
    k = k.reshape(B, S, kh, hd)
    v = v.reshape(B, S, kh, hd)
    if use_rope:
        q = rope(q, positions, theta)
        k = rope(k, positions, theta)
    q = ctx.constrain(q, ("batch", None, "heads_act", None))
    k = ctx.constrain(k, ("batch", None, "kv_heads_act", None))
    v = ctx.constrain(v, ("batch", None, "kv_heads_act", None))
    return q, k, v


def _largest_divisor(n: int, pref: int) -> int:
    """Largest divisor of ``n`` that is <= ``pref``.

    Non-power-of-two sequence lengths (whisper's 1500 encoder frames,
    internvl2's patch-prefixed 4352) can't use the preferred block size;
    an exact divisor keeps the online-softmax loop mask-free rather than
    padding + masking the tail block.
    """
    d = min(pref, n)
    while n % d:
        d -= 1
    return d


def blockwise_attention(
    q, k, v, *, causal: bool, window: int | None = None, cap: float | None = None,
    q_offset=0, kv_lengths=None, kv_hole=None, q_block: int = 512,
    kv_block: int = 1024, ctx=None,
):
    """Flash-style online-softmax attention, pure jnp (portable path).

    q: [B, Sq, H, D]; k, v: [B, Skv, KH, D] (GQA: H = KH * G).
    ``q_offset``: absolute position of q[0] (decode/chunked prefill).
    ``kv_lengths``: [B] valid KV lengths (None = all valid).
    ``kv_hole``: optional ``(lo, hi)`` — KV indices in ``[lo, hi)`` are
    masked invalid for every query. Chunked prefill pads its page-gathered
    prefix to a fixed bucket for shape-stable jit; the hole excludes the
    padding between the real prefix length and the padded one.

    GQA is handled by repeating KV to the full head count up front: a
    [KH, G] reshape of the head dim would break GSPMD head sharding
    whenever KH or G alone isn't divisible by the model axis (e.g. 48
    heads = 8 KV x 6 on a 16-way axis), silently replicating the entire
    score computation 16x. The repeat keeps heads flat and sharded; the
    expanded KV is (G x KV)/model_parallel per device — far smaller than
    replicated scores.
    """
    B, Sq, H, D = q.shape
    Skv, KH = k.shape[1], k.shape[2]
    if KH != H:
        k = jnp.repeat(k, H // KH, axis=2)
        v = jnp.repeat(v, H // KH, axis=2)
        if ctx is not None:
            k = ctx.constrain(k, ("batch", None, "heads_act", None))
            v = ctx.constrain(v, ("batch", None, "heads_act", None))
        KH = H
    G = H // KH
    qb = _largest_divisor(Sq, q_block)
    kb = _largest_divisor(Skv, kv_block)
    nq, nk = Sq // qb, Skv // kb
    scale = D ** -0.5

    # keep Q/K/V in model dtype; dots accumulate in f32 via
    # preferred_element_type (a wholesale .astype(F32) materializes f32
    # copies of the full-sequence tensors — see EXPERIMENTS.md §Perf)
    qr = q.reshape(B, nq, qb, KH, G, D)
    kr = k.reshape(B, nk, kb, KH, D)
    vr = v.reshape(B, nk, kb, KH, D)

    q_pos = q_offset + jnp.arange(Sq).reshape(nq, qb)
    k_pos = jnp.arange(Skv).reshape(nk, kb)

    # sliding-window block skip (what the flash kernel's grid does): a q
    # block at positions [lo, lo+qb) only sees kv blocks intersecting
    # (lo - window, lo + qb) — visit those ~(window+qb)/kb + 2 blocks
    # instead of all nk and masking. Exact: skipped blocks are fully masked.
    skip_blocks = (
        window is not None and causal and kv_lengths is None
        and kv_hole is None and (window + qb) // kb + 2 < nk
    )
    n_vis = min(nk, (window + qb) // kb + 2) if skip_blocks else nk

    def q_block_fn(qi):
        qblk = qr[:, qi]                                       # [B,qb,KH,G,D]
        qp = q_pos[qi]                                         # [qb]
        lo_blk = (
            jnp.maximum(0, (q_offset + qi * qb - window + 1) // kb)
            if skip_blocks else 0
        )

        def kv_step(carry, j):
            m, l, acc = carry
            ki = lo_blk + j if skip_blocks else j
            in_range = ki < nk
            ki = jnp.minimum(ki, nk - 1)
            kblk, vblk, kp = kr[:, ki], vr[:, ki], k_pos[ki]
            s = jnp.einsum(
                "bqhgd,bkhd->bqhgk", qblk, kblk,
                preferred_element_type=F32,
            ) * scale                                          # [B,qb,KH,G,kb]
            s = softcap(s, cap)
            mask = jnp.ones((qb, kb), bool) & in_range
            if causal:
                mask &= qp[:, None] >= kp[None, :]
            if window is not None:
                mask &= (qp[:, None] - kp[None, :]) < window
            m_ = mask[None, :, None, None, :]
            if kv_lengths is not None:
                m_ = m_ & (kp[None, :] < kv_lengths[:, None])[:, None, None, None, :]
            if kv_hole is not None:
                lo, hi = kv_hole
                m_ = m_ & ~((kp >= lo) & (kp < hi))[None, None, None, None, :]
            s = jnp.where(m_, s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(s - m_new[..., None])
            l_new = l * alpha + pexp.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bqhgk,bkhd->bqhgd", pexp.astype(q.dtype), vblk,
                preferred_element_type=F32,
            )
            return (m_new, l_new, acc_new), None

        init = (
            jnp.full((B, qb, KH, G), -1e30, F32),
            jnp.zeros((B, qb, KH, G), F32),
            jnp.zeros((B, qb, KH, G, D), F32),
        )
        (m, l, acc), _ = jax.lax.scan(kv_step, init, jnp.arange(n_vis))
        return acc / jnp.maximum(l, 1e-30)[..., None]          # [B,qb,KH,G,D]

    out = jax.lax.map(q_block_fn, jnp.arange(nq))              # [nq,B,qb,KH,G,D]
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, D)
    return out.astype(q.dtype)


def decode_attention(q, k_cache, v_cache, *, lengths, window=None, cap=None, ctx=NULL_CTX):
    """Single-token attention over a slot cache.

    q: [B, H, D]; k_cache/v_cache: [B, S, KH, D]; lengths: [B] (tokens valid,
    inclusive of the one just written).
    """
    B, S, KH, D = k_cache.shape
    H = q.shape[1]
    G = H // KH
    # f32 accumulation WITHOUT .astype(F32) on the caches: a wholesale
    # upcast makes XLA hoist an f32 copy of the entire KV cache out of the
    # layer scan (f32 loop carry, 2x cache traffic + entry round-trip
    # copies — found via the dry-run roofline, see EXPERIMENTS.md §Perf).
    # preferred_element_type keeps the cache reads bf16 and the MXU
    # accumulator f32.
    s = jnp.einsum(
        "bhgd,bshd->bhgs", q.reshape(B, KH, G, D), k_cache,
        preferred_element_type=F32,
    ) * (D ** -0.5)
    s = softcap(s, cap)
    pos = jnp.arange(S)[None, :]                               # [1,S]
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    # stable softmax over (possibly model-sharded) S
    m = s.max(-1, keepdims=True)
    p = jnp.exp(s - m)
    out = jnp.einsum(
        "bhgs,bshd->bhgd", p.astype(q.dtype), v_cache,
        preferred_element_type=F32,
    )
    out = out / p.sum(-1)[..., None]
    return out.reshape(B, H * D).astype(q.dtype)


# ======================================================================= FFN
def describe_ffn(cfg: ModelConfig, d_in: int | None = None, d_ff: int | None = None,
                 d_out: int | None = None):
    d = d_in or cfg.d_model
    f = d_ff or cfg.d_ff
    o = d_out or d
    return {
        "w_gate": Leaf((d, f), ("embed", "ffn")),
        "w_up": Leaf((d, f), ("embed", "ffn")),
        "w_down": Leaf((f, o), ("ffn", "embed")),
    }


def apply_ffn(p, x, ctx=NULL_CTX):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = ctx.constrain(h, ("batch", None, "ffn_act"))
    return h @ p["w_down"]


# ======================================================================= MoE
def describe_moe(cfg: ModelConfig):
    d, f, e = cfg.d_model, cfg.moe_d_ff, cfg.num_experts
    p = {
        "router": Leaf((d, e), ("embed", None), scale=0.02),
        "w_gate": Leaf((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_up": Leaf((e, d, f), ("experts", "embed", "expert_ffn")),
        "w_down": Leaf((e, f, d), ("experts", "expert_ffn", "embed")),
    }
    if cfg.dense_residual:  # arctic: dense FFN in parallel with the MoE
        p["dense"] = describe_ffn(cfg)
    return p


def apply_moe(p, x, cfg: ModelConfig, ctx=NULL_CTX):
    """GShard-style capacity dispatch (top-k, grouped tokens).

    Tokens are grouped [B*S/g, g]; per group each expert accepts
    C = g * top_k * capacity_factor / E tokens (overflow dropped).
    Returns (y, aux_loss).
    """
    B, S, d = x.shape
    e, k = cfg.num_experts, cfg.top_k
    g = min(4096, S)
    n_groups = B * S // g
    xg = x.reshape(n_groups, g, d)
    xg = ctx.constrain(xg, ("batch", None, None))

    logits = (xg @ p["router"].astype(F32)).astype(F32)        # [G,g,E]
    gates = jax.nn.softmax(logits, axis=-1)
    top_vals, top_idx = jax.lax.top_k(gates, k)                # [G,g,k]
    top_vals = top_vals / jnp.maximum(top_vals.sum(-1, keepdims=True), 1e-9)

    cap = max(1, int(g * k * cfg.capacity_factor / e))
    onehot = jax.nn.one_hot(top_idx, e, dtype=F32)             # [G,g,k,E]
    flat = onehot.reshape(n_groups, g * k, e)
    pos = jnp.cumsum(flat, axis=1) - flat                      # position in expert
    pos = pos.reshape(n_groups, g, k, e)
    keep = (pos < cap) * onehot
    pos_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=F32) * keep[..., None]
    dispatch = pos_oh.sum(2)                                   # [G,g,E,C]
    combine = (pos_oh * top_vals[..., None, None]).sum(2)      # [G,g,E,C]

    dispatch = ctx.constrain(dispatch, ("batch", None, "experts_act", None))
    xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
    xe = ctx.constrain(xe, ("batch", "experts_act", None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])) * jnp.einsum(
        "gecd,edf->gecf", xe, p["w_up"]
    )
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    y = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
    y = y.reshape(B, S, d)

    # load-balance auxiliary loss (Switch-style)
    density = flat.reshape(n_groups, g, k, e).sum(2).mean(1)   # [G,E] tokens frac
    router_prob = gates.mean(1)                                # [G,E]
    aux = (density * router_prob).sum(-1).mean() * (e / k)

    if "dense" in p:
        y = y + apply_ffn(p["dense"], x, ctx)
    return y, aux


# ================================================================ dense block
def describe_dense_block(cfg: ModelConfig):
    return {
        "ln1": Leaf((cfg.d_model,), ("embed_act",), init="zeros"),
        "attn": describe_attention(cfg),
        "ln2": Leaf((cfg.d_model,), ("embed_act",), init="zeros"),
        "ffn": describe_moe(cfg) if cfg.num_experts else describe_ffn(cfg),
    }


def apply_dense_block(
    p, x, cfg: ModelConfig, *, positions, window=None, cache=None, lengths=None,
    prefix=None, prefix_valid=None, ctx=NULL_CTX, causal=True,
    ring_window: int | None = None,
):
    """One transformer block. Modes:

    * sequence mode (cache is None): returns (x, (k, v), aux). With
      ``prefix=(pk, pv)`` (chunked prefill over a radix-cached prefix),
      attention runs over concat(prefix, current) — positions must already
      be offset by the prefix length. ``prefix_valid`` (traced scalar)
      marks how many prefix positions are real when the prefix is padded
      to a fixed bucket; positions in ``[prefix_valid, Sp)`` are masked.
    * decode mode (cache = (k_cache, v_cache) slot buffers): writes the new
      token at ``lengths - 1`` and returns (x, (k_cache, v_cache), aux)
    """
    h, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    a_in = rmsnorm(x, p["ln1"])
    q, k, v = _project_qkv(
        p["attn"], a_in, h, kh, hd, positions, cfg.rope_theta, ctx=ctx
    )
    if cache is None:
        k_att, v_att, q_off, hole = k, v, 0, None
        if prefix is not None:
            pk, pv = prefix
            k_att = jnp.concatenate([pk.astype(k.dtype), k], axis=1)
            v_att = jnp.concatenate([pv.astype(v.dtype), v], axis=1)
            q_off = pk.shape[1]
            if prefix_valid is not None:
                hole = (prefix_valid, q_off)
        attn = blockwise_attention(
            q, k_att, v_att, causal=causal, window=window,
            cap=cfg.attn_logit_softcap, q_offset=q_off, kv_hole=hole, ctx=ctx,
        )
        B, S, _, _ = attn.shape
        attn = attn.reshape(B, S, h * hd)
        new_kv = (k, v)
    else:
        k_cache, v_cache = cache
        B = x.shape[0]
        if ring_window is not None:
            # window-limited ring cache: slots hold exactly the last
            # `ring_window` tokens; attention is permutation-invariant over
            # KV so the ring order needs no re-sorting (RoPE baked into K).
            idx = (lengths - 1) % ring_window
            attn_lengths = jnp.minimum(lengths, ring_window)
            eff_window = None
        else:
            idx = lengths - 1                                  # write position
            attn_lengths = lengths
            eff_window = window
        k_cache = _write_slot(k_cache, k[:, 0], idx)
        v_cache = _write_slot(v_cache, v[:, 0], idx)
        attn = decode_attention(
            q[:, 0], k_cache, v_cache, lengths=attn_lengths, window=eff_window,
            cap=cfg.attn_logit_softcap, ctx=ctx,
        )[:, None, :]
        new_kv = (k_cache, v_cache)
    x = x + (attn @ p["attn"]["wo"])
    f_in = rmsnorm(x, p["ln2"])
    if cfg.num_experts:
        f_out, aux = apply_moe(p["ffn"], f_in, cfg, ctx)
    else:
        f_out, aux = apply_ffn(p["ffn"], f_in, ctx), 0.0
    x = x + f_out
    x = ctx.constrain(x, ("batch", "seq", "embed_act"))
    return x, new_kv, aux


def _write_slot(cache, new, idx):
    """cache: [B, S, ...]; new: [B, ...]; idx: [B] position per row."""
    B = cache.shape[0]
    return cache.at[jnp.arange(B), idx].set(new.astype(cache.dtype))


def apply_dense_block_paged(
    p, x, cfg: ModelConfig, *, k_pages, v_pages, block_tables, tail_pages,
    tail_offsets, lengths, k_scales=None, v_scales=None, window=None,
    ctx=NULL_CTX,
):
    """Decode mode of :func:`apply_dense_block` over a *paged* KV pool.

    The block-table twin of the dense-slot decode branch: instead of a
    ``[B, S_max, KH, HD]`` slot cache it takes one layer's slice of the
    ``PagePool`` (``k_pages``/``v_pages`` ``[N, T, KH, HD]``) *read-only*
    and attends through ``block_tables`` ``[B, P]`` with the
    paged-attention kernel (GQA + softcap + sliding window). The new
    token's KV (global position ``lengths[b] - 1``, destined for
    ``(tail_pages[b], tail_offsets[b])``) is incorporated by the kernel
    dispatch itself; it is *returned*, not written — the caller commits
    every layer's append to the pool in one batched scatter after the
    layer scan, so scanning this block never copies the pool per layer.
    On an int8-resident pool ``k_scales``/``v_scales`` carry this layer's
    per-page dequant sidecar (``[N]``), threaded to the kernel dispatch.
    Returns ``(x', (k_new, v_new), aux)`` with k_new/v_new ``[B, KH, HD]``.
    """
    h_, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    B = x.shape[0]
    a_in = rmsnorm(x, p["ln1"])
    positions = (lengths - 1)[:, None]
    q, k, v = _project_qkv(
        p["attn"], a_in, h_, kh, hd, positions, cfg.rope_theta, ctx=ctx
    )
    attn = paged_attention_decode(
        q[:, 0], k[:, 0], v[:, 0], k_pages, v_pages, block_tables, lengths,
        tail_pages, tail_offsets, k_scales, v_scales,
        softcap=cfg.attn_logit_softcap, window=window,
    )                                                      # [B, H, D]
    x = x + (attn.reshape(B, 1, h_ * hd) @ p["attn"]["wo"])
    f_in = rmsnorm(x, p["ln2"])
    if cfg.num_experts:
        f_out, aux = apply_moe(p["ffn"], f_in, cfg, ctx)
    else:
        f_out, aux = apply_ffn(p["ffn"], f_in, ctx), 0.0
    x = x + f_out
    x = ctx.constrain(x, ("batch", "seq", "embed_act"))
    return x, (k[:, 0], v[:, 0]), aux


# ============================================================== mamba2 block
def describe_mamba_block(cfg: ModelConfig):
    d = cfg.d_model
    inner = cfg.ssm_inner
    h, n, w = cfg.ssm_heads, cfg.ssm_state, cfg.ssm_conv_width
    g = 1  # single B/C group
    conv_dim = inner + 2 * g * n
    return {
        "ln": Leaf((d,), ("embed_act",), init="zeros"),
        "in_proj": Leaf((d, 2 * inner + 2 * g * n + h), ("embed", "ssm_heads")),
        "conv_w": Leaf((w, conv_dim), ("conv", "ssm_heads"), scale=0.2),
        "conv_b": Leaf((conv_dim,), ("ssm_heads",), init="zeros"),
        "dt_bias": Leaf((h,), ("ssm_heads",), init="zeros"),
        "A_log": Leaf((h,), ("ssm_heads",), scale=0.5),
        "D": Leaf((h,), ("ssm_heads",), init="ones"),
        "norm": Leaf((inner,), ("ssm_heads",), init="zeros"),
        "out_proj": Leaf((inner, d), ("ssm_heads", "embed")),
    }


def _mamba_split(cfg: ModelConfig, zxbcdt):
    inner = cfg.ssm_inner
    n = cfg.ssm_state
    z = zxbcdt[..., :inner]
    xBC = zxbcdt[..., inner : 2 * inner + 2 * n]
    dt = zxbcdt[..., 2 * inner + 2 * n :]
    return z, xBC, dt


def apply_mamba_block(p, x, cfg: ModelConfig, *, cache=None, ctx=NULL_CTX):
    """Mamba-2 block. sequence mode: cache=None -> (y, (ssm_state, conv_state)).
    decode mode: cache=(ssm_state [B,H,P,N], conv_state [B,W-1,conv_dim])."""
    inner, n, hh, w = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_conv_width
    hp = cfg.ssm_head_dim
    res = x
    xn = rmsnorm(x, p["ln"])
    zxbcdt = xn @ p["in_proj"]
    z, xBC, dt = _mamba_split(cfg, zxbcdt)
    A = -jnp.exp(p["A_log"].astype(F32))

    if cache is None:
        B_, S, _ = x.shape
        # causal depthwise conv over [B,S,conv_dim]
        pad = jnp.pad(xBC, ((0, 0), (w - 1, 0), (0, 0)))
        conv_state = pad[:, -(w - 1) :, :] if w > 1 else None
        xBC = _causal_conv(pad, p["conv_w"], p["conv_b"], S)
        xs, Bmat, Cmat = (
            xBC[..., :inner],
            xBC[..., inner : inner + n],
            xBC[..., inner + n :],
        )
        xs = ctx.constrain(
            xs.reshape(B_, S, hh, hp), ("batch", None, "ssm_heads_act", None)
        )
        dt = jax.nn.softplus(dt.astype(F32) + p["dt_bias"].astype(F32))
        y, final_state = ssd_reference(
            xs, dt, A, Bmat[:, :, None, :], Cmat[:, :, None, :], chunk=cfg.ssm_chunk
        )
        y = y + xs * p["D"].astype(x.dtype)[None, None, :, None]
        y = y.reshape(B_, S, inner)
        new_cache = (final_state.astype(F32), conv_state)
    else:
        ssm_state, conv_state = cache
        B_ = x.shape[0]
        xBC1 = xBC[:, 0]                                       # [B, conv_dim]
        window = jnp.concatenate([conv_state, xBC1[:, None, :]], axis=1)  # [B,W,c]
        xBC1 = jnp.einsum("bwc,wc->bc", window, p["conv_w"]) + p["conv_b"]
        xBC1 = jax.nn.silu(xBC1)
        new_conv = window[:, 1:, :]
        xs = xBC1[..., :inner].reshape(B_, hh, hp)
        Bmat = xBC1[..., inner : inner + n][:, None, :]
        Cmat = xBC1[..., inner + n :][:, None, :]
        dt1 = jax.nn.softplus(dt[:, 0].astype(F32) + p["dt_bias"].astype(F32))
        y, new_state = ssd_decode_step(ssm_state, xs, dt1, A, Bmat, Cmat)
        y = y + xs * p["D"].astype(x.dtype)[None, :, None]
        y = y.reshape(B_, 1, inner)
        new_cache = (new_state, new_conv)

    y = y * jax.nn.silu(z)
    y = rmsnorm(y, p["norm"])
    out = res + y @ p["out_proj"]
    out = ctx.constrain(out, ("batch", "seq", "embed_act"))
    return out, new_cache


def _causal_conv(padded, w, b, out_len):
    """padded: [B, S+W-1, C]; depthwise causal conv, silu."""
    W = w.shape[0]
    out = sum(padded[:, i : i + out_len, :] * w[i][None, None, :] for i in range(W))
    return jax.nn.silu(out + b)


# =================================================== zamba2 shared attention
def describe_shared_block(cfg: ModelConfig):
    """Zamba2: ONE transformer block shared across the depth, operating on
    concat(hidden, initial embedding) = 2*d_model, projected back to d_model."""
    d2 = 2 * cfg.d_model
    hd = cfg.hybrid_head_dim
    return {
        "ln1": Leaf((d2,), ("embed_act",), init="zeros"),
        "attn": describe_attention(cfg, d_in=d2, heads=cfg.num_heads,
                                   kv_heads=cfg.num_kv_heads, head_dim=hd, bias=False),
        "ln2": Leaf((d2,), ("embed_act",), init="zeros"),
        "ffn": describe_ffn(cfg, d_in=d2, d_ff=cfg.d_ff, d_out=d2),
        "down": Leaf((d2, cfg.d_model), ("embed", None)),
    }


def apply_shared_block(p, h, x0, cfg: ModelConfig, *, positions, cache=None,
                       lengths=None, ctx=NULL_CTX):
    """h: hidden [B,S,d]; x0: initial embedding [B,S,d]. Returns (h', kv)."""
    heads, kh, hd = cfg.num_heads, cfg.num_kv_heads, cfg.hybrid_head_dim
    xin = jnp.concatenate([h, x0], axis=-1)                    # [B,S,2d]
    a_in = rmsnorm(xin, p["ln1"])
    q, k, v = _project_qkv(p["attn"], a_in, heads, kh, hd, positions,
                           cfg.rope_theta, ctx=ctx)
    if cache is None:
        attn = blockwise_attention(q, k, v, causal=True, ctx=ctx)
        B, S = attn.shape[:2]
        attn = attn.reshape(B, S, heads * hd)
        new_kv = (k, v)
    else:
        k_cache, v_cache = cache
        idx = lengths - 1
        k_cache = _write_slot(k_cache, k[:, 0], idx)
        v_cache = _write_slot(v_cache, v[:, 0], idx)
        attn = decode_attention(q[:, 0], k_cache, v_cache, lengths=lengths, ctx=ctx)[
            :, None, :
        ]
        new_kv = (k_cache, v_cache)
    xin = xin + attn @ p["attn"]["wo"]
    xin = xin + apply_ffn(p["ffn"], rmsnorm(xin, p["ln2"]), ctx)
    return h + xin @ p["down"], new_kv
