"""Affinity-aware multi-replica placement (paper §4.1, §6.2.2).

Affinity itself is structural in MORI: CPU-queue promotions go back to the
replica whose DRAM holds the cache (enforced in the scheduler), so the
balancer only places programs with *no* resident state — Waiting-queue
returns and new arrivals — using the paper's most-available-capacity
(Best-Fit-Decreasing style) rule.

Every :meth:`ReplicaBalancer.place` call returns a typed
:class:`PlacementDecision` that carries the *reason* the replica won (or
why no replica could take the program); the router surfaces the reason
counts in ``RouterMetrics.placement_reasons`` so a replay explains its own
load distribution.

Beyond-paper (off by default): straggler mitigation. Replicas report an EWMA
of step latency; with ``straggler_penalty > 0`` the effective free capacity
of slow replicas is discounted, biasing new placements away from them.
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass

from repro.core.program import ProgramState
from repro.core.tiers import ReplicaTiers
from repro.core.types import SchedulerConfig

#: Why a placement decision came out the way it did.
#: ``most-available``      the replica had strictly the most effective free HBM
#: ``tie-break``           top replicas tied on effective free; highest id wins
#: ``straggler-discount``  the straggler EWMA discount changed the winner
#: ``drain-target``        chosen to receive a draining replica's DRAM copy
#: ``no-capacity``         a healthy replica exists but none fits the program
#: ``no-healthy-replica``  every replica is marked failed
PLACEMENT_REASONS = (
    "most-available",
    "tie-break",
    "straggler-discount",
    "drain-target",
    "no-capacity",
    "no-healthy-replica",
)


@dataclass(frozen=True)
class PlacementDecision:
    """Typed result of :meth:`ReplicaBalancer.place`.

    ``replica`` is None when no healthy replica can take the program;
    ``reason`` always explains the outcome (one of
    :data:`PLACEMENT_REASONS`). Truthiness follows placement success, so
    ``if decision:`` reads like the old ``if target is not None:``.
    """

    replica: int | None
    reason: str

    def __bool__(self) -> bool:
        return self.replica is not None


class ReplicaBalancer:
    def __init__(self, replicas: list[ReplicaTiers], config: SchedulerConfig):
        self.replicas = replicas
        self.config = config
        self._healthy: set[int] = {r.replica_id for r in replicas}
        self.reason_counts: Counter[str] = Counter()

    # ------------------------------------------------------------- health
    def mark_failed(self, replica_id: int) -> None:
        self._healthy.discard(replica_id)

    def mark_recovered(self, replica_id: int) -> None:
        self._healthy.add(replica_id)

    def healthy(self) -> list[ReplicaTiers]:
        return [r for r in self.replicas if r.replica_id in self._healthy]

    # ---------------------------------------------------------- placement
    def place(self, prog: ProgramState, now: float) -> PlacementDecision:
        """Pick a replica for a program with no resident KV state.

        Paper: 'Waiting-queue promotions use Best-Fit-Decreasing bin packing
        across replicas, selecting the replica with the most available
        capacity first.'
        """
        candidates = self.healthy()
        if not candidates:
            return self._decide(None, "no-healthy-replica")
        scored = sorted(
            ((self._effective_free(r), r.replica_id) for r in candidates),
            reverse=True,
        )
        best_free, best_id = scored[0]
        if best_free < prog.kv_bytes:
            return self._decide(None, "no-capacity")
        reason = "most-available"
        if len(scored) > 1 and scored[1][0] == best_free:
            reason = "tie-break"
        elif self.config.straggler_penalty > 0.0:
            raw = max(candidates, key=lambda r: (float(r.gpu_free()), r.replica_id))
            if raw.replica_id != best_id:
                reason = "straggler-discount"
        return self._decide(best_id, reason)

    def place_drain(self, prog: ProgramState, now: float) -> PlacementDecision:
        """Pick a replica to *receive* a draining replica's DRAM-resident KV.

        A drain target needs host DRAM headroom (the migrate lands in the
        destination's CPU queue), so the score is cpu_free, not gpu_free —
        the subsequent promotion competes for HBM through the normal passes.
        """
        candidates = self.healthy()
        if not candidates:
            return self._decide(None, "no-healthy-replica")
        best = max(candidates, key=lambda r: (r.cpu_free(), r.replica_id))
        if best.cpu_free() < prog.host_kv_bytes:
            return self._decide(None, "no-capacity")
        return self._decide(best.replica_id, "drain-target")

    def _decide(self, replica: int | None, reason: str) -> PlacementDecision:
        self.reason_counts[reason] += 1
        return PlacementDecision(replica, reason)

    def _effective_free(self, rep: ReplicaTiers) -> float:
        free = float(rep.gpu_free())
        penalty = self.config.straggler_penalty
        if penalty > 0.0:
            lat = [r.ewma_step_latency_s for r in self.healthy()]
            med = sorted(lat)[len(lat) // 2] if lat else 0.0
            if med > 0 and rep.ewma_step_latency_s > med:
                slowdown = rep.ewma_step_latency_s / med - 1.0
                free *= max(0.0, 1.0 - penalty * slowdown)
        return free
