"""Affinity-aware multi-replica placement (paper §4.1, §6.2.2).

Affinity itself is structural in MORI: CPU-queue promotions go back to the
replica whose DRAM holds the cache (enforced in the scheduler), so the
balancer only places programs with *no* resident state — Waiting-queue
returns and new arrivals — using the paper's most-available-capacity
(Best-Fit-Decreasing style) rule.

Beyond-paper (off by default): straggler mitigation. Replicas report an EWMA
of step latency; with ``straggler_penalty > 0`` the effective free capacity
of slow replicas is discounted, biasing new placements away from them.
"""
from __future__ import annotations

from repro.core.program import ProgramState
from repro.core.tiers import ReplicaTiers
from repro.core.types import SchedulerConfig


class ReplicaBalancer:
    def __init__(self, replicas: list[ReplicaTiers], config: SchedulerConfig):
        self.replicas = replicas
        self.config = config
        self._healthy: set[int] = {r.replica_id for r in replicas}

    # ------------------------------------------------------------- health
    def mark_failed(self, replica_id: int) -> None:
        self._healthy.discard(replica_id)

    def mark_recovered(self, replica_id: int) -> None:
        self._healthy.add(replica_id)

    def healthy(self) -> list[ReplicaTiers]:
        return [r for r in self.replicas if r.replica_id in self._healthy]

    # ---------------------------------------------------------- placement
    def place(self, prog: ProgramState, now: float) -> int | None:
        """Pick a replica for a program with no resident KV state.

        Paper: 'Waiting-queue promotions use Best-Fit-Decreasing bin packing
        across replicas, selecting the replica with the most available
        capacity first.'
        """
        candidates = self.healthy()
        if not candidates:
            return None
        scored = [(self._effective_free(r), r.replica_id) for r in candidates]
        scored.sort(reverse=True)
        best_free, best_id = scored[0]
        if best_free < prog.kv_bytes:
            return None
        return best_id

    def _effective_free(self, rep: ReplicaTiers) -> float:
        free = float(rep.gpu_free())
        penalty = self.config.straggler_penalty
        if penalty > 0.0:
            lat = [r.ewma_step_latency_s for r in self.healthy()]
            med = sorted(lat)[len(lat) // 2] if lat else 0.0
            if med > 0 and rep.ewma_step_latency_s > med:
                slowdown = rep.ewma_step_latency_s / med - 1.0
                free *= max(0.0, 1.0 - penalty * slowdown)
        return free
