"""MORI control plane: idleness metric, three-tier placement, typed eviction.

This package is the paper's primary contribution (§4), implemented once and
shared by the discrete-event simulator and the real JAX serving engine. The
scheduler ↔ runtime contract is the typed action IR in ``repro.core.actions``:
events in, :class:`PlacementPlan` out, transfers acknowledged through the
:class:`TransferLedger`.
"""
from repro.core.actions import (
    Action,
    CancelTransfer,
    Discard,
    Forward,
    Migrate,
    Offload,
    PlacementPlan,
    SetLabel,
    action_from_json,
    action_to_json,
    plan_from_json,
)
from repro.core.baselines import SMGScheduler, TAOScheduler, TAScheduler
from repro.core.idleness import IdlenessTracker
from repro.core.ledger import Channel, TransferLedger, TransferRecord, channel_for
from repro.core.program import ProgramState
from repro.core.radix_tree import TypedRadixTree
from repro.core.scheduler import AgentScheduler, MoriScheduler
from repro.core.tiers import ReplicaTiers, WaitingQueue
from repro.core.transfers import CopyJob, TransferChannels
from repro.core.types import (
    ProgramTrace,
    RequestRecord,
    SchedulerConfig,
    Status,
    Tier,
    TierCapacity,
    TypeLabel,
)

SCHEDULERS = {
    "mori": MoriScheduler,
    "ta": TAScheduler,
    "ta+o": TAOScheduler,
    "smg": SMGScheduler,
}

__all__ = [
    "Action",
    "AgentScheduler",
    "CancelTransfer",
    "Channel",
    "CopyJob",
    "Discard",
    "Forward",
    "IdlenessTracker",
    "Migrate",
    "MoriScheduler",
    "Offload",
    "PlacementPlan",
    "ProgramState",
    "ProgramTrace",
    "ReplicaTiers",
    "RequestRecord",
    "SCHEDULERS",
    "SMGScheduler",
    "SchedulerConfig",
    "SetLabel",
    "Status",
    "TAOScheduler",
    "TAScheduler",
    "Tier",
    "TierCapacity",
    "TransferChannels",
    "TransferLedger",
    "TransferRecord",
    "TypeLabel",
    "TypedRadixTree",
    "WaitingQueue",
    "action_from_json",
    "action_to_json",
    "channel_for",
    "plan_from_json",
]
