"""MORI control plane: idleness metric, three-tier placement, typed eviction.

This package is the paper's primary contribution (§4), implemented once and
shared by the discrete-event simulator and the real JAX serving engine.
"""
from repro.core.baselines import SMGScheduler, TAOScheduler, TAScheduler
from repro.core.idleness import IdlenessTracker
from repro.core.program import ProgramState
from repro.core.radix_tree import TypedRadixTree
from repro.core.scheduler import AgentScheduler, EngineAdapter, MoriScheduler
from repro.core.tiers import ReplicaTiers, WaitingQueue
from repro.core.types import (
    ProgramTrace,
    RequestRecord,
    SchedulerConfig,
    Status,
    Tier,
    TierCapacity,
    TypeLabel,
)

SCHEDULERS = {
    "mori": MoriScheduler,
    "ta": TAScheduler,
    "ta+o": TAOScheduler,
    "smg": SMGScheduler,
}

__all__ = [
    "AgentScheduler",
    "EngineAdapter",
    "IdlenessTracker",
    "MoriScheduler",
    "ProgramState",
    "ProgramTrace",
    "ReplicaTiers",
    "RequestRecord",
    "SCHEDULERS",
    "SMGScheduler",
    "SchedulerConfig",
    "Status",
    "TAOScheduler",
    "TAScheduler",
    "Tier",
    "TierCapacity",
    "TypeLabel",
    "TypedRadixTree",
    "WaitingQueue",
]
