"""MORI scheduling policy (paper §4.3): sticky rebalancing over three tiers.

The scheduler is runtime-agnostic: it consumes program lifecycle events and
emits placement actions through an :class:`EngineAdapter`. The discrete-event
simulator (``repro.sim``) and the real JAX serving engine (``repro.serving``)
both drive *this exact code* — the policy is implemented once.

Event flow (runtime -> scheduler):
    program_arrived -> request_arrived -> notify_inference_started
      -> request_completed -> [tool call] -> request_arrived -> ...
      -> program_finished
    tick(now) runs the periodic control loop (default every 5 s).

Action flow (scheduler -> runtime, via EngineAdapter):
    forward(pid, replica, reload, recompute): release a gated request; the
        runtime must first reload KV from host (reload=True) or re-prefill
        the whole context (recompute=True) before decoding.
    offload(pid, replica):   move the program's KV GPU -> CPU DRAM.
    discard(pid, replica, tier): drop the KV from the given tier.
    set_label(pid, replica, label): typed-offloading hint (paper §4.3.2).
"""
from __future__ import annotations

import abc
from typing import Protocol

from repro.core.balancer import ReplicaBalancer
from repro.core.program import ProgramState
from repro.core.tiers import ReplicaTiers, WaitingQueue
from repro.core.types import (
    SchedulerConfig,
    Status,
    Tier,
    TierCapacity,
    TypeLabel,
)


class EngineAdapter(Protocol):
    """What the scheduler can ask a runtime to do."""

    def forward(self, pid: str, replica: int, reload: bool, recompute: bool) -> None: ...
    def offload(self, pid: str, replica: int) -> None: ...
    def discard(self, pid: str, replica: int | None, tier: Tier) -> None: ...
    def set_label(self, pid: str, replica: int | None, label: TypeLabel) -> None: ...


class AgentScheduler(abc.ABC):
    """Shared event API for MORI and all baselines (SMG / TA / TA+O)."""

    name: str = "base"

    def __init__(
        self,
        num_replicas: int,
        capacity: TierCapacity,
        adapter: EngineAdapter,
        config: SchedulerConfig | None = None,
    ):
        self.config = config or SchedulerConfig()
        self.adapter = adapter
        self.replicas = [
            ReplicaTiers(replica_id=i, capacity=capacity) for i in range(num_replicas)
        ]
        self.waiting = WaitingQueue()
        self.programs: dict[str, ProgramState] = {}
        self.balancer = ReplicaBalancer(self.replicas, self.config)
        self._running: dict[int, set[str]] = {i: set() for i in range(num_replicas)}

    # -------------------------------------------------------------- events
    def program_arrived(self, pid: str, kv_bytes_per_token: int, now: float) -> ProgramState:
        prog = ProgramState(pid, kv_bytes_per_token, arrived_at=now)
        prog.set_window(self.config.idleness_window)
        self.programs[pid] = prog
        self.waiting.add(prog)
        return prog

    @abc.abstractmethod
    def request_arrived(self, pid: str, input_tokens: int, now: float) -> None: ...

    def notify_inference_started(self, pid: str, now: float) -> None:
        prog = self.programs[pid]
        prog.begin_reasoning(now)
        if prog.replica is not None:
            self._running[prog.replica].add(pid)

    @abc.abstractmethod
    def request_completed(self, pid: str, output_tokens: int, now: float) -> None: ...

    def program_finished(self, pid: str, now: float) -> None:
        prog = self.programs.pop(pid, None)
        if prog is None:
            return
        prog.finished = True
        if prog.replica is not None:
            self._running[prog.replica].discard(pid)
        self._release(prog)

    @abc.abstractmethod
    def tick(self, now: float) -> None: ...

    # ------------------------------------------------------- fault handling
    def replica_failed(self, replica_id: int, now: float) -> list[str]:
        """Node failure: all KV on the replica is lost. Its programs drop to
        the Waiting queue and will be re-admitted elsewhere via the normal
        recompute path — exactly MORI's Waiting-tier semantics, which is what
        makes the design restart-tolerant. Returns the affected program ids.
        """
        rep = self.replicas[replica_id]
        affected: list[str] = []
        for prog in list(rep.gpu.values()):
            rep.gpu_remove(prog)
            self.adapter.discard(prog.program_id, replica_id, Tier.GPU)
            self.waiting.add(prog)
            prog.metrics.evictions += 1
            prog.dispatched = False  # any in-flight forward died with the node
            prog.lazy_demote = False
            affected.append(prog.program_id)
        for prog in list(rep.cpu.values()):
            rep.cpu_remove(prog)
            self.adapter.discard(prog.program_id, replica_id, Tier.CPU)
            self.waiting.add(prog)
            prog.metrics.evictions += 1
            prog.dispatched = False
            affected.append(prog.program_id)
        for prog in list(rep.ssd.values()):
            rep.ssd_remove(prog)
            self.adapter.discard(prog.program_id, replica_id, Tier.SSD)
            self.waiting.add(prog)
            prog.metrics.evictions += 1
            prog.dispatched = False
            affected.append(prog.program_id)
        for pid in list(self._running[replica_id]):
            self._running[replica_id].discard(pid)
            prog = self.programs.get(pid)
            if prog is not None and not prog.finished:
                prog.gate(now)  # in-flight request will be re-issued
        self.balancer.mark_failed(replica_id)
        return affected

    def replica_recovered(self, replica_id: int) -> None:
        self.balancer.mark_recovered(replica_id)

    # ------------------------------------------------------------- queries
    def replica_of(self, pid: str) -> int | None:
        prog = self.programs.get(pid)
        return prog.replica if prog else None

    def running_count(self, replica: int) -> int:
        return len(self._running[replica])

    # ------------------------------------------------------------ plumbing
    def _release(self, prog: ProgramState) -> None:
        """Drop a program's KV from wherever it lives."""
        for rep in self.replicas:
            if prog.program_id in rep.gpu:
                rep.gpu_remove(prog)
                self.adapter.discard(prog.program_id, rep.replica_id, Tier.GPU)
            if prog.program_id in rep.cpu:
                rep.cpu_remove(prog)
                self.adapter.discard(prog.program_id, rep.replica_id, Tier.CPU)
            if prog.program_id in rep.ssd:
                rep.ssd_remove(prog)
                self.adapter.discard(prog.program_id, rep.replica_id, Tier.SSD)
        self.waiting.remove(prog)
        prog.tier = Tier.NONE
        prog.replica = None

    def _account_growth(self, prog: ProgramState, new_tokens: int) -> None:
        if new_tokens <= 0:
            return
        if prog.replica is not None:
            self.replicas[prog.replica].grow(prog, new_tokens)
        prog.context_tokens += new_tokens

    def _set_label(self, prog: ProgramState, label: TypeLabel) -> None:
        if prog.label is not label:
            prog.label = label
            self.adapter.set_label(prog.program_id, prog.replica, label)

    def _mark_not_running(self, prog: ProgramState) -> None:
        if prog.replica is not None:
            self._running[prog.replica].discard(prog.program_id)


class MoriScheduler(AgentScheduler):
    """The paper's scheduler: windowed idleness + sticky three-tier placement."""

    name = "mori"

    # ------------------------------------------------------------- events
    def request_arrived(self, pid: str, input_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        new_tokens = max(0, input_tokens - prog.context_tokens)
        self._account_growth(prog, new_tokens)
        prog.gate(now)
        if prog.tier is Tier.GPU and self._has_slot(prog.replica):
            self._dispatch(prog, reload=False, recompute=False)
        elif self.config.eager_promote:
            self._promote_pass(now)

    def request_completed(self, pid: str, output_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        self._mark_not_running(prog)
        self._account_growth(prog, 0)  # growth applied below via begin_acting
        if prog.replica is not None:
            self.replicas[prog.replica].grow(prog, output_tokens)
        prog.begin_acting(now, new_tokens=output_tokens)
        if prog.lazy_demote and prog.tier is Tier.GPU:
            prog.lazy_demote = False
            self._demote(prog, now)
        if self.config.eager_promote:
            self._promote_pass(now)

    def tick(self, now: float) -> None:
        for rep in self.replicas:
            self._demote_pass(rep, now)
            self._cpu_overflow_pass(rep, now)
            self._ssd_overflow_pass(rep, now)
        self._promote_pass(now)
        self._sync_labels()

    # ---------------------------------------------------------- demotions
    def _demote_pass(self, rep: ReplicaTiers, now: float) -> None:
        """Shrink the GPU queue until it fits (paper §4.3.1 'Demotion')."""
        overflow = rep.gpu_overflow()
        if overflow <= 0:
            return
        # Acting (and gated) programs first, then Reasoning; within a status
        # class, highest idleness first.
        order = {Status.ACTING: 0, Status.GATED: 1, Status.REASONING: 2}
        victims = sorted(
            rep.gpu.values(),
            key=lambda p: (order[p.status], -p.idleness(now)),
        )
        pending_free = 0
        for victim in victims:
            if rep.gpu_used - pending_free <= rep.capacity.gpu_kv_bytes:
                break
            if victim.status is Status.REASONING:
                # lazy demotion: finish the in-flight step first
                if not victim.lazy_demote:
                    victim.lazy_demote = True
                    pending_free += victim.kv_bytes
            else:
                self._demote(victim, now)

    def _demote(self, prog: ProgramState, now: float) -> None:
        """GPU -> CPU if DRAM permits, else SSD (§7.1 extension, when
        enabled), else GPU -> Waiting."""
        rep = self.replicas[prog.replica]
        rep.gpu_remove(prog)
        prog.metrics.demotions += 1
        if rep.cpu_free() >= prog.kv_bytes:
            rep.cpu_admit(prog)
            self.adapter.offload(prog.program_id, rep.replica_id)
            self._set_label(prog, TypeLabel.IDLE)
        elif rep.ssd_free() >= prog.kv_bytes and self._ssd_worthwhile(prog):
            rep.ssd_admit(prog)
            self.adapter.offload(prog.program_id, rep.replica_id)
            self._set_label(prog, TypeLabel.IDLE)
        else:
            self.adapter.discard(prog.program_id, rep.replica_id, Tier.GPU)
            self.waiting.add(prog)
            prog.metrics.evictions += 1
            self._set_label(prog, TypeLabel.INACTIVE)

    def _cpu_overflow_pass(self, rep: ReplicaTiers, now: float) -> None:
        """CPU-side admission control (paper §3.4).

        With the SSD tier enabled (§7.1 extension), the *most idle* CPU
        programs sink to NVMe first — they tolerate the slower reload and
        continue the idleness spectrum downward. Whatever still overflows
        is evicted to Waiting, busiest first, mirroring the typed block
        order (the CPU tier preferentially *retains idle* programs).
        """
        if rep.cpu_overflow() <= 0:
            return
        if rep.capacity.ssd_kv_bytes:
            sinkable = sorted(rep.cpu.values(), key=lambda p: -p.idleness(now))
            for victim in sinkable:
                if rep.cpu_overflow() <= 0:
                    return
                if rep.ssd_free() < victim.kv_bytes:
                    break
                if not self._ssd_worthwhile(victim):
                    continue
                rep.cpu_remove(victim)
                rep.ssd_admit(victim)
                self.adapter.offload(victim.program_id, rep.replica_id)
                self._set_label(victim, TypeLabel.IDLE)
        victims = sorted(rep.cpu.values(), key=lambda p: p.idleness(now))
        for victim in victims:
            if rep.cpu_overflow() <= 0:
                break
            rep.cpu_remove(victim)
            self.adapter.discard(victim.program_id, rep.replica_id, Tier.CPU)
            self.waiting.add(victim)
            victim.metrics.evictions += 1
            self._set_label(victim, TypeLabel.INACTIVE)

    def _ssd_worthwhile(self, prog: ProgramState) -> bool:
        """Cost-aware SSD guard (beyond §7.1's threshold proposal): keep
        the bytes only if an NVMe reload would beat recomputing them.
        Without configured rates, always sink (the paper-naive policy)."""
        cfg = self.config
        if not cfg.ssd_bytes_per_s or not cfg.recompute_tok_per_s:
            return True
        reload_s = prog.kv_bytes / cfg.ssd_bytes_per_s
        recompute_s = prog.context_tokens / cfg.recompute_tok_per_s
        return reload_s < cfg.ssd_guard_factor * recompute_s

    def _ssd_overflow_pass(self, rep: ReplicaTiers, now: float) -> None:
        """SSD-side admission control (§7.1 extension): evict to Waiting,
        busiest first (they will be recomputed soon regardless; the most
        idle keep their bytes where idleness is cheapest)."""
        if rep.ssd_overflow() <= 0:
            return
        victims = sorted(rep.ssd.values(), key=lambda p: p.idleness(now))
        for victim in victims:
            if rep.ssd_overflow() <= 0:
                break
            rep.ssd_remove(victim)
            self.adapter.discard(victim.program_id, rep.replica_id, Tier.SSD)
            self.waiting.add(victim)
            victim.metrics.evictions += 1
            self._set_label(victim, TypeLabel.INACTIVE)

    # ---------------------------------------------------------- promotions
    def _promote_pass(self, now: float) -> None:
        """Fill free GPU capacity in priority order (paper §4.3.1).

        (1) CPU-queue programs whose tool call has completed (gated), with
            replica affinity; (2) Waiting-queue gated programs, returning
            before new, via most-available-capacity placement; (3) new
            arrivals, smallest context first. Lowest idleness first within
            (1) and (2).
        """
        # --- P1: CPU -> GPU, affinity-preserving
        p1 = [
            p
            for rep in self.replicas
            for p in rep.cpu.values()
            if p.has_pending and not p.dispatched
        ]
        p1.sort(key=lambda p: p.idleness(now))
        for prog in p1:
            self._try_promote_cpu(prog, now)

        # --- P1b: SSD -> GPU (§7.1 extension), affinity-preserving; reload
        #     is NVMe-speed (the runtime reads prog.tier before forward)
        p1b = [
            p
            for rep in self.replicas
            for p in rep.ssd.values()
            if p.has_pending and not p.dispatched
        ]
        p1b.sort(key=lambda p: p.idleness(now))
        for prog in p1b:
            self._try_promote_ssd(prog, now)

        # --- P2: Waiting (returning) -> some replica
        p2 = [
            p
            for p in self.waiting.programs.values()
            if p.has_pending and not p.is_new and not p.dispatched
        ]
        p2.sort(key=lambda p: p.idleness(now))
        for prog in p2:
            self._try_admit_waiting(prog, now)

        # --- P3: new arrivals, smallest context first
        p3 = [
            p
            for p in self.waiting.programs.values()
            if p.has_pending and p.is_new and not p.dispatched
        ]
        p3.sort(key=lambda p: p.context_tokens)
        for prog in p3:
            self._try_admit_waiting(prog, now)

        # forward GPU-resident gated programs when slots free (busy first)
        for rep in self.replicas:
            gated = [
                p
                for p in rep.gpu.values()
                if p.status is Status.GATED and p.has_pending and not p.dispatched
            ]
            gated.sort(key=lambda p: p.idleness(now))
            for prog in gated:
                if not self._has_slot(rep.replica_id):
                    break
                self._dispatch(prog, reload=False, recompute=False)

    def _try_promote_cpu(self, prog: ProgramState, now: float) -> bool:
        rep = self.replicas[prog.replica]
        if not self._make_room(rep, prog, now):
            return False
        rep.cpu_remove(prog)
        rep.gpu_admit(prog)
        prog.metrics.promotions += 1
        self._set_label(prog, TypeLabel.BUSY)
        if self._has_slot(rep.replica_id):
            self._dispatch(prog, reload=True, recompute=False)
        return True

    def _try_promote_ssd(self, prog: ProgramState, now: float) -> bool:
        rep = self.replicas[prog.replica]
        if not self._make_room(rep, prog, now):
            return False
        rep.ssd_remove(prog)
        prog.reload_src = Tier.SSD
        rep.gpu_admit(prog)
        prog.metrics.promotions += 1
        self._set_label(prog, TypeLabel.BUSY)
        if self._has_slot(rep.replica_id):
            self._dispatch(prog, reload=True, recompute=False)
        return True

    def _try_admit_waiting(self, prog: ProgramState, now: float) -> bool:
        target = self.balancer.place(prog, now)
        if target is None:
            return False
        rep = self.replicas[target]
        if not self._make_room(rep, prog, now, allow_swap=not prog.is_new):
            return False
        self.waiting.remove(prog)
        if prog.home_replica is not None and prog.home_replica != target:
            prog.metrics.replica_switches += 1
        rep.gpu_admit(prog)
        prog.metrics.promotions += 1
        prog.metrics.recomputed_tokens += prog.context_tokens
        self._set_label(prog, TypeLabel.BUSY)
        if self._has_slot(rep.replica_id):
            self._dispatch(prog, reload=False, recompute=True)
        return True

    def _make_room(
        self,
        rep: ReplicaTiers,
        prog: ProgramState,
        now: float,
        allow_swap: bool = True,
    ) -> bool:
        """Ensure ``prog.kv_bytes`` fit on ``rep``'s GPU tier.

        Sticky placement: only displaces *Acting* GPU programs that are more
        idle than the candidate by at least the hysteresis margin — the
        'actual mismatch' rule of paper §4.3.
        """
        need = prog.kv_bytes - rep.gpu_free()
        if need <= 0:
            return True
        if not allow_swap:
            return False
        margin = self.config.swap_hysteresis
        cand_iota = prog.idleness(now)
        displaceable = sorted(
            (
                p
                for p in rep.gpu.values()
                if p.status is Status.ACTING
                and not p.lazy_demote
                and p.idleness(now) > cand_iota + margin
            ),
            key=lambda p: -p.idleness(now),
        )
        freed = 0
        chosen: list[ProgramState] = []
        for victim in displaceable:
            if freed >= need:
                break
            chosen.append(victim)
            freed += victim.kv_bytes
        if freed < need:
            return False
        for victim in chosen:
            self._demote(victim, now)
        return True

    # ------------------------------------------------------------ dispatch
    def _has_slot(self, replica: int | None) -> bool:
        if replica is None:
            return False
        cap = self.config.max_running
        return cap is None or len(self._running[replica]) < cap

    def _dispatch(self, prog: ProgramState, reload: bool, recompute: bool) -> None:
        if reload:
            prog.metrics.reloaded_bytes += prog.kv_bytes
        prog.dispatched = True
        self.adapter.forward(prog.program_id, prog.replica, reload, recompute)

    def _sync_labels(self) -> None:
        for rep in self.replicas:
            for p in rep.gpu.values():
                self._set_label(p, TypeLabel.BUSY)
            for p in rep.cpu.values():
                self._set_label(p, TypeLabel.IDLE)
            for p in rep.ssd.values():
                self._set_label(p, TypeLabel.IDLE)
        for p in self.waiting.programs.values():
            self._set_label(p, TypeLabel.INACTIVE)
