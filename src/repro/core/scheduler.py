"""MORI scheduling policy (paper §4.3): sticky rebalancing over three tiers.

The scheduler is runtime-agnostic and *declarative*: it consumes program
lifecycle events and every event returns a :class:`PlacementPlan` — an
ordered, immutable batch of typed actions (``Forward`` / ``Offload`` /
``Discard`` / ``Migrate`` / ``SetLabel`` / ``CancelTransfer``) that the
runtime executes through its own ``apply_plan`` executor. The
discrete-event simulator (``repro.sim``) and the real JAX serving engine
(``repro.serving``) both drive *this exact code* — the policy is
implemented once, and because plans are data, tests can assert exact
action sequences from both runtimes on the same trace.

Event flow (runtime -> scheduler; every call returns a PlacementPlan
unless noted):
    program_arrived -> request_arrived -> notify_inference_started
      -> request_completed -> [tool call] -> request_arrived -> ...
      -> program_finished
    tick(now) runs the periodic control loop (default every 5 s).
    replica_failed / replica_recovered track fleet membership.
    on_transfer_complete(pid, action_id, now) acknowledges a transfer the
      runtime finished executing; the scheduler closes the matching
      :class:`TransferLedger` record.

Transfers are asynchronous: when the scheduler emits an ``Offload``, a
reloading ``Forward``, or a ``Migrate``, it opens a ledger record for the
bytes on the PCIe or NVMe channel and the runtime acknowledges completion
later. Until that acknowledgement the scheduler *knows* the source copy is
still intact — which is how an offload gets cancelled (``CancelTransfer``)
when a tool call returns early, re-admitting the program warm instead of
paying a host round trip.
"""
from __future__ import annotations

import abc

from repro.core.actions import (
    Action,
    CancelTransfer,
    Discard,
    Forward,
    Migrate,
    Offload,
    PlacementPlan,
    SetLabel,
    _coalesce,
)
from repro.core.balancer import ReplicaBalancer
from repro.core.ledger import TransferLedger, TransferRecord, channel_for
from repro.core.program import ProgramState
from repro.core.tiers import ReplicaTiers, WaitingQueue
from repro.core.types import (
    SchedulerConfig,
    Status,
    Tier,
    TierCapacity,
    TypeLabel,
)


class AgentScheduler(abc.ABC):
    """Shared event API for MORI and all baselines (SMG / TA / TA+O).

    Subclasses implement the ``_on_*`` hooks and emit actions through the
    ``_emit_*`` helpers; the public event methods wrap each hook and drain
    the staged actions into the returned :class:`PlacementPlan`.
    """

    name: str = "base"

    def __init__(
        self,
        num_replicas: int,
        capacity: TierCapacity,
        config: SchedulerConfig | None = None,
    ):
        self.config = config or SchedulerConfig()
        self.replicas = [
            ReplicaTiers(replica_id=i, capacity=capacity) for i in range(num_replicas)
        ]
        self.waiting = WaitingQueue()
        self.programs: dict[str, ProgramState] = {}
        self.balancer = ReplicaBalancer(self.replicas, self.config)
        self.ledger = TransferLedger()
        self._running: dict[int, set[str]] = {i: set() for i in range(num_replicas)}
        self._staged: list[Action] = []
        self._next_action_id = 1
        self._now = 0.0
        # optional runtime occupancy probe: replica -> (free_slots, live
        # slots) read straight from the engine. When attached (the real
        # router's decode pump), _has_slot/running_count reflect *actual*
        # engine batch occupancy instead of the scheduler's shadow
        # bookkeeping; when absent (simulator, serial replay) behavior is
        # unchanged.
        self._slot_probe: "object | None" = None
        # programs admitted to the GPU queue whose KV has *not* been moved
        # yet (no free engine slot at admission time): maps pid -> the tier
        # the bytes still physically occupy, so the eventual Forward carries
        # the true source instead of pretending the KV is warm.
        self._pending_source: dict[str, Tier] = {}

    # -------------------------------------------------------------- events
    def program_arrived(
        self,
        pid: str,
        kv_bytes_per_token: int,
        now: float,
        wire_bytes_per_token: int | None = None,
    ) -> ProgramState:
        """Register a new program (emits no actions).

        ``wire_bytes_per_token`` is the per-token size in the *offload*
        format — what transfers and host tiers actually carry when pages
        quantize on offload. ``None`` (the default) means the offload
        format equals the device format and every byte figure collapses
        to ``kv_bytes_per_token``, reproducing pre-format accounting
        exactly."""
        prog = ProgramState(pid, kv_bytes_per_token, arrived_at=now)
        prog.wire_bytes_per_token = wire_bytes_per_token
        prog.set_window(self.config.idleness_window)
        self.programs[pid] = prog
        self.waiting.add(prog)
        return prog

    def request_arrived(self, pid: str, input_tokens: int, now: float) -> PlacementPlan:
        self._now = now
        self._on_request_arrived(pid, input_tokens, now)
        return self._drain(now)

    def notify_inference_started(self, pid: str, now: float) -> None:
        """The runtime started executing a forwarded request (no actions)."""
        prog = self.programs[pid]
        prog.begin_reasoning(now)
        if prog.replica is not None:
            self._running[prog.replica].add(pid)

    def request_completed(self, pid: str, output_tokens: int, now: float) -> PlacementPlan:
        self._now = now
        self._on_request_completed(pid, output_tokens, now)
        return self._drain(now)

    def program_finished(self, pid: str, now: float) -> PlacementPlan:
        self._now = now
        prog = self.programs.pop(pid, None)
        if prog is not None:
            prog.finished = True
            if prog.replica is not None:
                self._running[prog.replica].discard(pid)
            self._release(prog)
            self.ledger.drop_pid(pid)
        return self._drain(now)

    def tick(self, now: float) -> PlacementPlan:
        self._now = now
        self._on_tick(now)
        return self._drain(now)

    def on_transfer_complete(self, pid: str, action_id: int, now: float) -> PlacementPlan:
        """Runtime acknowledgement that a transfer finished. Closes the
        ledger record; unknown ids (cancelled, or dropped with a failed
        replica) are tolerated. Policies react to landed bytes through the
        ``_on_transfer_complete`` hook (e.g. promoting a migrated program
        only once its DRAM copy physically exists on the destination)."""
        self._now = now
        rec = self.ledger.complete(action_id)
        if rec is not None:
            self._on_transfer_complete(rec, now)
        return self._drain(now)

    def _on_transfer_complete(self, rec: TransferRecord, now: float) -> None:
        """Policy hook: the transfer behind ``rec`` has fully landed."""

    def on_slot_freed(self, replica: int, now: float) -> PlacementPlan:
        """Runtime notification that an engine decode slot freed mid-batch
        (a resident program finished its step while others keep decoding).
        Policies use the hook to forward gated work into the freed slot
        immediately instead of waiting for the next tick."""
        self._now = now
        self._on_slot_freed(replica, now)
        return self._drain(now)

    def _on_slot_freed(self, replica: int, now: float) -> None:
        """Policy hook: a decode slot on ``replica`` is free again."""

    @abc.abstractmethod
    def _on_request_arrived(self, pid: str, input_tokens: int, now: float) -> None:
        ...

    @abc.abstractmethod
    def _on_request_completed(self, pid: str, output_tokens: int, now: float) -> None:
        ...

    @abc.abstractmethod
    def _on_tick(self, now: float) -> None:
        ...

    # ------------------------------------------------------- fault handling
    def replica_failed(self, replica_id: int, now: float) -> PlacementPlan:
        """Replica failure / drain: the GPU is gone but host DRAM is still
        readable (the drain model — a dying node's device is what failed).

        With ``drain_migrate`` (default on), DRAM-resident programs whose
        bytes have fully landed (no open offload or migrate) are *migrated*
        to the healthy replica with the most host headroom
        (:meth:`ReplicaBalancer.place_drain`) instead of being discarded —
        they re-admit with a reload instead of a full recompute. Everything
        else (GPU-resident KV, half-written offloads) drops to the Waiting
        queue via the normal recompute path — MORI's Waiting-tier
        semantics, which is what makes the design restart-tolerant. The
        returned plan carries the ``Migrate`` per drained program and a
        ``Discard`` per lost KV copy (one per program and tier)."""
        self._now = now
        rep = self.replicas[replica_id]
        self.balancer.mark_failed(replica_id)
        if self.config.drain_migrate:
            for prog in list(rep.cpu.values()):
                if prog.finished:
                    continue
                if (
                    self.ledger.open_offload(prog.program_id) is not None
                    or self.ledger.open_migrate(prog.program_id) is not None
                ):
                    # bytes still in flight toward (or away from) this DRAM
                    # copy die with the node: not trustworthy to migrate
                    continue
                decision = self.balancer.place_drain(prog, now)
                if not decision:
                    continue
                dst = self.replicas[decision.replica]
                rep.cpu_remove(prog)
                self._emit_migrate(prog, replica_id, dst.replica_id)
                dst.cpu_admit(prog)
                prog.metrics.replica_switches += 1
                prog.dispatched = False
                prog.lazy_demote = False
                self._pending_source.pop(prog.program_id, None)
        for tier, prog in rep.evict_all():
            self._emit_discard(prog.program_id, replica_id, tier)
            self.waiting.add(prog)
            prog.metrics.evictions += 1
            prog.dispatched = False  # any in-flight forward died with the node
            prog.lazy_demote = False
            self._pending_source.pop(prog.program_id, None)
        for pid in list(self._running[replica_id]):
            self._running[replica_id].discard(pid)
            prog = self.programs.get(pid)
            if prog is not None and not prog.finished:
                prog.gate(now)  # in-flight request will be re-issued
        self.ledger.drop_replica(replica_id)
        return self._drain(now)

    def replica_recovered(self, replica_id: int) -> None:
        self.balancer.mark_recovered(replica_id)

    # ------------------------------------------------------------- queries
    def replica_of(self, pid: str) -> int | None:
        prog = self.programs.get(pid)
        return prog.replica if prog else None

    def attach_slot_probe(self, probe) -> None:
        """Install ``probe(replica) -> (free_slots, live_slots)`` so slot
        gating and ``running_count`` read real engine occupancy. Pass
        ``None`` to detach and fall back to shadow bookkeeping.

        Occupancy contract: the live side counts every slot a program
        *owns*, including slots still mid-prefill under the router's
        chunked-prefill mode (``Engine.begin_submit`` reserves the slot
        before any chunk runs) — a prefilling program must gate further
        admissions exactly like a decoding one, and the probe owner only
        reports a slot free again once the chunk pipeline drained and the
        program retired."""
        self._slot_probe = probe

    def running_count(self, replica: int) -> int:
        if self._slot_probe is not None:
            return self._slot_probe(replica)[1]
        return len(self._running[replica])

    def _has_slot(self, replica: int | None) -> bool:
        """Can ``replica`` take one more forwarded request right now?

        With a slot probe attached the answer is the engine's own free-slot
        count (minus requests already released but not yet submitted — the
        probe owner accounts for those); otherwise the optional
        ``max_running`` cap against the shadow running set, and unbounded
        when no cap is configured (the pre-probe behavior every scheduler
        shared)."""
        if replica is None:
            return False
        cap = self.config.max_running
        if cap is not None and self.running_count(replica) >= cap:
            return False
        if self._slot_probe is not None:
            free, _ = self._slot_probe(replica)
            return free > 0
        return True

    # ----------------------------------------------------------- emission
    def _drain(self, now: float) -> PlacementPlan:
        actions, self._staged = _coalesce(self._staged), []
        return PlacementPlan(now=now, actions=tuple(actions))

    def _next_id(self) -> int:
        aid = self._next_action_id
        self._next_action_id += 1
        return aid

    def _emit_forward(
        self, prog: ProgramState, source_tier: Tier, recompute: bool = False
    ) -> None:
        prog.dispatched = True
        # a reload moves only the KV that was actually materialized before
        # the offload — not the new input tokens the engine has yet to see —
        # and it moves it in the offload format (wire bytes, not device bytes)
        nbytes = (
            prog.materialized_wire_bytes
            if source_tier in (Tier.CPU, Tier.SSD) else 0
        )
        act = Forward(
            self._next_id(), prog.program_id, prog.replica,
            source_tier, recompute, nbytes,
        )
        if nbytes:
            prog.metrics.reloaded_bytes += nbytes
            self.ledger.open(TransferRecord(
                act.action_id, prog.program_id, prog.replica, "reload",
                channel_for(source_tier), nbytes, source_tier, Tier.GPU,
                self._now,
            ))
        self._staged.append(act)

    def _emit_offload(self, prog: ProgramState, src_tier: Tier, dst_tier: Tier) -> None:
        # like reloads, offloads move only the KV that physically exists —
        # context growth from a not-yet-prefilled input has no pages to copy —
        # and the copy on the wire carries the offload format's payload
        act = Offload(
            self._next_id(), prog.program_id, prog.replica,
            src_tier, dst_tier, prog.materialized_wire_bytes,
        )
        if act.nbytes:
            # offloads bill the channel the bytes are *read* from: SSD-bound
            # writes are staged through host DRAM, so the device/host DMA is
            # the contended resource, while the NVMe channel is reserved for
            # latency-critical reads (reloading Forwards)
            self.ledger.open(TransferRecord(
                act.action_id, prog.program_id, prog.replica, "offload",
                channel_for(src_tier), act.nbytes, src_tier, dst_tier,
                self._now,
            ))
        self._staged.append(act)

    def _emit_discard(self, pid: str, replica: int | None, tier: Tier) -> None:
        self._staged.append(Discard(self._next_id(), pid, replica, tier))

    def _emit_migrate(self, prog: ProgramState, src: int, dst: int) -> None:
        # a migrate ships the DRAM copy, which is stored in offload format
        act = Migrate(
            self._next_id(), prog.program_id, src, dst,
            prog.materialized_wire_bytes,
        )
        if act.nbytes:
            self.ledger.open(TransferRecord(
                act.action_id, prog.program_id, dst, "migrate",
                channel_for(Tier.CPU), act.nbytes, Tier.CPU, Tier.CPU,
                self._now,
            ))
        self._staged.append(act)

    def _emit_cancel(self, pid: str, rec: TransferRecord) -> None:
        self.ledger.cancel(rec.action_id)
        self._staged.append(
            CancelTransfer(self._next_id(), pid, rec.replica, rec.action_id)
        )

    def _set_label(self, prog: ProgramState, label: TypeLabel) -> None:
        if prog.label is not label:
            prog.label = label
            self._staged.append(
                SetLabel(self._next_id(), prog.program_id, prog.replica, label)
            )

    # ------------------------------------------------------------ plumbing
    def _release(self, prog: ProgramState) -> None:
        """Drop a program's KV from wherever it lives."""
        for rep in self.replicas:
            tier = rep.evict(prog)
            if tier is not None:
                self._emit_discard(prog.program_id, rep.replica_id, tier)
        self.waiting.remove(prog)
        self._pending_source.pop(prog.program_id, None)
        prog.tier = Tier.NONE
        prog.replica = None

    def _account_growth(self, prog: ProgramState, new_tokens: int) -> None:
        if new_tokens <= 0:
            return
        if prog.replica is not None:
            self.replicas[prog.replica].grow(prog, new_tokens)
        prog.context_tokens += new_tokens

    def _mark_not_running(self, prog: ProgramState) -> None:
        if prog.replica is not None:
            self._running[prog.replica].discard(prog.program_id)


class MoriScheduler(AgentScheduler):
    """The paper's scheduler: windowed idleness + sticky three-tier placement."""

    name = "mori"

    # ------------------------------------------------------------- events
    def _on_request_arrived(self, pid: str, input_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        new_tokens = max(0, input_tokens - prog.context_tokens)
        self._account_growth(prog, new_tokens)
        prog.gate(now)
        if prog.tier is Tier.GPU and self._has_slot(prog.replica):
            self._dispatch(prog)
        elif not self._cancel_inflight_offload(prog) and self.config.eager_promote:
            self._promote_pass(now)

    def _on_request_completed(self, pid: str, output_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        self._mark_not_running(prog)
        self._account_growth(prog, 0)  # growth applied below via begin_acting
        if prog.replica is not None:
            self.replicas[prog.replica].grow(prog, output_tokens)
        prog.begin_acting(now, new_tokens=output_tokens)
        if prog.lazy_demote and prog.tier is Tier.GPU:
            prog.lazy_demote = False
            self._demote(prog, now)
        if self.config.eager_promote:
            self._promote_pass(now)

    def _on_tick(self, now: float) -> None:
        for rep in self.replicas:
            self._demote_pass(rep, now)
            self._cpu_overflow_pass(rep, now)
            self._ssd_overflow_pass(rep, now)
        self._promote_pass(now)
        if self.config.migrate_on_pressure:
            self._migrate_pass(now)
        self._sync_labels()

    def _on_slot_freed(self, replica: int, now: float) -> None:
        """A decode slot opened mid-batch: run the promotion/forward pass so
        a gated program claims it immediately — the batch dimension never
        idles waiting for the next control tick."""
        del replica  # the promote pass is global and affinity-aware
        self._promote_pass(now)

    def _on_transfer_complete(self, rec: TransferRecord, now: float) -> None:
        """A migrate ack means the program's DRAM copy now physically
        exists on the destination replica — the promotion that was
        deferred when the ``Migrate`` was emitted can finally open its
        reload ``Forward`` (billing the PCIe channel once, after the
        cross-replica move, instead of concurrently with it)."""
        if rec.kind != "migrate":
            return
        prog = self.programs.get(rec.pid)
        if (
            prog is not None
            and not prog.finished
            and prog.tier is Tier.CPU
            and prog.has_pending
            and not prog.dispatched
        ):
            self._try_promote_cpu(prog, now)

    # ------------------------------------------------------ cancel on return
    def _cancel_inflight_offload(self, prog: ProgramState) -> bool:
        """Early tool return: the program's offload is still sitting in the
        runtime's transfer queue, so its KV never actually left the GPU.
        Cancel the transfer and re-admit warm — no reload, no recompute.
        Only offloads sourced from the GPU qualify (a CPU→SSD sink's bytes
        were never on the GPU in the first place)."""
        if prog.tier not in (Tier.CPU, Tier.SSD):
            return False
        rec = self.ledger.open_offload(prog.program_id)
        if rec is None or rec.src_tier is not Tier.GPU:
            return False
        rep = self.replicas[prog.replica]
        if rep.gpu_free() < prog.kv_bytes:
            return False
        rep.remove(prog.tier, prog)
        self._emit_cancel(prog.program_id, rec)
        rep.gpu_admit(prog)
        prog.metrics.cancelled_offloads += 1
        self._set_label(prog, TypeLabel.BUSY)
        if self._has_slot(rep.replica_id):
            self._dispatch(prog)
        return True

    # ---------------------------------------------------------- demotions
    def _demote_pass(self, rep: ReplicaTiers, now: float) -> None:
        """Shrink the GPU queue until it fits (paper §4.3.1 'Demotion')."""
        overflow = rep.gpu_overflow()
        if overflow <= 0:
            return
        # Acting (and gated) programs first, then Reasoning; within a status
        # class, highest idleness first.
        order = {Status.ACTING: 0, Status.GATED: 1, Status.REASONING: 2}
        victims = sorted(
            rep.gpu.values(),
            key=lambda p: (order[p.status], -p.idleness(now)),
        )
        # bytes already promised by victims marked on an *earlier* pass
        # whose in-flight step has not finished yet: without seeding the
        # running total with them, a second tick re-counts the same
        # overflow and demotes extra Acting programs that the pending lazy
        # demotions would already have freed
        pending_free = sum(p.kv_bytes for p in rep.gpu.values() if p.lazy_demote)
        for victim in victims:
            if rep.gpu_used - pending_free <= rep.capacity.gpu_kv_bytes:
                break
            if victim.lazy_demote:
                continue  # already counted in the seed above
            if victim.status is Status.REASONING or victim.dispatched:
                # lazy demotion: finish the in-flight step first. A
                # dispatched-but-not-started program is in the same boat —
                # its reload/recompute Forward is already executing, so
                # demoting it now would move KV out from under the runtime
                # and double-bill the transfer channel.
                victim.lazy_demote = True
                pending_free += victim.kv_bytes
            else:
                self._demote(victim, now)

    def _demote(self, prog: ProgramState, now: float) -> None:
        """GPU -> CPU if DRAM permits, else SSD (§7.1 extension, when
        enabled), else GPU -> Waiting.

        If the program was admitted to the GPU queue but its KV was never
        actually moved (``_pending_source``), the bytes still sit at their
        old tier: demoting back there is free (no transfer emitted), and
        demoting a never-recomputed Waiting re-admission is a pure
        accounting rollback."""
        rep = self.replicas[prog.replica]
        src = self._pending_source.pop(prog.program_id, Tier.GPU)
        rep.gpu_remove(prog)
        prog.metrics.demotions += 1
        if src is Tier.WAITING:
            # recompute never ran: nothing resident anywhere
            self.waiting.add(prog)
            self._set_label(prog, TypeLabel.INACTIVE)
            return
        if src is not Tier.GPU:
            # deferred promotion rolled back: the bytes still sit at their
            # old tier, so re-admitting there is free (no transfer emitted)
            free = rep.cpu_free if src is Tier.CPU else rep.ssd_free
            admit = rep.cpu_admit if src is Tier.CPU else rep.ssd_admit
            if free() >= prog.host_kv_bytes:
                admit(prog)
                self._set_label(prog, TypeLabel.IDLE)
                return
        if rep.cpu_free() >= prog.host_kv_bytes:
            rep.cpu_admit(prog)
            self._emit_offload(prog, src, Tier.CPU)
            self._set_label(prog, TypeLabel.IDLE)
        elif rep.ssd_free() >= prog.host_kv_bytes and self._ssd_worthwhile(prog):
            rep.ssd_admit(prog)
            self._emit_offload(prog, src, Tier.SSD)
            self._set_label(prog, TypeLabel.IDLE)
        else:
            self._emit_discard(prog.program_id, rep.replica_id, src)
            self.waiting.add(prog)
            prog.metrics.evictions += 1
            self._set_label(prog, TypeLabel.INACTIVE)

    def _cpu_overflow_pass(self, rep: ReplicaTiers, now: float) -> None:
        """CPU-side admission control (paper §3.4).

        With the SSD tier enabled (§7.1 extension), the *most idle* CPU
        programs sink to NVMe first — they tolerate the slower reload and
        continue the idleness spectrum downward. Whatever still overflows
        is evicted to Waiting, busiest first, mirroring the typed block
        order (the CPU tier preferentially *retains idle* programs).
        """
        if rep.cpu_overflow() <= 0:
            return
        if rep.capacity.ssd_kv_bytes:
            sinkable = sorted(rep.cpu.values(), key=lambda p: -p.idleness(now))
            for victim in sinkable:
                if rep.cpu_overflow() <= 0:
                    return
                if rep.ssd_free() < victim.host_kv_bytes:
                    break
                if not self._ssd_worthwhile(victim):
                    continue
                rep.cpu_remove(victim)
                rep.ssd_admit(victim)
                self._emit_offload(victim, Tier.CPU, Tier.SSD)
                self._set_label(victim, TypeLabel.IDLE)
        victims = sorted(rep.cpu.values(), key=lambda p: p.idleness(now))
        for victim in victims:
            if rep.cpu_overflow() <= 0:
                break
            rep.cpu_remove(victim)
            self._emit_discard(victim.program_id, rep.replica_id, Tier.CPU)
            self.waiting.add(victim)
            victim.metrics.evictions += 1
            self._set_label(victim, TypeLabel.INACTIVE)

    def _ssd_worthwhile(self, prog: ProgramState) -> bool:
        """Cost-aware SSD guard (beyond §7.1's threshold proposal): keep
        the bytes only if an NVMe reload would beat recomputing them.
        Without configured rates, always sink (the paper-naive policy)."""
        cfg = self.config
        if not cfg.ssd_bytes_per_s or not cfg.recompute_tok_per_s:
            return True
        # the NVMe read moves wire-format bytes: an int8 offload format
        # halves reload_s, widening the band where keeping bytes beats
        # recomputing them — format is a placement decision
        reload_s = prog.host_kv_bytes / cfg.ssd_bytes_per_s
        recompute_s = prog.context_tokens / cfg.recompute_tok_per_s
        return reload_s < cfg.ssd_guard_factor * recompute_s

    def _ssd_overflow_pass(self, rep: ReplicaTiers, now: float) -> None:
        """SSD-side admission control (§7.1 extension): evict to Waiting,
        busiest first (they will be recomputed soon regardless; the most
        idle keep their bytes where idleness is cheapest)."""
        if rep.ssd_overflow() <= 0:
            return
        victims = sorted(rep.ssd.values(), key=lambda p: p.idleness(now))
        for victim in victims:
            if rep.ssd_overflow() <= 0:
                break
            rep.ssd_remove(victim)
            self._emit_discard(victim.program_id, rep.replica_id, Tier.SSD)
            self.waiting.add(victim)
            victim.metrics.evictions += 1
            self._set_label(victim, TypeLabel.INACTIVE)

    # ---------------------------------------------------------- promotions
    def _promote_pass(self, now: float) -> None:
        """Fill free GPU capacity in priority order (paper §4.3.1).

        (1) CPU-queue programs whose tool call has completed (gated), with
            replica affinity; (2) Waiting-queue gated programs, returning
            before new, via most-available-capacity placement; (3) new
            arrivals, smallest context first. Lowest idleness first within
            (1) and (2).
        """
        # --- P1: CPU -> GPU, affinity-preserving. A program whose DRAM
        #     copy is still migrating between replicas is skipped: its
        #     bytes have not landed, so a reload Forward now would ship KV
        #     that does not exist on the destination yet (the promotion
        #     fires from the migrate's on_transfer_complete ack instead).
        #     Migrate records exist under migrate_on_pressure *or* after a
        #     drain_migrate failover; only with both off is the ledger scan
        #     skipped.
        p1 = [
            p
            for rep in self.replicas
            for p in rep.cpu.values()
            if p.has_pending
            and not p.dispatched
            and (
                not (self.config.migrate_on_pressure or self.config.drain_migrate)
                or self.ledger.open_migrate(p.program_id) is None
            )
        ]
        p1.sort(key=lambda p: p.idleness(now))
        for prog in p1:
            self._try_promote_cpu(prog, now)

        # --- P1b: SSD -> GPU (§7.1 extension), affinity-preserving; the
        #     Forward's source_tier bills the reload to the NVMe channel
        p1b = [
            p
            for rep in self.replicas
            for p in rep.ssd.values()
            if p.has_pending and not p.dispatched
        ]
        p1b.sort(key=lambda p: p.idleness(now))
        for prog in p1b:
            self._try_promote_ssd(prog, now)

        # --- P2: Waiting (returning) -> some replica
        p2 = [
            p
            for p in self.waiting.programs.values()
            if p.has_pending and not p.is_new and not p.dispatched
        ]
        p2.sort(key=lambda p: p.idleness(now))
        for prog in p2:
            self._try_admit_waiting(prog, now)

        # --- P3: new arrivals, smallest context first
        p3 = [
            p
            for p in self.waiting.programs.values()
            if p.has_pending and p.is_new and not p.dispatched
        ]
        p3.sort(key=lambda p: p.context_tokens)
        for prog in p3:
            self._try_admit_waiting(prog, now)

        # forward GPU-resident gated programs when slots free (busy first)
        for rep in self.replicas:
            gated = [
                p
                for p in rep.gpu.values()
                if p.status is Status.GATED and p.has_pending and not p.dispatched
            ]
            gated.sort(key=lambda p: p.idleness(now))
            for prog in gated:
                if not self._has_slot(rep.replica_id):
                    break
                self._dispatch(prog)

    def _try_promote_cpu(self, prog: ProgramState, now: float) -> bool:
        rep = self.replicas[prog.replica]
        if not self._make_room(rep, prog, now):
            return False
        rep.cpu_remove(prog)
        rep.gpu_admit(prog)
        prog.metrics.promotions += 1
        self._set_label(prog, TypeLabel.BUSY)
        if self._has_slot(rep.replica_id):
            self._emit_forward(prog, Tier.CPU)
        else:
            self._pending_source[prog.program_id] = Tier.CPU
        return True

    def _try_promote_ssd(self, prog: ProgramState, now: float) -> bool:
        rep = self.replicas[prog.replica]
        if not self._make_room(rep, prog, now):
            return False
        rep.ssd_remove(prog)
        rep.gpu_admit(prog)
        prog.metrics.promotions += 1
        self._set_label(prog, TypeLabel.BUSY)
        if self._has_slot(rep.replica_id):
            self._emit_forward(prog, Tier.SSD)
        else:
            self._pending_source[prog.program_id] = Tier.SSD
        return True

    def _try_admit_waiting(self, prog: ProgramState, now: float) -> bool:
        decision = self.balancer.place(prog, now)
        if not decision:
            return False
        rep = self.replicas[decision.replica]
        if not self._make_room(rep, prog, now, allow_swap=not prog.is_new):
            return False
        self.waiting.remove(prog)
        if prog.home_replica is not None and prog.home_replica != decision.replica:
            prog.metrics.replica_switches += 1
        rep.gpu_admit(prog)
        prog.metrics.promotions += 1
        self._set_label(prog, TypeLabel.BUSY)
        # recomputed_tokens is billed at dispatch time (_dispatch): a
        # deferred admission can still be rolled back by a demotion before
        # any prefill happens, and must not count twice on re-admission
        self._pending_source[prog.program_id] = Tier.WAITING
        if self._has_slot(rep.replica_id):
            self._dispatch(prog)
        return True

    def _make_room(
        self,
        rep: ReplicaTiers,
        prog: ProgramState,
        now: float,
        allow_swap: bool = True,
    ) -> bool:
        """Ensure ``prog.kv_bytes`` fit on ``rep``'s GPU tier.

        Sticky placement: only displaces *Acting* GPU programs that are more
        idle than the candidate by at least the hysteresis margin — the
        'actual mismatch' rule of paper §4.3.
        """
        need = prog.kv_bytes - rep.gpu_free()
        if need <= 0:
            return True
        if not allow_swap:
            return False
        margin = self.config.swap_hysteresis
        cand_iota = prog.idleness(now)
        displaceable = sorted(
            (
                p
                for p in rep.gpu.values()
                if p.status is Status.ACTING
                and not p.lazy_demote
                and p.idleness(now) > cand_iota + margin
            ),
            key=lambda p: -p.idleness(now),
        )
        freed = 0
        chosen: list[ProgramState] = []
        for victim in displaceable:
            if freed >= need:
                break
            chosen.append(victim)
            freed += victim.kv_bytes
        if freed < need:
            return False
        for victim in chosen:
            self._demote(victim, now)
        return True

    # ----------------------------------------------------------- migration
    def _migrate_pass(self, now: float) -> None:
        """Beyond-paper: when a pending CPU-resident program cannot fit its
        home GPU but another healthy replica has abundant room, move the
        DRAM copy there (``Migrate``) and promote on arrival — a reload on
        the new replica instead of a full recompute. Off by default
        (``migrate_on_pressure``); paper-faithful benchmarks keep affinity
        strictly sticky."""
        for rep in self.replicas:
            stuck = [
                p for p in list(rep.cpu.values())
                if p.has_pending and not p.dispatched
                and rep.gpu_free() < p.kv_bytes
            ]
            for prog in stuck:
                if self.ledger.open_offload(prog.program_id) is not None:
                    # its DRAM copy hasn't physically landed yet — migrating
                    # now would ship bytes that are still on the source GPU
                    continue
                if self.ledger.open_migrate(prog.program_id) is not None:
                    continue  # one move at a time
                others = [
                    r for r in self.balancer.healthy()
                    if r.replica_id != rep.replica_id
                    and r.gpu_free() >= prog.kv_bytes
                    and r.cpu_free() >= prog.host_kv_bytes
                ]
                if not others:
                    continue
                dst = max(others, key=lambda r: r.gpu_free())
                rep.cpu_remove(prog)
                self._emit_migrate(prog, rep.replica_id, dst.replica_id)
                dst.cpu_admit(prog)
                prog.metrics.replica_switches += 1
                # promotion is deferred to the migrate's ack
                # (_on_transfer_complete): opening the reload Forward now
                # would double-bill the PCIe channel for the same bytes and
                # forward KV that has not landed on the destination

    # ------------------------------------------------------------ dispatch
    def _dispatch(self, prog: ProgramState) -> None:
        """Forward a GPU-queue program, sourcing the KV from wherever it
        physically still lives (a deferred promotion keeps its true source
        in ``_pending_source``)."""
        src = self._pending_source.pop(prog.program_id, Tier.GPU)
        if src is Tier.WAITING:
            prog.metrics.recomputed_tokens += prog.context_tokens
        self._emit_forward(prog, src, recompute=src is Tier.WAITING)

    def _sync_labels(self) -> None:
        for rep in self.replicas:
            for p in rep.gpu.values():
                self._set_label(p, TypeLabel.BUSY)
            for p in rep.cpu.values():
                self._set_label(p, TypeLabel.IDLE)
            for p in rep.ssd.values():
                self._set_label(p, TypeLabel.IDLE)
        for p in self.waiting.programs.values():
            self._set_label(p, TypeLabel.INACTIVE)
