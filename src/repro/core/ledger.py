"""Per-replica, per-channel accounting of in-flight KV transfers.

The ledger is the scheduler's view of what it has *asked* runtimes to move
but not yet heard back about. A record opens when the scheduler emits a
transfer-bearing action (``Offload``, ``Forward`` with a CPU/SSD source,
``Migrate``) and closes when the runtime acknowledges completion via
``scheduler.on_transfer_complete(pid, action_id, now)`` — or when the
scheduler cancels it (early tool return) or the owning replica fails.

Two channels are modeled, matching the hardware in ``repro.sim.hardware``:

* ``pcie`` — host ↔ device DMA (GPU↔CPU offload/reload, migration ingest);
* ``nvme`` — the §7.1 SSD tier's drive bandwidth (anything touching SSD).

With the ledger the scheduler can see pending bytes per channel before
queueing more work behind them, and can recognise that a program whose
offload is still queued has never actually left the GPU — the fact the
cancel path exploits.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass

from repro.core.types import Tier


class Channel(enum.Enum):
    """Physical transfer channel a record occupies."""

    PCIE = "pcie"
    NVME = "nvme"


def channel_for(tier: Tier) -> Channel:
    """The channel a transfer *reading from* ``tier`` is billed to: SSD
    reads serialize on the drive; everything else is host↔device DMA.
    Callers pass the source tier — writes are staged through host DRAM, so
    the read side is the contended resource for offloads too."""
    return Channel.NVME if tier is Tier.SSD else Channel.PCIE


@dataclass(frozen=True)
class TransferRecord:
    """One in-flight KV movement, keyed by the action that requested it."""

    action_id: int
    pid: str
    replica: int
    kind: str               # "offload" | "reload" | "migrate"
    channel: Channel
    nbytes: int
    src_tier: Tier
    dst_tier: Tier
    opened_at: float


class TransferLedger:
    """Open-transfer table with per-replica / per-channel rollups."""

    def __init__(self) -> None:
        self._open: dict[int, TransferRecord] = {}
        self.completed = 0
        self.cancelled = 0
        self.dropped = 0
        self.completed_bytes: dict[Channel, int] = {c: 0 for c in Channel}
        # optional lifecycle observer (repro.analysis.invariants wires its
        # LedgerAuditor here under REPRO_KVSAN=1); None costs nothing
        self.observer = None

    # ------------------------------------------------------------ lifecycle
    def open(self, rec: TransferRecord) -> TransferRecord:
        assert rec.action_id not in self._open, rec.action_id
        self._open[rec.action_id] = rec
        if self.observer is not None:
            self.observer.on_open(rec)
        return rec

    def complete(self, action_id: int) -> TransferRecord | None:
        """Close a record on runtime acknowledgement. Unknown ids are
        tolerated (the record may have been cancelled, or dropped with a
        failed replica, while the runtime's completion was in flight)."""
        rec = self._open.pop(action_id, None)
        if self.observer is not None:
            self.observer.on_complete(action_id, rec)
        if rec is not None:
            self.completed += 1
            self.completed_bytes[rec.channel] += rec.nbytes
        return rec

    def cancel(self, action_id: int) -> TransferRecord | None:
        rec = self._open.pop(action_id, None)
        if self.observer is not None:
            self.observer.on_cancel(action_id, rec)
        if rec is not None:
            self.cancelled += 1
        return rec

    def drop_pid(self, pid: str) -> list[TransferRecord]:
        """Forget every open transfer for ``pid`` (program finished)."""
        drop = [r for r in self._open.values() if r.pid == pid]
        for r in drop:
            del self._open[r.action_id]
        self.dropped += len(drop)
        if drop and self.observer is not None:
            self.observer.on_drop(drop)
        return drop

    def drop_replica(self, replica: int) -> list[TransferRecord]:
        """Forget every open transfer on ``replica`` (node failure)."""
        drop = [r for r in self._open.values() if r.replica == replica]
        for r in drop:
            del self._open[r.action_id]
        self.dropped += len(drop)
        if drop and self.observer is not None:
            self.observer.on_drop(drop)
        return drop

    # -------------------------------------------------------------- queries
    def in_flight(
        self,
        replica: int | None = None,
        channel: Channel | None = None,
        kind: str | None = None,
    ) -> list[TransferRecord]:
        return [
            r
            for r in self._open.values()
            if (replica is None or r.replica == replica)
            and (channel is None or r.channel is channel)
            and (kind is None or r.kind == kind)
        ]

    def in_flight_bytes(
        self,
        replica: int | None = None,
        channel: Channel | None = None,
        kind: str | None = None,
    ) -> int:
        """Bytes the scheduler has asked to move but not heard back about —
        per replica / channel / kind, the backlog gauge the serving
        transfer plane exports (``RouterMetrics.peak_inflight_bytes``)."""
        return sum(r.nbytes for r in self.in_flight(replica, channel, kind))

    def is_open(self, action_id: int) -> bool:
        """Whether ``action_id`` still has an open record (it may have been
        dropped by program teardown or replica failure in the meantime)."""
        return action_id in self._open

    def open_for(self, pid: str, kind: str) -> TransferRecord | None:
        """The still-pending transfer of ``kind`` for ``pid``, if any."""
        for r in self._open.values():
            if r.pid == pid and r.kind == kind:
                return r
        return None

    def open_offload(self, pid: str) -> TransferRecord | None:
        """The still-pending offload of ``pid``'s KV, if any — the handle
        the early-return cancel path needs."""
        return self.open_for(pid, "offload")

    def open_migrate(self, pid: str) -> TransferRecord | None:
        """The still-pending cross-replica move of ``pid``'s DRAM copy —
        while it is open the bytes have not landed on the destination, so
        promotion (a reload ``Forward`` of the same bytes) must wait."""
        return self.open_for(pid, "migrate")

    def __len__(self) -> int:
        return len(self._open)
