"""Typed action IR for the scheduler ↔ runtime boundary.

The scheduler never *calls into* a runtime. Every lifecycle event
(``request_arrived``, ``request_completed``, ``tick``, ...) returns a
:class:`PlacementPlan` — an ordered, immutable, serializable sequence of
placement actions — and the runtime executes it through its own
``apply_plan`` executor. Transfers (offload / reload / migrate) are
acknowledged asynchronously via ``scheduler.on_transfer_complete``, with
in-flight bytes tracked per replica and channel by
:class:`repro.core.ledger.TransferLedger`.

Why an IR instead of callbacks: KV movement under transfer cost is the
paper's whole subject (§4.3), so movements must be *inspectable data* —
the scheduler can see what is still in flight (and cancel an offload when
a tool call returns early), tests can assert exact action sequences
instead of mock call orders, and the simulator and the real router can be
checked action-for-action against each other on the same trace.

Action vocabulary:

``Forward``   release a gated request on ``replica``; ``source_tier`` says
              where the program's KV currently lives (GPU = warm decode,
              CPU/SSD = reload ``nbytes`` over PCIe/NVMe first,
              WAITING/NONE with ``recompute`` = re-prefill from scratch).
``Offload``   copy KV ``src_tier`` → ``dst_tier`` on ``replica``.
``Discard``   drop the KV copy held by ``tier``.
``Migrate``   move a host-resident KV copy ``src_replica`` → ``dst_replica``.
``SetLabel``  typed-offloading hint (paper §4.3.2).
``CancelTransfer``  abort a still-queued transfer (early tool return).
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, fields
from typing import Iterator

from repro.core.types import Tier, TypeLabel


@dataclass(frozen=True)
class Action:
    """One placement instruction. ``action_id`` is unique and monotonically
    increasing per scheduler instance; transfer completions are acknowledged
    against it."""

    action_id: int
    pid: str


@dataclass(frozen=True)
class Forward(Action):
    """Release a gated request. ``source_tier`` replaces the old
    ``reload``/``recompute`` flag pair *and* the mutable
    ``ProgramState.reload_src`` side-channel: GPU means the KV is warm,
    CPU/SSD mean the runtime must first reload ``nbytes`` over the
    corresponding channel, WAITING (with ``recompute=True``) means the KV
    was discarded and the full context must be re-prefilled."""

    replica: int
    source_tier: Tier = Tier.GPU
    recompute: bool = False
    nbytes: int = 0


@dataclass(frozen=True)
class Offload(Action):
    """Copy a program's KV ``src_tier`` → ``dst_tier`` on ``replica``.
    The source copy stays valid until the transfer completes, which is what
    makes :class:`CancelTransfer` safe."""

    replica: int
    src_tier: Tier = Tier.GPU
    dst_tier: Tier = Tier.CPU
    nbytes: int = 0


@dataclass(frozen=True)
class Discard(Action):
    """Drop the program's KV copy held by ``tier`` (``replica`` None =
    wherever the runtime tracks it)."""

    replica: int | None
    tier: Tier = Tier.GPU


@dataclass(frozen=True)
class Migrate(Action):
    """Move a host-resident KV copy between replicas. Emitted under
    pressure rebalance (``SchedulerConfig.migrate_on_pressure``,
    beyond-paper, off by default) and replica drain
    (``SchedulerConfig.drain_migrate``, on by default). Both runtimes
    execute it through the endpoint-addressed copy API
    (:func:`repro.core.transfers.copy_request_for`); the real transfer
    plane streams it page-by-page through host staging, cancellable
    mid-flight like any other transfer."""

    src_replica: int
    dst_replica: int
    nbytes: int = 0


@dataclass(frozen=True)
class SetLabel(Action):
    """Typed-offloading stamp consulted by engine-level eviction."""

    replica: int | None
    label: TypeLabel = TypeLabel.BUSY


@dataclass(frozen=True)
class CancelTransfer(Action):
    """Abort the still-pending transfer ``target_action_id`` on
    ``replica``. Emitted when a tool call returns before an offload left
    the queue: the GPU copy is still intact, so the program is re-admitted
    warm instead of paying a host round trip. Runtimes that already
    started (or finished) the transfer treat this as a no-op — offloads
    copy rather than move, so the race is benign."""

    replica: int
    target_action_id: int = 0


_ACTION_TYPES: dict[str, type[Action]] = {
    cls.__name__: cls
    for cls in (Forward, Offload, Discard, Migrate, SetLabel, CancelTransfer)
}


def _coalesce(actions: list[Action]) -> list[Action]:
    """Plan-level coalescing: collapse same-kind movements that supersede
    each other inside one plan. Today that is label restamps — only the
    last ``SetLabel`` per program survives (labels are idempotent
    overwrites, so earlier stamps in the same plan are dead weight for the
    runtime). Transfers are never merged here: batching same-channel
    transfers is a *runtime* choice, and the plan keeps them distinct so
    each can be acknowledged (or cancelled) individually."""
    last_label: dict[str, int] = {}
    for i, act in enumerate(actions):
        if isinstance(act, SetLabel):
            last_label[act.pid] = i
    out = []
    for i, act in enumerate(actions):
        if isinstance(act, SetLabel) and last_label[act.pid] != i:
            continue
        out.append(act)
    return out


@dataclass(frozen=True)
class PlacementPlan:
    """An ordered batch of actions emitted by one scheduler event.

    Plans are immutable and JSON-serializable; equality is structural, so
    golden tests can compare entire streams across runtimes.
    """

    now: float
    actions: tuple[Action, ...] = ()

    def __iter__(self) -> Iterator[Action]:
        return iter(self.actions)

    def __len__(self) -> int:
        return len(self.actions)

    def __bool__(self) -> bool:
        return bool(self.actions)

    def of_kind(self, kind: type[Action]) -> list[Action]:
        return [a for a in self.actions if isinstance(a, kind)]

    def to_json(self) -> list[dict]:
        return [action_to_json(a) for a in self.actions]


def action_to_json(action: Action) -> dict:
    d = asdict(action)
    for k, v in d.items():
        if isinstance(v, (Tier, TypeLabel)):
            d[k] = v.value
    d["kind"] = type(action).__name__
    return d


def action_from_json(d: dict) -> Action:
    d = dict(d)
    cls = _ACTION_TYPES[d.pop("kind")]
    for f in fields(cls):
        if f.name in d and isinstance(d[f.name], str):
            if f.name in ("source_tier", "src_tier", "dst_tier", "tier"):
                d[f.name] = Tier(d[f.name])
            elif f.name == "label":
                d[f.name] = TypeLabel(d[f.name])
    return cls(**d)


def plan_from_json(now: float, items: list[dict]) -> PlacementPlan:
    return PlacementPlan(now, tuple(action_from_json(d) for d in items))
