"""Windowed relative-idleness metric (paper §4.2, Eq. 1).

    iota = T_acting^(k) / (T_reasoning^(k) + T_acting^(k))

over the last ``k`` inference/tool-call cycles, where the *in-progress*
interval contributes its elapsed time. This gives the two properties the
paper claims:

* responsive: an ongoing long tool call keeps growing inside the window, so
  iota of a program entering an idle phase rises quickly without needing to
  predict the call's duration;
* robust: one outlier long call amid a busy phase is diluted by the k-1
  surrounding short cycles.

Gated time (scheduler-imposed waiting) is excluded from both terms.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass

from repro.core.types import Status


@dataclass
class _Cycle:
    reasoning_s: float = 0.0
    acting_s: float = 0.0


class IdlenessTracker:
    """Tracks Reasoning/Acting intervals and computes windowed idleness.

    Usage: call :meth:`transition` on every status change with the wall-clock
    timestamp; query :meth:`idleness` at any time. One *cycle* is one
    Reasoning interval plus the Acting interval that follows it.
    """

    def __init__(self, window: int = 5):
        if window < 1:
            raise ValueError("idleness window must be >= 1")
        self.window = window
        self._cycles: deque[_Cycle] = deque(maxlen=window)
        self._status: Status = Status.ACTING  # programs are born "acting"
        self._since: float | None = None
        self._current = _Cycle()

    # ------------------------------------------------------------------ API
    @property
    def status(self) -> Status:
        return self._status

    def transition(self, status: Status, now: float) -> None:
        """Record a status change at time ``now``."""
        if self._since is not None:
            self._accumulate(now)
        if self._status is Status.ACTING and status is not Status.ACTING:
            # an Acting interval just closed -> the cycle is complete
            if self._current.reasoning_s > 0 or self._current.acting_s > 0:
                self._cycles.append(self._current)
                self._current = _Cycle()
        self._status = status
        self._since = now

    def idleness(self, now: float) -> float:
        """Eq. (1) including the elapsed part of the in-progress interval.

        A program with no observed reasoning time yet defaults to 0.5
        (unknown phase); this only affects a program's very first step.
        """
        # the in-progress cycle counts as one of the k window slots
        cur_r = self._current.reasoning_s
        cur_a = self._current.acting_s
        if self._since is not None:  # open interval (GATED adds to neither)
            elapsed = max(0.0, now - self._since)
            if self._status is Status.REASONING:
                cur_r += elapsed
            elif self._status is Status.ACTING:
                cur_a += elapsed
        closed = list(self._cycles)
        if cur_r > 0 or cur_a > 0:
            closed = closed[-(self.window - 1) :] if self.window > 1 else []
            closed.append(_Cycle(cur_r, cur_a))
        reasoning = sum(c.reasoning_s for c in closed)
        acting = sum(c.acting_s for c in closed)
        total = reasoning + acting
        if total <= 0.0:
            return 0.5
        return acting / total

    # -------------------------------------------------------- persistence
    def window_dump(self) -> list[list[float]]:
        """Serializable window contents (state_io snapshots)."""
        cycles = list(self._cycles) + [self._current]
        return [[c.reasoning_s, c.acting_s] for c in cycles]

    def window_load(self, dump: list[list[float]]) -> None:
        """Rebuild the window from :meth:`window_dump` output. The restored
        tracker starts a fresh Acting interval (restart semantics)."""
        self._cycles.clear()
        for r, a in dump[: self.window]:
            self._cycles.append(_Cycle(reasoning_s=r, acting_s=a))
        self._current = _Cycle()
        self._status = Status.ACTING
        self._since = None

    # ------------------------------------------------------------ internals
    def _accumulate(self, now: float) -> None:
        if self._since is None:
            return
        dt = max(0.0, now - self._since)
        if self._status is Status.REASONING:
            self._current.reasoning_s += dt
        elif self._status is Status.ACTING:
            self._current.acting_s += dt
        # GATED: excluded from both terms (paper §4.2)
