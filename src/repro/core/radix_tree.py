"""Typed radix tree over paged KV blocks (paper §4.3.2).

The real engine (``repro.serving``) stores KV in fixed-size pages; this tree
maps token-block chains to page ids so programs sharing a prefix share pages
(RadixAttention-style reuse). Each node carries:

* a *type label* (busy / idle / inactive) stamped from its program's tier —
  the scheduler's program-level placement propagated to block granularity;
* a *location* per tier (device page id and/or host page id);
* an LRU timestamp and a refcount.

Eviction is LRU at its core but uses the type label as the higher-priority
sort key, with the priority order **reversed** between tiers
(``GPU_EVICTION_ORDER`` vs ``CPU_EVICTION_ORDER``) so each tier preferentially
retains the programs assigned to it.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field

from repro.analysis import kvsan
from repro.core.types import (
    CPU_EVICTION_ORDER,
    GPU_EVICTION_ORDER,
    TypeLabel,
)

_counter = itertools.count()


@dataclass
class RadixNode:
    """One KV page worth of tokens."""

    tokens: tuple[int, ...]
    parent: "RadixNode | None"
    children: dict[tuple[int, ...], "RadixNode"] = field(default_factory=dict)
    device_page: int | None = None
    host_page: int | None = None
    label: TypeLabel = TypeLabel.BUSY
    last_access: int = 0
    refcount: int = 0
    node_id: int = field(default_factory=lambda: next(_counter))

    @property
    def depth(self) -> int:
        d, n = 0, self.parent
        while n is not None:
            d, n = d + 1, n.parent
        return d

    def is_leaf_on(self, tier: str) -> bool:
        attr = "device_page" if tier == "gpu" else "host_page"
        return not any(getattr(c, attr) is not None for c in self.children.values())


class TypedRadixTree:
    """Prefix tree at page (block) granularity with typed two-tier eviction."""

    def __init__(self, page_tokens: int):
        self.page_tokens = page_tokens
        self.root = RadixNode(tokens=(), parent=None)
        self._clock = itertools.count(1)
        # program_id -> list of nodes along its path (for label re-stamping)
        self._program_nodes: dict[str, list[RadixNode]] = {}
        # kvsan strict mode: refcount underflow and unbalanced pin/unpin
        # become hard errors instead of being clamped away silently
        self._strict = kvsan.enabled()
        self._pin_depth: dict[str, int] = {}

    # ------------------------------------------------------------- lookup
    def match_prefix(self, tokens: list[int]) -> list[RadixNode]:
        """Longest chain of *device-resident* full pages matching ``tokens``."""
        out: list[RadixNode] = []
        node = self.root
        t = next(self._clock)
        for i in range(0, len(tokens) - self.page_tokens + 1, self.page_tokens):
            key = tuple(tokens[i : i + self.page_tokens])
            child = node.children.get(key)
            if child is None or child.device_page is None:
                break
            child.last_access = t
            out.append(child)
            node = child
        return out

    def match_prefix_any_tier(self, tokens: list[int]) -> list[RadixNode]:
        """Longest chain resident on *either* tier (device or host)."""
        out: list[RadixNode] = []
        node = self.root
        for i in range(0, len(tokens) - self.page_tokens + 1, self.page_tokens):
            key = tuple(tokens[i : i + self.page_tokens])
            child = node.children.get(key)
            if child is None or (child.device_page is None and child.host_page is None):
                break
            out.append(child)
            node = child
        return out

    # ------------------------------------------------------------- insert
    def insert_chain(
        self,
        tokens: list[int],
        page_ids: list[int],
        program_id: str,
        label: TypeLabel,
    ) -> list[RadixNode]:
        """Insert/extend a path of full pages; stamp with the program's type."""
        node = self.root
        nodes: list[RadixNode] = []
        t = next(self._clock)
        pi = 0
        for i in range(0, len(tokens) - self.page_tokens + 1, self.page_tokens):
            key = tuple(tokens[i : i + self.page_tokens])
            child = node.children.get(key)
            if child is None:
                child = RadixNode(tokens=key, parent=node)
                node.children[key] = child
            if child.device_page is None:
                if pi >= len(page_ids):
                    raise ValueError("not enough pages supplied for new nodes")
                child.device_page = page_ids[pi]
                pi += 1
            child.label = label
            child.last_access = t
            nodes.append(child)
            node = child
        if pi != len(page_ids):
            raise ValueError(f"supplied {len(page_ids)} pages, consumed {pi}")
        self._program_nodes[program_id] = nodes
        return nodes

    def insert_host_chain(
        self,
        tokens: list[int],
        host_page_ids: list[int],
        program_id: str,
        label: TypeLabel,
    ) -> tuple[list[RadixNode], list[int]]:
        """Insert/extend a path of full pages resident on the *host* tier —
        the landing verb for a cross-replica migrate: imported DRAM pages
        become a host-resident prefix chain, reloadable to the GPU by the
        normal reload path. One page id is consumed per chain node; a node
        that already holds a host copy keeps it and the supplied duplicate
        is returned for the caller to free (share-on-match at the host
        tier, mirroring :meth:`insert_chain`'s device-side semantics)."""
        node = self.root
        nodes: list[RadixNode] = []
        duplicates: list[int] = []
        t = next(self._clock)
        pi = 0
        for i in range(0, len(tokens) - self.page_tokens + 1, self.page_tokens):
            key = tuple(tokens[i : i + self.page_tokens])
            child = node.children.get(key)
            if child is None:
                child = RadixNode(tokens=key, parent=node)
                node.children[key] = child
            if pi >= len(host_page_ids):
                raise ValueError("not enough host pages supplied for new nodes")
            if child.host_page is None:
                child.host_page = host_page_ids[pi]
            else:
                duplicates.append(host_page_ids[pi])
            pi += 1
            child.label = label
            child.last_access = t
            nodes.append(child)
            node = child
        if pi != len(host_page_ids):
            raise ValueError(f"supplied {len(host_page_ids)} pages, consumed {pi}")
        self._program_nodes[program_id] = nodes
        return nodes, duplicates

    # -------------------------------------------------------------- labels
    def restamp(self, program_id: str, label: TypeLabel) -> None:
        """Propagate a scheduler label change onto the program's blocks."""
        for node in self._program_nodes.get(program_id, []):
            node.label = label

    def pin(self, program_id: str) -> None:
        self._pin_depth[program_id] = self._pin_depth.get(program_id, 0) + 1
        self.acquire_nodes(self._program_nodes.get(program_id, []))

    def unpin(self, program_id: str) -> None:
        depth = self._pin_depth.get(program_id, 0)
        if depth <= 0:
            if self._strict:
                raise kvsan.KvsanError(
                    f"unpin({program_id!r}) without a matching pin — "
                    f"refcount underflow hidden by the clamp"
                )
        else:
            self._pin_depth[program_id] = depth - 1
        self.release_nodes(self._program_nodes.get(program_id, []))

    def acquire_nodes(self, nodes) -> None:
        """Refcount-hold a node chain (a block table, an in-flight reload).
        Must be balanced by :meth:`release_nodes` on every path."""
        for node in nodes:
            node.refcount += 1

    def release_nodes(self, nodes) -> None:
        """Drop a :meth:`acquire_nodes` hold. Under kvsan an underflow is a
        hard error; otherwise it clamps at zero (the historical, silently
        forgiving behaviour)."""
        for node in nodes:
            if node.refcount <= 0 and self._strict:
                raise kvsan.KvsanError(
                    f"refcount underflow releasing radix node "
                    f"{node.node_id} (device_page={node.device_page}, "
                    f"host_page={node.host_page}) — release without a "
                    f"matching acquire"
                )
            node.refcount = max(0, node.refcount - 1)

    def release_program(self, program_id: str) -> None:
        if self._strict and self._pin_depth.get(program_id, 0) > 0:
            raise kvsan.KvsanError(
                f"release_program({program_id!r}) with "
                f"{self._pin_depth[program_id]} outstanding pin(s) — an "
                f"in-flight hold still references the program's chain"
            )
        self._pin_depth.pop(program_id, None)
        self._program_nodes.pop(program_id, None)

    def program_nodes(self, program_id: str) -> list[RadixNode]:
        return self._program_nodes.get(program_id, [])

    # ------------------------------------------------------------ eviction
    def evictable(self, tier: str) -> list[RadixNode]:
        """Eviction candidates on a tier, best-victim-first.

        Sort key = (type priority for that tier, LRU time, -depth): the type
        label dominates, LRU breaks ties within a type (paper §4.3.2), and
        deeper nodes go first so parents never lose pages before children.
        """
        order = GPU_EVICTION_ORDER if tier == "gpu" else CPU_EVICTION_ORDER
        attr = "device_page" if tier == "gpu" else "host_page"
        nodes = [
            n
            for n in self._iter_nodes()
            if getattr(n, attr) is not None and n.refcount == 0 and n.is_leaf_on(tier)
        ]
        nodes.sort(key=lambda n: (order[n.label], n.last_access, -n.depth))
        return nodes

    def evict(self, node: RadixNode, tier: str) -> int:
        attr = "device_page" if tier == "gpu" else "host_page"
        page = getattr(node, attr)
        assert page is not None and node.refcount == 0
        setattr(node, attr, None)
        self._gc(node)
        return page

    # ------------------------------------------------------------ plumbing
    def _gc(self, node: RadixNode) -> None:
        while (
            node is not self.root
            and node.device_page is None
            and node.host_page is None
            and not node.children
            and node.refcount == 0
        ):
            parent = node.parent
            parent.children.pop(node.tokens, None)
            node = parent

    def _iter_nodes(self):
        stack = list(self.root.children.values())
        while stack:
            n = stack.pop()
            yield n
            stack.extend(n.children.values())

    def stats(self) -> dict:
        dev = host = 0
        for n in self._iter_nodes():
            dev += n.device_page is not None
            host += n.host_page is not None
        return {"device_pages": dev, "host_pages": host}
