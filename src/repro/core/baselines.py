"""Baseline schedulers from the paper's evaluation (§6.1).

* :class:`SMGScheduler` — SGLang Model Gateway: request-level, prefix-aware
  routing, engine-side LRU eviction, **no** program pinning, **no** offload.
* :class:`TAScheduler` — ThunderAgent: program-aware pinning across tool
  calls, context-length-based GPU eviction, **no** CPU tier; evicted programs
  are rerouted to the lightest-loaded replica (breaks affinity, §6.2.2).
* :class:`TAOScheduler` — ThunderAgent+Offloading: TA's scheduler on top of
  an engine whose HiCache layer independently spills GPU-evicted KV to CPU
  DRAM under plain LRU, *without scheduler coordination*: routing still
  treats evicted programs as stateless, so a reload only happens if the
  lightest-loaded replica coincidentally holds the CPU copy.

All implement the same :class:`repro.core.scheduler.AgentScheduler` event
API — events in, :class:`~repro.core.actions.PlacementPlan` out — so the
simulator and benchmarks are policy-agnostic.
"""
from __future__ import annotations

from collections import OrderedDict

from repro.core.program import ProgramState
from repro.core.scheduler import AgentScheduler
from repro.core.types import Status, Tier


class SMGScheduler(AgentScheduler):
    """Prefix-aware request gateway; engine LRU; no pinning, no offload."""

    name = "smg"

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._last_active: dict[str, float] = {}
        self._fifo: list[str] = []  # gated request order

    # ------------------------------------------------------------- events
    def _on_request_arrived(self, pid: str, input_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        self._account_growth(prog, max(0, input_tokens - prog.context_tokens))
        prog.gate(now)
        self._last_active[pid] = now
        if pid not in self._fifo:
            self._fifo.append(pid)
        self._admit(now)

    def _on_request_completed(self, pid: str, output_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        self._mark_not_running(prog)
        if prog.replica is not None:
            self.replicas[prog.replica].grow(prog, output_tokens)
        prog.begin_acting(now, new_tokens=output_tokens)
        self._last_active[pid] = now
        self._admit(now)

    def _on_tick(self, now: float) -> None:
        self._admit(now)

    def _on_slot_freed(self, replica: int, now: float) -> None:
        del replica
        self._admit(now)

    # ----------------------------------------------------------- admission
    def _admit(self, now: float) -> None:
        still_gated: list[str] = []
        for pid in self._fifo:
            prog = self.programs.get(pid)
            if prog is None or not prog.has_pending:
                continue
            if not self._admit_one(prog, now):
                still_gated.append(pid)
        self._fifo = still_gated

    def _admit_one(self, prog: ProgramState, now: float) -> bool:
        # prefix-aware routing: prefer the replica already caching this
        # program's KV (the longest-matching-prefix proxy at program grain)
        target = prog.replica if prog.tier is Tier.GPU else None
        cached = target is not None
        if target is None:
            reps = self.balancer.healthy()
            if not reps:
                return False
            target = max(reps, key=lambda r: r.gpu_free()).replica_id
        rep = self.replicas[target]
        if not self._has_slot(target):
            return False
        need = 0 if cached else prog.kv_bytes
        # growth overflow can leave gpu_free() negative even for a cached
        # candidate; never let the LRU pass evict the program being admitted
        if need > rep.gpu_free() and not self._lru_evict(
            rep, need - rep.gpu_free(), now, keep=prog.program_id
        ):
            return False
        if not cached:
            if prog.tier is Tier.GPU:  # resident elsewhere: drop old copy
                old = self.replicas[prog.replica]
                old.gpu_remove(prog)
                self._emit_discard(prog.program_id, old.replica_id, Tier.GPU)
            if prog.home_replica is not None and prog.home_replica != target:
                prog.metrics.replica_switches += 1
            self.waiting.remove(prog)
            rep.gpu_admit(prog)
            prog.metrics.recomputed_tokens += prog.context_tokens
        if cached:
            self._emit_forward(prog, Tier.GPU)
        else:
            self._emit_forward(prog, Tier.WAITING, recompute=True)
        return True

    # _has_slot comes from AgentScheduler (max_running cap / runtime probe)

    def _lru_evict(self, rep, need: int, now: float, keep: str | None = None) -> bool:
        """Engine-level LRU: evict least-recently-active non-running KV."""
        victims = sorted(
            (
                p
                for p in rep.gpu.values()
                if p.status is not Status.REASONING and p.program_id != keep
            ),
            key=lambda p: self._last_active.get(p.program_id, 0.0),
        )
        freed = 0
        for v in victims:
            if freed >= need:
                break
            freed += v.kv_bytes
            rep.gpu_remove(v)
            self._emit_discard(v.program_id, rep.replica_id, Tier.GPU)
            self.waiting.add(v)
            v.metrics.evictions += 1
        return freed >= need


class TAScheduler(AgentScheduler):
    """Program-aware pinning; context-length GPU eviction; no CPU tier."""

    name = "ta"
    offloading = False

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        self._fifo: list[str] = []

    # ------------------------------------------------------------- events
    def _on_request_arrived(self, pid: str, input_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        self._account_growth(prog, max(0, input_tokens - prog.context_tokens))
        prog.gate(now)
        if prog.tier is Tier.GPU and self._has_slot(prog.replica):
            self._emit_forward(prog, Tier.GPU)
            return
        if pid not in self._fifo:
            self._fifo.append(pid)
        self._admit(now)

    def _on_request_completed(self, pid: str, output_tokens: int, now: float) -> None:
        prog = self.programs[pid]
        self._mark_not_running(prog)
        if prog.replica is not None:
            self.replicas[prog.replica].grow(prog, output_tokens)
        prog.begin_acting(now, new_tokens=output_tokens)
        for rep in self.replicas:  # growth may overflow: evict by ctx length
            self._shrink_to_fit(rep, now)
        self._admit(now)

    def _on_tick(self, now: float) -> None:
        for rep in self.replicas:
            self._shrink_to_fit(rep, now)
        self._admit(now)

    def _on_slot_freed(self, replica: int, now: float) -> None:
        del replica
        self._admit(now)

    # ----------------------------------------------------------- policies
    def _shrink_to_fit(self, rep, now: float) -> None:
        while rep.gpu_overflow() > 0:
            acting = [p for p in rep.gpu.values() if p.status is not Status.REASONING]
            if not acting:
                break
            victim = max(acting, key=lambda p: p.context_tokens)
            self._evict_gpu(rep, victim)

    def _evict_gpu(self, rep, victim: ProgramState) -> None:
        rep.gpu_remove(victim)
        self._spill(rep, victim)
        self.waiting.add(victim)
        victim.metrics.evictions += 1

    def _spill(self, rep, victim: ProgramState) -> None:
        """TA discards outright; TA+O overrides to spill into HiCache."""
        self._emit_discard(victim.program_id, rep.replica_id, Tier.GPU)

    def _admit(self, now: float) -> None:
        still: list[str] = []
        for pid in self._fifo:
            prog = self.programs.get(pid)
            if prog is None or not prog.has_pending:
                continue
            if prog.tier is Tier.GPU:
                if self._has_slot(prog.replica):
                    self._emit_forward(prog, Tier.GPU)
                else:
                    still.append(pid)
                continue
            if not self._admit_one(prog, now):
                still.append(pid)
        self._fifo = still

    def _admit_one(self, prog: ProgramState, now: float) -> bool:
        # offloading-agnostic routing: lightest load (paper §6.2.2)
        reps = self.balancer.healthy()
        if not reps:
            return False
        rep = max(reps, key=lambda r: r.gpu_free())
        if not self._has_slot(rep.replica_id):
            return False
        need = prog.kv_bytes - rep.gpu_free()
        if need > 0:
            # context-length eviction, blind to phase (the §3.4 pathology)
            acting = sorted(
                (p for p in rep.gpu.values() if p.status is not Status.REASONING),
                key=lambda p: -p.context_tokens,
            )
            freed, chosen = 0, []
            for v in acting:
                if freed >= need:
                    break
                if v.context_tokens <= prog.context_tokens:
                    break  # don't evict smaller programs to fit a bigger one
                chosen.append(v)
                freed += v.kv_bytes
            if freed < need:
                return False
            for v in chosen:
                self._evict_gpu(rep, v)
        if prog.home_replica is not None and prog.home_replica != rep.replica_id:
            prog.metrics.replica_switches += 1
        self.waiting.remove(prog)
        rep.gpu_admit(prog)
        if self._try_reload(rep, prog):
            self._emit_forward(prog, Tier.CPU)
        else:
            prog.metrics.recomputed_tokens += prog.context_tokens
            self._emit_forward(prog, Tier.WAITING, recompute=True)
        return True

    def _try_reload(self, rep, prog: ProgramState) -> bool:
        return False  # TA has no CPU tier


class TAOScheduler(TAScheduler):
    """TA + uncoordinated HiCache-style CPU spill (engine-level plain LRU)."""

    name = "ta+o"
    offloading = True

    def __init__(self, *args, **kwargs):
        super().__init__(*args, **kwargs)
        # per-replica LRU of spilled KV: pid -> bytes (OrderedDict = LRU)
        self._hicache: dict[int, OrderedDict[str, int]] = {
            r.replica_id: OrderedDict() for r in self.replicas
        }
        self._hicache_used: dict[int, int] = {r.replica_id: 0 for r in self.replicas}

    def _spill(self, rep, victim: ProgramState) -> None:
        cache = self._hicache[rep.replica_id]
        cap = rep.capacity.cpu_kv_bytes
        size = victim.host_kv_bytes   # the spilled copy is in offload format
        if size > cap:
            self._emit_discard(victim.program_id, rep.replica_id, Tier.GPU)
            return
        while self._hicache_used[rep.replica_id] + size > cap and cache:
            old_pid, old_size = cache.popitem(last=False)  # plain LRU
            self._hicache_used[rep.replica_id] -= old_size
            self._emit_discard(old_pid, rep.replica_id, Tier.CPU)
        cache[victim.program_id] = size
        self._hicache_used[rep.replica_id] += size
        self._emit_offload(victim, Tier.GPU, Tier.CPU)

    def _try_reload(self, rep, prog: ProgramState) -> bool:
        cache = self._hicache[rep.replica_id]
        size = cache.pop(prog.program_id, None)
        if size is None:
            # the CPU copy (if any) lives on another replica -> wasted
            for rid, other in self._hicache.items():
                if prog.program_id in other:
                    self._hicache_used[rid] -= other.pop(prog.program_id)
                    self._emit_discard(prog.program_id, rid, Tier.CPU)
            return False
        self._hicache_used[rep.replica_id] -= size
        return True
