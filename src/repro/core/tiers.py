"""Three-tier queue structure (paper §4.1, Fig. 6).

Each replica owns a GPU queue (HBM-resident programs) and a CPU queue
(DRAM-offloaded programs); a single Waiting queue is global. Queues here are
*capacity-accounted sets* — ordering decisions live in the scheduler policy,
not in the container.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator

from repro.core.program import ProgramState
from repro.core.types import Tier, TierCapacity


@dataclass
class ReplicaTiers:
    """Byte-accounted GPU + CPU queues for one inference-engine replica.

    Tier formats: the GPU queue accounts programs at their device-resident
    size (``kv_bytes``); the CPU and SSD queues account them at the offload
    format's size (``host_kv_bytes``) — with an int8 offload format a host
    tier holds roughly twice the contexts per byte of budget.
    """

    replica_id: int
    capacity: TierCapacity
    gpu: dict[str, ProgramState] = field(default_factory=dict)
    cpu: dict[str, ProgramState] = field(default_factory=dict)
    ssd: dict[str, ProgramState] = field(default_factory=dict)
    gpu_used: int = 0
    cpu_used: int = 0
    ssd_used: int = 0
    # straggler signal: EWMA of observed step latency (updated by the runtime)
    ewma_step_latency_s: float = 0.0

    # ------------------------------------------------------------------ GPU
    def gpu_free(self) -> int:
        return self.capacity.gpu_kv_bytes - self.gpu_used

    def gpu_admit(self, prog: ProgramState) -> None:
        assert prog.program_id not in self.gpu
        self.gpu[prog.program_id] = prog
        self.gpu_used += prog.kv_bytes
        prog.tier = Tier.GPU
        prog.replica = self.replica_id
        prog.home_replica = self.replica_id

    def gpu_remove(self, prog: ProgramState) -> None:
        del self.gpu[prog.program_id]
        self.gpu_used -= prog.kv_bytes

    def gpu_overflow(self) -> int:
        return max(0, self.gpu_used - self.capacity.gpu_kv_bytes)

    # ------------------------------------------------------------------ CPU
    def cpu_free(self) -> int:
        return self.capacity.cpu_kv_bytes - self.cpu_used

    def cpu_admit(self, prog: ProgramState) -> None:
        assert prog.program_id not in self.cpu
        self.cpu[prog.program_id] = prog
        self.cpu_used += prog.host_kv_bytes
        prog.tier = Tier.CPU
        prog.replica = self.replica_id

    def cpu_remove(self, prog: ProgramState) -> None:
        del self.cpu[prog.program_id]
        self.cpu_used -= prog.host_kv_bytes

    def cpu_overflow(self) -> int:
        return max(0, self.cpu_used - self.capacity.cpu_kv_bytes)

    # ------------------------------------------------------------------ SSD
    # beyond-paper (§7.1): a third, NVMe-backed tier below CPU DRAM.
    def ssd_free(self) -> int:
        return self.capacity.ssd_kv_bytes - self.ssd_used

    def ssd_admit(self, prog: ProgramState) -> None:
        assert prog.program_id not in self.ssd
        self.ssd[prog.program_id] = prog
        self.ssd_used += prog.host_kv_bytes
        prog.tier = Tier.SSD
        prog.replica = self.replica_id

    def ssd_remove(self, prog: ProgramState) -> None:
        del self.ssd[prog.program_id]
        self.ssd_used -= prog.host_kv_bytes

    def ssd_overflow(self) -> int:
        return max(0, self.ssd_used - self.capacity.ssd_kv_bytes)

    # --------------------------------------------------- tier-generic views
    def queues(self) -> Iterator[tuple[Tier, dict[str, ProgramState]]]:
        """The hardware-backed queues in demotion order. Adding a tier means
        adding one entry here — every tier-generic loop picks it up."""
        yield Tier.GPU, self.gpu
        yield Tier.CPU, self.cpu
        yield Tier.SSD, self.ssd

    def remove(self, tier: Tier, prog: ProgramState) -> None:
        """Remove ``prog`` from the named tier's queue (byte-accounted)."""
        if tier is Tier.GPU:
            self.gpu_remove(prog)
        elif tier is Tier.CPU:
            self.cpu_remove(prog)
        else:
            self.ssd_remove(prog)

    def evict(self, prog: ProgramState) -> Tier | None:
        """Remove ``prog`` from whichever queue holds it; returns the tier
        it occupied, or None if it was not resident on this replica."""
        for tier, q in self.queues():
            if prog.program_id in q:
                self.remove(tier, prog)
                return tier
        return None

    def evict_all(self) -> Iterator[tuple[Tier, ProgramState]]:
        """Drain every resident program, yielding ``(tier, prog)`` pairs
        after removal — the single code path for whole-replica teardown
        (node failure), replacing three copy-pasted per-tier loops."""
        for tier, q in self.queues():
            for prog in list(q.values()):
                self.remove(tier, prog)
                yield tier, prog

    # ------------------------------------------------------------- growth
    def grow(self, prog: ProgramState, new_tokens: int) -> None:
        """Account for context growth of a resident program.

        May push the tier into overflow; the next scheduler pass resolves it
        (paper: capacity violations *force* demotion).
        """
        if prog.program_id in self.gpu:
            self.gpu_used += new_tokens * prog.kv_bytes_per_token
        elif prog.program_id in self.cpu:
            self.cpu_used += new_tokens * prog.host_bytes_per_token
        elif prog.program_id in self.ssd:
            self.ssd_used += new_tokens * prog.host_bytes_per_token

    def check(self) -> None:
        """Invariant check used by property tests."""
        assert self.gpu_used == sum(p.kv_bytes for p in self.gpu.values())
        assert self.cpu_used == sum(p.host_kv_bytes for p in self.cpu.values())
        assert self.ssd_used == sum(p.host_kv_bytes for p in self.ssd.values())
        assert not (set(self.gpu) & set(self.cpu))
        assert not (set(self.gpu) & set(self.ssd))
        assert not (set(self.cpu) & set(self.ssd))


@dataclass
class WaitingQueue:
    """Global queue of programs whose KV has been discarded (paper §4.1)."""

    programs: dict[str, ProgramState] = field(default_factory=dict)

    def add(self, prog: ProgramState) -> None:
        self.programs[prog.program_id] = prog
        prog.tier = Tier.WAITING
        prog.replica = None

    def remove(self, prog: ProgramState) -> None:
        self.programs.pop(prog.program_id, None)

    def __len__(self) -> int:
        return len(self.programs)
