"""Shared control-plane types for the MORI scheduler.

Everything in ``repro.core`` is *control plane*: pure Python, no JAX. The same
objects drive both the real JAX serving engine (``repro.serving``) and the
discrete-event simulator (``repro.sim``), which is how the paper's policy code
is validated once and reused everywhere.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field


class Status(enum.Enum):
    """Instantaneous program status (paper §4.1).

    REASONING: an inference request for the program is executing on a GPU.
    ACTING:    the program is inside a tool call; its KV cache is idle.
    GATED:     the program has a pending request but the scheduler is holding
               it (KV not GPU-resident / no capacity).  Gated time is excluded
               from the idleness metric (paper §4.2).
    """

    REASONING = "reasoning"
    ACTING = "acting"
    GATED = "gated"


class Tier(enum.Enum):
    """Memory tier a program's KV state currently occupies (paper §4.1)."""

    GPU = "gpu"          # HBM-resident, requests forwarded directly
    CPU = "cpu"          # offloaded to host DRAM, must reload before running
    SSD = "ssd"          # beyond-paper (paper §7.1): local NVMe tier
    WAITING = "waiting"  # KV discarded entirely; resume = full recompute
    NONE = "none"        # brand-new program, nothing allocated yet


class TypeLabel(enum.Enum):
    """Typed-offloading label stamped onto KV blocks (paper §4.3.2)."""

    BUSY = "busy"
    IDLE = "idle"
    INACTIVE = "inactive"


#: Engine-side eviction priority per tier: lower sorts first = evicted first.
#: GPU HBM evicts inactive -> idle -> busy; CPU DRAM evicts
#: inactive -> busy -> idle (reversed so each tier retains "its" programs).
GPU_EVICTION_ORDER = {
    TypeLabel.INACTIVE: 0,
    TypeLabel.IDLE: 1,
    TypeLabel.BUSY: 2,
}
CPU_EVICTION_ORDER = {
    TypeLabel.INACTIVE: 0,
    TypeLabel.BUSY: 1,
    TypeLabel.IDLE: 2,
}


@dataclass
class TierCapacity:
    """Byte budgets for one replica's hardware-backed tiers. ``ssd_kv_bytes``
    defaults to 0 = disabled (the paper's two-tier configuration); setting it
    enables the §7.1 NVMe extension evaluated in benchmarks/ssd_tier.py.

    Tier formats: the GPU budget is consumed at the device format's
    per-token size, the CPU/SSD budgets at the offload format's
    (``ProgramState.host_kv_bytes``) — an int8 offload format fits ~2x the
    contexts in the same host budget without the budget itself changing."""

    gpu_kv_bytes: int
    cpu_kv_bytes: int
    ssd_kv_bytes: int = 0

    def scaled(self, cpu_ratio: float, ssd_ratio: float = 0.0) -> "TierCapacity":
        """Return a copy with CPU capacity = ``cpu_ratio`` x GPU capacity
        (the paper evaluates 1x and 2x) and SSD = ``ssd_ratio`` x GPU."""
        return TierCapacity(
            self.gpu_kv_bytes,
            int(self.gpu_kv_bytes * cpu_ratio),
            int(self.gpu_kv_bytes * ssd_ratio),
        )


@dataclass
class SchedulerConfig:
    """Knobs for :class:`repro.core.scheduler.MoriScheduler`.

    Defaults follow the paper: k=5 cycle idleness window, 5 s control tick.
    """

    idleness_window: int = 5          # k in Eq. (1)
    tick_interval_s: float = 5.0      # control-loop period (paper §5)
    eager_promote: bool = True        # also try promotion on arrival/complete
    swap_hysteresis: float = 0.10     # min idleness gap to swap GPU<-CPU
    max_running: int | None = None    # optional engine batch-slot cap
    # straggler mitigation: penalty weight applied to replicas whose EWMA
    # step latency exceeds the fleet median (beyond-paper, off by default
    # in paper-faithful benchmarks).
    straggler_penalty: float = 0.0
    # beyond-paper: when a pending CPU-resident program cannot fit its home
    # GPU, move its DRAM copy to a roomier replica (a ``Migrate`` action)
    # instead of waiting — breaks strict affinity, so off by default. The
    # real router executes it as a page-granular host-to-host copy on the
    # destination's transfer plane (requires paged engines; it raises at
    # construction naming this knob otherwise).
    migrate_on_pressure: bool = False
    # on replica failure, migrate its DRAM-resident programs to healthy
    # replicas with host headroom instead of discarding them to Waiting
    # (which costs a full recompute). Independent of migrate_on_pressure:
    # drain migrates are emitted even when pressure migration is off.
    drain_migrate: bool = True
    # §7.1 SSD tier, cost-aware guard (beyond the paper's proposal): a
    # program sinks to SSD only if reloading its KV from NVMe would beat
    # recomputing it — kv_bytes/ssd_bw < context_tokens/recompute_rate.
    # Both 0 = no guard (sink whenever SSD has room). Small models with
    # fast prefill (7B-class) fail the guard; 70B-class passes it.
    ssd_bytes_per_s: float = 0.0
    recompute_tok_per_s: float = 0.0
    # recompute burns the SHARED prefill pipeline while NVMe reload runs on
    # the transfer queue in parallel: under load a recomputed token costs
    # more than its raw latency in queueing, so reload wins if
    # reload_s < factor * recompute_s. 1.5 is calibrated on the paper's
    # three hardware pairs (benchmarks/ssd_tier.py): it admits 7B
    # (ratio 0.48) and 70B (1.35) where SSD measurably helps and rejects
    # 30B-A3B (1.90) where cheap MoE recompute beats NVMe.
    ssd_guard_factor: float = 1.5


@dataclass
class ProgramMetrics:
    """Per-program accounting used by benchmarks (churn, hit rates)."""

    replica_switches: int = 0
    promotions: int = 0
    demotions: int = 0
    evictions: int = 0
    recomputed_tokens: int = 0
    reloaded_bytes: int = 0
    gated_time_s: float = 0.0
    # offloads aborted mid-flight because the tool call returned before the
    # bytes left the transfer queue (plan/ack protocol, CancelTransfer)
    cancelled_offloads: int = 0


@dataclass
class TransferCost:
    """Cost model terms for KV movement, used by sim and by the real
    engine's transfer queue accounting.

    Rates price *wire bytes* — the bytes of the format actually moved
    (offload-format payload + scale sidecars), not the device-resident
    size, so quantized offload shortens transfers at equal bandwidth."""

    pcie_bytes_per_s: float = 16e9   # effective host<->device per replica
    ssd_bytes_per_s: float = 3.5e9   # NVMe tier (paper §7.1 extension)
    # fixed per-transfer latency (driver/launch); measured ~100us-1ms range
    fixed_latency_s: float = 0.5e-3


@dataclass
class RequestRecord:
    """One inference step of an agentic program (trace schema, paper §6.1).

    ``input_tokens`` is the *full* context length at this step (prefix
    inclusive); ``tool_duration_s`` is the gap that follows this step's
    response. ``reasoning_wall_s`` is the wall-clock inference latency
    observed at collection time (the paper's proxy logs it); ``tool_kind``
    tags the call for trace analysis (read/edit/shell vs test/human/subagent).
    """

    input_tokens: int
    output_tokens: int
    tool_duration_s: float
    reasoning_wall_s: float = 0.0
    tool_kind: str = "shell"


@dataclass
class ProgramTrace:
    """A full agentic program: ordered steps with prefix dependency."""

    program_id: str
    steps: list[RequestRecord] = field(default_factory=list)

    @property
    def num_steps(self) -> int:
        return len(self.steps)

    def total_tool_time(self) -> float:
        return sum(s.tool_duration_s for s in self.steps)

    def final_context(self) -> int:
        if not self.steps:
            return 0
        last = self.steps[-1]
        return last.input_tokens + last.output_tokens
