"""Program-level state tracked by the scheduler (paper §4.1).

For each active agentic program the scheduler maintains: (i) the current
status, (ii) the estimated KV context size, (iii) recent Reasoning/Acting
durations (via :class:`IdlenessTracker`), plus placement bookkeeping
(tier, home replica, typed label) and churn metrics.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.idleness import IdlenessTracker
from repro.core.types import ProgramMetrics, Status, Tier, TypeLabel


@dataclass
class ProgramState:
    program_id: str
    kv_bytes_per_token: int
    context_tokens: int = 0
    tier: Tier = Tier.NONE
    replica: int | None = None          # home replica while GPU/CPU-resident
    # last replica that ever held this program's state; NOT cleared on
    # eviction — the churn metric (paper §6.2.2) compares re-admission
    # targets against it
    home_replica: int | None = None
    label: TypeLabel = TypeLabel.INACTIVE
    tracker: IdlenessTracker = field(default_factory=IdlenessTracker)
    metrics: ProgramMetrics = field(default_factory=ProgramMetrics)
    # tokens whose KV has actually been materialized by a completed step —
    # context_tokens may run ahead of it when a new request's input arrives
    # before the engine has prefilled it, so transfer sizing (Forward.nbytes)
    # uses this, not kv_bytes
    materialized_tokens: int = 0
    # pending request the scheduler is gating (None = no pending work)
    pending_since: float | None = None
    # set once the request was released to the engine; cleared when inference
    # actually starts (prevents double-forwarding a promoted program)
    dispatched: bool = False
    # set when a Reasoning program must be demoted after its current step
    # finishes (paper §4.3.1 "lazy demotion")
    lazy_demote: bool = False
    arrived_at: float = 0.0
    steps_completed: int = 0
    finished: bool = False
    # per-token size of the program's KV *as it crosses a link or sits in a
    # host tier* — differs from ``kv_bytes_per_token`` (the device-resident
    # size) when pages quantize on offload (int8 offload format). None means
    # "same format everywhere" and falls back to the device size, so bf16
    # deployments are byte-identical to the pre-format-layer accounting.
    wire_bytes_per_token: int | None = None

    # ------------------------------------------------------------ properties
    @property
    def status(self) -> Status:
        return self.tracker.status

    @property
    def kv_bytes(self) -> int:
        return self.context_tokens * self.kv_bytes_per_token

    @property
    def host_bytes_per_token(self) -> int:
        """Per-token size in the offload format (what CPU/SSD copies and
        link transfers actually carry)."""
        return (
            self.kv_bytes_per_token
            if self.wire_bytes_per_token is None
            else self.wire_bytes_per_token
        )

    @property
    def host_kv_bytes(self) -> int:
        """Full-context size in the offload format — what the program
        occupies in a host tier (CPU/SSD budget accounting)."""
        return self.context_tokens * self.host_bytes_per_token

    @property
    def materialized_bytes(self) -> int:
        """Bytes of KV that physically exist somewhere (≤ ``kv_bytes``)."""
        return min(self.materialized_tokens, self.context_tokens) * self.kv_bytes_per_token

    @property
    def materialized_wire_bytes(self) -> int:
        """Materialized KV priced at the *offload* format — the bytes a
        transfer of this program actually puts on the wire (offload copies
        carry the host-format payload; reloads move the same bytes back)."""
        return (
            min(self.materialized_tokens, self.context_tokens)
            * self.host_bytes_per_token
        )

    @property
    def has_pending(self) -> bool:
        return self.pending_since is not None

    @property
    def is_new(self) -> bool:
        return self.steps_completed == 0

    def idleness(self, now: float) -> float:
        return self.tracker.idleness(now)

    # ------------------------------------------------------------ transitions
    def begin_reasoning(self, now: float) -> None:
        if self.pending_since is not None:
            self.metrics.gated_time_s += max(0.0, now - self.pending_since)
        self.pending_since = None
        self.dispatched = False
        self.tracker.transition(Status.REASONING, now)

    def begin_acting(self, now: float, new_tokens: int = 0) -> None:
        self.context_tokens += new_tokens
        self.materialized_tokens = self.context_tokens
        self.steps_completed += 1
        self.tracker.transition(Status.ACTING, now)

    def gate(self, now: float) -> None:
        """Request arrived but cannot run: hold it, excluded from idleness."""
        if self.pending_since is None:
            self.pending_since = now
        self.dispatched = False
        self.tracker.transition(Status.GATED, now)

    def set_window(self, k: int) -> None:
        self.tracker = IdlenessTracker(window=k)
