"""Shared transfer-queue model: per-replica PCIe + NVMe copy channels.

One KV movement (an ``Offload``, a reloading ``Forward``, a ``Migrate``)
becomes one :class:`CopyJob` on one :class:`~repro.core.ledger.Channel`.
Jobs on a channel serialize FIFO — the channel is a physical wire — and a
job's duration is ``fixed_latency + nbytes / channel_bandwidth``
(:class:`~repro.core.types.TransferCost`). Completion callbacks fire on
the *runtime's* clock through a caller-supplied ``schedule(eta, fn)``
hook, so the same queue model drives both executors of the plan/ack
protocol:

* the discrete-event simulator schedules straight into its event heap
  (``repro.sim.engine._Replica``), one single-chunk job per transfer —
  the fluid model the paper's evaluation uses;
* the real serving path (``repro.serving.transfer_plane``) splits a job
  into page-granular chunks (``n_chunks``), copying one page per chunk
  tick, which is what lets a :class:`~repro.core.actions.CancelTransfer`
  abort a copy *mid-stream* with only the already-copied pages to roll
  back.

The model is pure control plane: it never touches pages itself. Runtimes
observe job progress through the ``on_start`` / ``on_chunk`` / ``on_done``
callbacks and do their own data movement there.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
from typing import Callable

from repro.core.ledger import Channel, channel_for
from repro.core.types import Tier, TransferCost


@dataclass(frozen=True)
class Endpoint:
    """One side of a KV copy: a tier on a replica."""

    replica: int
    tier: Tier


@dataclass(frozen=True)
class CopyRequest:
    """Endpoint-addressed KV copy — the one shape every transfer-bearing
    action lowers to.

    ``Offload``, reloading ``Forward`` and ``Migrate`` differ only in their
    endpoints (same-replica down-tier, same-replica up-tier, cross-replica
    host-to-host), so executors dispatch on the *geometry* instead of the
    action class: :attr:`kind` and :attr:`channel` are derived, and both
    runtimes bill the channel the bytes are read from. ``nbytes`` sizes the
    wire time; the concrete page set is bound by the executor when the job
    reaches its channel head (a copy queued behind an offload of the same
    program must see the pages that offload is about to produce).
    """

    src: Endpoint
    dst: Endpoint
    pid: str
    nbytes: int
    action_id: int

    @property
    def cross_replica(self) -> bool:
        return self.src.replica != self.dst.replica

    @property
    def kind(self) -> str:
        """Ledger record kind: ``offload`` | ``reload`` | ``migrate``."""
        if self.cross_replica:
            return "migrate"
        return "reload" if self.dst.tier is Tier.GPU else "offload"

    @property
    def channel(self) -> Channel:
        """Bill the channel the bytes are *read* from (writes are staged
        through host DRAM, so the read side is the contended resource)."""
        return channel_for(self.src.tier)

    @property
    def exec_replica(self) -> int:
        """The replica whose channel queues serialize this copy — always
        the receiving side (for a same-replica copy there is only one side;
        a migrate contends on the destination's ingest channel)."""
        return self.dst.replica

    def job(self, payload: object = None) -> CopyJob:
        """Lower to the queued-transfer representation."""
        return CopyJob(
            self.nbytes, self.action_id, self.pid, self.exec_replica,
            self.channel, payload=payload,
        )


def copy_request_for(act) -> CopyRequest:
    """Thin adapter from the action IR to the endpoint-addressed API."""
    from repro.core.actions import Forward, Migrate, Offload

    if isinstance(act, Offload):
        src = Endpoint(act.replica, act.src_tier)
        dst = Endpoint(act.replica, act.dst_tier)
    elif isinstance(act, Forward):
        # only CPU/SSD-sourced Forwards carry bytes; GPU/recompute Forwards
        # never reach a transfer executor
        src = Endpoint(act.replica, act.source_tier)
        dst = Endpoint(act.replica, Tier.GPU)
    elif isinstance(act, Migrate):
        src = Endpoint(act.src_replica, Tier.CPU)
        dst = Endpoint(act.dst_replica, Tier.CPU)
    else:
        raise TypeError(f"{type(act).__name__} carries no bytes to copy")
    return CopyRequest(
        src=src, dst=dst, pid=act.pid, nbytes=act.nbytes,
        action_id=act.action_id,
    )


@dataclass
class CopyJob:
    """One queued KV movement, executing a ledger-tracked action.

    ``n_chunks`` is the streaming granularity: 1 = fluid (the simulator),
    N = page-granular (the real transfer plane). ``payload`` is runtime
    state riding along (the simulator hangs the gated request a reload
    unblocks; the real plane hangs its page-copy stream)."""

    nbytes: int
    action_id: int
    pid: str
    replica: int = 0
    channel: Channel = Channel.PCIE
    n_chunks: int = 1
    payload: object = None
    # progress, owned by the lane
    chunks_done: int = 0
    started: bool = False
    cancelled: bool = False


class _Lane:
    """FIFO of :class:`CopyJob` serialized on one physical channel."""

    def __init__(
        self,
        channel: Channel,
        bytes_per_s: float,
        fixed_latency_s: float,
        schedule: Callable[[float, Callable[[float], None]], None],
        on_done: Callable[[CopyJob, float], None],
        on_start: Callable[[CopyJob, float], None] | None = None,
        on_chunk: Callable[[CopyJob, float], None] | None = None,
    ):
        self.channel = channel
        self.bytes_per_s = bytes_per_s
        self.fixed_latency_s = fixed_latency_s
        self.schedule = schedule
        self.on_done = on_done
        self.on_start = on_start
        self.on_chunk = on_chunk
        self.active: CopyJob | None = None
        self.q: deque[CopyJob] = deque()

    # ------------------------------------------------------------ lifecycle
    def enqueue(self, job: CopyJob, now: float) -> None:
        self.q.append(job)
        if self.active is None:
            self._start_next(now)

    def _start_next(self, now: float) -> None:
        if self.active is not None or not self.q:
            return
        job = self.q.popleft()
        self.active = job
        job.started = True
        if self.on_start is not None:
            self.on_start(job, now)  # may (re)size job.n_chunks
        self._schedule_chunk(job, now)

    def _schedule_chunk(self, job: CopyJob, now: float) -> None:
        per_chunk = job.nbytes / max(1, job.n_chunks) / self.bytes_per_s
        dur = per_chunk + (self.fixed_latency_s if job.chunks_done == 0 else 0.0)
        self.schedule(now + dur, lambda t: self._on_chunk_event(job, t))

    def _on_chunk_event(self, job: CopyJob, now: float) -> None:
        # stale completions are dropped: the job was cancelled mid-stream,
        # or the owning replica failed and the lane was reset
        if self.active is not job or job.cancelled:
            return
        job.chunks_done += 1
        if self.on_chunk is not None:
            self.on_chunk(job, now)
        if job.chunks_done < max(1, job.n_chunks):
            self._schedule_chunk(job, now)
            return
        self.active = None
        self.on_done(job, now)
        self._start_next(now)

    # -------------------------------------------------------- cancellation
    def cancel_queued(self, action_id: int) -> CopyJob | None:
        """Drop a still-queued job (never started: nothing to roll back)."""
        for job in self.q:
            if job.action_id == action_id:
                self.q.remove(job)
                job.cancelled = True
                return job
        return None

    def abort(self, action_id: int, now: float) -> CopyJob | None:
        """Cancel queued *or* abort the active job mid-stream. Returns the
        job (``chunks_done`` tells the runtime how much to roll back) or
        None if the id is not pending on this lane."""
        job = self.cancel_queued(action_id)
        if job is not None:
            return job
        if self.active is not None and self.active.action_id == action_id:
            job, self.active = self.active, None
            job.cancelled = True
            self._start_next(now)
            return job
        return None

    def reset(self) -> None:
        """Replica failure: drop everything; in-flight chunk events go stale."""
        if self.active is not None:
            self.active.cancelled = True
            self.active = None
        for job in self.q:
            job.cancelled = True
        self.q.clear()

    # -------------------------------------------------------------- queries
    def jobs(self) -> list[CopyJob]:
        return ([self.active] if self.active is not None else []) + list(self.q)

    def pending_bytes(self) -> int:
        return sum(j.nbytes for j in self.jobs())


@dataclass
class TransferChannels:
    """The two per-replica copy channels (paper §2.2 PCIe + §7.1 NVMe)."""

    cost: TransferCost
    schedule: Callable[[float, Callable[[float], None]], None]
    on_done: Callable[[CopyJob, float], None]
    on_start: Callable[[CopyJob, float], None] | None = None
    on_chunk: Callable[[CopyJob, float], None] | None = None
    lanes: dict[Channel, _Lane] = field(init=False)

    def __post_init__(self) -> None:
        self.lanes = {
            Channel.PCIE: _Lane(
                Channel.PCIE, self.cost.pcie_bytes_per_s,
                self.cost.fixed_latency_s, self.schedule,
                self.on_done, self.on_start, self.on_chunk,
            ),
            Channel.NVME: _Lane(
                Channel.NVME, self.cost.ssd_bytes_per_s,
                self.cost.fixed_latency_s, self.schedule,
                self.on_done, self.on_start, self.on_chunk,
            ),
        }

    def enqueue(self, job: CopyJob, now: float) -> None:
        self.lanes[job.channel].enqueue(job, now)

    def cancel_queued(self, action_id: int) -> CopyJob | None:
        for lane in self.lanes.values():
            job = lane.cancel_queued(action_id)
            if job is not None:
                return job
        return None

    def abort(self, action_id: int, now: float) -> CopyJob | None:
        for lane in self.lanes.values():
            job = lane.abort(action_id, now)
            if job is not None:
                return job
        return None

    def reset(self) -> None:
        for lane in self.lanes.values():
            lane.reset()

    # -------------------------------------------------------------- queries
    def in_flight(self) -> bool:
        return any(lane.jobs() for lane in self.lanes.values())

    def jobs(self) -> list[CopyJob]:
        return [j for lane in self.lanes.values() for j in lane.jobs()]

    def pending_bytes(self, channel: Channel | None = None) -> int:
        lanes = self.lanes.values() if channel is None else [self.lanes[channel]]
        return sum(lane.pending_bytes() for lane in lanes)
