"""Distribution layer: logical-axis sharding rules + replica placement."""
from repro.dist.placement import ReplicaPlacement, ReplicaSet, make_replica_set
from repro.dist.sharding import (
    LOGICAL_AXES,
    ShardingRules,
    make_decode_rules,
    make_train_rules,
)

__all__ = [
    "LOGICAL_AXES",
    "ReplicaPlacement",
    "ReplicaSet",
    "ShardingRules",
    "make_decode_rules",
    "make_replica_set",
    "make_train_rules",
]
