"""Replica-aware placement: one rules object shared by every replica.

A serving deployment runs N data-parallel *replicas* of the engine, each on
its own slice of the device fleet. The invariants this module enforces:

* every replica gets a mesh of the same shape and axis names, so one
  :class:`~repro.dist.sharding.ShardingRules` object (and therefore one
  compiled executable) is shared across all replicas — a program migrated
  between replicas by the MORI balancer lands on byte-identical layouts;
* replica device groups are disjoint slices of the fleet when enough
  devices exist, and alias the host device(s) otherwise (the CPU test
  path, where N logical replicas share one physical device).

Consumers: ``repro.serving.engine.Engine`` (real JAX engine, one placement
per replica), ``repro.launch.serve`` (builds the set), ``repro.sim``
(replica-count + layout provenance for simulated fleets) and
``examples/quickstart.py``.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from repro.dist.sharding import Axes, ShardingRules, make_decode_rules


@dataclass(frozen=True)
class ReplicaPlacement:
    """One replica's slice of the fleet: its mesh + the shared rules."""

    replica_id: int
    mesh: object
    rules: ShardingRules

    def spec(self, axes: Axes, shape=None):
        return self.rules.spec(self.mesh, axes, shape)

    def sharding(self, axes: Axes, shape=None):
        return self.rules.sharding(self.mesh, axes, shape)


class ReplicaSet:
    """All replicas of one deployment; iterable of :class:`ReplicaPlacement`."""

    def __init__(self, meshes: list, rules: ShardingRules):
        assert meshes, "a replica set needs at least one mesh"
        shape0 = dict(meshes[0].shape)
        for m in meshes[1:]:
            assert dict(m.shape) == shape0, "replica meshes must match"
        self.meshes = meshes
        self.rules = rules

    @property
    def num_replicas(self) -> int:
        return len(self.meshes)

    def placement(self, replica_id: int) -> ReplicaPlacement:
        return ReplicaPlacement(replica_id, self.meshes[replica_id], self.rules)

    def __len__(self) -> int:
        return len(self.meshes)

    def __iter__(self):
        return (self.placement(i) for i in range(len(self.meshes)))


def make_replica_set(
    num_replicas: int,
    *,
    mesh_shape: tuple[int, ...] = (1, 1),
    axis_names: tuple[str, ...] = ("data", "model"),
    devices: list | None = None,
    rules: ShardingRules | None = None,
    num_kv_heads: int = 1,
) -> ReplicaSet:
    """Partition the fleet into ``num_replicas`` same-shape meshes.

    With fewer devices than ``num_replicas * prod(mesh_shape)`` (the CPU
    test path) every replica aliases the first ``prod(mesh_shape)`` host
    devices. ``rules`` defaults to decode rules for ``num_kv_heads`` built
    against the (identical) replica mesh.
    """
    import jax
    from jax.sharding import Mesh

    assert len(mesh_shape) == len(axis_names), (mesh_shape, axis_names)
    devices = list(devices if devices is not None else jax.devices())
    per = int(np.prod(mesh_shape))
    if len(devices) >= num_replicas * per:
        groups = [devices[i * per:(i + 1) * per] for i in range(num_replicas)]
    else:
        assert len(devices) >= per, (
            f"need {per} devices for mesh {mesh_shape}, have {len(devices)}"
        )
        groups = [devices[:per]] * num_replicas
    meshes = [
        Mesh(np.asarray(g, dtype=object).reshape(mesh_shape), axis_names)
        for g in groups
    ]
    if rules is None:
        rules = make_decode_rules(meshes[0], num_kv_heads)
    return ReplicaSet(meshes, rules)
