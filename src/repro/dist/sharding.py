"""Logical-axis sharding rules: the layer between model code and the mesh.

Model code names *logical* axes ("embed", "heads", "batch", ...) on every
parameter (:class:`repro.models.params.Leaf`), activation constraint
(:meth:`repro.models.layers.ShardCtx.constrain`) and cache leaf. A
:class:`ShardingRules` object maps those names to *mesh* axes ("data",
"model", "pod") and resolves the mapping per-shape:

* a logical axis whose mesh axes are absent from the mesh is replicated
  (the same rules run on ``make_host_mesh()`` (1x1 CPU) and
  ``make_production_mesh()`` (16x16 / 2x16x16));
* a dimension that is not divisible by the mesh-axis product falls back to
  replication and is recorded in :attr:`ShardingRules.fallbacks` so the
  dry-run artifact surfaces every silently-replicated tensor (arctic's 56
  q heads on a 16-way model axis is the canonical case — see the
  ``pad_heads`` lever in ``repro.launch.steps``);
* one mesh axis is never used twice within a single PartitionSpec (GSPMD
  rejects it): the earlier dimension wins, the later one replicates.

Two rule sets cover the repo's two regimes:

* :func:`make_train_rules` — FSDP over "data" (parameters shard their
  "embed" dimension), tensor-parallel over "model" (heads / ffn / vocab),
  batch over "pod"+"data"; optional sequence parallelism.
* :func:`make_decode_rules` — pure tensor-parallel weights (replicated over
  "data", so decode batches need no weight collectives), KV-head-sharded
  caches when the head count divides the model axis, batch over
  "pod"+"data".

See docs/architecture.md for the full logical-axis glossary.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from jax.sharding import NamedSharding, PartitionSpec

Axes = tuple[str | None, ...]

#: logical axis -> one-line meaning (the glossary rendered in the docs)
LOGICAL_AXES = {
    # weight axes
    "vocab": "vocabulary rows of the embedding / output head",
    "embed": "model width (d_model) dimension of weight matrices",
    "heads": "flattened q/kv head projection columns (h * head_dim)",
    "ffn": "dense FFN hidden dimension",
    "experts": "MoE expert index",
    "expert_ffn": "per-expert FFN hidden dimension",
    "ssm_heads": "mamba2 inner / head projection columns",
    "layers": "stacked-layer leading dim of scanned blocks (never sharded)",
    "conv": "ssm depthwise-conv tap dim (never sharded)",
    # activation / cache axes
    "batch": "global batch rows",
    "seq": "sequence positions (sharded only under sequence parallelism)",
    "kv_seq": "cache slot positions",
    "head_dim": "per-head feature dim (never sharded)",
    "embed_act": "activation width",
    "heads_act": "activation attention heads",
    "kv_heads_act": "activation / cache KV heads",
    "ffn_act": "activation FFN hidden",
    "experts_act": "activation expert dim of MoE dispatch",
    "ssm_heads_act": "activation / cache SSM heads",
    "vocab_act": "activation logits vocabulary",
}


@dataclass
class ShardingRules:
    """Logical-axis -> mesh-axis mapping with divisibility-aware fallback.

    ``rules`` maps each logical axis to an ordered tuple of *candidate* mesh
    axes; resolution keeps the longest prefix of candidates that (a) exist
    in the mesh, (b) are not already used by an earlier dimension of the
    same spec, and (c) whose size product divides the dimension. An empty
    tuple (or a missing key) means "always replicate".
    """

    rules: dict[str, tuple[str, ...]]
    #: (logical_axis, mesh_axes, dim) triples that lost sharding to a
    #: divisibility or double-use fallback (deduplicated; surfaced by
    #: ``repro.launch.dryrun`` as the "fallbacks" artifact field).
    fallbacks: list[tuple[str, str, int]] = field(default_factory=list)

    # ------------------------------------------------------------ resolve
    def spec(self, mesh, axes: Axes, shape: tuple[int, ...] | None = None
             ) -> PartitionSpec:
        """PartitionSpec for one array. ``shape=None`` skips divisibility
        checks (used for specs built before shapes are known)."""
        sizes = dict(mesh.shape)
        used: set[str] = set()
        out: list[None | str | tuple[str, ...]] = []
        for i, logical in enumerate(axes):
            cand = self.rules.get(logical) if logical is not None else None
            if not cand:
                out.append(None)
                continue
            picked = [m for m in cand if m in sizes and m not in used]
            dim = None if shape is None else shape[i]
            if dim is not None:
                # drop trailing candidates until the product divides the dim
                while picked and dim % _prod(sizes[m] for m in picked):
                    picked.pop()
            if _prod(sizes[m] for m in picked) <= 1:
                # nothing actually sharded: replicate, and record the loss
                # when the rule *wanted* a >1-way mesh axis for this dim
                wanted = [m for m in cand if sizes.get(m, 1) > 1]
                if wanted and dim is not None:
                    self._record(logical, "+".join(wanted), dim)
                out.append(None)
                continue
            used.update(picked)
            out.append(picked[0] if len(picked) == 1 else tuple(picked))
        return PartitionSpec(*out)

    def sharding(self, mesh, axes: Axes, shape: tuple[int, ...] | None = None
                 ) -> NamedSharding:
        """NamedSharding for one array on ``mesh`` (see :meth:`spec`)."""
        return NamedSharding(mesh, self.spec(mesh, axes, shape))

    def _record(self, logical: str, mesh_axes: str, dim: int) -> None:
        entry = (logical, mesh_axes, int(dim))
        if entry not in self.fallbacks:
            self.fallbacks.append(entry)


def _prod(it) -> int:
    p = 1
    for v in it:
        p *= v
    return p


# ------------------------------------------------------------------ rule sets
def make_train_rules(mesh, *, sequence_parallel: bool = False) -> ShardingRules:
    """FSDP + tensor-parallel training rules.

    Parameters shard their width ("embed") over the "data" axis (FSDP) and
    their hidden/head dims over "model" (TP); MoE experts take the "pod"
    axis when present (expert parallelism across pods). Activations keep
    batch over "pod"+"data" and the TP'd hidden dims over "model";
    ``sequence_parallel`` additionally shards the sequence dimension of
    activations over "model" (norm/residual regions where the hidden dim is
    unsharded).

    ``mesh`` is part of the rule-set contract (``make_decode_rules`` needs
    it for the KV divisibility check, and callers build both the same way)
    but train rules are mesh-independent: resolution against the mesh
    happens per-array in :meth:`ShardingRules.spec`.
    """
    del mesh
    return ShardingRules({
        # weights
        "vocab": ("model",),
        "embed": ("data",),
        "heads": ("model",),
        "ffn": ("model",),
        "experts": ("pod",),
        "expert_ffn": ("model",),
        "ssm_heads": ("model",),
        # activations / caches
        "batch": ("pod", "data"),
        "seq": ("model",) if sequence_parallel else (),
        "heads_act": ("model",),
        "kv_heads_act": ("model",),
        "ffn_act": ("model",),
        "experts_act": ("model",),
        "ssm_heads_act": ("model",),
        "vocab_act": ("model",),
    })


def make_decode_rules(mesh, num_kv_heads: int) -> ShardingRules:
    """KV-head tensor-parallel decode/prefill rules.

    Weights are replicated over "data" (every decode replica in the data
    dimension holds full weights — no per-step weight collectives) and
    sharded over "model"; the KV cache shards its head dimension over
    "model" only when ``num_kv_heads`` divides the model-axis size, else
    the cache replicates (recorded as a fallback) — partial-head cache
    shards would corrupt decode_attention's per-head softmax.
    """
    tp = dict(mesh.shape).get("model", 1)
    kv_ok = tp <= 1 or num_kv_heads % tp == 0
    rules = ShardingRules({
        # weights
        "vocab": ("model",),
        "heads": ("model",),
        "ffn": ("model",),
        "expert_ffn": ("model",),
        "ssm_heads": ("model",),
        # activations / caches
        "batch": ("pod", "data"),
        "heads_act": ("model",),
        "kv_heads_act": ("model",) if kv_ok else (),
        "ffn_act": ("model",),
        "experts_act": ("model",),
        "ssm_heads_act": ("model",),
        "vocab_act": ("model",),
    })
    if not kv_ok:
        rules._record("kv_heads_act", "model", num_kv_heads)
    return rules
