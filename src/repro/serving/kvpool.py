"""Two-tier paged KV storage: device pages (HBM) + host pages (DRAM).

Layout ``[L, n_pages, page_tokens, KH, HD]`` for K and V — the trailing
(page_tokens, head_dim) tile is what the Pallas paged-attention kernel
consumes per grid step. Host pages are numpy arrays (on a real TPU host:
pinned DRAM reached via ``jax.device_get/put``; in this CPU container the
transfer mechanics — block granularity, explicit copies, byte accounting —
are identical, only the wire is missing).

Since the block-table decode path landed, the pool **is** the decode
state: :meth:`block_table_view` hands ``(k, v)`` straight to
``Model.decode_paged`` / the Pallas ``paged_attention`` kernel, the
engine's jitted (donated) step appends each new token's KV into the tail
pages in one batched scatter, and :meth:`adopt` installs the updated
arrays back. No dense per-slot copy of any page ever exists.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.analysis import kvsan


def scatter_token_run(k_arr, v_arr, page_idx, k_tokens, v_tokens, page_tokens):
    """Scatter a token run ``[L, S, KH, HD]`` into pool pages in ONE
    functional update (pure; jit-safe, so the engine's chunked-prefill step
    can run it under donation for an in-place pool write). ``page_idx``
    receives consecutive ``page_tokens``-sized chunks; a partial tail is
    zero-padded. Returns the updated ``(k_arr, v_arr)``."""
    T = page_tokens
    L, S, KH, HD = k_tokens.shape
    n = len(page_idx) if isinstance(page_idx, list) else page_idx.shape[0]
    pad = n * T - S
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_tokens = jnp.pad(k_tokens, widths)
        v_tokens = jnp.pad(v_tokens, widths)
    idx = jnp.asarray(page_idx, jnp.int32)
    kc = k_tokens.reshape(L, n, T, KH, HD).astype(k_arr.dtype)
    vc = v_tokens.reshape(L, n, T, KH, HD).astype(v_arr.dtype)
    return k_arr.at[:, idx].set(kc), v_arr.at[:, idx].set(vc)


def gather_token_run(k_arr, v_arr, page_idx):
    """Gather pages -> ``[L, n*page_tokens, KH, HD]`` (pure; jit-safe twin
    of :meth:`PagePool.read_device_pages`)."""
    idx = jnp.asarray(page_idx, jnp.int32)
    k = k_arr[:, idx]                                           # [L,n,t,KH,HD]
    v = v_arr[:, idx]
    L, n, t, KH, HD = k.shape
    return k.reshape(L, n * t, KH, HD), v.reshape(L, n * t, KH, HD)


@dataclass
class PoolStats:
    device_free: int
    device_total: int
    host_free: int
    host_total: int
    offload_bytes: int = 0
    reload_bytes: int = 0


class PagePool:
    def __init__(
        self,
        *,
        layers: int,
        kv_heads: int,
        head_dim: int,
        page_tokens: int,
        n_device_pages: int,
        n_host_pages: int,
        dtype=jnp.bfloat16,
    ):
        self.layers = layers
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.page_tokens = page_tokens
        self.dtype = dtype
        shape = (layers, n_device_pages, page_tokens, kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        hshape = (layers, n_host_pages, page_tokens, kv_heads, head_dim)
        # host pages hold the *raw bits* of the device dtype (bf16 -> uint16
        # view): an offload→reload round trip must be bit-exact. The old
        # float16 staging was lossy — bf16's exponent range overflows fp16
        # to inf, silently corrupting large-magnitude KV on reload.
        self._raw_bits = dtype != jnp.float32
        hdt = np.uint16 if self._raw_bits else np.float32
        self.host_k = np.zeros(hshape, hdt)
        self.host_v = np.zeros_like(self.host_k)
        self._free_dev = list(range(n_device_pages))
        self._free_host = list(range(n_host_pages))
        self.n_device_pages = n_device_pages
        self.n_host_pages = n_host_pages
        self.offload_bytes = 0
        self.reload_bytes = 0
        # page-lifetime sanitizer (None unless REPRO_KVSAN=1): every
        # alloc/free/read/write verb below reports to it
        self._san = kvsan.maybe_sanitizer(
            n_device_pages=n_device_pages,
            n_host_pages=n_host_pages,
            page_tokens=page_tokens,
        )
        if self._san is not None:
            self._san.pool = self

    @property
    def page_bytes(self) -> int:
        return self.layers * self.page_tokens * self.kv_heads * self.head_dim * 2 * 2

    # ---------------------------------------------------------- allocation
    def device_free_count(self) -> int:
        return len(self._free_dev)

    def host_free_count(self) -> int:
        return len(self._free_host)

    def alloc_device(self) -> int | None:
        page = self._free_dev.pop() if self._free_dev else None
        if page is not None and self._san is not None:
            self._san.on_alloc("dev", page)
        return page

    def alloc_host(self) -> int | None:
        page = self._free_host.pop() if self._free_host else None
        if page is not None and self._san is not None:
            self._san.on_alloc("host", page)
        return page

    def free_device(self, page: int) -> None:
        if self._san is not None:
            self._san.on_free("dev", page)
        self._free_dev.append(page)

    def free_host(self, page: int) -> None:
        if self._san is not None:
            self._san.on_free("host", page)
        self._free_host.append(page)

    # -------------------------------------------------------------- writes
    def block_table_view(self):
        """The pool's device arrays ``(k, v)``, each
        ``[L, n_pages, page_tokens, KH, HD]`` — the operand the block-table
        decode path (``Model.decode_paged`` -> Pallas ``paged_attention``)
        consumes directly. This is a zero-copy handle, not a gather: block
        tables index into these arrays page by page."""
        return self.k, self.v

    def adopt(self, k, v) -> None:
        """Install functionally-updated page arrays (same shapes/dtypes).

        The engine's jitted decode step takes :meth:`block_table_view`,
        appends the new tokens' KV into tail pages, and returns fresh
        arrays (with donation the update is in-place on the device); this
        re-points the pool at them. Page *ids* are stable across adopt —
        only tail-page contents changed — so host copies, free lists and
        in-flight transfer staging stay valid."""
        assert k.shape == self.k.shape and v.shape == self.v.shape
        self.k, self.v = k, v

    def append_token(self, page: int, offset: int, k_tok, v_tok) -> None:
        """Write one token's KV (``[L, KH, HD]``) into ``page`` at
        ``offset`` — the host-side append-to-tail-page verb. The hot decode
        path appends *inside* jit (``Model.decode_paged`` commits all
        layers in one batched scatter); this method serves tests and
        host-driven fixups."""
        if self._san is not None:
            self._san.on_append("dev", page, offset)
        self.k = self.k.at[:, page, offset].set(k_tok.astype(self.k.dtype))
        self.v = self.v.at[:, page, offset].set(v_tok.astype(self.v.dtype))

    def write_device_page(self, page: int, k_tokens, v_tokens) -> None:
        """k_tokens/v_tokens: [L, t<=page_tokens, KH, HD]."""
        if self._san is not None:
            self._san.on_write("dev", page)
        t = k_tokens.shape[1]
        self.k = self.k.at[:, page, :t].set(k_tokens.astype(self.k.dtype))
        self.v = self.v.at[:, page, :t].set(v_tokens.astype(self.v.dtype))

    def write_device_pages(self, pages: list[int], k_tokens, v_tokens) -> None:
        """Write a token run spanning several pages in ONE scatter.

        k_tokens/v_tokens: ``[L, S, KH, HD]`` with the run starting at a
        page boundary; ``pages`` receive consecutive ``page_tokens``-sized
        chunks (the last may be partial — it is zero-padded). One scatter
        = one functional pool update, instead of a full-pool copy per page
        (the prefill-into-pages hot path in ``Engine.submit``).
        """
        if not pages:
            return
        if self._san is not None:
            for page in pages:
                self._san.on_write("dev", page)
        self.k, self.v = scatter_token_run(
            self.k, self.v, pages, k_tokens, v_tokens, self.page_tokens
        )

    def read_device_pages(self, pages: list[int]):
        """Gather pages -> [L, n*page_tokens, KH, HD] (slot assembly)."""
        if self._san is not None:
            for page in pages:
                self._san.on_read("dev", page)
        return gather_token_run(self.k, self.v, pages)

    # ----------------------------------------------------------- transfers
    def _encode_host(self, dev_arr) -> np.ndarray:
        """Device page -> host representation (bit-preserving)."""
        a = np.asarray(dev_arr)
        return a.view(np.uint16) if self._raw_bits else a.astype(np.float32)

    def _decode_host(self, host_arr) -> np.ndarray:
        """Host representation -> array reinterpretable as the device dtype."""
        a = np.ascontiguousarray(host_arr)
        return a.view(np.dtype(self.dtype)) if self._raw_bits else a

    def copy_page_to_host(self, dev_page: int) -> int | None:
        """Stage one device page into a host page *without* freeing the
        device copy — the streamed-offload primitive: the source stays
        valid until the whole transfer commits, which is what makes a
        mid-stream CancelTransfer a pure rollback of host pages.

        Deliberately does NOT bill ``offload_bytes``: staging is
        speculative, and a cancelled transfer must leave no round-trip
        trace in :class:`PoolStats`. The committing caller bills via
        :meth:`bill_offload` (the atomic verbs below do it themselves)."""
        if self._san is not None:
            self._san.on_read("dev", dev_page)
        hp = self.alloc_host()
        if hp is None:
            return None
        if self._san is not None:
            self._san.on_write("host", hp)
        self.host_k[:, hp] = self._encode_host(self.k[:, dev_page])
        self.host_v[:, hp] = self._encode_host(self.v[:, dev_page])
        return hp

    def copy_page_to_device(self, host_page: int) -> int | None:
        """Stage one host page into a device page *without* freeing the
        host copy (streamed-reload primitive, mirror of the above)."""
        if self._san is not None:
            self._san.on_read("host", host_page)
        dp = self.alloc_device()
        if dp is None:
            return None
        if self._san is not None:
            self._san.on_write("dev", dp)
        self.k = self.k.at[:, dp].set(
            jnp.asarray(self._decode_host(self.host_k[:, host_page]), self.k.dtype)
        )
        self.v = self.v.at[:, dp].set(
            jnp.asarray(self._decode_host(self.host_v[:, host_page]), self.v.dtype)
        )
        return dp

    def import_host_page(self, src_pool: "PagePool", src_hp: int) -> int | None:
        """Copy one host page from *another replica's* pool into this pool's
        host tier — the cross-replica migrate primitive (dst-host ←
        src-host). The copy is raw-bits, so the destination KV is
        byte-identical to the source; like the staging verbs above it is
        copy-without-free and unbilled — the committing migrate stream
        frees the source copy and the router does the accounting."""
        same_geometry = (
            self.host_k.shape[0] == src_pool.host_k.shape[0]
            and self.host_k.shape[2:] == src_pool.host_k.shape[2:]
            and self.host_k.dtype == src_pool.host_k.dtype
        )
        assert same_geometry, "incompatible page geometry across replicas"
        if src_pool._san is not None:
            src_pool._san.on_read("host", src_hp)
        hp = self.alloc_host()
        if hp is None:
            return None
        if self._san is not None:
            self._san.on_write("host", hp)
        self.host_k[:, hp] = src_pool.host_k[:, src_hp]
        self.host_v[:, hp] = src_pool.host_v[:, src_hp]
        return hp

    def bill_offload(self, pages: int = 1) -> None:
        """Record ``pages`` worth of committed device→host movement."""
        self.offload_bytes += pages * self.page_bytes

    def bill_reload(self, pages: int = 1) -> None:
        """Record ``pages`` worth of committed host→device movement."""
        self.reload_bytes += pages * self.page_bytes

    def offload_page(self, dev_page: int) -> int | None:
        """Device -> host (atomic copy+free). Returns host page id."""
        hp = self.copy_page_to_host(dev_page)
        if hp is None:
            return None
        self.free_device(dev_page)
        self.bill_offload()
        return hp

    def reload_page(self, host_page: int) -> int | None:
        """Host -> device (atomic copy+free). Returns device page id."""
        dp = self.copy_page_to_device(host_page)
        if dp is None:
            return None
        self.free_host(host_page)
        self.bill_reload()
        return dp

    def stats(self) -> PoolStats:
        return PoolStats(
            device_free=len(self._free_dev),
            device_total=self.n_device_pages,
            host_free=len(self._free_host),
            host_total=self.n_host_pages,
            offload_bytes=self.offload_bytes,
            reload_bytes=self.reload_bytes,
        )


