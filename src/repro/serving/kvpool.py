"""Two-tier paged KV storage: device pages (HBM) + host pages (DRAM).

Layout ``[L, n_pages, page_tokens, KH, HD]`` for K and V — the trailing
(page_tokens, head_dim) tile is what the Pallas paged-attention kernel
consumes per grid step. Host pages are numpy arrays (on a real TPU host:
pinned DRAM reached via ``jax.device_get/put``; in this CPU container the
transfer mechanics — block granularity, explicit copies, byte accounting —
are identical, only the wire is missing).

Since the block-table decode path landed, the pool **is** the decode
state: :meth:`block_table_view` hands ``(k, v)`` straight to
``Model.decode_paged`` / the Pallas ``paged_attention`` kernel, the
engine's jitted (donated) step appends each new token's KV into the tail
pages in one batched scatter, and :meth:`adopt` installs the updated
arrays back. No dense per-slot copy of any page ever exists.

**Tier formats.** Each tier declares a page format from
``repro.kernels.kv_quant.PAGE_FORMATS``:

* ``offload_format`` — what host/NVMe copies carry. ``"bf16"`` (default)
  stages the raw device bits through a uint16 view, so round trips are
  bit-exact. ``"int8"`` quantizes on offload (one fp32 scale per
  (layer, page) for K and for V in the ``host_*_scale`` sidecars) and
  halves every wire byte the placement plane prices.
* ``device_format`` — what the resident pool itself holds. ``"int8"``
  packs HBM too (payload int8 + ``k_scale``/``v_scale`` sidecars), so the
  same HBM budget holds ~2x the pages; the attention kernel dequantizes
  in its gather. Requires ``offload_format="int8"`` — re-inflating a
  quantized page on offload would invent bytes that carry no information.

Format is *placement state*, not a kernel detail: :attr:`page_bytes`
(device-resident footprint) and :attr:`host_page_bytes` (wire/offload
footprint) are the only numbers billing and tier budgets may use, and
every verb that writes a page in a given format reports the transition to
KVSAN (``on_format``).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.analysis import kvsan
from repro.kernels import kv_quant


def scatter_token_run(k_arr, v_arr, page_idx, k_tokens, v_tokens, page_tokens):
    """Scatter a token run ``[L, S, KH, HD]`` into pool pages in ONE
    functional update (pure; jit-safe, so the engine's chunked-prefill step
    can run it under donation for an in-place pool write). ``page_idx``
    receives consecutive ``page_tokens``-sized chunks; a partial tail is
    zero-padded. Returns the updated ``(k_arr, v_arr)``."""
    T = page_tokens
    L, S, KH, HD = k_tokens.shape
    n = len(page_idx) if isinstance(page_idx, list) else page_idx.shape[0]
    pad = n * T - S
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_tokens = jnp.pad(k_tokens, widths)
        v_tokens = jnp.pad(v_tokens, widths)
    idx = jnp.asarray(page_idx, jnp.int32)
    kc = k_tokens.reshape(L, n, T, KH, HD).astype(k_arr.dtype)
    vc = v_tokens.reshape(L, n, T, KH, HD).astype(v_arr.dtype)
    return k_arr.at[:, idx].set(kc), v_arr.at[:, idx].set(vc)


def gather_token_run(k_arr, v_arr, page_idx):
    """Gather pages -> ``[L, n*page_tokens, KH, HD]`` (pure; jit-safe twin
    of :meth:`PagePool.read_device_pages`)."""
    idx = jnp.asarray(page_idx, jnp.int32)
    k = k_arr[:, idx]                                           # [L,n,t,KH,HD]
    v = v_arr[:, idx]
    L, n, t, KH, HD = k.shape
    return k.reshape(L, n * t, KH, HD), v.reshape(L, n * t, KH, HD)


def scatter_token_run_q(
    k_arr, k_scale, v_arr, v_scale, page_idx, k_tokens, v_tokens, page_tokens
):
    """Quantizing twin of :func:`scatter_token_run` for an int8-resident
    pool: the incoming run is split into pages, each page quantized with
    its own scale, and payload + sidecars land in one scatter apiece.
    Returns ``(k_arr, k_scale, v_arr, v_scale)`` (pure; jit-safe)."""
    T = page_tokens
    L, S, KH, HD = k_tokens.shape
    n = len(page_idx) if isinstance(page_idx, list) else page_idx.shape[0]
    pad = n * T - S
    if pad:
        widths = ((0, 0), (0, pad), (0, 0), (0, 0))
        k_tokens = jnp.pad(k_tokens, widths)
        v_tokens = jnp.pad(v_tokens, widths)
    idx = jnp.asarray(page_idx, jnp.int32)
    kq, ks = kv_quant.quantize_pages(k_tokens.reshape(L, n, T, KH, HD))
    vq, vs = kv_quant.quantize_pages(v_tokens.reshape(L, n, T, KH, HD))
    return (
        k_arr.at[:, idx].set(kq),
        k_scale.at[:, idx].set(ks),
        v_arr.at[:, idx].set(vq),
        v_scale.at[:, idx].set(vs),
    )


def gather_token_run_q(k_arr, k_scale, v_arr, v_scale, page_idx, dtype):
    """Dequantizing twin of :func:`gather_token_run`: gathers int8 pages +
    scale sidecars and returns ``[L, n*page_tokens, KH, HD]`` in the
    logical ``dtype`` (pure; jit-safe)."""
    idx = jnp.asarray(page_idx, jnp.int32)
    k = kv_quant.dequantize_pages(k_arr[:, idx], k_scale[:, idx], dtype)
    v = kv_quant.dequantize_pages(v_arr[:, idx], v_scale[:, idx], dtype)
    L, n, t, KH, HD = k.shape
    return k.reshape(L, n * t, KH, HD), v.reshape(L, n * t, KH, HD)


@dataclass
class PoolStats:
    device_free: int
    device_total: int
    host_free: int
    host_total: int
    offload_bytes: int = 0
    reload_bytes: int = 0


class PagePool:
    def __init__(
        self,
        *,
        layers: int,
        kv_heads: int,
        head_dim: int,
        page_tokens: int,
        n_device_pages: int,
        n_host_pages: int,
        dtype=jnp.bfloat16,
        offload_format: str = "bf16",
        device_format: str = "bf16",
    ):
        self.layers = layers
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.page_tokens = page_tokens
        self.dtype = dtype
        self.offload_format = kv_quant.check_format(offload_format)
        self.device_format = kv_quant.check_format(device_format)
        if self.device_format == "int8" and self.offload_format != "int8":
            raise ValueError(
                "device_format='int8' requires offload_format='int8': a "
                "quantized resident page carries no extra bits a bf16 host "
                "copy could preserve"
            )
        self.quantized_device = self.device_format == "int8"
        shape = (layers, n_device_pages, page_tokens, kv_heads, head_dim)
        if self.quantized_device:
            self.k = jnp.zeros(shape, jnp.int8)
            self.v = jnp.zeros(shape, jnp.int8)
            # per-(layer, page) fp32 scale sidecars; 1.0 on a zero page is
            # as good as any scale (payload 0 dequantizes to 0)
            self.k_scale = jnp.ones((layers, n_device_pages), jnp.float32)
            self.v_scale = jnp.ones((layers, n_device_pages), jnp.float32)
        else:
            self.k = jnp.zeros(shape, dtype)
            self.v = jnp.zeros(shape, dtype)
            self.k_scale = None
            self.v_scale = None
        hshape = (layers, n_host_pages, page_tokens, kv_heads, head_dim)
        # host pages hold either the *raw bits* of the device dtype (bf16 ->
        # uint16 view: an offload→reload round trip must be bit-exact; the
        # old float16 staging was lossy — bf16's exponent range overflows
        # fp16 to inf) or, under offload_format="int8", the quantized
        # payload plus fp32 scale sidecars.
        self._raw_bits = dtype != jnp.float32
        if self.offload_format == "int8":
            self.host_k = np.zeros(hshape, np.int8)
            self.host_v = np.zeros_like(self.host_k)
            self.host_k_scale = np.ones((layers, n_host_pages), np.float32)
            self.host_v_scale = np.ones((layers, n_host_pages), np.float32)
        else:
            hdt = np.uint16 if self._raw_bits else np.float32
            self.host_k = np.zeros(hshape, hdt)
            self.host_v = np.zeros_like(self.host_k)
            self.host_k_scale = None
            self.host_v_scale = None
        self._free_dev = list(range(n_device_pages))
        self._free_host = list(range(n_host_pages))
        self.n_device_pages = n_device_pages
        self.n_host_pages = n_host_pages
        self.offload_bytes = 0
        self.reload_bytes = 0
        # page-lifetime sanitizer (None unless REPRO_KVSAN=1): every
        # alloc/free/read/write verb below reports to it
        self._san = kvsan.maybe_sanitizer(
            n_device_pages=n_device_pages,
            n_host_pages=n_host_pages,
            page_tokens=page_tokens,
        )
        if self._san is not None:
            self._san.pool = self

    @property
    def page_bytes(self) -> int:
        """Device-resident bytes per page (in :attr:`device_format`) —
        the number HBM budgets are priced in."""
        return kv_quant.page_wire_bytes(
            self.layers, self.page_tokens, self.kv_heads, self.head_dim,
            self.device_format,
        )

    @property
    def host_page_bytes(self) -> int:
        """Bytes per page as moved/held on host tiers (in
        :attr:`offload_format`) — the number every transfer and DRAM/NVMe
        budget is priced in."""
        return kv_quant.page_wire_bytes(
            self.layers, self.page_tokens, self.kv_heads, self.head_dim,
            self.offload_format,
        )

    def _fmt_event(self, tier: str, page: int, fmt: str) -> None:
        if self._san is not None:
            self._san.on_format(tier, page, fmt)

    # ---------------------------------------------------------- allocation
    def device_free_count(self) -> int:
        return len(self._free_dev)

    def host_free_count(self) -> int:
        return len(self._free_host)

    def alloc_device(self) -> int | None:
        page = self._free_dev.pop() if self._free_dev else None
        if page is not None and self._san is not None:
            self._san.on_alloc("dev", page)
        return page

    def alloc_host(self) -> int | None:
        page = self._free_host.pop() if self._free_host else None
        if page is not None and self._san is not None:
            self._san.on_alloc("host", page)
        return page

    def free_device(self, page: int) -> None:
        if self._san is not None:
            self._san.on_free("dev", page)
        self._free_dev.append(page)

    def free_host(self, page: int) -> None:
        if self._san is not None:
            self._san.on_free("host", page)
        self._free_host.append(page)

    # -------------------------------------------------------------- writes
    def block_table_view(self):
        """The pool's device arrays ``(k, v)``, each
        ``[L, n_pages, page_tokens, KH, HD]`` — the operand the block-table
        decode path (``Model.decode_paged`` -> Pallas ``paged_attention``)
        consumes directly. This is a zero-copy handle, not a gather: block
        tables index into these arrays page by page. On an int8-resident
        pool the arrays are the quantized payload; :meth:`scale_view`
        hands out the sidecars the kernel dequantizes with."""
        return self.k, self.v

    def scale_view(self):
        """The per-(layer, page) fp32 scale sidecars ``(k_scale, v_scale)``
        (each ``[L, n_pages]``) on an int8-resident pool; ``(None, None)``
        on a bf16 pool — callers thread the pair straight through to the
        attention ops, which treat ``None`` as "no dequant"."""
        return self.k_scale, self.v_scale

    def adopt(self, k, v, k_scale=None, v_scale=None) -> None:
        """Install functionally-updated page arrays (same shapes/dtypes).

        The engine's jitted decode step takes :meth:`block_table_view`,
        appends the new tokens' KV into tail pages, and returns fresh
        arrays (with donation the update is in-place on the device); this
        re-points the pool at them. Page *ids* are stable across adopt —
        only tail-page contents changed — so host copies, free lists and
        in-flight transfer staging stay valid. An int8-resident pool's
        step also rewrites tail-page scales, so it must adopt the scale
        sidecars along with the payload."""
        assert k.shape == self.k.shape and v.shape == self.v.shape
        self.k, self.v = k, v
        if self.quantized_device:
            assert k_scale is not None and v_scale is not None, (
                "int8-resident pool: adopt() needs the updated scale sidecars"
            )
            assert k_scale.shape == self.k_scale.shape
            self.k_scale, self.v_scale = k_scale, v_scale

    def append_token(self, page: int, offset: int, k_tok, v_tok) -> None:
        """Write one token's KV (``[L, KH, HD]``) into ``page`` at
        ``offset`` — the host-side append-to-tail-page verb. The hot decode
        path appends *inside* jit (``Model.decode_paged`` commits all
        layers in one batched scatter); this method serves tests and
        host-driven fixups. On an int8 pool the touched page is
        requantized (its scale may grow to admit the new token)."""
        if self._san is not None:
            self._san.on_append("dev", page, offset)
        if self.quantized_device:
            idx = jnp.asarray([page], jnp.int32)
            off = jnp.asarray([offset], jnp.int32)
            self.k, self.k_scale = kv_quant.requantize_insert_run(
                self.k, self.k_scale, idx, off, k_tok[:, None]
            )
            self.v, self.v_scale = kv_quant.requantize_insert_run(
                self.v, self.v_scale, idx, off, v_tok[:, None]
            )
            return
        self.k = self.k.at[:, page, offset].set(k_tok.astype(self.k.dtype))
        self.v = self.v.at[:, page, offset].set(v_tok.astype(self.v.dtype))

    def write_device_page(self, page: int, k_tokens, v_tokens) -> None:
        """k_tokens/v_tokens: [L, t<=page_tokens, KH, HD]."""
        if self._san is not None:
            self._san.on_write("dev", page)
        self._fmt_event("dev", page, self.device_format)
        t = k_tokens.shape[1]
        if self.quantized_device:
            # rebuild the full page in f32 (existing tail content survives a
            # partial write), then requantize with a fresh per-page scale
            kf = kv_quant.dequantize_pages(
                self.k[:, page][:, None], self.k_scale[:, page][:, None],
                jnp.float32,
            )[:, 0]
            vf = kv_quant.dequantize_pages(
                self.v[:, page][:, None], self.v_scale[:, page][:, None],
                jnp.float32,
            )[:, 0]
            kf = kf.at[:, :t].set(k_tokens.astype(jnp.float32))
            vf = vf.at[:, :t].set(v_tokens.astype(jnp.float32))
            kq, ks = kv_quant.quantize_pages(kf)
            vq, vs = kv_quant.quantize_pages(vf)
            self.k = self.k.at[:, page].set(kq)
            self.v = self.v.at[:, page].set(vq)
            self.k_scale = self.k_scale.at[:, page].set(ks)
            self.v_scale = self.v_scale.at[:, page].set(vs)
            return
        self.k = self.k.at[:, page, :t].set(k_tokens.astype(self.k.dtype))
        self.v = self.v.at[:, page, :t].set(v_tokens.astype(self.v.dtype))

    def write_device_pages(self, pages: list[int], k_tokens, v_tokens) -> None:
        """Write a token run spanning several pages in ONE scatter.

        k_tokens/v_tokens: ``[L, S, KH, HD]`` with the run starting at a
        page boundary; ``pages`` receive consecutive ``page_tokens``-sized
        chunks (the last may be partial — it is zero-padded). One scatter
        = one functional pool update, instead of a full-pool copy per page
        (the prefill-into-pages hot path in ``Engine.submit``).
        """
        if not pages:
            return
        if self._san is not None:
            for page in pages:
                self._san.on_write("dev", page)
                self._san.on_format("dev", page, self.device_format)
        if self.quantized_device:
            self.k, self.k_scale, self.v, self.v_scale = scatter_token_run_q(
                self.k, self.k_scale, self.v, self.v_scale,
                pages, k_tokens, v_tokens, self.page_tokens,
            )
            return
        self.k, self.v = scatter_token_run(
            self.k, self.v, pages, k_tokens, v_tokens, self.page_tokens
        )

    def read_device_pages(self, pages: list[int]):
        """Gather pages -> [L, n*page_tokens, KH, HD] (slot assembly),
        dequantized to the logical dtype on an int8 pool."""
        if self._san is not None:
            for page in pages:
                self._san.on_read("dev", page)
        if self.quantized_device:
            return gather_token_run_q(
                self.k, self.k_scale, self.v, self.v_scale, pages, self.dtype
            )
        return gather_token_run(self.k, self.v, pages)

    # ----------------------------------------------------------- transfers
    def _encode_host(self, dev_arr) -> np.ndarray:
        """Device page -> host representation (bit-preserving)."""
        a = np.asarray(dev_arr)
        return a.view(np.uint16) if self._raw_bits else a.astype(np.float32)

    def _decode_host(self, host_arr) -> np.ndarray:
        """Host representation -> array reinterpretable as the device dtype."""
        a = np.ascontiguousarray(host_arr)
        return a.view(np.dtype(self.dtype)) if self._raw_bits else a

    def copy_page_to_host(self, dev_page: int) -> int | None:
        """Stage one device page into a host page *without* freeing the
        device copy — the streamed-offload primitive: the source stays
        valid until the whole transfer commits, which is what makes a
        mid-stream CancelTransfer a pure rollback of host pages.

        The host copy carries :attr:`offload_format`: bf16 stages raw
        bits, int8 quantizes here (or, from an int8-resident pool, copies
        payload + scales verbatim — already-quantized pages round-trip
        byte-identically).

        Deliberately does NOT bill ``offload_bytes``: staging is
        speculative, and a cancelled transfer must leave no round-trip
        trace in :class:`PoolStats`. The committing caller bills via
        :meth:`bill_offload` (the atomic verbs below do it themselves)."""
        if self._san is not None:
            self._san.on_read("dev", dev_page)
        hp = self.alloc_host()
        if hp is None:
            return None
        if self._san is not None:
            self._san.on_write("host", hp)
        self._fmt_event("host", hp, self.offload_format)
        if self.offload_format == "int8":
            if self.quantized_device:
                self.host_k[:, hp] = np.asarray(self.k[:, dev_page])
                self.host_v[:, hp] = np.asarray(self.v[:, dev_page])
                self.host_k_scale[:, hp] = np.asarray(self.k_scale[:, dev_page])
                self.host_v_scale[:, hp] = np.asarray(self.v_scale[:, dev_page])
            else:
                kf = np.asarray(self.k[:, dev_page].astype(jnp.float32))
                vf = np.asarray(self.v[:, dev_page].astype(jnp.float32))
                self.host_k[:, hp], self.host_k_scale[:, hp] = (
                    kv_quant.quantize_np(kf)
                )
                self.host_v[:, hp], self.host_v_scale[:, hp] = (
                    kv_quant.quantize_np(vf)
                )
            return hp
        self.host_k[:, hp] = self._encode_host(self.k[:, dev_page])
        self.host_v[:, hp] = self._encode_host(self.v[:, dev_page])
        return hp

    def copy_page_to_device(self, host_page: int) -> int | None:
        """Stage one host page into a device page *without* freeing the
        host copy (streamed-reload primitive, mirror of the above). An
        int8 host page lands verbatim on an int8-resident pool (payload +
        scales, byte-identical) and dequantizes to the logical dtype on a
        bf16 pool."""
        if self._san is not None:
            self._san.on_read("host", host_page)
        dp = self.alloc_device()
        if dp is None:
            return None
        if self._san is not None:
            self._san.on_write("dev", dp)
        self._fmt_event("dev", dp, self.device_format)
        if self.offload_format == "int8":
            if self.quantized_device:
                self.k = self.k.at[:, dp].set(
                    jnp.asarray(self.host_k[:, host_page])
                )
                self.v = self.v.at[:, dp].set(
                    jnp.asarray(self.host_v[:, host_page])
                )
                self.k_scale = self.k_scale.at[:, dp].set(
                    jnp.asarray(self.host_k_scale[:, host_page])
                )
                self.v_scale = self.v_scale.at[:, dp].set(
                    jnp.asarray(self.host_v_scale[:, host_page])
                )
                return dp
            kf = kv_quant.dequantize_np(
                self.host_k[:, host_page], self.host_k_scale[:, host_page]
            )
            vf = kv_quant.dequantize_np(
                self.host_v[:, host_page], self.host_v_scale[:, host_page]
            )
            self.k = self.k.at[:, dp].set(jnp.asarray(kf, self.k.dtype))
            self.v = self.v.at[:, dp].set(jnp.asarray(vf, self.v.dtype))
            return dp
        self.k = self.k.at[:, dp].set(
            jnp.asarray(self._decode_host(self.host_k[:, host_page]), self.k.dtype)
        )
        self.v = self.v.at[:, dp].set(
            jnp.asarray(self._decode_host(self.host_v[:, host_page]), self.v.dtype)
        )
        return dp

    def import_host_page(self, src_pool: "PagePool", src_hp: int) -> int | None:
        """Copy one host page from *another replica's* pool into this pool's
        host tier — the cross-replica migrate primitive (dst-host ←
        src-host). The copy is format-verbatim (raw bits for bf16, payload
        + scale sidecar for int8), so the destination KV is byte-identical
        to the source; like the staging verbs above it is
        copy-without-free and unbilled — the committing migrate stream
        frees the source copy and the router does the accounting."""
        same_geometry = (
            self.host_k.shape[0] == src_pool.host_k.shape[0]
            and self.host_k.shape[2:] == src_pool.host_k.shape[2:]
            and self.host_k.dtype == src_pool.host_k.dtype
            and self.offload_format == src_pool.offload_format
        )
        assert same_geometry, "incompatible page geometry across replicas"
        if src_pool._san is not None:
            src_pool._san.on_read("host", src_hp)
        hp = self.alloc_host()
        if hp is None:
            return None
        if self._san is not None:
            self._san.on_write("host", hp)
        self._fmt_event("host", hp, self.offload_format)
        self.host_k[:, hp] = src_pool.host_k[:, src_hp]
        self.host_v[:, hp] = src_pool.host_v[:, src_hp]
        if self.offload_format == "int8":
            self.host_k_scale[:, hp] = src_pool.host_k_scale[:, src_hp]
            self.host_v_scale[:, hp] = src_pool.host_v_scale[:, src_hp]
        return hp

    def bill_offload(self, pages: int = 1) -> None:
        """Record ``pages`` worth of committed device→host movement, at
        the offload format's wire size."""
        self.offload_bytes += pages * self.host_page_bytes

    def bill_reload(self, pages: int = 1) -> None:
        """Record ``pages`` worth of committed host→device movement, at
        the offload format's wire size (the wire carries the host copy)."""
        self.reload_bytes += pages * self.host_page_bytes

    def offload_page(self, dev_page: int) -> int | None:
        """Device -> host (atomic copy+free). Returns host page id."""
        hp = self.copy_page_to_host(dev_page)
        if hp is None:
            return None
        self.free_device(dev_page)
        self.bill_offload()
        return hp

    def reload_page(self, host_page: int) -> int | None:
        """Host -> device (atomic copy+free). Returns device page id."""
        dp = self.copy_page_to_device(host_page)
        if dp is None:
            return None
        self.free_host(host_page)
        self.bill_reload()
        return dp

    def stats(self) -> PoolStats:
        return PoolStats(
            device_free=len(self._free_dev),
            device_total=self.n_device_pages,
            host_free=len(self._free_host),
            host_total=self.n_host_pages,
            offload_bytes=self.offload_bytes,
            reload_bytes=self.reload_bytes,
        )
