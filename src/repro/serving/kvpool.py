"""Two-tier paged KV storage: device pages (HBM) + host pages (DRAM).

Layout ``[L, n_pages, page_tokens, KH, HD]`` for K and V — the trailing
(page_tokens, head_dim) tile is what the Pallas paged-attention kernel
consumes per grid step. Host pages are numpy arrays (on a real TPU host:
pinned DRAM reached via ``jax.device_get/put``; in this CPU container the
transfer mechanics — block granularity, explicit copies, byte accounting —
are identical, only the wire is missing).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np


@dataclass
class PoolStats:
    device_free: int
    device_total: int
    host_free: int
    host_total: int
    offload_bytes: int = 0
    reload_bytes: int = 0


class PagePool:
    def __init__(
        self,
        *,
        layers: int,
        kv_heads: int,
        head_dim: int,
        page_tokens: int,
        n_device_pages: int,
        n_host_pages: int,
        dtype=jnp.bfloat16,
    ):
        self.layers = layers
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.page_tokens = page_tokens
        shape = (layers, n_device_pages, page_tokens, kv_heads, head_dim)
        self.k = jnp.zeros(shape, dtype)
        self.v = jnp.zeros(shape, dtype)
        hshape = (layers, n_host_pages, page_tokens, kv_heads, head_dim)
        self.host_k = np.zeros(hshape, np.float32 if dtype == jnp.float32 else np.float16)
        self.host_v = np.zeros_like(self.host_k)
        self._free_dev = list(range(n_device_pages))
        self._free_host = list(range(n_host_pages))
        self.n_device_pages = n_device_pages
        self.n_host_pages = n_host_pages
        self.offload_bytes = 0
        self.reload_bytes = 0

    @property
    def page_bytes(self) -> int:
        return self.layers * self.page_tokens * self.kv_heads * self.head_dim * 2 * 2

    # ---------------------------------------------------------- allocation
    def device_free_count(self) -> int:
        return len(self._free_dev)

    def host_free_count(self) -> int:
        return len(self._free_host)

    def alloc_device(self) -> int | None:
        return self._free_dev.pop() if self._free_dev else None

    def alloc_host(self) -> int | None:
        return self._free_host.pop() if self._free_host else None

    def free_device(self, page: int) -> None:
        self._free_dev.append(page)

    def free_host(self, page: int) -> None:
        self._free_host.append(page)

    # -------------------------------------------------------------- writes
    def write_device_page(self, page: int, k_tokens, v_tokens) -> None:
        """k_tokens/v_tokens: [L, t<=page_tokens, KH, HD]."""
        t = k_tokens.shape[1]
        self.k = self.k.at[:, page, :t].set(k_tokens.astype(self.k.dtype))
        self.v = self.v.at[:, page, :t].set(v_tokens.astype(self.v.dtype))

    def read_device_pages(self, pages: list[int]):
        """Gather pages -> [L, n*page_tokens, KH, HD] (slot assembly)."""
        idx = jnp.asarray(pages, jnp.int32)
        k = self.k[:, idx]                                      # [L,n,t,KH,HD]
        v = self.v[:, idx]
        L, n, t, KH, HD = k.shape
        return k.reshape(L, n * t, KH, HD), v.reshape(L, n * t, KH, HD)

    # ----------------------------------------------------------- transfers
    def offload_page(self, dev_page: int) -> int | None:
        """Device -> host. Returns host page id (None if host full)."""
        hp = self.alloc_host()
        if hp is None:
            return None
        self.host_k[:, hp] = np.asarray(self.k[:, dev_page], np.float32).astype(
            self.host_k.dtype
        )
        self.host_v[:, hp] = np.asarray(self.v[:, dev_page], np.float32).astype(
            self.host_v.dtype
        )
        self.free_device(dev_page)
        self.offload_bytes += self.page_bytes
        return hp

    def reload_page(self, host_page: int) -> int | None:
        """Host -> device. Returns device page id (None if device full)."""
        dp = self.alloc_device()
        if dp is None:
            return None
        self.k = self.k.at[:, dp].set(
            jnp.asarray(self.host_k[:, host_page], self.k.dtype)
        )
        self.v = self.v.at[:, dp].set(
            jnp.asarray(self.host_v[:, host_page], self.v.dtype)
        )
        self.free_host(host_page)
        self.reload_bytes += self.page_bytes
        return dp

    def stats(self) -> PoolStats:
        return PoolStats(
            device_free=len(self._free_dev),
            device_total=self.n_device_pages,
            host_free=len(self._free_host),
            host_total=self.n_host_pages,
            offload_bytes=self.offload_bytes,
            reload_bytes=self.reload_bytes,
        )
