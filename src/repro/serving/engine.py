"""A real (small-scale) JAX inference engine with paged KV + typed eviction.

This is the execution plane the MORI scheduler drives in the real system:

* paged two-tier KV storage (:class:`repro.serving.kvpool.PagePool`),
* RadixAttention-style prefix reuse via :class:`TypedRadixTree` — a new
  request whose prefix is cached skips prefill for those pages (chunked
  prefill over the radix prefix),
* continuous batching decode over fixed slots (JetStream-style),
* engine-level eviction that follows the scheduler's typed labels
  (paper §4.3.2): GPU evicts inactive->idle->busy, host evicts
  inactive->busy->idle, LRU within type,
* program-level offload / reload / discard entry points used by the
  MORI router.

Scale note: this engine serves *reduced* configs end-to-end on CPU (tests,
examples). Paper-scale timing experiments live in ``repro.sim``; production
mesh lowering in ``repro.launch.dryrun``.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.radix_tree import TypedRadixTree
from repro.core.types import Tier, TypeLabel
from repro.dist import ReplicaPlacement
from repro.models import NULL_CTX, Model, ShardCtx
from repro.models.config import ModelConfig
from repro.models.params import sharding_tree


@dataclass
class EngineRequest:
    program_id: str
    tokens: list[int]            # full accumulated context (token ids)
    max_new_tokens: int = 16


@dataclass
class Completion:
    program_id: str
    output_tokens: list[int]
    cached_tokens: int           # tokens served from the radix cache
    prefilled_tokens: int        # tokens actually prefilled
    reloaded_pages: int


@dataclass
class _Slot:
    request: EngineRequest
    slot_id: int
    length: int                  # current context length (incl. generated)
    produced: list[int] = field(default_factory=list)
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    reloaded_pages: int = 0


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        page_tokens: int = 16,
        n_device_pages: int = 256,
        n_host_pages: int = 256,
        max_slots: int = 4,
        max_seq: int = 512,
        placement: ReplicaPlacement | None = None,
    ):
        assert cfg.family in ("dense", "moe", "vlm") and not cfg.local_global_alternating, (
            "the real engine serves dense-cache families; see DESIGN.md"
        )
        self.cfg = cfg
        self.model = Model(cfg)
        self.placement = placement
        if placement is not None:
            # pin the replica's weight copy to its mesh slice under the
            # shared rules so every replica compiles identical layouts
            self.ctx = ShardCtx(placement.mesh, placement.rules)
            p_sh = sharding_tree(
                self.model.describe(), placement.mesh, placement.rules
            )
            params = jax.tree.map(jax.device_put, params, p_sh)
        else:
            self.ctx = NULL_CTX
        self.params = params
        self.page_tokens = page_tokens
        self.max_slots = max_slots
        self.max_seq = max_seq
        from repro.serving.kvpool import PagePool

        self.pool = PagePool(
            layers=cfg.num_layers,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            page_tokens=page_tokens,
            n_device_pages=n_device_pages,
            n_host_pages=n_host_pages,
        )
        self.tree = TypedRadixTree(page_tokens)
        L, KH, HD = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
        self.slot_k = jnp.zeros((L, max_slots, max_seq, KH, HD), jnp.bfloat16)
        self.slot_v = jnp.zeros_like(self.slot_k)
        self.lengths = np.zeros(max_slots, np.int32)
        self.last_token = np.zeros(max_slots, np.int32)
        self.slots: dict[int, _Slot] = {}
        self._free_slots = list(range(max_slots))
        self._decode_fn = jax.jit(self._decode_impl)
        # metrics
        self.steps = 0
        self.evicted_pages = {"gpu": 0, "cpu": 0}

    # ------------------------------------------------------------ admission
    def has_slot(self) -> bool:
        return bool(self._free_slots)

    def submit(self, req: EngineRequest) -> int:
        """Admit one request: radix match -> reload -> chunked prefill."""
        assert self._free_slots, "no free decode slots"
        assert len(req.tokens) + req.max_new_tokens <= self.max_seq
        pid = req.program_id

        # 1. promote any host-resident prefix pages back to the device
        reloaded = self._reload_prefix(req.tokens)
        # 2. device-resident prefix
        nodes = self.tree.match_prefix(req.tokens)
        cached = len(nodes) * self.page_tokens
        pages = [n.device_page for n in nodes]
        suffix = req.tokens[cached:]
        assert suffix, "request must extend its cached prefix"

        prefix = None
        if pages:
            pk, pv = self.pool.read_device_pages(pages)
            prefix = {"k": pk[:, None], "v": pv[:, None]}       # [L,1,Sp,KH,HD]

        batch = {"tokens": jnp.asarray([suffix], jnp.int32)}
        logits, cache = self.model.prefill(
            self.params, batch, ctx=self.ctx, prefix=prefix
        )
        first_token = int(jnp.argmax(logits[0]))

        # 3. install into a decode slot
        sid = self._free_slots.pop()
        length = len(req.tokens)
        k_ctx = cache["k"][:, 0]                                # [L,Ssuf,KH,HD]
        v_ctx = cache["v"][:, 0]
        if prefix is not None:
            k_ctx = jnp.concatenate([prefix["k"][:, 0], k_ctx], axis=1)
            v_ctx = jnp.concatenate([prefix["v"][:, 0], v_ctx], axis=1)
        self.slot_k = self.slot_k.at[:, sid, :length].set(k_ctx)
        self.slot_v = self.slot_v.at[:, sid, :length].set(v_ctx)
        self.lengths[sid] = length
        self.last_token[sid] = first_token
        slot = _Slot(
            request=req,
            slot_id=sid,
            length=length,
            produced=[first_token],
            cached_tokens=cached,
            prefilled_tokens=len(suffix),
            reloaded_pages=reloaded,
        )
        self.slots[sid] = slot
        self.tree.pin(pid)  # in-use pages are not evictable
        return sid

    def _reload_prefix(self, tokens: list[int]) -> int:
        n = 0
        for node in self.tree.match_prefix_any_tier(tokens):
            if node.device_page is None and node.host_page is not None:
                self._ensure_device_page()
                dp = self.pool.reload_page(node.host_page)
                if dp is None:
                    break
                node.host_page = None
                node.device_page = dp
                n += 1
        return n

    # -------------------------------------------------------------- decode
    def _decode_impl(self, params, slot_k, slot_v, tokens, lengths):
        cache = {"k": slot_k, "v": slot_v}
        logits, new_cache = self.model.decode(
            params, cache, tokens, lengths, ctx=self.ctx
        )
        return jnp.argmax(logits, axis=-1), new_cache["k"], new_cache["v"]

    def step(self) -> list[Completion]:
        """One continuous-batching decode step across all active slots."""
        if not self.slots:
            return []
        self.steps += 1
        for sid in self.slots:
            self.lengths[sid] += 1  # the token being decoded extends the ctx
        toks = jnp.asarray(self.last_token, jnp.int32)
        lens = jnp.asarray(np.maximum(self.lengths, 1), jnp.int32)
        next_tok, self.slot_k, self.slot_v = self._decode_fn(
            self.params, self.slot_k, self.slot_v, toks, lens
        )
        next_tok = np.asarray(next_tok)
        done: list[Completion] = []
        for sid, slot in list(self.slots.items()):
            slot.length = int(self.lengths[sid])
            tok = int(next_tok[sid])
            slot.produced.append(tok)
            self.last_token[sid] = tok
            if len(slot.produced) >= slot.request.max_new_tokens:
                done.append(self._finish(slot))
        return done

    def _finish(self, slot: _Slot) -> Completion:
        """Write the slot's full pages back to the pool + radix, free slot."""
        req = slot.request
        all_tokens = req.tokens + slot.produced[:-1]  # last token has no KV yet
        n_full = len(all_tokens) // self.page_tokens
        have = len(self.tree.match_prefix(all_tokens))
        new_pages = []
        for p in range(have, n_full):
            self._ensure_device_page()
            page = self.pool.alloc_device()
            if page is None:
                break
            lo, hi = p * self.page_tokens, (p + 1) * self.page_tokens
            self.pool.write_device_page(
                page,
                self.slot_k[:, slot.slot_id, lo:hi],
                self.slot_v[:, slot.slot_id, lo:hi],
            )
            new_pages.append(page)
        self.tree.unpin(req.program_id)  # release the pages pinned at submit
        covered = (have + len(new_pages)) * self.page_tokens
        self.tree.insert_chain(
            all_tokens[:covered], new_pages, req.program_id, TypeLabel.BUSY
        )
        self.slots.pop(slot.slot_id)
        self._free_slots.append(slot.slot_id)
        return Completion(
            program_id=req.program_id,
            output_tokens=slot.produced,
            cached_tokens=slot.cached_tokens,
            prefilled_tokens=slot.prefilled_tokens,
            reloaded_pages=slot.reloaded_pages,
        )

    def run_to_completion(self, max_steps: int = 10_000) -> list[Completion]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.slots:
                break
        return out

    # ---------------------------------------------- typed eviction machinery
    def _ensure_device_page(self) -> None:
        """Free one device page if the pool is exhausted (typed order)."""
        if self.pool.device_free_count() > 0:
            return
        for node in self.tree.evictable("gpu"):
            dp = node.device_page
            hp = self.pool.offload_page(dp)  # spill to host if possible
            if hp is not None:
                node.device_page = None
                node.host_page = hp
            else:
                node.device_page = None
                self.pool.free_device(dp)
                self.tree._gc(node)
            self.evicted_pages["gpu"] += 1
            return
        raise RuntimeError("device pool exhausted and nothing evictable")

    def _ensure_host_page(self) -> None:
        if self.pool.host_free_count() > 0:
            return
        for node in self.tree.evictable("cpu"):
            self.pool.free_host(self.tree.evict(node, "cpu"))
            self.evicted_pages["cpu"] += 1
            return

    # --------------------------------------------- MORI program-level verbs
    def offload_program(self, pid: str) -> int:
        """GPU -> host for all of the program's device pages. Returns count."""
        n = 0
        for node in reversed(self.tree.program_nodes(pid)):  # leaves first
            if node.device_page is not None and node.refcount == 0:
                self._ensure_host_page()
                hp = self.pool.offload_page(node.device_page)
                if hp is None:
                    break
                node.device_page = None
                node.host_page = hp
                n += 1
        return n

    def reload_program(self, pid: str) -> int:
        n = 0
        for node in self.tree.program_nodes(pid):
            if node.device_page is None and node.host_page is not None:
                self._ensure_device_page()
                dp = self.pool.reload_page(node.host_page)
                if dp is None:
                    break
                node.host_page = None
                node.device_page = dp
                n += 1
        return n

    def discard_program(self, pid: str, tier: Tier) -> None:
        for node in reversed(self.tree.program_nodes(pid)):
            if node.refcount > 0:
                continue
            if tier is Tier.GPU and node.device_page is not None:
                self.pool.free_device(node.device_page)
                node.device_page = None
            if tier is Tier.CPU and node.host_page is not None:
                self.pool.free_host(node.host_page)
                node.host_page = None
            self.tree._gc(node)
        if not any(
            n.device_page is not None or n.host_page is not None
            for n in self.tree.program_nodes(pid)
        ):
            self.tree.release_program(pid)

    def set_label(self, pid: str, label: TypeLabel) -> None:
        self.tree.restamp(pid, label)
