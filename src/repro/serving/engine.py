"""A real (small-scale) JAX inference engine with paged KV + typed eviction.

This is the execution plane the MORI scheduler drives in the real system:

* paged two-tier KV storage (:class:`repro.serving.kvpool.PagePool`),
* RadixAttention-style prefix reuse via :class:`TypedRadixTree` — a new
  request whose prefix is cached skips prefill for those pages (chunked
  prefill over the radix prefix),
* **block-table decode** (default): the pool *is* the decode state.
  Continuous-batching decode runs the paged-attention kernel straight off
  the ``PagePool`` through per-slot block tables; each step appends the
  new token's KV into the slot's tail page in place. ``submit()`` writes
  suffix prefill KV directly into pool pages (cached prefix pages are
  *referenced*, never copied) and ``_finish`` hands the already-resident
  full pages to the radix tree — the dense-slot path's
  gather → concatenate → slot-write → write-back round trip is gone,
  and a program's KV never exists anywhere but the pool,
* ``dense_slots=True`` compatibility knob: the pre-block-table decode
  path (JetStream-style fixed slot buffers), kept token-identical to the
  paged path by a golden test and used as the benchmark baseline,
* engine-level eviction that follows the scheduler's typed labels
  (paper §4.3.2): GPU evicts inactive->idle->busy, host evicts
  inactive->busy->idle, LRU within type,
* program-level offload / reload / discard entry points used by the
  MORI router.

Scale note: this engine serves *reduced* configs end-to-end on CPU (tests,
examples). Paper-scale timing experiments live in ``repro.sim``; production
mesh lowering in ``repro.launch.dryrun``.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import compile_tracker
from repro.core.radix_tree import TypedRadixTree
from repro.core.types import Tier, TypeLabel
from repro.dist import ReplicaPlacement
from repro.models import NULL_CTX, Model, ShardCtx
from repro.models.config import ModelConfig
from repro.models.params import sharding_tree


@dataclass
class EngineRequest:
    program_id: str
    tokens: list[int]            # full accumulated context (token ids)
    max_new_tokens: int = 16


@dataclass
class Completion:
    program_id: str
    output_tokens: list[int]
    cached_tokens: int           # tokens served from the radix cache
    prefilled_tokens: int        # tokens actually prefilled
    reloaded_pages: int


@dataclass
class PrefillJob:
    """A resumable chunked prefill: the two-phase twin of ``submit``.

    ``Engine.begin_submit`` reserves the decode slot, enters the
    radix-matched prefix pages and stages the suffix pages; each
    ``Engine.prefill_step`` call then prefills one page-aligned,
    budget-bounded chunk into the staged pages. The decode pump runs
    chunks between decode steps so a long prefill never stalls the
    whole batch. ``first_token`` is set by the final chunk, at which
    point the job's slot is installed for decode.
    """

    request: EngineRequest
    slot_id: int
    suffix: list[int]            # tokens past the radix-cached prefix
    cached_tokens: int
    reloaded_pages: int
    prefix_pages: list[int]      # radix device pages (referenced, pinned)
    prefix_nodes: list
    new_pages: list[int]         # staged suffix pages (allocated up front)
    cursor: int = 0              # suffix tokens prefilled so far
    chunks_run: int = 0
    first_token: int | None = None
    kvsan_hold: int | None = None   # sanitizer hold token on new_pages

    @property
    def done(self) -> bool:
        return self.first_token is not None

    @property
    def remaining(self) -> int:
        return len(self.suffix) - self.cursor


@dataclass
class WarmupSpec:
    """One shape ``Engine.warmup`` precompiles — and, equivalently, one
    audit target for :mod:`repro.analysis.jitaudit`.

    ``make_args`` is lazy on purpose: the decode/chunk fns donate the
    pool arrays, so each spec must read ``pool.block_table_view()`` (or
    the dense slot buffers) *at call time*, after the previous spec's
    donation was re-adopted.  ``probe_group`` names the structural
    equivalence class: any two specs in a group must trace to the same
    primitive sequence (the jitaudit shape-branch probe pairs
    consecutive group members).
    """

    name: str
    kind: str                    # "dense" | "paged_decode" | "chunk_prefill"
    fn_name: str                 # engine attribute holding the jitted fn
    make_args: object            # () -> positional argument tuple
    donate_argnums: tuple = ()
    static_argnums: tuple = ()
    bucket: dict = field(default_factory=dict)
    probe_group: str = ""


def greedy_token(logits):
    """Deterministic greedy sampling shared by every sample site (dense
    decode, paged decode, monolithic and chunked prefill).

    The KV cache is bf16 while logits are f32, so two token-identical
    paths that materialize the context differently (dense slots vs paged
    gather, bf16 vs int8 pages) can produce logits differing by ~1 bf16
    ulp — enough to flip an f32 argmax between two near-tied candidates.
    Rounding the logits to bf16 first collapses those sub-ulp differences
    into *exact* ties, and ``jnp.argmax`` breaks exact ties by lowest
    index on every backend — so the sampled token is a deterministic
    function of the context, not of which code path computed it."""
    return jnp.argmax(
        logits.astype(jnp.bfloat16).astype(jnp.float32), axis=-1
    )


def _chunk_prefill_impl(model, ctx, params, k_pages, v_pages, prefix_idx,
                        write_idx, tokens, prefix_valid, pos0, take,
                        logit_idx, page_tokens):
    """One chunk of prefill, pool-in/pool-out (jit body; donation makes the
    page scatter an in-place pool update). ``prefix_idx`` is padded to a
    page bucket (garbage tail masked via ``prefix_valid``); ``write_idx``
    is padded with a scratch page; chunk KV past ``take`` is zeroed so the
    written tail page is byte-identical to the monolithic path's."""
    from repro.serving.kvpool import gather_token_run, scatter_token_run

    prefix = None
    # shape branch is deliberate bucketing: prefix_idx is padded to the
    # table bucket, so this compiles once per bucket, not per length
    if prefix_idx.shape[0]:  # lint: jit-shape-branch-ok
        pk, pv = gather_token_run(k_pages, v_pages, prefix_idx)
        prefix = {"k": pk[:, None], "v": pv[:, None]}           # [L,1,Sp,KH,HD]
    logits, cache = model.prefill(
        params, {"tokens": tokens}, ctx=ctx, prefix=prefix,
        logit_index=logit_idx, positions_offset=pos0,
        prefix_valid=prefix_valid if prefix is not None else None,
    )
    k_c = cache["k"][:, 0]                                     # [L,C_pad,KH,HD]
    v_c = cache["v"][:, 0]
    keep = (jnp.arange(k_c.shape[1]) < take)[None, :, None, None]
    k_c = jnp.where(keep, k_c, 0)
    v_c = jnp.where(keep, v_c, 0)
    k_pages, v_pages = scatter_token_run(
        k_pages, v_pages, write_idx, k_c, v_c, page_tokens
    )
    return logits[0], k_pages, v_pages


def _chunk_prefill_impl_q(model, ctx, params, k_pages, v_pages, k_scale,
                          v_scale, prefix_idx, write_idx, tokens,
                          prefix_valid, pos0, take, logit_idx, page_tokens):
    """Int8-resident twin of :func:`_chunk_prefill_impl`: the prefix gather
    dequantizes through the scale sidecars and the chunk scatter quantizes
    each written page (payload + sidecar updated together, all donated)."""
    from repro.serving.kvpool import gather_token_run_q, scatter_token_run_q

    prefix = None
    if prefix_idx.shape[0]:  # lint: jit-shape-branch-ok
        pk, pv = gather_token_run_q(
            k_pages, k_scale, v_pages, v_scale, prefix_idx, jnp.bfloat16
        )
        prefix = {"k": pk[:, None], "v": pv[:, None]}           # [L,1,Sp,KH,HD]
    logits, cache = model.prefill(
        params, {"tokens": tokens}, ctx=ctx, prefix=prefix,
        logit_index=logit_idx, positions_offset=pos0,
        prefix_valid=prefix_valid if prefix is not None else None,
    )
    k_c = cache["k"][:, 0]                                     # [L,C_pad,KH,HD]
    v_c = cache["v"][:, 0]
    keep = (jnp.arange(k_c.shape[1]) < take)[None, :, None, None]
    k_c = jnp.where(keep, k_c, 0)
    v_c = jnp.where(keep, v_c, 0)
    k_pages, k_scale, v_pages, v_scale = scatter_token_run_q(
        k_pages, k_scale, v_pages, v_scale, write_idx, k_c, v_c, page_tokens
    )
    return logits[0], k_pages, v_pages, k_scale, v_scale


@functools.lru_cache(maxsize=None)
def _chunk_prefill_fn(cfg: ModelConfig, quantized: bool = False):
    """Process-global jitted chunk prefill, keyed on the (hashable) model
    config and the pool's device format. Sharing the jit cache across
    Engine instances is the point: chunk shapes are bucketed, so every
    engine in the process reuses the same few compiles instead of paying a
    fresh trace per submit the way monolithic variable-shape prefill
    does."""
    model = Model(cfg)
    if quantized:
        fn = functools.partial(_chunk_prefill_impl_q, model, NULL_CTX)
        return jax.jit(fn, donate_argnums=(1, 2, 3, 4), static_argnums=(12,))
    fn = functools.partial(_chunk_prefill_impl, model, NULL_CTX)
    return jax.jit(fn, donate_argnums=(1, 2), static_argnums=(10,))


#: per-process engine ids for compile-tracker names (stable within a run)
_ENGINE_IDS = iter(range(1 << 30))


@dataclass
class _Slot:
    request: EngineRequest
    slot_id: int
    length: int                  # current context length (incl. generated)
    produced: list[int] = field(default_factory=list)
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    reloaded_pages: int = 0
    # block-table decode state (paged mode): page ids covering positions
    # [i*T, (i+1)*T); entries below ``owned_from`` are shared radix pages
    # (read-only, pinned), entries from ``owned_from`` on are slot-owned
    table: list[int] = field(default_factory=list)
    owned_from: int = 0
    # the radix nodes backing table[:owned_from] — refcount-held for the
    # slot's lifetime so eviction/offload can never recycle a device page
    # a live block table still points at (they may belong to ANOTHER
    # program sharing the prefix, which tree.pin(pid) does not cover)
    prefix_nodes: list = field(default_factory=list)


class Engine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        page_tokens: int = 16,
        n_device_pages: int = 256,
        n_host_pages: int = 256,
        max_slots: int = 4,
        max_seq: int = 512,
        placement: ReplicaPlacement | None = None,
        dense_slots: bool = False,
        table_bucket_pages: int = 4,
        prefill_bucket_tokens: int = 32,
        prefill_chunk_tokens: int = 64,
        offload_format: str = "bf16",
        device_format: str = "bf16",
    ):
        assert cfg.family in ("dense", "moe", "vlm") and not cfg.local_global_alternating, (
            "the real engine serves dense-cache families; see DESIGN.md"
        )
        assert not (dense_slots and device_format == "int8"), (
            "device_format='int8' packs the paged pool; the dense-slot "
            "compatibility path has no page-granular scale sidecars"
        )
        self.cfg = cfg
        self.model = Model(cfg)
        self.placement = placement
        if placement is not None:
            # pin the replica's weight copy to its mesh slice under the
            # shared rules so every replica compiles identical layouts
            self.ctx = ShardCtx(placement.mesh, placement.rules)
            p_sh = sharding_tree(
                self.model.describe(), placement.mesh, placement.rules
            )
            params = jax.tree.map(jax.device_put, params, p_sh)
        else:
            self.ctx = NULL_CTX
        self.params = params
        self.page_tokens = page_tokens
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.dense_slots = dense_slots
        # suffix prefill pads to this bucket so jit compiles once per bucket
        # (not once per context length); causality keeps outputs identical
        self.prefill_bucket = max(1, prefill_bucket_tokens)
        # default per-call token budget for prefill_step (page-aligned there)
        self.prefill_chunk_tokens = max(1, prefill_chunk_tokens)
        self.pages_per_slot = -(-max_seq // page_tokens)
        # Paged mode stores decode state IN the pool, so the device pool is
        # provisioned with the HBM the dense slot buffers used to occupy:
        # pages_per_slot per slot plus one scratch page per slot (inactive
        # batch rows write their garbage token there, mirroring the dense
        # path's harmless writes into unused slot rows). The reserve is
        # excluded from the router's radix-capacity accounting via
        # ``decode_reserve_pages``.
        self.decode_reserve_pages = (
            0 if dense_slots else max_slots * (self.pages_per_slot + 1)
        )
        self.radix_device_pages = n_device_pages  # cache budget (sans reserve)
        from repro.serving.kvpool import PagePool

        self.pool = PagePool(
            layers=cfg.num_layers,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            page_tokens=page_tokens,
            n_device_pages=n_device_pages + self.decode_reserve_pages,
            n_host_pages=n_host_pages,
            offload_format=offload_format,
            device_format=device_format,
        )
        self.quantized = self.pool.quantized_device
        self.tree = TypedRadixTree(page_tokens)
        if self.pool._san is not None:
            # give the sanitizer the node graph (pin checks) and the live
            # block-table / scratch references (hold + leak checks)
            self.pool._san.tree = self.tree
            self.pool._san.add_reachable_cb(self._kvsan_reachable)
        self.lengths = np.zeros(max_slots, np.int32)
        self.last_token = np.zeros(max_slots, np.int32)
        # token whose KV currently occupies position lengths[sid]-1 — what a
        # step NOT advancing this slot must re-feed so its row's write is an
        # idempotent rewrite of the existing tail KV (last_token's KV is not
        # written yet; feeding it unpaced would corrupt the tail position)
        self._tail_token = np.zeros(max_slots, np.int32)
        self.slots: dict[int, _Slot] = {}
        self._free_slots = list(range(max_slots))
        if dense_slots:
            L, KH, HD = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim
            self.slot_k = jnp.zeros((L, max_slots, max_seq, KH, HD), jnp.bfloat16)
            self.slot_v = jnp.zeros_like(self.slot_k)
            self._decode_fn = jax.jit(self._decode_impl, donate_argnums=(1, 2))
        else:
            self._scratch_pages = [
                self.pool.alloc_device() for _ in range(max_slots)
            ]
            self._table_bucket = table_bucket_pages
            if self.quantized:
                # the step rewrites tail-page scales alongside the payload,
                # so the sidecars are donated (and re-adopted) too
                self._paged_decode_fn = jax.jit(
                    self._paged_decode_impl_q, donate_argnums=(1, 2, 3, 4)
                )
            else:
                self._paged_decode_fn = jax.jit(
                    self._paged_decode_impl, donate_argnums=(1, 2)
                )
            # chunked prefill: the process-global callable shares compiles
            # across engines; placement engines need their own ShardCtx
            if placement is None:
                self._chunk_fn = _chunk_prefill_fn(cfg, self.quantized)
            elif self.quantized:
                self._chunk_fn = jax.jit(
                    functools.partial(_chunk_prefill_impl_q, self.model, self.ctx),
                    donate_argnums=(1, 2, 3, 4), static_argnums=(12,),
                )
            else:
                self._chunk_fn = jax.jit(
                    functools.partial(_chunk_prefill_impl, self.model, self.ctx),
                    donate_argnums=(1, 2), static_argnums=(10,),
                )
        # metrics
        self.steps = 0
        self.evicted_pages = {"gpu": 0, "cpu": 0}
        # compile tracker (REPRO_JITAUDIT=1 only): register the hot-path
        # jits so post-warmup recompiles are attributable and gateable
        self._audit_id = next(_ENGINE_IDS)
        if compile_tracker.enabled():
            tracker = compile_tracker.get_tracker()
            for name, fn in self.jit_functions().items():
                tracker.register(name, fn)

    # ------------------------------------------------------- compile plane
    def jit_functions(self) -> dict:
        """The hot-path jitted callables by tracker name.  The process-
        global chunk-prefill fn keeps a shared name (one compile cache,
        one budget); per-engine fns are suffixed so multi-replica routers
        track each replica's cache."""
        if self.dense_slots:
            return {f"engine{self._audit_id}.decode_fn": self._decode_fn}
        out = {
            f"engine{self._audit_id}.paged_decode_fn": self._paged_decode_fn,
        }
        if self.placement is None:
            out["chunk_prefill_fn[shared]"] = self._chunk_fn
        else:
            out[f"engine{self._audit_id}.chunk_prefill_fn"] = self._chunk_fn
        return out

    # ------------------------------------------------------------- kvsan
    def _kvsan_reachable(self):
        """Live page references outside the radix tree, for the sanitizer:
        per-slot scratch pages and every resident block table."""
        out = []
        for p in getattr(self, "_scratch_pages", []):
            out.append(("dev", p, "scratch"))
        for slot in self.slots.values():
            pid = slot.request.program_id
            for p in slot.table:
                out.append(("dev", p, f"block table of {pid}"))
        return out

    def _san_scope(self, tag: str) -> None:
        if self.pool._san is not None:
            self.pool._san.set_scope(tag)

    # ------------------------------------------------------------ admission
    def has_slot(self) -> bool:
        return bool(self._free_slots)

    def free_slot_count(self) -> int:
        """Decode slots currently available for ``submit`` — the real
        occupancy signal the scheduler's slot probe reads."""
        return len(self._free_slots)

    def warmup_specs(self, prefill_chunks: bool = False) -> list[WarmupSpec]:
        """Every shape the serving hot path can dispatch, as lazy-argument
        specs — the single source of truth shared by :meth:`warmup` (which
        executes them) and :mod:`repro.analysis.jitaudit` (which traces
        them without executing).

        Paged decode emits one spec per table bucket (tables pad to
        ``table_bucket_pages``); chunked prefill one per (prefix-page
        bucket x chunk bucket) pair up to ``prefill_chunk_tokens``; the
        dense path a single shape.  A replay that stays inside these specs
        never compiles after warmup — the compile tracker's budget.
        """
        if self.dense_slots:
            def dense_args():
                return (
                    self.params, self.slot_k, self.slot_v,
                    jnp.zeros(self.max_slots, jnp.int32),
                    jnp.ones(self.max_slots, jnp.int32),
                )

            return [WarmupSpec(
                name="decode_fn", kind="dense", fn_name="_decode_fn",
                make_args=dense_args, donate_argnums=(1, 2),
                bucket={"max_slots": self.max_slots,
                        "max_seq": self.max_seq},
                probe_group=f"engine{self._audit_id}/dense",
            )]
        scratch = np.asarray(self._scratch_pages, np.int32)
        n_buckets = -(-self.pages_per_slot // self._table_bucket)
        specs: list[WarmupSpec] = []

        quantized = self.quantized
        decode_donate = (1, 2, 3, 4) if quantized else (1, 2)

        def decode_args(p_pad: int):
            def make():
                tables = np.repeat(scratch[:, None], p_pad, axis=1)
                k_pages, v_pages = self.pool.block_table_view()
                sidecars = ()
                if quantized:
                    sidecars = self.pool.scale_view()
                return (
                    self.params, k_pages, v_pages, *sidecars,
                    jnp.zeros(self.max_slots, jnp.int32),
                    jnp.ones(self.max_slots, jnp.int32),
                    jnp.asarray(tables), jnp.asarray(scratch),
                    jnp.zeros(self.max_slots, jnp.int32),
                )

            return make

        for i in range(1, n_buckets + 1):
            p_pad = i * self._table_bucket
            specs.append(WarmupSpec(
                name=f"paged_decode_fn[pages={p_pad}]", kind="paged_decode",
                fn_name="_paged_decode_fn", make_args=decode_args(p_pad),
                donate_argnums=decode_donate, bucket={"table_pages": p_pad},
                probe_group=f"engine{self._audit_id}/paged_decode",
            ))
        if not prefill_chunks:
            return specs
        T = self.page_tokens
        cap = max(T, (self.prefill_chunk_tokens // T) * T)
        cap_pad = -(-cap // self.prefill_bucket) * self.prefill_bucket
        sp = int(scratch[0])

        chunk_donate = (1, 2, 3, 4) if quantized else (1, 2)
        chunk_static = (12,) if quantized else (10,)

        def chunk_args(p_pad: int, c_pad: int):
            def make():
                w_pad = -(-c_pad // T)
                k_pages, v_pages = self.pool.block_table_view()
                sidecars = ()
                if quantized:
                    sidecars = self.pool.scale_view()
                return (
                    self.params, k_pages, v_pages, *sidecars,
                    jnp.asarray([sp] * p_pad, jnp.int32),
                    jnp.asarray([sp] * w_pad, jnp.int32),
                    jnp.zeros((1, c_pad), jnp.int32),
                    jnp.int32(0), jnp.int32(0),
                    jnp.int32(c_pad), jnp.int32(c_pad - 1), T,
                )

            return make

        for pb in range(n_buckets + 1):
            p_pad = pb * self._table_bucket
            # the prefix gather exists only when prefix pages do, so the
            # pb==0 bucket is deliberately a different traced program —
            # keep it in its own structural probe group
            group = "prefix" if p_pad else "no-prefix"
            for c_pad in range(self.prefill_bucket, cap_pad + 1,
                               self.prefill_bucket):
                specs.append(WarmupSpec(
                    name=f"chunk_prefill_fn[prefix_pages={p_pad},"
                         f"chunk={c_pad}]",
                    kind="chunk_prefill", fn_name="_chunk_fn",
                    make_args=chunk_args(p_pad, c_pad),
                    donate_argnums=chunk_donate, static_argnums=chunk_static,
                    bucket={"prefix_pages": p_pad, "chunk_tokens": c_pad},
                    probe_group=(
                        f"engine{self._audit_id}/chunk_prefill/{group}"
                    ),
                ))
        return specs

    def warmup(self, prefill_chunks: bool = False) -> None:
        """Precompile every decode-step shape before admitting traffic.

        The block-table path compiles once per table bucket (tables are
        padded to ``table_bucket_pages``); running each bucket here on the
        per-slot scratch pages means serving never hits a jit stall when a
        batch first crosses a bucket boundary. The dense path has a single
        shape. Must run on an idle engine (the dummy step writes garbage
        KV into scratch pages / slot position 0, both overwritten by the
        first real submit).

        ``prefill_chunks=True`` additionally compiles the chunked-prefill
        shapes (every prefix-page bucket x every chunk bucket up to the
        default ``prefill_chunk_tokens``) by running dummy chunks against
        scratch pages.

        When the compile tracker is armed (``REPRO_JITAUDIT=1``) the
        post-warmup cache sizes are snapshotted as this engine's compile
        budget: any later growth is a retrace warmup missed, and the
        router fails the replay on it."""
        assert not self.slots, "warmup must run on an idle engine"
        for spec in self.warmup_specs(prefill_chunks=prefill_chunks):
            out = getattr(self, spec.fn_name)(*spec.make_args())
            if spec.kind == "dense":
                _, self.slot_k, self.slot_v = out
            elif self.quantized:
                self.pool.adopt(out[1], out[2], out[3], out[4])
            else:
                self.pool.adopt(out[1], out[2])
        if compile_tracker.enabled():
            compile_tracker.get_tracker().mark_warm(
                tuple(self.jit_functions())
            )

    def submit(self, req: EngineRequest) -> int:
        """Admit one request: radix match -> reload -> chunked prefill."""
        assert self._free_slots, "no free decode slots"
        assert len(req.tokens) + req.max_new_tokens <= self.max_seq
        pid = req.program_id
        self._san_scope(f"submit:{pid}")

        # 1. promote any host-resident prefix pages back to the device
        reloaded = self._reload_prefix(req.tokens)
        # 2. device-resident prefix
        nodes = self.tree.match_prefix(req.tokens)
        cached = len(nodes) * self.page_tokens
        pages = [n.device_page for n in nodes]
        suffix = req.tokens[cached:]
        assert suffix, "request must extend its cached prefix"

        # pin before touching the pool: suffix-page allocation below may
        # evict, and the prefix chain a block table points at must survive.
        # tree.pin covers the program's own nodes; the matched chain is
        # refcount-held separately because a shared prefix may belong to a
        # different program (released in _finish)
        self.tree.pin(pid)
        if not self.dense_slots:
            self.tree.acquire_nodes(nodes)

        prefix = None
        if pages:
            pk, pv = self.pool.read_device_pages(pages)
            prefix = {"k": pk[:, None], "v": pv[:, None]}       # [L,1,Sp,KH,HD]

        pad = (-len(suffix)) % self.prefill_bucket
        batch = {"tokens": jnp.asarray([suffix + [0] * pad], jnp.int32)}
        logits, cache = self.model.prefill(
            self.params, batch, ctx=self.ctx, prefix=prefix,
            logit_index=len(suffix) - 1,
        )
        first_token = int(greedy_token(logits[0]))

        # 3. install into a decode slot
        sid = self._free_slots.pop()
        length = len(req.tokens)
        slot = _Slot(
            request=req,
            slot_id=sid,
            length=length,
            produced=[first_token],
            cached_tokens=cached,
            prefilled_tokens=len(suffix),
            reloaded_pages=reloaded,
        )
        k_suf = cache["k"][:, 0, : len(suffix)]                 # [L,Ssuf,KH,HD]
        v_suf = cache["v"][:, 0, : len(suffix)]
        if self.dense_slots:
            k_ctx, v_ctx = k_suf, v_suf
            if prefix is not None:
                k_ctx = jnp.concatenate([prefix["k"][:, 0], k_ctx], axis=1)
                v_ctx = jnp.concatenate([prefix["v"][:, 0], v_ctx], axis=1)
            self.slot_k = self.slot_k.at[:, sid, :length].set(k_ctx)
            self.slot_v = self.slot_v.at[:, sid, :length].set(v_ctx)
        else:
            # block-table install: reference the cached prefix pages and
            # write the suffix KV straight into freshly-allocated pool
            # pages — no dense materialization, no write-back at finish
            T = self.page_tokens
            slot.table = list(pages)
            slot.owned_from = len(pages)
            slot.prefix_nodes = nodes
            new_pages: list[int] = []
            try:
                for _ in range(len(pages), -(-length // T)):
                    new_pages.append(self._alloc_decode_page())
            except RuntimeError:
                for page in new_pages:
                    self.pool.free_device(page)
                self.tree.release_nodes(nodes)
                self.tree.unpin(pid)
                self._free_slots.append(sid)
                raise
            slot.table.extend(new_pages)
            self.pool.write_device_pages(new_pages, k_suf, v_suf)
        self.lengths[sid] = length
        self.last_token[sid] = first_token
        self._tail_token[sid] = req.tokens[-1]  # prefill wrote its KV last
        self.slots[sid] = slot
        return sid

    # --------------------------------------------------- chunked prefill
    def begin_submit(self, req: EngineRequest) -> PrefillJob:
        """Phase one of a chunked submit: radix match -> reload -> reserve.

        Reserves a decode slot (occupancy is visible to the scheduler's
        slot probe for the whole prefill), pins the matched prefix chain,
        and stages every suffix page up front so ``prefill_step`` can
        scatter chunk KV with a fixed-shape write. No model compute runs
        here. On allocation failure all state is rolled back and the
        RuntimeError propagates, mirroring ``submit``.
        """
        assert not self.dense_slots, "chunked prefill requires the paged engine"
        assert self._free_slots, "no free decode slots"
        assert len(req.tokens) + req.max_new_tokens <= self.max_seq
        pid = req.program_id
        self._san_scope(f"begin_submit:{pid}")

        reloaded = self._reload_prefix(req.tokens)
        nodes = self.tree.match_prefix(req.tokens)
        cached = len(nodes) * self.page_tokens
        pages = [n.device_page for n in nodes]
        suffix = req.tokens[cached:]
        assert suffix, "request must extend its cached prefix"

        self.tree.pin(pid)
        self.tree.acquire_nodes(nodes)
        sid = self._free_slots.pop()
        T = self.page_tokens
        new_pages: list[int] = []
        try:
            for _ in range(len(pages), -(-len(req.tokens) // T)):
                new_pages.append(self._alloc_decode_page())
        except RuntimeError:
            for page in new_pages:
                self.pool.free_device(page)
            self.tree.release_nodes(nodes)
            self.tree.unpin(pid)
            self._free_slots.append(sid)
            raise
        hold = None
        if self.pool._san is not None:
            # the staged suffix pages belong to this job until the final
            # chunk installs them into a slot's block table
            hold = self.pool._san.add_hold(
                "dev", new_pages, f"prefill job:{pid}"
            )
        return PrefillJob(
            request=req,
            slot_id=sid,
            suffix=suffix,
            cached_tokens=cached,
            reloaded_pages=reloaded,
            prefix_pages=pages,
            prefix_nodes=nodes,
            new_pages=new_pages,
            kvsan_hold=hold,
        )

    def prefill_step(self, job: PrefillJob, token_budget: int | None = None) -> bool:
        """Run ONE bucketed prefill chunk of at most ``token_budget`` tokens
        (page-aligned; default ``prefill_chunk_tokens``). Returns True when
        the final chunk lands, at which point ``job.first_token`` is set and
        the slot is installed for decode.

        Shape discipline is what makes this fast: the chunk pads to
        ``prefill_bucket`` tokens and the page-gathered prefix pads to the
        table bucket (tail masked via ``prefix_valid``), so the jitted
        chunk fn compiles once per (prefix-bucket, chunk-bucket) pair and
        is shared process-wide — monolithic ``submit`` re-traces per
        context length instead.
        """
        assert not job.done, "prefill job already completed"
        assert job.remaining > 0, "prefill job was cancelled"
        T = self.page_tokens
        budget = self.prefill_chunk_tokens if token_budget is None else token_budget
        cap = max(T, (budget // T) * T)          # page-aligned chunk ceiling
        take = min(job.remaining, cap)
        c_pad = -(-take // self.prefill_bucket) * self.prefill_bucket
        scratch = self._scratch_pages[job.slot_id]

        # prefix for this chunk: radix pages + suffix pages already written
        # (the cursor is page-aligned on every chunk but the last)
        prefix_pages = job.prefix_pages + job.new_pages[: job.cursor // T]
        p_real = len(prefix_pages)
        p_pad = -(-p_real // self._table_bucket) * self._table_bucket
        prefix_idx = prefix_pages + [scratch] * (p_pad - p_real)

        # staged pages this chunk writes, padded to the bucketed width with
        # the slot's scratch page (pad lanes scatter zeros — harmless)
        w0 = job.cursor // T
        w_real = -(-take // T)
        w_pad = -(-c_pad // T)
        write_idx = job.new_pages[w0 : w0 + w_real]
        write_idx = write_idx + [scratch] * (w_pad - len(write_idx))

        chunk = job.suffix[job.cursor : job.cursor + take]
        tokens = jnp.asarray([chunk + [0] * (c_pad - take)], jnp.int32)
        pos0 = job.cached_tokens + job.cursor    # absolute chunk start
        k_pages, v_pages = self.pool.block_table_view()
        sidecars = self.pool.scale_view() if self.quantized else ()
        out = self._chunk_fn(
            self.params, k_pages, v_pages, *sidecars,
            jnp.asarray(prefix_idx, jnp.int32),
            jnp.asarray(write_idx, jnp.int32),
            tokens,
            jnp.int32(pos0),                     # prefix_valid == chunk start
            jnp.int32(pos0),
            jnp.int32(take),
            jnp.int32(take - 1),                 # final-chunk logit position
            T,
        )
        logits = out[0]
        self.pool.adopt(*out[1:])
        job.cursor += take
        job.chunks_run += 1
        if job.cursor < len(job.suffix):
            return False
        job.first_token = int(greedy_token(logits))
        self._install_job(job)
        return True

    def _install_job(self, job: PrefillJob) -> None:
        """Final chunk landed: install the job's slot for decode (the
        chunked twin of ``submit``'s step 3)."""
        req = job.request
        sid = job.slot_id
        length = len(req.tokens)
        if job.kvsan_hold is not None:
            # ownership moves to the slot's block table (registered via
            # the engine's reachability callback)
            self.pool._san.drop_hold(job.kvsan_hold)
            job.kvsan_hold = None
        self.slots[sid] = _Slot(
            request=req,
            slot_id=sid,
            length=length,
            produced=[job.first_token],
            cached_tokens=job.cached_tokens,
            prefilled_tokens=len(job.suffix),
            reloaded_pages=job.reloaded_pages,
            table=list(job.prefix_pages) + list(job.new_pages),
            owned_from=len(job.prefix_pages),
            prefix_nodes=job.prefix_nodes,
        )
        self.lengths[sid] = length
        self.last_token[sid] = job.first_token
        self._tail_token[sid] = req.tokens[-1]

    def cancel_prefill(self, job: PrefillJob) -> None:
        """Abort a mid-flight prefill job: free the staged pages, release
        the pinned prefix chain and return the reserved slot. Partially
        written pages go back to the free list (pages are always fully
        rewritten before anything attends over them)."""
        assert not job.done, "job already installed; retire via decode"
        self._san_scope(f"cancel_prefill:{job.request.program_id}")
        if job.kvsan_hold is not None:
            self.pool._san.drop_hold(job.kvsan_hold)
            job.kvsan_hold = None
        for page in job.new_pages:
            self.pool.free_device(page)
        self.tree.release_nodes(job.prefix_nodes)
        self.tree.unpin(job.request.program_id)
        self._free_slots.append(job.slot_id)
        self.lengths[job.slot_id] = 0
        job.cursor = len(job.suffix)  # poison: no further prefill_step

    def _reload_prefix(self, tokens: list[int]) -> int:
        """Promote host-resident prefix pages to the device, best-effort.

        Stops at the first failed reload: pages past the break point cannot
        extend the *device-resident* prefix chain, so reloading them would
        burn scarce device pages (and evictions) for zero cached-token
        benefit. The chain is refcount-pinned while it streams so
        ``_ensure_device_page`` can never evict a later chain node to make
        room for an earlier one, and a fully-exhausted pool degrades to a
        shorter cached prefix instead of failing the submit.
        """
        chain = self.tree.match_prefix_any_tier(tokens)
        self.tree.acquire_nodes(chain)
        n = 0
        try:
            for node in chain:
                if node.device_page is not None:
                    continue
                try:
                    self._ensure_device_page()
                except RuntimeError:
                    break            # pool exhausted and nothing evictable
                dp = self.pool.reload_page(node.host_page)
                if dp is None:
                    break
                node.host_page = None
                node.device_page = dp
                n += 1
        finally:
            self.tree.release_nodes(chain)
        return n

    def _alloc_decode_page(self) -> int:
        """One device page for decode state (evicting cold cache if needed).

        Decode-state pages are funded by the pool's decode reserve, so the
        radix-cache budget is NOT consulted here — a cache legitimately
        sitting at its budget must not lose a warm page to every tail-page
        rollover; eviction only kicks in when the pool is genuinely out of
        free pages."""
        self._ensure_device_page(cache_page=False)
        page = self.pool.alloc_device()
        if page is None:
            raise RuntimeError("device pool exhausted and nothing evictable")
        return page

    # -------------------------------------------------------------- decode
    def _decode_impl(self, params, slot_k, slot_v, tokens, lengths):
        cache = {"k": slot_k, "v": slot_v}
        logits, new_cache = self.model.decode(
            params, cache, tokens, lengths, ctx=self.ctx
        )
        return greedy_token(logits), new_cache["k"], new_cache["v"]

    def _paged_decode_impl(
        self, params, k_pages, v_pages, tokens, lengths, tables,
        tail_pages, tail_offsets,
    ):
        logits, k_pages, v_pages = self.model.decode_paged(
            params, k_pages, v_pages, tokens, lengths, tables,
            tail_pages, tail_offsets, ctx=self.ctx,
        )
        return greedy_token(logits), k_pages, v_pages

    def _paged_decode_impl_q(
        self, params, k_pages, v_pages, k_scale, v_scale, tokens, lengths,
        tables, tail_pages, tail_offsets,
    ):
        """Int8-resident decode step: scale sidecars ride in and out (the
        tail-page requantize may grow them)."""
        logits, k_pages, v_pages, k_scale, v_scale = self.model.decode_paged(
            params, k_pages, v_pages, tokens, lengths, tables,
            tail_pages, tail_offsets, k_scale, v_scale, ctx=self.ctx,
        )
        return greedy_token(logits), k_pages, v_pages, k_scale, v_scale

    def step(self, active: "list[int] | None" = None) -> list[Completion]:
        """One continuous-batching decode step across the active slots.

        ``active`` selects which resident slots advance this step (default:
        all of them) — the router's decode pump uses it to pace each slot on
        its own virtual-time deadline while still issuing ONE batched decode
        call. Masked slots stay in the batch but their state is untouched:
        their lengths are not bumped and their row re-feeds the token whose
        KV already occupies the tail position (``_tail_token``), so the
        kernel's write is an idempotent rewrite of existing KV and the
        sampled token for those rows is discarded. Active rows are computed
        independently per batch row, so their tokens are identical whether
        the masked rows are present or not.

        Submitting a new request between steps is safe while other slots are
        mid-decode: the jitted decode donates the pool arrays, but
        ``pool.adopt`` reinstates the committed buffers before ``step``
        returns, so ``submit``'s pool reads/writes never see a donated
        (invalidated) buffer and its freshly-written pages are disjoint from
        every live block table.
        """
        if not self.slots:
            return []
        if active is None:
            active_ids = list(self.slots)
        else:
            active_ids = [sid for sid in active if sid in self.slots]
            if not active_ids:
                return []
        self.steps += 1
        active_set = set(active_ids)
        toks_np = self.last_token.copy()
        for sid in self.slots:
            if sid in active_set:
                # this step writes last_token's KV at the new tail position
                self._tail_token[sid] = self.last_token[sid]
                self.lengths[sid] += 1  # the decoded token extends the ctx
            else:
                # masked: rewrite the existing tail KV instead of clobbering
                # it with the (not-yet-written) last token's
                toks_np[sid] = self._tail_token[sid]
        toks = jnp.asarray(toks_np, jnp.int32)
        lens = jnp.asarray(np.maximum(self.lengths, 1), jnp.int32)
        if self.dense_slots:
            next_tok, self.slot_k, self.slot_v = self._decode_fn(
                self.params, self.slot_k, self.slot_v, toks, lens
            )
        else:
            next_tok = self._paged_step(toks, lens)
        next_tok = np.asarray(next_tok)
        done: list[Completion] = []
        for sid, slot in list(self.slots.items()):
            if sid not in active_set:
                continue
            slot.length = int(self.lengths[sid])
            tok = int(next_tok[sid])
            slot.produced.append(tok)
            self.last_token[sid] = tok
            if len(slot.produced) >= slot.request.max_new_tokens:
                done.append(self._finish(slot))
        return done

    def slot_progress(self) -> dict[int, tuple[str, int, int]]:
        """Per-slot decode progress: ``{slot_id: (pid, produced, budget)}``.
        Introspection for tests and operators (the pump paces decode from
        its own virtual-clock deadlines; this is the engine-truth view to
        check that bookkeeping against)."""
        return {
            sid: (
                slot.request.program_id,
                len(slot.produced),
                slot.request.max_new_tokens,
            )
            for sid, slot in self.slots.items()
        }

    def _paged_step(self, toks, lens):
        """Block-table decode: append KV to tail pages, attend via tables."""
        T = self.page_tokens
        for sid, slot in self.slots.items():
            pos = int(self.lengths[sid]) - 1    # this step's write position
            if pos // T == len(slot.table):     # tail page rolled over
                slot.table.append(self._alloc_decode_page())
        san = self.pool._san
        if san is not None:
            san.set_scope(f"step#{self.steps}")
            for sid, slot in self.slots.items():
                san.check_table(
                    slot.table, int(self.lengths[sid]) - 1,
                    slot.request.program_id,
                )
        # tables are padded to a bucketed page count so jit recompiles at
        # most pages_per_slot / bucket times per engine, while short
        # contexts still attend over far fewer positions than max_seq
        p_used = max(len(s.table) for s in self.slots.values())
        p_pad = -(-p_used // self._table_bucket) * self._table_bucket
        B = self.max_slots
        tables = np.zeros((B, p_pad), np.int32)
        tail_pages = np.zeros(B, np.int32)
        tail_offsets = np.zeros(B, np.int32)
        for sid in range(B):
            slot = self.slots.get(sid)
            if slot is None:
                # inactive batch row: attend over (and write to) its private
                # scratch page — never a live page
                tables[sid, :] = self._scratch_pages[sid]
                tail_pages[sid] = self._scratch_pages[sid]
            else:
                tables[sid, : len(slot.table)] = slot.table
                pos = int(self.lengths[sid]) - 1
                tail_pages[sid] = slot.table[pos // T]
                tail_offsets[sid] = pos % T
        k_pages, v_pages = self.pool.block_table_view()
        sidecars = self.pool.scale_view() if self.quantized else ()
        out = self._paged_decode_fn(
            self.params, k_pages, v_pages, *sidecars, toks, lens,
            jnp.asarray(tables), jnp.asarray(tail_pages),
            jnp.asarray(tail_offsets),
        )
        self.pool.adopt(*out[1:])
        return out[0]

    def _finish(self, slot: _Slot) -> Completion:
        """Persist the slot's full pages into the radix tree, free the slot.

        Paged mode hands the already-resident pages over by id (zero copy,
        and — unlike the dense path — persistence can never fail for lack
        of free pages: the pages exist by construction). Dense mode copies
        slot data back into freshly-allocated pool pages.
        """
        req = slot.request
        self._san_scope(f"finish:{req.program_id}")
        all_tokens = req.tokens + slot.produced[:-1]  # last token has no KV yet
        T = self.page_tokens
        n_full = len(all_tokens) // T
        have = len(self.tree.match_prefix(all_tokens))
        # retire the slot FIRST: the duplicate/tail frees below release
        # pages its block table still lists, and the sanitizer (rightly)
        # treats freeing a page under a live table as an eviction bug
        self.slots.pop(slot.slot_id)
        self._free_slots.append(slot.slot_id)
        self.lengths[slot.slot_id] = 0
        if self.dense_slots:
            new_pages = []
            for p in range(have, n_full):
                self._ensure_device_page()
                page = self.pool.alloc_device()
                if page is None:
                    break
                lo, hi = p * T, (p + 1) * T
                self.pool.write_device_page(
                    page,
                    self.slot_k[:, slot.slot_id, lo:hi],
                    self.slot_v[:, slot.slot_id, lo:hi],
                )
                new_pages.append(page)
            covered = (have + len(new_pages)) * T
        else:
            # duplicates of pages another program inserted first, plus the
            # partially-filled tail page, go back to the free list; the
            # rest transfer ownership to the tree in place
            new_pages = slot.table[have:n_full]
            for p in range(slot.owned_from, have):
                self.pool.free_device(slot.table[p])
            if len(all_tokens) % T and n_full < len(slot.table):
                self.pool.free_device(slot.table[n_full])
            covered = n_full * T
            self.tree.release_nodes(slot.prefix_nodes)
        self.tree.unpin(req.program_id)  # release the pages pinned at submit
        self.tree.insert_chain(
            all_tokens[:covered], new_pages, req.program_id, TypeLabel.BUSY
        )
        # budget enforcement happens where the cache GROWS: handing decode
        # pages to the tree may push it past radix_device_pages, so trim
        # back (typed order, LRU — fresh BUSY pages are the last victims).
        # Decode-state allocations deliberately never evict; see
        # _alloc_decode_page.
        while self._cache_over_budget() and self._evict_one_cache_page():
            pass
        return Completion(
            program_id=req.program_id,
            output_tokens=slot.produced,
            cached_tokens=slot.cached_tokens,
            prefilled_tokens=slot.prefilled_tokens,
            reloaded_pages=slot.reloaded_pages,
        )

    def run_to_completion(self, max_steps: int = 10_000) -> list[Completion]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.slots:
                break
        return out

    # ---------------------------------------------- typed eviction machinery
    def _cache_over_budget(self) -> bool:
        """Paged mode: is the radix cache at/over its device-page budget?

        The pool is over-provisioned by ``decode_reserve_pages`` for decode
        state, so raw free count no longer signals cache pressure — cache-
        growing allocations (reloads) evict back to ``radix_device_pages``
        so the cache cannot squat on the decode reserve indefinitely.
        (Walks the tree; only consulted on cache-growing allocs, which sit
        behind a host-side page copy anyway.)"""
        if self.dense_slots:
            return False
        return self.tree.stats()["device_pages"] >= self.radix_device_pages

    def _ensure_device_page(self, cache_page: bool = True) -> None:
        """Free one device page if the pool is exhausted (typed order) or
        a *cache-growing* allocation would push the radix cache past its
        budgeted share of the pool (``cache_page=False`` for decode-state
        pages, which the decode reserve funds)."""
        over_budget = cache_page and self._cache_over_budget()
        if self.pool.device_free_count() > 0 and not over_budget:
            return
        if self._evict_one_cache_page():
            return
        if self.pool.device_free_count() > 0:
            # over cache budget but every cached page is pinned by live
            # decodes: degrade into the reserve headroom rather than fail
            return
        raise RuntimeError("device pool exhausted and nothing evictable")

    def _evict_one_cache_page(self) -> bool:
        """Spill the best victim page to host (typed order); False if every
        cached page is pinned."""
        for node in self.tree.evictable("gpu"):
            dp = node.device_page
            hp = self.pool.offload_page(dp)  # spill to host if possible
            if hp is not None:
                node.device_page = None
                node.host_page = hp
            else:
                node.device_page = None
                self.pool.free_device(dp)
                self.tree._gc(node)
            self.evicted_pages["gpu"] += 1
            return True
        return False

    def _ensure_host_page(self) -> None:
        if self.pool.host_free_count() > 0:
            return
        for node in self.tree.evictable("cpu"):
            self.pool.free_host(self.tree.evict(node, "cpu"))
            self.evicted_pages["cpu"] += 1
            return

    # --------------------------------------------- MORI program-level verbs
    def offload_program(self, pid: str) -> int:
        """GPU -> host for all of the program's device pages. Returns count."""
        self._san_scope(f"offload_program:{pid}")
        n = 0
        for node in reversed(self.tree.program_nodes(pid)):  # leaves first
            if node.device_page is not None and node.refcount == 0:
                self._ensure_host_page()
                hp = self.pool.offload_page(node.device_page)
                if hp is None:
                    break
                node.device_page = None
                node.host_page = hp
                n += 1
        return n

    def reload_program(self, pid: str) -> int:
        """Host -> GPU for all of the program's pages. Returns count.

        The chain is refcount-held while it streams (mirroring
        ``_reload_prefix``): with the cache at its budget, the budget
        eviction inside ``_ensure_device_page`` would otherwise pick the
        just-reloaded, LRU-stale nodes of this very program as victims —
        a reload that silently undoes itself while billing full PCIe
        traffic."""
        self._san_scope(f"reload_program:{pid}")
        nodes = self.tree.program_nodes(pid)
        self.tree.acquire_nodes(nodes)
        n = 0
        try:
            for node in nodes:
                if node.device_page is None and node.host_page is not None:
                    self._ensure_device_page()
                    dp = self.pool.reload_page(node.host_page)
                    if dp is None:
                        break
                    node.host_page = None
                    node.device_page = dp
                    n += 1
        finally:
            self.tree.release_nodes(nodes)
        return n

    def discard_program(self, pid: str, tier: Tier) -> None:
        self._san_scope(f"discard_program:{pid}:{tier.value}")
        for node in reversed(self.tree.program_nodes(pid)):
            if node.refcount > 0:
                continue
            if tier is Tier.GPU and node.device_page is not None:
                self.pool.free_device(node.device_page)
                node.device_page = None
            if tier is Tier.CPU and node.host_page is not None:
                self.pool.free_host(node.host_page)
                node.host_page = None
            self.tree._gc(node)
        if not any(
            n.device_page is not None or n.host_page is not None
            for n in self.tree.program_nodes(pid)
        ):
            self.tree.release_program(pid)

    def set_label(self, pid: str, label: TypeLabel) -> None:
        self.tree.restamp(pid, label)

    def abort_request(self, pid: str) -> EngineRequest | None:
        """Tear down a mid-decode slot without persisting its KV — the
        failover path: the router requeues the returned request and a
        healthy replica re-prefills the identical context, so no tokens
        are lost. Slot-owned pages (prefix duplicates, decode tail) go
        back to the free list; the shared prefix chain keeps its pages
        and just drops this slot's holds."""
        slot = next(
            (s for s in self.slots.values() if s.request.program_id == pid), None
        )
        if slot is None:
            return None
        self._san_scope(f"abort_request:{pid}")
        # retire the slot FIRST (same reachability rationale as _finish)
        self.slots.pop(slot.slot_id)
        self._free_slots.append(slot.slot_id)
        self.lengths[slot.slot_id] = 0
        if not self.dense_slots:
            for page in slot.table[slot.owned_from:]:
                self.pool.free_device(page)
            self.tree.release_nodes(slot.prefix_nodes)
        self.tree.unpin(pid)
        return slot.request
