"""Per-replica asynchronous transfer executor for the real serving path.

This is what makes the paper's thesis *true on the real engine*: an
``Offload`` or reloading ``Forward`` no longer executes (and acks)
synchronously inside ``MoriRouter.apply_plan`` — it becomes a
:class:`~repro.core.transfers.CopyJob` on the replica's PCIe/NVMe channel
queues (:class:`~repro.core.transfers.TransferChannels`, the same FIFO
model the simulator runs), chunked at *page granularity* on the router's
virtual clock. Pages stream one per chunk tick while the engine keeps
decoding; ``scheduler.on_transfer_complete`` fires only when the last
page lands. Until then the scheduler's ledger shows the transfer open —
so a tool call that returns early finds its offload still pending and the
scheduler's ``CancelTransfer`` path genuinely aborts a partially-streamed
copy: staged host pages are rolled back and the program re-admits warm
off its untouched device pages.

Admission is *endpoint-addressed*: every transfer-bearing action lowers
to a :class:`~repro.core.transfers.CopyRequest` (``src``/``dst`` =
``Endpoint(replica, tier)``), and the plane dispatches on the copy's
geometry — same-replica down-tier (offload), same-replica up-tier
(reload), cross-replica (migrate) — instead of on the action class.

Three streaming strategies cover the engines and copy shapes:

* :class:`_PagedStream` (dense :class:`~repro.serving.engine.Engine`) —
  copies one radix page per chunk through the pool's copy-without-free
  primitives; the *move* commits atomically at job completion (free
  device pages / flip node pointers), so an abort at chunk *k* only has
  *k* staged host pages to discard.
* :class:`_MigrateStream` — the cross-replica shape: one page per chunk
  from the source replica's host tier (device-only pages are first
  staged through the source host) into the destination pool via
  :meth:`~repro.serving.kvpool.PagePool.import_host_page`
  (format-tagged verbatim payload — bf16 raw bits or int8 payload plus
  scale sidecars, matching the pools' shared ``offload_format`` — so
  the landed KV is byte-identical). Commit installs the imported pages
  as a host-resident radix chain on the destination
  (:meth:`~repro.core.radix_tree.TypedRadixTree.insert_host_chain`) and
  retires the source copies; an abort discards the imports and leaves
  the source replica untouched.
* :class:`_AtomicStream` (:class:`~repro.serving.ssm_engine.SsmEngine`
  and anything else bundle-granular) — the whole verb executes at job
  completion; an abort before that moved nothing and rolls back nothing.

Provisioning note: copy-then-commit means both copies of an in-flight
transfer exist simultaneously and the source pages are pinned against
engine-level eviction until commit/abort. Size the physical pools with
headroom above the scheduler's tier budgets for the largest expected
in-flight transfer (real systems reserve staging buffers the same way);
a reload that finds the device pool exhausted mid-stream degrades
gracefully by committing the pages it has staged so far.
"""
from __future__ import annotations

import contextlib
import heapq
import itertools
from typing import Callable

from repro.core.transfers import (
    CopyJob,
    CopyRequest,
    TransferChannels,
    copy_request_for,
)
from repro.core.types import Tier, TransferCost, TypeLabel


class _PagedStream:
    """Page-granular streamed copy against the dense engine's PagePool."""

    def __init__(self, engine, pid: str, kind: str):
        self.engine = engine
        self.pid = pid
        self.kind = kind
        tree, nodes = engine.tree, engine.tree.program_nodes(pid)
        if kind == "offload":
            # leaves first, matching Engine.offload_program; shared-prefix
            # nodes pinned by another running program are left in place
            self.nodes = [
                n for n in reversed(nodes)
                if n.device_page is not None and n.refcount == 0
            ]
        else:
            self.nodes = [
                n for n in nodes
                if n.device_page is None and n.host_page is not None
            ]
        self.copied: list[tuple[object, int]] = []
        self._next = 0
        # protect the nodes from engine-level eviction while the copy is
        # in flight (balanced by unpin in commit/abort)
        tree.pin(pid)
        # kvsan: the source pages of an in-flight CopyJob must stay valid
        # until commit/abort — hold them (and each staged page as it is
        # copied) so a buggy free mid-stream is caught at the free site
        self._san = engine.pool._san
        self._holds: list[int] = []
        if self._san is not None:
            src_tier = "dev" if kind == "offload" else "host"
            src_pages = [
                n.device_page if kind == "offload" else n.host_page
                for n in self.nodes
            ]
            self._holds.append(
                self._san.add_hold(src_tier, src_pages, f"{kind} src:{pid}")
            )

    @property
    def n_units(self) -> int:
        return len(self.nodes)

    def copy_unit(self) -> None:
        """Stage the next page across the wire (source stays valid)."""
        if self._next >= len(self.nodes):
            return
        node = self.nodes[self._next]
        self._next += 1
        pool = self.engine.pool
        if self.kind == "offload":
            if node.device_page is None:
                return  # evicted out from under us before the pin landed
            self.engine._ensure_host_page()
            hp = pool.copy_page_to_host(node.device_page)
            if hp is not None:
                self.copied.append((node, hp))
                if self._san is not None:
                    self._holds.append(self._san.add_hold(
                        "host", [hp], f"offload staging:{self.pid}"
                    ))
        else:
            if node.host_page is None:
                return
            try:
                self.engine._ensure_device_page()
            except RuntimeError:
                # device pool exhausted with nothing evictable (everything
                # pinned): stop staging — the commit lands what was copied
                # and Engine.submit's _reload_prefix retries the rest once
                # decode slots release their pins
                return
            dp = pool.copy_page_to_device(node.host_page)
            if dp is not None:
                self.copied.append((node, dp))
                if self._san is not None:
                    self._holds.append(self._san.add_hold(
                        "dev", [dp], f"reload staging:{self.pid}"
                    ))

    def _settle_holds(self) -> None:
        """The stream is settling (commit or abort): its frees below are
        legitimate, so release every sanitizer hold first."""
        if self._san is not None:
            self._san.set_scope(f"{self.kind} settle:{self.pid}")
            for tok in self._holds:
                self._san.drop_hold(tok)
            self._holds = []

    def commit(self) -> int:
        """All pages landed: atomically retire the source copies."""
        self._settle_holds()
        pool = self.engine.pool
        n = 0
        # the sources retired below are pinned by *this stream's own*
        # tree.pin (released right after the loop) — tell the sanitizer
        # these frees are the pin owner's custody transfer, not eviction
        own = (
            self._san.owned_pin_frees(f"{self.kind} commit:{self.pid}")
            if self._san is not None
            else contextlib.nullcontext()
        )
        with own:
            n = self._commit_pages(pool)
        self.engine.tree.unpin(self.pid)
        return n

    def _commit_pages(self, pool) -> int:
        n = 0
        for node, page in self.copied:
            if self.kind == "offload":
                if node.refcount > 1:
                    # another program pinned this shared-prefix page while
                    # the copy streamed (our own pin accounts for 1):
                    # retiring the device page now would yank warm KV out
                    # from under an active decode — keep it, drop the
                    # staged host copy (mirrors offload_program skipping
                    # pinned nodes)
                    pool.free_host(page)
                    continue
                if node.device_page is not None:
                    pool.free_device(node.device_page)
                    node.device_page = None
                if node.host_page is None:
                    node.host_page = page
                    pool.bill_offload()
                    n += 1
                else:           # engine spilled it itself mid-stream
                    pool.free_host(page)
            else:
                if node.host_page is not None:
                    pool.free_host(node.host_page)
                    node.host_page = None
                if node.device_page is None:
                    node.device_page = page
                    pool.bill_reload()
                    n += 1
                else:
                    pool.free_device(page)
        return n

    def abort(self) -> int:
        """Mid-stream cancel: discard the staged partial page set. The
        source pages were never freed, so the program's KV is intact
        exactly where it was."""
        self._settle_holds()
        pool = self.engine.pool
        for _node, page in self.copied:
            if self.kind == "offload":
                pool.free_host(page)
            else:
                pool.free_device(page)
        self.engine.tree.unpin(self.pid)
        return len(self.copied)


class _AtomicStream:
    """Whole-bundle move at commit time (SSM engine & friends)."""

    def __init__(self, engine, pid: str, kind: str):
        self.engine = engine
        self.pid = pid
        self.kind = kind

    @property
    def n_units(self) -> int:
        return 1

    def copy_unit(self) -> None:
        pass

    def commit(self) -> int:
        if self.kind == "offload":
            return self.engine.offload_program(self.pid)
        return self.engine.reload_program(self.pid)

    def abort(self) -> int:
        return 0  # nothing moved before commit


class _MigrateStream:
    """Page-granular cross-replica move: source host tier → destination
    host tier, one page per chunk, through the pools'
    copy-without-free primitives (format-tagged: the payload moves
    verbatim in the shared ``offload_format``, scale sidecars included). Device-only pages on the source (e.g. a
    shared prefix that was never offloaded) are first staged through the
    source host tier. Commit installs the imported pages as a
    host-resident radix chain on the destination and retires the source
    copies (move semantics); an abort discards the imports and staging
    and leaves the source replica untouched — the same
    cancellable-mid-stream contract as :class:`_PagedStream`."""

    kind = "migrate"

    def __init__(self, src_engine, dst_engine, pid: str):
        self.src = src_engine
        self.dst = dst_engine
        self.pid = pid
        self.nodes = list(src_engine.tree.program_nodes(pid))
        # (src node, imported dst host page, src staging page or None)
        self.copied: list[tuple[object, int, int | None]] = []
        self._next = 0
        self._stopped = False
        # protect the source chain from engine-level eviction while the
        # copy is in flight (balanced by unpin in commit/abort)
        src_engine.tree.pin(pid)
        # kvsan: two pools, two sanitizers — hold the source pages on the
        # source sanitizer and each imported/staged page as it appears
        self._src_san = src_engine.pool._san
        self._dst_san = dst_engine.pool._san
        self._src_holds: list[int] = []
        self._dst_holds: list[int] = []
        if self._src_san is not None:
            host_src = [n.host_page for n in self.nodes if n.host_page is not None]
            dev_src = [
                n.device_page for n in self.nodes
                if n.host_page is None and n.device_page is not None
            ]
            if host_src:
                self._src_holds.append(
                    self._src_san.add_hold("host", host_src, f"migrate src:{pid}")
                )
            if dev_src:
                self._src_holds.append(
                    self._src_san.add_hold("dev", dev_src, f"migrate src:{pid}")
                )

    @property
    def n_units(self) -> int:
        return len(self.nodes)

    def copy_unit(self) -> None:
        """Import the next page into the destination pool. The landed
        chain must stay contiguous from the prefix root, so the first
        page that cannot be sourced or imported stops the stream — commit
        lands the contiguous prefix copied so far."""
        if self._stopped or self._next >= len(self.nodes):
            return
        node = self.nodes[self._next]
        self._next += 1
        staging = None
        src_hp = node.host_page
        if src_hp is None:
            if node.device_page is None:
                self._stopped = True
                return
            # device-only page: stage through the source host tier first
            self.src._ensure_host_page()
            staging = self.src.pool.copy_page_to_host(node.device_page)
            if staging is None:
                self._stopped = True
                return
            if self._src_san is not None:
                self._src_holds.append(self._src_san.add_hold(
                    "host", [staging], f"migrate staging:{self.pid}"
                ))
            src_hp = staging
        self.dst._ensure_host_page()
        hp = self.dst.pool.import_host_page(self.src.pool, src_hp)
        if hp is None:
            self._stopped = True
            return
        if self._dst_san is not None:
            self._dst_holds.append(self._dst_san.add_hold(
                "host", [hp], f"migrate import:{self.pid}"
            ))
        self.copied.append((node, hp, staging))

    def _settle_holds(self) -> None:
        if self._src_san is not None:
            self._src_san.set_scope(f"migrate settle:{self.pid}")
            for tok in self._src_holds:
                self._src_san.drop_hold(tok)
            self._src_holds = []
        if self._dst_san is not None:
            self._dst_san.set_scope(f"migrate settle:{self.pid}")
            for tok in self._dst_holds:
                self._dst_san.drop_hold(tok)
            self._dst_holds = []

    def commit(self) -> int:
        """Install the imported chain on the destination and retire the
        source copies."""
        self._settle_holds()
        for _node, _hp, staging in self.copied:
            if staging is not None:
                self.src.pool.free_host(staging)
        tokens = [t for node, _, _ in self.copied for t in node.tokens]
        pages = [hp for _, hp, _ in self.copied]
        if pages:
            _nodes, duplicates = self.dst.tree.insert_host_chain(
                tokens, pages, self.pid, TypeLabel.IDLE
            )
            for hp in duplicates:
                self.dst.pool.free_host(hp)
        self.src.tree.unpin(self.pid)
        # retire the source copies: frees refcount-0 pages on either tier
        # and releases the program entry once nothing is resident. Pages a
        # live decode on the source still pins are left alone — for those
        # the migrate degrades to a copy, exactly like a shared-prefix
        # offload keeping its device page.
        self.src.discard_program(self.pid, Tier.CPU)
        self.src.discard_program(self.pid, Tier.GPU)
        return len(pages)

    def abort(self) -> int:
        """Mid-stream cancel: drop the imports and staging; the source
        replica's KV is intact exactly where it was."""
        self._settle_holds()
        for _node, hp, staging in self.copied:
            self.dst.pool.free_host(hp)
            if staging is not None:
                self.src.pool.free_host(staging)
        self.src.tree.unpin(self.pid)
        return len(self.copied)


class _PlaneTask:
    """Runtime payload riding on a CopyJob."""

    __slots__ = ("kind", "act", "creq", "stream")

    def __init__(self, kind: str, act, creq: CopyRequest | None = None):
        self.kind = kind
        self.act = act
        self.creq = creq
        self.stream: _PagedStream | _AtomicStream | _MigrateStream | None = None


class ReplicaTransferPlane:
    """Chunked async executor of one replica's Offload / reload jobs.

    Completions run on the router's virtual clock: ``schedule`` targets an
    internal eta-ordered heap, :meth:`advance` drains everything due, and
    the ``wake`` hook tells the router's replay loop to revisit that
    timestamp so no completion is stranded between trace events.
    """

    def __init__(
        self,
        replica_id: int,
        engine,
        cost: TransferCost,
        *,
        wake: Callable[[float], None],
        on_committed: Callable[[CopyJob, str, int, float], None],
        peer_engine: Callable[[int], object] | None = None,
    ):
        self.replica_id = replica_id
        self.engine = engine
        self.wake = wake
        self.on_committed = on_committed
        # resolver for cross-replica copies: replica id -> that replica's
        # engine (installed by the router; a single-replica plane has none)
        self.peer_engine = peer_engine
        # monotone progress counter the router's stall guard reads: every
        # executed chunk tick counts, whether or not its job ever commits
        self.chunks_executed = 0
        self._heap: list[tuple[float, int, Callable[[float], None]]] = []
        self._seq = itertools.count()
        self.channels = TransferChannels(
            cost=cost,
            schedule=self._schedule,
            on_start=self._job_start,
            on_chunk=self._job_chunk,
            on_done=self._job_done,
        )

    # ---------------------------------------------------------- virtual clock
    def _schedule(self, eta: float, fn: Callable[[float], None]) -> None:
        heapq.heappush(self._heap, (eta, next(self._seq), fn))
        self.wake(eta)

    def advance(self, now: float) -> None:
        """Run every due chunk/completion, in eta order, stamping each with
        its own eta (not ``now``) so ledger acks carry faithful times."""
        while self._heap and self._heap[0][0] <= now:
            eta, _, fn = heapq.heappop(self._heap)
            fn(eta)

    # ------------------------------------------------------------ admission
    def enqueue(self, act, now: float) -> None:
        """Thin adapter from the action IR: lower to a CopyRequest."""
        self.enqueue_request(copy_request_for(act), now, act=act)

    def enqueue_request(self, creq: CopyRequest, now: float, act=None) -> None:
        """Endpoint-addressed admission: the request's geometry picks the
        kind and channel; this plane must be the executing (destination)
        replica."""
        assert creq.exec_replica == self.replica_id, (
            f"copy executes on replica {creq.exec_replica}, "
            f"enqueued on plane {self.replica_id}"
        )
        task = _PlaneTask(creq.kind, act, creq)
        self.channels.enqueue(creq.job(payload=task), now)

    # ------------------------------------------------------- job lifecycle
    def _job_start(self, job: CopyJob, now: float) -> None:
        """Bind the page set when the job reaches the channel head — not at
        enqueue: a reload queued behind the same program's offload must see
        the host pages that offload's commit is about to produce."""
        task: _PlaneTask = job.payload
        if task.kind == "migrate":
            if self.peer_engine is None:
                raise RuntimeError(
                    "cross-replica copy enqueued on a plane with no "
                    "peer_engine resolver"
                )
            src_engine = self.peer_engine(task.creq.src.replica)
            task.stream = _MigrateStream(src_engine, self.engine, job.pid)
        elif hasattr(self.engine, "tree") and hasattr(
            getattr(self.engine, "pool", None), "copy_page_to_host"
        ):
            task.stream = _PagedStream(self.engine, job.pid, task.kind)
        else:
            task.stream = _AtomicStream(self.engine, job.pid, task.kind)
        job.n_chunks = max(1, task.stream.n_units)

    def _job_chunk(self, job: CopyJob, now: float) -> None:
        task: _PlaneTask = job.payload
        self.chunks_executed += 1
        task.stream.copy_unit()

    def _job_done(self, job: CopyJob, now: float) -> None:
        task: _PlaneTask = job.payload
        pages = task.stream.commit()
        self.on_committed(job, task.kind, pages, now)

    # ---------------------------------------------------------- cancellation
    def abort(self, action_id: int, now: float) -> tuple[CopyJob, int] | None:
        """Cancel a queued job or abort an in-stream one; returns the job
        and the number of staged pages rolled back."""
        job = self.channels.abort(action_id, now)
        if job is None:
            return None
        task: _PlaneTask = job.payload
        rolled = task.stream.abort() if task.stream is not None else 0
        return job, rolled

    def abort_pid(self, pid: str, now: float) -> list[tuple[CopyJob, int]]:
        out = []
        for job in list(self.channels.jobs()):
            if job.pid == pid:
                res = self.abort(job.action_id, now)
                if res is not None:
                    out.append(res)
        return out

    # -------------------------------------------------------------- queries
    def in_flight(self) -> bool:
        return self.channels.in_flight()

    def pending_bytes(self) -> int:
        return self.channels.pending_bytes()

    def describe_jobs(self) -> list[str]:
        """Human-readable in-flight/queued jobs, for stall diagnostics."""
        return [
            f"{j.pid}#{j.action_id} {j.payload.kind} "
            f"({j.chunks_done}/{max(1, j.n_chunks)} chunks, {j.nbytes}B)"
            for j in self.channels.jobs()
        ]
