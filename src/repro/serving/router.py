"""MORI router over real engine replicas (the paper's Fig. 6 front door).

The router is the real-engine executor of the scheduler's
:class:`~repro.core.actions.PlacementPlan` protocol: every lifecycle event
returns a plan and :meth:`MoriRouter.apply_plan` turns its actions into
real page movements in each engine's two-tier pool. Workload replay runs
on a *virtual clock* (tool-call sleeps advance time instantly; inference
advances it by the trace's recorded reasoning wall-time) while the engine
compute itself is real JAX execution — so policy behaviour is timed
faithfully and the data plane actually runs.

Transfers execute in one of two modes:

* **async (default)** — an ``Offload`` or reloading ``Forward`` becomes a
  chunked, page-granular copy job on the replica's
  :class:`~repro.serving.transfer_plane.ReplicaTransferPlane` (PCIe and
  NVMe channel queues, bandwidths from
  :class:`~repro.core.types.TransferCost` or a
  :class:`~repro.sim.hardware.HwConfig`). Copy chunks interleave with
  engine decode steps on the virtual clock, ``on_transfer_complete`` acks
  only when the last page lands, and a tool call that returns early finds
  its offload still pending — the scheduler's ``CancelTransfer`` path
  aborts the partially-streamed copy and the program re-admits warm
  (``RouterMetrics.cancelled_offloads``). Decode steps taken while a
  transfer was in flight are counted in
  ``RouterMetrics.overlap_decode_steps`` — the paper's idle-window
  overlap, measured on the real path.
* **sync (``sync_transfers=True``)** — the pre-async compatibility mode:
  every transfer-bearing action executes and acks inside ``apply_plan``,
  keeping the ledger empty between events. This mode reproduces the
  golden byte-identical sim↔router action streams of
  ``tests/test_plan_protocol.py``.

Action semantics on the real path:

* ``Forward(source_tier=GPU)`` — warm: submit against the cached pages.
* ``Forward(source_tier=CPU)`` — reload host pages over PCIe, then submit.
* ``Forward(source_tier=SSD)`` — reload billed to the NVMe channel
  (``RouterMetrics.nvme_reloaded_pages``).
* ``Forward(recompute=True)`` — Waiting-tier re-admission: the program's
  stale pages (if any survived) are dropped so the engine genuinely
  re-prefills the full context.
* ``Migrate`` — rejected: separate engine processes cannot exchange pages.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass

from repro.core import SCHEDULERS, SchedulerConfig, TierCapacity
from repro.core.actions import (
    Action,
    CancelTransfer,
    Discard,
    Forward,
    Migrate,
    Offload,
    PlacementPlan,
    SetLabel,
)
from repro.core.transfers import CopyJob
from repro.core.types import ProgramTrace, Tier, TransferCost
from repro.serving.engine import Engine, EngineRequest
from repro.serving.transfer_plane import ReplicaTransferPlane


@dataclass
class RouterMetrics:
    steps_completed: int = 0
    tokens_generated: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    offloaded_pages: int = 0
    reloaded_pages: int = 0          # PCIe-billed (CPU-tier) reloads
    nvme_reloaded_pages: int = 0     # NVMe-billed (SSD-tier) reloads
    recompute_submits: int = 0
    gated_events: int = 0
    # async transfer plane (zero in sync_transfers mode)
    overlap_decode_steps: int = 0    # decode steps with a transfer in flight
    cancelled_offloads: int = 0      # offloads aborted by early tool return
    cancelled_pages: int = 0         # staged pages rolled back by aborts
    peak_inflight_bytes: int = 0     # high-water mark of the transfer ledger

    @property
    def cache_hit_rate(self) -> float:
        total = self.cached_tokens + self.prefilled_tokens
        return self.cached_tokens / total if total else 0.0


class MoriRouter:
    """Front door: program-aware routing + placement over real engines."""

    def __init__(
        self,
        engines: list[Engine],
        *,
        scheduler: str = "mori",
        gpu_capacity_bytes: int | None = None,
        cpu_capacity_bytes: int | None = None,
        ssd_capacity_bytes: int = 0,
        config: SchedulerConfig | None = None,
        record_plans: bool = False,
        sync_transfers: bool = False,
        xfer_cost: TransferCost | None = None,
        hw: "object | None" = None,   # repro.sim.hardware.HwConfig
    ):
        self.engines = engines
        cfg0 = engines[0].cfg
        self.kv_bytes_per_token = (
            cfg0.num_layers * 2 * cfg0.num_kv_heads * cfg0.head_dim * 2
        )
        pool = engines[0].pool
        # default GPU budget = the pool's *cache* capacity: the block-table
        # engine provisions extra pages as decode state (the HBM its dense
        # slot buffers used to occupy) and the scheduler must not place
        # programs into that reserve
        reserve = getattr(engines[0], "decode_reserve_pages", 0)
        gpu_cap = (
            gpu_capacity_bytes
            if gpu_capacity_bytes is not None
            else (pool.n_device_pages - reserve) * pool.page_bytes
        )
        cpu_cap = (
            cpu_capacity_bytes
            if cpu_capacity_bytes is not None
            else pool.n_host_pages * pool.page_bytes
        )
        config = config or SchedulerConfig(tick_interval_s=5.0)
        if config.migrate_on_pressure:
            raise ValueError(
                "migrate_on_pressure is simulator-only: real engine replicas "
                "are separate processes and cannot exchange KV pages"
            )
        self.sched = SCHEDULERS[scheduler](
            len(engines),
            TierCapacity(gpu_cap, cpu_cap, ssd_capacity_bytes),
            config,
        )
        self.metrics = RouterMetrics()
        self.record_plans = record_plans
        self.action_log: list[Action] = []
        self.output_log: dict[str, list[int]] = {}
        self._pending: dict[str, tuple[EngineRequest, int]] = {}
        self._dispatched: dict[str, Forward] = {}

        self.sync_transfers = sync_transfers
        if xfer_cost is None:
            # channel bandwidths from the hardware model when one is given
            # (mirrors Simulation.__init__), else the TransferCost defaults
            xfer_cost = (
                TransferCost(pcie_bytes_per_s=hw.pcie_bw)
                if hw is not None
                else TransferCost()
            )
        self.xfer_cost = xfer_cost
        # set only while replay() runs; without a virtual clock (direct
        # apply_plan use) transfers fall back to synchronous execution
        self._push = None
        self.planes: list[ReplicaTransferPlane] = [
            ReplicaTransferPlane(
                i, eng, xfer_cost,
                wake=self._wake, on_committed=self._plane_committed,
            )
            for i, eng in enumerate(engines)
        ]

    # -------------------------------------------------------------- helpers
    @property
    def _async(self) -> bool:
        """Async execution needs both the knob and a live virtual clock."""
        return not self.sync_transfers and self._push is not None

    def _wake(self, eta: float) -> None:
        """A plane scheduled a chunk at ``eta``: make sure the replay loop
        visits that timestamp even if no trace event falls on it."""
        if self._push is not None:
            self._push(eta, lambda t: None)

    def _advance_planes(self, now: float) -> None:
        for plane in self.planes:
            plane.advance(now)

    def _planes_busy(self) -> bool:
        return any(p.in_flight() for p in self.planes)

    # ------------------------------------------------------- plan executor
    def apply_plan(self, plan: PlacementPlan) -> None:
        """Execute a scheduler plan as real page movements — queueing
        transfer-bearing actions on the async planes, or executing and
        acknowledging them synchronously in ``sync_transfers`` mode."""
        if self.record_plans and plan.actions:
            self.action_log.extend(plan.actions)
        for act in plan:
            if isinstance(act, Forward):
                self._exec_forward(act, plan.now)
            elif isinstance(act, Offload):
                self._exec_offload(act, plan.now)
            elif isinstance(act, Discard):
                if act.replica is not None:
                    # abort any copy still streaming this program's pages.
                    # On program teardown the ledger already dropped the
                    # records; on a live-program eviction (CPU/SSD overflow
                    # passes) they are still open, and must be closed here —
                    # a stale open offload would later match
                    # _cancel_inflight_offload and cancel the wrong transfer
                    if not self.sync_transfers:
                        for job, rolled in self.planes[act.replica].abort_pid(
                            act.pid, plan.now
                        ):
                            self.metrics.cancelled_pages += rolled
                            self.sched.ledger.cancel(job.action_id)
                    # the logical SSD tier is backed by the host pool on the
                    # real path — freeing it frees host pages
                    tier = Tier.CPU if act.tier is Tier.SSD else act.tier
                    self.engines[act.replica].discard_program(act.pid, tier)
            elif isinstance(act, SetLabel):
                if act.replica is not None:
                    self.engines[act.replica].set_label(act.pid, act.label)
            elif isinstance(act, CancelTransfer):
                self._exec_cancel(act, plan.now)
            elif isinstance(act, Migrate):
                raise RuntimeError(
                    "Migrate reached the real router; construct the scheduler "
                    "with migrate_on_pressure=False"
                )
        self.metrics.peak_inflight_bytes = max(
            self.metrics.peak_inflight_bytes, self.sched.ledger.in_flight_bytes()
        )

    def _exec_forward(self, act: Forward, now: float) -> None:
        if act.source_tier in (Tier.CPU, Tier.SSD):
            if self._async:
                # queue the reload; the program dispatches only when the
                # last page lands (_plane_committed)
                self.planes[act.replica].enqueue(act, now)
                return
            pages = self.engines[act.replica].reload_program(act.pid)
            if act.source_tier is Tier.SSD:
                self.metrics.nvme_reloaded_pages += pages
            else:
                self.metrics.reloaded_pages += pages
            self._ack(act.pid, act.action_id, now)
        elif act.recompute:
            # Waiting-tier re-admission: drop any pages that survived
            # engine-side eviction so the full context is re-prefilled —
            # what the scheduler billed is what the engine now does
            eng = self.engines[act.replica]
            eng.discard_program(act.pid, Tier.GPU)
            eng.discard_program(act.pid, Tier.CPU)
            self.metrics.recompute_submits += 1
        self._dispatched[act.pid] = act

    def _exec_offload(self, act: Offload, now: float) -> None:
        if self._async and act.nbytes > 0:
            self.planes[act.replica].enqueue(act, now)
            return
        self.metrics.offloaded_pages += self.engines[act.replica].offload_program(
            act.pid
        )
        self._ack(act.pid, act.action_id, now)

    def _exec_cancel(self, act: CancelTransfer, now: float) -> None:
        if self.sync_transfers:
            return  # transfers are synchronous: never still queued
        res = self.planes[act.replica].abort(act.target_action_id, now)
        if res is not None:
            job, rolled = res
            self.metrics.cancelled_offloads += 1
            self.metrics.cancelled_pages += rolled

    def _plane_committed(
        self, job: CopyJob, kind: str, pages: int, now: float
    ) -> None:
        """Async transfer fully landed: bill it, release any gated forward,
        and acknowledge the scheduler's ledger record."""
        if kind == "offload":
            self.metrics.offloaded_pages += pages
        else:
            act: Forward = job.payload.act
            if act.source_tier is Tier.SSD:
                self.metrics.nvme_reloaded_pages += pages
            else:
                self.metrics.reloaded_pages += pages
            self._dispatched[act.pid] = act
        self._ack(job.pid, job.action_id, now)

    def _ack(self, pid: str, action_id: int, now: float) -> None:
        self.apply_plan(self.sched.on_transfer_complete(pid, action_id, now))

    # ------------------------------------------------------------- replay
    def replay(
        self,
        traces: list[ProgramTrace],
        *,
        vocab_size: int,
        max_new_tokens: int = 8,
        seed: int = 0,
    ) -> RouterMetrics:
        """Replay traces concurrently on the virtual clock."""
        import random

        rng = random.Random(seed)
        q: list[tuple[float, int, object]] = []
        seq = itertools.count()
        state: dict[str, dict] = {}

        def push(t, fn):
            heapq.heappush(q, (t, next(seq), fn))

        self._push = push

        def issue(pid: str, step_idx: int, now: float):
            st = state[pid]
            trace: ProgramTrace = st["trace"]
            rec = trace.steps[step_idx]
            # synthesize a token context of the recorded length (prefix-stable)
            want = max(
                st["ctx_len"] + 1,
                min(rec.input_tokens // st["scale"], st["max_ctx"]),
            )
            grow = want - st["ctx_len"]
            st["tokens"].extend(
                rng.randrange(2, vocab_size) for _ in range(grow)
            )
            st["ctx_len"] = want
            req = EngineRequest(
                program_id=pid,
                tokens=list(st["tokens"]),
                max_new_tokens=max_new_tokens,
            )
            self._pending[pid] = (req, step_idx)
            self.apply_plan(self.sched.request_arrived(pid, want, now))
            if pid not in self._dispatched:
                self.metrics.gated_events += 1

        def run_decode(eng, replica: int, pid: str, req, wall_s: float, now: float):
            """Run the submitted request to completion. In async mode the
            decode steps spread over the virtual window [now, now+wall] and
            the transfer planes advance between steps — a copy chunk lands
            *during* decode exactly as the paper's overlap requires."""
            if not self._async:
                return eng.run_to_completion()
            n_est = max(1, req.max_new_tokens - 1)
            dt = wall_s / n_est if wall_s > 0 else 0.0
            t, done = now, []
            for _ in range(20_000):
                busy = self.planes[replica].in_flight()
                done.extend(eng.step())
                if busy:
                    self.metrics.overlap_decode_steps += 1
                t = min(now + wall_s, t + dt)
                self._advance_planes(t)
                if any(c.program_id == pid for c in done):
                    return done
            raise RuntimeError("decode did not complete")

        def finish_step(pid: str, now: float):
            st = state[pid]
            req, step_idx = self._pending.pop(pid)
            act = self._dispatched.pop(pid)
            eng = self.engines[act.replica]
            eng.submit(req)
            self.sched.notify_inference_started(pid, now)
            trace: ProgramTrace = st["trace"]
            rec = trace.steps[step_idx]
            done = run_decode(eng, act.replica, pid, req, rec.reasoning_wall_s, now)
            comp = next(c for c in done if c.program_id == pid)
            self.metrics.steps_completed += 1
            self.metrics.tokens_generated += len(comp.output_tokens)
            self.metrics.cached_tokens += comp.cached_tokens
            self.metrics.prefilled_tokens += comp.prefilled_tokens
            self.output_log.setdefault(pid, []).extend(comp.output_tokens)
            st["tokens"].extend(comp.output_tokens[:-1])
            st["ctx_len"] = len(st["tokens"])
            end = now + rec.reasoning_wall_s
            if self._async:
                self._advance_planes(end)
            self.apply_plan(
                self.sched.request_completed(pid, len(comp.output_tokens), end)
            )
            nxt = step_idx + 1
            if nxt < len(trace.steps) and nxt < st["max_steps"]:
                push(end + rec.tool_duration_s, lambda t, p=pid, n=nxt: issue(p, n, t))
            else:
                self.apply_plan(self.sched.program_finished(pid, end))

        # register programs
        max_seq = self.engines[0].max_seq
        for tr in traces:
            pid = tr.program_id
            scale = max(1, tr.steps[0].input_tokens // 48)
            state[pid] = {
                "trace": tr,
                "tokens": [],
                "ctx_len": 0,
                "scale": scale,
                "max_ctx": max_seq - (max_new_tokens + 2) * len(tr.steps) - 8,
                "max_steps": len(tr.steps),
            }
            self.sched.program_arrived(pid, self.kv_bytes_per_token, 0.0)
            push(0.0, lambda t, p=pid: issue(p, 0, t))

        def drain(now: float) -> None:
            """Execute any requests the scheduler has released to an engine."""
            progress = True
            while progress:
                progress = False
                for pid in list(self._pending):
                    if pid in self._dispatched:
                        finish_step(pid, now)
                        progress = True

        tick = self.sched.config.tick_interval_s
        next_tick = tick
        now = 0.0
        guard = 0
        while q:
            guard += 1
            if guard > 200_000:
                raise RuntimeError("router replay did not terminate")
            t, _, fn = heapq.heappop(q)
            now = max(now, t)
            while next_tick <= now:
                self._advance_planes(next_tick)
                self.apply_plan(self.sched.tick(next_tick))
                drain(next_tick)
                next_tick += tick
            self._advance_planes(now)
            fn(now)
            drain(now)
        # final drain: keep ticking until nothing is pending anywhere —
        # including transfers still streaming on the planes
        for _ in range(512):
            if not self._pending and not self._planes_busy():
                break
            now += tick
            self._advance_planes(now)
            self.apply_plan(self.sched.tick(now))
            drain(now)
        else:
            raise RuntimeError(
                "router replay did not drain: requests or transfers still "
                "pending after 512 final ticks (transfer slower than "
                "512 x tick_interval_s?)"
            )
        self._push = None
        return self.metrics


def snapshot_state(router: MoriRouter) -> dict:
    """Serializable control-plane snapshot (fault tolerance / restart)."""
    sched = router.sched
    return {
        "programs": {
            pid: {
                "tier": p.tier.value,
                "replica": p.replica,
                "context_tokens": p.context_tokens,
                "label": p.label.value,
                "steps_completed": p.steps_completed,
            }
            for pid, p in sched.programs.items()
        },
        "gpu_used": [r.gpu_used for r in sched.replicas],
        "cpu_used": [r.cpu_used for r in sched.replicas],
    }
