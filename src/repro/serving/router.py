"""MORI router over real engine replicas (the paper's Fig. 6 front door).

The router is the real-engine executor of the scheduler's
:class:`~repro.core.actions.PlacementPlan` protocol: every lifecycle event
returns a plan, :meth:`MoriRouter.apply_plan` turns its actions into real
page movements in each engine's two-tier pool, and — because engine
transfers here are synchronous — each transfer-bearing action is
acknowledged back to the scheduler immediately via
``on_transfer_complete``, keeping the :class:`~repro.core.ledger.
TransferLedger` empty between events. Workload replay runs on a *virtual
clock* (tool-call sleeps advance time instantly; inference advances it by
the trace's recorded reasoning wall-time) while the engine compute itself
is real JAX execution — so policy behaviour is timed faithfully and the
data plane actually runs.

Action semantics on the real path:

* ``Forward(source_tier=GPU)`` — warm: submit against the cached pages.
* ``Forward(source_tier=CPU)`` — reload host pages over PCIe, then submit.
* ``Forward(source_tier=SSD)`` — reload billed to the NVMe channel
  (``RouterMetrics.nvme_reloaded_pages``); previously this was silently
  mis-accounted as PCIe via the mutable ``reload_src`` side-channel.
* ``Forward(recompute=True)`` — Waiting-tier re-admission: the program's
  stale pages (if any survived) are dropped so the engine genuinely
  re-prefills the full context; previously the flag was ignored.
* ``Migrate`` — rejected: separate engine processes cannot exchange pages.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass

from repro.core import SCHEDULERS, SchedulerConfig, TierCapacity
from repro.core.actions import (
    Action,
    CancelTransfer,
    Discard,
    Forward,
    Migrate,
    Offload,
    PlacementPlan,
    SetLabel,
)
from repro.core.types import ProgramTrace, Tier
from repro.serving.engine import Engine, EngineRequest


@dataclass
class RouterMetrics:
    steps_completed: int = 0
    tokens_generated: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    offloaded_pages: int = 0
    reloaded_pages: int = 0          # PCIe-billed (CPU-tier) reloads
    nvme_reloaded_pages: int = 0     # NVMe-billed (SSD-tier) reloads
    recompute_submits: int = 0
    gated_events: int = 0

    @property
    def cache_hit_rate(self) -> float:
        total = self.cached_tokens + self.prefilled_tokens
        return self.cached_tokens / total if total else 0.0


class MoriRouter:
    """Front door: program-aware routing + placement over real engines."""

    def __init__(
        self,
        engines: list[Engine],
        *,
        scheduler: str = "mori",
        gpu_capacity_bytes: int | None = None,
        cpu_capacity_bytes: int | None = None,
        ssd_capacity_bytes: int = 0,
        config: SchedulerConfig | None = None,
        record_plans: bool = False,
    ):
        self.engines = engines
        cfg0 = engines[0].cfg
        self.kv_bytes_per_token = (
            cfg0.num_layers * 2 * cfg0.num_kv_heads * cfg0.head_dim * 2
        )
        pool = engines[0].pool
        gpu_cap = (
            gpu_capacity_bytes
            if gpu_capacity_bytes is not None
            else pool.n_device_pages * pool.page_bytes
        )
        cpu_cap = (
            cpu_capacity_bytes
            if cpu_capacity_bytes is not None
            else pool.n_host_pages * pool.page_bytes
        )
        config = config or SchedulerConfig(tick_interval_s=5.0)
        if config.migrate_on_pressure:
            raise ValueError(
                "migrate_on_pressure is simulator-only: real engine replicas "
                "are separate processes and cannot exchange KV pages"
            )
        self.sched = SCHEDULERS[scheduler](
            len(engines),
            TierCapacity(gpu_cap, cpu_cap, ssd_capacity_bytes),
            config,
        )
        self.metrics = RouterMetrics()
        self.record_plans = record_plans
        self.action_log: list[Action] = []
        self._pending: dict[str, tuple[EngineRequest, int]] = {}
        self._dispatched: dict[str, Forward] = {}

    # ------------------------------------------------------- plan executor
    def apply_plan(self, plan: PlacementPlan) -> None:
        """Execute a scheduler plan as real page movements, acknowledging
        each transfer synchronously."""
        if self.record_plans and plan.actions:
            self.action_log.extend(plan.actions)
        for act in plan:
            if isinstance(act, Forward):
                self._exec_forward(act, plan.now)
            elif isinstance(act, Offload):
                self.metrics.offloaded_pages += self.engines[
                    act.replica
                ].offload_program(act.pid)
                self._ack(act.pid, act.action_id, plan.now)
            elif isinstance(act, Discard):
                if act.replica is not None:
                    # the logical SSD tier is backed by the host pool on the
                    # real path — freeing it frees host pages
                    tier = Tier.CPU if act.tier is Tier.SSD else act.tier
                    self.engines[act.replica].discard_program(act.pid, tier)
            elif isinstance(act, SetLabel):
                if act.replica is not None:
                    self.engines[act.replica].set_label(act.pid, act.label)
            elif isinstance(act, CancelTransfer):
                pass  # transfers are synchronous here: never still queued
            elif isinstance(act, Migrate):
                raise RuntimeError(
                    "Migrate reached the real router; construct the scheduler "
                    "with migrate_on_pressure=False"
                )

    def _exec_forward(self, act: Forward, now: float) -> None:
        if act.source_tier in (Tier.CPU, Tier.SSD):
            pages = self.engines[act.replica].reload_program(act.pid)
            if act.source_tier is Tier.SSD:
                self.metrics.nvme_reloaded_pages += pages
            else:
                self.metrics.reloaded_pages += pages
            self._ack(act.pid, act.action_id, now)
        elif act.recompute:
            # Waiting-tier re-admission: drop any pages that survived
            # engine-side eviction so the full context is re-prefilled —
            # what the scheduler billed is what the engine now does
            eng = self.engines[act.replica]
            eng.discard_program(act.pid, Tier.GPU)
            eng.discard_program(act.pid, Tier.CPU)
            self.metrics.recompute_submits += 1
        self._dispatched[act.pid] = act

    def _ack(self, pid: str, action_id: int, now: float) -> None:
        self.apply_plan(self.sched.on_transfer_complete(pid, action_id, now))

    # ------------------------------------------------------------- replay
    def replay(
        self,
        traces: list[ProgramTrace],
        *,
        vocab_size: int,
        max_new_tokens: int = 8,
        seed: int = 0,
    ) -> RouterMetrics:
        """Replay traces concurrently on the virtual clock."""
        import random

        rng = random.Random(seed)
        q: list[tuple[float, int, object]] = []
        seq = itertools.count()
        state: dict[str, dict] = {}

        def push(t, fn):
            heapq.heappush(q, (t, next(seq), fn))

        def issue(pid: str, step_idx: int, now: float):
            st = state[pid]
            trace: ProgramTrace = st["trace"]
            rec = trace.steps[step_idx]
            # synthesize a token context of the recorded length (prefix-stable)
            want = max(
                st["ctx_len"] + 1,
                min(rec.input_tokens // st["scale"], st["max_ctx"]),
            )
            grow = want - st["ctx_len"]
            st["tokens"].extend(
                rng.randrange(2, vocab_size) for _ in range(grow)
            )
            st["ctx_len"] = want
            req = EngineRequest(
                program_id=pid,
                tokens=list(st["tokens"]),
                max_new_tokens=max_new_tokens,
            )
            self._pending[pid] = (req, step_idx)
            self.apply_plan(self.sched.request_arrived(pid, want, now))
            if pid not in self._dispatched:
                self.metrics.gated_events += 1

        def finish_step(pid: str, now: float):
            st = state[pid]
            req, step_idx = self._pending.pop(pid)
            act = self._dispatched.pop(pid)
            eng = self.engines[act.replica]
            eng.submit(req)
            self.sched.notify_inference_started(pid, now)
            done = eng.run_to_completion()
            comp = next(c for c in done if c.program_id == pid)
            self.metrics.steps_completed += 1
            self.metrics.tokens_generated += len(comp.output_tokens)
            self.metrics.cached_tokens += comp.cached_tokens
            self.metrics.prefilled_tokens += comp.prefilled_tokens
            st["tokens"].extend(comp.output_tokens[:-1])
            st["ctx_len"] = len(st["tokens"])
            trace: ProgramTrace = st["trace"]
            rec = trace.steps[step_idx]
            end = now + rec.reasoning_wall_s
            self.apply_plan(
                self.sched.request_completed(pid, len(comp.output_tokens), end)
            )
            nxt = step_idx + 1
            if nxt < len(trace.steps) and nxt < st["max_steps"]:
                push(end + rec.tool_duration_s, lambda t, p=pid, n=nxt: issue(p, n, t))
            else:
                self.apply_plan(self.sched.program_finished(pid, end))

        # register programs
        max_seq = self.engines[0].max_seq
        for tr in traces:
            pid = tr.program_id
            scale = max(1, tr.steps[0].input_tokens // 48)
            state[pid] = {
                "trace": tr,
                "tokens": [],
                "ctx_len": 0,
                "scale": scale,
                "max_ctx": max_seq - (max_new_tokens + 2) * len(tr.steps) - 8,
                "max_steps": len(tr.steps),
            }
            self.sched.program_arrived(pid, self.kv_bytes_per_token, 0.0)
            push(0.0, lambda t, p=pid: issue(p, 0, t))

        def drain(now: float) -> None:
            """Execute any requests the scheduler has released to an engine."""
            progress = True
            while progress:
                progress = False
                for pid in list(self._pending):
                    if pid in self._dispatched:
                        finish_step(pid, now)
                        progress = True

        tick = self.sched.config.tick_interval_s
        next_tick = tick
        now = 0.0
        guard = 0
        while q:
            guard += 1
            if guard > 100_000:
                raise RuntimeError("router replay did not terminate")
            t, _, fn = heapq.heappop(q)
            now = max(now, t)
            while next_tick <= now:
                self.apply_plan(self.sched.tick(next_tick))
                drain(next_tick)
                next_tick += tick
            fn(now)
            drain(now)
        # final drain: keep ticking until nothing is pending
        for _ in range(256):
            if not self._pending:
                break
            now += tick
            self.apply_plan(self.sched.tick(now))
            drain(now)
        return self.metrics


def snapshot_state(router: MoriRouter) -> dict:
    """Serializable control-plane snapshot (fault tolerance / restart)."""
    sched = router.sched
    return {
        "programs": {
            pid: {
                "tier": p.tier.value,
                "replica": p.replica,
                "context_tokens": p.context_tokens,
                "label": p.label.value,
                "steps_completed": p.steps_completed,
            }
            for pid, p in sched.programs.items()
        },
        "gpu_used": [r.gpu_used for r in sched.replicas],
        "cpu_used": [r.cpu_used for r in sched.replicas],
    }
