"""MORI router over real engine replicas (the paper's Fig. 6 front door).

The router is the real-engine executor of the scheduler's
:class:`~repro.core.actions.PlacementPlan` protocol: every lifecycle event
returns a plan and :meth:`MoriRouter.apply_plan` turns its actions into
real page movements in each engine's two-tier pool. Workload replay runs
on a *virtual clock* (tool-call sleeps advance time instantly; inference
advances it by the trace's recorded reasoning wall-time) while the engine
compute itself is real JAX execution — so policy behaviour is timed
faithfully and the data plane actually runs.

Replay executes decode through a clocked **decode pump**: every replica
holds a queue of resident program slots, and at each virtual-clock quantum
every replica with due slots takes ONE batched ``Engine.step`` that
advances all of them together. New ``Forward``s submit into free engine
slots while other slots are mid-decode, each program's decode steps are
paced across its own recorded ``reasoning_wall_s`` window (a slow program
never monopolizes the replica), completions retire per-slot when their
window ends, and transfer-plane chunks interleave with pump steps — so
the measured compute/transfer overlap is against genuinely batched
decode. The scheduler reads *real* engine occupancy through its slot
probe and is poked via ``on_slot_freed`` the moment a batch slot opens,
so gated programs join a running batch mid-flight.
``MoriRouter(serial_decode=True)`` keeps the pre-pump serialized
replay — each dispatched request runs to completion before the next
event — pinned token-identical by ``tests/test_decode_pump.py``'s golden
corpus.

``MoriRouter(chunked_prefill=True)`` makes prefill itself preemptible:
admission goes through the engine's two-phase ``begin_submit`` /
``prefill_step`` API, the ``_PumpSlot`` sits in a *prefilling* state
(owning its engine slot, visible to occupancy probes, never stepping)
while the pump runs one ``prefill_token_budget``-bounded chunk per settle
visit, and due decode steps interleave between chunks instead of stalling
behind a whole prefill (``RouterMetrics.prefill_interleaved_steps``).
Chunk shapes are bucketed so the jitted chunk prefill compiles once per
bucket process-wide — monolithic submit re-traces per context length —
which is where the measured TTFT win (``RouterMetrics.ttft_s``) comes
from. Token streams are pinned identical to monolithic submit by
``tests/test_chunked_prefill.py``.

Transfers execute in one of two modes:

* **async (default)** — an ``Offload`` or reloading ``Forward`` becomes a
  chunked, page-granular copy job on the replica's
  :class:`~repro.serving.transfer_plane.ReplicaTransferPlane` (PCIe and
  NVMe channel queues, bandwidths from
  :class:`~repro.core.types.TransferCost` or a
  :class:`~repro.sim.hardware.HwConfig`). Copy chunks interleave with
  engine decode steps on the virtual clock, ``on_transfer_complete`` acks
  only when the last page lands, and a tool call that returns early finds
  its offload still pending — the scheduler's ``CancelTransfer`` path
  aborts the partially-streamed copy and the program re-admits warm
  (``RouterMetrics.cancelled_offloads``). Decode steps taken while a
  transfer was in flight are counted in
  ``RouterMetrics.overlap_decode_steps`` — the paper's idle-window
  overlap, measured on the real path.
* **sync (``sync_transfers=True``)** — the pre-async compatibility mode:
  every transfer-bearing action executes and acks inside ``apply_plan``,
  keeping the ledger empty between events. Together with
  ``serial_decode=True`` this reproduces the golden byte-identical
  sim↔router action streams of ``tests/test_plan_protocol.py``.

Action semantics on the real path:

* ``Forward(source_tier=GPU)`` — warm: submit against the cached pages.
* ``Forward(source_tier=CPU)`` — reload host pages over PCIe, then submit.
* ``Forward(source_tier=SSD)`` — reload billed to the NVMe channel
  (``RouterMetrics.nvme_reloaded_pages``).
* ``Forward(recompute=True)`` — Waiting-tier re-admission: the program's
  stale pages (if any survived) are dropped so the engine genuinely
  re-prefills the full context.
* ``Migrate`` — cross-replica KV move, executed on the *destination*
  replica's plane as a page-granular host→host copy
  (:class:`~repro.serving.transfer_plane._MigrateStream`, raw-bits
  byte-identical via ``PagePool.import_host_page``), cancellable
  mid-stream like any offload. Requires paged engines; the router raises
  at construction naming ``migrate_on_pressure`` otherwise.

Live drain/failover: :meth:`MoriRouter.mark_failed` mid-replay aborts the
failed replica's in-flight copies (and migrates sourced from it), tears
down its mid-decode slots (``Engine.abort_request``) and requeues them —
the requeued step re-prefills the identical context on a healthy replica,
so no tokens are lost — then hands the scheduler the failure event, whose
``drain_migrate`` pass moves host-resident KV to the healthy replica with
the most DRAM headroom. :meth:`MoriRouter.mark_recovered` re-admits the
replica for placement. ``replay(faults=[...])`` injects both on the
virtual clock (same :class:`~repro.sim.engine.FaultPlan` shape the
simulator takes).
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
import math
import time
from dataclasses import dataclass, field

from repro.analysis import compile_tracker, kvsan
from repro.analysis.invariants import ControlPlaneChecker
from repro.core import SCHEDULERS, SchedulerConfig, TierCapacity
from repro.core.actions import (
    Action,
    CancelTransfer,
    Discard,
    Forward,
    Migrate,
    Offload,
    PlacementPlan,
    SetLabel,
)
from repro.core.transfers import CopyJob, copy_request_for
from repro.kernels import kv_quant
from repro.core.types import ProgramTrace, Tier, TransferCost
from repro.serving.engine import Completion, Engine, EngineRequest
from repro.serving.transfer_plane import ReplicaTransferPlane, _MigrateStream

#: float slack for virtual-clock due/retire comparisons
_EPS = 1e-9
#: smallest synthesized context the replay will accept after reserving
#: per-step headroom — below this the trace cannot express prefix growth
_MIN_SYNTH_CTX = 16


@dataclass
class RouterMetrics:
    steps_completed: int = 0
    tokens_generated: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    offloaded_pages: int = 0
    reloaded_pages: int = 0          # PCIe-billed (CPU-tier) reloads
    nvme_reloaded_pages: int = 0     # NVMe-billed (SSD-tier) reloads
    # wire bytes actually moved, priced at the offload format (int8 pages
    # bill their int8 payload + scale sidecars, not the bf16 device size)
    offload_bytes: int = 0
    reload_bytes: int = 0
    recompute_submits: int = 0
    gated_events: int = 0
    # async transfer plane (zero in sync_transfers mode)
    overlap_decode_steps: int = 0    # decode steps with a transfer in flight
    cancelled_offloads: int = 0      # offloads aborted by early tool return
    cancelled_pages: int = 0         # staged pages rolled back by aborts
    peak_inflight_bytes: int = 0     # high-water mark of the transfer ledger
    # decode pump (batch occupancy; serial_decode replay pins these at one
    # live slot per step by construction)
    pump_steps: int = 0              # batched decode steps taken by replay
    sum_live_slots: int = 0          # Σ slots advanced across pump steps
    peak_live_slots: int = 0         # most slots one step ever advanced
    multi_slot_steps: int = 0        # steps that advanced ≥ 2 slots
    slot_wait_s: float = 0.0         # Forward release → engine-submit wait
    slot_waits: int = 0              # submits that waited on a full batch
    # chunked prefill (zero when chunked_prefill is off)
    prefill_chunks: int = 0          # prefill_step calls executed by the pump
    prefill_interleaved_steps: int = 0  # decode steps with a prefill in flight
    # multi-replica scale-out: cross-replica migration and drain/failover
    migrations: int = 0              # Migrate actions executed
    migrated_pages: int = 0          # pages landed on a migrate destination
    drain_events: int = 0            # mark_failed calls (drain/failover)
    requeued_slots: int = 0          # mid-flight slots requeued by failover
    makespan_s: float = 0.0          # virtual time at which replay drained
    # why the balancer placed where it did (copied from
    # ReplicaBalancer.reason_counts at end of replay)
    placement_reasons: dict = field(default_factory=dict)
    # real (wall-clock) submit-event → first-token latency per program step —
    # the paper's headline TTFT, measured on the actual execution path
    ttft_samples: list = field(default_factory=list)

    @property
    def cache_hit_rate(self) -> float:
        total = self.cached_tokens + self.prefilled_tokens
        return self.cached_tokens / total if total else 0.0

    @property
    def ttft_s(self) -> dict:
        """Summary of real time-to-first-token: ``{n, mean, p50, p95}``
        (seconds, nearest-rank percentiles; zeros when nothing retired)."""
        xs = sorted(self.ttft_samples)
        if not xs:
            return {"n": 0, "mean": 0.0, "p50": 0.0, "p95": 0.0}

        def pct(p: float) -> float:
            return xs[min(len(xs) - 1, max(0, math.ceil(p * len(xs)) - 1))]

        return {
            "n": len(xs),
            "mean": sum(xs) / len(xs),
            "p50": pct(0.50),
            "p95": pct(0.95),
        }

    @property
    def mean_batch_occupancy(self) -> float:
        """Mean live slots advanced per decode step (the continuous-batching
        payoff: > 1.0 means programs genuinely decoded together)."""
        return self.sum_live_slots / self.pump_steps if self.pump_steps else 0.0


@dataclass
class _PumpSlot:
    """One resident program in a replica's decode batch."""

    pid: str
    replica: int
    engine_slot: int
    req: EngineRequest
    step_idx: int
    start: float                 # virtual submit time
    wall: float                  # recorded reasoning_wall_s for this step
    dt: float                    # virtual seconds between decode steps
    seq: int                     # join order, for deterministic iteration
    steps_taken: int = 0
    next_due: float = 0.0
    done: Completion | None = None
    # chunked prefill: the resumable engine job while the slot is still
    # prefilling (None once the first token lands / in monolithic mode).
    # A prefilling slot owns its engine slot — occupancy probes count it —
    # but never steps decode and never retires until the pipeline drains.
    prefill: "object | None" = None

    @property
    def end(self) -> float:
        return self.start + self.wall

    @property
    def prefilling(self) -> bool:
        return self.prefill is not None


@dataclass
class _ReplayState:
    """Replay-scoped context shared by issue/submit/retire."""

    state: dict[str, dict]
    vocab_size: int
    max_new_tokens: int
    traces: list[ProgramTrace] = field(default_factory=list)


class MoriRouter:
    """Front door: program-aware routing + placement over real engines."""

    def __init__(
        self,
        engines: list[Engine],
        *,
        scheduler: str = "mori",
        gpu_capacity_bytes: int | None = None,
        cpu_capacity_bytes: int | None = None,
        ssd_capacity_bytes: int = 0,
        config: SchedulerConfig | None = None,
        record_plans: bool = False,
        sync_transfers: bool = False,
        serial_decode: bool = False,
        pump_quantum_s: float | None = None,
        chunked_prefill: bool = False,
        prefill_token_budget: int | None = None,
        xfer_cost: TransferCost | None = None,
        hw: "object | None" = None,   # repro.sim.hardware.HwConfig
    ):
        self.engines = engines
        cfg0 = engines[0].cfg
        pool = engines[0].pool
        # per-token sizes come from the pool's tier formats: the device
        # size prices GPU budgets, the wire size prices transfers and host
        # tiers (kv_quant.token_wire_bytes is the format-aware sizing
        # helper; see docs/architecture.md "tier formats")
        self.kv_bytes_per_token = kv_quant.token_wire_bytes(
            cfg0.num_layers, cfg0.num_kv_heads, cfg0.head_dim,
            getattr(pool, "device_format", "bf16"),
        )
        wire_bpt = kv_quant.token_wire_bytes(
            cfg0.num_layers, cfg0.num_kv_heads, cfg0.head_dim,
            getattr(pool, "offload_format", "bf16"),
        )
        # None = same format everywhere -> byte-identical legacy accounting
        self.wire_bytes_per_token = (
            None if wire_bpt == self.kv_bytes_per_token else wire_bpt
        )
        # default GPU budget = the pool's *cache* capacity: the block-table
        # engine provisions extra pages as decode state (the HBM its dense
        # slot buffers used to occupy) and the scheduler must not place
        # programs into that reserve
        reserve = getattr(engines[0], "decode_reserve_pages", 0)
        gpu_cap = (
            gpu_capacity_bytes
            if gpu_capacity_bytes is not None
            else (pool.n_device_pages - reserve) * pool.page_bytes
        )
        cpu_cap = (
            cpu_capacity_bytes
            if cpu_capacity_bytes is not None
            else pool.n_host_pages * getattr(  # lint: kv008-ok (page_bytes is only the stub-pool fallback)
                pool, "host_page_bytes", pool.page_bytes
            )
        )
        config = config or SchedulerConfig(tick_interval_s=5.0)
        # cross-replica migration (pressure-driven or drain-driven) copies
        # KV at page granularity through the pools' host staging, so it
        # needs paged engines on both ends
        paged = all(
            hasattr(e, "tree")
            and hasattr(getattr(e, "pool", None), "import_host_page")
            for e in engines
        )
        if config.migrate_on_pressure and not paged:
            raise ValueError(
                "migrate_on_pressure=True requires paged engine replicas: "
                "cross-replica migration streams KV page-by-page through "
                "PagePool.import_host_page, which these engines lack — "
                "construct the router with paged Engine replicas or set "
                "migrate_on_pressure=False"
            )
        if config.drain_migrate and not paged:
            # drain_migrate defaults on; degrade unpaged fleets to the
            # discard-and-recompute failure path instead of erroring
            config = dataclasses.replace(config, drain_migrate=False)
        self.sched = SCHEDULERS[scheduler](
            len(engines),
            TierCapacity(gpu_cap, cpu_cap, ssd_capacity_bytes),
            config,
        )
        # control-plane invariant checker (REPRO_KVSAN=1 only): audits the
        # ledger's record lifecycle inline and sweeps scheduler occupancy /
        # placement consistency at every tick
        self._checker = (
            ControlPlaneChecker(self.sched) if kvsan.enabled() else None
        )
        self.metrics = RouterMetrics()
        self.record_plans = record_plans
        self.action_log: list[Action] = []
        self.output_log: dict[str, list[int]] = {}
        self._pending: dict[str, tuple[EngineRequest, int]] = {}
        self._dispatched: dict[str, Forward] = {}
        self._dispatch_time: dict[str, float] = {}

        self.serial_decode = serial_decode
        self.pump_quantum_s = pump_quantum_s
        if chunked_prefill:
            if serial_decode:
                raise ValueError(
                    "chunked_prefill needs the decode pump; serial_decode "
                    "replay keeps the monolithic golden path"
                )
            if any(getattr(e, "dense_slots", True) for e in engines):
                raise ValueError(
                    "chunked_prefill requires paged engines "
                    "(dense_slots=False)"
                )
        self.chunked_prefill = chunked_prefill
        self.prefill_token_budget = prefill_token_budget
        self._ttft_start: dict[tuple[str, int], float] = {}
        # per-replica decode batches (pid -> _PumpSlot); always empty in
        # serial_decode mode
        self._pump_slots: list[dict[str, _PumpSlot]] = [{} for _ in engines]
        self._slot_seq = itertools.count()
        if not serial_decode:
            # the scheduler's slot gate reads real engine occupancy (minus
            # requests released but not yet submitted) instead of its own
            # shadow running set
            self.sched.attach_slot_probe(self._probe_slots)

        self.sync_transfers = sync_transfers
        if xfer_cost is None:
            # channel bandwidths from the hardware model when one is given
            # (mirrors Simulation.__init__), else the TransferCost defaults
            xfer_cost = (
                TransferCost(pcie_bytes_per_s=hw.pcie_bw)
                if hw is not None
                else TransferCost()
            )
        self.xfer_cost = xfer_cost
        # set only while replay() runs; without a virtual clock (direct
        # apply_plan use) transfers fall back to synchronous execution
        self._push = None
        self._rs: _ReplayState | None = None
        self.planes: list[ReplicaTransferPlane] = [
            ReplicaTransferPlane(
                i, eng, xfer_cost,
                wake=self._wake, on_committed=self._plane_committed,
                peer_engine=lambda r: self.engines[r],
            )
            for i, eng in enumerate(engines)
        ]

    # -------------------------------------------------------------- helpers
    @property
    def _async(self) -> bool:
        """Async execution needs both the knob and a live virtual clock."""
        return not self.sync_transfers and self._push is not None

    def _wake(self, eta: float) -> None:
        """A plane or pump slot scheduled work at ``eta``: make sure the
        replay loop visits that timestamp even if no trace event falls on
        it (the drain after every event runs the pump/planes)."""
        if self._push is not None:
            self._push(eta, lambda t: None)

    def _advance_planes(self, now: float) -> None:
        for plane in self.planes:
            plane.advance(now)

    def _planes_busy(self) -> bool:
        return any(p.in_flight() for p in self.planes)

    def _probe_slots(self, replica: int) -> tuple[int, int]:
        """Scheduler slot probe: (free, live) decode slots on ``replica``.

        Requests the scheduler already released but the pump has not yet
        submitted count against the free side (they own a slot the moment
        one opens) and toward the live side, so the gate can never
        over-release into a batch that is already spoken for.
        """
        queued = sum(
            1
            for pid, act in self._dispatched.items()
            if act.replica == replica and pid in self._pending
        )
        free = self.engines[replica].free_slot_count()
        return max(0, free - queued), len(self._pump_slots[replica]) + queued

    def _kvsan_check(self, now: float) -> None:
        """Tick-granularity sanity sweep (no-op unless REPRO_KVSAN=1):
        control-plane occupancy/placement plus each pool's structural
        page invariants."""
        if self._checker is not None:
            self._checker.check(now)
        for i, eng in enumerate(self.engines):
            san = getattr(eng.pool, "_san", None)
            if san is not None:
                san.verify(f"router tick t={now:.3f}, replica {i}")

    def _kvsan_end_of_replay(self) -> None:
        """Replay drained: the ledger must be empty and every allocated
        page reachable (anything else is a leak)."""
        if self._checker is not None:
            self._checker.assert_drained()
        for i, eng in enumerate(self.engines):
            san = getattr(eng.pool, "_san", None)
            if san is not None:
                san.verify(f"end of replay, replica {i}")
                san.check_leaks(f"end of replay, replica {i}")

    def _jitaudit_end_of_replay(self) -> None:
        """Recompile-budget gate (no-op unless ``REPRO_JITAUDIT=1`` and
        some engine ran ``warmup()``): a replay that retraced any tracked
        hot-path jit past its warm snapshot stalled the pump for a full
        XLA compile — fail it loudly with the per-function counts."""
        if not compile_tracker.enabled():
            return
        tracker = compile_tracker.get_tracker()
        if not tracker.marked():
            return                      # no warm baseline, nothing to gate
        grew = tracker.post_warmup_compiles()
        if grew:
            detail = ", ".join(
                f"{name}: {warm} warm -> {cur}"
                for name, (warm, cur) in sorted(grew.items())
            )
            phases = {
                ph: len(tracker.events_in(ph))
                for ph in sorted({e.phase for e in tracker.events})
            }
            raise RuntimeError(
                f"compile budget violated: {len(grew)} hot-path jit(s) "
                f"compiled after warmup ({detail}); backend compiles by "
                f"phase: {phases} — a shape escaped the warmup buckets"
            )

    def _record_ttft(self, pid: str, step_idx: int) -> None:
        """First token just landed for (pid, step): close its TTFT sample."""
        t0 = self._ttft_start.pop((pid, step_idx), None)
        if t0 is not None:
            self.metrics.ttft_samples.append(time.perf_counter() - t0)

    # ------------------------------------------------------- plan executor
    def apply_plan(self, plan: PlacementPlan) -> None:
        """Execute a scheduler plan as real page movements — queueing
        transfer-bearing actions on the async planes, or executing and
        acknowledging them synchronously in ``sync_transfers`` mode."""
        if self.record_plans and plan.actions:
            self.action_log.extend(plan.actions)
        for act in plan:
            if isinstance(act, Forward):
                self._exec_forward(act, plan.now)
            elif isinstance(act, Offload):
                self._exec_offload(act, plan.now)
            elif isinstance(act, Discard):
                if act.replica is not None:
                    # abort any copy still streaming this program's pages.
                    # On program teardown the ledger already dropped the
                    # records; on a live-program eviction (CPU/SSD overflow
                    # passes) they are still open, and must be closed here —
                    # a stale open offload would later match
                    # _cancel_inflight_offload and cancel the wrong transfer
                    if not self.sync_transfers:
                        for job, rolled in self.planes[act.replica].abort_pid(
                            act.pid, plan.now
                        ):
                            self.metrics.cancelled_pages += rolled
                            if self.sched.ledger.is_open(job.action_id):
                                self.sched.ledger.cancel(job.action_id)
                    # the logical SSD tier is backed by the host pool on the
                    # real path — freeing it frees host pages
                    tier = Tier.CPU if act.tier is Tier.SSD else act.tier
                    self.engines[act.replica].discard_program(act.pid, tier)
            elif isinstance(act, SetLabel):
                if act.replica is not None:
                    self.engines[act.replica].set_label(act.pid, act.label)
            elif isinstance(act, CancelTransfer):
                self._exec_cancel(act, plan.now)
            elif isinstance(act, Migrate):
                self._exec_migrate(act, plan.now)
        self.metrics.peak_inflight_bytes = max(
            self.metrics.peak_inflight_bytes, self.sched.ledger.in_flight_bytes()
        )
        if self._checker is not None:
            self._checker.check(plan.now)

    def _exec_forward(self, act: Forward, now: float) -> None:
        if act.source_tier in (Tier.CPU, Tier.SSD):
            if self._async:
                # queue the reload; the program dispatches only when the
                # last page lands (_plane_committed)
                self.planes[act.replica].enqueue(act, now)
                return
            eng = self.engines[act.replica]
            pages = eng.reload_program(act.pid)
            if act.source_tier is Tier.SSD:
                self.metrics.nvme_reloaded_pages += pages
            else:
                self.metrics.reloaded_pages += pages
            self.metrics.reload_bytes += pages * eng.pool.host_page_bytes
            self._ack(act.pid, act.action_id, now)
        elif act.recompute:
            # Waiting-tier re-admission: drop any pages that survived
            # engine-side eviction so the full context is re-prefilled —
            # what the scheduler billed is what the engine now does
            eng = self.engines[act.replica]
            eng.discard_program(act.pid, Tier.GPU)
            eng.discard_program(act.pid, Tier.CPU)
            self.metrics.recompute_submits += 1
        self._dispatched[act.pid] = act
        self._dispatch_time[act.pid] = now

    def _exec_offload(self, act: Offload, now: float) -> None:
        if self._async and act.nbytes > 0:
            self.planes[act.replica].enqueue(act, now)
            return
        eng = self.engines[act.replica]
        pages = eng.offload_program(act.pid)
        self.metrics.offloaded_pages += pages
        self.metrics.offload_bytes += pages * eng.pool.host_page_bytes
        self._ack(act.pid, act.action_id, now)

    def _exec_migrate(self, act: Migrate, now: float) -> None:
        """Cross-replica KV move. Async: a chunked copy job on the
        *destination* replica's plane (the copy executes where it lands),
        cancellable mid-stream like any offload. Sync: the stream runs
        inline and acks immediately."""
        self.metrics.migrations += 1
        if self._async and act.nbytes > 0:
            creq = copy_request_for(act)
            self.planes[creq.exec_replica].enqueue_request(creq, now, act=act)
            return
        stream = _MigrateStream(
            self.engines[act.src_replica], self.engines[act.dst_replica],
            act.pid,
        )
        for _ in range(stream.n_units):
            stream.copy_unit()
        self.metrics.migrated_pages += stream.commit()
        self._ack(act.pid, act.action_id, now)

    def _exec_cancel(self, act: CancelTransfer, now: float) -> None:
        if self.sync_transfers:
            return  # transfers are synchronous: never still queued
        res = self.planes[act.replica].abort(act.target_action_id, now)
        if res is not None:
            job, rolled = res
            self.metrics.cancelled_offloads += 1
            self.metrics.cancelled_pages += rolled

    def _plane_committed(
        self, job: CopyJob, kind: str, pages: int, now: float
    ) -> None:
        """Async transfer fully landed: bill it, release any gated forward,
        and acknowledge the scheduler's ledger record."""
        page_wire = (
            self.engines[job.payload.creq.exec_replica].pool.host_page_bytes
        )
        if kind == "offload":
            self.metrics.offloaded_pages += pages
            self.metrics.offload_bytes += pages * page_wire
        elif kind == "migrate":
            self.metrics.migrated_pages += pages
        else:
            act: Forward = job.payload.act
            if act.source_tier is Tier.SSD:
                self.metrics.nvme_reloaded_pages += pages
            else:
                self.metrics.reloaded_pages += pages
            self.metrics.reload_bytes += pages * page_wire
            self._dispatched[act.pid] = act
            self._dispatch_time[act.pid] = now
        self._ack(job.pid, job.action_id, now)

    def _ack(self, pid: str, action_id: int, now: float) -> None:
        self.apply_plan(self.sched.on_transfer_complete(pid, action_id, now))

    # ------------------------------------------------------ drain/failover
    def mark_failed(self, replica: int, now: float) -> None:
        """Live failover: the replica's GPU is gone, its host DRAM is still
        readable (the drain model). In order:

        1. abort every copy job this replica executes, plus any
           cross-replica migrate elsewhere that *reads* from it, closing
           their ledger records (staged pages roll back);
        2. tear down its mid-flight decode/prefill slots and requeue the
           requests (:func:`repro.serving.state_io.requeue_resident_slots`)
           — the requeued step re-prefills the identical context on a
           healthy replica, so the token stream loses nothing;
        3. hand the scheduler the failure event: its ``drain_migrate``
           pass migrates host-resident KV to healthy replicas and drops
           the rest to the Waiting tier.
        """
        from repro.serving.state_io import requeue_resident_slots

        self.metrics.drain_events += 1
        if not self.sync_transfers:
            # jobs executing on the failed plane: abort the streams (staged
            # pages roll back) but leave their ledger records — they are
            # billed to the failed replica, so ``replica_failed``'s
            # drop_replica closes them, and until then a half-offloaded
            # program still shows an *open* offload, which is exactly what
            # makes the drain pass skip its untrustworthy DRAM copy
            for job in list(self.planes[replica].channels.jobs()):
                res = self.planes[replica].abort(job.action_id, now)
                if res is not None:
                    self.metrics.cancelled_pages += res[1]
            # migrates elsewhere that *read* from the failed replica: abort
            # and cancel explicitly (their records bill to the destination,
            # which drop_replica will not touch)
            for r, plane in enumerate(self.planes):
                if r == replica:
                    continue
                for job in list(plane.channels.jobs()):
                    task = job.payload
                    if (
                        task.kind == "migrate"
                        and task.creq is not None
                        and task.creq.src.replica == replica
                    ):
                        res = plane.abort(job.action_id, now)
                        if res is not None:
                            self.metrics.cancelled_pages += res[1]
                            self.sched.ledger.cancel(job.action_id)
        self.metrics.requeued_slots += requeue_resident_slots(
            self, replica, now
        )
        # dispatched-but-not-yet-submitted work targeting the dead replica
        # goes back to pending; the scheduler re-places it after the drain
        for pid in [
            p for p, a in self._dispatched.items() if a.replica == replica
        ]:
            self._dispatched.pop(pid)
            self._dispatch_time.pop(pid, None)
        self.apply_plan(self.sched.replica_failed(replica, now))

    def mark_recovered(self, replica: int, now: float) -> None:
        """Re-admit a recovered replica for placement. Its pools were lost
        with the node; programs return through the normal Waiting-tier
        recompute path as the balancer starts placing onto it again."""
        self.sched.replica_recovered(replica)
        self.apply_plan(self.sched.tick(now))

    # ------------------------------------------------------------- replay
    def replay(
        self,
        traces: list[ProgramTrace],
        *,
        vocab_size: int,
        max_new_tokens: int = 8,
        seed: int = 0,
        faults: "list | None" = None,
    ) -> RouterMetrics:
        """Replay traces concurrently on the virtual clock.

        Default mode runs the clocked decode pump (batched multi-program
        decode); ``serial_decode=True`` reproduces the pre-pump serialized
        order, running each dispatched request to completion before the
        next event.

        ``faults`` injects live drain/failover on the virtual clock: each
        entry (duck-typed like :class:`~repro.sim.engine.FaultPlan` —
        ``replica`` / ``fail_at`` / optional ``recover_at``) triggers
        :meth:`mark_failed` and :meth:`mark_recovered` at those times.
        """
        import random

        self._ttft_start.clear()
        q: list[tuple[float, int, object]] = []
        seq = itertools.count()

        def push(t, fn):
            heapq.heappush(q, (t, next(seq), fn))

        self._push = push

        # register programs (validating that each trace's synthesized
        # context can grow for its whole lifetime without hitting max_seq)
        max_seq = self.engines[0].max_seq
        state: dict[str, dict] = {}
        for tr in traces:
            pid = tr.program_id
            scale = max(1, tr.steps[0].input_tokens // 48)
            reserved = (max_new_tokens + 2) * len(tr.steps) + 8
            max_ctx = max_seq - reserved
            if max_ctx < _MIN_SYNTH_CTX:
                raise ValueError(
                    f"trace {pid!r} cannot replay on this engine: "
                    f"{len(tr.steps)} steps reserve "
                    f"(max_new_tokens={max_new_tokens} + 2) * steps + 8 = "
                    f"{reserved} tokens of growth headroom, but "
                    f"max_seq={max_seq} leaves max_ctx={max_ctx} "
                    f"(< {_MIN_SYNTH_CTX}) for the synthesized context — "
                    "shorten the trace, lower max_new_tokens, or raise the "
                    "engine's max_seq"
                )
            state[pid] = {
                "trace": tr,
                "tokens": [],
                "ctx_len": 0,
                "scale": scale,
                "max_ctx": max_ctx,
                "max_steps": len(tr.steps),
                "completed_steps": 0,
                # per-program stream (string seeding is hash-stable): the
                # synthesized context is a pure function of the program's
                # own history, never of cross-program admission order —
                # so a drained-and-requeued step regrows the *identical*
                # context, which is what makes failover token-preserving
                # and testable (output_log equality vs an undisturbed run)
                "rng": random.Random(f"{seed}:{pid}"),
            }
            self.sched.program_arrived(
                pid, self.kv_bytes_per_token, 0.0,
                wire_bytes_per_token=self.wire_bytes_per_token,
            )
            push(0.0, lambda t, p=pid: self._issue(p, 0, t))

        for f in faults or []:
            push(f.fail_at, lambda t, fr=f: self.mark_failed(fr.replica, t))
            if getattr(f, "recover_at", None) is not None:
                push(
                    f.recover_at,
                    lambda t, fr=f: self.mark_recovered(fr.replica, t),
                )

        self._rs = _ReplayState(
            state=state, vocab_size=vocab_size,
            max_new_tokens=max_new_tokens, traces=list(traces),
        )
        drain = self._drain_serial if self.serial_decode else self._pump_all

        def can_step(t: float) -> bool:
            """Step only when no other event shares this virtual instant —
            same-time admissions then batch into one decode step."""
            return not (q and q[0][0] <= t + _EPS)

        tick = self.sched.config.tick_interval_s
        next_tick = tick
        now = 0.0
        # stall guard derived from the workload: an event is allowed to make
        # no progress (stale wakes, gated arrivals already counted) only so
        # many times in a row before the replay is declared wedged
        total_steps = sum(len(tr.steps) for tr in traces)
        stall_cap = max(1_000, 64 * len(traces) + 8 * total_steps)
        stalled, last_progress = 0, self._progress_vector()
        # once the trace event heap runs dry with work still outstanding
        # (requests gated on capacity, transfers mid-stream), the loop
        # injects drain ticks until everything resolves — bounded by a
        # deadline derived from the outstanding work itself (remaining
        # virtual trace time + worst-case transfer time), not a fixed
        # tick count
        drain_deadline: float | None = None
        while q or self._outstanding_work():
            if not q:
                if drain_deadline is None:
                    drain_deadline = (
                        now + self._drain_budget_s(state) + 32 * tick
                    )
                if now > drain_deadline:
                    raise RuntimeError(
                        "router replay did not drain by its derived "
                        f"deadline (t={now:.3f} > {drain_deadline:.3f}); "
                        + self._stall_report()
                    )
                now += tick
                next_tick = now + tick
                self._advance_planes(now)
                self.apply_plan(self.sched.tick(now))
                drain(now, can_step(now))
                self._kvsan_check(now)
                continue
            # a live event heap means new work (and new transfers) can
            # still start: any prior drain deadline is stale, re-derive it
            # at the next empty-heap episode from the work outstanding then
            drain_deadline = None
            t, _, fn = heapq.heappop(q)
            now = max(now, t)
            while next_tick <= now:
                self._advance_planes(next_tick)
                self.apply_plan(self.sched.tick(next_tick))
                drain(next_tick, can_step(next_tick))
                self._kvsan_check(next_tick)
                next_tick += tick
            self._advance_planes(now)
            fn(now)
            drain(now, can_step(now))
            cur = self._progress_vector()
            if cur == last_progress:
                stalled += 1
                if stalled > stall_cap:
                    raise RuntimeError(
                        f"router replay stalled: {stall_cap} consecutive "
                        f"events without progress at t={now:.3f}; "
                        + self._stall_report()
                    )
            else:
                stalled, last_progress = 0, cur
        self._kvsan_end_of_replay()
        self._jitaudit_end_of_replay()
        self._push = None
        self._rs = None
        self.metrics.makespan_s = now
        self.metrics.placement_reasons = dict(self.sched.balancer.reason_counts)
        return self.metrics

    # --------------------------------------------------- replay event hooks
    def _issue(self, pid: str, step_idx: int, now: float) -> None:
        rs = self._rs
        st = rs.state[pid]
        trace: ProgramTrace = st["trace"]
        rec = trace.steps[step_idx]
        # synthesize a token context of the recorded length (prefix-stable)
        want = max(
            st["ctx_len"] + 1,
            min(rec.input_tokens // st["scale"], st["max_ctx"]),
        )
        grow = want - st["ctx_len"]
        st["tokens"].extend(
            st["rng"].randrange(2, rs.vocab_size) for _ in range(grow)
        )
        st["ctx_len"] = want
        req = EngineRequest(
            program_id=pid,
            tokens=list(st["tokens"]),
            max_new_tokens=rs.max_new_tokens,
        )
        self._pending[pid] = (req, step_idx)
        # TTFT clock starts at the submit event (real time): scheduler
        # gating and slot waits are part of the latency a caller sees
        self._ttft_start[(pid, step_idx)] = time.perf_counter()
        self.apply_plan(self.sched.request_arrived(pid, want, now))
        if pid not in self._dispatched:
            self.metrics.gated_events += 1

    def _complete_step(
        self, pid: str, step_idx: int, comp: Completion, end: float
    ) -> None:
        """Shared retire bookkeeping: metrics, context growth, the
        ``request_completed`` plan, and the next issue (or teardown)."""
        rs = self._rs
        st = rs.state[pid]
        self.metrics.steps_completed += 1
        self.metrics.tokens_generated += len(comp.output_tokens)
        self.metrics.cached_tokens += comp.cached_tokens
        self.metrics.prefilled_tokens += comp.prefilled_tokens
        self.output_log.setdefault(pid, []).extend(comp.output_tokens)
        st["tokens"].extend(comp.output_tokens[:-1])
        st["ctx_len"] = len(st["tokens"])
        st["completed_steps"] = step_idx + 1
        trace: ProgramTrace = st["trace"]
        rec = trace.steps[step_idx]
        self.apply_plan(
            self.sched.request_completed(pid, len(comp.output_tokens), end)
        )
        nxt = step_idx + 1
        if nxt < len(trace.steps) and nxt < st["max_steps"]:
            self._push(
                end + rec.tool_duration_s,
                lambda t, p=pid, n=nxt: self._issue(p, n, t),
            )
        else:
            self.apply_plan(self.sched.program_finished(pid, end))

    # ------------------------------------------------------ serialized mode
    def _drain_serial(self, now: float, allow_step: bool = True) -> None:
        """Pre-pump compatibility drain: run each released request to
        completion before touching the next event (``allow_step`` is a
        pump-signature stand-in; serialized replay never defers)."""
        del allow_step
        progress = True
        while progress:
            progress = False
            for pid in list(self._pending):
                if pid in self._dispatched:
                    self._finish_step_serial(pid, now)
                    progress = True

    def _finish_step_serial(self, pid: str, now: float) -> None:
        rs = self._rs
        st = rs.state[pid]
        req, step_idx = self._pending.pop(pid)
        act = self._dispatched.pop(pid)
        self._dispatch_time.pop(pid, None)
        eng = self.engines[act.replica]
        eng.submit(req)
        self._record_ttft(pid, step_idx)
        self.sched.notify_inference_started(pid, now)
        trace: ProgramTrace = st["trace"]
        rec = trace.steps[step_idx]
        before = eng.steps
        done = self._run_decode_serial(
            eng, act.replica, pid, req, rec.reasoning_wall_s, now
        )
        delta = eng.steps - before
        m = self.metrics
        m.pump_steps += delta
        m.sum_live_slots += delta     # serialized: one live slot per step
        if delta:
            m.peak_live_slots = max(m.peak_live_slots, 1)
        comp = next(c for c in done if c.program_id == pid)
        end = now + rec.reasoning_wall_s
        if self._async:
            self._advance_planes(end)
        self._complete_step(pid, step_idx, comp, end)

    def _run_decode_serial(
        self, eng, replica: int, pid: str, req, wall_s: float, now: float
    ):
        """Run the submitted request to completion. In async mode the
        decode steps spread over the virtual window [now, now+wall] and
        the transfer planes advance between steps — a copy chunk lands
        *during* decode exactly as the paper's overlap requires."""
        if not self._async:
            return eng.run_to_completion()
        n_est = max(1, req.max_new_tokens - 1)
        dt = wall_s / n_est if wall_s > 0 else 0.0
        t, done = now, []
        for _ in range(20_000):
            busy = self.planes[replica].in_flight()
            done.extend(eng.step())
            if busy:
                self.metrics.overlap_decode_steps += 1
            t = min(now + wall_s, t + dt)
            self._advance_planes(t)
            if any(c.program_id == pid for c in done):
                return done
        raise RuntimeError("decode did not complete")

    # --------------------------------------------------------- decode pump
    def _pump_all(self, now: float, allow_step: bool = True) -> None:
        """Advance every replica's decode batch at virtual time ``now``
        until the whole system settles (retires can release slots that
        admit gated programs on other replicas, so iterate to fixpoint).

        ``allow_step=False`` defers decode steps while another event at
        the same virtual instant is still pending in the replay heap —
        programs admitted by *separate* same-time events then share one
        batched step at the instant's final visit (the wake pushed at
        submit time guarantees that visit happens) instead of each
        stepping solo as its admission event drains. Retires and
        admissions always run; only stepping waits.
        """
        for _ in range(100_000):
            progress = False
            for r in range(len(self.engines)):
                progress |= self._pump_replica(r, now, allow_step)
            if not progress:
                return
        raise RuntimeError(
            f"decode pump did not settle at t={now:.3f}; "
            + self._stall_report()
        )

    def _pump_replica(self, r: int, now: float, allow_step: bool = True) -> bool:
        eng = self.engines[r]
        slots = self._pump_slots[r]
        acted = False

        # 1. retire slots whose virtual reasoning window has ended —
        #    deterministic order: window end, then batch-join sequence
        ready = sorted(
            (s for s in slots.values()
             if s.done is not None and s.end <= now + _EPS),
            key=lambda s: (s.end, s.seq),
        )
        for slot in ready:
            slots.pop(slot.pid, None)
            self._complete_step(slot.pid, slot.step_idx, slot.done, slot.end)
            acted = True

        # 2. admit released requests into free engine slots (release order)
        #    while other slots keep decoding — continuous batching's join
        for pid in list(self._dispatched):
            if pid not in self._pending:
                continue
            act = self._dispatched[pid]
            if act.replica != r:
                continue
            if eng.free_slot_count() <= 0:
                break
            self._submit_into_slot(pid, r, now)
            acted = True

        # 2b. advance chunked prefills — ONE budgeted chunk per slot per
        #     visit, so the settle loop interleaves due decode steps between
        #     chunks instead of stalling the batch behind a whole prefill.
        #     The pipeline drains within the admission instant (prefill is
        #     virtually instantaneous, like monolithic submit), and the slot
        #     only becomes step-eligible — and ``on_slot_freed``-relevant —
        #     once its final chunk lands.
        prefilling = sorted(
            (s for s in slots.values() if s.prefilling), key=lambda s: s.seq
        )
        for slot in prefilling:
            finished = eng.prefill_step(slot.prefill, self.prefill_token_budget)
            self.metrics.prefill_chunks += 1
            acted = True
            if finished:
                slot.prefill = None
                self._record_ttft(slot.pid, slot.step_idx)

        # 3. one batched decode step advancing every due slot together
        if not allow_step:
            return acted
        due = sorted(
            (s for s in slots.values()
             if s.done is None and not s.prefilling
             and s.next_due <= now + _EPS),
            key=lambda s: s.seq,
        )
        if due:
            busy = self.planes[r].in_flight()
            completions = eng.step(active=[s.engine_slot for s in due])
            m = self.metrics
            m.pump_steps += 1
            m.sum_live_slots += len(due)
            m.peak_live_slots = max(m.peak_live_slots, len(due))
            if len(due) >= 2:
                m.multi_slot_steps += 1
            if any(s.prefilling for s in slots.values()):
                # the chunked-prefill payoff: decode kept running while a
                # join was still mid-prefill on this replica
                m.prefill_interleaved_steps += 1
            if busy:
                m.overlap_decode_steps += 1
            for s in due:
                s.steps_taken += 1
                if s.dt > 0:
                    s.next_due = self._quantize(
                        s.start + s.steps_taken * s.dt, s.end
                    )
                    if s.next_due > now + _EPS:
                        self._wake(s.next_due)
                # dt == 0 (zero recorded wall): keep stepping this quantum
                # until the engine finishes the request
            freed = False
            for comp in completions:
                s = slots.get(comp.program_id)
                if s is not None and s.done is None:
                    s.done = comp
                    freed = True
                    if s.end > now + _EPS:
                        self._wake(s.end)
            if freed:
                # the engine slot opened mid-batch: let the scheduler
                # forward gated work into it right now, not at next tick
                self.apply_plan(self.sched.on_slot_freed(r, now))
            acted = True
        return acted

    def _submit_into_slot(self, pid: str, r: int, now: float) -> None:
        rs = self._rs
        req, step_idx = self._pending.pop(pid)
        self._dispatched.pop(pid)
        job = None
        if self.chunked_prefill:
            # two-phase admission: reserve the slot now, prefill in budgeted
            # chunks from the pump (stage 2b) while other slots keep decoding
            job = self.engines[r].begin_submit(req)
            sid = job.slot_id
        else:
            sid = self.engines[r].submit(req)
            self._record_ttft(pid, step_idx)
        self.sched.notify_inference_started(pid, now)
        rec = rs.state[pid]["trace"].steps[step_idx]
        wall = rec.reasoning_wall_s
        n_est = max(1, req.max_new_tokens - 1)
        dt = wall / n_est if wall > 0 else 0.0
        self._pump_slots[r][pid] = _PumpSlot(
            pid=pid, replica=r, engine_slot=sid, req=req, step_idx=step_idx,
            start=now, wall=wall, dt=dt, seq=next(self._slot_seq),
            next_due=now, prefill=job,
        )
        # guarantee a final same-instant pump visit: if stepping is being
        # deferred for same-time batching, this wake is where it happens
        self._wake(now)
        released = self._dispatch_time.pop(pid, now)
        wait = now - released
        if wait > _EPS:
            self.metrics.slot_wait_s += wait
            self.metrics.slot_waits += 1

    def _quantize(self, t: float, end: float) -> float:
        """Snap a due time up to the pump quantum grid (when configured) so
        co-resident slots with near-equal pacing share batched steps; never
        past the slot's window end, so retires stay on schedule."""
        q = self.pump_quantum_s
        if not q:
            return t
        return min(end, math.ceil(t / q - _EPS) * q)

    # -------------------------------------------------- stall/drain guards
    def _progress_vector(self) -> tuple:
        """Monotone counters that move whenever replay does real work."""
        m = self.metrics
        return (
            m.steps_completed, m.tokens_generated, m.pump_steps,
            m.offloaded_pages, m.reloaded_pages, m.nvme_reloaded_pages,
            m.cancelled_pages, m.cancelled_offloads, m.gated_events,
            m.recompute_submits, m.prefill_chunks,
            m.migrations, m.migrated_pages, m.requeued_slots,
            sum(e.steps for e in self.engines),
            sum(p.chunks_executed for p in self.planes),
        )

    def _outstanding_work(self) -> bool:
        return (
            bool(self._pending)
            or bool(self._dispatched)
            or any(self._pump_slots)
            or self._planes_busy()
        )

    def _drain_budget_s(self, state: dict[str, dict]) -> float:
        """Upper bound on the virtual time the outstanding work needs:
        every un-replayed trace step's reasoning + tool window, plus the
        pending transfer bytes over the slowest configured channel."""
        remaining = 0.0
        for st in state.values():
            tr: ProgramTrace = st["trace"]
            for rec in tr.steps[st["completed_steps"]:]:
                remaining += rec.reasoning_wall_s + rec.tool_duration_s
        pend = sum(p.pending_bytes() for p in self.planes)
        bw = min(
            self.xfer_cost.pcie_bytes_per_s, self.xfer_cost.ssd_bytes_per_s
        )
        xfer_s = (pend / bw + self.xfer_cost.fixed_latency_s) if pend else 0.0
        return remaining + xfer_s

    def _stall_report(self) -> str:
        """Name exactly what is still pending (for termination errors)."""
        parts = []
        if self._pending:
            gated = sorted(p for p in self._pending if p not in self._dispatched)
            if gated:
                parts.append(f"requests gated by the scheduler: {gated}")
            released = sorted(p for p in self._pending if p in self._dispatched)
            if released:
                parts.append(
                    f"requests released but awaiting an engine slot: {released}"
                )
        for r, slots in enumerate(self._pump_slots):
            if slots:
                desc = [
                    f"{s.pid}(step {s.step_idx}, {s.steps_taken} decode steps,"
                    + (" prefilling," if s.prefilling else "")
                    + f" window ends t={s.end:.3f})"
                    for s in sorted(slots.values(), key=lambda s: s.seq)
                ]
                parts.append(f"replica {r} resident slots: {desc}")
        for r, plane in enumerate(self.planes):
            jobs = plane.describe_jobs()
            if jobs:
                parts.append(f"replica {r} transfers in flight: {jobs}")
        return "; ".join(parts) if parts else "no outstanding work recorded"


def snapshot_state(router: MoriRouter) -> dict:
    """Serializable control-plane snapshot (fault tolerance / restart).

    Delegates to :func:`repro.serving.state_io.control_plane_state` — the
    single source of truth for the snapshot schema (program table, per-
    replica tier usage, live decode-slot occupancy)."""
    from repro.serving.state_io import control_plane_state

    return control_plane_state(router)
