"""MORI router over real engine replicas (the paper's Fig. 6 front door).

The router implements :class:`EngineAdapter`: the scheduler's placement
actions become real page movements in each engine's two-tier pool. Workload
replay runs on a *virtual clock* (tool-call sleeps advance time instantly;
inference advances it by the trace's recorded reasoning wall-time) while the
engine compute itself is real JAX execution — so policy behaviour is timed
faithfully and the data plane actually runs.
"""
from __future__ import annotations

import heapq
import itertools
from dataclasses import dataclass, field

from repro.core import SCHEDULERS, SchedulerConfig, TierCapacity
from repro.core.types import ProgramTrace, Tier, TypeLabel
from repro.serving.engine import Engine, EngineRequest


@dataclass
class RouterMetrics:
    steps_completed: int = 0
    tokens_generated: int = 0
    cached_tokens: int = 0
    prefilled_tokens: int = 0
    offloaded_pages: int = 0
    reloaded_pages: int = 0
    gated_events: int = 0

    @property
    def cache_hit_rate(self) -> float:
        total = self.cached_tokens + self.prefilled_tokens
        return self.cached_tokens / total if total else 0.0


class MoriRouter:
    """Front door: program-aware routing + placement over real engines."""

    def __init__(
        self,
        engines: list[Engine],
        *,
        scheduler: str = "mori",
        gpu_capacity_bytes: int | None = None,
        cpu_capacity_bytes: int | None = None,
        config: SchedulerConfig | None = None,
    ):
        self.engines = engines
        cfg0 = engines[0].cfg
        self.kv_bytes_per_token = (
            cfg0.num_layers * 2 * cfg0.num_kv_heads * cfg0.head_dim * 2
        )
        pool = engines[0].pool
        gpu_cap = gpu_capacity_bytes or (
            pool.n_device_pages * pool.page_bytes
        )
        cpu_cap = cpu_capacity_bytes or (pool.n_host_pages * pool.page_bytes)
        self.sched = SCHEDULERS[scheduler](
            len(engines),
            TierCapacity(gpu_cap, cpu_cap),
            self,
            config or SchedulerConfig(tick_interval_s=5.0),
        )
        self.metrics = RouterMetrics()
        self._pending: dict[str, tuple[EngineRequest, int]] = {}
        self._dispatched: dict[str, int] = {}

    # ------------------------------------------------------- EngineAdapter
    def forward(self, pid: str, replica: int, reload: bool, recompute: bool) -> None:
        req, _ = self._pending[pid]
        eng = self.engines[replica]
        if reload:
            self.metrics.reloaded_pages += eng.reload_program(pid)
        self._dispatched[pid] = replica

    def offload(self, pid: str, replica: int) -> None:
        self.metrics.offloaded_pages += self.engines[replica].offload_program(pid)

    def discard(self, pid: str, replica: int | None, tier: Tier) -> None:
        if replica is not None:
            self.engines[replica].discard_program(pid, tier)

    def set_label(self, pid: str, replica: int | None, label: TypeLabel) -> None:
        if replica is not None:
            self.engines[replica].set_label(pid, label)

    # ------------------------------------------------------------- replay
    def replay(
        self,
        traces: list[ProgramTrace],
        *,
        vocab_size: int,
        max_new_tokens: int = 8,
        seed: int = 0,
    ) -> RouterMetrics:
        """Replay traces concurrently on the virtual clock."""
        import random

        rng = random.Random(seed)
        q: list[tuple[float, int, object]] = []
        seq = itertools.count()
        state: dict[str, dict] = {}

        def push(t, fn):
            heapq.heappush(q, (t, next(seq), fn))

        def issue(pid: str, step_idx: int, now: float):
            st = state[pid]
            trace: ProgramTrace = st["trace"]
            rec = trace.steps[step_idx]
            # synthesize a token context of the recorded length (prefix-stable)
            want = max(
                st["ctx_len"] + 1,
                min(rec.input_tokens // st["scale"], st["max_ctx"]),
            )
            grow = want - st["ctx_len"]
            st["tokens"].extend(
                rng.randrange(2, vocab_size) for _ in range(grow)
            )
            st["ctx_len"] = want
            req = EngineRequest(
                program_id=pid,
                tokens=list(st["tokens"]),
                max_new_tokens=max_new_tokens,
            )
            self._pending[pid] = (req, step_idx)
            self.sched.request_arrived(pid, want, now)
            if pid not in self._dispatched:
                self.metrics.gated_events += 1

        def finish_step(pid: str, now: float):
            st = state[pid]
            req, step_idx = self._pending.pop(pid)
            replica = self._dispatched.pop(pid)
            eng = self.engines[replica]
            sid = eng.submit(req)
            self.sched.notify_inference_started(pid, now)
            done = eng.run_to_completion()
            comp = next(c for c in done if c.program_id == pid)
            self.metrics.steps_completed += 1
            self.metrics.tokens_generated += len(comp.output_tokens)
            self.metrics.cached_tokens += comp.cached_tokens
            self.metrics.prefilled_tokens += comp.prefilled_tokens
            st["tokens"].extend(comp.output_tokens[:-1])
            st["ctx_len"] = len(st["tokens"])
            trace: ProgramTrace = st["trace"]
            rec = trace.steps[step_idx]
            end = now + rec.reasoning_wall_s
            self.sched.request_completed(pid, len(comp.output_tokens), end)
            nxt = step_idx + 1
            if nxt < len(trace.steps) and nxt < st["max_steps"]:
                push(end + rec.tool_duration_s, lambda t, p=pid, n=nxt: issue(p, n, t))
            else:
                self.sched.program_finished(pid, end)

        # register programs
        max_seq = self.engines[0].max_seq
        for tr in traces:
            pid = tr.program_id
            scale = max(1, tr.steps[0].input_tokens // 48)
            state[pid] = {
                "trace": tr,
                "tokens": [],
                "ctx_len": 0,
                "scale": scale,
                "max_ctx": max_seq - (max_new_tokens + 2) * len(tr.steps) - 8,
                "max_steps": len(tr.steps),
            }
            self.sched.program_arrived(pid, self.kv_bytes_per_token, 0.0)
            push(0.0, lambda t, p=pid: issue(p, 0, t))

        def drain(now: float) -> None:
            """Execute any requests the scheduler has released to an engine."""
            progress = True
            while progress:
                progress = False
                for pid in list(self._pending):
                    if pid in self._dispatched:
                        finish_step(pid, now)
                        progress = True

        tick = self.sched.config.tick_interval_s
        next_tick = tick
        now = 0.0
        guard = 0
        while q:
            guard += 1
            if guard > 100_000:
                raise RuntimeError("router replay did not terminate")
            t, _, fn = heapq.heappop(q)
            now = max(now, t)
            while next_tick <= now:
                self.sched.tick(next_tick)
                drain(next_tick)
                next_tick += tick
            fn(now)
            drain(now)
        # final drain: keep ticking until nothing is pending
        for _ in range(256):
            if not self._pending:
                break
            now += tick
            self.sched.tick(now)
            drain(now)
        return self.metrics


def snapshot_state(router: MoriRouter) -> dict:
    """Serializable control-plane snapshot (fault tolerance / restart)."""
    sched = router.sched
    return {
        "programs": {
            pid: {
                "tier": p.tier.value,
                "replica": p.replica,
                "context_tokens": p.context_tokens,
                "label": p.label.value,
                "steps_completed": p.steps_completed,
            }
            for pid, p in sched.programs.items()
        },
        "gpu_used": [r.gpu_used for r in sched.replicas],
        "cpu_used": [r.cpu_used for r in sched.replicas],
    }
