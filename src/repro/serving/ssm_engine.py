"""MORI on attn-free state (DESIGN.md §Arch-applicability, real engine).

For SSM programs (mamba2) the per-program serving state is an O(1)-in-
seq-len bundle — SSD state [L,1,H,P,N] + conv state [L,1,W-1,C] — not a
paged KV cache. Two structural consequences, both visible here:

* **no radix sharing**: SSM state is a lossy running summary, so the only
  reuse is *exact continuation* — a new request whose tokens extend the
  program's recorded context resumes from the saved state and feeds just
  the suffix (the SSM analogue of chunked prefill over a radix prefix);
* **bundle-granular tiering**: offload/reload moves the whole fixed-size
  bundle; the two-tier store is a counted slot pool, not a page pool.

:class:`SsmEngine` exposes the same surface as :class:`repro.serving.
engine.Engine` (offload/reload/discard/set_label program verbs), so
:class:`MoriRouter`'s ``apply_plan`` executor — and with it the full MORI
plan/ack policy stack — drives it unchanged: bundle moves are the page
moves of the dense path at N=1 granularity. Demonstrated in
tests/test_ssm_engine.py.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.types import Tier, TypeLabel
from repro.models import Model
from repro.models.config import ModelConfig
from repro.models.params import is_leaf
from repro.serving.engine import Completion, EngineRequest


@dataclass
class _Bundle:
    cache: dict                        # {"ssm": [L,1,...], "conv": [L,1,...]}
    ctx: list[int]                     # tokens summarized by the state
    label: TypeLabel = TypeLabel.BUSY
    last_used: int = 0


class _PoolShim:
    """Capacity view the router reads (page == one state bundle)."""

    def __init__(self, bundle_bytes: int, n_device: int, n_host: int):
        self.page_bytes = bundle_bytes
        self.n_device_pages = n_device
        self.n_host_pages = n_host


class SsmEngine:
    def __init__(
        self,
        cfg: ModelConfig,
        params,
        *,
        max_slots: int = 4,
        max_seq: int = 512,
        n_device_states: int = 4,
        n_host_states: int = 8,
    ):
        assert cfg.family == "ssm", cfg.family
        self.cfg = cfg
        self.model = Model(cfg)
        self.params = params
        self.max_slots = max_slots
        self.max_seq = max_seq
        self.n_device_states = n_device_states
        self.n_host_states = n_host_states
        self.device: dict[str, _Bundle] = {}
        self.host: dict[str, _Bundle] = {}
        self.labels: dict[str, TypeLabel] = {}
        self._clock = 0
        self._completions: list[Completion] = []
        self.evicted_pages = {"gpu": 0, "cpu": 0}
        self.steps = 0

        self.bundle_bytes = sum(
            int(np.prod(l.shape)) * 2
            for l in jax.tree.leaves(
                self.model.describe_cache(1, 1), is_leaf=is_leaf
            )
        )
        self.pool = _PoolShim(self.bundle_bytes, n_device_states, n_host_states)
        self._decode = jax.jit(self.model.decode)
        self._prefill = jax.jit(self.model.prefill)

    # ------------------------------------------------------------ surface
    def has_slot(self) -> bool:
        return True                      # execution is synchronous

    def free_slot_count(self) -> int:
        """Execution is synchronous inside :meth:`submit`, so a slot is
        always free — the router's pump probe sees the full budget."""
        return self.max_slots

    def submit(self, req: EngineRequest) -> int:
        self._clock += 1
        pid = req.program_id
        tokens = req.tokens
        reloaded = 0

        bundle = self.device.get(pid)
        if bundle is None and pid in self.host:
            bundle = self._reload(pid)
            reloaded = 1

        if (
            bundle is not None
            and len(tokens) > len(bundle.ctx)
            and tokens[: len(bundle.ctx)] == bundle.ctx
        ):
            # exact continuation: resume from the state, feed the suffix
            # (a non-extending request can't reuse — the state has already
            # consumed its last token and SSM state can't roll back)
            cached = len(bundle.ctx)
            cache = bundle.cache
            suffix = tokens[cached:]
        else:
            # divergence or no state: recompute from scratch
            if bundle is not None:
                self._drop(pid)
            cached = 0
            cache = None
            suffix = tokens

        if cache is None:
            batch = {"tokens": jnp.asarray([tokens], jnp.int32)}
            logits, cache = self._prefill(self.params, batch)
            last_logits = logits[0]
            prefilled = len(tokens)
            ctx = list(tokens)
        else:
            prefilled = len(suffix)
            ctx = list(tokens)
            last_logits = None
            for i, tok in enumerate(suffix):
                lengths = jnp.asarray([cached + i + 1], jnp.int32)
                logits, cache = self._decode(
                    self.params, cache, jnp.asarray([tok], jnp.int32), lengths
                )
                last_logits = logits[0]

        out: list[int] = []
        for i in range(req.max_new_tokens):
            nxt = int(jnp.argmax(last_logits))
            out.append(nxt)
            if i == req.max_new_tokens - 1:
                break                  # don't feed the final token: the
                # stored state then summarizes exactly ``ctx`` and the next
                # (strictly extending) request starts from a clean suffix
            ctx.append(nxt)
            lengths = jnp.asarray([len(ctx)], jnp.int32)
            logits, cache = self._decode(
                self.params, cache, jnp.asarray([nxt], jnp.int32), lengths
            )
            last_logits = logits[0]

        self.device[pid] = _Bundle(
            cache, ctx, self.labels.get(pid, TypeLabel.BUSY), self._clock
        )
        self._enforce_device_capacity()
        self.steps += 1
        self._completions.append(
            Completion(
                program_id=pid,
                output_tokens=out,
                cached_tokens=cached,
                prefilled_tokens=prefilled,
                reloaded_pages=reloaded,
            )
        )
        return self.steps

    def step(self, active: "list[int] | None" = None) -> list[Completion]:
        """Drain completions. ``active`` is accepted for pump-API parity and
        ignored — :meth:`submit` already ran the whole request, so every
        stashed completion is final regardless of pacing."""
        del active
        done, self._completions = self._completions, []
        return done

    def run_to_completion(self, max_steps: int = 10_000) -> list[Completion]:
        return self.step()

    # --------------------------------------------------------- tier moves
    def offload_program(self, pid: str) -> int:
        bundle = self.device.pop(pid, None)
        if bundle is None:
            return 0
        if len(self.host) >= self.n_host_states:
            self._evict_host()
        bundle.cache = jax.tree.map(np.asarray, bundle.cache)
        self.host[pid] = bundle
        return 1

    def reload_program(self, pid: str) -> int:
        return 1 if self._reload(pid) is not None else 0

    def discard_program(self, pid: str, tier: Tier) -> None:
        if tier is Tier.GPU:
            self.device.pop(pid, None)
        else:
            self.host.pop(pid, None)

    def set_label(self, pid: str, label: TypeLabel) -> None:
        self.labels[pid] = label
        for store in (self.device, self.host):
            if pid in store:
                store[pid].label = label

    # ----------------------------------------------------------- internals
    def _reload(self, pid: str) -> _Bundle | None:
        bundle = self.host.pop(pid, None)
        if bundle is None:
            return None
        bundle.cache = jax.tree.map(jnp.asarray, bundle.cache)
        self.device[pid] = bundle
        self._enforce_device_capacity(keep=pid)
        return bundle

    def _drop(self, pid: str) -> None:
        self.device.pop(pid, None)
        self.host.pop(pid, None)

    def _enforce_device_capacity(self, keep: str | None = None) -> None:
        """Typed eviction, GPU order: inactive -> idle -> busy, LRU within."""
        order = {TypeLabel.INACTIVE: 0, TypeLabel.IDLE: 1, TypeLabel.BUSY: 2}
        while len(self.device) > self.n_device_states:
            victims = sorted(
                (p for p in self.device if p != keep),
                key=lambda p: (order[self.device[p].label],
                               self.device[p].last_used),
            )
            if not victims:
                break
            v = victims[0]
            self.evicted_pages["gpu"] += 1
            if len(self.host) < self.n_host_states:
                b = self.device.pop(v)
                b.cache = jax.tree.map(np.asarray, b.cache)
                self.host[v] = b
            else:
                self.device.pop(v)

    def _evict_host(self) -> None:
        """Typed eviction, host order: inactive -> busy -> idle (reversed —
        the host tier preferentially retains idle programs, paper §4.3.2)."""
        order = {TypeLabel.INACTIVE: 0, TypeLabel.BUSY: 1, TypeLabel.IDLE: 2}
        if not self.host:
            return
        v = min(self.host, key=lambda p: (order[self.host[p].label],
                                          self.host[p].last_used))
        self.host.pop(v)
        self.evicted_pages["cpu"] += 1
