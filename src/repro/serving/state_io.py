"""Serving control-plane persistence (restart / failover).

What must survive a router crash is the *control plane*: the program table
(tier, replica, context length, idleness window), per-replica tier usage,
and the typed-radix metadata needed to re-admit programs. KV pages
themselves are NOT persisted — on restart a program whose pages died with
the engine re-enters through the Waiting queue and recomputes, which is
exactly MORI's §4.3.1 semantics (the recompute path doubles as the
recovery path).

Snapshots are atomic (write-temp + os.replace) and versioned; ``restore``
rebuilds scheduler state onto a (possibly different-sized) replica set —
programs homed on replicas that no longer exist are re-queued as Waiting.
"""
from __future__ import annotations

import json
import os
from pathlib import Path

from repro.core.types import Tier, TypeLabel

FORMAT_VERSION = 1


def save_snapshot(router, path: str | os.PathLike) -> Path:
    """Atomic JSON snapshot of the router's scheduler state."""
    sched = router.sched
    snap = {
        "version": FORMAT_VERSION,
        "num_replicas": len(sched.replicas),
        "programs": {
            pid: {
                "tier": p.tier.value,
                "replica": p.replica,
                "context_tokens": p.context_tokens,
                "kv_bytes_per_token": p.kv_bytes_per_token,
                "label": p.label.value,
                "steps_completed": p.steps_completed,
                "finished": p.finished,
                "window": p.tracker.window_dump(),
            }
            for pid, p in sched.programs.items()
        },
    }
    path = Path(path)
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(json.dumps(snap, indent=1))
    os.replace(tmp, path)
    return path


def restore_snapshot(router, path: str | os.PathLike) -> dict:
    """Rebuild scheduler state from a snapshot onto ``router``.

    KV residency is conservative: every restored unfinished program enters
    the Waiting tier (its pages died with the old process); its context
    length and idleness window survive, so placement decisions pick up
    where they left off after the first recompute. Programs homed on
    replicas beyond the new replica count are likewise Waiting.

    Returns counters {"restored": n, "requeued": m}.
    """
    snap = json.loads(Path(path).read_text())
    assert snap["version"] == FORMAT_VERSION, snap["version"]
    sched = router.sched
    restored = requeued = 0
    for pid, rec in snap["programs"].items():
        if rec["finished"]:
            continue
        prog = sched.program_arrived(pid, rec["kv_bytes_per_token"], 0.0)
        prog.context_tokens = rec["context_tokens"]
        prog.steps_completed = rec["steps_completed"]
        prog.label = TypeLabel(rec["label"])
        prog.tracker.window_load(rec["window"])
        # conservative placement: pages did not survive the crash
        prog.tier = Tier.NONE
        prog.replica = None
        restored += 1
        requeued += 1
    return {"restored": restored, "requeued": requeued}
