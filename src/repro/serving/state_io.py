"""Serving control-plane persistence (restart / failover).

What must survive a router crash is the *control plane*: the program table
(tier, replica, context length, idleness window), per-replica tier usage,
and — since the decode-pump router — the per-slot batch occupancy at
snapshot time. KV pages themselves are NOT persisted — on restart a
program whose pages died with the engine re-enters through the Waiting
queue and recomputes, which is exactly MORI's §4.3.1 semantics (the
recompute path doubles as the recovery path).

:func:`control_plane_state` is the single source of truth for the
snapshot schema; ``repro.serving.router.snapshot_state`` delegates here
(the two used to serialize overlapping state independently).

Snapshots are atomic (write-temp + os.replace) and versioned; ``restore``
rebuilds scheduler state onto a (possibly different-sized) replica set —
programs homed on replicas that no longer exist are re-queued as Waiting.
"""
from __future__ import annotations

import json
import os
import time
from pathlib import Path

from repro.core.types import Tier, TypeLabel

#: v2 adds the per-replica section (tier byte usage + live decode-slot
#: occupancy); v3 adds tier formats (per-replica pool device/offload
#: formats and per-program wire_bytes_per_token) so restored placement
#: decisions keep pricing transfers at the format actually moved. v1/v2
#: snapshots (no format fields) still restore — absent fields mean bf16
#: everywhere, which is exactly what those versions could express.
FORMAT_VERSION = 3


def control_plane_state(router) -> dict:
    """The serializable control-plane view of a router: program table,
    per-replica tier usage, and live decode-slot occupancy."""
    sched = router.sched
    replicas = []
    for rep in sched.replicas:
        r = rep.replica_id
        pump = router._pump_slots[r] if r < len(router._pump_slots) else {}
        pool = getattr(router.engines[r], "pool", None) if r < len(
            router.engines
        ) else None
        replicas.append(
            {
                "gpu_used": rep.gpu_used,
                "cpu_used": rep.cpu_used,
                "ssd_used": rep.ssd_used,
                "device_format": getattr(pool, "device_format", "bf16"),
                "offload_format": getattr(pool, "offload_format", "bf16"),
                "slots": [
                    {
                        "pid": s.pid,
                        "step_idx": s.step_idx,
                        "decode_steps_taken": s.steps_taken,
                        "started_at": s.start,
                        "window_end": s.end,
                    }
                    for s in sorted(pump.values(), key=lambda s: s.seq)
                ],
            }
        )
    return {
        "version": FORMAT_VERSION,
        "num_replicas": len(sched.replicas),
        "programs": {
            pid: {
                "tier": p.tier.value,
                "replica": p.replica,
                "context_tokens": p.context_tokens,
                "kv_bytes_per_token": p.kv_bytes_per_token,
                "wire_bytes_per_token": p.wire_bytes_per_token,
                "label": p.label.value,
                "steps_completed": p.steps_completed,
                "finished": p.finished,
                "window": p.tracker.window_dump(),
            }
            for pid, p in sched.programs.items()
        },
        "replicas": replicas,
    }


def requeue_resident_slots(router, replica: int, now: float) -> int:
    """Tear down a failed replica's mid-flight decode/prefill slots and
    return their requests to the router's pending queue — the live-drain
    counterpart of :func:`restore_snapshot`'s was-resident handling.

    Each slot's engine-side state is released (``cancel_prefill`` for a
    slot still mid-prefill, ``Engine.abort_request`` for a decoding one;
    a slot whose decode already finished engine-side holds no pages and
    needs no teardown) and its ``(request, step_idx)`` goes back to
    ``router._pending``. The requeued step re-prefills the *identical*
    token context on whichever healthy replica the scheduler re-places it
    — decode is deterministic in the context, so the program's token
    stream is byte-identical to an undisturbed run: zero tokens lost.

    Returns the number of slots requeued.
    """
    slots = router._pump_slots[replica]
    eng = router.engines[replica]
    n = 0
    for slot in sorted(slots.values(), key=lambda s: s.seq):
        if slot.prefilling:
            eng.cancel_prefill(slot.prefill)
        elif slot.done is None and hasattr(eng, "abort_request"):
            eng.abort_request(slot.pid)
        router._pending[slot.pid] = (slot.req, slot.step_idx)
        # restart the TTFT clock only if the first token never landed
        # (the re-run's latency is what a caller would actually see)
        router._ttft_start.setdefault(
            (slot.pid, slot.step_idx), time.perf_counter()
        )
        n += 1
    slots.clear()
    return n


def save_snapshot(router, path: str | os.PathLike) -> Path:
    """Atomic JSON snapshot of the router's control-plane state."""
    snap = control_plane_state(router)
    path = Path(path)
    tmp = path.with_suffix(path.suffix + ".tmp")
    tmp.write_text(json.dumps(snap, indent=1))
    os.replace(tmp, path)
    return path


def restore_snapshot(router, path: str | os.PathLike) -> dict:
    """Rebuild scheduler state from a snapshot onto ``router``.

    KV residency is conservative: every restored unfinished program enters
    the Waiting tier (its pages died with the old process); its context
    length and idleness window survive, so placement decisions pick up
    where they left off after the first recompute. Programs homed on
    replicas beyond the new replica count are likewise Waiting, and
    programs that were resident in decode slots at snapshot time (their
    step was mid-flight) are counted separately — their in-flight step is
    simply re-issued after recompute, like a replica failure.

    Returns counters {"restored": n, "requeued": m, "was_resident": k}.
    """
    snap = json.loads(Path(path).read_text())
    assert snap["version"] in (1, 2, FORMAT_VERSION), snap["version"]
    sched = router.sched
    resident = {
        s["pid"]
        for rep in snap.get("replicas", [])
        for s in rep.get("slots", [])
    }
    restored = requeued = was_resident = 0
    for pid, rec in snap["programs"].items():
        if rec["finished"]:
            continue
        prog = sched.program_arrived(
            pid, rec["kv_bytes_per_token"], 0.0,
            wire_bytes_per_token=rec.get("wire_bytes_per_token"),
        )
        prog.context_tokens = rec["context_tokens"]
        prog.steps_completed = rec["steps_completed"]
        prog.label = TypeLabel(rec["label"])
        prog.tracker.window_load(rec["window"])
        # conservative placement: pages did not survive the crash
        prog.tier = Tier.NONE
        prog.replica = None
        restored += 1
        requeued += 1
        if pid in resident:
            was_resident += 1
    return {
        "restored": restored,
        "requeued": requeued,
        "was_resident": was_resident,
    }
