"""Real JAX serving plane: paged KV pool, engine, async transfer plane,
MORI router (multi-replica, with live cross-replica migration and
drain/failover)."""
from repro.core.balancer import PlacementDecision
from repro.core.transfers import CopyRequest, Endpoint
from repro.serving.engine import Completion, Engine, EngineRequest, PrefillJob
from repro.serving.kvpool import PagePool
from repro.serving.router import MoriRouter, RouterMetrics, snapshot_state
from repro.serving.ssm_engine import SsmEngine
from repro.serving.state_io import requeue_resident_slots
from repro.serving.transfer_plane import ReplicaTransferPlane

__all__ = [
    "Completion",
    "CopyRequest",
    "Endpoint",
    "Engine",
    "EngineRequest",
    "MoriRouter",
    "PagePool",
    "PlacementDecision",
    "PrefillJob",
    "ReplicaTransferPlane",
    "RouterMetrics",
    "SsmEngine",
    "requeue_resident_slots",
    "snapshot_state",
]
