"""Real JAX serving plane: paged KV pool, engine, async transfer plane,
MORI router."""
from repro.serving.engine import Completion, Engine, EngineRequest, PrefillJob
from repro.serving.kvpool import PagePool
from repro.serving.router import MoriRouter, RouterMetrics, snapshot_state
from repro.serving.ssm_engine import SsmEngine
from repro.serving.transfer_plane import ReplicaTransferPlane

__all__ = [
    "Completion",
    "Engine",
    "EngineRequest",
    "MoriRouter",
    "PagePool",
    "PrefillJob",
    "ReplicaTransferPlane",
    "RouterMetrics",
    "SsmEngine",
    "snapshot_state",
]
