"""Real JAX serving plane: paged KV pool, engine, MORI router."""
from repro.serving.engine import Completion, Engine, EngineRequest
from repro.serving.kvpool import PagePool
from repro.serving.router import MoriRouter, RouterMetrics, snapshot_state
from repro.serving.ssm_engine import SsmEngine

__all__ = [
    "Completion",
    "Engine",
    "EngineRequest",
    "MoriRouter",
    "PagePool",
    "RouterMetrics",
    "SsmEngine",
    "snapshot_state",
]
