"""Per-page int8 KV quantization: the format layer behind tiered KV pages.

MORI's placement math is all bytes-over-links: offloads must fit tool-call
idle windows, tier budgets are bytes, and the cancel-vs-round-trip regime
boundary sits wherever page bytes / link bandwidth says it does. Halving
bytes-per-page therefore moves *every* boundary at once. This module is the
single source of truth for what a page weighs in each format and for the
quantize / dequantize / requantize transforms the pool, the Pallas kernel,
the jnp oracle and the host staging path all share.

Format vocabulary (``PAGE_FORMATS``):

* ``"bf16"`` — raw bfloat16 payload, 2 bytes/element, no sidecar. Host
  staging carries the exact bits (uint16 view), so round trips are
  bit-exact.
* ``"int8"`` — symmetric int8 payload, 1 byte/element, plus one fp32
  scale per (layer, page) for K and one for V riding in a *sidecar*
  array. ``scale = max(|x|) / 127`` over the page's ``T*KH*HD`` elements;
  dequant is ``x̂ = q * scale``. Quantize→dequantize is lossy (bounded by
  ``scale/2`` per element); quantized payload + sidecar round-trip
  byte-identically through host tiers and cross-replica imports.

Every byte count anyone bills — ``CopyRequest.nbytes``, ledger in-flight
bytes, tier budgets, ``RouterMetrics.offload_bytes`` — must come from
:func:`page_wire_bytes` / :func:`token_wire_bytes` so the accounting can
never drift from the format actually moved (lint rule KV008 enforces the
"no hand-rolled 2-bytes-per-element arithmetic" side of this).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

F32 = jnp.float32

#: the page formats a tier can declare; anything else is a config error
PAGE_FORMATS = ("bf16", "int8")

#: quantized values live in [-QMAX, QMAX] (symmetric, no -128 asymmetry)
QMAX = 127.0

#: floor for scales so an all-zero page stays representable (and division
#: by the scale is always finite)
SCALE_EPS = 1e-8


def check_format(fmt: str) -> str:
    if fmt not in PAGE_FORMATS:
        raise ValueError(f"unknown KV page format {fmt!r}; pick from {PAGE_FORMATS}")
    return fmt


def bytes_per_element(fmt: str) -> int:
    """Payload bytes per KV element in ``fmt`` (sidecar excluded)."""
    return 1 if check_format(fmt) == "int8" else 2


def page_wire_bytes(
    layers: int, page_tokens: int, kv_heads: int, head_dim: int, fmt: str
) -> int:
    """Bytes one page occupies on the wire (and at rest) in ``fmt``:
    K+V payload plus, for int8, the fp32 scale sidecar (one scale per
    layer for K and one for V)."""
    elems = layers * page_tokens * kv_heads * head_dim * 2  # K and V
    payload = elems * bytes_per_element(fmt)
    sidecar = layers * 2 * 4 if fmt == "int8" else 0
    return payload + sidecar


def token_wire_bytes(layers: int, kv_heads: int, head_dim: int, fmt: str) -> int:
    """Bytes one token's KV contributes in ``fmt`` — the per-token figure
    schedulers price transfers with. Scale sidecars are per *page*, not per
    token, so they amortize away here (they are < 1% of a page and the
    control plane sizes transfers in whole tokens anyway)."""
    return layers * 2 * kv_heads * head_dim * bytes_per_element(fmt)


# ---------------------------------------------------------------- jnp side
def quantize_pages(x):
    """Quantize pages to int8 with one scale per page (jit-safe).

    ``x``: ``[..., T, KH, HD]`` — any number of leading axes (the pool uses
    ``[L, N, T, KH, HD]``, the kernel's layer slice ``[N, T, KH, HD]``).
    Returns ``(q int8 same-shape, scales f32 over the leading axes)``.
    """
    amax = jnp.max(jnp.abs(x.astype(F32)), axis=(-3, -2, -1))
    scales = jnp.maximum(amax, SCALE_EPS) / QMAX
    q = jnp.round(x.astype(F32) / scales[..., None, None, None])
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q, scales


def dequantize_pages(q, scales, dtype=jnp.bfloat16):
    """Inverse of :func:`quantize_pages` (up to quantization error)."""
    return (q.astype(F32) * scales[..., None, None, None]).astype(dtype)


def requantize_insert(q_pages, scales, pages, offsets, new_vals):
    """Insert one new token per batch row into quantized pages (jit-safe).

    The decode append on an int8-resident pool: dequantize the ``B``
    affected pages, write ``new_vals[b]`` at ``(pages[b], offsets[b])``,
    re-derive each page's scale (it may grow — the new token can exceed the
    old amax) and requantize. Only the touched pages move; the pool update
    is a single scatter.

    ``q_pages`` ``[N, T, KH, HD]`` int8, ``scales`` ``[N]`` f32,
    ``pages``/``offsets`` ``[B]`` int32 (distinct pages — each batch row
    owns its tail page), ``new_vals`` ``[B, KH, HD]``.
    """
    B = pages.shape[0]
    tiles = q_pages[pages].astype(F32) * scales[pages][:, None, None, None]
    tiles = tiles.at[jnp.arange(B), offsets].set(new_vals.astype(F32))
    amax = jnp.max(jnp.abs(tiles), axis=(1, 2, 3))
    new_s = jnp.maximum(amax, SCALE_EPS) / QMAX
    q = jnp.round(tiles / new_s[:, None, None, None])
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q_pages.at[pages].set(q), scales.at[pages].set(new_s)


def requantize_insert_run(q_k, s_k, pages, offsets, new_vals):
    """All-layers twin of :func:`requantize_insert` for the pool layout:
    ``q_k`` ``[L, N, T, KH, HD]`` int8, ``s_k`` ``[L, N]`` f32, ``new_vals``
    ``[L, B, KH, HD]`` — one batched gather/scatter commits every layer's
    append (the paged decode step's post-scan commit)."""
    B = pages.shape[0]
    tiles = q_k[:, pages].astype(F32) * s_k[:, pages][..., None, None, None]
    tiles = tiles.at[:, jnp.arange(B), offsets].set(new_vals.astype(F32))
    amax = jnp.max(jnp.abs(tiles), axis=(2, 3, 4))
    new_s = jnp.maximum(amax, SCALE_EPS) / QMAX
    q = jnp.round(tiles / new_s[..., None, None, None])
    q = jnp.clip(q, -QMAX, QMAX).astype(jnp.int8)
    return q_k.at[:, pages].set(q), s_k.at[:, pages].set(new_s)


# -------------------------------------------------------------- numpy side
def quantize_np(x: np.ndarray):
    """Host-staging quantizer: ``x`` ``[L, T, KH, HD]`` (one page, all
    layers) → ``(int8 payload, f32 scales [L])``. Mirrors
    :func:`quantize_pages` exactly so device- and host-side quantization of
    the same page produce identical bytes."""
    xf = np.asarray(x, np.float32)
    amax = np.max(np.abs(xf), axis=(1, 2, 3))
    scales = (np.maximum(amax, SCALE_EPS) / QMAX).astype(np.float32)
    q = np.rint(xf / scales[:, None, None, None])
    return np.clip(q, -QMAX, QMAX).astype(np.int8), scales


def dequantize_np(q: np.ndarray, scales: np.ndarray) -> np.ndarray:
    """Inverse of :func:`quantize_np` → float32 ``[L, T, KH, HD]``."""
    return q.astype(np.float32) * np.asarray(scales, np.float32)[:, None, None, None]
