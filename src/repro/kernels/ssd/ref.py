"""Pure-jnp oracle for the Mamba-2 SSD (state-space duality) scan.

Implements the chunked block decomposition of Dao & Gu, "Transformers are
SSMs" (arXiv:2405.21060, Algorithm 1 / SSD): within-chunk attention-like
term + between-chunk low-rank state recurrence. This file is the single
source of truth: the model's portable path calls it, and the Pallas kernel
(`ssd/kernel.py`) is validated against it in interpret mode.

Shapes (h = heads, p = head dim, n = state dim, g = B/C groups):
    x  : [b, s, h, p]
    dt : [b, s, h]       (post-softplus, >= 0)
    A  : [h]             (negative reals; decay = exp(dt * A))
    B  : [b, s, g, n]
    C  : [b, s, g, n]
returns
    y          : [b, s, h, p]
    final_state: [b, h, p, n]
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _expand_groups(t: jax.Array, h: int) -> jax.Array:
    """[b, s, g, n] -> [b, s, h, n] by repeating each group over its heads."""
    g = t.shape[2]
    assert h % g == 0, (h, g)
    return jnp.repeat(t, h // g, axis=2)


def ssd_reference(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    B: jax.Array,
    C: jax.Array,
    *,
    chunk: int = 128,
    initial_state: jax.Array | None = None,
):
    b, s, h, p = x.shape
    n = B.shape[-1]
    if s % chunk != 0:
        # zero-pad to a chunk multiple: dt=0 -> decay 1, x=0 -> no update
        pad = chunk - s % chunk
        y, st = ssd_reference(
            jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(dt, ((0, 0), (0, pad), (0, 0))),
            A,
            jnp.pad(B, ((0, 0), (0, pad), (0, 0), (0, 0))),
            jnp.pad(C, ((0, 0), (0, pad), (0, 0), (0, 0))),
            chunk=chunk,
            initial_state=initial_state,
        )
        return y[:, :s], st
    c = s // chunk
    f32 = jnp.float32

    Bh = _expand_groups(B, h).astype(f32)
    Ch = _expand_groups(C, h).astype(f32)
    xf = x.astype(f32)
    dtf = dt.astype(f32)
    dA = dtf * A.astype(f32)[None, None, :]                    # [b,s,h]

    # chunked views
    xq = xf.reshape(b, c, chunk, h, p)
    dtq = dtf.reshape(b, c, chunk, h)
    dAq = dA.reshape(b, c, chunk, h)
    Bq = Bh.reshape(b, c, chunk, h, n)
    Cq = Ch.reshape(b, c, chunk, h, n)

    cum = jnp.cumsum(dAq, axis=2)                              # [b,c,q,h]
    total = cum[:, :, -1, :]                                   # [b,c,h]

    # ---- intra-chunk (the "attention-like" quadratic term)
    # L[i,j] = exp(cum_i - cum_j) for i >= j else 0
    li = cum[:, :, :, None, :]                                 # [b,c,q,1,h]
    lj = cum[:, :, None, :, :]                                 # [b,c,1,q,h]
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    L = jnp.where(mask[None, None, :, :, None], jnp.exp(li - lj), 0.0)
    scores = jnp.einsum("bcqhn,bckhn->bcqkh", Cq, Bq) * L      # [b,c,q,k,h]
    xdt = xq * dtq[..., None]                                  # [b,c,q,h,p]
    y_diag = jnp.einsum("bcqkh,bckhp->bcqhp", scores, xdt)

    # ---- per-chunk states: sum_k exp(total - cum_k) * B_k (x)dt_k
    decay_to_end = jnp.exp(total[:, :, None, :] - cum)         # [b,c,q,h]
    chunk_states = jnp.einsum(
        "bcqhn,bcqhp->bchpn", Bq * decay_to_end[..., None], xdt
    )                                                          # [b,c,h,p,n]

    # ---- inter-chunk recurrence over chunk states
    decay_chunk = jnp.exp(total)                               # [b,c,h]
    if initial_state is None:
        init = jnp.zeros((b, h, p, n), f32)
    else:
        init = initial_state.astype(f32)

    def scan_fn(carry, inp):
        st, dc = inp                                           # [b,h,p,n], [b,h]
        new = carry * dc[:, :, None, None] + st
        return new, carry                                      # emit state BEFORE chunk

    final, prev_states = jax.lax.scan(
        scan_fn,
        init,
        (
            jnp.moveaxis(chunk_states, 1, 0),                  # [c,b,h,p,n]
            jnp.moveaxis(decay_chunk, 1, 0),                   # [c,b,h]
        ),
    )
    prev_states = jnp.moveaxis(prev_states, 0, 1)              # [b,c,h,p,n]

    # ---- inter-chunk contribution: C_q exp(cum_q) @ state_before_chunk
    y_off = jnp.einsum(
        "bcqhn,bchpn->bcqhp", Cq * jnp.exp(cum)[..., None], prev_states
    )

    y = (y_diag + y_off).reshape(b, s, h, p)
    return y.astype(x.dtype), final


def ssd_decode_step(
    state: jax.Array,   # [b, h, p, n]
    x: jax.Array,       # [b, h, p]
    dt: jax.Array,      # [b, h]
    A: jax.Array,       # [h]
    B: jax.Array,       # [b, g, n]
    C: jax.Array,       # [b, g, n]
):
    """One-token recurrent update: h' = exp(dt*A) h + dt * x (x) B; y = C h'."""
    b, h, p, n = state.shape
    f32 = jnp.float32
    Bh = jnp.repeat(B, h // B.shape[1], axis=1).astype(f32)    # [b,h,n]
    Ch = jnp.repeat(C, h // C.shape[1], axis=1).astype(f32)
    decay = jnp.exp(dt.astype(f32) * A.astype(f32)[None, :])   # [b,h]
    upd = (dt.astype(f32)[..., None] * x.astype(f32))[..., None] * Bh[:, :, None, :]
    new_state = state * decay[:, :, None, None] + upd          # [b,h,p,n]
    y = jnp.einsum("bhpn,bhn->bhp", new_state, Ch)
    return y.astype(x.dtype), new_state


def ssd_naive(x, dt, A, B, C, *, initial_state=None):
    """O(s) sequential scan — the ground-truth oracle for tiny shapes."""
    b, s, h, p = x.shape
    n = B.shape[-1]
    state = (
        jnp.zeros((b, h, p, n), jnp.float32)
        if initial_state is None
        else initial_state.astype(jnp.float32)
    )
    ys = []
    for t in range(s):
        y, state = ssd_decode_step(state, x[:, t], dt[:, t], A, B[:, t], C[:, t])
        ys.append(y)
    return jnp.stack(ys, axis=1), state
