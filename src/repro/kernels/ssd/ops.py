"""Public entry point: Pallas SSD on TPU, chunked-jnp reference elsewhere.

``REPRO_KERNEL_INTERPRET=1`` routes the off-TPU path through the Pallas
kernel in interpret mode (CI kernel-parity job); read at call time.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.ssd.kernel import ssd as _pallas
from repro.kernels.ssd.ref import ssd_reference as _ref


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Mamba-2 SSD scan. x [B,S,H,P]; B/C [B,S,1,N] (single group)."""
    if jax.default_backend() == "tpu":
        return _pallas(x, dt, A, Bm, Cm, chunk=chunk)
    if os.environ.get("REPRO_KERNEL_INTERPRET", "0") == "1":
        return _pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    if Bm.ndim == 3:
        Bm, Cm = Bm[:, :, None, :], Cm[:, :, None, :]
    return _ref(x, dt, A, Bm, Cm, chunk=chunk)
