"""Public entry point: Pallas SSD on TPU, chunked-jnp reference elsewhere.

``REPRO_KERNEL_INTERPRET=1`` routes the off-TPU path through the Pallas
kernel in interpret mode (CI kernel-parity job); read at call time.
"""
from __future__ import annotations

import os

import jax

from repro.kernels.ssd.kernel import ssd as _pallas
from repro.kernels.ssd.ref import ssd_reference as _ref


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Mamba-2 SSD scan. x [B,S,H,P]; B/C [B,S,1,N] (single group)."""
    if jax.default_backend() == "tpu":
        return _pallas(x, dt, A, Bm, Cm, chunk=chunk)
    if os.environ.get("REPRO_KERNEL_INTERPRET", "0") == "1":
        return _pallas(x, dt, A, Bm, Cm, chunk=chunk, interpret=True)
    # rank normalization, not size bucketing: compiles once per rank,
    # which is deliberate (the audit probe stays within one rank)
    if Bm.ndim == 3:  # lint: jit-shape-branch-ok
        Bm, Cm = Bm[:, :, None, :], Cm[:, :, None, :]
    return _ref(x, dt, A, Bm, Cm, chunk=chunk)


def audit_spec():
    """Example-shape jit target for :mod:`repro.analysis.jitaudit` — the
    chunked SSD scan at one sequence bucket, probed at double length
    (more chunks, same per-chunk program structure is NOT guaranteed —
    the scan length is baked into the jaxpr — so the probe stays within
    one chunk count by doubling heads instead)."""
    import functools

    import jax.numpy as jnp

    def make(heads: int):
        def args():
            x = jnp.zeros((1, 64, heads, 16), jnp.bfloat16)
            dt = jnp.ones((1, 64, heads), jnp.float32)
            A = -jnp.ones((heads,), jnp.float32)
            B = jnp.zeros((1, 64, 1, 16), jnp.bfloat16)
            return x, dt, A, B, B

        return args

    return {
        "name": "kernels.ssd",
        "fn": jax.jit(functools.partial(ssd, chunk=32)),
        "make_args": make(2),
        "probe_args": make(4),
        "bucket": {"seq": 64, "heads": 2, "state": 16, "chunk": 32},
    }
