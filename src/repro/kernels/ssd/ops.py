"""Public entry point: Pallas SSD on TPU, chunked-jnp reference elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.ssd.kernel import ssd as _pallas
from repro.kernels.ssd.ref import ssd_reference as _ref


def ssd(x, dt, A, Bm, Cm, *, chunk: int = 128):
    """Mamba-2 SSD scan. x [B,S,H,P]; B/C [B,S,1,N] (single group)."""
    if jax.default_backend() == "tpu":
        return _pallas(x, dt, A, Bm, Cm, chunk=chunk)
    if Bm.ndim == 3:
        Bm, Cm = Bm[:, :, None, :], Cm[:, :, None, :]
    return _ref(x, dt, A, Bm, Cm, chunk=chunk)
