"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

TPU adaptation of the SSD algorithm (arXiv:2405.21060): the chunk axis is
the *sequential minor grid dimension* so the running inter-chunk state lives
in a VMEM scratch accumulator ([P, N] f32 per (batch, head)) — the TPU
analogue of the CUDA implementation's cross-block state passing. The
intra-chunk quadratic term and the state update are both MXU matmuls over
(Q, P)/(Q, N) tiles.

Host-side layouts (pre-chunked):
    x   [B, C, Q, H, P]     dt [B, C, Q, H]     A [H]
    Bm  [B, C, Q, N]        Cm [B, C, Q, N]     (single B/C group)
    y   [B, C, Q, H, P]     final_state [B, H, P, N]
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(
    x_ref,      # [1, 1, Q, 1, P]
    dt_ref,     # [1, 1, Q, 1]
    a_ref,      # [1]
    b_ref,      # [1, 1, Q, N]
    c_ref,      # [1, 1, Q, N]
    y_ref,      # [1, 1, Q, 1, P]
    st_ref,     # [1, 1, P, N]  (final state out)
    state_scr,  # [P, N] f32
    *,
    chunk: int,
):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)
    Q = chunk

    @pl.when(ci == 0)
    def _init():
        state_scr[...] = jnp.zeros_like(state_scr)

    x = x_ref[0, 0, :, 0, :].astype(F32)                       # [Q, P]
    dt = dt_ref[0, 0, :, :].astype(F32)                        # [Q, 1]
    A = a_ref[0].astype(F32)
    Bm = b_ref[0, 0].astype(F32)                               # [Q, N]
    Cm = c_ref[0, 0].astype(F32)                               # [Q, N]

    dA = dt * A                                                # [Q, 1]
    cum = jnp.cumsum(dA, axis=0)                               # [Q, 1]
    total = cum[Q - 1, 0]

    # intra-chunk attention-like term
    li = cum                                                   # [Q,1]
    lj = cum.reshape(1, Q)                                     # [1,Q]
    ii = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    L = jnp.where(ii >= jj, jnp.exp(li - lj), 0.0)             # [Q,Q]
    scores = (
        jax.lax.dot(Cm, Bm.T, preferred_element_type=F32) * L
    )                                                          # [Q,Q]
    xdt = x * dt                                               # [Q,P]
    y = jax.lax.dot(scores, xdt, preferred_element_type=F32)   # [Q,P]

    # inter-chunk contribution from the carried state
    state = state_scr[...]                                     # [P,N]
    c_decay = Cm * jnp.exp(cum)                                # [Q,N]
    y = y + jax.lax.dot(c_decay, state.T, preferred_element_type=F32)

    # state update: state' = state * exp(total) + xdt^T (Bm * decay_to_end)
    decay_end = jnp.exp(total - cum)                           # [Q,1]
    state_scr[...] = state * jnp.exp(total) + jax.lax.dot(
        xdt.T, Bm * decay_end, preferred_element_type=F32
    )

    y_ref[0, 0, :, 0, :] = y.astype(y_ref.dtype)

    @pl.when(ci == nc - 1)
    def _final():
        st_ref[0, 0] = state_scr[...].astype(st_ref.dtype)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,    # [B, S, H, P]
    dt: jax.Array,   # [B, S, H]  (post-softplus)
    A: jax.Array,    # [H]
    Bm: jax.Array,   # [B, S, 1, N] or [B, S, N]
    Cm: jax.Array,
    *,
    chunk: int = 128,
    interpret: bool = False,
):
    # rank normalization, not data-dependent control flow: callers pass B/C
    # as [B,S,1,N] or [B,S,N] and each rank compiles exactly once
    if Bm.ndim == 4:  # lint: jit-shape-branch-ok
        Bm = Bm[:, :, 0, :]
        Cm = Cm[:, :, 0, :]
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    assert S % chunk == 0
    C = S // chunk
    xr = x.reshape(B, C, chunk, H, P)
    dtr = dt.reshape(B, C, chunk, H)
    br = Bm.reshape(B, C, chunk, N)
    cr = Cm.reshape(B, C, chunk, N)
    grid = (B, H, C)
    y, st = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, c, 0, h)),
            pl.BlockSpec((1,), lambda b, h, c: (h,)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, c, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, 1, P), lambda b, h, c: (b, c, 0, h, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, C, chunk, H, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), F32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), F32)],
        interpret=interpret,
    )(xr, dtr, A, br, cr)
    return y.reshape(B, S, H, P), st
