"""Pure-jnp oracle for paged decode attention: gather pages densely, run
masked softmax attention.

Semantics (shared with the Pallas kernel, validated in tests):

* GQA — ``H = KH * G`` query heads share KH KV heads;
* ``softcap`` — gemma2-style logit capping ``cap * tanh(s / cap)``;
* ``window`` — sliding-window decode: only the last ``window`` positions
  (``[length - window, length)``) are visible, matching
  :func:`repro.models.layers.decode_attention`;
* ragged ``lengths`` — positions at or past a sequence's length are
  masked, so partially-filled tail pages and garbage pages beyond the
  block table's live span never leak into the output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def paged_attention_ref(
    q, k_pages, v_pages, block_tables, lengths, *, softcap=None, window=None
):
    B = q.shape[0]
    T, KH, D = k_pages.shape[1:]
    P = block_tables.shape[1]
    # dense gather: [B, P*T, KH, D]
    k = k_pages[block_tables].reshape(B, P * T, KH, D)
    v = v_pages[block_tables].reshape(B, P * T, KH, D)
    return _gathered_attention(q, k, v, lengths, softcap, window)


def paged_attention_decode_ref(
    q, k_new, v_new, k_pages, v_pages, block_tables, lengths,
    *, softcap=None, window=None,
):
    """Decode-step oracle where the current token's KV (``k_new``/``v_new``
    ``[B, KH, D]``, global position ``lengths - 1``) has *not* been written
    to the pool yet: it is inserted into the gathered context locally.

    Bit-identical to scattering into the tail page first and calling
    :func:`paged_attention_ref` — but the insert touches a ``[B, P*T]``
    gather, not the ``[N, T]`` pool, so a layer scan over this op never
    copies the pool. The engine appends all layers' KV to the tail pages
    in one batched scatter after the scan.
    """
    B = q.shape[0]
    T, KH, D = k_pages.shape[1:]
    P = block_tables.shape[1]
    k = k_pages[block_tables].reshape(B, P * T, KH, D)
    v = v_pages[block_tables].reshape(B, P * T, KH, D)
    idx = jnp.arange(B), lengths - 1
    k = k.at[idx].set(k_new.astype(k.dtype))
    v = v.at[idx].set(v_new.astype(v.dtype))
    return _gathered_attention(q, k, v, lengths, softcap, window)


def _gathered_attention(q, k, v, lengths, softcap, window):
    B, H, D = q.shape
    S = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    k = k.astype(F32)
    v = v.astype(F32)
    qf = q.reshape(B, KH, G, D).astype(F32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None, :]
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return out.reshape(B, H, D).astype(q.dtype)
