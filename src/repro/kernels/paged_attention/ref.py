"""Pure-jnp oracle for paged decode attention: gather pages densely, run
masked softmax attention.

Semantics (shared with the Pallas kernel, validated in tests):

* GQA — ``H = KH * G`` query heads share KH KV heads;
* ``softcap`` — gemma2-style logit capping ``cap * tanh(s / cap)``;
* ``window`` — sliding-window decode: only the last ``window`` positions
  (``[length - window, length)``) are visible, matching
  :func:`repro.models.layers.decode_attention`;
* ragged ``lengths`` — positions at or past a sequence's length are
  masked, so partially-filled tail pages and garbage pages beyond the
  block table's live span never leak into the output;
* ``k_scales``/``v_scales`` — int8 pools: pages are dequantized *in the
  gather* (``q_page.astype(f32) * scale[page]``), exactly what the Pallas
  kernel does per VMEM tile, so oracle and kernel see identical operands.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def _gather_pages(k_pages, v_pages, block_tables, k_scales, v_scales):
    """Dense gather -> ``[B, P*T, KH, D]``, dequantizing int8 pools."""
    B, P = block_tables.shape
    T, KH, D = k_pages.shape[1:]
    k = k_pages[block_tables]                       # [B, P, T, KH, D]
    v = v_pages[block_tables]
    if k_scales is not None:
        k = k.astype(F32) * k_scales[block_tables][..., None, None, None]
        v = v.astype(F32) * v_scales[block_tables][..., None, None, None]
    return k.reshape(B, P * T, KH, D), v.reshape(B, P * T, KH, D)


def paged_attention_ref(
    q, k_pages, v_pages, block_tables, lengths,
    k_scales=None, v_scales=None, *, softcap=None, window=None,
):
    k, v = _gather_pages(k_pages, v_pages, block_tables, k_scales, v_scales)
    return _gathered_attention(q, k, v, lengths, softcap, window)


def paged_attention_decode_ref(
    q, k_new, v_new, k_pages, v_pages, block_tables, lengths,
    k_scales=None, v_scales=None, *, softcap=None, window=None,
):
    """Decode-step oracle where the current token's KV (``k_new``/``v_new``
    ``[B, KH, D]``, global position ``lengths - 1``) has *not* been written
    to the pool yet: it is inserted into the gathered context locally.

    Bit-identical to scattering into the tail page first and calling
    :func:`paged_attention_ref` — but the insert touches a ``[B, P*T]``
    gather, not the ``[N, T]`` pool, so a layer scan over this op never
    copies the pool. The engine appends all layers' KV to the tail pages
    in one batched scatter after the scan.

    On an int8 pool the insert lands in the dequantized f32 gather, i.e.
    the new token is attended at full precision; the kernel path instead
    requantizes the tail page before the gather, which adds one page's
    quantization error on the freshly appended token (inside the
    documented parity band, pinned in tests/test_kv_quant.py).
    """
    B = q.shape[0]
    k, v = _gather_pages(k_pages, v_pages, block_tables, k_scales, v_scales)
    idx = jnp.arange(B), lengths - 1
    k = k.at[idx].set(k_new.astype(k.dtype))
    v = v.at[idx].set(v_new.astype(v.dtype))
    return _gathered_attention(q, k, v, lengths, softcap, window)


def _gathered_attention(q, k, v, lengths, softcap, window):
    B, H, D = q.shape
    S = k.shape[1]
    KH = k.shape[2]
    G = H // KH
    k = k.astype(F32)
    v = v.astype(F32)
    qf = q.reshape(B, KH, G, D).astype(F32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(S)[None, :]
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return out.reshape(B, H, D).astype(q.dtype)
