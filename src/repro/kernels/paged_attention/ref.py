"""Pure-jnp oracle for paged decode attention: gather pages densely, run
masked softmax attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

F32 = jnp.float32


def paged_attention_ref(q, k_pages, v_pages, block_tables, lengths, *, softcap=None):
    B, H, D = q.shape
    N, T, KH, _ = k_pages.shape
    P = block_tables.shape[1]
    G = H // KH
    # dense gather: [B, P*T, KH, D]
    k = k_pages[block_tables].reshape(B, P * T, KH, D).astype(F32)
    v = v_pages[block_tables].reshape(B, P * T, KH, D).astype(F32)
    qf = q.reshape(B, KH, G, D).astype(F32) * (D ** -0.5)
    s = jnp.einsum("bkgd,bskd->bkgs", qf, k)
    if softcap is not None:
        s = softcap * jnp.tanh(s / softcap)
    pos = jnp.arange(P * T)[None, :]
    mask = pos < lengths[:, None]
    s = jnp.where(mask[:, None, None, :], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bkgs,bskd->bkgd", w, v)
    return out.reshape(B, H, D).astype(q.dtype)
