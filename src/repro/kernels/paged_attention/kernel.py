"""Pallas TPU paged-attention decode kernel.

The serving hot-spot MORI's placement feeds: one new query token per
sequence attends over a *paged* KV pool through a block-table indirection.

TPU adaptation (vs. the CUDA PagedAttention of vLLM): instead of per-warp
gather loops, the block table is **scalar-prefetched** and drives the
``BlockSpec`` index_map — the Pallas pipeline DMAs exactly the right
(page_tokens, head_dim) KV tile from HBM into VMEM for every grid step, so
the gather *is* the pipeline (no scatter/gather ALU work, MXU-friendly
tiles). Online-softmax accumulators live in VMEM scratch and persist across
the sequential page-grid dimension.

Layouts:
    q            [B, H, D]           (one decode token per sequence)
    k_pages      [N_pages, T, KH, D] (T = page_tokens)
    v_pages      [N_pages, T, KH, D]
    block_tables [B, P]   int32      (P = max pages per sequence)
    lengths      [B]      int32      (valid context incl. current token)
    k_scales     [N_pages] f32       (int8 pools only: per-page dequant scale)
    v_scales     [N_pages] f32
    out          [B, H, D]

Quantized (int8) pools: the per-page scale sidecars are *scalar-prefetched*
alongside the block table — they live in SMEM, so the kernel reads the one
scale its current page needs (``ks_ref[tables_ref[b, p]]``) and folds the
dequant into the existing ``astype(F32)`` on the VMEM tile. No extra DMA,
no dequantized copy of the pool ever exists.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

F32 = jnp.float32


def _kernel(
    # scalar-prefetch refs (quantized adds ks_ref/vs_ref after lengths_ref)
    tables_ref,          # [B, P] int32
    lengths_ref,         # [B] int32
    *rest,
    page_tokens: int,
    kv_heads: int,
    q_per_kv: int,
    softcap: float | None,
    window: int | None,
    quantized: bool,
):
    if quantized:
        # ks_ref/vs_ref: [N] f32 per-page scales, scalar-prefetched (SMEM)
        ks_ref, vs_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = rest
    else:
        q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr = rest
        ks_ref = vs_ref = None
    b = pl.program_id(0)
    p = pl.program_id(1)
    n_pages = pl.num_programs(1)
    T = page_tokens

    @pl.when(p == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, -1e30)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    length = lengths_ref[b]
    # sliding-window decode: only positions [lo, length) are visible. Pages
    # entirely below lo are skipped at the grid level (their DMA still runs —
    # the index_map is position-blind — but no MXU work is issued).
    lo = jnp.maximum(0, length - window) if window is not None else 0
    visit = p * T < length
    if window is not None:
        visit &= (p + 1) * T > lo

    @pl.when(visit)
    def _compute():
        q = q_ref[0].astype(F32)                               # [H, D]
        D = q.shape[-1]
        q = q.reshape(kv_heads, q_per_kv, D) * (D ** -0.5)
        k = k_ref[0].astype(F32)                               # [T, KH, D]
        v = v_ref[0].astype(F32)
        if quantized:
            # dequant folded into the f32 upcast: one SMEM scalar per page
            page = tables_ref[b, p]
            k = k * ks_ref[page]
            v = v * vs_ref[page]
        s = jax.lax.dot_general(                               # [KH, G, T]
            q,
            k.transpose(1, 2, 0),                              # [KH, D, T]
            ((( 2,), (1,)), ((0,), (0,))),
            preferred_element_type=F32,
        )
        if softcap is not None:
            s = softcap * jnp.tanh(s / softcap)
        pos = p * T + jax.lax.broadcasted_iota(jnp.int32, (1, 1, T), 2)
        valid = pos < length
        if window is not None:
            valid &= pos >= lo
        s = jnp.where(valid, s, -1e30)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        pexp = jnp.exp(s - m_new[..., None])
        l_scr[...] = l_scr[...] * alpha + pexp.sum(axis=-1)
        pv = jax.lax.dot_general(                              # [KH, G, D]
            pexp,
            v.transpose(1, 0, 2),                              # [KH, T, D]
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=F32,
        )
        acc_scr[...] = acc_scr[...] * alpha[..., None] + pv
        m_scr[...] = m_new

    @pl.when(p == n_pages - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-30)[..., None]
        out = acc_scr[...] / denom                             # [KH, G, D]
        o_ref[0] = out.reshape(kv_heads * q_per_kv, -1).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("softcap", "window", "interpret")
)
def paged_attention(
    q: jax.Array,            # [B, H, D]
    k_pages: jax.Array,      # [N, T, KH, D]
    v_pages: jax.Array,      # [N, T, KH, D]
    block_tables: jax.Array, # [B, P] int32
    lengths: jax.Array,      # [B] int32
    k_scales: jax.Array | None = None,   # [N] f32 (int8 pools only)
    v_scales: jax.Array | None = None,
    *,
    softcap: float | None = None,
    window: int | None = None,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    N, T, KH, _ = k_pages.shape
    P = block_tables.shape[1]
    G = H // KH
    quantized = k_scales is not None
    # index_maps take (b, p, *prefetch_refs); the block table is always the
    # first prefetch ref, so one lambda arity covers both operand sets
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quantized else 2,
        grid=(B, P),
        in_specs=[
            pl.BlockSpec((1, H, D), lambda b, p, *refs: (b, 0, 0)),
            pl.BlockSpec(
                (1, T, KH, D), lambda b, p, *refs: (refs[0][b, p], 0, 0, 0)
            ),
            pl.BlockSpec(
                (1, T, KH, D), lambda b, p, *refs: (refs[0][b, p], 0, 0, 0)
            ),
        ],
        out_specs=pl.BlockSpec((1, H, D), lambda b, p, *refs: (b, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((KH, G), F32),
            pltpu.VMEM((KH, G), F32),
            pltpu.VMEM((KH, G, D), F32),
        ],
    )
    kern = functools.partial(
        _kernel,
        page_tokens=T,
        kv_heads=KH,
        q_per_kv=G,
        softcap=softcap,
        window=window,
        quantized=quantized,
    )
    call = pl.pallas_call(
        kern,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        interpret=interpret,
    )
    if quantized:
        return call(
            block_tables, lengths,
            k_scales.astype(F32), v_scales.astype(F32),
            q, k_pages, v_pages,
        )
    return call(block_tables, lengths, q, k_pages, v_pages)
