"""Public entry point: Pallas kernel on TPU, oracle fallback elsewhere.

``REPRO_KERNEL_INTERPRET=1`` routes the off-TPU path through the Pallas
kernel in interpret mode instead of the jnp oracle — CI's kernel-parity job
uses it so the TPU branch of this dispatch is never dead code on a CPU
runner. The env var is read at call time so tests can flip it per-case.

Both entry points accept optional ``k_scales``/``v_scales`` per-page fp32
sidecars (``[N]``): pass them when the pool is int8-resident and every
backend dequantizes in its gather (the kernel via scalar-prefetched SMEM
scales, the oracle in its dense gather). ``None`` means bf16 pages — the
pre-quantization paths, bit-identical to before the format layer existed.
"""
from __future__ import annotations

import os

import jax

from repro.kernels import kv_quant
from repro.kernels.paged_attention.kernel import paged_attention as _pallas
from repro.kernels.paged_attention.ref import (
    paged_attention_decode_ref as _decode_ref,
)
from repro.kernels.paged_attention.ref import paged_attention_ref as _ref


def _interpret_forced() -> bool:
    return os.environ.get("REPRO_KERNEL_INTERPRET", "0") == "1"


def paged_attention(
    q, k_pages, v_pages, block_tables, lengths,
    k_scales=None, v_scales=None, *, softcap=None, window=None,
):
    """Decode attention over a paged KV pool (see kernel.py for layouts)."""
    if jax.default_backend() == "tpu":
        return _pallas(
            q, k_pages, v_pages, block_tables, lengths, k_scales, v_scales,
            softcap=softcap, window=window,
        )
    if _interpret_forced():
        return _pallas(
            q, k_pages, v_pages, block_tables, lengths, k_scales, v_scales,
            softcap=softcap, window=window, interpret=True,
        )
    # CPU/GPU: interpret the kernel for tiny shapes is too slow in prod paths;
    # use the jnp oracle (identical semantics, validated in tests).
    return _ref(
        q, k_pages, v_pages, block_tables, lengths, k_scales, v_scales,
        softcap=softcap, window=window,
    )


def paged_attention_decode(
    q, k_new, v_new, k_pages, v_pages, block_tables, lengths, tail_pages,
    tail_offsets, k_scales=None, v_scales=None, *, softcap=None, window=None,
):
    """Decode attention for a token whose KV is not yet in the pool.

    The serving hot path: ``k_new``/``v_new`` ``[B, KH, D]`` belong at
    ``(tail_pages[b], tail_offsets[b])`` = global position ``lengths - 1``.
    On CPU/GPU the oracle inserts them into its dense gather, so a layer
    scan over this op never materializes a full-pool copy per layer (the
    engine commits all layers' appends in one batched scatter after the
    scan). On TPU (and in forced-interpret parity runs) they are scattered
    into a copy of the layer's page slice before the Pallas kernel runs —
    XLA cannot alias that update while the caller still holds the arrays
    for the post-scan commit, so the TPU branch still pays one layer-slice
    copy per layer; folding k_new/v_new into the kernel as operands (the
    oracle's trick, done in VMEM) is the follow-up that removes it.

    On an int8 pool the pre-kernel scatter becomes a *requantize-insert*
    of the tail pages (their scale may grow to admit the new token), so
    the kernel sees a self-consistent quantized pool; the oracle inserts
    into its dequantized gather at full precision instead. The divergence
    is one token's quantization error — inside the parity band.
    """
    def _scatter_then_kernel(interpret: bool):
        if k_scales is not None:
            kp, ks = kv_quant.requantize_insert(
                k_pages, k_scales, tail_pages, tail_offsets, k_new
            )
            vp, vs = kv_quant.requantize_insert(
                v_pages, v_scales, tail_pages, tail_offsets, v_new
            )
        else:
            kp = k_pages.at[tail_pages, tail_offsets].set(
                k_new.astype(k_pages.dtype)
            )
            vp = v_pages.at[tail_pages, tail_offsets].set(
                v_new.astype(v_pages.dtype)
            )
            ks = vs = None
        return _pallas(
            q, kp, vp, block_tables, lengths, ks, vs,
            softcap=softcap, window=window, interpret=interpret,
        )

    if jax.default_backend() == "tpu":
        return _scatter_then_kernel(False)
    if _interpret_forced():
        return _scatter_then_kernel(True)
    return _decode_ref(
        q, k_new, v_new, k_pages, v_pages, block_tables, lengths,
        k_scales, v_scales, softcap=softcap, window=window,
    )


def audit_spec():
    """Example-shape jit target for :mod:`repro.analysis.jitaudit` — one
    decode step over a paged pool at one table bucket, probed against the
    next bucket (the structure must not depend on the table width)."""
    import jax.numpy as jnp

    def make(table_pages: int):
        def args():
            q = jnp.zeros((2, 4, 64), jnp.bfloat16)
            pages = jnp.zeros((16, 8, 4, 64), jnp.bfloat16)
            tables = jnp.zeros((2, table_pages), jnp.int32)
            lengths = jnp.ones(2, jnp.int32)
            return q, pages, pages, tables, lengths

        return args

    return {
        "name": "kernels.paged_attention",
        "fn": jax.jit(paged_attention),
        "make_args": make(4),
        "probe_args": make(8),
        "bucket": {"batch": 2, "table_pages": 4, "page_tokens": 8},
    }
