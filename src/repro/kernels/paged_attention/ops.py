"""Public entry point: Pallas kernel on TPU, oracle fallback elsewhere."""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _pallas
from repro.kernels.paged_attention.ref import paged_attention_ref as _ref


def paged_attention(q, k_pages, v_pages, block_tables, lengths, *, softcap=None):
    """Decode attention over a paged KV pool (see kernel.py for layouts)."""
    platform = jax.default_backend()
    if platform == "tpu":
        return _pallas(
            q, k_pages, v_pages, block_tables, lengths, softcap=softcap
        )
    # CPU/GPU: interpret the kernel for tiny shapes is too slow in prod paths;
    # use the jnp oracle (identical semantics, validated in tests).
    return _ref(q, k_pages, v_pages, block_tables, lengths, softcap=softcap)
