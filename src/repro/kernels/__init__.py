"""Pallas TPU kernels for the serving/compute hot-spots, each with a jnp
oracle (ref.py) and a jit'd dispatcher (ops.py). Validated in interpret
mode on CPU; TPU is the compilation target."""
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.paged_attention.ops import paged_attention
from repro.kernels.ssd.ops import ssd

__all__ = ["flash_attention", "paged_attention", "ssd"]
